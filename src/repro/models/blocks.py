"""Layer definitions for every architecture family in the pool.

Pure-functional: each ``*_defs`` function returns a PD tree (shapes +
logical sharding names); each ``*_fwd`` consumes the matching param tree.
Blocks are written to be stacked on a leading 'layers' axis and driven by
``lax.scan`` (see model.py's segment machinery).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import attention, attention_decode, update_kv_cache
from .params import PD
from .sharding import constrain

__all__ = [
    "rmsnorm", "rope", "block_defs", "block_fwd", "block_decode",
    "embed_defs", "moe_ffn", "init_cache_shapes",
]


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def rope(x, positions, theta: float):
    """x: (B,S,H,D); positions: (B,S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def swiglu(x, wi, wg, wo):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, wi))
    h = h * jnp.einsum("bsd,df->bsf", x, wg)
    return jnp.einsum("bsf,fd->bsd", h, wo)


# --------------------------------------------------------------------------
# attention sub-block
# --------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig, cross: bool = False) -> Dict[str, PD]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    out = {
        "wq": PD((d, h, hd), ("p_embed", "p_heads", "p_head_dim")),
        "wk": PD((d, kv, hd), ("p_embed", "p_kv_heads", "p_head_dim")),
        "wv": PD((d, kv, hd), ("p_embed", "p_kv_heads", "p_head_dim")),
        "wo": PD((h, hd, d), ("p_heads", "p_head_dim", "p_embed"),
                 scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }
    if cfg.qkv_bias:
        out["bq"] = PD((h, hd), ("p_heads", "p_head_dim"), init="zeros")
        out["bk"] = PD((kv, hd), ("p_kv_heads", "p_head_dim"), init="zeros")
        out["bv"] = PD((kv, hd), ("p_kv_heads", "p_head_dim"), init="zeros")
    if cross:
        out["gate"] = PD((), (), init="zeros")   # tanh-gated cross-attn
    return out


def _qkv(p, x, kv_x, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", kv_x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", kv_x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def attn_fwd(p, x, cfg: ModelConfig, *, positions, window: int,
             causal: bool = True, kv_x=None, cross_positions=None,
             impl: Optional[str] = None):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    kv_inp = x if kv_x is None else kv_x
    q, k, v = _qkv(p, x, kv_inp, cfg)
    if causal or kv_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions if cross_positions is None else cross_positions,
                 cfg.rope_theta)
    o = attention(q, k, v, causal=causal, window=window,
                  impl=impl or "scan",
                  block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
    if "gate" in p:
        o = o * jnp.tanh(p["gate"]).astype(o.dtype)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(x.dtype))
    return out, (k, v)


def attn_decode_fwd(p, x, cfg: ModelConfig, *, cache, pos, window: int,
                    static_kv: bool = False):
    """One-token decode. cache = (k_cache, v_cache); pos = write index."""
    q, k_new, v_new = _qkv(p, x, x, cfg)
    k_cache, v_cache = cache
    if static_kv:
        # cross-attention: cache holds the (already-projected) memory
        o = attention_decode(q, k_cache, v_cache, window=0)
    else:
        posv = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
        q = rope(q, posv, cfg.rope_theta)
        k_new = rope(k_new, posv, cfg.rope_theta)
        k_cache, v_cache = update_kv_cache(k_cache, v_cache, k_new, v_new,
                                           pos)
        valid = jnp.minimum(pos + 1, k_cache.shape[1])
        o = attention_decode(q, k_cache, v_cache, window=window,
                             valid_len=valid)
    if "gate" in p:
        o = o * jnp.tanh(p["gate"]).astype(o.dtype)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(x.dtype))
    return out, (k_cache, v_cache)


# --------------------------------------------------------------------------
# MoE FFN (capacity-buffer dispatch; experts shard over 'model' => EP)
# --------------------------------------------------------------------------

def moe_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, ef, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    out = {
        "router": PD((d, e), ("p_embed", "experts")),
        "wi": PD((e, d, ef), ("experts", "p_embed", "p_expert_mlp")),
        "wg": PD((e, d, ef), ("experts", "p_embed", "p_expert_mlp")),
        "wo": PD((e, ef, d), ("experts", "p_expert_mlp", "p_embed"),
                 scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }
    if cfg.n_shared_experts:
        sf = cfg.n_shared_experts * ef
        out["shared"] = {
            "wi": PD((d, sf), ("p_embed", "p_mlp")),
            "wg": PD((d, sf), ("p_embed", "p_mlp")),
            "wo": PD((sf, d), ("p_mlp", "p_embed"),
                     scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
        }
    return out


def moe_ffn_dense(p, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense-dispatch MoE: every expert runs on every token, combined with
    the (renormalized) top-k gates.

    §Perf lever for few-expert MoEs (mixtral E=8, k=2): E/k more expert
    FLOPs in exchange for ZERO token movement — no scatter/gather, so the
    autodiff of the dispatch generates no cross-shard all-reduces (the
    dominant collective cost of the scatter path at scale).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("bsd,de->bse", x,
                        p["router"].astype(x.dtype)).astype(jnp.float32)
    gate_vals, idx = jax.lax.top_k(logits, k)
    gates_k = jax.nn.softmax(gate_vals, axis=-1)
    # scatter top-k gates into dense (B,S,E) via one-hot combine
    gates = jnp.einsum("bske,bsk->bse", jax.nn.one_hot(idx, e), gates_k)
    probs = jax.nn.softmax(logits, axis=-1)
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros(e).at[idx.reshape(-1)].add(1.0) / (b * s * k)
    aux = e * jnp.sum(me * ce)

    h = jax.nn.silu(jnp.einsum("bsd,edf->ebsf", x,
                               p["wi"].astype(x.dtype)))
    h = h * jnp.einsum("bsd,edf->ebsf", x, p["wg"].astype(x.dtype))
    y = jnp.einsum("ebsf,efd->ebsd", h, p["wo"].astype(x.dtype))
    out = jnp.einsum("ebsd,bse->bsd", y, gates.astype(x.dtype))
    if "shared" in p:
        sh = p["shared"]
        out = out + swiglu(x, sh["wi"].astype(x.dtype),
                           sh["wg"].astype(x.dtype),
                           sh["wo"].astype(x.dtype))
    return out, aux.astype(jnp.float32)


def moe_ffn(p, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k capacity-buffer MoE. Returns (out, aux_loss)."""
    if getattr(cfg, "moe_impl", "scatter") == "dense":
        return moe_ffn_dense(p, x, cfg)
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = int(cfg.capacity_factor * n * k / e)
    cap = max(8, -(-cap // 8) * 8)
    xt = x.reshape(n, d)
    logits = jnp.einsum("nd,de->ne", xt, p["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    gate_vals, idx = jax.lax.top_k(logits, k)               # (N,k)
    gates = jax.nn.softmax(gate_vals, axis=-1)
    # aux load-balancing loss (Switch-style)
    probs = jax.nn.softmax(logits, axis=-1)                 # (N,E)
    me = probs.mean(axis=0)
    ce = jnp.zeros(e).at[idx.reshape(-1)].add(1.0) / (n * k)
    aux = e * jnp.sum(me * ce)

    flat_e = idx.reshape(-1)                                # (N*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e))
    ranks_sorted = jnp.arange(n * k) - starts[sorted_e]
    ranks = jnp.zeros(n * k, jnp.int32).at[order].set(
        ranks_sorted.astype(jnp.int32))
    keep = ranks < cap
    slot = jnp.where(keep, flat_e * cap + ranks, e * cap)   # drop -> sentinel
    tok = jnp.repeat(jnp.arange(n), k)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(xt[tok])
    buf = buf[:e * cap].reshape(e, cap, d)
    # EP dispatch boundary. Baseline: capacity dim replicated (every data
    # shard computes every expert row). §Perf lever `moe_dispatch_2d`
    # shards capacity over 'data' => true (experts x data) 2D dispatch.
    cap_name = "expert_cap" if cfg.moe_dispatch_2d else None
    buf = constrain(buf, "experts", cap_name, "embed")
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(x.dtype))
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))
    y = constrain(y, "experts", cap_name, "embed")
    y = jnp.concatenate([y.reshape(e * cap, d),
                         jnp.zeros((1, d), x.dtype)], axis=0)
    out_tok = y[slot] * gates.reshape(-1)[:, None].astype(x.dtype)
    out = out_tok.reshape(n, k, d).sum(axis=1).reshape(b, s, d)
    if "shared" in p:
        sh = p["shared"]
        out = out + swiglu(x, sh["wi"].astype(x.dtype),
                           sh["wg"].astype(x.dtype),
                           sh["wo"].astype(x.dtype))
    return out, aux.astype(jnp.float32)


# --------------------------------------------------------------------------
# RWKV6 time-mix / channel-mix (Finch: data-dependent decay)
# --------------------------------------------------------------------------

def rwkv_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, dff = cfg.d_model, cfg.d_ff
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    lora = 64
    return {
        "mu": PD((5, d), (None, "p_embed")),         # r,k,v,w,g token-shift
        "wr": PD((d, d), ("p_embed", "p_mlp")),
        "wk": PD((d, d), ("p_embed", "p_mlp")),
        "wv": PD((d, d), ("p_embed", "p_mlp")),
        "wg": PD((d, d), ("p_embed", "p_mlp")),
        "w0": PD((h, hd), ("p_heads", "p_head_dim"), init="zeros"),
        "wa": PD((d, lora), ("p_embed", None)),
        "wb": PD((lora, d), (None, "p_mlp")),
        "u": PD((h, hd), ("p_heads", "p_head_dim")),
        "ln_x": PD((d,), ("p_embed",), init="ones"),
        "wo": PD((d, d), ("p_mlp", "p_embed"),
                 scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
        "cm_mu": PD((2, d), (None, "p_embed")),      # channel-mix shifts
        "cm_wk": PD((d, dff), ("p_embed", "p_mlp")),
        "cm_wv": PD((dff, d), ("p_mlp", "p_embed"),
                    scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
        "cm_wr": PD((d, d), ("p_embed", "p_mlp")),
    }


def _token_shift(x, x_prev):
    """x: (B,S,D); x_prev: (B,D) last token of previous segment."""
    shifted = jnp.concatenate(
        [x_prev[:, None, :].astype(x.dtype), x[:, :-1, :]], axis=1)
    return shifted


def rwkv_time_mix(p, x, cfg: ModelConfig, state, x_prev):
    """state: (B,H,hd,hd) recurrent matrix; x_prev: (B,D).

    Returns (out, new_state, new_x_prev). Sequential scan over time — the
    chunked Pallas kernel replaces this on TPU (kernels/rwkv6_scan.py).
    """
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    xs = _token_shift(x, x_prev)
    mu = p["mu"].astype(x.dtype)
    xr = x + (xs - x) * mu[0]
    xk = x + (xs - x) * mu[1]
    xv = x + (xs - x) * mu[2]
    xw = x + (xs - x) * mu[3]
    xg = x + (xs - x) * mu[4]
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(x.dtype)))
    # data-dependent decay (the Finch signature): w = exp(-exp(w0 + lora))
    dw = jnp.einsum("bsd,dl,le->bse", xw, p["wa"].astype(x.dtype),
                    p["wb"].astype(x.dtype))
    w_log = -jnp.exp(jnp.clip(
        p["w0"].reshape(-1).astype(jnp.float32) + dw.astype(jnp.float32),
        -8.0, 4.0))                                     # (B,S,D), <= 0
    r = r.reshape(b, s, h, hd)
    k = k.reshape(b, s, h, hd)
    v = v.reshape(b, s, h, hd)
    w = jnp.exp(w_log).reshape(b, s, h, hd)             # decay in (0,1)
    u = p["u"].astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                            # (B,H,hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, yt

    xs_t = (r.transpose(1, 0, 2, 3).astype(jnp.float32),
            k.transpose(1, 0, 2, 3).astype(jnp.float32),
            v.transpose(1, 0, 2, 3).astype(jnp.float32),
            w.transpose(1, 0, 2, 3).astype(jnp.float32))
    blk_g = max(int(cfg.rwkv_scan_block), 1)
    if blk_g > 1 and s % blk_g == 0 and s > blk_g:
        # §Perf lever: G timesteps per scan iteration — the (hd x hd)
        # recurrent state round-trips HBM once per block instead of once
        # per token (the Pallas kernel keeps it VMEM-resident entirely).
        xs_blk = tuple(a.reshape(s // blk_g, blk_g, *a.shape[1:])
                       for a in xs_t)

        def block_step(S, blk):
            ys = []
            for i in range(blk_g):
                S, yt = step(S, tuple(a[i] for a in blk))
                ys.append(yt)
            return S, jnp.stack(ys)

        new_state, ys = jax.lax.scan(block_step, state.astype(jnp.float32),
                                     xs_blk)
        ys = ys.reshape(s, *ys.shape[2:])
    else:
        new_state, ys = jax.lax.scan(step, state.astype(jnp.float32), xs_t)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y = rmsnorm(y, p["ln_x"].astype(x.dtype), cfg.norm_eps) * g
    out = jnp.einsum("bsd,de->bse", y, p["wo"].astype(x.dtype))
    return out, new_state.astype(jnp.float32), x[:, -1, :]


def rwkv_channel_mix(p, x, cfg: ModelConfig, x_prev):
    xs = _token_shift(x, x_prev)
    mu = p["cm_mu"].astype(x.dtype)
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    kk = jnp.square(jax.nn.relu(
        jnp.einsum("bsd,df->bsf", xk, p["cm_wk"].astype(x.dtype))))
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, p["cm_wr"].astype(x.dtype)))
    out = rr * jnp.einsum("bsf,fd->bsd", kk, p["cm_wv"].astype(x.dtype))
    return out, x[:, -1, :]


# --------------------------------------------------------------------------
# Hymba-style parallel SSM heads (diagonal selective state space)
# --------------------------------------------------------------------------

def ssm_defs(cfg: ModelConfig) -> Dict[str, PD]:
    d = cfg.d_model
    h = cfg.ssm_heads or cfg.n_heads
    hd = cfg.resolved_head_dim
    st = cfg.ssm_state
    return {
        "wx": PD((d, h, hd), ("p_embed", "p_heads", "p_head_dim")),
        "wdt": PD((d, h), ("p_embed", "p_heads")),
        "wB": PD((d, h, st), ("p_embed", "p_heads", "ssm_state")),
        "wC": PD((d, h, st), ("p_embed", "p_heads", "ssm_state")),
        "a_log": PD((h, st), ("p_heads", "ssm_state")),
        "skip": PD((h,), ("p_heads",), init="ones"),
        "wo": PD((h, hd, d), ("p_heads", "p_head_dim", "p_embed"),
                 scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def ssm_fwd(p, x, cfg: ModelConfig, state):
    """state: (B,H,hd,st). Sequential selective scan; returns (out, state)."""
    b, s, d = x.shape
    h = cfg.ssm_heads or cfg.n_heads
    hd, st = cfg.resolved_head_dim, cfg.ssm_state
    xh = jnp.einsum("bsd,dhe->bshe", x, p["wx"].astype(x.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(x.dtype))
        .astype(jnp.float32))
    bb = jnp.einsum("bsd,dhn->bshn", x, p["wB"].astype(x.dtype))
    cc = jnp.einsum("bsd,dhn->bshn", x, p["wC"].astype(x.dtype))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))        # (H,st), < 0

    def step(hstate, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt[..., None] * a[None])       # (B,H,st)
        upd = jnp.einsum("bhe,bhn->bhen", xt, bt * dtt[..., None])
        hstate = hstate * decay[:, :, None, :] + upd
        yt = jnp.einsum("bhen,bhn->bhe", hstate, ct)
        return hstate, yt

    inp = (xh.transpose(1, 0, 2, 3).astype(jnp.float32),
           dt.transpose(1, 0, 2),
           bb.transpose(1, 0, 2, 3).astype(jnp.float32),
           cc.transpose(1, 0, 2, 3).astype(jnp.float32))
    blk_g = max(int(cfg.rwkv_scan_block), 1)
    if blk_g > 1 and s % blk_g == 0 and s > blk_g:
        inp_blk = tuple(a.reshape(s // blk_g, blk_g, *a.shape[1:])
                        for a in inp)

        def block_step(hs, blk):
            ys = []
            for i in range(blk_g):
                hs, yt = step(hs, tuple(a[i] for a in blk))
                ys.append(yt)
            return hs, jnp.stack(ys)

        new_state, ys = jax.lax.scan(block_step, state.astype(jnp.float32),
                                     inp_blk)
        ys = ys.reshape(s, *ys.shape[2:])
    else:
        new_state, ys = jax.lax.scan(step, state.astype(jnp.float32), inp)
    y = ys.transpose(1, 0, 2, 3)
    y = y + xh.astype(jnp.float32) * p["skip"].astype(jnp.float32)[None, None,
                                                                   :, None]
    out = jnp.einsum("bshe,hed->bsd", y.astype(x.dtype),
                     p["wo"].astype(x.dtype))
    return out, new_state.astype(jnp.float32)


# --------------------------------------------------------------------------
# block assembly per family
# --------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, PD]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi": PD((d, f), ("p_embed", "p_mlp")),
        "wg": PD((d, f), ("p_embed", "p_mlp")),
        "wo": PD((f, d), ("p_mlp", "p_embed"),
                 scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def block_defs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    """kind: dense | dense_swa | dense_global | moe | moe_swa | rwkv |
    hybrid | hybrid_global | enc | dec | cross."""
    d = cfg.d_model
    ln = lambda: PD((d,), ("p_embed",), init="ones")  # noqa: E731
    if kind == "rwkv":
        return {"ln1": ln(), "tm": rwkv_defs(cfg), "ln2": ln(),
                "cm": {k: v for k, v in rwkv_defs(cfg).items()
                       if k.startswith("cm_")}}
    if kind in ("hybrid", "hybrid_global"):
        return {"ln1": ln(), "attn": attn_defs(cfg), "ssm": ssm_defs(cfg),
                "ln_attn": ln(), "ln_ssm": ln(),
                "ln2": ln(), "mlp": mlp_defs(cfg)}
    if kind in ("moe", "moe_swa"):
        return {"ln1": ln(), "attn": attn_defs(cfg), "ln2": ln(),
                "moe": moe_defs(cfg)}
    if kind == "dec":
        return {"ln1": ln(), "attn": attn_defs(cfg),
                "lnx": ln(), "xattn": attn_defs(cfg),
                "ln2": ln(), "mlp": mlp_defs(cfg)}
    if kind == "cross":
        return {"lnx": ln(), "xattn": attn_defs(cfg, cross=True),
                "ln2": ln(), "mlp": mlp_defs(cfg)}
    # dense / dense_swa / dense_global / enc / dense_wide
    d_ff = cfg.d_ff
    return {"ln1": ln(), "attn": attn_defs(cfg), "ln2": ln(),
            "mlp": mlp_defs(cfg, d_ff)}


def _window_for(cfg: ModelConfig, kind: str) -> int:
    if kind.endswith("_swa") or kind == "hybrid":
        return cfg.sliding_window
    return 0


def block_fwd(p, x, cfg: ModelConfig, kind: str, *, positions,
              memory=None, impl: Optional[str] = None,
              carry: Optional[Dict[str, Any]] = None):
    """Full-sequence forward. Returns (x, aux_loss, new_carry).

    ``carry`` holds recurrent state for rwkv/ssm blocks (threaded across
    sequence chunks); attention caches are not materialized in train mode.
    """
    aux = jnp.zeros((), jnp.float32)
    new_carry: Dict[str, Any] = {}
    window = _window_for(cfg, kind)
    if kind == "rwkv":
        h, tm_state, xp = rwkv_time_mix(
            p["tm"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
            carry["tm_state"], carry["tm_xprev"])
        new_carry["tm_state"], new_carry["tm_xprev"] = tm_state, xp
        x = x + h
        h, xp2 = rwkv_channel_mix(p["cm"],
                                  rmsnorm(x, p["ln2"], cfg.norm_eps), cfg,
                                  carry["cm_xprev"])
        new_carry["cm_xprev"] = xp2
        return x + h, aux, new_carry
    if kind in ("hybrid", "hybrid_global"):
        xin = rmsnorm(x, p["ln1"], cfg.norm_eps)
        ao, _ = attn_fwd(p["attn"], xin, cfg, positions=positions,
                         window=window, impl=impl)
        so, sstate = ssm_fwd(p["ssm"], xin, cfg, carry["ssm_state"])
        new_carry["ssm_state"] = sstate
        h = 0.5 * (rmsnorm(ao, p["ln_attn"], cfg.norm_eps)
                   + rmsnorm(so, p["ln_ssm"], cfg.norm_eps))
        x = x + h
        m = p["mlp"]
        x = x + swiglu(rmsnorm(x, p["ln2"], cfg.norm_eps),
                       m["wi"].astype(x.dtype), m["wg"].astype(x.dtype),
                       m["wo"].astype(x.dtype))
        return x, aux, new_carry
    if kind == "cross":
        h, _ = attn_fwd(p["xattn"], rmsnorm(x, p["lnx"], cfg.norm_eps), cfg,
                        positions=positions, window=0, causal=False,
                        kv_x=memory, impl=impl)
        x = x + h
        m = p["mlp"]
        x = x + swiglu(rmsnorm(x, p["ln2"], cfg.norm_eps),
                       m["wi"].astype(x.dtype), m["wg"].astype(x.dtype),
                       m["wo"].astype(x.dtype))
        return x, aux, new_carry
    # attention blocks (dense / moe / enc / dec)
    causal = kind != "enc"
    h, _ = attn_fwd(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
                    positions=positions, window=window, causal=causal,
                    impl=impl)
    x = x + h
    if kind == "dec":
        h, _ = attn_fwd(p["xattn"], rmsnorm(x, p["lnx"], cfg.norm_eps), cfg,
                        positions=positions, window=0, causal=False,
                        kv_x=memory, impl=impl)
        x = x + h
    if kind in ("moe", "moe_swa"):
        h, aux = moe_ffn(p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
        x = x + h
    else:
        m = p["mlp"]
        x = x + swiglu(rmsnorm(x, p["ln2"], cfg.norm_eps),
                       m["wi"].astype(x.dtype), m["wg"].astype(x.dtype),
                       m["wo"].astype(x.dtype))
    return x, aux, new_carry


def block_decode(p, x, cfg: ModelConfig, kind: str, *, cache, pos):
    """One-token decode. cache is a dict; returns (x, new_cache)."""
    window = _window_for(cfg, kind)
    new_cache: Dict[str, Any] = {}
    if kind == "rwkv":
        h, st, xp = rwkv_time_mix(p["tm"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                                  cfg, cache["tm_state"], cache["tm_xprev"])
        new_cache["tm_state"], new_cache["tm_xprev"] = st, xp
        x = x + h
        h, xp2 = rwkv_channel_mix(p["cm"],
                                  rmsnorm(x, p["ln2"], cfg.norm_eps), cfg,
                                  cache["cm_xprev"])
        new_cache["cm_xprev"] = xp2
        return x + h, new_cache
    if kind in ("hybrid", "hybrid_global"):
        xin = rmsnorm(x, p["ln1"], cfg.norm_eps)
        ao, kvc = attn_decode_fwd(p["attn"], xin, cfg,
                                  cache=(cache["k"], cache["v"]), pos=pos,
                                  window=window)
        new_cache["k"], new_cache["v"] = kvc
        so, sstate = ssm_fwd(p["ssm"], xin, cfg, cache["ssm_state"])
        new_cache["ssm_state"] = sstate
        h = 0.5 * (rmsnorm(ao, p["ln_attn"], cfg.norm_eps)
                   + rmsnorm(so, p["ln_ssm"], cfg.norm_eps))
        x = x + h
        m = p["mlp"]
        x = x + swiglu(rmsnorm(x, p["ln2"], cfg.norm_eps),
                       m["wi"].astype(x.dtype), m["wg"].astype(x.dtype),
                       m["wo"].astype(x.dtype))
        return x, new_cache
    h, kvc = attn_decode_fwd(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                             cfg, cache=(cache["k"], cache["v"]), pos=pos,
                             window=window)
    new_cache["k"], new_cache["v"] = kvc
    x = x + h
    if kind in ("dec", "cross"):
        h, _ = attn_decode_fwd(p["xattn"],
                               rmsnorm(x, p["lnx"], cfg.norm_eps), cfg,
                               cache=(cache["xk"], cache["xv"]), pos=pos,
                               window=0, static_kv=True)
        new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
        x = x + h
    if kind in ("moe", "moe_swa"):
        h, _ = moe_ffn(p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
        x = x + h
    else:
        m = p["mlp"]
        x = x + swiglu(rmsnorm(x, p["ln2"], cfg.norm_eps),
                       m["wi"].astype(x.dtype), m["wg"].astype(x.dtype),
                       m["wo"].astype(x.dtype))
    return x, new_cache


def block_decode_cross(p, x, cfg: ModelConfig, *, cache, pos):
    """Decode through a VLM 'cross' block (no self-attention)."""
    h, _ = attn_decode_fwd(p["xattn"], rmsnorm(x, p["lnx"], cfg.norm_eps),
                           cfg, cache=(cache["xk"], cache["xv"]), pos=pos,
                           window=0, static_kv=True)
    x = x + h
    m = p["mlp"]
    x = x + swiglu(rmsnorm(x, p["ln2"], cfg.norm_eps),
                   m["wi"].astype(x.dtype), m["wg"].astype(x.dtype),
                   m["wo"].astype(x.dtype))
    return x, dict(cache)


# --------------------------------------------------------------------------
# embeddings + cache shape declarations
# --------------------------------------------------------------------------

def embed_defs(cfg: ModelConfig) -> Dict[str, PD]:
    d = cfg.d_model
    out = {
        "tok": PD((cfg.vocab, d), ("vocab", "p_embed"), scale=1.0),
        "ln_f": PD((d,), ("p_embed",), init="ones"),
        "unembed": PD((d, cfg.vocab), ("p_embed", "vocab")),
    }
    if cfg.encoder_seq:
        out["enc_pos"] = PD((cfg.encoder_seq, d), ("enc_seq", "p_embed"),
                            scale=0.02)
    return out


def cache_defs_for_kind(cfg: ModelConfig, kind: str, batch: int,
                        seq: int) -> Dict[str, Tuple[Tuple[int, ...], Tuple]]:
    """Cache entry shapes + logical names for one block of ``kind``."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    h = cfg.ssm_heads or cfg.n_heads
    window = _window_for(cfg, kind)
    s_eff = min(seq, window) if window else seq
    out: Dict[str, Tuple[Tuple[int, ...], Tuple]] = {}
    if kind == "rwkv":
        d = cfg.d_model
        out["tm_state"] = ((batch, cfg.n_heads, hd, hd),
                           ("batch", "heads", "head_dim", None))
        out["tm_xprev"] = ((batch, d), ("batch", "embed"))
        out["cm_xprev"] = ((batch, d), ("batch", "embed"))
        return out
    if kind in ("hybrid", "hybrid_global"):
        out["ssm_state"] = ((batch, h, hd, cfg.ssm_state),
                            ("batch", "heads", "head_dim", "ssm_state"))
    if kind != "rwkv":
        out["k"] = ((batch, s_eff, kv, hd),
                    ("batch", "cache_seq", "kv_heads", "head_dim"))
        out["v"] = ((batch, s_eff, kv, hd),
                    ("batch", "cache_seq", "kv_heads", "head_dim"))
    if kind in ("dec", "cross"):
        mem = cfg.encoder_seq or cfg.vision_seq
        out["xk"] = ((batch, mem, kv, hd),
                     ("batch", None, "kv_heads", "head_dim"))
        out["xv"] = ((batch, mem, kv, hd),
                     ("batch", None, "kv_heads", "head_dim"))
    if kind == "cross":
        out.pop("k"), out.pop("v")
    return out


def init_cache_shapes(cfg, kind, batch, seq):
    return cache_defs_for_kind(cfg, kind, batch, seq)
