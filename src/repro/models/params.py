"""Parameter declaration system: shapes + logical sharding names + init.

Every parameter is declared once as a ``PD(shape, names, scale)``; the same
tree drives (a) random init, (b) ``ShapeDtypeStruct`` construction for the
dry-run (no allocation), and (c) NamedSharding resolution via the logical
rules in ``sharding.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PD", "init_params", "shape_tree", "names_tree", "count_params"]


@dataclasses.dataclass(frozen=True)
class PD:
    """Parameter definition: shape, logical axis names, init scale."""

    shape: Tuple[int, ...]
    names: Tuple[Optional[str], ...]
    scale: float = 1.0
    init: str = "normal"        # normal | zeros | ones
    dtype: Optional[str] = None  # override param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.names), (self.shape, self.names)


def _is_pd(x):
    return isinstance(x, PD)


def init_params(rng: jax.Array, defs, param_dtype: str = "float32"):
    """Materialize a PD tree into a parameter tree."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=_is_pd)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        dt = jnp.dtype(d.dtype or param_dtype)
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            fan_in = d.shape[0] if d.shape else 1
            std = d.scale / np.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, d.shape, jnp.float32)
                        * std).astype(dt))
    return jax.tree_util.tree_unflatten(treedef, out)


def shape_tree(defs, param_dtype: str = "float32"):
    """PD tree -> ShapeDtypeStruct tree (dry-run, no allocation)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape,
                                       jnp.dtype(d.dtype or param_dtype)),
        defs, is_leaf=_is_pd)


def names_tree(defs):
    return jax.tree_util.tree_map(lambda d: d.names, defs, is_leaf=_is_pd)


def count_params(defs) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=_is_pd)
    return int(sum(int(np.prod(d.shape)) for d in leaves))
