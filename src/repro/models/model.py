"""Model assembly: layer plans, parameter trees, train/prefill/decode steps.

A config resolves to a *layer plan* — an ordered list of (block kind,
count) segments; each multi-layer segment is a ``lax.scan`` over stacked
parameters (with optional remat), which keeps the HLO small even for
88-layer models. Recurrent families (rwkv / hybrid) thread their state
through the blocks; decode threads per-layer caches.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from . import blocks as B
from .params import PD, init_params, names_tree, shape_tree
from .sharding import constrain

__all__ = ["layer_plan", "model_defs", "init_model", "forward", "loss_fn",
           "prefill", "decode_step", "input_specs", "cache_specs",
           "Segment"]


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str
    count: int


def layer_plan(cfg: ModelConfig) -> List[Segment]:
    f = cfg.family
    L = cfg.n_layers
    if f == "dense":
        kind = "dense_swa" if cfg.sliding_window else "dense"
        return [Segment(kind, L)]
    if f == "moe":
        kind = "moe_swa" if cfg.sliding_window else "moe"
        segs = []
        if cfg.first_dense_layers:
            segs.append(Segment("dense", cfg.first_dense_layers))
        segs.append(Segment(kind, L - cfg.first_dense_layers))
        return segs
    if f == "ssm":
        return [Segment("rwkv", L)]
    if f == "hybrid":
        # global full-attention at first / middle / last layer (hymba),
        # sliding-window + parallel SSM heads elsewhere.
        glb = {0, L // 2, L - 1}
        kinds = ["hybrid_global" if i in glb else "hybrid"
                 for i in range(L)]
        segs: List[Segment] = []
        for k in kinds:
            if segs and segs[-1].kind == k:
                segs[-1] = Segment(k, segs[-1].count + 1)
            else:
                segs.append(Segment(k, 1))
        return segs
    if f == "encdec":
        return [Segment("dec", L)]
    if f == "vlm":
        period = cfg.cross_attn_period
        n_cross = L // period
        n_self = L - n_cross
        per_group = period - 1
        segs: List[Segment] = []
        for _ in range(n_cross):
            segs.append(Segment("dense", per_group))
            segs.append(Segment("cross", 1))
        rem = n_self - n_cross * per_group
        if rem > 0:
            segs.append(Segment("dense", rem))
        return segs
    raise ValueError(f"unknown family {f}")


def encoder_plan(cfg: ModelConfig) -> List[Segment]:
    if cfg.encoder_layers:
        return [Segment("enc", cfg.encoder_layers)]
    return []


def _stack_defs(defs, n: int):
    """Add a leading 'layers' axis of extent n to every PD in the tree."""
    return jax.tree_util.tree_map(
        lambda d: PD((n,) + d.shape, ("layers",) + d.names,
                     scale=d.scale, init=d.init, dtype=d.dtype),
        defs, is_leaf=lambda x: isinstance(x, PD))


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    segs = layer_plan(cfg)
    out: Dict[str, Any] = {
        "embed": B.embed_defs(cfg),
        "segments": [
            _stack_defs(B.block_defs(cfg, s.kind), s.count)
            if s.count > 1 else B.block_defs(cfg, s.kind)
            for s in segs
        ],
    }
    enc = encoder_plan(cfg)
    if enc:
        out["encoder"] = [
            _stack_defs(B.block_defs(cfg, s.kind), s.count)
            if s.count > 1 else B.block_defs(cfg, s.kind)
            for s in enc
        ]
        out["embed"]["enc_ln"] = PD((cfg.d_model,), ("p_embed",),
                                    init="ones")
    return out


def init_model(cfg: ModelConfig, rng: jax.Array):
    return init_params(rng, model_defs(cfg), cfg.param_dtype)


def param_specs(cfg: ModelConfig):
    defs = model_defs(cfg)
    return shape_tree(defs, cfg.param_dtype), names_tree(defs)


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def _zero_carry(cfg: ModelConfig, kind: str, batch: int):
    h = cfg.ssm_heads or cfg.n_heads
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    if kind == "rwkv":
        return {
            "tm_state": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
            "tm_xprev": jnp.zeros((batch, d), jnp.float32),
            "cm_xprev": jnp.zeros((batch, d), jnp.float32),
        }
    if kind in ("hybrid", "hybrid_global"):
        return {"ssm_state": jnp.zeros((batch, h, hd, cfg.ssm_state),
                                       jnp.float32)}
    return {}


def _run_segment(seg_p, x, cfg: ModelConfig, seg: Segment, *, positions,
                 memory, impl, return_cache: bool):
    """Returns (x, aux, caches) — caches stacked over the segment layers."""
    b = x.shape[0]

    def one(p, x):
        x = constrain(x, "batch", "seq", "embed")
        carry = _zero_carry(cfg, seg.kind, b)
        if seg.kind == "rwkv":
            xx, aux, nc = B.block_fwd(p, x, cfg, seg.kind,
                                      positions=positions, memory=memory,
                                      impl=impl, carry=carry)
        elif seg.kind in ("hybrid", "hybrid_global"):
            xx, aux, nc = B.block_fwd(p, x, cfg, seg.kind,
                                      positions=positions, memory=memory,
                                      impl=impl, carry=carry)
        else:
            xx, aux, nc = B.block_fwd(p, x, cfg, seg.kind,
                                      positions=positions, memory=memory,
                                      impl=impl)
        cache = _build_cache(p, nc, x, cfg, seg.kind, memory,
                             impl) if return_cache else {}
        return xx, aux, cache

    if seg.count == 1:
        x, aux, cache = one(seg_p, x)
        return x, aux, cache

    def body(carry, p):
        x, aux = carry
        xx, a, cache = one(p, x)
        return (xx, aux + a), cache

    if cfg.remat:
        if cfg.remat_policy == "dots":
            policies = jax.checkpoint_policies
            body = jax.checkpoint(
                body,
                policy=policies.dots_with_no_batch_dims_saveable)
        else:
            body = jax.checkpoint(body)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    seg_p)
    return x, aux, caches


def _build_cache(p, new_carry, x_in, cfg: ModelConfig, kind: str, memory,
                 impl):
    """Materialize decode caches during prefill."""
    cache: Dict[str, Any] = {}
    window = cfg.sliding_window if (kind.endswith("_swa")
                                    or kind == "hybrid") else 0
    if kind == "rwkv":
        return dict(new_carry)
    if kind in ("hybrid", "hybrid_global"):
        cache["ssm_state"] = new_carry["ssm_state"]
    if kind != "cross":
        # recompute k/v projections for the cache (cheap relative to attn)
        xin = B.rmsnorm(x_in, p["ln1"], cfg.norm_eps)
        positions = jnp.arange(x_in.shape[1], dtype=jnp.int32)
        _, k, v = B._qkv(p["attn"], xin, xin, cfg)
        k = B.rope(k, positions, cfg.rope_theta)
        # static branch: window is config, k.shape is fixed at trace time
        if window and k.shape[1] > window:  # analysis: ignore[tracer-branch]
            k, v = k[:, -window:], v[:, -window:]
        cache["k"], cache["v"] = k, v
    if kind in ("dec", "cross"):
        _, xk, xv = B._qkv(p["xattn"], memory, memory, cfg)
        cache["xk"], cache["xv"] = xk, xv
    return cache


def encode(params, cfg: ModelConfig, frames):
    """Whisper-style encoder over stub frame embeddings."""
    x = frames + params["embed"]["enc_pos"][None].astype(frames.dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    for seg_p, seg in zip(params["encoder"], encoder_plan(cfg)):
        x, _, _ = _run_segment(seg_p, x, cfg, seg, positions=positions,
                               memory=None, impl=None, return_cache=False)
    return B.rmsnorm(x, params["embed"]["enc_ln"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens, *, memory=None,
            impl: Optional[str] = None, return_cache: bool = False):
    """tokens: (B,S) -> logits (B,S,V) [+ caches]. memory: encoder/vision
    embeddings for encdec/vlm families (from the stub frontend)."""
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"]["tok"].astype(dtype)[tokens]
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    if memory is not None:
        memory = constrain(memory.astype(dtype), "batch", None, "embed")
    aux = jnp.zeros((), jnp.float32)
    caches = []
    for seg_p, seg in zip(params["segments"], layer_plan(cfg)):
        x, a, cache = _run_segment(seg_p, x, cfg, seg, positions=positions,
                                   memory=memory, impl=impl,
                                   return_cache=return_cache)
        aux = aux + a
        caches.append(cache)
    x = constrain(x, "batch", "seq", "embed")
    x = B.rmsnorm(x, params["embed"]["ln_f"].astype(dtype), cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["embed"]["unembed"].astype(dtype))
    logits = constrain(logits, "batch", "seq", "vocab")
    if return_cache:
        return logits, aux, caches
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch, *, impl: Optional[str] = None):
    """Next-token cross entropy (+0.01 * MoE aux)."""
    tokens = batch["tokens"]
    memory = batch.get("memory")
    if cfg.family == "encdec":
        memory = encode(params, cfg, batch["frames"])
    logits, aux = forward(params, cfg, tokens, memory=memory, impl=impl)
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1)[..., 0]
    ce = (logz - gold).mean()
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, tokens, *, memory=None,
            impl: Optional[str] = None, cache_len: Optional[int] = None):
    """Full-sequence prefill: returns (last-token logits, caches).

    ``cache_len`` pads full-attention KV caches to a target capacity so
    that decode can append. SWA caches are ring buffers of capacity
    ``window``; prefill length must be a multiple of the window so the
    ring write pointer (pos % window) lines up with the oldest entry.
    """
    s = tokens.shape[1]
    if cfg.sliding_window and s % cfg.sliding_window != 0:
        raise ValueError("prefill length must be a multiple of the window")
    if cfg.family == "encdec":
        memory = encode(params, cfg, memory)
    logits, _, caches = forward(params, cfg, tokens, memory=memory,
                                impl=impl, return_cache=True)
    if cache_len is not None and cache_len > s:
        pad = cache_len - s

        def pad_kv(seg_cache):
            out = dict(seg_cache)
            for key in ("k", "v"):
                if key in out and out[key].shape[-3] == s:
                    widths = [(0, 0)] * out[key].ndim
                    widths[-3] = (0, pad)
                    out[key] = jnp.pad(out[key], widths)
            return out

        caches = [pad_kv(c) for c in caches]
    return logits[:, -1:], caches


def decode_step(params, cfg: ModelConfig, caches, token, pos):
    """One decode step. token: (B,1) int32; pos: scalar int32 (next index).

    Caches mirror the segment structure; SWA caches are ring buffers
    (write at pos % window)."""
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"]["tok"].astype(dtype)[token]
    x = constrain(x, "batch", None, "embed")
    new_caches = []
    for seg_p, seg_c, seg in zip(params["segments"], caches,
                                 layer_plan(cfg)):
        if seg.count == 1:
            if seg.kind == "cross":
                x, nc = B.block_decode_cross(seg_p, x, cfg, cache=seg_c,
                                             pos=pos)
            else:
                x, nc = B.block_decode(seg_p, x, cfg, seg.kind,
                                       cache=seg_c, pos=pos)
            new_caches.append(nc)
        else:
            def body(x, inp):
                p_l, c_l = inp
                if seg.kind == "cross":
                    xx, nc = B.block_decode_cross(p_l, x, cfg, cache=c_l,
                                                  pos=pos)
                else:
                    xx, nc = B.block_decode(p_l, x, cfg, seg.kind,
                                            cache=c_l, pos=pos)
                return xx, nc
            x, ncs = jax.lax.scan(body, x, (seg_p, seg_c))
            new_caches.append(ncs)
    x = B.rmsnorm(x, params["embed"]["ln_f"].astype(dtype), cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["embed"]["unembed"].astype(dtype))
    return logits, new_caches


# --------------------------------------------------------------------------
# shape declarations (dry-run stand-ins; no allocation)
# --------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, seq: int):
    """ShapeDtypeStruct + logical-name trees for the decode caches."""
    dtype = jnp.dtype(cfg.dtype)
    shapes, names = [], []
    for seg in layer_plan(cfg):
        defs = B.cache_defs_for_kind(cfg, seg.kind, batch, seq)
        sh: Dict[str, Any] = {}
        nm: Dict[str, Any] = {}
        for key, (shape, lnames) in defs.items():
            dt = jnp.float32 if ("state" in key or "xprev" in key) else dtype
            if seg.count > 1:
                sh[key] = jax.ShapeDtypeStruct((seg.count,) + shape, dt)
                nm[key] = ("layers",) + lnames
            else:
                sh[key] = jax.ShapeDtypeStruct(shape, dt)
                nm[key] = lnames
        shapes.append(sh)
        names.append(nm)
    return shapes, names


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Model inputs as ShapeDtypeStructs (+ logical names) for a cell.

    Stub frontends (whisper frames / VLM patches) appear here as
    precomputed embeddings, per the assignment.
    """
    b, s = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.dtype)
    ii = jnp.int32
    specs: Dict[str, Any] = {}
    names: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), ii)
        names["tokens"] = ("batch", "seq")
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), dtype)
            names["frames"] = ("batch", "enc_seq", "embed")
        if cfg.family == "vlm":
            specs["memory"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_seq, cfg.d_model), dtype)
            names["memory"] = ("batch", "vision_seq", "embed")
    else:  # decode: one new token against a seq-long cache
        specs["token"] = jax.ShapeDtypeStruct((b, 1), ii)
        names["token"] = ("batch", None)
        specs["pos"] = jax.ShapeDtypeStruct((), ii)
        names["pos"] = ()
        cache_sh, cache_nm = cache_specs(cfg, b, s)
        specs["caches"] = cache_sh
        names["caches"] = cache_nm
    return specs, names
