"""Model zoo: dense GQA / MoE / RWKV6 / hybrid / enc-dec / VLM."""

from .model import (cache_specs, decode_step, forward, init_model,
                    input_specs, layer_plan, loss_fn, model_defs,
                    param_specs, prefill)
from .sharding import DEFAULT_RULES, sharding_for, spec_for, tree_shardings

__all__ = ["forward", "loss_fn", "prefill", "decode_step", "init_model",
           "model_defs", "param_specs", "layer_plan", "input_specs",
           "cache_specs", "DEFAULT_RULES", "spec_for", "sharding_for",
           "tree_shardings"]
