"""Attention implementations (XLA path; the Pallas kernel mirrors these).

Three execution strategies, selected by config/shape:

* ``attention_scan``        — blocked online-softmax over KV blocks via
  ``lax.scan`` with causal/window masking. O(block) memory, but a causal
  mask burns ~2x the minimal FLOPs (every q block visits every kv block).
  This is the BASELINE the roofline §Perf iterates on.
* ``attention_triangular``  — unrolled lower-triangular schedule: q block i
  only visits kv blocks <= i via static slices. ~minimal FLOPs; larger HLO.
  Sliding-window variants slice only the in-window kv blocks.
* ``attention_decode``      — q_len == 1 against a KV cache (full or
  sliding-window slice).

All support GQA by folding query-head groups onto KV heads.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["attention", "attention_decode", "update_kv_cache"]

NEG_INF = -1e30


def _gqa_reshape(q, n_kv: int):
    """(B,S,H,D) -> (B,S,KV,G,D) where H = KV * G."""
    b, s, h, d = q.shape
    g = h // n_kv
    return q.reshape(b, s, n_kv, g, d)


def _block_scores(qb, kb):
    """qb: (B,bq,KV,G,D), kb: (B,bkv,KV,D) -> (B,KV,G,bq,bkv)."""
    return jnp.einsum("bqkgd,bskd->bkgqs", qb, kb)


def _block_av(p, vb):
    """p: (B,KV,G,bq,bkv), vb: (B,bkv,KV,D) -> (B,bq,KV,G,D)."""
    return jnp.einsum("bkgqs,bskd->bqkgd", p, vb)


def _mask(bq_idx, bkv_idx, bq, bkv, causal, window):
    """(bq, bkv) additive mask for block (bq_idx, bkv_idx)."""
    q_pos = bq_idx * bq + jnp.arange(bq)[:, None]
    k_pos = bkv_idx * bkv + jnp.arange(bkv)[None, :]
    ok = jnp.ones((bq, bkv), dtype=bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    return jnp.where(ok, 0.0, NEG_INF)


def _online_update(carry, scores, vb):
    """Online-softmax accumulate: carry = (m, l, acc)."""
    m_prev, l_prev, acc = carry
    m_cur = jnp.max(scores, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[..., None])
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc = acc * alpha[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, vb)
    return m_new, l_new, acc


def attention_scan(q, k, v, *, causal: bool, window: int = 0,
                   block_q: int = 512, block_kv: int = 1024):
    """Blocked online-softmax attention; masked blocks still compute."""
    b, sq, h, d = q.shape
    _, skv, n_kv, _ = k.shape
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    nq, nkv = -(-sq // bq), -(-skv // bkv)
    scale = 1.0 / math.sqrt(d)
    pad_q, pad_kv = nq * bq - sq, nkv * bkv - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    qr = _gqa_reshape(q * scale, n_kv)
    qr = qr.reshape(b, nq, bq, n_kv, h // n_kv, d)
    kr = k.reshape(b, nkv, bkv, n_kv, d)
    vr = v.reshape(b, nkv, bkv, n_kv, d)

    def q_block(qi, qb):
        def kv_step(carry, kv_i):
            kb = kr[:, kv_i]
            vb = vr[:, kv_i]
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb).astype(jnp.float32)
            s = s + _mask(qi, kv_i, bq, bkv, causal, window)[None, None, None]
            # mask padded kv tail
            k_pos = kv_i * bkv + jnp.arange(bkv)
            s = jnp.where((k_pos < skv)[None, None, None, None, :], s,
                          NEG_INF)
            return _online_update(carry, s, vb.astype(jnp.float32)), None

        g = h // n_kv
        m0 = jnp.full((b, n_kv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, n_kv, g, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            lambda c, i: kv_step(c, i), (m0, l0, a0),
            jnp.arange(nkv))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B, KV, G, bq, D)

    outs = []
    for qi in range(nq):
        outs.append(q_block(qi, qr[:, qi]))
    out = jnp.stack(outs, axis=1)  # (B, nq, KV, G, bq, D)
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(b, nq * bq, h, d)
    if pad_q:
        out = out[:, :sq]
    return out.astype(q.dtype)


def attention_triangular(q, k, v, *, causal: bool, window: int = 0,
                         block_q: int = 512, block_kv: int = 1024):
    """Unrolled triangular schedule: q block i reads only kv blocks that
    intersect its causal/window range (static slices => ~minimal FLOPs)."""
    b, sq, h, d = q.shape
    _, skv, n_kv, _ = k.shape
    bq = min(block_q, sq)
    nq = -(-sq // bq)
    scale = 1.0 / math.sqrt(d)
    g = h // n_kv
    qr = _gqa_reshape(q * scale, n_kv)
    offset = skv - sq  # cache prefix (prefill with pre-existing cache)

    outs = []
    for qi in range(nq):
        q_lo = qi * bq
        q_hi = min(q_lo + bq, sq)
        qb = qr[:, q_lo:q_hi]
        k_hi = (q_hi + offset) if causal else skv
        k_lo = 0
        if window > 0:
            k_lo = max(0, q_lo + offset - window + 1)
        kb = k[:, k_lo:k_hi]
        vb = v[:, k_lo:k_hi]
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb).astype(jnp.float32)
        q_pos = (jnp.arange(q_lo, q_hi) + offset)[:, None]
        k_pos = jnp.arange(k_lo, k_hi)[None, :]
        ok = jnp.ones((q_hi - q_lo, k_hi - k_lo), bool)
        if causal:
            ok &= k_pos <= q_pos
        if window > 0:
            ok &= k_pos > q_pos - window
        s = s + jnp.where(ok, 0.0, NEG_INF)[None, None, None]
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p,
                       vb.astype(jnp.float32))
        outs.append(o.reshape(b, q_hi - q_lo, h, d))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              impl: str = "scan", block_q: int = 512, block_kv: int = 1024):
    """q: (B,Sq,H,D); k,v: (B,Skv,KV,D)."""
    if impl == "triangular":
        return attention_triangular(q, k, v, causal=causal, window=window,
                                    block_q=block_q, block_kv=block_kv)
    return attention_scan(q, k, v, causal=causal, window=window,
                          block_q=block_q, block_kv=block_kv)


def attention_decode(q, k_cache, v_cache, *, window: int = 0,
                     valid_len=None):
    """Single-token decode: q (B,1,H,D) against cache (B,S,KV,D).

    SWA caches are ring buffers of capacity == window, so they arrive here
    already window-sized; ``valid_len`` (traced) masks unwritten slots.
    """
    b, _, h, d = q.shape
    _, s, n_kv, _ = k_cache.shape
    if window > 0 and s > window:
        k_cache = k_cache[:, s - window:]
        v_cache = v_cache[:, s - window:]
        s = window
    scale = 1.0 / math.sqrt(d)
    qr = _gqa_reshape(q * scale, n_kv)[:, 0]          # (B,KV,G,D)
    s_ = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache).astype(jnp.float32)
    if valid_len is not None:
        pos_k = jnp.arange(s)
        s_ = jnp.where((pos_k < valid_len)[None, None, None, :], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, h, d).astype(q.dtype)


def update_kv_cache(k_cache, v_cache, k_new, v_new, pos):
    """Insert new K/V at ring position ``pos % capacity`` (decode step)."""
    cap = k_cache.shape[1]
    write = pos % cap
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), write, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), write, axis=1)
    return k_cache, v_cache
