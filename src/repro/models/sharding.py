"""Logical-axis sharding rules with a divisibility-aware planner.

MaxText-style: every tensor dimension carries a logical name; rules map
names to mesh axes; the planner drops a mapping whenever the dimension is
not divisible by the mesh-axis extent (e.g. qwen2's 8 KV heads cannot
shard over a 16-way 'model' axis — the KV *cache sequence* axis picks up
the sharding instead via the 'cache_seq' fallback rule).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisRules", "DEFAULT_RULES", "spec_for", "sharding_for",
           "tree_shardings", "mesh_axis_size"]

AxisVal = Union[None, str, Tuple[str, ...]]
AxisRules = Dict[str, AxisVal]

# Logical-axis vocabulary used across the model zoo.
DEFAULT_RULES: AxisRules = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp_act": "model",
    "cache_seq": None,       # fallback target when kv_heads won't shard
    "vision_seq": None,
    "enc_seq": None,
    # parameters (FSDP over 'data', TP over 'model')
    "p_embed": "data",
    "vocab": "model",
    "p_heads": "model",
    "p_kv_heads": "model",
    "p_head_dim": None,
    "p_mlp": "model",
    "experts": "model",
    "p_expert_mlp": "model",      # fallback TP when experts don't divide
    "expert_cap": "data",         # MoE capacity dim (2D dispatch lever)
    "ssm_state": None,
    "layers": None,
    # optimizer / scalars
    "none": None,
}

# Sequence-parallel override used for the 500k-context SSM path.
SP_RULES: AxisRules = dict(DEFAULT_RULES, seq="model", cache_seq="model")


def mesh_axis_size(mesh: Mesh, axes: AxisVal) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return size


def _present(mesh: Mesh, axes: AxisVal) -> AxisVal:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' on 2D)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.shape else None
    kept = tuple(a for a in axes if a in mesh.shape)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def spec_for(mesh: Mesh, logical: Sequence[Optional[str]],
             shape: Sequence[int],
             rules: Optional[AxisRules] = None) -> P:
    """Resolve logical dim names -> PartitionSpec, enforcing divisibility.

    A mesh axis may be consumed by at most one tensor dimension; when a
    dimension's size is not divisible by its rule's extent the dimension
    falls back to replication (and the freed axis stays available for a
    later dimension such as 'cache_seq').
    """
    rules = rules or DEFAULT_RULES
    used: set = set()
    out = []
    for name, dim in zip(logical, shape):
        axes = _present(mesh, rules.get(name)) if name else None
        if axes is None:
            out.append(None)
            continue
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        if any(a in used for a in tup):
            out.append(None)
            continue
        ext = mesh_axis_size(mesh, tup)
        if ext <= 1 or dim % ext != 0:
            out.append(None)
            continue
        used.update(tup)
        out.append(axes)
    return P(*out)


def sharding_for(mesh: Mesh, logical: Sequence[Optional[str]],
                 shape: Sequence[int],
                 rules: Optional[AxisRules] = None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, logical, shape, rules))


import contextlib
import threading

_ACTIVE = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: Optional[AxisRules] = None):
    """Enable logical activation-sharding constraints during tracing.

    The step builders (launch/steps.py) enter this around ``.lower()`` /
    execution so that ``constrain`` calls inside model code resolve against
    the actual mesh. Without these constraints GSPMD loses batch sharding
    through scan bodies (observed: replicated layer activations => 62
    GB/chip of spurious all-reduce in the starcoder train cell).
    """
    prev = getattr(_ACTIVE, "ctx", None)
    _ACTIVE.ctx = (mesh, rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _ACTIVE.ctx = prev


def constrain(x, *names: Optional[str], rules: Optional[AxisRules] = None):
    """Logical-axis sharding constraint; no-op outside activation_sharding
    (plain CPU unit tests)."""
    ctx = getattr(_ACTIVE, "ctx", None)
    if ctx is None:
        return x
    mesh, default_rules = ctx
    spec = spec_for(mesh, names, x.shape, rules or default_rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def tree_shardings(mesh: Mesh, shapes_tree, logical_tree,
                   rules: Optional[AxisRules] = None):
    """Map a pytree of ShapeDtypeStructs + logical-name tuples to
    NamedShardings."""
    def one(sds, names):
        return sharding_for(mesh, names, sds.shape, rules)
    return jax.tree_util.tree_map(
        one, shapes_tree, logical_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
