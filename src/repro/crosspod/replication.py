"""QUACK-tracked cross-pod checkpoint replication + straggler mitigation.

Host-side control plane (pure Python — this is coordination, not compute)
implementing the paper's machinery on checkpoint shards flowing between
pods over DCN:

* each pod is an RSM of hosts: a shard is *durable* once hosts totalling
  ``u+1`` stake at the peer pod acknowledge it (weighted QUACK, §5.1) —
  only then may the sender GC its staging copy (§4.3);
* duplicate acks (a host re-acking its highest contiguous shard) signal a
  lost shard; the retransmitter is elected with zero coordination:
  ``(origin + retries) mod n_hosts`` (§4.2);
* send quotas are apportioned with Hamilton's method over measured host
  throughput ("stake"), re-planned every quantum — slow hosts get
  proportionally fewer shards (straggler mitigation, §5.2 DSS);
* the GC-stall defence: when a sender sees duplicate acks below its GC
  frontier it republishes its highest-quacked shard id; after ``r+1``
  such attestations receivers advance their ack floor (§4.3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set

import numpy as np

from ..core.scheduler import hamilton_apportion

__all__ = ["ShardState", "ReplicationLedger"]


@dataclasses.dataclass
class ShardState:
    shard_id: int
    origin_host: int
    acked_by: Set[int] = dataclasses.field(default_factory=set)
    retries: int = 0
    durable: bool = False
    gc_done: bool = False


class ReplicationLedger:
    """Tracks replication of checkpoint shards from one pod to another."""

    def __init__(self, n_hosts: int, u: int, r: int,
                 stakes: Optional[np.ndarray] = None):
        self.n = n_hosts
        self.u = u
        self.r = r
        self.stakes = (np.ones(n_hosts) if stakes is None
                       else np.asarray(stakes, dtype=np.float64))
        self.shards: Dict[int, ShardState] = {}
        self.last_ack: Dict[int, int] = {}      # host -> cum ack value
        self.dup_counts: Dict[int, Set[int]] = {}  # shard -> dup hosts
        self.hq_attestations: Dict[int, Set[int]] = {}
        self.ack_floor = 0

    # -- send planning ----------------------------------------------------
    def plan_sends(self, shard_ids: List[int],
                   host_throughput: Optional[np.ndarray] = None
                   ) -> Dict[int, int]:
        """Apportion shards across sender hosts by throughput stakes."""
        tp = (self.stakes if host_throughput is None
              else np.asarray(host_throughput, dtype=np.float64))
        counts = hamilton_apportion(tp, len(shard_ids))
        plan: Dict[int, int] = {}
        host_iter: List[int] = []
        for h, c in enumerate(counts):
            host_iter.extend([h] * int(c))
        for sid, host in zip(shard_ids, host_iter):
            plan[sid] = host
            self.shards[sid] = ShardState(shard_id=sid, origin_host=host)
        return plan

    # -- ack path ----------------------------------------------------------
    def record_ack(self, host: int, cum_shard: int) -> None:
        """Host acks contiguous receipt of shards [0, cum_shard]."""
        prev = self.last_ack.get(host, -1)
        if cum_shard == prev:
            missing = cum_shard + 1
            self.dup_counts.setdefault(missing, set()).add(host)
        self.last_ack[host] = max(prev, cum_shard)
        for sid, st in self.shards.items():
            if sid <= cum_shard and not st.durable:
                st.acked_by.add(host)
                stake = sum(self.stakes[h] for h in st.acked_by)
                if stake >= self.u + 1:
                    st.durable = True
                    st.gc_done = True          # §4.3: quacked => collectable

    # -- failure path --------------------------------------------------------
    def lost_shards(self) -> List[int]:
        """Shards with >= r+1 (stake) duplicate complaints, not durable."""
        out = []
        thresh = max(self.r + 1, 1)
        for sid, hosts in self.dup_counts.items():
            st = self.shards.get(sid)
            if st is None or st.durable:
                continue
            if sum(self.stakes[h] for h in hosts) >= thresh:
                out.append(sid)
        return sorted(out)

    def elect_retransmitter(self, shard_id: int) -> int:
        """§4.2: (origin + #retries) mod n — no coordination messages."""
        st = self.shards[shard_id]
        st.retries += 1
        self.dup_counts.pop(shard_id, None)
        return (st.origin_host + st.retries) % self.n

    # -- GC-stall defence -------------------------------------------------
    def highest_quacked(self) -> int:
        hq = -1
        for sid in sorted(self.shards):
            if self.shards[sid].durable:
                hq = sid
            else:
                break
        return hq

    def record_hq_attestation(self, sender_host: int, hq: int) -> int:
        """Receiver side: after r+1 attestations of hq >= k, the floor
        advances past the hole (§4.3 strategy 1)."""
        self.hq_attestations.setdefault(hq, set()).add(sender_host)
        thresh = max(self.r + 1, 1)
        for k in sorted(self.hq_attestations, reverse=True):
            hosts = set()
            for kk, hh in self.hq_attestations.items():
                if kk >= k:
                    hosts |= hh
            if sum(self.stakes[h] for h in hosts) >= thresh:
                self.ack_floor = max(self.ack_floor, k + 1)
                break
        return self.ack_floor

    # -- invariants -----------------------------------------------------------
    def all_durable(self) -> bool:
        return all(s.durable for s in self.shards.values())

    def summary(self) -> Dict[str, float]:
        n = len(self.shards) or 1
        return {
            "shards": len(self.shards),
            "durable": sum(s.durable for s in self.shards.values()),
            "retries": sum(s.retries for s in self.shards.values()),
            "durable_frac": sum(s.durable for s in self.shards.values()) / n,
        }
