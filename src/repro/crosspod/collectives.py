"""PICSOU-patterned hierarchical cross-pod collectives (shard_map).

Two gradient-sync schedules over a (pod, data, model) mesh:

* ``ata_cross_pod_sync``    — flat ``psum`` over (pod, data): the all-to-all
  baseline of the paper (§6, Figure 2a): simple, robust, but every gradient
  byte crosses the inter-pod boundary as part of one global ring that mixes
  fast ICI hops with slow DCN hops.

* ``picsou_cross_pod_sync`` — the C3B pattern (Figure 2c):
    1. ``psum_scatter`` over 'data'  (intra-pod, fast ICI): each chip now
       owns 1/|data| of the pod-reduced gradient — this is the "partition
       the send task round-robin across all replicas" step (§4.1);
    2. ``psum`` over 'pod' (slow DCN): each shard crosses the boundary
       exactly once, from exactly one chip — the paper's single
       cross-cluster copy, with the 16 chips acting as the rotating
       sender-receiver pairs;
    3. ``all_gather`` over 'data' (intra-pod): the receiver-side broadcast
       of §4.1.

  DCN bytes drop from 2*N*(P-1)/P per chip (flat ring over pods) to
  2*(N/D)*(P-1)/P — a |data|x reduction of slow-link traffic per chip.

Both are exposed as pure functions on gradient pytrees, jit-compatible,
and verified equal to each other and to the unsharded mean in tests.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["picsou_cross_pod_sync", "ata_cross_pod_sync",
           "dcn_bytes_analytic"]


def _flat_size(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


def ata_cross_pod_sync(grads, mesh: Mesh, in_specs=None):
    """Flat all-reduce over (pod, data) — the ATA baseline."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    spec = in_specs if in_specs is not None else P()

    def sync(g):
        return jax.tree_util.tree_map(
            lambda x: jax.lax.psum(x, axes) / mesh.shape.get("pod", 1)
            / mesh.shape.get("data", 1), g)

    f = _shard_map(sync, mesh, spec, grads)
    return f(grads)


def picsou_cross_pod_sync(grads, mesh: Mesh, in_specs=None):
    """Hierarchical RS(data) -> AR(pod) -> AG(data): one DCN copy/shard."""
    has_pod = "pod" in mesh.shape
    spec = in_specs if in_specs is not None else P()
    d = mesh.shape.get("data", 1)
    p = mesh.shape.get("pod", 1)

    def sync(g):
        def one(x):
            orig_shape = x.shape
            flat = x.reshape(-1)
            pad = (-flat.shape[0]) % d
            if pad:
                flat = jnp.concatenate(
                    [flat, jnp.zeros((pad,), flat.dtype)])
            # 1) intra-pod reduce-scatter (round-robin send partitioning)
            shard = jax.lax.psum_scatter(flat, "data", scatter_dimension=0,
                                         tiled=True)
            # 2) one cross-pod copy per shard (the C3B single-copy step)
            if has_pod:
                shard = jax.lax.psum(shard, "pod")
            # 3) intra-pod broadcast (receiver-side §4.1 broadcast)
            full = jax.lax.all_gather(shard, "data", axis=0, tiled=True)
            if pad:
                full = full[:-pad]
            return (full / (d * p)).reshape(orig_shape)
        return jax.tree_util.tree_map(one, g)

    f = _shard_map(sync, mesh, spec, grads)
    return f(grads)


def _is_arr(x):
    return hasattr(x, "shape")


def _shard_map(fn, mesh, spec, tree):
    try:
        from jax import shard_map as _sm  # jax >= 0.6
        kw = {"check_vma": False}
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm
        kw = {"check_rep": False}
    specs = jax.tree_util.tree_map(lambda _: spec, tree, is_leaf=_is_arr)
    return _sm(fn, mesh=mesh, in_specs=(specs,), out_specs=specs, **kw)


def dcn_bytes_analytic(n_bytes: float, mesh_shape: Dict[str, int],
                       schedule: str) -> Dict[str, float]:
    """Slow-link (pod-boundary) traffic per chip for one sync of n_bytes.

    ATA    : the flat ring over pod*data chips carries the full tensor
             through every hop class; each chip's DCN share is
             2*n*(P-1)/P (ring segments crossing the boundary).
    PICSOU : only step (2) crosses pods, on 1/D-sized shards:
             2*(n/D)*(P-1)/P per chip.
    """
    p = mesh_shape.get("pod", 1)
    d = mesh_shape.get("data", 1)
    if p <= 1:
        return {"dcn_per_chip": 0.0, "ici_per_chip": 2.0 * n_bytes}
    if schedule == "ata":
        dcn = 2.0 * n_bytes * (p - 1) / p
        ici = 2.0 * n_bytes * (d - 1) / d
    elif schedule == "picsou":
        dcn = 2.0 * (n_bytes / d) * (p - 1) / p
        ici = (n_bytes * (d - 1) / d          # reduce-scatter
               + n_bytes * (d - 1) / d)       # all-gather
    else:
        raise ValueError(schedule)
    return {"dcn_per_chip": dcn, "ici_per_chip": ici,
            "dcn_reduction": (2.0 * n_bytes * (p - 1) / p) / max(dcn, 1e-9)}
