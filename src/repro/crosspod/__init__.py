"""Cross-pod runtime: the PICSOU schedule mapped onto TPU pod meshes.

The paper's efficiency pillar P1 — "a single copy of each message crosses
the expensive inter-cluster link; broadcast happens intra-cluster" — maps
exactly onto hierarchical collectives over a (pod, data, model) mesh:

    reduce-scatter(intra-pod)  ->  all-reduce(pod axis, 1/N bytes/chip)
                               ->  all-gather(intra-pod)

vs the ATA baseline (flat all-reduce over all axes, every byte crossing
the slow pod boundary multiple times). QUACK bookkeeping drives the
fault-tolerant checkpoint replication (replication.py) and the DSS /
apportionment scheduler drives straggler-aware send quotas.
"""

from .collectives import (ata_cross_pod_sync, dcn_bytes_analytic,
                          picsou_cross_pod_sync)
from .compression import (ef_int8_compress, ef_int8_decompress,
                          make_ef_state)
from .replication import ReplicationLedger, ShardState

__all__ = ["picsou_cross_pod_sync", "ata_cross_pod_sync",
           "dcn_bytes_analytic", "ReplicationLedger", "ShardState",
           "ef_int8_compress", "ef_int8_decompress", "make_ef_state"]
