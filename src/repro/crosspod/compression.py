"""Error-feedback int8 compression for the DCN-crossing sync segment.

Beyond-paper optimization (recorded separately in EXPERIMENTS.md §Perf):
the cross-pod step of the picsou schedule moves 1/D-sized f32 shards over
the slow links; quantizing that segment to int8 with per-block scales and
an error-feedback residual cuts DCN bytes another ~4x with provably
bounded bias accumulation (the residual re-enters the next step's
gradient, standard EF-SGD).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["make_ef_state", "ef_int8_compress", "ef_int8_decompress"]

BLOCK = 256


def make_ef_state(tree):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _quant(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), pad


def _dequant(q: jnp.ndarray, scale: jnp.ndarray, pad: int,
             shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def ef_int8_compress(grad: jnp.ndarray, residual: jnp.ndarray):
    """Returns ((q, scale, pad), new_residual). grad+residual is quantized;
    the quantization error becomes the next residual."""
    target = grad.astype(jnp.float32) + residual
    q, scale, pad = _quant(target)
    deq = _dequant(q, scale, pad, grad.shape)
    return (q, scale, pad), target - deq


def ef_int8_decompress(packed, shape) -> jnp.ndarray:
    q, scale, pad = packed
    return _dequant(q, scale, pad, shape)
