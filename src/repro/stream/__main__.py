"""CLI driver for the streaming session engine.

``python -m repro.stream --selftest`` is the CI fast-tier gate: it runs
a bounded 512-message horizon through the streaming session (constant
arrivals, K=8 pipelining) and checks the live path against the batch
path on the *same spec*:

  * live-aggregated percentiles / histograms must equal a post-hoc
    ``RunReport`` of the bounded prefix bit-exactly (the mergeable
    sketch algebra against the device oracle),
  * the streaming session must issue **zero additional device
    dispatches** versus plain batch-mode ``run_simulation`` of the
    identical spec (the telemetry rides the drains that already happen),
  * every message must be delivered, the SLO watchdogs must stay
    quiet on the failure-free stream, and the exported Chrome trace
    (now with counter tracks + instant events) must validate,

and writes the LiveReport artifacts (``stream.json`` / ``live.jsonl``
/ ``dashboard.txt`` / ``trace.json``) into ``--out`` for CI upload.
Exit code 0 = all checks passed.

Without ``--selftest`` it runs a session at user-chosen shape/workload
and prints the live dashboard + capacity calibration — e.g.::

    python -m repro.stream --horizon 65536 --kind diurnal --rate 6
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from ..core.simulator import chunk_dispatch_count, run_simulation
from ..core.types import RSMConfig, SimConfig
from ..obs.report import report_from_results, validate_chrome_trace
from ..obs.tracer import SpanTracer, tracing
from .session import StreamConfig, StreamSession
from .workload import ArrivalProcess

_REQUIRED_SPANS = ("run", "drain_wait", "final_flush")


def _session(args) -> StreamSession:
    sim = SimConfig(window=4, phi=6, window_slots="auto",
                    chunk_steps=args.chunk_steps, superchunk=args.k)
    process = ArrivalProcess(kind=args.kind, rate=args.rate,
                             period=args.period, seed=args.seed)
    cfg = StreamConfig(
        horizon=args.horizon, process=process,
        utilization=args.utilization, links=args.links,
        chained=args.chained, report_every=args.report_every,
        jsonl_path=os.path.join(args.out, "live.jsonl"),
        echo=args.echo)
    return StreamSession(RSMConfig.bft(1), RSMConfig.bft(1), sim, cfg)


def _write_artifacts(result, tracer, out: str) -> dict:
    os.makedirs(out, exist_ok=True)
    paths = result.save(os.path.join(out, "stream"))
    tpath = os.path.join(out, "trace.json")
    with open(tpath, "w") as f:
        json.dump(tracer.to_chrome_trace(), f)
    paths["trace"] = tpath
    print("# wrote " + " ".join(sorted(paths.values())))
    return paths


def selftest(args) -> int:
    """Bounded-horizon streaming gate; returns exit code."""
    session = _session(args)
    tracer = SpanTracer()
    d0 = chunk_dispatch_count()
    result = session.run(tracer=tracer)
    stream_dispatches = chunk_dispatch_count() - d0
    problems = list(result.problems)

    # (1) live aggregates vs a post-hoc RunReport of the same prefix:
    # batch-run the *identical spec* and compare sketches bit-exactly
    batch_tracer = SpanTracer()
    db = chunk_dispatch_count()
    with tracing(batch_tracer):
        batch = run_simulation(session.spec)
    batch_dispatches = chunk_dispatch_count() - db
    report = report_from_results([batch], batch_tracer,
                                 lane_names=["link"])
    problems += [f"posthoc: {p}" for p in report.validate()]
    live_hist = np.asarray(result.sketch.lane_sum(), dtype=np.int64)
    post_hist = np.asarray(report.obs["link"].latency_hist,
                           dtype=np.int64)
    if not np.array_equal(live_hist, post_hist):
        problems.append(f"live hist != post-hoc RunReport hist "
                        f"({live_hist.tolist()} vs {post_hist.tolist()})")
    if result.percentiles() != report.obs["link"].percentiles():
        problems.append(
            f"live percentiles {result.percentiles()} != post-hoc "
            f"{report.obs['link'].percentiles()}")

    # (2) zero extra device dispatches vs batch mode of the same spec
    if stream_dispatches != batch_dispatches:
        problems.append(f"stream mode used {stream_dispatches} "
                        f"dispatches, batch mode {batch_dispatches}")

    # (3) full delivery + quiet watchdogs on the failure-free stream
    if result.delivered != session.spec.m * args.links:
        problems.append(f"only {result.delivered}/"
                        f"{session.spec.m * args.links} delivered")
    breaches = [e for e in result.slo_events if not e.recovered]
    if breaches:
        problems.append(f"SLO breaches on failure-free stream: "
                        f"{[e.kind for e in breaches]}")

    # (4) trace schema (counter tracks + instants included) and the
    # canonical engine spans
    trace = tracer.to_chrome_trace()
    problems += [f"trace: {p}" for p in validate_chrome_trace(trace)]
    names = {e["name"] for e in trace["traceEvents"]}
    for want in _REQUIRED_SPANS:
        if want not in names:
            problems.append(f"span {want!r} missing from trace")
    if not any(e.get("ph") == "C" for e in trace["traceEvents"]):
        problems.append("no counter tracks in the live trace")

    # (5) flat-memory proxies: bounded dashboard, no O(M) mirrors
    if len(result.live.rows) > result.live.rows.maxlen:
        problems.append("LiveReport rows exceeded bound")

    print(result.summary())
    print()
    print(result.live.dashboard())
    _write_artifacts(result, tracer, args.out)
    if problems:
        print("\nSELFTEST FAILED:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"\nSELFTEST OK: {result.delivered} deliveries, "
          f"{stream_dispatches} dispatches (batch: {batch_dispatches}), "
          f"{result.counters['live_rows']} live rows, "
          f"{len(trace['traceEvents'])} trace events")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.stream",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--selftest", action="store_true",
                    help="run the CI streaming gate (512-msg horizon)")
    ap.add_argument("--horizon", type=int, default=512,
                    help="messages fed through the session")
    ap.add_argument("--kind", default="constant",
                    choices=("constant", "diurnal", "bursty",
                             "heavytail"))
    ap.add_argument("--rate", type=float, default=4.0,
                    help="mean arrivals per protocol round")
    ap.add_argument("--utilization", type=float, default=None,
                    help="calibrate rate to this fraction of analytic "
                         "capacity (overrides --rate)")
    ap.add_argument("--period", type=int, default=512,
                    help="diurnal cycle length in rounds")
    ap.add_argument("--links", type=int, default=1)
    ap.add_argument("--chained", action="store_true",
                    help="chain lane i behind lane i-1's GC frontier")
    ap.add_argument("--k", type=int, default=8,
                    help="superchunk fusion depth")
    ap.add_argument("--chunk-steps", type=int, default=16)
    ap.add_argument("--report-every", type=int, default=8,
                    help="chunks per LiveReport row / counter sample")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--echo", action="store_true",
                    help="print dashboard rows as chunks drain")
    ap.add_argument("--out", default="stream_out",
                    help="artifact directory (report + live jsonl + "
                         "chrome trace)")
    args = ap.parse_args(argv)

    if args.selftest:
        return selftest(args)
    session = _session(args)
    tracer = SpanTracer()
    result = session.run(tracer=tracer)
    print(result.summary())
    print()
    print(result.live.dashboard())
    _write_artifacts(result, tracer, args.out)
    for p in result.problems:
        print(f"WARNING: {p}")
    return 1 if result.problems else 0


if __name__ == "__main__":
    sys.exit(main())
