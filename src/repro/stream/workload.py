"""Seeded workload generator for streaming sessions.

An :class:`ArrivalProcess` describes client traffic offered to one C3B
link in *messages per protocol round* (one round = one cross-RSM RTT,
``NetworkModel.rtt_s``).  :func:`arrivals_per_round` expands it into a
deterministic per-round arrival count sequence covering exactly
``horizon`` messages, and :func:`build_stream_spec` turns that sequence
into an engine ``SimSpec`` whose ``orig_step`` schedule *is* the
arrival process — the protocol's dispatch gate (``orig_step <= t``)
injects messages at the generated rounds, so no engine changes are
needed to shape traffic.

Four process kinds:

  ``constant``   fixed rate via exact fractional accumulation (no rng);
  ``diurnal``    sinusoidal rate modulation (period/amplitude) with
                 Poisson per-round counts — the paper's "millions of
                 simulated clients" day/night envelope;
  ``bursty``     two-state Markov-modulated Poisson process (on/off
                 transition probabilities, elevated on-state rate);
  ``heavytail``  Pareto-sized batches (shape ``alpha``) scaled so the
                 long-run mean matches ``rate``.

Everything is seeded and host-side numpy — generation is reproducible
and never touches a trace context.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.simulator import SimSpec, build_spec
from ..core.types import FailureScenario, RSMConfig, SimConfig

__all__ = [
    "ArrivalProcess",
    "arrivals_per_round",
    "dispatch_rounds",
    "stream_window_slots",
    "build_stream_spec",
]

KINDS = ("constant", "diurnal", "bursty", "heavytail")


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """One link's offered-load description (messages per round)."""

    kind: str = "constant"
    rate: float = 4.0          # long-run mean arrivals per round
    period: int = 512          # diurnal: rounds per day/night cycle
    amplitude: float = 0.5     # diurnal: fractional swing in [0, 1)
    p_on: float = 0.05         # bursty: off->on transition probability
    p_off: float = 0.25        # bursty: on->off transition probability
    burst_factor: float = 4.0  # bursty: on-state rate multiplier
    alpha: float = 1.8         # heavytail: Pareto shape (> 1)
    cap: int = 0               # per-round arrival cap (0 = 8x rate)
    seed: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown arrival kind {self.kind!r}; "
                             f"have {KINDS}")
        if self.rate <= 0:
            raise ValueError("rate must be positive")
        if self.kind == "heavytail" and self.alpha <= 1.0:
            raise ValueError("heavytail alpha must exceed 1 (finite mean)")

    def round_cap(self) -> int:
        return self.cap if self.cap > 0 else max(int(8 * self.rate), 64)


def _per_round_rates(p: ArrivalProcess, n: int) -> np.ndarray:
    t = np.arange(n, dtype=np.float64)
    if p.kind == "diurnal":
        return p.rate * (1.0 + p.amplitude
                         * np.sin(2.0 * np.pi * t / max(p.period, 1)))
    return np.full(n, p.rate, dtype=np.float64)


def arrivals_per_round(process: ArrivalProcess,
                       horizon: int) -> np.ndarray:
    """Per-round arrival counts summing exactly to ``horizon``.

    Generates in blocks until the cumulative count covers the horizon,
    then trims the final round so the stream carries exactly ``horizon``
    messages — the schedule length (number of loaded rounds) is the
    process's own, not fixed up front.
    """
    if horizon <= 0:
        raise ValueError("horizon must be positive")
    rng = np.random.default_rng(process.seed)
    cap = process.round_cap()
    counts: list = []
    total = 0
    frac = 0.0            # constant-kind exact accumulator
    on = False            # bursty-kind Markov state
    block = max(int(np.ceil(horizon / process.rate)) + 64, 256)
    while total < horizon:
        n0 = len(counts)
        if process.kind == "constant":
            got = np.empty(block, dtype=np.int64)
            for i in range(block):
                frac += process.rate
                got[i] = int(frac)
                frac -= got[i]
        elif process.kind == "diurnal":
            got = rng.poisson(
                np.clip(_per_round_rates(process, n0 + block)[n0:],
                        0.0, None)).astype(np.int64)
        elif process.kind == "bursty":
            pi_on = process.p_on / max(process.p_on + process.p_off,
                                       1e-12)
            rate_on = process.rate * process.burst_factor
            # off-state rate chosen so the long-run mean stays `rate`
            rate_off = max((process.rate - pi_on * rate_on)
                           / max(1.0 - pi_on, 1e-12), 0.0)
            got = np.empty(block, dtype=np.int64)
            flips = rng.random(block)
            for i in range(block):
                on = (flips[i] < process.p_on) if not on else \
                    (flips[i] >= process.p_off)
                got[i] = rng.poisson(rate_on if on else rate_off)
        else:  # heavytail
            # Pareto(alpha, xm) has mean alpha*xm/(alpha-1); pick xm so
            # floor(batch) keeps roughly the configured long-run rate
            xm = process.rate * (process.alpha - 1.0) / process.alpha
            got = np.floor((rng.pareto(process.alpha, block) + 1.0)
                           * xm).astype(np.int64)
        got = np.minimum(got, cap)
        counts.extend(int(x) for x in got)
        total += int(got.sum())
    # trim to exactly `horizon` messages
    out = np.asarray(counts, dtype=np.int64)
    cum = np.cumsum(out)
    last = int(np.searchsorted(cum, horizon))
    out = out[:last + 1].copy()
    out[last] -= int(cum[last]) - horizon
    return out


def dispatch_rounds(counts: np.ndarray) -> np.ndarray:
    """Expand per-round counts into each message's dispatch round."""
    counts = np.asarray(counts, dtype=np.int64)
    return np.repeat(np.arange(len(counts), dtype=np.int64), counts)


def stream_window_slots(counts: np.ndarray, n_s: int, n_r: int,
                        chunk_steps: int, phi: int,
                        slack_rounds: int = 8) -> int:
    """Window sized for the *offered load* instead of the send pacing.

    The frontier can trail the dispatch head by roughly a chunk plus
    the ack/retransmission rotation; the window must hold every arrival
    inside that lag, so we take the peak arrivals over any lag-sized
    span of the actual schedule (plus the phi slack), rounded up to 64.
    """
    counts = np.asarray(counts, dtype=np.int64)
    lag = max(int(chunk_steps), 1) + n_s + n_r + slack_rounds
    cum = np.concatenate([[0], np.cumsum(counts)])
    if len(cum) <= lag:
        peak = int(cum[-1])
    else:
        peak = int((cum[lag:] - cum[:-lag]).max())
        peak = max(peak, int(cum[min(lag, len(cum) - 1)]))
    return max(int(-(-(peak + phi) // 64) * 64), 64)


def build_stream_spec(sender: RSMConfig, receiver: RSMConfig,
                      sim: SimConfig, process: ArrivalProcess,
                      horizon: int,
                      failures: FailureScenario = FailureScenario.none(),
                      drain_slack: Optional[int] = None,
                      ) -> SimSpec:
    """Resolve a workload into an engine spec with an arrival-driven
    ``orig_step`` schedule.

    ``sim.n_msgs``/``sim.steps`` are derived (horizon; last arrival
    plus a drain tail), ``collect_metrics`` is forced on (the blocks
    are the session's live feed), and ``window_slots="auto"`` resolves
    through :func:`stream_window_slots` — sized for the offered load,
    never the dense fallback.
    """
    counts = arrivals_per_round(process, horizon)
    ostep = dispatch_rounds(counts)
    n_rounds = len(counts)
    if drain_slack is None:
        drain_slack = (max(sim.chunk_steps, 1) + sender.n + receiver.n
                       + 2 * sim.phi + 96)
    w_slots = sim.window_slots
    if w_slots in (None, "auto", 0):
        w_slots = stream_window_slots(counts, sender.n, receiver.n,
                                      sim.chunk_steps, sim.phi)
    w_slots = min(int(w_slots), max(horizon, 64))
    sim2 = dataclasses.replace(
        sim, n_msgs=horizon, steps=n_rounds + drain_slack,
        window_slots=int(w_slots), collect_metrics=True)
    spec = build_spec(sender, receiver, sim2, failures)
    return dataclasses.replace(
        spec, orig_step=tuple(int(x) for x in ostep))
