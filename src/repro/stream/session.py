"""Streaming session driver — the engine as a resident service.

A :class:`StreamSession` builds an arrival-driven spec from a workload
(:mod:`repro.stream.workload`), points the engine's horizon-mode
``drain_sink`` at a live telemetry pipeline (:mod:`repro.obs.live`),
and runs the unbounded horizon in one engine invocation:

  * per drained chunk (riding the batched ``device_get`` that already
    happens — zero extra dispatches or transfers), the sink folds the
    cumulative ``MetricsBlock`` snapshot into mergeable sketches,
    windowed rates and trend lines, runs the SLO watchdogs, and emits
    periodic ``LiveReport`` rows plus Perfetto counter samples;
  * host memory stays O(1) in stream length — no (B, M) output
    mirrors exist anywhere in the path;
  * offered and sustained load are priced against the analytic
    capacity model (``core/network.py``), so the result states
    "X% of analytic capacity sustained at fleet size N".

Multi-link sessions run the same workload across ``links`` engine
lanes — independent (fan-out) or chained through the topology engine's
:class:`~repro.topology.engine.FloorPlanner` with history retention
off.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Optional

import numpy as np

from ..core.simulator import (SimSpec, _run_windowed_batch,
                              chunk_dispatch_count, chunk_trace_count,
                              host_sync_count, spec_with_failures)
from ..core.types import NetworkModel, RSMConfig, SimConfig
from ..obs.live import (LatencySketch, LiveAggregator, LiveReport,
                        LiveSample, SLOConfig, SLOEvent, SLOWatchdog)
from ..obs.metrics import ObsMetrics, obs_from_final
from ..obs.tracer import SpanTracer, current_tracer, tracing
from .workload import ArrivalProcess, arrivals_per_round, build_stream_spec

__all__ = ["StreamConfig", "StreamResult", "StreamSession",
           "analytic_capacity", "run_stream"]


def analytic_capacity(sender: RSMConfig, receiver: RSMConfig,
                      net: NetworkModel, window: int = 8,
                      resend_factor: float = 0.0) -> dict:
    """Analytic PICSOU capacity of one link, in per-second and
    per-round (one round = one cross-RSM RTT) units."""
    from ..core.protocols import analytic_throughput
    terms = analytic_throughput("picsou", sender, receiver, net,
                                resend_factor=resend_factor,
                                window=window)
    per_s = float(terms["throughput_msgs_per_s"])
    return {
        "msgs_per_s": per_s,
        "msgs_per_round": per_s * net.rtt_s,
        "bottleneck": terms["bottleneck"],
        "fleet": sender.n + receiver.n,
        "n_senders": sender.n,
        "n_receivers": receiver.n,
    }


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """One streaming session's service description."""

    horizon: int = 65536              # messages fed through the session
    process: ArrivalProcess = ArrivalProcess()
    utilization: Optional[float] = None  # calibrate rate to this
                                         # fraction of analytic capacity
    net: NetworkModel = NetworkModel()   # capacity model + RTT pricing
    slo: SLOConfig = SLOConfig()
    links: int = 1                    # engine lanes fed the workload
    chained: bool = False             # lane i gated on lane i-1's frontier
    report_every: int = 8             # chunks per LiveReport row/counter
    window_chunks: int = 8            # sliding window width (chunks)
    jsonl_path: Optional[str] = None  # stream LiveReport rows to disk
    echo: bool = False                # print dashboard rows as they land


@dataclasses.dataclass
class StreamResult:
    """Everything a finished (or drained-so-far) session knows."""

    config: StreamConfig
    spec: SimSpec
    delivered: int
    retired: int
    rounds: int                       # protocol rounds executed
    horizon: int
    sketch: LatencySketch             # cumulative, merge-built
    obs: List[ObsMetrics]             # per-lane device totals
    live: LiveReport
    slo_events: List[SLOEvent]
    capacity: dict                    # offered/sustained vs analytic
    counters: dict                    # dispatches/traces/syncs deltas
    final_window_slots: int
    growth_events: tuple
    spans: dict
    problems: List[str]               # live-vs-device invariant breaks

    def percentiles(self, qs=(50, 95, 99)) -> dict:
        return {"p%g" % q: self.sketch.percentile(q) for q in qs}

    def summary(self) -> str:
        cap = self.capacity
        p = self.percentiles()
        lines = [
            "stream session: %d/%d msgs delivered over %d rounds "
            "(%d lanes%s)" % (self.delivered,
                              self.horizon * self.config.links,
                              self.rounds, self.config.links,
                              ", chained" if self.config.chained else ""),
            "latency p50/p95/p99 = %d/%d/%d rounds; resends=%d "
            "losses=%d" % (p["p50"], p["p95"], p["p99"],
                           sum(int(o.resend_total) for o in self.obs),
                           sum(int(o.loss_events) for o in self.obs)),
            "offered %.2f msg/round (%.0f%% of analytic capacity); "
            "sustained %.2f msg/round = %.1f msg/s (%.0f%% of "
            "analytic, fleet %d, bottleneck %s)"
            % (cap["offered_msgs_per_round"],
               100.0 * cap["offered_frac"],
               cap["sustained_msgs_per_round"],
               cap["sustained_msgs_per_s"],
               100.0 * cap["sustained_frac"], cap["fleet"],
               cap["bottleneck"]),
            "dispatches=%d traces=%d syncs=%d window=%d slo_events=%d"
            % (self.counters["dispatches"], self.counters["traces"],
               self.counters["syncs"], self.final_window_slots,
               len(self.slo_events)),
        ]
        if self.problems:
            lines.append("PROBLEMS: " + "; ".join(self.problems))
        return "\n".join(lines)

    def to_json_dict(self) -> dict:
        return {
            "horizon": self.horizon,
            "links": self.config.links,
            "chained": self.config.chained,
            "process": dataclasses.asdict(self.config.process),
            "delivered": self.delivered,
            "retired": self.retired,
            "rounds": self.rounds,
            "latency_hist": np.asarray(
                self.sketch.lane_sum()).tolist(),
            "percentiles": self.percentiles(),
            "capacity": self.capacity,
            "counters": self.counters,
            "final_window_slots": self.final_window_slots,
            "growth_events": len(self.growth_events),
            "slo_events": [e.to_dict() for e in self.slo_events],
            "live_rows": self.live.total_rows,
            "problems": self.problems,
        }

    def save(self, prefix: str) -> dict:
        d = os.path.dirname(prefix)
        if d:
            os.makedirs(d, exist_ok=True)
        jpath = prefix + ".json"
        with open(jpath, "w") as f:
            json.dump(self.to_json_dict(), f, indent=1, default=float)
        paths = {"json": jpath}
        tpath = prefix + ".txt"
        with open(tpath, "w") as f:
            f.write(self.summary() + "\n\n" + self.live.dashboard())
        paths["dashboard"] = tpath
        return paths


class _EngineSink:
    """The engine's horizon-mode drain sink: aggregate, watch, report."""

    def __init__(self, cfg: StreamConfig, agg: LiveAggregator,
                 watchdog: SLOWatchdog, report: LiveReport):
        self.cfg = cfg
        self.agg = agg
        self.watchdog = watchdog
        self.report = report
        self.chunks = 0
        self.last_sample: Optional[LiveSample] = None
        self.final_state = None
        self.final_mc = None
        self.final_bases = None
        self.final_w = 0
        self.growth_events: tuple = ()
        self.rounds = 0

    def on_chunk(self, t_end, metrics, queue, block, bases) -> None:
        sample = self.agg.observe(t_end, metrics, bases, block)
        self.last_sample = sample
        self.chunks += 1
        events = self.watchdog.check(sample)
        tracer = current_tracer()
        if tracer is not None:
            for ev in events:
                tracer.instant(
                    "slo:%s" % ev.kind, cat="slo",
                    recovered=ev.recovered, t=ev.t,
                    value=ev.value, threshold=ev.threshold)
        if events or self.chunks % max(self.cfg.report_every, 1) == 0:
            self.report.add(sample, events)
            if tracer is not None:
                tracer.counter("stream/rate",
                               throughput=sample.throughput,
                               goodput=sample.goodput)
                tracer.counter("stream/backlog",
                               backlog=sample.backlog,
                               gc_lag=sample.gc_lag)
                tracer.counter("stream/latency", p99=sample.p99,
                               p99_recent=sample.p99_recent)
            if self.cfg.echo:
                print(self.report.dashboard(last_n=1).splitlines()[-1])

    def on_final(self, state, mc, bases, w, growth_events, t) -> None:
        self.final_state = state
        self.final_mc = mc
        self.final_bases = np.asarray(bases)
        self.final_w = int(w)
        self.growth_events = tuple(growth_events)
        self.rounds = int(t)


class StreamSession:
    """One resident engine session fed by a workload generator."""

    def __init__(self, sender: RSMConfig, receiver: RSMConfig,
                 sim: SimConfig = SimConfig(),
                 config: StreamConfig = StreamConfig(),
                 failures=None):
        self.sender, self.receiver = sender, receiver
        self.capacity = analytic_capacity(sender, receiver, config.net,
                                          window=sim.window)
        process = config.process
        if config.utilization is not None:
            rate = max(config.utilization
                       * self.capacity["msgs_per_round"], 1e-3)
            process = dataclasses.replace(process, rate=rate)
            config = dataclasses.replace(config, process=process)
        self.config = config
        self.spec = build_stream_spec(sender, receiver, sim, process,
                                      config.horizon)
        if failures is not None:
            self.spec = spec_with_failures(self.spec, failures)
        self.arrivals = arrivals_per_round(process, config.horizon)

    def _specs(self) -> List[SimSpec]:
        return [self.spec] * max(self.config.links, 1)

    def _compile_schedule(self, fail_schedule, n_lanes: int):
        """Normalize an attack schedule into the engine callback.

        Accepts the engine's native callable form, or a mapping
        ``{round: FailureScenario | SimSpec}`` applied to every lane —
        the convenient way to switch a palette adversary on and off
        mid-stream (``{t_on: scenario, t_off: FailureScenario.none()}``)
        and watch the SLO watchdogs breach and recover. Swap rounds
        must be chunk boundaries (the only host-observable points).
        """
        if fail_schedule is None or callable(fail_schedule):
            return fail_schedule
        chunk = max(self.spec.chunk_steps, 1)
        swaps = {}
        for t, f in fail_schedule.items():
            if int(t) % chunk != 0:
                raise ValueError(
                    f"attack schedule round {t} is not a chunk boundary "
                    f"(chunk_steps={chunk}); swaps can only take effect "
                    f"where the scan state is host-observable")
            s = f if isinstance(f, SimSpec) else \
                spec_with_failures(self.spec, f)
            swaps[int(t)] = [s] * n_lanes
        return lambda t: swaps.get(int(t))

    def run(self, tracer: Optional[SpanTracer] = None,
            fail_schedule=None) -> StreamResult:
        cfg = self.config
        specs = self._specs()
        n_lanes = len(specs)
        schedule = self._compile_schedule(fail_schedule, n_lanes)
        arrivals_cum = np.concatenate(
            [[0], np.cumsum(self.arrivals)]).astype(np.int64)
        agg = LiveAggregator(n_lanes, arrivals_cum,
                             window_chunks=cfg.window_chunks)
        watchdog = SLOWatchdog(cfg.slo)
        report = LiveReport(jsonl_path=cfg.jsonl_path)
        sink = _EngineSink(cfg, agg, watchdog, report)
        commit_floors = None
        if cfg.chained and n_lanes > 1:
            from ..topology.engine import FloorPlanner
            commit_floors = FloorPlanner.chain(n_lanes, self.spec.m,
                                               keep_history=False)
        tracer = tracer or SpanTracer()
        t0, d0, s0 = (chunk_trace_count(), chunk_dispatch_count(),
                      host_sync_count())
        try:
            with tracing(tracer):
                out = _run_windowed_batch(specs,
                                          commit_floors=commit_floors,
                                          fail_schedule=schedule,
                                          drain_sink=sink)
            assert out == []          # horizon mode returns no mirrors
        finally:
            report.close()
        counters = {"traces": chunk_trace_count() - t0,
                    "dispatches": chunk_dispatch_count() - d0,
                    "syncs": host_sync_count() - s0,
                    "chunks_drained": sink.chunks,
                    "live_rows": report.total_rows}

        obs = [obs_from_final(sink.final_mc, [], b)
               for b in range(n_lanes)]
        problems = self._validate(agg, obs)
        delivered = int(agg.delivered.sum())
        rounds = max(sink.rounds, 1)
        cap = dict(self.capacity)
        # sustained rate over the *loaded* rounds (the drain tail after
        # the last arrival serves stragglers, not offered load)
        active_rounds = max(len(self.arrivals), 1)
        sus_round = delivered / n_lanes / active_rounds
        cap.update(
            offered_msgs_per_round=float(cfg.process.rate),
            offered_frac=float(cfg.process.rate)
            / max(cap["msgs_per_round"], 1e-12),
            sustained_msgs_per_round=sus_round,
            sustained_msgs_per_s=sus_round / max(cfg.net.rtt_s, 1e-12),
            sustained_frac=sus_round / max(cap["msgs_per_round"], 1e-12),
        )
        return StreamResult(
            config=cfg, spec=self.spec, delivered=delivered,
            retired=int(agg.retired.sum()), rounds=rounds,
            horizon=cfg.horizon, sketch=agg.sketch(), obs=obs,
            live=report, slo_events=list(watchdog.events),
            capacity=cap, counters=counters,
            final_window_slots=sink.final_w,
            growth_events=sink.growth_events,
            spans=tracer.to_dict(), problems=problems)

    @staticmethod
    def _validate(agg: LiveAggregator, obs: List[ObsMetrics]) -> List[str]:
        """The live invariant: the sketch built purely by folding
        per-chunk deltas must equal the device's final cumulative
        histogram bit-exactly."""
        problems = []
        final_hist = np.stack([np.asarray(o.latency_hist, dtype=np.int64)
                               for o in obs])
        live_hist = np.asarray(agg.sketch().hist, dtype=np.int64)
        if live_hist.shape != final_hist.shape or \
                not np.array_equal(live_hist, final_hist):
            problems.append("live merged histogram != device final "
                            "histogram")
        for name in ("quack_events", "loss_events", "resend_total",
                     "uncounted", "occupancy_hwm", "gc_lag_hwm"):
            live_v = np.asarray(getattr(agg.cum, name)).reshape(-1)
            dev_v = np.asarray([getattr(o, name) for o in obs])
            if not np.array_equal(live_v, dev_v):
                problems.append(f"live {name} != device final")
        return problems


def run_stream(sender: RSMConfig, receiver: RSMConfig,
               sim: SimConfig = SimConfig(),
               config: StreamConfig = StreamConfig()) -> StreamResult:
    """One-call convenience wrapper around :class:`StreamSession`."""
    return StreamSession(sender, receiver, sim, config).run()
