"""repro.stream — streaming session driver ("C3B fabric as a service").

Turns the fixed M-message batch engine into a resident service: a
seeded workload generator (:mod:`repro.stream.workload` — constant /
diurnal / bursty / heavy-tailed arrival processes) schedules an
unbounded message horizon onto the link fabric, the engine runs it in
horizon mode (``drain_sink`` — O(W) device state, O(1) host memory per
superchunk, zero extra dispatches), and :mod:`repro.stream.session`
aggregates the per-chunk ``MetricsBlock`` feed into live percentiles,
rates, SLO watchdog events and a periodic ``LiveReport``, calibrated
against the analytic capacity model in ``core/network.py``.

CLI: ``python -m repro.stream`` (``--selftest`` for the CI smoke).
"""

from .session import (  # noqa: F401
    StreamConfig,
    StreamResult,
    StreamSession,
    analytic_capacity,
    run_stream,
)
from .workload import (  # noqa: F401
    ArrivalProcess,
    arrivals_per_round,
    build_stream_spec,
    dispatch_rounds,
    stream_window_slots,
)
