"""Data reconciliation over C3B (the paper's §6 application).

N RSMs (the paper's microbenchmark uses two) hold divergent key-value
stores: a common history plus keys the peers are missing or hold at older
versions. Each reconciliation round builds a full bidirectional mesh
topology — every ordered cluster pair is one C3B link, all executed as a
single vmapped windowed session — and every cluster streams the entries
its peer lacks. Received entries merge with last-writer-wins resolution
on ``(version, value)``, a commutative/idempotent merge in the spirit of
log-free state replication (merging *state deltas*, not replaying full
histories), so out-of-order delivery needs no sequencing: the delivered
*set* of a link, not just its prefix, is applied. Rounds repeat — each
round re-streams whatever differences remain (undelivered entries under
failures, or stores larger than one stream) — until the stores are equal
or ``max_rounds`` is hit.

The per-round deltas are computed from the global view of both stores,
modelling the digest exchange real reconcilers run out of band; the C3B
links carry the actual entries. ``use_reference=True`` runs every round
on the pure-numpy multi-link oracle instead of the vmapped engine; the
two must converge to identical stores on every fixture
(``tests/test_apps.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from ..core.types import FailureScenario, RSMConfig, SimConfig
from ..topology import (LinkSpec, RefTopologyResult, Topology,
                        TopologyResult, run_topology,
                        run_topology_reference)

__all__ = ["ReconciliationReport", "lww_merge", "run_reconciliation"]

# a store maps key -> (value, version); higher (version, value) wins.
Store = Dict[int, Tuple[int, int]]


def _wins(entry: Tuple[int, int], over: Optional[Tuple[int, int]]) -> bool:
    if over is None:
        return True
    return (entry[1], entry[0]) > (over[1], over[0])


def lww_merge(dst: Store, entries: Sequence[Tuple[int, int, int]]) -> int:
    """Merge ``(key, value, version)`` entries into ``dst`` (LWW).

    Returns how many entries changed the store. Commutative and
    idempotent, so delivery order across links/rounds cannot matter.
    """
    changed = 0
    for key, value, version in entries:
        if _wins((value, version), dst.get(key)):
            dst[key] = (value, version)
            changed += 1
    return changed


def _delta(src: Store, dst: Store) -> List[Tuple[int, int, int]]:
    """Entries of ``src`` that would change ``dst``, sorted by key."""
    return [(k, v, ver) for k, (v, ver) in sorted(src.items())
            if _wins((v, ver), dst.get(k))]


@dataclasses.dataclass
class ReconciliationReport:
    rounds: int                         # reconciliation rounds executed
    converged: bool                     # all stores identical at the end
    stores: Dict[str, Store]            # final stores (merged in place)
    exchanged: int                      # entries delivered+merged in total
    sessions: List[Union[TopologyResult, RefTopologyResult]]


def run_reconciliation(
        cfg: RSMConfig, stores: Dict[str, Store], sim: SimConfig,
        failures: Optional[Dict[str, FailureScenario]] = None,
        max_rounds: int = 4,
        use_reference: bool = False) -> ReconciliationReport:
    """Reconcile N divergent stores over a bidirectional C3B mesh.

    stores: cluster name -> store; merged **in place** round by round.
    failures: link name (``"a->b"``) -> that link's failure scenario,
    applied every round.
    """
    if len(stores) < 2:
        raise ValueError("reconciliation needs >= 2 stores")
    names = sorted(stores)
    m = sim.n_msgs
    run = run_topology_reference if use_reference else run_topology
    sessions: List[Union[TopologyResult, RefTopologyResult]] = []
    exchanged = 0
    rounds = 0

    for _ in range(max_rounds):
        deltas = {(a, b): _delta(stores[a], stores[b])
                  for a in names for b in names if a != b}
        if not any(deltas.values()):
            break
        rounds += 1
        links = tuple(
            LinkSpec(f"{a}->{b}", a, b,
                     (failures or {}).get(f"{a}->{b}",
                                          FailureScenario.none()))
            for a in names for b in names if a != b)
        topo = Topology(clusters={n: cfg for n in names}, links=links,
                        sim=sim)
        res = run(topo)
        sessions.append(res)
        for (a, b), delta in deltas.items():
            delivered = res[f"{a}->{b}"].delivered_mask()
            # message k of the link carries delta[k]; slots beyond the
            # delta (or beyond the stream) carry nothing this round.
            got = [delta[k] for k in range(min(len(delta), m))
                   if delivered[k]]
            exchanged += lww_merge(stores[b], got)

    converged = all(stores[n] == stores[names[0]] for n in names[1:])
    return ReconciliationReport(rounds=rounds, converged=converged,
                                stores=stores, exchanged=exchanged,
                                sessions=sessions)
