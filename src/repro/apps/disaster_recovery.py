"""Disaster recovery over C3B (the paper's §6 application).

A primary RSM streams its committed log to N backup RSMs over a fanout
topology (one C3B link per backup, all executed as one vmapped windowed
session). At a configured round every primary replica crashes; each
backup is left with whatever contiguous log prefix reached at least one
of its honest replicas. Failover then elects the most-caught-up backup
(longest applied prefix, deterministic name tiebreak) and, in a second
fanout session, the elected backup streams its log so the remaining
backups converge to the elected prefix. The report records both phases,
the election, and a convergence check on the reconstructed logs
themselves (payload values, not just lengths).

Backups apply their log *in order*: a backup's state after a phase is the
contiguous delivered prefix of that phase's stream — exactly an RSM
replaying a log — so holes (deliverable only out of order) do not count
until filled. With ``use_reference=True`` the same procedure runs on the
pure-numpy multi-link oracle instead of the vmapped engine; the two must
produce identical reports on every fixture (``tests/test_apps.py``).

With ``inject_via_replay=True`` the crash is no longer a static
schedule: phase 1 streams failure-free (on the primary side) while
``repro.replay`` records chunk-boundary checkpoints, and the crash is
*injected* at the last boundary before ``crash_at`` — a mid-stream
``FailArrays`` swap on the already-compiled chunk. The report is
bit-identical to the static-schedule run (a crash at round ``t`` only
affects rounds ``>= t``), and the returned ``phase1_trace`` holds the
pre-crash checkpoints, so what-if studies can fork alternative futures
(different crash times, no crash at all) from the same shared prefix
(``repro.replay.fork_whatif``; see ``examples/replay_whatif.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Union

import numpy as np

from ..core.gc import snap_to_boundary
from ..core.types import FailureScenario, RSMConfig, SimConfig
from ..replay.trace import Injection as _Injection
from ..topology import (RefTopologyResult, Topology, TopologyResult,
                        link_specs, run_topology, run_topology_reference)

__all__ = ["RecoveryReport", "run_disaster_recovery"]


@dataclasses.dataclass
class RecoveryReport:
    """Outcome of a primary-crash + failover + catch-up cycle."""

    elected: str                        # most-caught-up backup
    phase1_prefixes: Dict[str, int]     # per-backup applied prefix at crash
    final_prefixes: Dict[str, int]      # per-backup prefix after catch-up
    converged: bool                     # all backups hold the elected log
    recovered_log: np.ndarray           # the elected backup's log (payloads)
    phase1: Union[TopologyResult, RefTopologyResult]
    phase2: Optional[Union[TopologyResult, RefTopologyResult]]
    # replay-injection provenance (inject_via_replay only): the chunk
    # boundary the crash was injected at, and the recorded pre-crash
    # trace for what-if forking (engine runs only).
    injected_at: Optional[int] = None
    phase1_trace: Optional[object] = None

    @property
    def recovered_entries(self) -> int:
        return int(len(self.recovered_log))


def _with_primary_crash(fails: FailureScenario, n_s: int,
                        crash_at: Optional[int]) -> FailureScenario:
    """Overlay the primary's crash round on a per-backup link scenario."""
    if crash_at is None:
        return fails
    if fails.crash_s is not None and any(c >= 0 for c in fails.crash_s):
        raise ValueError("backup link scenarios describe the receiver "
                         "side; the primary crash is set via crash_at")
    return dataclasses.replace(fails, crash_s=(crash_at,) * n_s)


def _catchup_steps(m: int, n_s: int, window: int) -> int:
    """Rounds for a failure-free catch-up stream of m messages."""
    return m // max(n_s * max(window, 1), 1) + 16 * n_s + 48


def _oracle_with_injection(topo: Topology, at_step: int,
                           scenarios) -> RefTopologyResult:
    """Numpy oracle of the injected run: the merged schedule from
    scratch — base masks until ``at_step``, crash masks after."""

    def schedule(t):
        return scenarios if t == at_step else None

    return run_topology_reference(topo, fail_schedule=schedule)


def run_disaster_recovery(
        primary_cfg: RSMConfig, backup_cfg: RSMConfig,
        sim: SimConfig,
        backups: Sequence[str] = ("backup-0", "backup-1"),
        crash_at: Optional[int] = None,
        backup_failures: Optional[Dict[str, FailureScenario]] = None,
        payloads: Optional[np.ndarray] = None,
        use_reference: bool = False,
        inject_via_replay: bool = False) -> RecoveryReport:
    """Stream, crash, elect, catch up, verify convergence.

    backup_failures maps backup name -> receiver-side scenario on its
    link (crashed/byzantine backup replicas make the backups genuinely
    diverge); the primary's ``crash_at`` is overlaid on every link — as
    a static schedule by default, or as a replay-injected mid-stream
    event (``inject_via_replay=True``): the failure-free stream is
    recorded with checkpoints and the crash swapped in at the last chunk
    boundary before ``crash_at``, which produces the identical report
    and additionally returns the pre-crash trace for what-if forking.
    """
    if len(backups) < 2:
        raise ValueError("disaster recovery needs >= 2 backups (the "
                         "elected one must have peers to catch up)")
    m = sim.n_msgs
    payloads = (np.arange(m, dtype=np.int64) if payloads is None
                else np.asarray(payloads))
    if len(payloads) != m:
        raise ValueError(f"payloads has {len(payloads)} entries, stream "
                         f"carries {m}")
    run = run_topology_reference if use_reference else run_topology
    base_fails = {
        b: (backup_failures or {}).get(b, FailureScenario.none())
        for b in backups}
    fails = {
        b: _with_primary_crash(base_fails[b], primary_cfg.n, crash_at)
        for b in backups}

    # --- phase 1: primary streams its log until it crashes ---------------
    injected_at = None
    trace = None
    if inject_via_replay and crash_at is not None:
        # the crash is an *event*: record the no-crash stream, then swap
        # the crash schedule in at the last boundary before it hits.
        topo1 = Topology.fanout("primary", list(backups), primary_cfg,
                                sim, failures=base_fails,
                                backup_cfg=backup_cfg)
        injected_at = snap_to_boundary(
            crash_at, link_specs(topo1)[0].chunk_steps)
        injections = {
            f"primary->{b}": [_Injection(injected_at, fails[b])]
            for b in backups}
        if use_reference:
            r1 = _oracle_with_injection(topo1, injected_at,
                                        [fails[b] for b in backups])
        else:
            from ..replay import record_topology, replay_topology
            _, trace = record_topology(topo1)
            r1 = replay_topology(trace, injected_at, injections)
    else:
        topo1 = Topology.fanout("primary", list(backups), primary_cfg,
                                sim, failures=fails,
                                backup_cfg=backup_cfg)
        r1 = run(topo1)
    prefixes = {b: r1[f"primary->{b}"].delivered_prefix() for b in backups}

    # --- failover: elect the most-caught-up backup (name tiebreak) -------
    elected = min(sorted(backups), key=lambda b: -prefixes[b])
    e_prefix = prefixes[elected]
    recovered = payloads[:e_prefix].copy()
    behind = [b for b in backups if b != elected]

    # --- phase 2: elected backup streams its log to the others -----------
    final = dict(prefixes)
    r2 = None
    if e_prefix > 0 and any(prefixes[b] < e_prefix for b in behind):
        sim2 = dataclasses.replace(
            sim, n_msgs=e_prefix,
            steps=_catchup_steps(e_prefix, backup_cfg.n, sim.window))
        topo2 = Topology.fanout(elected, behind, backup_cfg, sim2)
        r2 = run(topo2)
        for b in behind:
            caught = r2[f"{elected}->{b}"].delivered_prefix()
            # the backup already held prefixes[b]; replaying the elected
            # log extends its contiguous applied prefix to the catch-up
            # stream's own delivered prefix (same entries, same order).
            final[b] = max(prefixes[b], caught)

    converged = all(final[b] == e_prefix for b in backups) and bool(
        np.array_equal(recovered, payloads[:e_prefix]))
    return RecoveryReport(
        elected=elected, phase1_prefixes=prefixes, final_prefixes=final,
        converged=converged, recovered_log=recovered, phase1=r1, phase2=r2,
        injected_at=injected_at, phase1_trace=trace)
