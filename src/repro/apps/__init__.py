"""Paper §6 applications of C3B, end-to-end on the topology layer.

    from repro.apps import run_disaster_recovery, run_reconciliation

Disaster recovery: a primary RSM streams its committed log to N backup
RSMs; on a primary crash, failover elects the most-caught-up backup and
a catch-up session converges the rest. Data reconciliation: N RSMs with
divergent key-value stores exchange deltas over a bidirectional link
mesh until the stores merge (last-writer-wins). Both run every link
through one vmapped windowed dispatch per chunk and are bit-identical to
the pure-numpy multi-link oracle (``use_reference=True``).
"""

from .disaster_recovery import RecoveryReport, run_disaster_recovery
from .reconciliation import (ReconciliationReport, lww_merge,
                             run_reconciliation)

__all__ = [
    "RecoveryReport", "run_disaster_recovery",
    "ReconciliationReport", "lww_merge", "run_reconciliation",
]
