"""Deterministic synthetic token pipeline (per-host sharded).

Every (step, host_shard) pair maps to the same tokens regardless of how
many hosts participate — the property that makes elastic re-sharding and
restart-after-failure exactly reproducible: a restarted job resumes the
stream at the same step with the same global batch.

Tokens follow a Zipf-like marginal with a deterministic mixing hash
(SplitMix64) so losses are stable across runs but not degenerate.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

__all__ = ["SyntheticTokens", "make_batch_iterator"]

_MASK = (1 << 64) - 1


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + 0x9E3779B97F4A7C15) & _MASK
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    return z ^ (z >> 31)


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2

    def batch_at(self, step: int, shard: int = 0,
                 n_shards: int = 1) -> Dict[str, np.ndarray]:
        """Deterministic batch for (step, shard-of-n)."""
        assert self.global_batch % n_shards == 0
        local = self.global_batch // n_shards
        rows = np.arange(local, dtype=np.uint64) + shard * local
        cols = np.arange(self.seq_len, dtype=np.uint64)
        base = (np.uint64(self.seed) * np.uint64(0x100000001B3)
                + np.uint64(step) * np.uint64(0x1000193)) & np.uint64(_MASK)
        grid = (rows[:, None] * np.uint64(self.seq_len * 2 + 1)
                + cols[None, :] + base) & np.uint64(_MASK)
        h = _splitmix64(grid)
        u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        # Zipf-ish: token = floor(V * u^a) has heavier mass on low ids
        tok = np.minimum((self.vocab * np.power(u, self.zipf_a)),
                         self.vocab - 1).astype(np.int32)
        return {"tokens": tok}


def make_batch_iterator(spec: SyntheticTokens, start_step: int = 0,
                        shard: int = 0, n_shards: int = 1
                        ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield spec.batch_at(step, shard, n_shards)
        step += 1
