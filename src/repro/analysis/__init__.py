"""repro.analysis — static & runtime correctness tooling for the engine.

PICSOU's performance claim rests on contracts the type system cannot
see: one device dispatch per K fused chunks, zero implicit device->host
transfers inside the windowed loop, zero recompilation on warm replay
resume. This package enforces them with three cooperating passes:

``astlint``
    A repo-specific AST linter over ``src/repro/**``: no host
    synchronization (``.item()`` / ``float()`` / ``np.asarray()`` /
    ``jax.device_get()``) on traced values inside scan bodies or
    jit-reachable functions, no Python ``if``/``while`` on tracer
    values, no ``jnp`` calls at module import time, ``donate_argnums``
    on every scan-carrying ``jax.jit``, and consistent static-vs-traced
    pytree field registration. Findings carry rule IDs, fix-it hints,
    an ``# analysis: ignore[rule]`` suppression syntax and a checked-in
    baseline (``ANALYSIS_BASELINE.txt``) for grandfathered cases.

``jaxprlint``
    A jaxpr/HLO-level auditor that traces the *actual* compiled chunk,
    superchunk, dense and replay programs and statically detects host
    callbacks inside fused spans, unexpected dtype widenings, large
    non-donated buffers and per-run dispatch-count estimates — emitted
    as the machine-readable ``ANALYSIS.json`` report.

``sanitizer``
    A runtime sanitizer context manager wiring ``jax.transfer_guard``
    plus implicit-transfer interposition and compile-cache-miss
    counting into any run, so tests and benches assert their dispatch
    contract ("<= ceil(C/K)+2 dispatches, 0 implicit transfers, 0
    recompiles warm") declaratively. The windowed engine arms it
    automatically behind ``SimConfig.debug_checks``.

``python -m repro.analysis --check`` runs all passes and is the CI
lint gate (see ``.github/workflows/ci.yml``).
"""

from .astlint import (RULES, Finding, lint_paths, lint_source, lint_tree,
                      load_baseline, partition)
from .jaxprlint import (ProgramAudit, audit_callable, audit_engine,
                        estimate_dispatches)
from .sanitizer import (DispatchContract, SanitizerError, SanitizerReport,
                        dispatch_bound, dispatch_contract, sanitized)

__all__ = [
    "Finding", "RULES", "lint_source", "lint_paths", "lint_tree",
    "load_baseline", "partition",
    "ProgramAudit", "audit_callable", "audit_engine", "estimate_dispatches",
    "DispatchContract", "SanitizerError", "SanitizerReport",
    "dispatch_bound", "dispatch_contract", "sanitized",
]
