"""Jaxpr/HLO-level auditor for the engine's actual compiled programs.

``astlint`` reasons about source text; this pass reasons about what JAX
will really stage. It traces the engine's dense, chunk, final-chunk and
superchunk programs exactly as the windowed loop builds them (same
constructors, same argument trees, tiny shapes) and checks, on the
jaxpr and on the lowered module:

* **host callbacks** — ``pure_callback`` / ``io_callback`` /
  ``debug_callback`` equations anywhere inside a fused span. One of
  these inside the superchunk scan serializes the whole span on the
  host and silently destroys the K× dispatch reduction (ROADMAP: the
  saturated-pipeline claim is only as strong as the dispatch path is
  clean).
* **dtype widenings** — ``convert_element_type`` to int64 / float64 /
  complex128. The engine is int32/bool/float32 end to end; an x64
  widening doubles the scan-state footprint and recompiles on
  machines with ``jax_enable_x64`` set.
* **donation** — per-argument input bytes, and whether the scan-state
  argument is donated on backends where XLA implements aliasing (the
  CPU client ignores donation, so there it is reported as info, not a
  violation).
* **dispatch estimates** — the exact number of device dispatches the
  host loop will issue for a (steps, chunk_steps, K) plan, computed by
  replicating the loop's span arithmetic; the sanitizer's runtime
  contract (``ceil(C/K) + 2``) is derived from the same numbers.

``audit_engine`` returns a JSON-ready dict (the ``jaxpr`` section of
``ANALYSIS.json``); the CLI fails ``--check`` when any audited program
is not clean.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

__all__ = ["ProgramAudit", "audit_callable", "audit_engine",
           "estimate_dispatches", "BANNED_PRIMITIVES"]

BANNED_PRIMITIVES = ("pure_callback", "io_callback", "debug_callback")
_WIDE_DTYPES = ("int64", "uint64", "float64", "complex128")


@dataclasses.dataclass
class ProgramAudit:
    """Static audit of one compiled program."""

    name: str
    n_eqns: int
    primitives: Tuple[str, ...]
    host_callbacks: Tuple[str, ...]        # banned primitive instances
    widenings: Tuple[str, ...]             # "int32->int64 (eqn ...)"
    arg_bytes: Tuple[int, ...]             # per top-level argument
    donated_args: Tuple[int, ...]          # argnums declared donated
    undonated_large: Tuple[int, ...]       # large argnums not donated
    donation_enforced: bool                # backend implements aliasing
    lowered_callback_calls: int            # custom_call cross-check
    notes: str = ""

    @property
    def ok(self) -> bool:
        """Clean = no host callbacks, no widenings, donation honoured
        wherever the backend implements it."""
        return (not self.host_callbacks and not self.widenings
                and self.lowered_callback_calls == 0
                and (not self.donation_enforced
                     or not self.undonated_large))

    def violations(self) -> List[str]:
        out = []
        for cb in self.host_callbacks:
            out.append(f"{self.name}: host callback '{cb}' inside the "
                       f"compiled program")
        if self.lowered_callback_calls:
            out.append(f"{self.name}: {self.lowered_callback_calls} "
                       f"callback custom-calls in the lowered module")
        for w in self.widenings:
            out.append(f"{self.name}: dtype widening {w}")
        if self.donation_enforced and self.undonated_large:
            out.append(f"{self.name}: large undonated args "
                       f"{list(self.undonated_large)}")
        return out

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["ok"] = self.ok
        # primitives can be long; keep the set, drop repetition order
        d["primitives"] = sorted(set(self.primitives))
        return d


def iter_eqns(jaxpr):
    """Yield every equation of ``jaxpr``, descending into sub-jaxprs
    (pjit bodies, scan bodies, cond branches, custom_* calls...)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for item in vals:
                sub = getattr(item, "jaxpr", None)
                if sub is not None:              # ClosedJaxpr
                    yield from iter_eqns(sub)
                elif hasattr(item, "eqns"):      # raw Jaxpr
                    yield from iter_eqns(item)


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(tree)
               if hasattr(leaf, "shape"))


def audit_callable(fn, args: Sequence[Any], name: str,
                   donate: Tuple[int, ...] = (),
                   large_bytes: int = 1 << 20,
                   lowered_text: Optional[str] = None) -> ProgramAudit:
    """Trace ``fn(*args)`` and audit the staged program.

    ``donate`` is the donate_argnums the caller compiles with;
    ``lowered_text``, when given, is the lowered module text used for
    the callback custom-call cross-check (pass it for jitted callables;
    omitting it skips the HLO-level check).
    """
    closed = jax.make_jaxpr(fn)(*args)
    prims: List[str] = []
    callbacks: List[str] = []
    widenings: List[str] = []
    for eqn in iter_eqns(closed.jaxpr):
        prim = eqn.primitive.name
        prims.append(prim)
        if prim in BANNED_PRIMITIVES:
            callbacks.append(prim)
        if prim == "convert_element_type":
            new = str(eqn.params.get("new_dtype", ""))
            if any(new.startswith(w) for w in _WIDE_DTYPES):
                old = str(eqn.invars[0].aval.dtype)
                widenings.append(f"{old}->{new}")

    arg_bytes = tuple(_tree_bytes(a) for a in args)
    undonated = tuple(i for i, b in enumerate(arg_bytes)
                      if b >= large_bytes and i not in donate)
    callback_calls = 0
    if lowered_text is not None:
        callback_calls = lowered_text.count("callback")
    return ProgramAudit(
        name=name, n_eqns=len(prims), primitives=tuple(prims),
        host_callbacks=tuple(callbacks), widenings=tuple(widenings),
        arg_bytes=arg_bytes, donated_args=tuple(donate),
        undonated_large=undonated,
        donation_enforced=jax.default_backend() != "cpu",
        lowered_callback_calls=callback_calls)


def estimate_dispatches(steps: int, chunk_steps: int, k: int) -> int:
    """Device dispatches the windowed host loop issues for this plan.

    Replicates ``_run_windowed_batch``'s span arithmetic exactly
    (fusion capped at K, broken at the final/partial chunk), assuming
    no mandatory host boundary fires mid-run — the clean-pipeline
    number the sanitizer contract is measured against.
    """
    c_full = max(chunk_steps, 1)
    t, n = 0, 0
    while t < steps:
        c = min(c_full, steps - t)
        last = t + c >= steps
        span = 1
        if not last and c == c_full:
            span = max(1, min(max(k, 1), (steps - t - 1) // c_full))
        n += 1
        t += span * c
    return n


def _tiny_spec(m: int = 64, window_slots: int = 16, chunk_steps: int = 4,
               superchunk: int = 8):
    from ..core import RSMConfig, SimConfig
    from ..core.simulator import build_spec
    rsm = RSMConfig.bft(1)
    sim = SimConfig(n_msgs=m, steps=m // 4 + 24, window=1, phi=6,
                    window_slots=window_slots, chunk_steps=chunk_steps,
                    superchunk=superchunk)
    return build_spec(rsm, rsm, sim)


def audit_engine(m: int = 64, window_slots: int = 16,
                 chunk_steps: int = 4, superchunk: int = 8,
                 with_lowered: bool = True) -> Dict[str, Any]:
    """Audit the engine's real programs at a tiny windowed shape.

    Programs audited (the same constructors the host loop calls — the
    audit cannot drift from the implementation):

    * ``dense``          — the full-M runner (``_build_run``);
    * ``chunk``          — one rotating windowed chunk, batched
                           (``_build_chunk`` + vmap). This is ALSO the
                           replay resume/injection program (K = 1) and
                           the chained-topology program (commit floors
                           are traced inputs of the same jaxpr);
    * ``chunk_final``    — the unrotated final chunk;
    * ``superchunk``     — K fused chunk bodies (``lax.scan`` over
                           boundaries), the pipelined hot path;
    * ``chunk_obs`` / ``superchunk_obs`` — the same chunk/superchunk
                           programs with the in-graph metrics fabric on
                           (``collect_metrics=True``, carry =
                           ``(SimState, MetricsCarry)``): the
                           observability layer must satisfy the exact
                           same cleanliness contract as the bare engine
                           (no callbacks, no widenings, donated carry);
    * ``chunk_stream`` / ``superchunk_stream`` — horizon-mode programs
                           staged at a ``repro.stream`` spec (arrival-
                           driven ``orig_step``, load-sized window,
                           metrics carry feeding the live drain sink):
                           the resident streaming service runs these
                           exact programs over unbounded horizons.
    """
    import dataclasses as dc

    import jax.numpy as jnp

    from ..core.simulator import (_build_chunk, _build_run, _donate_state,
                                  _fail_arrays, _init_state, _neutral,
                                  _max_msg_by_round)

    spec = _tiny_spec(m, window_slots, chunk_steps, superchunk)
    nspec = _neutral(spec)
    cspec = dc.replace(nspec, steps=0)
    w, c, k = spec.window_slots, spec.chunk_steps, spec.superchunk

    fails = _fail_arrays(spec)
    bfails = jax.tree_util.tree_map(lambda x: jnp.broadcast_to(
        x, (1,) + jnp.shape(x)), fails)
    state = _init_state(nspec, w)
    bstate = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (1,) + x.shape), state)
    t0 = jnp.int32(0)
    donate = _donate_state()

    audits: List[ProgramAudit] = []

    dense_fn = _build_run(nspec)
    audits.append(audit_callable(
        dense_fn, (fails,), "dense",
        lowered_text=(jax.jit(dense_fn).lower(fails).as_text()
                      if with_lowered else None)))

    for rotate, name in ((True, "chunk"), (False, "chunk_final")):
        fn = jax.vmap(_build_chunk(cspec, w, c, rotate),
                      in_axes=(0, 0, None))
        audits.append(audit_callable(
            fn, (bfails, bstate, t0), name, donate=donate,
            lowered_text=(jax.jit(fn, donate_argnums=donate)
                          .lower(bfails, bstate, t0).as_text()
                          if with_lowered else None)))

    # the superchunk program, staged through the real cached constructor
    from ..core.simulator import _compiled_batch_superchunk
    sc = _compiled_batch_superchunk(cspec, w, c, k)
    dispatched_by = _max_msg_by_round(spec)
    needs = jnp.asarray(
        np.minimum(dispatched_by[c - 1::c][:k], spec.m).astype(np.int32))
    if needs.shape[0] < k:                      # short plans: pad needs
        needs = jnp.concatenate(
            [needs, jnp.full((k - needs.shape[0],), spec.m, jnp.int32)])
    audits.append(audit_callable(
        sc, (bfails, bstate, t0, needs), "superchunk", donate=donate,
        lowered_text=(sc.lower(bfails, bstate, t0, needs).as_text()
                      if with_lowered else None)))

    # the observability fabric's programs: same constructors with
    # collect_metrics on, scan carry = (SimState, MetricsCarry)
    from ..obs.metrics import init_metrics_carry
    mspec = dc.replace(cspec, collect_metrics=True)
    bmc = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (1,) + jnp.shape(x)),
        init_metrics_carry(w))
    bcarry = (bstate, bmc)
    fn_obs = jax.vmap(_build_chunk(mspec, w, c, True),
                      in_axes=(0, 0, None))
    audits.append(audit_callable(
        fn_obs, (bfails, bcarry, t0), "chunk_obs", donate=donate,
        lowered_text=(jax.jit(fn_obs, donate_argnums=donate)
                      .lower(bfails, bcarry, t0).as_text()
                      if with_lowered else None)))
    sc_obs = _compiled_batch_superchunk(mspec, w, c, k)
    audits.append(audit_callable(
        sc_obs, (bfails, bcarry, t0, needs), "superchunk_obs",
        donate=donate,
        lowered_text=(sc_obs.lower(bfails, bcarry, t0, needs).as_text()
                      if with_lowered else None)))

    # horizon-mode (streaming-session) programs: the same chunk /
    # superchunk constructors, staged at a *stream* spec — an
    # arrival-process ``orig_step`` schedule, a load-sized window from
    # ``stream_window_slots`` and the metrics carry that feeds the live
    # drain sink. The resident-service hot path must satisfy the exact
    # same cleanliness contract as the batch engine; the import is lazy
    # (repro.stream sits above repro.analysis in the layer order).
    from ..core import RSMConfig, SimConfig
    from ..stream.workload import ArrivalProcess, build_stream_spec
    sspec = build_stream_spec(
        RSMConfig.bft(1), RSMConfig.bft(1),
        SimConfig(window=1, phi=6, window_slots="auto",
                  chunk_steps=chunk_steps, superchunk=superchunk),
        ArrivalProcess(kind="constant", rate=4.0), horizon=m)
    s_cspec = dc.replace(_neutral(sspec), steps=0)
    sw, s_c, s_k = (sspec.window_slots, sspec.chunk_steps,
                    sspec.superchunk)
    sfails = _fail_arrays(sspec)
    sbfails = jax.tree_util.tree_map(lambda x: jnp.broadcast_to(
        x, (1,) + jnp.shape(x)), sfails)
    sbcarry = (
        jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (1,) + x.shape),
            _init_state(s_cspec, sw)),
        jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (1,) + jnp.shape(x)),
            init_metrics_carry(sw)))
    fn_stream = jax.vmap(_build_chunk(s_cspec, sw, s_c, True),
                         in_axes=(0, 0, None))
    audits.append(audit_callable(
        fn_stream, (sbfails, sbcarry, t0), "chunk_stream",
        donate=donate,
        lowered_text=(jax.jit(fn_stream, donate_argnums=donate)
                      .lower(sbfails, sbcarry, t0).as_text()
                      if with_lowered else None)))
    s_by = _max_msg_by_round(sspec)
    s_needs = jnp.asarray(np.minimum(
        s_by[s_c - 1::s_c][:s_k], sspec.m).astype(np.int32))
    if s_needs.shape[0] < s_k:
        s_needs = jnp.concatenate(
            [s_needs,
             jnp.full((s_k - s_needs.shape[0],), sspec.m, jnp.int32)])
    sc_stream = _compiled_batch_superchunk(s_cspec, sw, s_c, s_k)
    audits.append(audit_callable(
        sc_stream, (sbfails, sbcarry, t0, s_needs), "superchunk_stream",
        donate=donate,
        lowered_text=(sc_stream.lower(sbfails, sbcarry, t0,
                                      s_needs).as_text()
                      if with_lowered else None)))

    n_chunks = -(-spec.steps // c)
    estimates = []
    for kk in sorted({1, 2, k, 8}):
        estimates.append(dict(
            steps=spec.steps, chunk_steps=c, k=kk, n_chunks=n_chunks,
            dispatches=estimate_dispatches(spec.steps, c, kk),
            contract_bound=-(-n_chunks // kk) + 2))

    violations = [v for a in audits for v in a.violations()]
    return {
        "shape": dict(m=spec.m, steps=spec.steps, window_slots=w,
                      chunk_steps=c, superchunk=k,
                      backend=jax.default_backend()),
        "programs": [a.to_dict() for a in audits],
        "program_reuse": {
            "replay_resume": "chunk (K=1, zero-recompilation contract)",
            "topology_chained": "chunk (commit floors are traced inputs)",
        },
        "dispatch_estimates": estimates,
        "violations": violations,
        "ok": not violations,
    }
