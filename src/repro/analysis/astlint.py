"""Trace-discipline AST linter for the PICSOU engine (repo-specific).

The engine's hot path is a handful of functions that execute *inside*
``jax.jit`` / ``jax.lax.scan`` tracing — the chunk body, the superchunk
scan, the protocol step. A single host synchronization (``.item()``,
``np.asarray`` on a tracer, a Python ``if`` on a traced value) in one of
them either fails at trace time in some configuration nobody tested, or
— worse — silently breaks superchunk fusion by forcing a device sync
per chunk. This linter finds those hazards statically.

Trace contexts are discovered per module, without importing anything:

* functions decorated with ``@jax.jit`` (directly or via
  ``functools.partial(jax.jit, ...)``);
* functions passed to ``jax.jit`` / ``jax.vmap`` / ``jax.lax.scan`` /
  ``lax.cond`` / ``lax.while_loop`` / ``lax.fori_lax`` call sites —
  including through arbitrary ``jax.vmap(...)`` nesting;
* the *builder pattern* the engine uses everywhere: when the wrapped
  argument is a call to a local function (``jax.jit(_build_run(spec))``),
  every function nested directly inside that builder is a trace context;
* anything transitively called (by module-local name) from the above.

Rules (each finding carries the rule ID, a fix-it hint and supports
``# analysis: ignore[rule-id]`` on the flagged line; ``ANALYSIS_BASELINE
.txt`` grandfathers pre-existing findings by fingerprint):

``host-sync``
    ``.item()`` / ``float()`` / ``int()`` / ``bool()`` / ``np.asarray()``
    / ``np.array()`` / ``jax.device_get()`` on a non-constant value
    inside a trace context — a device->host sync (or a trace error).
``tracer-branch``
    Python ``if`` / ``while`` whose test reads values computed *inside*
    a trace context (parameters or locals). Branching on closure
    variables from the enclosing builder is fine — those are static at
    trace time.
``import-time-jnp``
    A ``jnp.*`` call in module (or class) scope: it initializes the JAX
    backend as an import side effect and freezes platform selection
    before the caller can configure it.
``missing-donate``
    A ``jax.jit`` whose callee (transitively) carries ``lax.scan`` state
    but declares no ``donate_argnums`` / ``donate_argnames`` — the scan
    state is copied instead of aliased on every dispatch.
``pytree-fields``
    Inconsistent static-vs-traced pytree registration: a frozen (i.e.
    hashable, compile-cache-key) dataclass declaring array-typed fields,
    or a NamedTuple constructed inside a trace context declaring plain
    ``int`` / ``float`` / ``bool`` / ``str`` fields.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["Finding", "RULES", "lint_source", "lint_paths", "lint_tree",
           "load_baseline", "partition"]

# rule-id -> (summary, fix-it hint)
RULES: Dict[str, Tuple[str, str]] = {
    "host-sync": (
        "host synchronization on a traced value inside a trace context",
        "keep the value on device (jnp ops) or move the host read to the "
        "chunk-boundary drain; jax.device_get belongs in the host loop "
        "only",
    ),
    "tracer-branch": (
        "Python if/while on a tracer-valued expression",
        "use jax.lax.cond / lax.select / jnp.where on traced values; "
        "branch on builder closure values only",
    ),
    "import-time-jnp": (
        "jnp call at module import time",
        "use a plain Python constant, or build the array lazily inside "
        "the function that needs it — import-time jnp calls initialize "
        "the JAX backend before the caller can configure it",
    ),
    "missing-donate": (
        "jax.jit over a scan-carrying callee without donate_argnums",
        "declare donate_argnums for the scan-state argument so XLA "
        "aliases input to output buffers (see simulator._donate_state)",
    ),
    "pytree-fields": (
        "inconsistent static-vs-traced pytree field registration",
        "frozen (compile-key) dataclasses must hold only hashable "
        "static fields; NamedTuple state trees built under tracing must "
        "annotate every field as an array",
    ),
}

_IGNORE_RE = re.compile(r"#\s*analysis:\s*ignore\[([a-z\-,\s]+)\]")

# callables that take a traceable function argument (positions given)
_WRAPPER_FUNC_ARGS = {
    "jax.jit": (0,), "jit": (0,),
    "jax.vmap": (0,), "vmap": (0,),
    "jax.pmap": (0,), "jax.grad": (0,), "jax.value_and_grad": (0,),
    "jax.lax.scan": (0,), "lax.scan": (0,),
    "jax.lax.map": (0,), "lax.map": (0,),
    "jax.lax.cond": (1, 2), "lax.cond": (1, 2),
    "jax.lax.while_loop": (0, 1), "lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,), "lax.fori_loop": (2,),
    "jax.checkpoint": (0,), "jax.remat": (0,),
}

_JIT_NAMES = {"jax.jit", "jit"}
_SCAN_NAMES = {"jax.lax.scan", "lax.scan"}
_ARRAY_ANNOT = ("jnp.ndarray", "jax.Array", "jnp.array", "np.ndarray",
                "chex.Array", "Array", "ndarray", "ArrayLike")
_STATIC_ANNOT = {"int", "float", "bool", "str", "bytes"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint finding: rule ID + location + hint + stable fingerprint."""

    rule: str
    path: str
    line: int
    col: int
    symbol: str          # enclosing function qualname, or flagged name
    message: str

    @property
    def hint(self) -> str:
        return RULES[self.rule][1]

    def fingerprint(self) -> str:
        """Line-number-free identity used by the baseline file."""
        return f"{self.rule}::{self.path}::{self.symbol}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: [{self.rule}] "
                f"{self.message}\n    hint: {self.hint}")


def _static_argnames(dec: ast.Call) -> Set[str]:
    """Names declared static in a jit decorator call (literal tuples)."""
    out: Set[str] = set()
    for kw in dec.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            for node in ast.walk(kw.value):
                if (isinstance(node, ast.Constant)
                        and isinstance(node.value, str)):
                    out.add(node.value)
    return out


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of a call target ('jax.lax.scan', ...)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


class _Scope:
    """One function (or module) scope: nested defs + locals."""

    def __init__(self, node, parent: Optional["_Scope"], qualname: str):
        self.node = node
        self.parent = parent
        self.qualname = qualname
        self.defs: Dict[str, "_Scope"] = {}
        self.locals: Set[str] = set()
        self.static_names: Set[str] = set()   # jit static_argnames
        self.is_trace = False

    def resolve(self, name: str) -> Optional["_Scope"]:
        scope: Optional[_Scope] = self
        while scope is not None:
            if name in scope.defs:
                return scope.defs[name]
            scope = scope.parent
        return None


class _ModuleLinter:
    def __init__(self, tree: ast.Module, src: str, path: str):
        self.tree = tree
        self.path = path
        self.lines = src.splitlines()
        self.findings: List[Finding] = []
        self.module = _Scope(tree, None, "<module>")
        self._index_scopes(tree, self.module)

    # -- scope index ----------------------------------------------------
    def _index_scopes(self, node: ast.AST, scope: _Scope) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = (child.name if scope is self.module
                        else f"{scope.qualname}.{child.name}")
                sub = _Scope(child, scope, qual)
                scope.defs[child.name] = sub
                self._collect_locals(child, sub)
                self._index_scopes(child, sub)
            elif isinstance(child, ast.ClassDef):
                # class bodies share the enclosing scope for resolution
                self._index_scopes(child, scope)
            elif isinstance(child, ast.Lambda):
                self._index_scopes(child, scope)
            else:
                self._index_scopes(child, scope)

    @staticmethod
    def _collect_locals(fn, scope: _Scope) -> None:
        args = fn.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs
                  + ([args.vararg] if args.vararg else [])
                  + ([args.kwarg] if args.kwarg else [])):
            scope.locals.add(a.arg)
        for sub in ast.walk(fn):
            if sub is fn:
                continue
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scope.locals.add(sub.name)
                continue
            if isinstance(sub, ast.Assign):
                for tgt in sub.targets:
                    for n in ast.walk(tgt):
                        if isinstance(n, ast.Name):
                            scope.locals.add(n.id)
            elif isinstance(sub, (ast.AnnAssign, ast.AugAssign)):
                if isinstance(sub.target, ast.Name):
                    scope.locals.add(sub.target.id)
            elif isinstance(sub, ast.NamedExpr):
                if isinstance(sub.target, ast.Name):
                    scope.locals.add(sub.target.id)
            elif isinstance(sub, ast.For):
                for n in ast.walk(sub.target):
                    if isinstance(n, ast.Name):
                        scope.locals.add(n.id)
            elif isinstance(sub, ast.comprehension):
                for n in ast.walk(sub.target):
                    if isinstance(n, ast.Name):
                        scope.locals.add(n.id)

    # -- trace-context discovery ---------------------------------------
    def _scope_of(self, node: ast.AST) -> _Scope:
        """The innermost scope whose function contains ``node``."""
        best = self.module
        stack: List[Tuple[ast.AST, _Scope]] = [(self.tree, self.module)]
        while stack:
            cur, scope = stack.pop()
            for child in ast.iter_child_nodes(cur):
                sub = scope
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    sub = scope.defs.get(child.name, scope)
                if child is node:
                    return sub
                stack.append((child, sub))
        return best

    def _mark_trace_roots(self) -> None:
        # (a) decorated defs
        for scope in self._all_scopes():
            node = scope.node
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            for dec in node.decorator_list:
                name = _dotted(dec if not isinstance(dec, ast.Call)
                               else dec.func)
                if name in _JIT_NAMES:
                    scope.is_trace = True
                    if isinstance(dec, ast.Call):
                        scope.static_names |= _static_argnames(dec)
                if (isinstance(dec, ast.Call)
                        and name in ("functools.partial", "partial")
                        and dec.args
                        and _dotted(dec.args[0]) in _JIT_NAMES):
                    scope.is_trace = True
                    scope.static_names |= _static_argnames(dec)
        # (b) call-site wrapped functions, resolved in the *enclosing*
        # scope of the call site (so `jax.lax.scan(step, ...)` inside a
        # builder marks the builder-local `step`)
        for call, scope in self._calls_with_scopes():
            name = _dotted(call.func)
            positions = _WRAPPER_FUNC_ARGS.get(name)
            if positions is None:
                continue
            for pos in positions:
                if pos < len(call.args):
                    self._mark_callable_expr(call.args[pos], scope)

    def _mark_callable_expr(self, expr: ast.AST, scope: _Scope) -> None:
        if isinstance(expr, ast.Name):
            target = scope.resolve(expr.id)
            if target is not None:
                target.is_trace = True
        elif isinstance(expr, ast.Lambda):
            # treated as part of the enclosing trace context; rules run
            # over the whole function body anyway
            pass
        elif isinstance(expr, ast.Call):
            inner = _dotted(expr.func)
            if inner in _WRAPPER_FUNC_ARGS:     # jax.vmap(fn) nesting
                for pos in _WRAPPER_FUNC_ARGS[inner]:
                    if pos < len(expr.args):
                        self._mark_callable_expr(expr.args[pos], scope)
            else:
                # builder pattern: jit(_build_chunk(...)) — everything
                # defined directly inside the builder is trace code
                builder = (scope.resolve(inner)
                           if inner and "." not in inner else None)
                if builder is not None:
                    for sub in builder.defs.values():
                        sub.is_trace = True

    def _propagate_trace(self) -> None:
        changed = True
        while changed:
            changed = False
            for scope in self._all_scopes():
                if not scope.is_trace:
                    continue
                for call in ast.walk(scope.node):
                    if not isinstance(call, ast.Call):
                        continue
                    if isinstance(call.func, ast.Name):
                        callee = scope.resolve(call.func.id)
                        if callee is not None and not callee.is_trace:
                            callee.is_trace = True
                            changed = True

    def _all_scopes(self) -> Iterable[_Scope]:
        stack = [self.module]
        while stack:
            s = stack.pop()
            yield s
            stack.extend(s.defs.values())

    def _calls_with_scopes(self):
        out = []
        stack: List[Tuple[ast.AST, _Scope]] = [(self.tree, self.module)]
        while stack:
            node, scope = stack.pop()
            for child in ast.iter_child_nodes(node):
                sub = scope
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    sub = scope.defs.get(child.name, scope)
                if isinstance(child, ast.Call):
                    out.append((child, sub))
                stack.append((child, sub))
        return out

    # -- suppression ----------------------------------------------------
    def _suppressed(self, rule: str, line: int) -> bool:
        if 1 <= line <= len(self.lines):
            m = _IGNORE_RE.search(self.lines[line - 1])
            if m:
                rules = {r.strip() for r in m.group(1).split(",")}
                return rule in rules or "all" in rules
        return False

    def _emit(self, rule: str, node: ast.AST, symbol: str,
              message: str) -> None:
        line = getattr(node, "lineno", 1)
        if self._suppressed(rule, line):
            return
        self.findings.append(Finding(
            rule=rule, path=self.path, line=line,
            col=getattr(node, "col_offset", 0), symbol=symbol,
            message=message))

    # -- rules ----------------------------------------------------------
    def run(self) -> List[Finding]:
        self._mark_trace_roots()
        self._propagate_trace()
        self._rule_import_time_jnp()
        self._rule_missing_donate()
        self._rule_pytree_fields()
        for scope in self._all_scopes():
            if scope.is_trace:
                self._rules_in_trace(scope)
        self.findings.sort(key=lambda f: (f.path, f.line, f.col))
        return self.findings

    def _rules_in_trace(self, scope: _Scope) -> None:
        fn = scope.node
        nested = {s.node for s in scope.defs.values()}
        # walk this function's own statements only (nested defs get
        # their own pass when they are trace contexts themselves)
        for node in self._walk_own(fn, nested):
            if isinstance(node, ast.Call):
                self._check_host_sync(node, scope)
            elif isinstance(node, (ast.If, ast.While)):
                self._check_tracer_branch(node, scope)

    @staticmethod
    def _walk_own(fn, nested_defs) -> Iterable[ast.AST]:
        stack = list(ast.iter_child_nodes(fn))
        while stack:
            node = stack.pop()
            if node in nested_defs:
                continue
            yield node
            stack.extend(ast.iter_child_nodes(node))

    def _check_host_sync(self, call: ast.Call, scope: _Scope) -> None:
        name = _dotted(call.func)
        if (isinstance(call.func, ast.Attribute)
                and call.func.attr == "item" and not call.args):
            self._emit("host-sync", call, scope.qualname,
                       ".item() forces a device->host sync inside a "
                       "trace context")
            return
        if name in ("np.asarray", "np.array", "numpy.asarray",
                    "numpy.array", "onp.asarray", "onp.array"):
            self._emit("host-sync", call, scope.qualname,
                       f"{name}() materializes a traced value on the "
                       f"host inside a trace context")
            return
        if name in ("jax.device_get", "device_get"):
            self._emit("host-sync", call, scope.qualname,
                       "jax.device_get() inside a trace context — the "
                       "host drain belongs in the chunk-boundary loop")
            return
        if (isinstance(call.func, ast.Name)
                and call.func.id in ("float", "int", "bool")
                and call.args
                and not isinstance(call.args[0], ast.Constant)):
            self._emit("host-sync", call, scope.qualname,
                       f"{call.func.id}() on a non-constant value "
                       f"concretizes a tracer inside a trace context")

    @staticmethod
    def _static_comparison(test: ast.AST) -> bool:
        """True when the test can only be a static (trace-time) branch.

        Comparisons whose right-hand sides are string / ``None``
        literals (or containers of them) are static by construction —
        comparing a tracer against a string would not compile at all,
        so ``if kind == "rwkv"`` is config dispatch, not data-dependent
        control flow. ``isinstance`` tests are likewise static.
        """
        comparisons = [n for n in ast.walk(test)
                       if isinstance(n, ast.Compare)]
        names_in_compares: Set[int] = set()
        for cmp_node in comparisons:
            static_rhs = True
            for comparator in cmp_node.comparators:
                consts = [c for c in ast.walk(comparator)
                          if isinstance(c, ast.Constant)]
                if not consts or not all(
                        isinstance(c.value, (str, bytes))
                        or c.value is None for c in consts):
                    static_rhs = False
            if static_rhs:
                for n in ast.walk(cmp_node):
                    names_in_compares.add(id(n))
        for n in ast.walk(test):
            if (isinstance(n, ast.Call)
                    and _dotted(n.func) == "isinstance"):
                for sub in ast.walk(n):
                    names_in_compares.add(id(sub))
        # static iff every Name occurrence is inside a static compare
        for n in ast.walk(test):
            if isinstance(n, ast.Name) and id(n) not in names_in_compares:
                return False
        return True

    def _check_tracer_branch(self, node, scope: _Scope) -> None:
        if self._static_comparison(node.test):
            return
        suspect = []
        for sub in ast.walk(node.test):
            if (isinstance(sub, ast.Name) and sub.id in scope.locals
                    and sub.id not in scope.static_names):
                suspect.append(sub.id)
        if suspect:
            kw = "if" if isinstance(node, ast.If) else "while"
            self._emit("tracer-branch", node, scope.qualname,
                       f"Python {kw} on {', '.join(sorted(set(suspect)))} "
                       f"— locals of a trace context are traced values; "
                       f"control flow must be lax.cond/select")

    def _rule_import_time_jnp(self) -> None:
        def scan_body(body, where: str) -> None:
            for stmt in body:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if isinstance(stmt, ast.ClassDef):
                    scan_body(stmt.body, stmt.name)
                    continue
                for node in ast.walk(stmt):
                    if isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef, ast.Lambda)):
                        break
                    if isinstance(node, ast.Call):
                        name = _dotted(node.func)
                        if name.startswith(("jnp.", "jax.numpy.")):
                            target = name
                            self._emit(
                                "import-time-jnp", node,
                                f"{where}:{name}",
                                f"{target}() runs at import time and "
                                f"initializes the JAX backend as a side "
                                f"effect")

        scan_body(self.tree.body, "<module>")

    def _reaches_scan(self, scope: _Scope, seen=None) -> bool:
        if seen is None:
            seen = set()
        if scope in seen:
            return False
        seen.add(scope)
        for node in ast.walk(scope.node):
            if isinstance(node, ast.Call):
                if _dotted(node.func) in _SCAN_NAMES:
                    return True
                if isinstance(node.func, ast.Name):
                    callee = scope.resolve(node.func.id)
                    if callee is not None and self._reaches_scan(callee,
                                                                 seen):
                        return True
        for sub in scope.defs.values():
            if self._reaches_scan(sub, seen):
                return True
        return False

    def _callee_scopes(self, expr: ast.AST,
                       scope: _Scope) -> List[_Scope]:
        """Scopes a jit-wrapped argument expression may execute."""
        if isinstance(expr, ast.Name):
            target = scope.resolve(expr.id)
            return [target] if target is not None else []
        if isinstance(expr, ast.Call):
            name = _dotted(expr.func)
            if name in _WRAPPER_FUNC_ARGS:
                out: List[_Scope] = []
                for pos in _WRAPPER_FUNC_ARGS[name]:
                    if pos < len(expr.args):
                        out.extend(self._callee_scopes(expr.args[pos],
                                                       scope))
                return out
            if name and "." not in name:
                builder = scope.resolve(name)
                if builder is not None:
                    return list(builder.defs.values()) or [builder]
        return []

    def _rule_missing_donate(self) -> None:
        for call, scope in self._calls_with_scopes():
            if _dotted(call.func) not in _JIT_NAMES or not call.args:
                continue
            kwargs = {kw.arg for kw in call.keywords}
            if kwargs & {"donate_argnums", "donate_argnames"}:
                continue
            callees = self._callee_scopes(call.args[0], scope)
            if any(self._reaches_scan(c) for c in callees):
                sym = (callees[0].qualname if callees
                       else scope.qualname)
                self._emit("missing-donate", call,
                           f"{scope.qualname}->{sym}",
                           f"jax.jit over scan-carrying '{sym}' without "
                           f"donate_argnums — scan state is copied, not "
                           f"aliased, on every dispatch")

    def _rule_pytree_fields(self) -> None:
        trace_names = {s.node.name for s in self._all_scopes()
                       if s.is_trace and isinstance(
                           s.node, (ast.FunctionDef, ast.AsyncFunctionDef))}
        constructed_in_trace: Set[str] = set()
        for scope in self._all_scopes():
            if not scope.is_trace:
                continue
            for node in ast.walk(scope.node):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)):
                    constructed_in_trace.add(node.func.id)
        del trace_names

        for node in ast.walk(self.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            is_frozen_dc = False
            for dec in node.decorator_list:
                name = _dotted(dec if not isinstance(dec, ast.Call)
                               else dec.func)
                if name in ("dataclasses.dataclass", "dataclass"):
                    if isinstance(dec, ast.Call):
                        for kw in dec.keywords:
                            if (kw.arg == "frozen"
                                    and isinstance(kw.value, ast.Constant)
                                    and kw.value.value is True):
                                is_frozen_dc = True
            is_namedtuple = any(_dotted(b) in ("NamedTuple",
                                               "typing.NamedTuple")
                                for b in node.bases)
            if is_frozen_dc:
                for stmt in node.body:
                    if (isinstance(stmt, ast.AnnAssign)
                            and isinstance(stmt.target, ast.Name)):
                        annot = ast.unparse(stmt.annotation)
                        if any(a in annot for a in _ARRAY_ANNOT):
                            self._emit(
                                "pytree-fields", stmt,
                                f"{node.name}.{stmt.target.id}",
                                f"frozen dataclass {node.name} declares "
                                f"array-typed field "
                                f"'{stmt.target.id}: {annot}' — a frozen "
                                f"spec is a compile-cache key and must "
                                f"hold only hashable static fields")
            if is_namedtuple and node.name in constructed_in_trace:
                for stmt in node.body:
                    if (isinstance(stmt, ast.AnnAssign)
                            and isinstance(stmt.target, ast.Name)):
                        annot = ast.unparse(stmt.annotation)
                        if annot in _STATIC_ANNOT:
                            self._emit(
                                "pytree-fields", stmt,
                                f"{node.name}.{stmt.target.id}",
                                f"NamedTuple {node.name} is constructed "
                                f"inside a trace context but field "
                                f"'{stmt.target.id}: {annot}' is "
                                f"annotated static — traced leaves must "
                                f"be arrays")


def lint_source(src: str, path: str = "<string>") -> List[Finding]:
    """Lint one module's source text; returns sorted findings."""
    tree = ast.parse(src, filename=path)
    return _ModuleLinter(tree, src, path).run()


def _canonical_path(p: str) -> str:
    """Repo-relative form of ``p`` so baseline fingerprints are stable
    regardless of the invocation cwd or an absolute ``--root``: anchor
    on the last ``src/`` path component when present (the repo layout),
    else fall back to a plain cwd-relative path."""
    norm = os.path.normpath(p).replace(os.sep, "/")
    head, sep, tail = norm.rpartition("/src/")
    if sep:
        return "src/" + tail
    if norm.startswith("src/"):
        return norm
    return os.path.relpath(p).replace(os.sep, "/")


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    for p in paths:
        with open(p, "r") as f:
            src = f.read()
        findings.extend(lint_source(src, _canonical_path(p)))
    return findings


def lint_tree(root: str) -> List[Finding]:
    """Lint every ``*.py`` under ``root`` (skipping this package)."""
    paths = []
    skip = os.path.join("repro", "analysis")
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                full = os.path.join(dirpath, fn)
                if skip in full:
                    continue    # the linter does host work by design
                paths.append(full)
    return lint_paths(sorted(paths))


def load_baseline(path: str) -> Set[str]:
    """Fingerprints grandfathered by the checked-in baseline file."""
    if not os.path.exists(path):
        return set()
    out: Set[str] = set()
    with open(path, "r") as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                out.add(line.split(" #")[0].strip())
    return out


def partition(findings: Iterable[Finding],
              baseline: Set[str]) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (new, grandfathered-by-baseline)."""
    new, old = [], []
    for f in findings:
        (old if f.fingerprint() in baseline else new).append(f)
    return new, old
