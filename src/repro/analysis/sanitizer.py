"""Runtime dispatch/transfer sanitizer for the windowed engine.

The third analysis pass runs *alongside* real executions. Where
``astlint`` checks source and ``jaxprlint`` checks staged programs,
the sanitizer checks what actually happened: how many device dispatches
the engine issued, how often the host blocked on device results,
whether any device array was implicitly materialized on the host, and
whether a warm path re-traced a program it should have reused.

The declarative contract (ISSUE 7 / ROADMAP "kill the remaining host
round-trips"):

    a windowed run of C chunks at fusion K issues
        <= ceil(C / K) + 2 dispatches,
    with 0 implicit device->host transfers and
         0 recompilations on a warm (replay resume) path.

Usage::

    from repro.analysis import dispatch_contract, sanitized

    with sanitized(dispatch_contract(spec)) as report:
        run_simulation(spec)
    # raises SanitizerError on violation; `report` holds the deltas

Implicit-transfer detection: ``jax.transfer_guard`` is installed for
backends where it bites, but the CPU client shares buffers with the
host, so device->host guards never fire there. The sanitizer therefore
also interposes on ``np.asarray`` / ``np.array`` (the only routes
through which a ``jax.Array`` silently becomes host memory in this
codebase) and on ``jax.device_get`` (the *sanctioned* route, which
marks its dynamic extent as explicit). A conversion of a committed
``jax.Array`` outside an explicit fetch is recorded as an implicit
transfer. Interposition is refcounted and thread-aware, so nested
sanitizers (e.g. a test's ``sanitized`` around the engine's own
``debug_checks`` guard) each see every event.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Iterator, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["DispatchContract", "SanitizerError", "SanitizerReport",
           "dispatch_bound", "dispatch_contract", "sanitized",
           "engine_guard"]


class SanitizerError(RuntimeError):
    """A sanitized execution violated its dispatch/transfer contract."""


def dispatch_bound(steps: int, chunk_steps: int, k: int) -> int:
    """The contract ceiling ``ceil(C/K) + 2`` for a windowed run.

    C = ceil(steps / chunk_steps) chunks; fusion K collapses full-rate
    interior chunks ~K per dispatch; the +2 covers the unfused final
    chunk and one span truncated at the stream tail. Dense runs
    (``chunk_steps <= 0``) are a single dispatch, same slack.
    """
    if chunk_steps is None or chunk_steps <= 0:
        return 3
    n_chunks = -(-max(steps, 1) // chunk_steps)
    return -(-n_chunks // max(k or 1, 1)) + 2


@dataclasses.dataclass(frozen=True)
class DispatchContract:
    """Ceilings a sanitized execution must respect.

    ``None`` disables the corresponding check. ``sync_slack`` bounds
    host syncs relative to *observed* dispatches (each dispatch may
    drain once; +slack for the final flush and checkpoint reads).
    """

    max_dispatches: Optional[int] = None
    max_recompiles: Optional[int] = None     # 0 == warm-path contract
    max_transfers: Optional[int] = 0
    sync_slack: Optional[int] = 2
    label: str = ""


def dispatch_contract(spec: Any, *, warm: bool = False,
                      label: str = "") -> DispatchContract:
    """Contract for one engine run of ``spec`` (SimSpec or SimConfig —
    anything with ``steps`` / ``chunk_steps`` / ``superchunk``)."""
    bound = dispatch_bound(int(getattr(spec, "steps", 0) or 0),
                           int(getattr(spec, "chunk_steps", 0) or 0),
                           int(getattr(spec, "superchunk", 1) or 1))
    return DispatchContract(
        max_dispatches=bound,
        max_recompiles=0 if warm else None,
        max_transfers=0, sync_slack=2,
        label=label or f"dispatch<=ceil(C/K)+2={bound}")


@dataclasses.dataclass
class SanitizerReport:
    """Deltas observed inside one ``sanitized`` region."""

    contract: Optional[DispatchContract] = None
    dispatches: int = 0
    host_syncs: int = 0
    recompiles: int = 0
    transfers: Tuple[str, ...] = ()
    closed: bool = False

    def violations(self) -> List[str]:
        c = self.contract
        out = []
        if c is None:
            return out
        if (c.max_dispatches is not None
                and self.dispatches > c.max_dispatches):
            out.append(f"{self.dispatches} dispatches > contract "
                       f"{c.max_dispatches} ({c.label})")
        if (c.max_recompiles is not None
                and self.recompiles > c.max_recompiles):
            out.append(f"{self.recompiles} recompilations > contract "
                       f"{c.max_recompiles} (warm path must reuse "
                       f"compiled chunk programs)")
        if (c.max_transfers is not None
                and len(self.transfers) > c.max_transfers):
            out.append(f"{len(self.transfers)} implicit device->host "
                       f"transfers (want <= {c.max_transfers}): "
                       + "; ".join(self.transfers[:4]))
        if (c.sync_slack is not None
                and self.host_syncs > self.dispatches + c.sync_slack):
            out.append(f"{self.host_syncs} host syncs > dispatches "
                       f"({self.dispatches}) + {c.sync_slack}")
        return out

    @property
    def ok(self) -> bool:
        return not self.violations()

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["transfers"] = list(self.transfers)
        d["violations"] = self.violations()
        d["ok"] = self.ok
        return d


# ---------------------------------------------------------------------------
# implicit-transfer interposition (refcounted, multi-collector)

_LOCK = threading.Lock()
_INSTALLS = 0
_COLLECTORS: List[List[str]] = []
_ORIG_ASARRAY = None
_ORIG_ARRAY = None
_ORIG_DEVICE_GET = None
_TLS = threading.local()


def _explicit_depth() -> int:
    return getattr(_TLS, "depth", 0)


def _is_committed_device_array(x: Any) -> bool:
    # Tracers are jax.Array too; converting one is a *trace* error the
    # AST linter owns, not a runtime transfer.
    return isinstance(x, jax.Array) and not isinstance(x, jax.core.Tracer)


def _record(kind: str, x: Any) -> None:
    if _explicit_depth() > 0:
        return
    desc = (f"{kind} on jax.Array shape={getattr(x, 'shape', '?')} "
            f"dtype={getattr(x, 'dtype', '?')} (use jax.device_get)")
    with _LOCK:
        for sink in _COLLECTORS:
            sink.append(desc)


def _install() -> List[str]:
    """Register a collector; patch numpy/jax entry points on first use."""
    global _INSTALLS, _ORIG_ASARRAY, _ORIG_ARRAY, _ORIG_DEVICE_GET
    sink: List[str] = []
    with _LOCK:
        _COLLECTORS.append(sink)
        _INSTALLS += 1
        if _INSTALLS > 1:
            return sink
        _ORIG_ASARRAY = np.asarray
        _ORIG_ARRAY = np.array
        _ORIG_DEVICE_GET = jax.device_get

    def asarray(a, *args, **kwargs):
        if _is_committed_device_array(a):
            _record("np.asarray", a)
        return _ORIG_ASARRAY(a, *args, **kwargs)

    def array(a, *args, **kwargs):
        if _is_committed_device_array(a):
            _record("np.array", a)
        return _ORIG_ARRAY(a, *args, **kwargs)

    def device_get(tree):
        _TLS.depth = _explicit_depth() + 1
        try:
            return _ORIG_DEVICE_GET(tree)
        finally:
            _TLS.depth -= 1

    np.asarray = asarray
    np.array = array
    jax.device_get = device_get
    return sink


def _uninstall(sink: List[str]) -> None:
    global _INSTALLS
    with _LOCK:
        _COLLECTORS.remove(sink)
        _INSTALLS -= 1
        if _INSTALLS == 0:
            np.asarray = _ORIG_ASARRAY
            np.array = _ORIG_ARRAY
            jax.device_get = _ORIG_DEVICE_GET


def _counters():
    # lazy: the simulator imports numpy/jax heavily; importing it here
    # (not at module import) keeps `repro.analysis` cheap to load and
    # avoids a circular import from the engine's own debug_checks guard.
    from ..core import simulator as sim
    return (sim.chunk_dispatch_count(), sim.host_sync_count(),
            sim.chunk_trace_count())


@contextlib.contextmanager
def sanitized(contract: Optional[DispatchContract] = None, *,
              check: bool = True) -> Iterator[SanitizerReport]:
    """Run the body under the dispatch/transfer sanitizer.

    Yields a :class:`SanitizerReport` whose fields are filled in when
    the block exits; with ``check`` (default) a violated contract
    raises :class:`SanitizerError`. ``transfer_guard`` is engaged for
    backends that enforce it; the numpy interposition covers the CPU
    client, where XLA buffers are host-shared and the guard is inert.
    """
    report = SanitizerReport(contract=contract)
    d0, s0, t0 = _counters()
    sink = _install()
    try:
        with jax.transfer_guard_device_to_host(
                "disallow" if jax.default_backend() != "cpu"
                else "allow"):
            yield report
    finally:
        _uninstall(sink)
        d1, s1, t1 = _counters()
        report.dispatches = d1 - d0
        report.host_syncs = s1 - s0
        report.recompiles = t1 - t0
        report.transfers = tuple(sink)
        report.closed = True
    if check:
        problems = report.violations()
        if problems:
            raise SanitizerError(
                "sanitizer contract violated:\n  - "
                + "\n  - ".join(problems))


@contextlib.contextmanager
def engine_guard() -> Iterator[None]:
    """The engine's own ``debug_checks`` hook: transfer checking only.

    Wrapped around ``_run_windowed_batch`` when
    ``SimConfig.debug_checks`` is set — any implicit device->host
    materialization inside the drain/checkpoint path raises
    immediately, with no dispatch ceiling (callers compose their own
    :func:`sanitized` for that).
    """
    sink = _install()
    try:
        yield
    finally:
        _uninstall(sink)
    if sink:
        raise SanitizerError(
            "implicit device->host transfer inside the windowed "
            "engine:\n  - " + "\n  - ".join(sink[:8]))
