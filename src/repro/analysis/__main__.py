"""CLI driver: run all three analysis passes, emit ANALYSIS.json.

Usage::

    python -m repro.analysis                  # report, exit 0
    python -m repro.analysis --check          # CI gate: exit 1 on any
                                              # unbaselined violation
    python -m repro.analysis --json OUT.json  # machine-readable report
    python -m repro.analysis --skip-engine    # astlint only (fast)

Passes:

1. **astlint** — AST trace-discipline rules over ``src/repro``; new
   findings (not in ``ANALYSIS_BASELINE.txt``, not suppressed inline)
   fail the gate. Stale baseline entries are reported so the file
   shrinks as debt is paid.
2. **jaxprlint** — stages the engine's dense / chunk / superchunk
   programs at a tiny shape and audits the jaxprs + lowered modules
   for host callbacks, dtype widenings and donation.
3. **sanitizer smoke** — one real windowed run (M=512, C=42 chunks,
   K=8) under the dispatch contract ``<= ceil(C/K)+2`` with zero
   implicit transfers, plus a warm rerun asserting zero recompiles.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys


def _astlint_section(root: str, baseline_path: str) -> dict:
    from .astlint import lint_tree, load_baseline, partition
    findings = lint_tree(root)
    baseline = load_baseline(baseline_path)
    new, old = partition(findings, baseline)
    live = {f.fingerprint() for f in findings}
    stale = sorted(baseline - live)
    return {
        "root": root,
        "baseline": baseline_path,
        "n_findings": len(findings),
        "new": [dataclasses.asdict(f) for f in new],
        "grandfathered": [f.fingerprint() for f in old],
        "stale_baseline": stale,
        "ok": not new,
        "rendered": [f.render() for f in new],
    }


def _jaxpr_section() -> dict:
    from .jaxprlint import audit_engine
    return audit_engine()


def _sanitizer_section() -> dict:
    import dataclasses as dc

    from ..core import RSMConfig, SimConfig
    from ..core.simulator import build_spec, run_simulation
    from .sanitizer import SanitizerError, dispatch_contract, sanitized

    rsm = RSMConfig.bft(1)
    sim = SimConfig(n_msgs=512, steps=168, window=1, phi=6,
                    window_slots=96, chunk_steps=4, superchunk=8,
                    debug_checks=True)
    spec = build_spec(rsm, rsm, sim)
    out = {"shape": dict(m=spec.m, steps=spec.steps,
                         window_slots=spec.window_slots,
                         chunk_steps=spec.chunk_steps,
                         superchunk=spec.superchunk)}
    try:
        with sanitized(dispatch_contract(spec, label="cold")) as cold:
            run_simulation(spec)
        # second run: every program is compiled — the warm contract
        # additionally demands zero re-traces (the replay-resume
        # guarantee, measured on the same counters resume uses)
        with sanitized(dispatch_contract(spec, warm=True,
                                         label="warm")) as warm:
            run_simulation(dc.replace(spec))
        out["cold"] = cold.to_dict()
        out["warm"] = warm.to_dict()
        out["ok"] = True
    except SanitizerError as e:
        out["error"] = str(e)
        out["ok"] = False
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="trace-discipline linter, jaxpr auditor and "
                    "runtime dispatch sanitizer")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any unbaselined violation (CI gate)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the full machine-readable report here")
    ap.add_argument("--root", default="src/repro",
                    help="tree to lint (default: src/repro)")
    ap.add_argument("--baseline", default="ANALYSIS_BASELINE.txt",
                    help="grandfathered-findings file")
    ap.add_argument("--skip-engine", action="store_true",
                    help="run only the AST pass (no JAX tracing)")
    args = ap.parse_args(argv)

    report = {"astlint": _astlint_section(args.root, args.baseline)}
    if not args.skip_engine:
        report["jaxpr"] = _jaxpr_section()
        report["sanitizer"] = _sanitizer_section()
    report["ok"] = all(sec.get("ok", True) for sec in report.values()
                       if isinstance(sec, dict))

    ast_sec = report["astlint"]
    print(f"astlint: {ast_sec['n_findings']} finding(s), "
          f"{len(ast_sec['new'])} new, "
          f"{len(ast_sec['grandfathered'])} baselined")
    for text in ast_sec["rendered"]:
        print(text)
    for fp in ast_sec["stale_baseline"]:
        print(f"  stale baseline entry (remove it): {fp}")
    if "jaxpr" in report:
        jx = report["jaxpr"]
        names = ", ".join(p["name"] for p in jx["programs"])
        print(f"jaxprlint: {len(jx['programs'])} program(s) [{names}] "
              + ("clean" if jx["ok"] else "VIOLATIONS"))
        for v in jx["violations"]:
            print(f"  {v}")
    if "sanitizer" in report:
        sz = report["sanitizer"]
        if sz["ok"]:
            print(f"sanitizer: cold {sz['cold']['dispatches']} dispatches "
                  f"(contract {sz['cold']['contract']['max_dispatches']}), "
                  f"warm {sz['warm']['recompiles']} recompiles, "
                  f"{len(sz['cold']['transfers'])} implicit transfers")
        else:
            print(f"sanitizer: FAILED\n{sz['error']}")

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, default=str)
        print(f"wrote {args.json}")

    if args.check and not report["ok"]:
        print("analysis: FAILED", file=sys.stderr)
        return 1
    print("analysis: ok" if report["ok"]
          else "analysis: violations found (informational mode; "
               "use --check to fail)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
