"""Pure-numpy multi-link oracle mirroring the vmapped topology engine.

One :class:`~repro.core.refsim._RefMachine` per link, driven with exactly
the engine's chunk structure: the same commit floors computed from the
same retired-prefix plumbing at the same chunk starts, the same
per-scenario overflow decisions (batch-wide window growth, dense-layout
migration mirrored as widening to W = M), and the same GC-frontier
advances at chunk boundaries. Every per-message output, every frontier
trajectory and every commit-floor trajectory must agree bit-for-bit with
``run_topology`` — that is the ground truth ``tests/test_topology.py``
and the application fixtures check against.

The machines also snapshot every retired slot and assert at the end that
no retired output ever changed, which is what makes routing the retired
prefix into a downstream link's commit stream sound: a downstream
cluster never commits an entry its upstream hop could still lose.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from ..core.refsim import RefResult, _RefMachine
from ..core.simulator import (SimSpec, _max_msg_by_round,
                              _widen_on_overflow, spec_failures)
from .engine import (LinkAccessors, TopologyAccessors, _floor_plan,
                     link_specs, plan_floors)
from .graph import LinkSpec, Topology

__all__ = ["RefLinkResult", "RefTopologyResult", "run_topology_reference"]


@dataclasses.dataclass
class RefLinkResult(LinkAccessors):
    """Oracle twin of :class:`repro.topology.engine.LinkResult`."""

    link: LinkSpec
    result: RefResult
    commit_floors: np.ndarray      # (n_chunks,) floor per chunk start


@dataclasses.dataclass
class RefTopologyResult(TopologyAccessors):
    topology: Topology
    links: Dict[str, RefLinkResult]


def run_topology_reference(topo: Topology,
                           fail_schedule=None) -> RefTopologyResult:
    """Oracle topology run; ``fail_schedule(t)`` may return one entry
    per link at a chunk start to swap the failure state in force from
    round ``t`` on (the numpy twin of the engine's mid-stream
    ``FailArrays`` swap — replay-with-injection ground truth). Each
    entry is a ``FailureScenario`` (mask swap) or a full ``SimSpec``
    (mask swap plus stake/threshold reconfiguration)."""
    specs = link_specs(topo)
    spec0 = specs[0]
    n_l, m = len(specs), spec0.m
    machines = [_RefMachine(s) for s in specs]
    up = _floor_plan(topo)
    w = spec0.window_slots
    c_full = max(spec0.chunk_steps, 1)
    dispatched_by = _max_msg_by_round(spec0)

    bases = np.zeros(n_l, dtype=np.int64)
    bases_hist = [bases.copy()]
    floors_hist: List[np.ndarray] = []
    t = 0
    while t < spec0.steps:
        c = min(c_full, spec0.steps - t)
        if fail_schedule is not None:
            new_fails = fail_schedule(t)
            if new_fails is not None:
                for mac, f in zip(machines, new_fails):
                    if isinstance(f, SimSpec):
                        mac.set_quorum(f)
                        mac.set_failures(spec_failures(f))
                    else:
                        mac.set_failures(f)
        # commit floors for this chunk: a chained link may originate only
        # what its upstream link has retired (durably delivered) so far.
        floors = plan_floors(up, n_l, m, bases)
        floors_hist.append(floors.copy())
        # per-link overflow check + batch-wide growth, exactly like the
        # engine: the whole batch shares one window width.
        need_b = np.minimum(int(dispatched_by[t + c - 1]), floors - 1)
        over = need_b - bases
        b = int(over.argmax())
        if over[b] >= w:
            new_w = _widen_on_overflow(spec0, w, int(bases[b]),
                                       int(need_b[b]), t + c - 1)
            w = m if new_w is None else new_w
        last = t + c >= spec0.steps
        for i, mac in enumerate(machines):
            for tt in range(t, t + c):
                mac.step(tt, commit_floor=int(floors[i]))
        t += c
        if not last:
            for i, mac in enumerate(machines):
                f = mac.frontier(int(bases[i]), w, t)
                mac.retire(int(bases[i]), f)
                bases[i] += f
            bases_hist.append(bases.copy())

    for mac in machines:
        mac.assert_retirement_safe()

    traj = np.stack(bases_hist)                   # (n_boundaries, L)
    fhist = np.stack(floors_hist)                 # (n_chunks, L)
    links = {}
    for i, (l, mac) in enumerate(zip(topo.links, machines)):
        res = mac.result(traj[:, i].astype(np.int64), True)
        links[l.name] = RefLinkResult(link=l, result=res,
                                      commit_floors=fhist[:, i])
    return RefTopologyResult(topology=topo, links=links)
