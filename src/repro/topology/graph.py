"""Link-graph model for multi-link C3B sessions.

A :class:`Topology` is a set of named RSM clusters plus directed C3B
links between them. Every link carries its own failure scenario, but all
links share one :class:`~repro.core.SimConfig` stream shape and every
link's (source config, destination config) pair must resolve to the same
schedules/thresholds — that uniformity is what lets the engine execute
*all* links through one ``jax.vmap``-ed windowed chunk kernel (one
compilation, one device dispatch per chunk, O(L·W) state) instead of a
Python loop over per-link compiled calls.

A link may name an ``upstream`` link: its commit stream is then gated by
the upstream link's retired prefix (chained RSMs — cluster B only
forwards to C what it has durably received from A). The engine routes the
upstream's retired/delivered prefix into the downstream link's
``commit_floor`` between chunks; the gate is a traced input, so the
plumbing costs no recompilation.

Constructors cover the paper's application shapes: ``pair`` (a
bidirectional link pair, data reconciliation §6), ``fanout`` (a primary
streaming its committed log to N backups, disaster recovery §6) and
``chain`` (relay pipelines, each hop gated by the previous one).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

from ..core.types import FailureScenario, RSMConfig, SimConfig

__all__ = ["LinkSpec", "Topology"]


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One directed C3B link: ``src`` cluster streams to ``dst`` cluster.

    upstream: optional name of the link whose retired prefix gates this
              link's commit stream (chained delivery). ``None`` means the
              full stream is committed at the source from round 0.
    """

    name: str
    src: str
    dst: str
    failures: FailureScenario = FailureScenario.none()
    upstream: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class Topology:
    """A graph of RSM clusters and directed C3B links (uniform shape)."""

    clusters: Mapping[str, RSMConfig]
    links: Tuple[LinkSpec, ...]
    sim: SimConfig = SimConfig()

    def __post_init__(self):
        if not self.links:
            raise ValueError("topology has no links")
        names = [l.name for l in self.links]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate link names: {names}")
        by_name = {l.name: l for l in self.links}
        for l in self.links:
            for c in (l.src, l.dst):
                if c not in self.clusters:
                    raise ValueError(f"link {l.name!r} references unknown "
                                     f"cluster {c!r}")
            if l.src == l.dst:
                raise ValueError(f"link {l.name!r} is a self-loop")
            if l.upstream is not None and l.upstream not in by_name:
                raise ValueError(f"link {l.name!r} chains unknown upstream "
                                 f"{l.upstream!r}")
        # chained delivery must be acyclic (a cycle would deadlock every
        # floor at 0 forever)
        for l in self.links:
            seen = {l.name}
            cur = l.upstream
            while cur is not None:
                if cur in seen:
                    raise ValueError(f"chained-delivery cycle through "
                                     f"{l.name!r}")
                seen.add(cur)
                cur = by_name[cur].upstream
        # one vmapped dispatch needs one shape: every link's (src, dst)
        # config pair must match the first link's.
        l0 = self.links[0]
        pair0 = (self.clusters[l0.src], self.clusters[l0.dst])
        for l in self.links[1:]:
            pair = (self.clusters[l.src], self.clusters[l.dst])
            if pair != pair0:
                raise ValueError(
                    f"link {l.name!r} has cluster configs {pair} != "
                    f"{pair0} of link {l0.name!r}; all links of one "
                    f"topology must share (src config, dst config) so the "
                    f"whole graph runs as one vmapped windowed dispatch")

    @property
    def link_names(self) -> Tuple[str, ...]:
        return tuple(l.name for l in self.links)

    def link(self, name: str) -> LinkSpec:
        for l in self.links:
            if l.name == name:
                return l
        raise KeyError(name)

    # --- constructors for the paper's application shapes -----------------

    @classmethod
    def pair(cls, a: str, b: str, cfg: RSMConfig,
             sim: SimConfig = SimConfig(),
             failures_ab: FailureScenario = FailureScenario.none(),
             failures_ba: FailureScenario = FailureScenario.none(),
             ) -> "Topology":
        """Bidirectional link pair ``a<->b`` (data reconciliation)."""
        return cls(clusters={a: cfg, b: cfg},
                   links=(LinkSpec(f"{a}->{b}", a, b, failures_ab),
                          LinkSpec(f"{b}->{a}", b, a, failures_ba)),
                   sim=sim)

    @classmethod
    def fanout(cls, primary: str, backups: Sequence[str], cfg: RSMConfig,
               sim: SimConfig = SimConfig(),
               failures: Optional[Dict[str, FailureScenario]] = None,
               backup_cfg: Optional[RSMConfig] = None) -> "Topology":
        """Primary streaming its committed log to N backups (disaster
        recovery). ``failures`` maps backup name -> that link's scenario
        (e.g. the primary's crash round plus per-backup receiver faults).
        """
        if not backups:
            raise ValueError("fanout needs at least one backup")
        failures = failures or {}
        bcfg = backup_cfg if backup_cfg is not None else cfg
        clusters = {primary: cfg}
        clusters.update({b: bcfg for b in backups})
        links = tuple(
            LinkSpec(f"{primary}->{b}", primary, b,
                     failures.get(b, FailureScenario.none()))
            for b in backups)
        return cls(clusters=clusters, links=links, sim=sim)

    @classmethod
    def chain(cls, hops: Sequence[str], cfg: RSMConfig,
              sim: SimConfig = SimConfig(),
              failures: Optional[Dict[str, FailureScenario]] = None,
              ) -> "Topology":
        """Relay pipeline ``hops[0] -> hops[1] -> ...``: each hop's commit
        stream is gated by the previous link's retired prefix (chained
        delivery), so downstream clusters only ever forward entries the
        upstream hop has durably received — the prefix-consistency
        invariant ``tests/test_topology.py`` checks against the oracle.
        ``failures`` maps link name (``"a->b"``) -> scenario."""
        if len(hops) < 2:
            raise ValueError("chain needs at least two clusters")
        failures = failures or {}
        links = []
        prev = None
        for src, dst in zip(hops[:-1], hops[1:]):
            name = f"{src}->{dst}"
            links.append(LinkSpec(
                name, src, dst,
                failures.get(name, FailureScenario.none()), upstream=prev))
            prev = name
        return cls(clusters={h: cfg for h in hops}, links=tuple(links),
                   sim=sim)
