"""Multi-link C3B topologies: RSM cluster graphs on the batched kernel.

    from repro.topology import Topology, run_topology

    topo = Topology.fanout("primary", ["b0", "b1"], RSMConfig.bft(1),
                           SimConfig(n_msgs=256, steps=120,
                                     window_slots="auto"))
    res = run_topology(topo)
    res["primary->b0"].delivered_prefix()

Every link of the graph runs as one lane of a single ``jax.vmap``-ed
windowed chunk stream (one compilation, one dispatch per chunk, O(L·W)
device state); chained links gate their commit stream on the upstream
link's retired prefix between chunks. ``run_topology_reference`` is the
pure-numpy oracle mirror used by the test suite.
"""

from .engine import LinkResult, TopologyResult, link_specs, run_topology
from .graph import LinkSpec, Topology
from .refmirror import (RefLinkResult, RefTopologyResult,
                        run_topology_reference)

__all__ = [
    "LinkSpec", "Topology",
    "LinkResult", "TopologyResult", "link_specs", "run_topology",
    "RefLinkResult", "RefTopologyResult", "run_topology_reference",
]
