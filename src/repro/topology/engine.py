"""Multi-link C3B session engine on the batched windowed kernel.

``run_topology`` resolves every link of a :class:`Topology` into a
``SimSpec`` (identical modulo failure masks — enforced) and executes all
of them through the *existing* vmapped windowed chunk kernel
(``simulator._run_windowed_batch``): one compilation, one device
dispatch per chunk across links, per-link window bases/frontiers and
O(L·W) device state. There is no per-link Python loop over compiled
calls anywhere — a link is just one lane of the batch.

Chained delivery rides the commit-floor plumbing: between chunks the
engine sets each chained link's ``commit_floor`` to its upstream link's
retired prefix (the window base the in-graph GC rotation has advanced
past). A retired slot is QUACKed at every sender — provably held by at
least one honest receiver — so the floor is a *durable delivered* prefix:
downstream clusters only ever originate entries the upstream hop cannot
lose, which is exactly the prefix-consistency contract the oracle mirror
(``refmirror``) and ``tests/test_topology.py`` verify bit-for-bit.

Topology execution is always chunked (the floors must be able to move
between chunks), so a stream small enough for ``window_slots="auto"`` to
clamp to the dense kernel instead runs the windowed kernel at full width
W = M — same observable results, chunk boundaries retained.

Because the floors are recomputed from every boundary's actual retired
prefixes, a commit-floor callback is a *mandatory host interaction* for
the pipelined superchunk engine: chained runs execute chunk-at-a-time
(fusion breaks at every boundary) and are bit-identical for every
``SimConfig.superchunk`` setting (``tests/test_pipeline.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from ..core.simulator import (SimResult, SimSpec, _run_windowed_batch,
                              build_spec, require_uniform_batch)
from ..obs.tracer import obs_span
from .graph import LinkSpec, Topology

__all__ = ["LinkAccessors", "TopologyAccessors", "LinkResult",
           "TopologyResult", "link_specs", "plan_floors", "FloorPlanner",
           "run_topology"]


def link_specs(topo: Topology) -> List[SimSpec]:
    """Per-link SimSpecs, forced onto the chunked windowed kernel."""
    specs = [build_spec(topo.clusters[l.src], topo.clusters[l.dst],
                        topo.sim, l.failures)
             for l in topo.links]
    if specs[0].window_slots == 0:
        # commit-floor plumbing needs chunk boundaries: when the auto
        # sizing clamps to dense (W >= M), run the windowed kernel at full
        # width instead — bit-identical results, boundaries retained.
        specs = [dataclasses.replace(s, window_slots=s.m,
                                     chunk_steps=topo.sim.chunk_steps)
                 for s in specs]
    require_uniform_batch(specs)
    return specs


class LinkAccessors:
    """Shared derived views over one link's outputs (engine AND oracle —
    both result flavours expose ``result.deliver_time`` /
    ``result.gc_frontiers``, so the prefix semantics cannot drift between
    the vmapped run and its numpy mirror)."""

    def delivered_mask(self) -> np.ndarray:
        """(M,) bool — messages that reached >=1 honest dst replica."""
        return np.asarray(self.result.deliver_time) >= 0

    def delivered_prefix(self) -> int:
        """Length of the contiguous delivered prefix (the applied log)."""
        mask = self.delivered_mask()
        return int(np.argmin(mask)) if not mask.all() else len(mask)

    def retired_prefix(self) -> int:
        """Final GC frontier — the durable prefix both sides may forget."""
        return int(self.result.gc_frontiers[-1])


class TopologyAccessors:
    """Shared by-name addressing over a run's links (engine AND oracle)."""

    def __getitem__(self, name: str):
        return self.links[name]

    def delivered_prefixes(self) -> Dict[str, int]:
        return {n: lr.delivered_prefix() for n, lr in self.links.items()}


@dataclasses.dataclass
class LinkResult(LinkAccessors):
    """One link's simulation outputs + the commit floors it ran under."""

    link: LinkSpec
    result: SimResult
    commit_floors: np.ndarray      # (n_chunks,) floor per chunk start


@dataclasses.dataclass
class TopologyResult(TopologyAccessors):
    """All links' results, addressable by link name."""

    topology: Topology
    links: Dict[str, LinkResult]


def _floor_plan(topo: Topology) -> Dict[int, int]:
    """link index -> upstream link index, for chained links only."""
    idx = {l.name: i for i, l in enumerate(topo.links)}
    return {i: idx[l.upstream] for i, l in enumerate(topo.links)
            if l.upstream is not None}


def plan_floors(plan: Dict[int, int], n_lanes: int, m: int,
                bases) -> np.ndarray:
    """Commit floors for one chunk from the lanes' retired prefixes.

    ``plan`` maps lane -> upstream lane; unchained lanes are fully
    committed (floor = m). Shared by the engine, the numpy mirror and the
    replay/what-if drivers (which tile the plan across fork blocks), so
    the chained-delivery rule has exactly one implementation.
    """
    floors = np.full(n_lanes, m, dtype=np.int64)
    for i, j in plan.items():
        floors[i] = np.int64(bases[j])
    return floors


class FloorPlanner:
    """Reusable commit-floor callback over a lane -> upstream plan.

    One instance is one session's floor stream: the engine calls it at
    every chunk boundary with the lanes' retired prefixes and it applies
    the shared :func:`plan_floors` rule. ``keep_history=True`` (batch
    topology runs) records every boundary's floors so
    ``LinkResult.commit_floors`` can be reconstructed; streaming
    sessions pass ``False`` — only the latest floors are retained and
    host memory stays O(1) in stream length.
    """

    def __init__(self, plan: Dict[int, int], n_lanes: int, m: int,
                 keep_history: bool = True):
        self.plan = dict(plan)
        self.n_lanes = int(n_lanes)
        self.m = int(m)
        self.keep_history = keep_history
        self.history: List[np.ndarray] = []
        self.last: np.ndarray = np.full(n_lanes, m, dtype=np.int64)
        self.calls = 0

    @classmethod
    def chain(cls, n_lanes: int, m: int,
              keep_history: bool = True) -> "FloorPlanner":
        """Lane i is chained behind lane i-1 (lane 0 unchained)."""
        return cls({i: i - 1 for i in range(1, n_lanes)}, n_lanes, m,
                   keep_history=keep_history)

    def seed_history(self, bases_rows) -> None:
        """Reconstruct pre-resume floors from a checkpoint's base
        trajectory (same rule — bit-identical to the original run)."""
        self.history = [plan_floors(self.plan, self.n_lanes, self.m, row)
                        for row in bases_rows]

    def __call__(self, t: int, bases: np.ndarray) -> np.ndarray:
        floors = plan_floors(self.plan, self.n_lanes, self.m, bases)
        self.calls += 1
        self.last = floors.copy()
        if self.keep_history:
            self.history.append(self.last)
        return floors

    def stacked(self) -> np.ndarray:
        return np.stack(self.history)


def run_topology(topo: Topology, *, recorder=None, resume=None,
                 fail_schedule=None) -> TopologyResult:
    """Execute every link of the graph in one vmapped windowed session.

    ``recorder`` / ``resume`` / ``fail_schedule`` pass straight through
    to the batched windowed kernel loop — chunk-boundary checkpoint
    capture, deterministic resume, and mid-stream failure-schedule swaps
    for the replay subsystem (``repro.replay``). On resume the
    commit-floor history of the already-executed chunks is reconstructed
    from the checkpoint's base trajectory via the same ``plan_floors``
    rule, so a replayed ``LinkResult.commit_floors`` is bit-identical to
    the original run's.
    """
    specs = link_specs(topo)
    m = specs[0].m
    planner = FloorPlanner(_floor_plan(topo), len(specs), m)
    if resume is not None:
        planner.seed_history(np.asarray(resume.bases_hist)[:-1])

    # the engine wraps each commit_floors call in a "plan_floors" span;
    # this outer span makes whole-graph sessions addressable in the
    # exported timeline (repro.obs.tracer)
    with obs_span("run_topology", cat="engine",
                  links=[l.name for l in topo.links]):
        results = _run_windowed_batch(specs, commit_floors=planner,
                                      recorder=recorder, resume=resume,
                                      fail_schedule=fail_schedule)
    hist = planner.stacked()                      # (n_chunks, L)
    links = {
        l.name: LinkResult(link=l, result=r, commit_floors=hist[:, i])
        for i, (l, r) in enumerate(zip(topo.links, results))}
    return TopologyResult(topology=topo, links=links)
