"""Checkpoint substrate."""

from .checkpoint import (CheckpointManager, latest_step, restore_tree,
                         save_tree)

__all__ = ["CheckpointManager", "save_tree", "restore_tree", "latest_step"]
