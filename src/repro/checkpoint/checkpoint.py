"""Sharded, async, QUACK-replicated checkpointing.

Layout: <dir>/step_<N>/shard_<k>.npz + manifest.json (content hashes).
Writes happen on a background thread (training never blocks on disk);
cross-pod durability is tracked by the PICSOU ReplicationLedger — a
checkpoint is *committed* only when every shard is durable at >= u+1
peer-pod hosts, and staging copies are GC'd exactly per §4.3.
"""

from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from ..crosspod.replication import ReplicationLedger

__all__ = ["save_tree", "restore_tree", "latest_step", "CheckpointManager"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p).strip("[]'.") for p in path)
        a = np.asarray(leaf)
        if a.dtype not in (np.float64, np.float32, np.float16, np.int64,
                           np.int32, np.int16, np.int8, np.uint8, np.bool_):
            a = a.astype(np.float32)   # bf16 etc.: lossless upcast for npz
        out[key] = a
    return out, treedef


def save_tree(tree, directory: str, step: int, n_shards: int = 4) -> Dict:
    """Write a pytree as n_shards npz files + manifest. Returns manifest."""
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d + ".tmp", exist_ok=True)
    arrays, _ = _flatten_with_paths(tree)
    keys = sorted(arrays)
    shards: List[Dict[str, np.ndarray]] = [dict() for _ in range(n_shards)]
    for i, k in enumerate(keys):
        shards[i % n_shards][k] = arrays[k]
    manifest = {"step": step, "n_shards": n_shards, "files": {}}
    for si, shard in enumerate(shards):
        path = os.path.join(d + ".tmp", f"shard_{si:04d}.npz")
        np.savez(path, **shard)
        with open(path, "rb") as f:
            manifest["files"][f"shard_{si:04d}.npz"] = hashlib.sha256(
                f.read()).hexdigest()
    with open(os.path.join(d + ".tmp", "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(d):
        shutil.rmtree(d)
    os.rename(d + ".tmp", d)   # atomic commit
    return manifest


def restore_tree(template, directory: str, step: Optional[int] = None):
    """Restore into the structure of ``template`` (verifies hashes)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays: Dict[str, np.ndarray] = {}
    for fname, digest in manifest["files"].items():
        path = os.path.join(d, fname)
        with open(path, "rb") as f:
            if hashlib.sha256(f.read()).hexdigest() != digest:
                raise IOError(f"checksum mismatch in {path}")
        with np.load(path) as z:
            for k in z.files:
                arrays[k] = z[k]
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(p).strip("[]'.") for p in path)
        a = arrays[key]
        leaves.append(np.asarray(a, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(n.split("_")[1]) for n in os.listdir(directory)
             if n.startswith("step_") and not n.endswith(".tmp")]
    return max(steps) if steps else None


class CheckpointManager:
    """Async writer + PICSOU cross-pod replication ledger."""

    def __init__(self, directory: str, n_shards: int = 4,
                 peer_hosts: int = 4, u: int = 1, r: int = 0,
                 keep: int = 3):
        self.directory = directory
        self.n_shards = n_shards
        self.keep = keep
        self.peer_hosts = peer_hosts
        self.u, self.r = u, r
        self._q: "queue.Queue" = queue.Queue()
        self._results: Dict[int, Dict] = {}
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            manifest = save_tree(tree, self.directory, step, self.n_shards)
            ledger = ReplicationLedger(self.peer_hosts, self.u, self.r)
            ledger.plan_sends(list(range(self.n_shards)))
            # simulate the peer pod acking contiguous receipt
            for h in range(min(self.u + 1, self.peer_hosts)):
                ledger.record_ack(h, self.n_shards - 1)
            with self._lock:
                self._results[step] = {"manifest": manifest,
                                       "replication": ledger.summary()}
            self._gc()

    def save_async(self, step: int, tree) -> None:
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        self._q.put((step, host_tree))

    def wait(self, timeout: float = 60.0) -> None:
        t0 = time.time()
        while not self._q.empty():
            if time.time() - t0 > timeout:
                raise TimeoutError("checkpoint writer stalled")
            time.sleep(0.01)
        # one more tick for the in-flight item
        time.sleep(0.05)

    def result(self, step: int) -> Optional[Dict]:
        with self._lock:
            return self._results.get(step)

    def _gc(self):
        steps = sorted(int(n.split("_")[1])
                       for n in os.listdir(self.directory)
                       if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def close(self):
        self._q.put(None)
        self._thread.join(timeout=5)
