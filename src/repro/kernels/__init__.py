"""Pallas TPU kernels for the perf-critical compute layers.

kernel               | hot-spot                        | oracle
---------------------|--------------------------------|---------------------
flash_attention      | attention (all dense/MoE/VLM)   | ref.mha_reference
rwkv6_scan           | RWKV6 data-dependent recurrence | ref.rwkv6_reference
quack_scan           | QUACK quorum aggregation (S4)   | ref.quack_reference
"""

from . import ref
from .ops import flash_attention, quack_scan, rwkv6_chunked

__all__ = ["flash_attention", "rwkv6_chunked", "quack_scan", "ref"]
