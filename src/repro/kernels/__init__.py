"""Pallas TPU kernels for the perf-critical compute layers.

kernel               | hot-spot                        | oracle
---------------------|--------------------------------|---------------------
flash_attention      | attention (all dense/MoE/VLM)   | ref.mha_reference
rwkv6_scan           | RWKV6 data-dependent recurrence | ref.rwkv6_reference
quack_scan           | QUACK quorum aggregation (S4)   | ref.quack_reference
"""

from jax.experimental.pallas import tpu as _pltpu

# jax renamed pltpu.CompilerParams <-> TPUCompilerParams across releases;
# alias whichever spelling this jax lacks so the kernels work on both.
if not hasattr(_pltpu, "CompilerParams"):
    _pltpu.CompilerParams = _pltpu.TPUCompilerParams
elif not hasattr(_pltpu, "TPUCompilerParams"):
    _pltpu.TPUCompilerParams = _pltpu.CompilerParams

from . import ref
from .ops import flash_attention, quack_scan, rwkv6_chunked

__all__ = ["flash_attention", "rwkv6_chunked", "quack_scan", "ref"]
