"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["mha_reference", "rwkv6_reference", "quack_reference"]


def mha_reference(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,H,Sq,D); k,v: (B,KV,Skv,D); GQA via head folding.

    Returns (B,H,Sq,D). Positions are aligned at the END (q token i sits at
    absolute position Skv - Sq + i), matching prefill-with-cache."""
    b, h, sq, d = q.shape
    _, n_kv, skv, _ = k.shape
    g = h // n_kv
    qr = q.reshape(b, n_kv, g, sq, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qr, kf) / math.sqrt(d)
    q_pos = (skv - sq) + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(b, h, sq, d).astype(q.dtype)


def rwkv6_reference(r, k, v, w, u, state=None):
    """RWKV6 (Finch) recurrence, sequential oracle.

    r,k,v,w: (B,H,T,D) — w is the per-step decay in (0,1);
    u: (H,D) bonus. Returns (y: (B,H,T,D) f32, final_state: (B,H,D,D)).

      S_t = diag(w_t) S_{t-1} + k_t v_t^T
      y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    """
    b, h, t, d = r.shape
    if state is None:
        state = jnp.zeros((b, h, d, d), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        yt = jnp.einsum("bhk,bhkv->bhv", rt,
                        S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, yt

    xs = tuple(x.transpose(2, 0, 1, 3).astype(jnp.float32)
               for x in (r, k, v, w))
    final, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return ys.transpose(1, 2, 0, 3), final


def quack_reference(claims, complaints, stakes, quack_thresh, dup_thresh):
    """QUACK aggregation oracle.

    claims:     (S, R, W) bool — receiver r claims message w (to sender s)
    complaints: (S, R, W) bool — repeat complaints
    stakes:     (R,) f32
    Returns (quacked (S,W) bool, lost (S,W) bool, prefix (S,) int32).
    """
    w_claim = jnp.einsum("srw,r->sw", claims.astype(jnp.float32), stakes)
    w_comp = jnp.einsum("srw,r->sw", complaints.astype(jnp.float32), stakes)
    quacked = w_claim >= quack_thresh
    lost = (w_comp >= dup_thresh) & ~quacked
    prefix = jnp.cumprod(quacked.astype(jnp.int32), axis=1).sum(axis=1)
    return quacked, lost, prefix.astype(jnp.int32)
