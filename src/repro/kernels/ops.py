"""Jitted public wrappers for the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel
body runs in Python via the Pallas interpreter — bit-faithful to the TPU
algorithm); on a real TPU set ``interpret=False`` (ModelConfig.use_pallas
flips the model's attention/rwkv paths onto these wrappers).
"""

from __future__ import annotations

import jax

from .flash_attention import flash_attention
from .quack_scan import quack_scan
from .rwkv6_scan import rwkv6_chunked

__all__ = ["flash_attention", "rwkv6_chunked", "quack_scan",
           "on_tpu", "default_interpret"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()
