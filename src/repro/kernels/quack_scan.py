"""QUACK aggregation Pallas-TPU kernel — the protocol's compute hot loop.

Every round, every sender folds R receiver claim/complaint bitmaps over a
W-message window into stake-weighted quorum decisions (§4.1/§4.2):

    quacked[s,w] = sum_r stakes[r] * claims[s,r,w]     >= u_r + 1
    lost[s,w]    = sum_r stakes[r] * complaints[s,r,w] >= r_r + 1  & ~quacked
    prefix[s]    = length of the contiguous quacked prefix

At RSM scale (hundreds of replicas x 10^5-message windows x thousands of
link-pairs) this is a dense stake-weighted matmul + a prefix-AND scan —
MXU work. Grid: (senders, W/block); the claim/complaint tiles stream into
VMEM, the stake row is resident, and the prefix carry crosses window
blocks through SMEM-like scratch (a (1,1) VMEM cell).

Validated in interpret mode against ``ref.quack_reference``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# window block streamed per grid step — the single home of the kernel's
# alignment requirement (callers padding W to a block multiple import
# this, e.g. core.quack.stake_quorum_bitmap).
BLOCK_W = 512


def _prefix_scan(quacked, prefix_ref, carry_ref):
    """Prefix-AND scan across window blocks (carry in VMEM scratch)."""
    alive = carry_ref[0, 0]
    run = jnp.cumprod(quacked.astype(jnp.int32))
    prefix_ref[0, 0] += alive * jnp.sum(run).astype(jnp.int32)
    carry_ref[0, 0] = alive * run[-1]


def _kernel(claims_ref, comp_ref, stakes_ref, qthr_ref, dthr_ref,
            quacked_ref, lost_ref, prefix_ref, carry_ref, *,
            bw: int, n_blocks: int):
    wj = pl.program_id(1)

    @pl.when(wj == 0)
    def _init():
        carry_ref[...] = jnp.ones_like(carry_ref)      # prefix still alive
        prefix_ref[...] = jnp.zeros_like(prefix_ref)

    claims = claims_ref[0].astype(jnp.float32)         # (R, bw)
    comp = comp_ref[0].astype(jnp.float32)             # (R, bw)
    stakes = stakes_ref[...].astype(jnp.float32)       # (1, R)
    w_claim = stakes @ claims                          # (1, bw)
    w_comp = stakes @ comp
    quacked = w_claim >= qthr_ref[0, 0]
    lost = (w_comp >= dthr_ref[0, 0]) & ~quacked
    quacked_ref[0] = quacked[0]
    lost_ref[0] = lost[0]
    _prefix_scan(quacked[0], prefix_ref, carry_ref)


def _kernel_no_lost(claims_ref, stakes_ref, qthr_ref,
                    quacked_ref, prefix_ref, carry_ref, *,
                    bw: int, n_blocks: int):
    wj = pl.program_id(1)

    @pl.when(wj == 0)
    def _init():
        carry_ref[...] = jnp.ones_like(carry_ref)
        prefix_ref[...] = jnp.zeros_like(prefix_ref)

    claims = claims_ref[0].astype(jnp.float32)
    stakes = stakes_ref[...].astype(jnp.float32)
    quacked = (stakes @ claims) >= qthr_ref[0, 0]
    quacked_ref[0] = quacked[0]
    _prefix_scan(quacked[0], prefix_ref, carry_ref)


@functools.partial(jax.jit,
                   static_argnames=("block_w", "interpret",
                                    "compute_lost"))
def quack_scan(claims, complaints, stakes, quack_thresh, dup_thresh, *,
               block_w: int = BLOCK_W, interpret: bool = True,
               compute_lost: bool = True):
    """claims/complaints: (S,R,W) bool; stakes: (R,) f32.

    Returns (quacked (S,W) bool, lost (S,W) bool, prefix (S,) int32).
    W must be a multiple of block_w (or smaller than it).

    ``compute_lost=False`` drops the loss-quorum side entirely — the
    complaints operand is never streamed into VMEM and its stake matmul
    never issued (Pallas kernels are opaque to XLA DCE, so a dead
    output must be cut at the kernel boundary, not left for the
    compiler) — and ``lost`` comes back as ``None``.
    """
    s, r, w = claims.shape
    bw = min(block_w, w)
    assert w % bw == 0, (w, bw)
    nb = w // bw
    stakes2 = stakes.reshape(1, r).astype(jnp.float32)
    qthr = jnp.full((1, 1), quack_thresh, jnp.float32)
    dthr = jnp.full((1, 1), dup_thresh, jnp.float32)

    tile = pl.BlockSpec((1, r, bw), lambda i, j: (i, 0, j))
    row = pl.BlockSpec((1, r), lambda i, j: (0, 0))
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    out_w = pl.BlockSpec((1, bw), lambda i, j: (i, j))
    out_s = pl.BlockSpec((1, 1), lambda i, j: (i, 0))
    common = dict(
        grid=(s, nb),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.int32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )
    if not compute_lost:
        kernel = functools.partial(_kernel_no_lost, bw=bw, n_blocks=nb)
        quacked, prefix = pl.pallas_call(
            kernel,
            in_specs=[tile, row, scalar],
            out_specs=[out_w, out_s],
            out_shape=[
                jax.ShapeDtypeStruct((s, w), jnp.bool_),
                jax.ShapeDtypeStruct((s, 1), jnp.int32),
            ],
            **common,
        )(claims, stakes2, qthr)
        return quacked, None, prefix[:, 0]
    kernel = functools.partial(_kernel, bw=bw, n_blocks=nb)
    quacked, lost, prefix = pl.pallas_call(
        kernel,
        in_specs=[tile, tile, row, scalar, scalar],
        out_specs=[out_w, out_w, out_s],
        out_shape=[
            jax.ShapeDtypeStruct((s, w), jnp.bool_),
            jax.ShapeDtypeStruct((s, w), jnp.bool_),
            jax.ShapeDtypeStruct((s, 1), jnp.int32),
        ],
        **common,
    )(claims, complaints, stakes2, qthr, dthr)
    return quacked, lost, prefix[:, 0]
