"""Flash attention Pallas-TPU kernel (causal + sliding-window + GQA).

TPU-native adaptation of the flash algorithm: the grid iterates
(batch*q_head, q_block, kv_block) with the kv dimension 'arbitrary'
(sequential) so the online-softmax running state (m, l, acc) lives in VMEM
scratch across kv steps; q/k/v tiles stream HBM->VMEM through BlockSpecs.
Block shapes default to (128, 128) — MXU-aligned (128x128 systolic array),
and the working set  bq*D + bkv*D * 2 + bq*bkv  stays well under VMEM.

Validated on CPU in interpret mode against ``ref.mha_reference``
(tests/test_kernels.py sweeps shapes/dtypes/window/causal).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, bq: int, bkv: int, n_kv_blocks: int,
            causal: bool, window: int, q_offset: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, D)
    k = k_ref[0].astype(jnp.float32)                    # (bkv, D)
    v = v_ref[0].astype(jnp.float32)                    # (bkv, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)

    q_pos = q_offset + qi * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bkv), 0)
    k_pos = kj * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    ok = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                                 # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * alpha
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_ref[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = True):
    """q: (B,H,Sq,D); k,v: (B,KV,Skv,D) -> (B,H,Sq,D).

    Sq and Skv must be multiples of the block sizes; D should be a
    multiple of 128 for MXU alignment (any D works in interpret mode).
    """
    b, h, sq, d = q.shape
    _, n_kv, skv, _ = k.shape
    g = h // n_kv
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, skv, bq, bkv)
    nq, nkv = sq // bq, skv // bkv
    q_offset = skv - sq

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * n_kv, skv, d)
    vf = v.reshape(b * n_kv, skv, d)

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(d), bq=bq, bkv=bkv, n_kv_blocks=nkv,
        causal=causal, window=window, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda bh, i, j, g=g: (bh // g, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda bh, i, j, g=g: (bh // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
