"""RWKV6 (Finch) recurrence as a chunked Pallas-TPU kernel.

TPU adaptation of the data-dependent-decay linear recurrence: the
(D_k x D_v) per-head state is the bandwidth hazard — a naive per-timestep
scan round-trips it through HBM T times (the XLA baseline in
models/blocks.py does exactly that, and the roofline memory term shows
it). Here the grid iterates (batch*head, chunk) with the chunk axis
sequential, so the state matrix stays RESIDENT IN VMEM across the whole
sequence; HBM traffic drops from O(T * D^2) to O(T * D + D^2).

Inside a chunk the recurrence is still stepped (fori_loop over the chunk)
— rank-1 state updates on the VPU; the intra-chunk matrix form (secondary
chunking with decay rescaling, as in flash-linear-attention) is the next
optimization recorded in EXPERIMENTS.md §Perf.

Validated in interpret mode against ``ref.rwkv6_reference``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *,
            chunk: int, n_chunks: int, d: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)          # (C, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (1, D) bonus row

    def step(t, carry):
        S, out = carry                        # S: (D, D) k-major
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)   # (1, D)
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        kv = kt.T @ vt                                   # (D, D)
        yt = rt @ (S + u.T * kv)                         # (1, D)
        S = wt.T * S + kv
        out = jax.lax.dynamic_update_slice_in_dim(out, yt, t, 0)
        return S, out

    S0 = state_ref[...]
    out0 = jnp.zeros((chunk, d), jnp.float32)
    S, out = jax.lax.fori_loop(0, chunk, step, (S0, out0))
    state_ref[...] = S
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_chunked(r, k, v, w, u, *, chunk: int = 128,
                  interpret: bool = True):
    """r,k,v,w: (B,H,T,D); u: (H,D). Returns y: (B,H,T,D) float32.

    T must be a multiple of ``chunk``. The state stays in VMEM across
    chunks (sequential minor grid dimension).
    """
    b, h, t, d = r.shape
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    rf = r.reshape(b * h, t, d)
    kf = k.reshape(b * h, t, d)
    vf = v.reshape(b * h, t, d)
    wf = w.reshape(b * h, t, d)
    uf = jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, 1, d)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nc, d=d)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, chunk, d), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, chunk, d), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, chunk, d), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, 1, d), lambda bh, j: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d), lambda bh, j: (bh, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    return out.reshape(b, h, t, d)
