"""AdamW with decoupled weight decay + global-norm clipping.

Optimizer state shards exactly like the parameters (the m/v trees inherit
the parameter logical names), which is what makes FSDP-style 'data'-axis
sharding of optimizer state work without extra rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "global_norm", "opt_state_specs"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def opt_state_specs(param_shapes, param_names):
    """ShapeDtypeStructs + logical names for the optimizer state tree."""
    f32 = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_shapes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    shapes = AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=f32,
                        v=jax.tree_util.tree_map(lambda x: x, f32))
    names = AdamWState(step=(), m=param_names,
                       v=jax.tree_util.tree_map(lambda x: x, param_names,
                                                is_leaf=lambda x:
                                                isinstance(x, tuple)))
    return shapes, names


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, grads, params, state: AdamWState,
                 lr_scale: Optional[jnp.ndarray] = None
                 ) -> Tuple[Any, AdamWState]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * (lr_scale if lr_scale is not None else 1.0)

    def upd(g, p, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    new_p, new_m, new_v = [], [], []
    for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v):
        np_, nm, nv = upd(g, p, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    unf = jax.tree_util.tree_unflatten
    return unf(treedef, new_p), AdamWState(
        step=step, m=unf(treedef, new_m), v=unf(treedef, new_v))
