"""Optimizer substrate (pure-JAX, optax-free)."""

from .adamw import (AdamWConfig, adamw_init, adamw_update, global_norm,
                    opt_state_specs)
from .schedule import cosine_schedule

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "opt_state_specs", "cosine_schedule"]
