"""RunReport — the merged reporting surface of the observability stack.

One :class:`RunReport` joins the two halves of ``repro.obs`` for a
single engine run:

  * the *device* half — per-lane :class:`~repro.obs.metrics.ObsMetrics`
    drained from the in-graph fabric (latency histograms, HWMs, event
    counters) plus the exact per-message ``delivery_latency`` arrays,
  * the *host* half — the :class:`~repro.obs.tracer.SpanTracer` wall
    timeline (compile/dispatch/drain spans, drain-overlap ratio) and
    its Chrome-trace export.

Persistence is the repo's usual split: arrays go to one compressed
``.npz``, everything scalar/structural to a sibling ``.json``
(:meth:`RunReport.save` / :meth:`RunReport.load` round-trip
bit-exactly). :func:`validate_chrome_trace` schema-checks a trace
document against the Chrome Trace Event Format subset Perfetto loads;
:meth:`RunReport.validate` cross-checks the device histograms against
the per-message latency oracle and the drained delivery counts.

This module imports the simulator, so it is deliberately *not*
re-exported from ``repro.obs.__init__`` (which the simulator itself
imports) — import it directly::

    from repro.obs.report import run_reported
    result, report = run_reported(spec)
    report.save("obs_out/report")

``python -m repro.obs --selftest`` (``repro.obs.__main__``) drives this
end to end and is wired into CI's fast tier.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional

import numpy as np

from ..core.simulator import (SimResult, SimSpec, chunk_dispatch_count,
                              chunk_trace_count, run_simulation)
from .metrics import ObsMetrics, bucket_label, latency_histogram_np
from .tracer import SpanTracer, tracing

__all__ = ["RunReport", "validate_chrome_trace", "report_from_results",
           "run_reported", "run_reported_topology"]


def validate_chrome_trace(doc: dict) -> List[str]:
    """Schema-check a Chrome Trace Event Format document.

    Returns a list of problems (empty = valid): the subset Perfetto /
    ``chrome://tracing`` require for the event phases the tracer emits —
    complete spans ("ph": "X", with a non-negative numeric ``dur``),
    counter-track samples ("ph": "C", with all-numeric ``args``) and
    instant markers ("ph": "i") — plus ``traceEvents`` list shape,
    per-event name/cat/ts/pid/tid and JSON-serializable args.
    """
    problems: List[str] = []
    if not isinstance(doc, dict):
        return [f"document is {type(doc).__name__}, expected dict"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["missing/invalid traceEvents list"]
    last_ts = None
    for i, e in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            problems.append(f"{where}: not a dict")
            continue
        for key, types in (("name", str), ("cat", str), ("ph", str),
                           ("ts", (int, float)),
                           ("pid", int), ("tid", int), ("args", dict)):
            if not isinstance(e.get(key), types):
                problems.append(f"{where}: bad/missing {key!r}")
        ph = e.get("ph")
        if isinstance(e.get("dur"), (int, float)) and e["dur"] < 0:
            problems.append(f"{where}: negative dur")
        if ph == "X":
            if not isinstance(e.get("dur"), (int, float)):
                problems.append(f"{where}: bad/missing 'dur'")
        elif ph == "C":
            args = e.get("args")
            if isinstance(args, dict) and (
                    not args or any(not isinstance(v, (int, float))
                                    for v in args.values())):
                problems.append(f"{where}: counter args must be "
                                f"non-empty numeric")
        elif ph == "i":
            if e.get("s") not in (None, "g", "p", "t"):
                problems.append(f"{where}: bad instant scope "
                                f"{e.get('s')!r}")
        else:
            problems.append(f"{where}: ph={ph!r}, expected one of "
                            f"'X'/'C'/'i'")
        if isinstance(e.get("ts"), (int, float)):
            if last_ts is not None and e["ts"] < last_ts:
                problems.append(f"{where}: ts not sorted")
            last_ts = e["ts"]
        try:
            json.dumps(e.get("args", {}))
        except TypeError:
            problems.append(f"{where}: args not JSON-serializable")
    return problems


@dataclasses.dataclass
class RunReport:
    """Merged device-metrics + host-span record of one engine run."""

    lane_names: List[str]
    obs: Dict[str, ObsMetrics]             # lane name -> device metrics
    latency: Dict[str, np.ndarray]         # lane name -> (M,) int32
    spans: dict                            # SpanTracer.to_dict()
    chrome_trace: dict                     # SpanTracer.to_chrome_trace()
    meta: dict = dataclasses.field(default_factory=dict)

    # -- tables ------------------------------------------------------

    def percentile_table(self) -> str:
        """Per-link latency/counter table (bucketed percentiles)."""
        hdr = ("%-12s %8s %6s %6s %6s %6s %6s %8s %8s"
               % ("link", "counted", "p50", "p95", "p99", "occ",
                  "gclag", "quacks", "resends"))
        lines = [hdr]
        for name in self.lane_names:
            o = self.obs[name]
            p = o.percentiles()
            lines.append("%-12s %8d %6d %6d %6d %6d %6d %8d %8d"
                         % (name, o.total_counted(), p["p50"], p["p95"],
                            p["p99"], o.occupancy_hwm, o.gc_lag_hwm,
                            o.quack_events, o.resend_total))
        return "\n".join(lines)

    def histogram_table(self, name: str) -> str:
        """One lane's latency histogram as label,count rows."""
        o = self.obs[name]
        rows = [f"# {name} delivery-latency histogram (rounds)"]
        for i, c in enumerate(np.asarray(o.latency_hist)):
            if c:
                rows.append("%-10s %d" % (bucket_label(i), int(c)))
        return "\n".join(rows)

    def no_drains(self) -> bool:
        """True when the traced run recorded zero drain spans (the
        overlap ratio is then vacuously 0.0, not a pipelining failure)."""
        return bool(self.spans.get("no_drains", False))

    def summary(self) -> str:
        parts = [self.percentile_table()]
        if self.no_drains():
            parts.append("drain_overlap_ratio n/a (no_drains)")
        else:
            ratio = self.spans.get("drain_overlap_ratio", 0.0)
            parts.append("drain_overlap_ratio %.3f" % ratio)
        if self.meta:
            parts.append("meta " + json.dumps(self.meta, sort_keys=True,
                                              default=str))
        return "\n".join(parts)

    # -- validation --------------------------------------------------

    def validate(self) -> List[str]:
        """Cross-check the report against its own oracles.

        Empty list = consistent: every lane's device histogram must
        equal the numpy histogram of its per-message latency array,
        histogram totals must equal drained (delivered) counts, and the
        Chrome trace must pass :func:`validate_chrome_trace`.
        """
        problems = list(validate_chrome_trace(self.chrome_trace))
        for name in self.lane_names:
            o, lat = self.obs[name], np.asarray(self.latency[name])
            oracle = latency_histogram_np(lat)
            if not np.array_equal(np.asarray(o.latency_hist), oracle):
                problems.append(f"{name}: device histogram != oracle "
                                f"({np.asarray(o.latency_hist).tolist()}"
                                f" vs {oracle.tolist()})")
            delivered = int((lat >= 0).sum())
            if o.total_counted() + o.uncounted != delivered:
                problems.append(
                    f"{name}: histogram total {o.total_counted()} + "
                    f"uncounted {o.uncounted} != delivered {delivered}")
            if o.per_chunk_hist is not None:
                part = np.asarray(o.per_chunk_hist)
                if part.size and not np.array_equal(
                        part[-1], np.asarray(o.latency_hist)):
                    problems.append(f"{name}: last per-chunk snapshot "
                                    f"!= final histogram")
        return problems

    # -- persistence -------------------------------------------------

    def to_json_dict(self) -> dict:
        return {
            "lane_names": list(self.lane_names),
            "meta": self.meta,
            "obs": {n: self.obs[n].to_dict() for n in self.lane_names},
            "spans": self.spans,
            "chrome_trace": self.chrome_trace,
        }

    def save(self, prefix: str) -> Dict[str, str]:
        """Write ``<prefix>.json`` + ``<prefix>.npz``; returns paths."""
        d = os.path.dirname(prefix)
        if d:
            os.makedirs(d, exist_ok=True)
        jpath, npath = prefix + ".json", prefix + ".npz"
        with open(jpath, "w") as f:
            json.dump(self.to_json_dict(), f, indent=1)
        arrays: Dict[str, np.ndarray] = {}
        for i, name in enumerate(self.lane_names):
            o = self.obs[name]
            p = f"l{i}."
            arrays[p + "latency_hist"] = np.asarray(o.latency_hist,
                                                    dtype=np.int64)
            arrays[p + "delivery_latency"] = np.asarray(
                self.latency[name], dtype=np.int32)
            if o.per_chunk_hist is not None:
                arrays[p + "per_chunk_hist"] = np.asarray(
                    o.per_chunk_hist, dtype=np.int64)
        np.savez_compressed(npath, **arrays)
        return {"json": jpath, "npz": npath}

    @classmethod
    def load(cls, prefix: str) -> "RunReport":
        with open(prefix + ".json") as f:
            meta = json.load(f)
        lane_names = list(meta["lane_names"])
        obs: Dict[str, ObsMetrics] = {}
        latency: Dict[str, np.ndarray] = {}
        with np.load(prefix + ".npz", allow_pickle=False) as d:
            for i, name in enumerate(lane_names):
                p, jo = f"l{i}.", meta["obs"][name]
                obs[name] = ObsMetrics(
                    latency_hist=d[p + "latency_hist"],
                    occupancy_hwm=int(jo["occupancy_hwm"]),
                    gc_lag_hwm=int(jo["gc_lag_hwm"]),
                    quack_events=int(jo["quack_events"]),
                    loss_events=int(jo["loss_events"]),
                    resend_total=int(jo["resend_total"]),
                    uncounted=int(jo["uncounted"]),
                    per_chunk_hist=(d[p + "per_chunk_hist"]
                                    if p + "per_chunk_hist" in d
                                    else None),
                )
                latency[name] = d[p + "delivery_latency"]
        return cls(lane_names=lane_names, obs=obs, latency=latency,
                   spans=meta["spans"], chrome_trace=meta["chrome_trace"],
                   meta=meta["meta"])


def report_from_results(results, tracer: SpanTracer,
                        lane_names: Optional[List[str]] = None,
                        meta: Optional[dict] = None) -> RunReport:
    """Assemble a :class:`RunReport` from engine outputs + a tracer.

    Every result must carry ``obs`` (run with
    ``SimConfig.collect_metrics=True``) and ``delivery_latency``.
    """
    names = (list(lane_names) if lane_names is not None
             else [f"lane{i}" for i in range(len(results))])
    obs: Dict[str, ObsMetrics] = {}
    latency: Dict[str, np.ndarray] = {}
    for name, r in zip(names, results):
        if r.obs is None:
            raise ValueError(
                f"lane {name!r} has no device metrics — run with "
                f"SimConfig.collect_metrics=True to build a RunReport")
        obs[name] = r.obs
        latency[name] = np.asarray(r.delivery_latency)
    return RunReport(lane_names=names, obs=obs, latency=latency,
                     spans=tracer.to_dict(),
                     chrome_trace=tracer.to_chrome_trace(),
                     meta=dict(meta or {}))


def _metrics_spec(spec: SimSpec) -> SimSpec:
    return (spec if spec.collect_metrics
            else dataclasses.replace(spec, collect_metrics=True))


def run_reported(spec: SimSpec):
    """Run one spec with the full observability stack on.

    Forces ``collect_metrics`` on, installs a fresh tracer for the run,
    and returns ``(SimResult, RunReport)`` with compile/dispatch deltas
    recorded in ``report.meta``.
    """
    spec = _metrics_spec(spec)
    tracer = SpanTracer()
    t0, d0 = chunk_trace_count(), chunk_dispatch_count()
    with tracing(tracer):
        result = run_simulation(spec)
    meta = {
        "m": spec.m, "steps": spec.steps,
        "window_slots": int(spec.window_slots or 0),
        "superchunk": spec.superchunk,
        "chunk_traces": chunk_trace_count() - t0,
        "chunk_dispatches": chunk_dispatch_count() - d0,
        "delivered": int((np.asarray(result.deliver_time) >= 0).sum()),
    }
    return result, report_from_results([result], tracer,
                                       lane_names=["link"], meta=meta)


def run_reported_topology(topo):
    """Run a topology with the full observability stack on.

    Returns ``(TopologyResult, RunReport)`` with one report lane per
    link, named by link name.
    """
    # local import: topology.engine imports the simulator like we do,
    # keeping the obs package's import surface acyclic
    from ..topology.engine import run_topology
    if not topo.sim.collect_metrics:
        topo = dataclasses.replace(
            topo, sim=dataclasses.replace(topo.sim, collect_metrics=True))
    tracer = SpanTracer()
    t0, d0 = chunk_trace_count(), chunk_dispatch_count()
    with tracing(tracer):
        tres = run_topology(topo)
    names = [l.name for l in topo.links]
    meta = {
        "links": names,
        "chunk_traces": chunk_trace_count() - t0,
        "chunk_dispatches": chunk_dispatch_count() - d0,
    }
    results = [tres.links[n].result for n in names]
    return tres, report_from_results(results, tracer, lane_names=names,
                                     meta=meta)
