"""CLI driver for the observability stack.

``python -m repro.obs --selftest`` is the CI fast-tier gate: it runs a
512-message K=8 pipelined windowed stream with the in-graph metrics
fabric on and the span tracer installed, then checks

  * the exported Chrome trace against the trace-event schema
    (:func:`repro.obs.report.validate_chrome_trace`),
  * every device histogram against the numpy latency oracle and the
    drained delivery counts (:meth:`RunReport.validate`),
  * that the canonical engine span names actually showed up,
  * that metrics collection added zero device dispatches versus the
    metrics-off run of the same spec (the zero-transfer contract),

and writes the RunReport artifact (``report.json`` / ``report.npz`` /
``trace.json``) into ``--out`` for CI upload. Exit code 0 = all checks
passed.

Without ``--selftest`` it runs the same pipeline at user-chosen shape
and prints the percentile table + span summary — a quick way to eyeball
a run's timeline before loading ``trace.json`` into Perfetto.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys

import numpy as np

from ..core.simulator import build_spec, chunk_dispatch_count, run_simulation
from ..core.types import RSMConfig, SimConfig
from .report import run_reported

# spans the engine must emit for any chunked windowed run
_REQUIRED_SPANS = ("run", "drain_wait", "final_flush")


def _build(args) -> SimConfig:
    steps = args.msgs // args.window + 96
    return SimConfig(
        n_msgs=args.msgs, steps=steps, window=args.window, phi=6,
        window_slots=args.window_slots, chunk_steps=args.chunk_steps,
        superchunk=args.k, collect_metrics=True)


def _run(args):
    sim = _build(args)
    spec = build_spec(RSMConfig.bft(1), RSMConfig.bft(1), sim)
    result, report = run_reported(spec)
    return spec, result, report


def _write_artifacts(report, out: str) -> None:
    os.makedirs(out, exist_ok=True)
    paths = report.save(os.path.join(out, "report"))
    tpath = os.path.join(out, "trace.json")
    import json
    with open(tpath, "w") as f:
        json.dump(report.chrome_trace, f)
    print(f"# wrote {paths['json']} {paths['npz']} {tpath}")


def selftest(args) -> int:
    """512-msg K=8 observability self-test; returns exit code."""
    spec, result, report = _run(args)
    problems = report.validate()

    names = {e["name"] for e in report.chrome_trace["traceEvents"]}
    for want in _REQUIRED_SPANS:
        if want not in names:
            problems.append(f"span {want!r} missing from trace "
                            f"(got {sorted(names)})")
    if "compile" not in names and "dispatch" not in names:
        problems.append("neither compile nor dispatch spans recorded")

    lat = np.asarray(result.delivery_latency)
    delivered = int((lat >= 0).sum())
    if delivered != spec.m:
        problems.append(f"only {delivered}/{spec.m} messages delivered "
                        f"in the failure-free selftest stream")
    o = report.obs["link"]
    if o.total_counted() != delivered:
        problems.append(f"histogram total {o.total_counted()} != "
                        f"drained count {delivered}")

    # metrics-off twin: collection must add zero device dispatches
    off = dataclasses.replace(spec, collect_metrics=False)
    d0 = chunk_dispatch_count()
    off_res = run_simulation(off)
    off_dispatches = chunk_dispatch_count() - d0
    if report.meta["chunk_dispatches"] != off_dispatches:
        problems.append(
            f"metrics-on used {report.meta['chunk_dispatches']} "
            f"dispatches, metrics-off used {off_dispatches}")
    if not np.array_equal(np.asarray(off_res.deliver_time),
                          np.asarray(result.deliver_time)):
        problems.append("metrics collection changed deliver_time")

    print(report.summary())
    _write_artifacts(report, args.out)
    if problems:
        print("\nSELFTEST FAILED:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"\nSELFTEST OK: {delivered} deliveries, "
          f"{len(report.chrome_trace['traceEvents'])} spans, "
          f"{report.meta['chunk_dispatches']} dispatches "
          f"(metrics-off: {off_dispatches})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--selftest", action="store_true",
                    help="run the CI observability gate (512 msgs, K=8)")
    ap.add_argument("--msgs", type=int, default=512)
    ap.add_argument("--k", type=int, default=8,
                    help="superchunk fusion depth")
    ap.add_argument("--window", type=int, default=4,
                    help="sender dispatch window per round")
    ap.add_argument("--window-slots", default=128,
                    help="W (int) or 'auto' (default 128: small streams "
                         "must still exercise the windowed kernel)")
    ap.add_argument("--chunk-steps", type=int, default=16)
    ap.add_argument("--out", default="obs_out",
                    help="artifact directory (report + chrome trace)")
    args = ap.parse_args(argv)
    if isinstance(args.window_slots, str) and args.window_slots != "auto":
        args.window_slots = int(args.window_slots)

    if args.selftest:
        return selftest(args)
    spec, result, report = _run(args)
    print(report.summary())
    print()
    print(report.histogram_table("link"))
    _write_artifacts(report, args.out)
    problems = report.validate()
    for p in problems:
        print(f"WARNING: {p}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
