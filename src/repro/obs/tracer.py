"""Host-side span tracer for the windowed engine's control loop.

Monotonic-clock wall-time spans with *explicit* begin/end — the traced
chunk programs never see a clock; all timestamps are taken in the host
loop between dispatches, so jaxprs are unaffected (the PR 6 auditor
stays green by construction).

A :class:`SpanTracer` is installed for the dynamic extent of a run with
:func:`tracing`; the engine's instrumentation points go through
:func:`obs_begin` / :func:`obs_end`, which are no-ops (and take no
clock samples) when no tracer is installed.

Canonical span names emitted by the engine
(``tests/test_obs.py`` asserts these):

  ``run``             whole ``_run_windowed_batch`` invocation
  ``compile``         a dispatch that traced at least one new program
  ``dispatch``        enqueue of an already-compiled chunk/superchunk
  ``drain_wait``      blocking ``device_get`` of a dispatch's queue;
                      ``args.overlapped`` is True when the fetched
                      dispatch had a successor already in flight
                      (PR 5 double buffering doing its job)
  ``plan_floors``     topology commit-floor planning callback
  ``checkpoint``      recorder snapshot capture
  ``window_growth``   adaptive 2x window growth (state re-pad)
  ``dense_migration`` windowed -> dense layout fallback
  ``final_flush``     terminal state fetch + retire scatter

Export: :meth:`SpanTracer.export_chrome_trace` writes Chrome
trace-event JSON loadable in Perfetto / ``chrome://tracing``;
:meth:`SpanTracer.summary` renders a flamegraph-style text table.
The PR 5 async double buffering becomes a first-class number via
:meth:`SpanTracer.drain_overlap_ratio`.
"""

from __future__ import annotations

import json
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

__all__ = [
    "Span",
    "SpanTracer",
    "tracing",
    "current_tracer",
    "obs_begin",
    "obs_end",
    "obs_span",
]


@dataclass
class Span:
    """One closed wall-time interval."""

    name: str
    start_ns: int
    dur_ns: int
    cat: str = "host"
    args: Dict[str, Any] = field(default_factory=dict)


class SpanTracer:
    """Collects :class:`Span` records against one monotonic origin."""

    def __init__(self, pid: int = 0, tid: int = 0):
        self.pid = pid
        self.tid = tid
        self.origin_ns = time.monotonic_ns()
        self.spans: List[Span] = []

    # -- recording ---------------------------------------------------

    def begin(self) -> int:
        return time.monotonic_ns()

    def end(self, begin_ns: int, name: str, cat: str = "host",
            **args: Any) -> Span:
        sp = Span(name=name, start_ns=begin_ns,
                  dur_ns=time.monotonic_ns() - begin_ns,
                  cat=cat, args=dict(args))
        self.spans.append(sp)
        return sp

    @contextmanager
    def span(self, name: str, cat: str = "host", **args: Any):
        b = self.begin()
        try:
            yield
        finally:
            self.end(b, name, cat, **args)

    # -- queries -----------------------------------------------------

    def names(self) -> List[str]:
        return [s.name for s in self.spans]

    def count(self, name: str) -> int:
        return sum(1 for s in self.spans if s.name == name)

    def total_ns(self, name: str) -> int:
        return sum(s.dur_ns for s in self.spans if s.name == name)

    def wall_ns(self) -> int:
        if not self.spans:
            return 0
        end = max(s.start_ns + s.dur_ns for s in self.spans)
        start = min(s.start_ns for s in self.spans)
        return end - start

    def drain_overlap_ratio(self) -> float:
        """Fraction of drain-wait time spent with a successor dispatch
        already in flight (1.0 = every drain overlapped compute)."""
        tot = over = 0
        for s in self.spans:
            if s.name != "drain_wait":
                continue
            tot += s.dur_ns
            if s.args.get("overlapped"):
                over += s.dur_ns
        return over / tot if tot else 0.0

    # -- export ------------------------------------------------------

    def to_chrome_trace(self) -> dict:
        events = []
        for s in self.spans:
            events.append({
                "name": s.name,
                "cat": s.cat,
                "ph": "X",
                "ts": (s.start_ns - self.origin_ns) / 1000.0,
                "dur": s.dur_ns / 1000.0,
                "pid": self.pid,
                "tid": self.tid,
                "args": s.args,
            })
        events.sort(key=lambda e: e["ts"])
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=None)
        return path

    def to_dict(self) -> dict:
        return {
            "origin_ns": self.origin_ns,
            "drain_overlap_ratio": self.drain_overlap_ratio(),
            "spans": [{
                "name": s.name, "cat": s.cat,
                "start_ns": s.start_ns - self.origin_ns,
                "dur_ns": s.dur_ns, "args": s.args,
            } for s in self.spans],
        }

    def summary(self) -> str:
        """Flamegraph-style text rollup, widest spans first."""
        agg: Dict[str, List[int]] = {}
        for s in self.spans:
            ent = agg.setdefault(s.name, [0, 0])
            ent[0] += 1
            ent[1] += s.dur_ns
        wall = max(self.wall_ns(), 1)
        lines = ["%-16s %6s %12s %10s %7s"
                 % ("span", "count", "total_ms", "avg_ms", "%wall")]
        for name, (n, tot) in sorted(agg.items(),
                                     key=lambda kv: -kv[1][1]):
            lines.append("%-16s %6d %12.3f %10.3f %6.1f%%"
                         % (name, n, tot / 1e6, tot / 1e6 / n,
                            100.0 * tot / wall))
        lines.append("drain_overlap_ratio %.3f"
                     % self.drain_overlap_ratio())
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Ambient tracer — engine hooks are no-ops unless one is installed.
# ---------------------------------------------------------------------------

_CURRENT: List[Optional[SpanTracer]] = [None]


def current_tracer() -> Optional[SpanTracer]:
    return _CURRENT[0]


@contextmanager
def tracing(tracer: SpanTracer):
    """Install ``tracer`` as the ambient tracer for this block."""
    prev = _CURRENT[0]
    _CURRENT[0] = tracer
    try:
        yield tracer
    finally:
        _CURRENT[0] = prev


def obs_begin() -> Optional[int]:
    """Timestamp for a prospective span; None (no clock sample) when
    tracing is disabled."""
    tr = _CURRENT[0]
    return tr.begin() if tr is not None else None


def obs_end(begin_ns: Optional[int], name: str, cat: str = "host",
            **args: Any) -> None:
    tr = _CURRENT[0]
    if tr is not None and begin_ns is not None:
        tr.end(begin_ns, name, cat, **args)


@contextmanager
def obs_span(name: str, cat: str = "host", **args: Any):
    b = obs_begin()
    try:
        yield
    finally:
        obs_end(b, name, cat, **args)
