"""Host-side span tracer for the windowed engine's control loop.

Monotonic-clock wall-time spans with *explicit* begin/end — the traced
chunk programs never see a clock; all timestamps are taken in the host
loop between dispatches, so jaxprs are unaffected (the PR 6 auditor
stays green by construction).

A :class:`SpanTracer` is installed for the dynamic extent of a run with
:func:`tracing`; the engine's instrumentation points go through
:func:`obs_begin` / :func:`obs_end`, which are no-ops (and take no
clock samples) when no tracer is installed.

Canonical span names emitted by the engine
(``tests/test_obs.py`` asserts these):

  ``run``             whole ``_run_windowed_batch`` invocation
  ``compile``         a dispatch that traced at least one new program
  ``dispatch``        enqueue of an already-compiled chunk/superchunk
  ``drain_wait``      blocking ``device_get`` of a dispatch's queue;
                      ``args.overlapped`` is True when the fetched
                      dispatch had a successor already in flight
                      (PR 5 double buffering doing its job)
  ``plan_floors``     topology commit-floor planning callback
  ``checkpoint``      recorder snapshot capture
  ``window_growth``   adaptive 2x window growth (state re-pad)
  ``dense_migration`` windowed -> dense layout fallback
  ``final_flush``     terminal state fetch + retire scatter

Export: :meth:`SpanTracer.export_chrome_trace` writes Chrome
trace-event JSON loadable in Perfetto / ``chrome://tracing``;
:meth:`SpanTracer.summary` renders a flamegraph-style text table.
The PR 5 async double buffering becomes a first-class number via
:meth:`SpanTracer.drain_overlap_ratio`.
"""

from __future__ import annotations

import json
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

__all__ = [
    "Span",
    "CounterSample",
    "InstantEvent",
    "SpanTracer",
    "tracing",
    "current_tracer",
    "obs_begin",
    "obs_end",
    "obs_span",
]


@dataclass
class Span:
    """One closed wall-time interval."""

    name: str
    start_ns: int
    dur_ns: int
    cat: str = "host"
    args: Dict[str, Any] = field(default_factory=dict)


@dataclass
class CounterSample:
    """One sample on a named Perfetto counter track (``ph: "C"``)."""

    name: str
    ts_ns: int
    values: Dict[str, float] = field(default_factory=dict)


@dataclass
class InstantEvent:
    """One point-in-time marker (``ph: "i"``) — e.g. an SLO breach."""

    name: str
    ts_ns: int
    cat: str = "host"
    args: Dict[str, Any] = field(default_factory=dict)


class SpanTracer:
    """Collects :class:`Span` records against one monotonic origin.

    Besides duration spans it carries two live-telemetry event kinds:
    counter samples (numeric track values — throughput, backlog, p99 —
    rendered as Perfetto counter tracks) and instant events (SLO
    watchdog breaches / recoveries on the same timeline).
    """

    def __init__(self, pid: int = 0, tid: int = 0):
        self.pid = pid
        self.tid = tid
        self.origin_ns = time.monotonic_ns()
        self.spans: List[Span] = []
        self.counters: List[CounterSample] = []
        self.instants: List[InstantEvent] = []

    # -- recording ---------------------------------------------------

    def begin(self) -> int:
        return time.monotonic_ns()

    def counter(self, name: str, **values: float) -> CounterSample:
        cs = CounterSample(name=name, ts_ns=time.monotonic_ns(),
                           values={k: float(v) for k, v in values.items()})
        self.counters.append(cs)
        return cs

    def instant(self, name: str, cat: str = "host",
                **args: Any) -> InstantEvent:
        ev = InstantEvent(name=name, ts_ns=time.monotonic_ns(),
                          cat=cat, args=dict(args))
        self.instants.append(ev)
        return ev

    def end(self, begin_ns: int, name: str, cat: str = "host",
            **args: Any) -> Span:
        sp = Span(name=name, start_ns=begin_ns,
                  dur_ns=time.monotonic_ns() - begin_ns,
                  cat=cat, args=dict(args))
        self.spans.append(sp)
        return sp

    @contextmanager
    def span(self, name: str, cat: str = "host", **args: Any):
        b = self.begin()
        try:
            yield
        finally:
            self.end(b, name, cat, **args)

    # -- queries -----------------------------------------------------

    def names(self) -> List[str]:
        return [s.name for s in self.spans]

    def count(self, name: str) -> int:
        return sum(1 for s in self.spans if s.name == name)

    def total_ns(self, name: str) -> int:
        return sum(s.dur_ns for s in self.spans if s.name == name)

    def wall_ns(self) -> int:
        if not self.spans:
            return 0
        end = max(s.start_ns + s.dur_ns for s in self.spans)
        start = min(s.start_ns for s in self.spans)
        return end - start

    def no_drains(self) -> bool:
        """True when the run recorded zero ``drain_wait`` spans — the
        0.0 returned by :meth:`drain_overlap_ratio` then means "nothing
        to overlap", not "overlap failed" (dense path, empty runs)."""
        return not any(s.name == "drain_wait" for s in self.spans)

    def drain_overlap_ratio(self) -> float:
        """Fraction of drain-wait time spent with a successor dispatch
        already in flight (1.0 = every drain overlapped compute).

        Defined as 0.0 when there were no drain spans at all; check
        :meth:`no_drains` (exported as the ``no_drains`` field in
        :meth:`to_dict` / ``RunReport``) to tell the cases apart."""
        tot = over = 0
        for s in self.spans:
            if s.name != "drain_wait":
                continue
            tot += s.dur_ns
            if s.args.get("overlapped"):
                over += s.dur_ns
        return over / tot if tot else 0.0

    # -- export ------------------------------------------------------

    def to_chrome_trace(self) -> dict:
        events = []
        for s in self.spans:
            events.append({
                "name": s.name,
                "cat": s.cat,
                "ph": "X",
                "ts": (s.start_ns - self.origin_ns) / 1000.0,
                "dur": s.dur_ns / 1000.0,
                "pid": self.pid,
                "tid": self.tid,
                "args": s.args,
            })
        for c in self.counters:
            events.append({
                "name": c.name,
                "cat": "counter",
                "ph": "C",
                "ts": (c.ts_ns - self.origin_ns) / 1000.0,
                "pid": self.pid,
                "tid": self.tid,
                "args": c.values,
            })
        for ev in self.instants:
            events.append({
                "name": ev.name,
                "cat": ev.cat,
                "ph": "i",
                "s": "t",   # thread-scoped marker
                "ts": (ev.ts_ns - self.origin_ns) / 1000.0,
                "pid": self.pid,
                "tid": self.tid,
                "args": ev.args,
            })
        events.sort(key=lambda e: e["ts"])
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=None)
        return path

    def to_dict(self) -> dict:
        return {
            "origin_ns": self.origin_ns,
            "drain_overlap_ratio": self.drain_overlap_ratio(),
            "no_drains": self.no_drains(),
            "counter_samples": len(self.counters),
            "instant_events": len(self.instants),
            "spans": [{
                "name": s.name, "cat": s.cat,
                "start_ns": s.start_ns - self.origin_ns,
                "dur_ns": s.dur_ns, "args": s.args,
            } for s in self.spans],
        }

    def summary(self) -> str:
        """Flamegraph-style text rollup, widest spans first."""
        agg: Dict[str, List[int]] = {}
        for s in self.spans:
            ent = agg.setdefault(s.name, [0, 0])
            ent[0] += 1
            ent[1] += s.dur_ns
        wall = max(self.wall_ns(), 1)
        lines = ["%-16s %6s %12s %10s %7s"
                 % ("span", "count", "total_ms", "avg_ms", "%wall")]
        for name, (n, tot) in sorted(agg.items(),
                                     key=lambda kv: -kv[1][1]):
            lines.append("%-16s %6d %12.3f %10.3f %6.1f%%"
                         % (name, n, tot / 1e6, tot / 1e6 / n,
                            100.0 * tot / wall))
        if self.no_drains():
            lines.append("drain_overlap_ratio n/a (no_drains)")
        else:
            lines.append("drain_overlap_ratio %.3f"
                         % self.drain_overlap_ratio())
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Ambient tracer — engine hooks are no-ops unless one is installed.
# ---------------------------------------------------------------------------

_CURRENT: List[Optional[SpanTracer]] = [None]


def current_tracer() -> Optional[SpanTracer]:
    return _CURRENT[0]


@contextmanager
def tracing(tracer: SpanTracer):
    """Install ``tracer`` as the ambient tracer for this block."""
    prev = _CURRENT[0]
    _CURRENT[0] = tracer
    try:
        yield tracer
    finally:
        _CURRENT[0] = prev


def obs_begin() -> Optional[int]:
    """Timestamp for a prospective span; None (no clock sample) when
    tracing is disabled."""
    tr = _CURRENT[0]
    return tr.begin() if tr is not None else None


def obs_end(begin_ns: Optional[int], name: str, cat: str = "host",
            **args: Any) -> None:
    tr = _CURRENT[0]
    if tr is not None and begin_ns is not None:
        tr.end(begin_ns, name, cat, **args)


@contextmanager
def obs_span(name: str, cat: str = "host", **args: Any):
    b = obs_begin()
    try:
        yield
    finally:
        obs_end(b, name, cat, **args)
