"""In-graph observability fabric for the windowed engine.

The metrics fabric is a small pytree (:class:`MetricsCarry`) threaded
through the chunk/superchunk scan bodies alongside ``SimState``.  Every
protocol round it accumulates, per lane:

  * a delivery-latency histogram — bucketed ``retire_step - send_step``
    deltas over fixed power-of-two buckets, so the update is a static
    ``.at[].add`` scatter and fully trace-safe,
  * window-occupancy and GC-frontier-lag high-water marks,
  * QUACK / loss-quorum trigger counts and cumulative resend totals.

Only scalar accumulators leave the device: :func:`snapshot_metrics`
emits a :class:`MetricsBlock` (no window-shaped leaves) that rides the
existing one-``device_get``-per-dispatch drain next to ``ChunkQueue`` —
zero additional dispatches or transfers.  The per-slot ``send_time``
ring stays on device and is rotated/padded in lockstep with the window
(:func:`rotate_metrics` / :func:`pad_metrics`).

Everything here is derived from *state deltas* — ``_protocol_step``
itself is untouched, and when ``SimConfig.collect_metrics`` is off the
engine builds byte-identical jaxprs (asserted by ``tests/test_obs.py``).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "NUM_LATENCY_BUCKETS",
    "LATENCY_BUCKET_EDGES",
    "MetricsCarry",
    "MetricsBlock",
    "ObsMetrics",
    "init_metrics_carry",
    "update_metrics",
    "rotate_metrics",
    "pad_metrics",
    "snapshot_metrics",
    "zero_metrics_block",
    "delta_metrics_block",
    "merge_metrics_blocks",
    "latency_bucket",
    "latency_bucket_np",
    "latency_histogram_np",
    "bucket_label",
    "percentile_from_hist",
    "migrate_dense_metrics",
    "resume_metrics_carry",
    "obs_from_carry",
    "obs_from_final",
]

# Power-of-two bucket edges (python ints — no import-time jnp).  A
# latency ``x`` lands in bucket ``#edges <= x``: bucket 0 holds x < 1
# (same-round retirement), bucket i holds 2^(i-1) <= x < 2^i, and the
# last bucket is the >= 2^16 overflow sink.
NUM_LATENCY_BUCKETS = 18
LATENCY_BUCKET_EDGES = tuple(2 ** i for i in range(NUM_LATENCY_BUCKETS - 1))


class MetricsCarry(NamedTuple):
    """Device-resident metrics state carried through the chunk scan.

    ``send_time`` is window-shaped (one slot per live message, -1 when
    the slot's message has not been dispatched); everything else is a
    scalar accumulator.
    """

    send_time: jnp.ndarray      # (W,) int32, dispatch round or -1
    latency_hist: jnp.ndarray   # (NUM_LATENCY_BUCKETS,) int32
    occupancy_hwm: jnp.ndarray  # () int32, max in-flight msgs
    gc_lag_hwm: jnp.ndarray     # () int32, max dispatched-in-window
    quack_events: jnp.ndarray   # () int32, QUACK quorum first-trips
    loss_events: jnp.ndarray    # () int32, loss-quorum (retry) triggers
    resend_total: jnp.ndarray   # () int32, cumulative resent messages
    uncounted: jnp.ndarray      # () int32, deliveries with unknown send


class MetricsBlock(NamedTuple):
    """Scalar-only snapshot of ``MetricsCarry`` drained per chunk."""

    latency_hist: jnp.ndarray   # (NUM_LATENCY_BUCKETS,) int32
    occupancy_hwm: jnp.ndarray  # () int32
    gc_lag_hwm: jnp.ndarray     # () int32
    quack_events: jnp.ndarray   # () int32
    loss_events: jnp.ndarray    # () int32
    resend_total: jnp.ndarray   # () int32
    uncounted: jnp.ndarray      # () int32


def init_metrics_carry(w_slots: int) -> MetricsCarry:
    z = jnp.zeros((), dtype=jnp.int32)
    return MetricsCarry(
        send_time=jnp.full((w_slots,), -1, dtype=jnp.int32),
        latency_hist=jnp.zeros((NUM_LATENCY_BUCKETS,), dtype=jnp.int32),
        occupancy_hwm=z,
        gc_lag_hwm=z,
        quack_events=z,
        loss_events=z,
        resend_total=z,
        uncounted=z,
    )


def latency_bucket(lat: jnp.ndarray) -> jnp.ndarray:
    """Bucket index for each latency (trace-safe, static edges)."""
    edges = jnp.asarray(LATENCY_BUCKET_EDGES, dtype=jnp.int32)
    return (lat[..., None] >= edges).sum(axis=-1).astype(jnp.int32)


def update_metrics(mc, old_state, new_state, ms, t):
    """Fold one protocol round's state delta into the carry.

    ``old_state``/``new_state`` are the window-shaped ``SimState``
    before/after ``_protocol_step`` at round ``t``; ``ms`` is the
    round's ``StepMetrics``.  Pure function of its inputs — safe under
    vmap/scan/jit.
    """
    sent_now = jnp.logical_and(new_state.orig_sent,
                               jnp.logical_not(old_state.orig_sent))
    send_time = jnp.where(sent_now, t, mc.send_time).astype(jnp.int32)

    delivered_now = jnp.logical_and(old_state.deliver_time < 0,
                                    new_state.deliver_time >= 0)
    known = send_time >= 0
    counted = jnp.logical_and(delivered_now, known)
    lat = jnp.maximum(t - send_time, 0)
    hist = mc.latency_hist.at[latency_bucket(lat)].add(
        counted.astype(jnp.int32))

    in_flight = jnp.logical_and(
        new_state.orig_sent, new_state.deliver_time < 0
    ).sum().astype(jnp.int32)
    # Frontier lag: dispatched slots still resident in the window —
    # i.e. how far the GC frontier trails the dispatch head.
    gc_lag = new_state.orig_sent.sum().astype(jnp.int32)

    return MetricsCarry(
        send_time=send_time,
        latency_hist=hist,
        occupancy_hwm=jnp.maximum(mc.occupancy_hwm, in_flight),
        gc_lag_hwm=jnp.maximum(mc.gc_lag_hwm, gc_lag),
        quack_events=(mc.quack_events + jnp.logical_and(
            old_state.quack_time < 0, new_state.quack_time >= 0
        ).sum()).astype(jnp.int32),
        loss_events=(mc.loss_events
                     + (new_state.retry - old_state.retry).sum()
                     ).astype(jnp.int32),
        resend_total=(mc.resend_total + ms.resends).astype(jnp.int32),
        uncounted=(mc.uncounted + jnp.logical_and(
            delivered_now, jnp.logical_not(known)
        ).sum()).astype(jnp.int32),
    )


def rotate_metrics(mc: MetricsCarry, frontier, w_slots: int
                   ) -> MetricsCarry:
    """Shift ``send_time`` with the window ring (traced ``frontier``)."""
    ext = jnp.concatenate(
        [mc.send_time, jnp.full((w_slots,), -1, dtype=jnp.int32)])
    return mc._replace(
        send_time=jax.lax.dynamic_slice_in_dim(ext, frontier, w_slots))


def pad_metrics(mc: MetricsCarry, new_w: int) -> MetricsCarry:
    """Grow ``send_time`` to ``new_w`` slots (batched leaves OK)."""
    pad = new_w - mc.send_time.shape[-1]
    fill = jnp.full(mc.send_time.shape[:-1] + (pad,), -1,
                    dtype=jnp.int32)
    return mc._replace(
        send_time=jnp.concatenate([mc.send_time, fill], axis=-1))


def snapshot_metrics(mc: MetricsCarry) -> MetricsBlock:
    """Scalar accumulators only — what rides the drain."""
    return MetricsBlock(*(getattr(mc, f) for f in MetricsBlock._fields))


# Block algebra (host-side numpy).  Snapshots drained from the engine
# are *cumulative*: the block after chunk i holds totals since round 0.
# ``delta_metrics_block`` turns consecutive snapshots into per-interval
# sketches; ``merge_metrics_blocks`` recombines any grouping of those
# sketches.  Counters are integer-additive and HWMs are maxes of a
# monotone sequence, so folds are exact (bit-identical) in any
# association order — the property ``tests/test_stream.py`` checks.

_BLOCK_ADDITIVE = ("latency_hist", "quack_events", "loss_events",
                   "resend_total", "uncounted")
_BLOCK_HWM = ("occupancy_hwm", "gc_lag_hwm")


def _block_np(b: MetricsBlock) -> MetricsBlock:
    return MetricsBlock(*(np.asarray(v, dtype=np.int64) for v in b))


def zero_metrics_block(n_lanes: Optional[int] = None) -> MetricsBlock:
    """Identity element for :func:`merge_metrics_blocks` (numpy)."""
    lead = () if n_lanes is None else (n_lanes,)
    return MetricsBlock(
        latency_hist=np.zeros(lead + (NUM_LATENCY_BUCKETS,),
                              dtype=np.int64),
        **{f: np.zeros(lead, dtype=np.int64)
           for f in MetricsBlock._fields if f != "latency_hist"})


def delta_metrics_block(prev: Optional[MetricsBlock],
                        cur: MetricsBlock) -> MetricsBlock:
    """Per-interval sketch between two cumulative snapshots.

    Additive counters subtract; HWMs keep ``cur`` (the running max is
    monotone, so re-merging deltas restores the end-of-run max).
    ``prev=None`` means the start of the stream (all-zero baseline).
    """
    cur = _block_np(cur)
    if prev is None:
        return cur
    prev = _block_np(prev)
    return cur._replace(**{f: getattr(cur, f) - getattr(prev, f)
                           for f in _BLOCK_ADDITIVE})


def merge_metrics_blocks(a: MetricsBlock, b: MetricsBlock) -> MetricsBlock:
    """Exact merge of two interval sketches (add counters, max HWMs)."""
    a, b = _block_np(a), _block_np(b)
    out = {f: getattr(a, f) + getattr(b, f) for f in _BLOCK_ADDITIVE}
    out.update({f: np.maximum(getattr(a, f), getattr(b, f))
                for f in _BLOCK_HWM})
    return MetricsBlock(**out)


# ---------------------------------------------------------------------------
# Host-side mirrors & summaries (never called from trace contexts)
# ---------------------------------------------------------------------------


def latency_bucket_np(lat) -> np.ndarray:
    edges = np.asarray(LATENCY_BUCKET_EDGES, dtype=np.int64)
    return (np.asarray(lat)[..., None] >= edges).sum(axis=-1)


def latency_histogram_np(latencies) -> np.ndarray:
    """Oracle histogram from a raw latency array (-1 = undelivered)."""
    lat = np.asarray(latencies).ravel()
    lat = lat[lat >= 0]
    hist = np.zeros(NUM_LATENCY_BUCKETS, dtype=np.int64)
    np.add.at(hist, latency_bucket_np(lat), 1)
    return hist


def bucket_label(i: int) -> str:
    if i == 0:
        return "0"
    if i == NUM_LATENCY_BUCKETS - 1:
        return ">=%d" % LATENCY_BUCKET_EDGES[-1]
    lo, hi = LATENCY_BUCKET_EDGES[i - 1], LATENCY_BUCKET_EDGES[i]
    if hi - lo == 1:
        return "%d" % lo
    return "%d-%d" % (lo, hi - 1)


def percentile_from_hist(hist, q: float) -> int:
    """Upper bucket edge covering the q-th percentile (q in [0,100]).

    Conservative (bucketed) estimate: returns the smallest power-of-two
    edge E such that at least q% of counted deliveries had latency < E
    (0 for bucket 0).  -1 when the histogram is empty.
    """
    hist = np.asarray(hist, dtype=np.int64)
    total = int(hist.sum())
    if total == 0:
        return -1
    need = q / 100.0 * total
    cum = np.cumsum(hist)
    idx = int(np.searchsorted(cum, need))       # bucket holding the q-th
    if idx == 0:
        return 0                                # bucket 0: latency < 1
    # bucket i (i >= 1) holds [2^(i-1), 2^i): upper edge = edges[i];
    # the overflow sink has no finite upper edge — report its lower one
    return int(LATENCY_BUCKET_EDGES[min(idx,
                                        len(LATENCY_BUCKET_EDGES) - 1)])


@dataclasses.dataclass
class ObsMetrics:
    """Per-lane device-metrics summary drained from one run."""

    latency_hist: np.ndarray            # (NUM_LATENCY_BUCKETS,) int64
    occupancy_hwm: int
    gc_lag_hwm: int
    quack_events: int
    loss_events: int
    resend_total: int
    uncounted: int
    per_chunk_hist: Optional[np.ndarray] = None  # (n_chunks, NB) int64

    def total_counted(self) -> int:
        return int(np.asarray(self.latency_hist).sum())

    def percentiles(self, qs=(50, 95, 99)) -> dict:
        return {"p%g" % q: percentile_from_hist(self.latency_hist, q)
                for q in qs}

    def to_dict(self) -> dict:
        d = {
            "latency_hist": np.asarray(self.latency_hist).tolist(),
            "bucket_labels": [bucket_label(i)
                              for i in range(NUM_LATENCY_BUCKETS)],
            "occupancy_hwm": int(self.occupancy_hwm),
            "gc_lag_hwm": int(self.gc_lag_hwm),
            "quack_events": int(self.quack_events),
            "loss_events": int(self.loss_events),
            "resend_total": int(self.resend_total),
            "uncounted": int(self.uncounted),
            "total_counted": self.total_counted(),
        }
        d.update(self.percentiles())
        return d


def migrate_dense_metrics(mc: MetricsCarry, bases: Sequence[int],
                          send_step: np.ndarray, m: int) -> MetricsCarry:
    """Re-embed a batched carry into the dense (base 0, W=M) layout.

    Called only from the host loop's dense-migration path (which is
    already a synchronization point).  Slots already retired out of the
    ring are refilled from the host ``send_step`` dispatch mirror so
    the carry stays exact across the fallback.
    """
    host = jax.device_get(mc)
    st = np.asarray(host.send_time)
    n_b, w = st.shape
    dense = np.full((n_b, m), -1, dtype=np.int32)
    for b in range(n_b):
        lo = int(bases[b])
        live = min(w, m - lo)
        if live > 0:
            dense[b, lo:lo + live] = st[b, :live]
        if lo > 0:
            dense[b, :lo] = send_step[b, :lo]
    return MetricsCarry(
        send_time=jnp.asarray(dense),
        latency_hist=jnp.asarray(host.latency_hist),
        occupancy_hwm=jnp.asarray(host.occupancy_hwm),
        gc_lag_hwm=jnp.asarray(host.gc_lag_hwm),
        quack_events=jnp.asarray(host.quack_events),
        loss_events=jnp.asarray(host.loss_events),
        resend_total=jnp.asarray(host.resend_total),
        uncounted=jnp.asarray(host.uncounted),
    )


def resume_metrics_carry(w_slots: int, bases: Sequence[int],
                         send_step: np.ndarray, m: int) -> MetricsCarry:
    """Fresh batched carry for a replay resume.

    Accumulators restart at zero (metrics cover the resumed segment);
    ``send_time`` is seeded from the checkpointed dispatch mirror so
    latencies of messages in flight across the boundary stay exact.
    """
    n_b = len(bases)
    st = np.full((n_b, w_slots), -1, dtype=np.int32)
    for b in range(n_b):
        lo = int(bases[b])
        live = max(0, min(w_slots, m - lo))
        if live > 0:
            st[b, :live] = send_step[b, lo:lo + live]
    z = jnp.zeros((n_b,), dtype=jnp.int32)
    return MetricsCarry(
        send_time=jnp.asarray(st),
        latency_hist=jnp.zeros((n_b, NUM_LATENCY_BUCKETS),
                               dtype=jnp.int32),
        occupancy_hwm=z,
        gc_lag_hwm=z,
        quack_events=z,
        loss_events=z,
        resend_total=z,
        uncounted=z,
    )


def obs_from_carry(mc) -> ObsMetrics:
    """Unbatched carry (one lane, e.g. the dense single-run path)."""
    return ObsMetrics(
        latency_hist=np.asarray(mc.latency_hist, dtype=np.int64),
        occupancy_hwm=int(mc.occupancy_hwm),
        gc_lag_hwm=int(mc.gc_lag_hwm),
        quack_events=int(mc.quack_events),
        loss_events=int(mc.loss_events),
        resend_total=int(mc.resend_total),
        uncounted=int(mc.uncounted),
    )


def obs_from_final(final_mc, blocks, lane: int) -> ObsMetrics:
    """Build one lane's :class:`ObsMetrics` from the fetched final
    carry plus the per-chunk :class:`MetricsBlock` drain parts."""
    per_chunk = None
    if blocks:
        per_chunk = np.stack(
            [np.asarray(b.latency_hist[lane], dtype=np.int64)
             for b in blocks])
    return ObsMetrics(
        latency_hist=np.asarray(final_mc.latency_hist[lane],
                                dtype=np.int64),
        occupancy_hwm=int(final_mc.occupancy_hwm[lane]),
        gc_lag_hwm=int(final_mc.gc_lag_hwm[lane]),
        quack_events=int(final_mc.quack_events[lane]),
        loss_events=int(final_mc.loss_events[lane]),
        resend_total=int(final_mc.resend_total[lane]),
        uncounted=int(final_mc.uncounted[lane]),
        per_chunk_hist=per_chunk,
    )
