"""Online aggregation over the engine's live drain feed.

The streaming session driver (``repro.stream``) points the engine's
horizon-mode ``drain_sink`` at these classes: every drained chunk
already carries a cumulative :class:`~repro.obs.metrics.MetricsBlock`
snapshot (zero extra dispatches or transfers), and this module turns
that feed into rolling service telemetry:

  * :class:`LatencySketch` — a mergeable power-of-two latency sketch.
    Snapshots are cumulative, so consecutive ones are differenced into
    per-interval sketches (:func:`~repro.obs.metrics.delta_metrics_block`)
    and re-merged (:func:`~repro.obs.metrics.merge_metrics_blocks`);
    integer counters make every fold *bit-exact* in any association
    order, so the live totals equal a post-hoc ``RunReport`` of the
    same prefix exactly.
  * :class:`LiveAggregator` — folds the per-chunk feed into cumulative
    and windowed sketches, throughput/goodput/resend rates over a
    sliding chunk window, and GC-frontier-lag / backlog trend lines;
    emits one :class:`LiveSample` per chunk.
  * :class:`SLOWatchdog` — edge-triggered watchdogs (p99 delivery
    latency, resend rate, frontier stall) producing structured
    :class:`SLOEvent` records on breach/recovery transitions.
  * :class:`LiveReport` — bounded in-memory dashboard rows plus an
    append-only JSON-lines stream on disk; host memory stays O(1) in
    stream length.

Everything here is host-side numpy — never imported by trace contexts.
"""

from __future__ import annotations

import dataclasses
import json
import os
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np

from .metrics import (MetricsBlock, delta_metrics_block,
                      merge_metrics_blocks, percentile_from_hist,
                      zero_metrics_block)

__all__ = [
    "LatencySketch",
    "TrendLine",
    "LiveSample",
    "LiveAggregator",
    "SLOConfig",
    "SLOEvent",
    "SLOWatchdog",
    "LiveReport",
]


@dataclasses.dataclass
class LatencySketch:
    """Mergeable delivery-latency sketch (power-of-two histogram).

    Buckets are the engine's static edges, counts are integers — merging
    two sketches is elementwise addition, exact and associative.
    """

    hist: np.ndarray     # (..., NUM_LATENCY_BUCKETS) int64

    @classmethod
    def empty(cls, n_lanes: Optional[int] = None) -> "LatencySketch":
        return cls(hist=zero_metrics_block(n_lanes).latency_hist)

    def merge(self, other: "LatencySketch") -> "LatencySketch":
        return LatencySketch(hist=self.hist + other.hist)

    def lane_sum(self) -> np.ndarray:
        h = self.hist
        return h.sum(axis=0) if h.ndim > 1 else h

    def total(self) -> int:
        return int(self.hist.sum())

    def percentile(self, q: float) -> int:
        return percentile_from_hist(self.lane_sum(), q)

    def percentiles(self, qs=(50, 95, 99)) -> dict:
        return {"p%g" % q: self.percentile(q) for q in qs}


class TrendLine:
    """Bounded (t, value) series — the last ``maxlen`` observations."""

    def __init__(self, name: str, maxlen: int = 256):
        self.name = name
        self.points: Deque[Tuple[int, float]] = deque(maxlen=maxlen)

    def add(self, t: int, value: float) -> None:
        self.points.append((int(t), float(value)))

    def last(self) -> float:
        return self.points[-1][1] if self.points else 0.0

    def slope_per_round(self) -> float:
        """Least-squares slope over the retained points (0 if < 2)."""
        if len(self.points) < 2:
            return 0.0
        ts = np.array([p[0] for p in self.points], dtype=np.float64)
        vs = np.array([p[1] for p in self.points], dtype=np.float64)
        dt = ts - ts.mean()
        denom = float((dt * dt).sum())
        return float((dt * (vs - vs.mean())).sum() / denom) if denom else 0.0

    def to_list(self) -> List[Tuple[int, float]]:
        return list(self.points)


@dataclasses.dataclass
class LiveSample:
    """One per-chunk digest of the live feed (all lanes folded)."""

    t: int                    # protocol round at the chunk boundary
    delivered: int            # unique messages delivered, cumulative
    retired: int              # messages GC-retired out of the window
    backlog: int              # arrived (scheduled) - delivered
    gc_lag: int               # dispatched-by-now - slowest lane frontier
    resends: int              # cumulative resent messages
    losses: int               # cumulative loss-quorum triggers
    throughput: float         # wire msgs / round over the rate window
    goodput: float            # delivered msgs / round over the rate window
    resend_rate: float        # resends per delivered msg over the window
    p50: int                  # cumulative bucketed percentiles (rounds)
    p95: int
    p99: int
    p99_recent: int           # percentile over the rate window only
    occupancy_hwm: int
    rounds_elapsed: int

    def to_row(self) -> dict:
        return dataclasses.asdict(self)


class LiveAggregator:
    """Folds the horizon-mode drain feed into online aggregates.

    ``arrivals_cum[t]`` is the number of messages whose schedule round
    is ``< t`` (from the workload generator) — it prices backlog and
    frontier lag without touching the device.  The cumulative sketch is
    rebuilt purely through the delta/merge algebra, so the live path
    exercises exactly the code the merge-associativity tests pin down.
    """

    def __init__(self, n_lanes: int, arrivals_cum: np.ndarray,
                 window_chunks: int = 8, trend_len: int = 256):
        self.n_lanes = n_lanes
        self.arrivals_cum = np.asarray(arrivals_cum, dtype=np.int64)
        self.window_chunks = max(int(window_chunks), 1)
        self.prev_block: Optional[MetricsBlock] = None
        self.cum = zero_metrics_block(n_lanes)
        # (t, delta-block, delivered_cum, wire_cum) ring for rates
        self._ring: Deque[Tuple[int, MetricsBlock, int, int]] = deque(
            maxlen=self.window_chunks)
        self.delivered = np.zeros(n_lanes, dtype=np.int64)
        self.retired = np.zeros(n_lanes, dtype=np.int64)
        self.wire_total = 0
        self.chunks = 0
        self.gc_lag_trend = TrendLine("gc_lag", trend_len)
        self.backlog_trend = TrendLine("backlog", trend_len)
        self.occupancy_trend = TrendLine("occupancy", trend_len)

    def _arrived_by(self, t: int) -> int:
        idx = min(int(t), len(self.arrivals_cum) - 1)
        return int(self.arrivals_cum[idx]) if idx >= 0 else 0

    def observe(self, t_end: int, metrics, bases: np.ndarray,
                block: Optional[MetricsBlock]) -> LiveSample:
        """Fold one drained chunk; returns the chunk's digest."""
        self.chunks += 1
        if block is not None:
            delta = delta_metrics_block(self.prev_block, block)
            self.cum = merge_metrics_blocks(self.cum, delta)
            self.prev_block = block
        else:
            delta = zero_metrics_block(self.n_lanes)
        # StepMetrics.delivered is cumulative per round; cross/intra
        # are per-round wire counts
        dl = np.asarray(metrics.delivered)
        self.delivered = dl[..., -1].astype(np.int64).reshape(-1)
        self.retired = np.asarray(bases, dtype=np.int64).reshape(-1)
        wire = int(np.asarray(metrics.cross_msgs).sum()
                   + np.asarray(metrics.intra_msgs).sum())
        self.wire_total += wire
        self._ring.append((int(t_end), delta,
                           int(self.delivered.sum()), self.wire_total))

        arrived = self._arrived_by(t_end)
        backlog = max(arrived * self.n_lanes - int(self.delivered.sum()),
                      0)
        gc_lag = max(arrived - int(self.retired.min()), 0)
        occ = int(np.asarray(self.cum.occupancy_hwm).max())
        self.gc_lag_trend.add(t_end, gc_lag)
        self.backlog_trend.add(t_end, backlog)
        self.occupancy_trend.add(t_end, occ)

        t0, _, d0, w0 = self._ring[0]
        rounds = max(int(t_end) - t0, 1) if len(self._ring) > 1 else \
            max(int(t_end), 1)
        if len(self._ring) == 1:
            d0, w0 = 0, 0
        good = (int(self.delivered.sum()) - d0) / rounds
        thr = (self.wire_total - w0) / rounds
        recent = LatencySketch.empty(self.n_lanes)
        for _, dblk, _, _ in self._ring:
            recent = recent.merge(LatencySketch(hist=dblk.latency_hist))
        win_delivered = max(int(self.delivered.sum()) - d0, 0)
        win_resends = sum(int(np.asarray(dblk.resend_total).sum())
                          for _, dblk, _, _ in self._ring)
        cum_sketch = self.sketch()
        return LiveSample(
            t=int(t_end),
            delivered=int(self.delivered.sum()),
            retired=int(self.retired.sum()),
            backlog=backlog,
            gc_lag=gc_lag,
            resends=int(np.asarray(self.cum.resend_total).sum()),
            losses=int(np.asarray(self.cum.loss_events).sum()),
            throughput=thr,
            goodput=good,
            resend_rate=(win_resends / win_delivered
                         if win_delivered else 0.0),
            p50=cum_sketch.percentile(50),
            p95=cum_sketch.percentile(95),
            p99=cum_sketch.percentile(99),
            p99_recent=recent.percentile(99),
            occupancy_hwm=occ,
            rounds_elapsed=int(t_end),
        )

    def sketch(self) -> LatencySketch:
        """Cumulative latency sketch (folded deltas == latest snapshot,
        bit-exactly — the merge-algebra invariant)."""
        return LatencySketch(hist=np.asarray(self.cum.latency_hist))


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Breach thresholds; ``None`` disables a watchdog."""

    p99_latency_rounds: Optional[int] = 64    # recent p99 above this
    resend_rate: Optional[float] = 0.5        # resends per delivered msg
    frontier_stall_chunks: Optional[int] = 8  # chunks with no GC advance
                                              # while backlog is non-zero


@dataclasses.dataclass
class SLOEvent:
    """One edge-triggered watchdog transition."""

    kind: str          # "p99_latency" | "resend_rate" | "frontier_stall"
    t: int             # protocol round of the observation
    value: float
    threshold: float
    recovered: bool = False   # False = breach edge, True = recovery edge

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class SLOWatchdog:
    """Edge-triggered SLO monitors over :class:`LiveSample` digests.

    Emits one event when a rule first breaches and one when it
    recovers — not one per sample — so the tracer timeline stays
    readable at horizon scale.
    """

    def __init__(self, config: SLOConfig):
        self.config = config
        self._breached = {"p99_latency": False, "resend_rate": False,
                          "frontier_stall": False}
        self._stall_chunks = 0
        self._last_retired: Optional[int] = None
        self.events: List[SLOEvent] = []

    def _edge(self, kind: str, bad: bool, value: float,
              threshold: float, t: int, out: List[SLOEvent]) -> None:
        if bad != self._breached[kind]:
            self._breached[kind] = bad
            out.append(SLOEvent(kind=kind, t=t, value=float(value),
                                threshold=float(threshold),
                                recovered=not bad))

    def check(self, sample: LiveSample) -> List[SLOEvent]:
        cfg, out = self.config, []
        if cfg.p99_latency_rounds is not None:
            self._edge("p99_latency",
                       sample.p99_recent > cfg.p99_latency_rounds,
                       sample.p99_recent, cfg.p99_latency_rounds,
                       sample.t, out)
        if cfg.resend_rate is not None:
            self._edge("resend_rate",
                       sample.resend_rate > cfg.resend_rate,
                       sample.resend_rate, cfg.resend_rate,
                       sample.t, out)
        if cfg.frontier_stall_chunks is not None:
            stalled = (self._last_retired is not None
                       and sample.retired == self._last_retired
                       and sample.backlog > 0)
            self._stall_chunks = self._stall_chunks + 1 if stalled else 0
            self._last_retired = sample.retired
            self._edge("frontier_stall",
                       self._stall_chunks >= cfg.frontier_stall_chunks,
                       self._stall_chunks, cfg.frontier_stall_chunks,
                       sample.t, out)
        self.events.extend(out)
        return out


class LiveReport:
    """Bounded dashboard rows + append-only JSON-lines stream.

    ``rows`` keeps only the last ``maxlen`` samples in memory; when
    ``jsonl_path`` is given every row is also appended to disk as it
    happens, so a crash loses nothing and memory stays flat.
    """

    COLUMNS = ("t", "delivered", "backlog", "gc_lag", "throughput",
               "goodput", "resend_rate", "p50", "p95", "p99",
               "p99_recent")

    def __init__(self, maxlen: int = 256,
                 jsonl_path: Optional[str] = None):
        self.rows: Deque[dict] = deque(maxlen=maxlen)
        self.jsonl_path = jsonl_path
        self._fh = None
        self.total_rows = 0
        if jsonl_path:
            d = os.path.dirname(jsonl_path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._fh = open(jsonl_path, "w")

    def add(self, sample: LiveSample,
            slo_events: Optional[List[SLOEvent]] = None) -> dict:
        row = sample.to_row()
        if slo_events:
            row["slo_events"] = [e.to_dict() for e in slo_events]
        self.rows.append(row)
        self.total_rows += 1
        if self._fh is not None:
            self._fh.write(json.dumps(row, default=float) + "\n")
            self._fh.flush()
        return row

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def dashboard(self, last_n: int = 12) -> str:
        """Fixed-width text table over the most recent rows."""
        hdr = ("%8s %10s %9s %7s %8s %8s %7s %5s %5s %5s %6s"
               % ("t", "delivered", "backlog", "gclag", "thr/rnd",
                  "good/rnd", "resend", "p50", "p95", "p99", "p99w"))
        lines = [hdr]
        for row in list(self.rows)[-last_n:]:
            lines.append(
                "%8d %10d %9d %7d %8.2f %8.2f %6.1f%% %5d %5d %5d %6d"
                % (row["t"], row["delivered"], row["backlog"],
                   row["gc_lag"], row["throughput"], row["goodput"],
                   100.0 * row["resend_rate"], row["p50"], row["p95"],
                   row["p99"], row["p99_recent"]))
            for ev in row.get("slo_events", ()):
                tag = "recovered" if ev["recovered"] else "BREACH"
                lines.append("  !! slo:%s %s value=%.2f thr=%.2f"
                             % (ev["kind"], tag, ev["value"],
                                ev["threshold"]))
        return "\n".join(lines)
