"""repro.obs — observability layer.

Three parts (ISSUE 8):

  * :mod:`repro.obs.metrics` — in-graph metrics fabric carried through
    the chunk/superchunk scan bodies (delivery-latency histograms,
    occupancy/GC-lag high-water marks, quorum trigger counts).
  * :mod:`repro.obs.tracer` — host-side monotonic-clock span tracer
    with Chrome-trace/Perfetto export and drain-overlap ratio.
  * :mod:`repro.obs.live` — online aggregation over the live drain
    feed (mergeable latency sketches, windowed rates, trend lines, SLO
    watchdogs, ``LiveReport``) consumed by ``repro.stream``.
  * :mod:`repro.obs.report` — merges device metrics + host spans into
    one ``RunReport`` (npz+json); CLI via ``python -m repro.obs``.

``report`` imports the engine, and the engine imports ``metrics`` —
so this package init deliberately pulls in only the cycle-free halves;
import ``repro.obs.report`` directly (it is not re-exported here).
"""

from .live import (  # noqa: F401
    LatencySketch,
    LiveAggregator,
    LiveReport,
    LiveSample,
    SLOConfig,
    SLOEvent,
    SLOWatchdog,
    TrendLine,
)
from .metrics import (  # noqa: F401
    LATENCY_BUCKET_EDGES,
    NUM_LATENCY_BUCKETS,
    MetricsBlock,
    MetricsCarry,
    ObsMetrics,
    bucket_label,
    delta_metrics_block,
    init_metrics_carry,
    latency_bucket,
    latency_bucket_np,
    latency_histogram_np,
    merge_metrics_blocks,
    migrate_dense_metrics,
    obs_from_carry,
    obs_from_final,
    pad_metrics,
    percentile_from_hist,
    resume_metrics_carry,
    rotate_metrics,
    snapshot_metrics,
    update_metrics,
    zero_metrics_block,
)
from .tracer import (  # noqa: F401
    CounterSample,
    InstantEvent,
    Span,
    SpanTracer,
    current_tracer,
    obs_begin,
    obs_end,
    obs_span,
    tracing,
)
