"""End-to-end training driver (runs for real on CPU with reduced configs).

Two execution modes:

* ``pjit``  — the production path: build_train_step's fully-sharded step
  (FSDP over 'data', TP over 'model', DP over 'pod'); gradient sync is
  GSPMD-inserted.
* ``ddp``   — pure data-parallel with an EXPLICIT cross-pod gradient sync
  so the PICSOU schedule is exercised end to end:
  ``--sync picsou`` (RS -> pod-AR -> AG, one DCN copy per shard) vs
  ``--sync ata`` (flat all-reduce). ``--compress`` adds int8 error-feedback
  on the slow segment. Both modes produce the same losses (tested).

Checkpoint/restart: --ckpt-dir enables async QUACK-replicated snapshots;
--restore resumes from the latest committed step (the data pipeline is
deterministic in (step, shard), so the loss curve continues exactly).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b-smoke \
      --steps 30 --mesh 1x2x2 --mode ddp --sync picsou --ckpt-dir /tmp/ck
"""

import argparse
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..checkpoint import CheckpointManager, restore_tree
from ..configs import get_config
from ..configs.base import ShapeSpec
from ..crosspod import (ata_cross_pod_sync, ef_int8_compress,
                        ef_int8_decompress, make_ef_state,
                        picsou_cross_pod_sync)
from ..data import SyntheticTokens
from ..models import init_model, loss_fn
from ..optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from .mesh import make_mesh
from .steps import build_train_step


def parse_mesh(s: str):
    dims = [int(x) for x in s.split("x")]
    if len(dims) == 3:
        return make_mesh(dims, ("pod", "data", "model"))
    return make_mesh(dims, ("data", "model"))


def run(args):
    cfg = get_config(args.arch)
    mesh = parse_mesh(args.mesh)
    shape = ShapeSpec("train", args.seq, args.batch, "train")
    data = SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch, seed=17)
    rng = jax.random.PRNGKey(args.seed)

    params = init_model(cfg, rng)
    opt = adamw_init(params)
    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, n_shards=4)
        if args.restore:
            (params, opt), start_step = restore_tree((params, opt),
                                                     args.ckpt_dir)
            start_step += 1
            print(f"restored checkpoint, resuming at step {start_step}")

    opt_cfg = AdamWConfig(lr=args.lr)
    if args.mode == "pjit":
        bundle = build_train_step(cfg, mesh, shape, opt_cfg=opt_cfg)
        step_fn = bundle
        params = jax.device_put(params, bundle.in_shardings[0])
        opt = jax.device_put(opt, bundle.in_shardings[1])

        def one_step(params, opt, batch):
            batch = jax.device_put(batch, bundle.in_shardings[2])
            return step_fn(params, opt, batch)
    else:
        ocfg = opt_cfg
        rep = NamedSharding(mesh, P())
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        bsh = {"tokens": NamedSharding(mesh, P(batch_axes, None))}
        sync = (picsou_cross_pod_sync if args.sync == "picsou"
                else ata_cross_pod_sync)
        bspec = P(batch_axes, None)

        @jax.jit
        def ddp_step(params, opt, batch, ef):
            def local_loss(p, b):
                return loss_fn(p, cfg, b)
            (loss, metrics), grads = jax.value_and_grad(
                local_loss, has_aux=True)(params, batch)
            if args.compress and "pod" in mesh.shape:
                flat, treedef = jax.tree_util.tree_flatten(grads)
                ef_flat = treedef.flatten_up_to(ef)
                outs, new_ef = [], []
                for g, e in zip(flat, ef_flat):
                    packed, ne = ef_int8_compress(g, e)
                    outs.append(ef_int8_decompress(packed, g.shape)
                                .astype(g.dtype))
                    new_ef.append(ne)
                grads = jax.tree_util.tree_unflatten(treedef, outs)
                ef = jax.tree_util.tree_unflatten(treedef, new_ef)
            grads = sync(grads, mesh, in_specs=P())
            lr = cosine_schedule(opt.step, 10, args.steps * 10)
            params, opt = adamw_update(ocfg, grads, params, opt, lr)
            return params, opt, metrics, ef

        ef = make_ef_state(params) if args.compress else params
        params = jax.device_put(params, rep)
        opt = jax.device_put(opt, rep)

        def one_step(params, opt, batch):
            nonlocal ef
            batch = {k: jax.device_put(v, bsh["tokens"])
                     for k, v in batch.items()}
            params, opt, metrics, ef = ddp_step(params, opt, batch, ef)
            return params, opt, metrics

    losses = []
    for step in range(start_step, start_step + args.steps):
        batch = data.batch_at(step)
        t0 = time.time()
        params, opt, metrics = one_step(params, opt, batch)
        ce = float(metrics["ce"])
        losses.append(ce)
        print(f"step {step:4d} ce={ce:7.4f} "
              f"({time.time() - t0:5.2f}s)")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step, (params, opt))
    if mgr:
        mgr.wait()
        mgr.close()
    # basic sanity: loss must decrease on synthetic data
    if len(losses) >= 10:
        print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"(improved={losses[-1] < losses[0]})")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b-smoke")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default="2x2")
    ap.add_argument("--mode", default="pjit", choices=["pjit", "ddp"])
    ap.add_argument("--sync", default="picsou", choices=["picsou", "ata"])
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    run(args)


if __name__ == "__main__":
    main()
