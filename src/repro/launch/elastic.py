"""Elastic scaling: membership changes + stake re-apportionment.

The paper assumes periodic reconfigurations with a reliable mechanism to
learn the new configuration (§2.1). At fleet scale that mechanism is the
job scheduler; what PICSOU contributes is *how to re-balance work* when
the membership or relative capacity ("stake") changes:

* on pod loss: rebuild the mesh on the surviving pods, restore the last
  committed (QUACK-durable) checkpoint, and resume — the deterministic
  data pipeline replays the exact step stream;
* on host capacity skew: re-run Hamilton apportionment over measured
  throughput so send quotas track capacity (§5.2 DSS), with LCM rescaling
  when pods have incommensurate totals (§5.3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.scheduler import hamilton_apportion
from ..core.types import lcm_scale_factors

__all__ = ["ElasticPlan", "replan_membership", "replan_quotas"]


@dataclasses.dataclass
class ElasticPlan:
    n_pods: int
    hosts_per_pod: int
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    send_quota: Dict[int, int]
    restore_step: Optional[int]


def replan_membership(alive_pods: List[int], hosts_per_pod: int,
                      data_parallel: int, model_parallel: int,
                      last_committed_step: Optional[int]) -> ElasticPlan:
    """Rebuild the mesh over surviving pods; fewer pods = less DP, same
    model sharding (the per-pod submesh is unchanged, so parameter shards
    stay valid and only the data-parallel degree changes)."""
    n = len(alive_pods)
    if n < 1:
        raise RuntimeError("no pods left")
    if n == 1:
        shape: Tuple[int, ...] = (data_parallel, model_parallel)
        axes: Tuple[str, ...] = ("data", "model")
    else:
        shape = (n, data_parallel, model_parallel)
        axes = ("pod", "data", "model")
    return ElasticPlan(n_pods=n, hosts_per_pod=hosts_per_pod,
                       mesh_shape=shape, mesh_axes=axes, send_quota={},
                       restore_step=last_committed_step)


def replan_quotas(host_throughput: np.ndarray, quantum: int,
                  peer_total_stake: Optional[float] = None
                  ) -> Dict[int, int]:
    """DSS re-apportionment of cross-pod send quotas (§5.2/§5.3)."""
    tp = np.asarray(host_throughput, dtype=np.float64)
    if peer_total_stake is not None and peer_total_stake > 0:
        psi, _ = lcm_scale_factors(tp.sum(), peer_total_stake)
        tp = tp * psi
    counts = hamilton_apportion(tp, quantum)
    return {h: int(c) for h, c in enumerate(counts)}
