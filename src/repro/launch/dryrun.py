import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count on first init) — which is why this module sets XLA_FLAGS at the very
top and why nothing else in the package sets it globally.

For every cell we:
  1. build the step function (train_step / prefill / serve_step),
  2. resolve in/out shardings from the logical rules,
  3. ``.lower().compile()`` against ShapeDtypeStructs (no allocation),
  4. print ``compiled.memory_analysis()`` (proves it fits) and
     ``compiled.cost_analysis()`` (FLOPs/bytes for the roofline),
  5. parse collective wire bytes from the optimized HLO,
  6. append one JSON record to the results file.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch all
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --arch qwen2-72b \
      --shape train_4k --impl triangular
"""

import argparse
import json
import time
import traceback



def apply_opts(cfg, opts: str):
    """Apply §Perf levers: 'moe2d', 'rwkvblock=16', 'noremat'."""
    import dataclasses
    for opt in filter(None, (opts or "").split(",")):
        if opt == "moe2d":
            cfg = dataclasses.replace(cfg, moe_dispatch_2d=True)
        elif opt.startswith("rwkvblock="):
            cfg = dataclasses.replace(cfg,
                                      rwkv_scan_block=int(opt.split("=")[1]))
        elif opt == "noremat":
            cfg = dataclasses.replace(cfg, remat=False)
        elif opt == "rematdots":
            cfg = dataclasses.replace(cfg, remat_policy="dots")
        elif opt == "moedense":
            cfg = dataclasses.replace(cfg, moe_impl="dense")
        else:
            raise ValueError(f"unknown opt {opt!r}")
    return cfg


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             impl=None, out_path=None, verbose=True, extra_tag="",
             opts: str = ""):
    from ..configs import SHAPES, get_config, shape_applicable
    from ..roofline import analyze_compiled
    from . import steps as S
    from .mesh import make_production_mesh

    cfg = apply_opts(get_config(arch), opts)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "impl": impl or "scan", "tag": extra_tag}
    if not ok:
        rec.update(status="SKIP", reason=why)
        _emit(rec, out_path, verbose)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    t0 = time.time()
    try:
        with mesh:
            bundle = S.build_step(cfg, mesh, shape, impl=impl)
            lowered = bundle.lower()
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            hlo = compiled.as_text()
            rep = analyze_compiled(compiled, cfg, shape, mesh_kind, n_chips,
                                   hlo_text=hlo)
        rec.update(
            status="OK", lower_s=round(t1 - t0, 1),
            compile_s=round(t2 - t1, 1),
            hlo_flops_per_chip=rep.hlo_flops_per_chip,
            hlo_bytes_per_chip=rep.hlo_bytes_per_chip,
            wire_bytes_per_chip=rep.wire_bytes_per_chip,
            model_flops_total=rep.model_flops_total,
            compute_s=rep.compute_s, memory_s=rep.memory_s,
            collective_s=rep.collective_s, bottleneck=rep.bottleneck,
            useful_ratio=rep.useful_ratio,
            collectives={k: v for k, v in rep.collective_breakdown.items()
                         if v},
            memory_analysis=rep.memory_analysis[:2000],
        )
        if verbose:
            print(f"--- {arch} x {shape_name} x {mesh_kind} "
                  f"({rec['impl']}) ---")
            print("memory_analysis:", rep.memory_analysis[:400])
            print(f"cost: flops/chip={rep.hlo_flops_per_chip:.3e} "
                  f"bytes/chip={rep.hlo_bytes_per_chip:.3e} "
                  f"wire/chip={rep.wire_bytes_per_chip:.3e}")
            print(f"roofline: compute={rep.compute_s:.4f}s "
                  f"memory={rep.memory_s:.4f}s "
                  f"collective={rep.collective_s:.4f}s "
                  f"-> {rep.bottleneck}-bound "
                  f"(useful={rep.useful_ratio:.2f})")
    except Exception as e:  # noqa: BLE001
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"--- {arch} x {shape_name} x {mesh_kind} FAILED: {e}")
    _emit(rec, out_path, verbose=False)
    return rec


def _emit(rec, out_path, verbose):
    if verbose:
        print(json.dumps({k: v for k, v in rec.items()
                          if k not in ("memory_analysis", "trace")}))
    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")


def main():
    from ..configs import SHAPES, list_configs

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--impl", default=None,
                    choices=[None, "scan", "triangular"])
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--tag", default="")
    ap.add_argument("--opt", default="",
                    help="comma list: moe2d, rwkvblock=N, noremat")
    args = ap.parse_args()

    archs = list_configs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    done = set()
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("OK", "SKIP"):
                        done.add((r["arch"], r["shape"], r["mesh"],
                                  r.get("impl", "scan"), r.get("tag", "")))
                except json.JSONDecodeError:
                    pass

    n_fail = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                key = (arch, shape, mesh_kind, args.impl or "scan", args.tag)
                if key in done:
                    print(f"skip (cached): {key}")
                    continue
                rec = run_cell(arch, shape, mesh_kind, impl=args.impl,
                               out_path=args.out, extra_tag=args.tag,
                               opts=args.opt)
                n_fail += rec["status"] == "FAIL"
    print(f"dry-run complete; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
