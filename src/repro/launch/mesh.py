"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips, axes
(data, model). Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model) —
the 'pod' axis crosses the slow inter-pod links and is where the PICSOU
cross-pod schedule applies (see repro.crosspod).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_mesh", "small_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — the dry-run entry "
            "point must set XLA_FLAGS=--xla_force_host_platform_device_"
            "count before any jax import")
    dev = np.asarray(devices[:n]).reshape(tuple(shape))
    return Mesh(dev, tuple(axes))


def small_mesh(data: int = 2, model: int = 2,
               pod: Optional[int] = None) -> Mesh:
    """Reduced mesh for CPU tests (requires >= data*model*pod devices)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
