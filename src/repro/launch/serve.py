"""Batched decode serving driver (CPU demo with reduced configs).

Prefills a batch of prompts, then decodes tokens step by step with the
ring-buffer KV caches; prints per-step latency and tokens/s.

  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b-smoke \
      --batch 4 --prompt-len 32 --gen 16 --mesh 2x2
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data import SyntheticTokens
from ..models import decode_step, init_model, prefill
from .train import parse_mesh


def run(args):
    cfg = get_config(args.arch)
    mesh = parse_mesh(args.mesh)
    rng = jax.random.PRNGKey(args.seed)
    params = init_model(cfg, rng)
    data = SyntheticTokens(vocab=cfg.vocab, seq_len=args.prompt_len,
                           global_batch=args.batch, seed=3)
    prompts = jnp.asarray(data.batch_at(0)["tokens"])
    memory = None
    if cfg.family == "encdec":
        memory = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model),
                           jnp.float32)
    if cfg.family == "vlm":
        memory = jnp.zeros((args.batch, cfg.vision_seq, cfg.d_model),
                           jnp.float32)

    cache_len = args.prompt_len + args.gen
    t0 = time.time()
    logits, caches = prefill(params, cfg, prompts, memory=memory,
                             cache_len=cache_len)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    print(f"prefill: {time.time() - t0:.2f}s for "
          f"{args.batch}x{args.prompt_len}")

    fn = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    out_tokens = [tok]
    times = []
    for i in range(args.gen):
        t0 = time.time()
        logits, caches = fn(params, caches, tok,
                            jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
        times.append(time.time() - t0)
        out_tokens.append(tok)
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    steady = times[1:] or times
    print(f"decode: {np.mean(steady) * 1e3:.1f} ms/step, "
          f"{args.batch / np.mean(steady):.1f} tok/s aggregate")
    print("sample:", gen[0][:12].tolist())
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="2x2")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args)


if __name__ == "__main__":
    main()
