"""Launch layer: mesh factory, step builders, dry-run, train/serve drivers."""
