"""Step builders: jitted train/prefill/decode steps with explicit shardings.

Everything here works from ShapeDtypeStructs, so the dry-run can lower and
compile each (arch x shape x mesh) cell without allocating a single real
tensor; the same builders drive the real CPU training example with
materialized params.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from ..models import model as M
from ..models.sharding import (DEFAULT_RULES, activation_sharding,
                               sharding_for)
from ..optim import (AdamWConfig, adamw_update, cosine_schedule,
                     opt_state_specs)

__all__ = ["rules_for", "param_shardings", "build_train_step",
           "build_prefill_step", "build_decode_step", "StepBundle"]


def rules_for(cfg: ModelConfig, mesh: Mesh) -> Dict[str, Any]:
    """Divisibility-aware rule selection (see DESIGN.md §4).

    When KV heads cannot shard over 'model' (e.g. qwen2 kv=8 on a 16-way
    axis) the KV-cache sequence axis takes the sharding instead.
    """
    rules = dict(DEFAULT_RULES)
    model_size = mesh.shape.get("model", 1)
    if model_size > 1 and cfg.n_kv_heads % model_size != 0:
        rules["cache_seq"] = "model"
    return rules


def param_shardings(cfg: ModelConfig, mesh: Mesh, rules=None):
    shapes, names = M.param_specs(cfg)
    rules = rules or rules_for(cfg, mesh)
    return jax.tree_util.tree_map(
        lambda s, n: sharding_for(mesh, n, s.shape, rules),
        shapes, names,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)), shapes


def _shardings_from(mesh, shapes, names, rules):
    return jax.tree_util.tree_map(
        lambda s, n: sharding_for(mesh, n, s.shape, rules),
        shapes, names,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@dataclasses.dataclass
class StepBundle:
    """A jittable step with its sharded input/output declarations."""

    fn: Any                     # the jitted function
    in_shapes: Tuple[Any, ...]  # ShapeDtypeStruct trees (lower(*in_shapes))
    in_shardings: Tuple[Any, ...]
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, Any]] = None

    def lower(self):
        with activation_sharding(self.mesh, self.rules):
            return self.fn.lower(*self.in_shapes)

    def __call__(self, *args):
        with activation_sharding(self.mesh, self.rules):
            return self.fn(*args)


def build_train_step(cfg: ModelConfig, mesh: Mesh,
                     shape: ShapeSpec,
                     opt_cfg: AdamWConfig = AdamWConfig(),
                     impl: Optional[str] = None,
                     warmup: int = 100, total_steps: int = 10_000
                     ) -> StepBundle:
    rules = rules_for(cfg, mesh)
    p_shard, p_shapes = param_shardings(cfg, mesh, rules)
    _, p_names = M.param_specs(cfg)
    o_shapes, o_names = opt_state_specs(p_shapes, p_names)
    o_shard = _shardings_from(mesh, o_shapes, o_names, rules)
    b_shapes_d, b_names = M.input_specs(cfg, shape)
    b_shard = _shardings_from(mesh, b_shapes_d, b_names, rules)
    rep = NamedSharding(mesh, P())

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            M.loss_fn, has_aux=True)(params, cfg, batch, impl=impl)
        lr_scale = cosine_schedule(opt_state.step, warmup, total_steps)
        params, opt_state = adamw_update(opt_cfg, grads, params, opt_state,
                                         lr_scale)
        out_metrics = {"loss": loss, **metrics}
        return params, opt_state, out_metrics

    metric_shard = {"loss": rep, "ce": rep, "aux": rep}
    fn = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, metric_shard),
        donate_argnums=(0, 1),
    )
    return StepBundle(fn=fn, in_shapes=(p_shapes, o_shapes, b_shapes_d),
                      in_shardings=(p_shard, o_shard, b_shard),
                      mesh=mesh, rules=rules)


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec,
                       impl: Optional[str] = None) -> StepBundle:
    rules = rules_for(cfg, mesh)
    p_shard, p_shapes = param_shardings(cfg, mesh, rules)
    b_shapes, b_names = M.input_specs(cfg, shape)
    b_shard = _shardings_from(mesh, b_shapes, b_names, rules)

    def prefill_step(params, batch):
        memory = batch.get("frames", batch.get("memory"))
        return M.prefill(params, cfg, batch["tokens"], memory=memory,
                         impl=impl)

    fn = jax.jit(prefill_step, in_shardings=(p_shard, b_shard))
    return StepBundle(fn=fn, in_shapes=(p_shapes, b_shapes),
                      in_shardings=(p_shard, b_shard), mesh=mesh,
                      rules=rules)


def build_decode_step(cfg: ModelConfig, mesh: Mesh,
                      shape: ShapeSpec) -> StepBundle:
    rules = rules_for(cfg, mesh)
    p_shard, p_shapes = param_shardings(cfg, mesh, rules)
    b_shapes, b_names = M.input_specs(cfg, shape)
    b_shard = _shardings_from(mesh, b_shapes, b_names, rules)

    def serve_step(params, caches, token, pos):
        return M.decode_step(params, cfg, caches, token, pos)

    fn = jax.jit(
        serve_step,
        in_shardings=(p_shard, b_shard["caches"], b_shard["token"],
                      b_shard["pos"]),
        donate_argnums=(1,),
    )
    return StepBundle(
        fn=fn,
        in_shapes=(p_shapes, b_shapes["caches"], b_shapes["token"],
                   b_shapes["pos"]),
        in_shardings=(p_shard, b_shard["caches"], b_shard["token"],
                      b_shard["pos"]),
        mesh=mesh, rules=rules)


def build_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec,
               impl: Optional[str] = None) -> StepBundle:
    """Dispatch on the shape kind: train_step / prefill / serve_step."""
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, impl=impl)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, impl=impl)
    return build_decode_step(cfg, mesh, shape)
