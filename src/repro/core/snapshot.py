"""Shared host<->device materialization of windowed scan state.

One home for the window-layout invariants that used to be spread over
private helpers in ``core/simulator.py`` (``_np_state`` / grow-padding /
dense-migration padding): which ``SimState`` fields are window-indexed,
what a *fresh* (never-touched) slot looks like, and how to move a whole
state tree between host (numpy) and device (jnp) or between window
widths. ``repro.replay`` uses the same utilities to capture chunk-
boundary checkpoints, serialize them (``state_to_arrays`` /
``state_from_arrays``) and push them back onto the device for resume —
so a checkpointed state can never drift from what the simulator
actually carries.

Everything here operates structurally on ``NamedTuple`` state trees
(``_fields`` / ``_replace``), so this module depends on neither the
simulator nor jax tracing internals.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["WINDOW_FILLS", "window_shapes", "host_state", "device_state",
           "pad_window", "state_to_arrays", "state_from_arrays"]

# window-indexed SimState fields -> neutral fill for a fresh slot. The
# single source of truth for state init, in-graph rotation refills,
# adaptive growth and dense-layout migration, so the constructors cannot
# drift when a field is added (a wrong tail fill would compile fine and
# corrupt only long/adversarial runs).
WINDOW_FILLS = dict(recv_has=False, bcast_q=False, bcast_done=False,
                    orig_sent=False, known=False, complaint=False,
                    repeat_c=False, retry=0, quack_time=-1, deliver_time=-1)


def window_shapes(n_s: int, n_r: int, w: int) -> dict:
    """Window-indexed SimState field -> shape at window width ``w``."""
    return dict(recv_has=(n_r, w), bcast_q=(n_r, w), bcast_done=(n_r, w),
                orig_sent=(w,), known=(n_s, n_r, w),
                complaint=(n_s, n_r, w), repeat_c=(n_s, n_r, w),
                retry=(n_s, w), quack_time=(n_s, w), deliver_time=(w,))


def host_state(state):
    """Materialize a (possibly device-resident) state tree as numpy.

    Goes through ``jax.device_get`` — the sanctioned d2h route: one
    batched fetch for the whole tree, and the analysis sanitizer
    (``repro.analysis``) treats it as an *explicit* transfer, where a
    per-leaf ``np.asarray`` would be flagged as an implicit one.
    """
    return jax.device_get(state)


def device_state(state):
    """Push a host-side state tree back onto the device (exact: every
    leaf is int32/bool, so the round-trip is bit-preserving)."""
    return jax.tree_util.tree_map(jnp.asarray, state)


def pad_window(state, new_w: int):
    """Migrate scan state to a wider window, preserving live columns.

    Window-indexed arrays gain fresh-fill tail slots; per-replica state,
    ``base`` and leading (batch) axes are untouched, so the migrated
    state resumes the identical protocol at the wider width. Works on
    host (numpy) and device (jnp) trees alike.
    """
    w = state.deliver_time.shape[-1]

    def pad(a, fill):
        a = jnp.asarray(a)
        ext = jnp.full(a.shape[:-1] + (new_w - w,), fill, dtype=a.dtype)
        return jnp.concatenate([a, ext], axis=-1)

    return state._replace(
        **{name: pad(getattr(state, name), fill)
           for name, fill in WINDOW_FILLS.items()})


def state_to_arrays(state, prefix: str = "") -> Dict[str, np.ndarray]:
    """Flatten a state NamedTuple into named numpy arrays (npz-ready)."""
    return {f"{prefix}{name}": np.asarray(getattr(state, name))
            for name in state._fields}


def state_from_arrays(cls, arrays: Dict[str, np.ndarray],
                      prefix: str = "", defaults=None):
    """Rebuild a state NamedTuple of type ``cls`` from named arrays.

    ``defaults`` maps field name -> array for fields absent from
    ``arrays`` — the forward-compat shim for loading traces written
    before a state field existed (e.g. pre-adversary-palette
    ``FailArrays`` without the traced stakes/thresholds). A field
    missing from both is a hard ``KeyError``: silently zero-filling
    protocol state would corrupt a resume.
    """
    defaults = defaults or {}

    def get(name):
        key = f"{prefix}{name}"
        if key in arrays:
            return np.asarray(arrays[key])
        return np.asarray(defaults[name])

    return cls(**{name: get(name) for name in cls._fields})
