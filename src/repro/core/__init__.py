"""PICSOU / C3B protocol core — the paper's contribution.

Public API:

    from repro.core import (RSMConfig, NetworkModel, SimConfig,
                            FailureScenario, run_picsou,
                            analytic_throughput)

    run = run_picsou(RSMConfig.bft(1), RSMConfig.bft(1))
    assert run.all_delivered and run.cross_copies_per_msg < 1.01
"""

from .gc import (ack_floor_from_reports, collectable, default_window_slots,
                 gc_frontier, gc_frontier_device, grow_window,
                 resolve_window_slots)
from .protocols import (C3BRun, analytic_throughput, ata_loads, ost_loads,
                        picsou_loads, run_picsou, run_picsou_batch)
from .quack import (claim_bitmask, cumulative_ack, missing_below_horizon,
                    selective_quack, weighted_quorum_prefix)
from .retransmit import (declared_lost, elect_retransmitter,
                         faulty_pair_bound, max_retransmissions,
                         theorem1_resends)
from .scheduler import (dss_sequence, hamilton_apportion, lottery_sequence,
                        round_robin_sequence, sender_assignment,
                        skewed_rr_sequence)
from .simulator import (FailArrays, SimResult, SimSpec, build_spec,
                        require_uniform_batch, run_simulation,
                        run_simulation_batch)
from .types import (FailureScenario, NetworkModel, RSMConfig, SimConfig,
                    lcm_scale_factors)

__all__ = [
    "RSMConfig", "NetworkModel", "SimConfig", "FailureScenario",
    "SimSpec", "SimResult", "FailArrays", "build_spec", "run_simulation",
    "run_simulation_batch", "require_uniform_batch",
    "default_window_slots", "gc_frontier",
    "gc_frontier_device", "grow_window", "resolve_window_slots",
    "C3BRun", "run_picsou", "run_picsou_batch", "analytic_throughput",
    "picsou_loads", "ata_loads", "ost_loads",
    "cumulative_ack", "claim_bitmask", "missing_below_horizon",
    "weighted_quorum_prefix", "selective_quack",
    "elect_retransmitter", "declared_lost", "max_retransmissions",
    "faulty_pair_bound", "theorem1_resends",
    "hamilton_apportion", "dss_sequence", "skewed_rr_sequence",
    "lottery_sequence", "round_robin_sequence", "sender_assignment",
    "collectable", "ack_floor_from_reports", "lcm_scale_factors",
]
