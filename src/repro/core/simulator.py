"""Vectorized PICSOU simulator — windowed streaming core (``jax.lax.scan``).

The simulator executes the *full* protocol of §4–§5 — round-robin / DSS
send scheduling, receiver rotation, intra-RSM broadcast, cumulative +
phi-list acknowledgements, QUACK formation, duplicate-complaint loss
detection, communication-free retransmitter election, GC with the
highest-quacked metadata defence, stake weighting and LCM-scaled
retransmission rotation — as array state transitions, one scan step per
synchronous round (one cross-RSM RTT).

Per-message state lives in a **sliding window**: each message-indexed array
holds ``W = spec.window_slots`` columns covering absolute sequence numbers
``[base, base + W)``. The run is split into compiled chunks of
``spec.chunk_steps`` rounds; between chunks the host advances ``base`` past
the GC frontier (``gc.gc_frontier`` — the prefix both sides may forget,
§4.3), streaming the retired columns' quack/deliver/retry/recv outputs into
host buffers and refilling the tail with fresh slots. Failure-free, the
frontier tracks the stream, so device state and compile time are O(W) —
*independent of the stream length M* — which is exactly the paper's P1
constant-metadata invariant applied to the simulator itself. The dense path
(``window_slots == 0``) is the same step function instantiated at
``base=0, W=M`` with no rotation, and the two are bit-identical wherever
the window is wide enough to hold every in-flight message
(``tests/test_windowed.py``).

Semantics of a round ``t`` (matching Figure 3/4/5/6 of the paper):
  1. intra-RSM broadcasts queued at t-1 land;
  2. retransmissions are declared/elected from knowledge as of t-1 and the
     corresponding resends are put on the wire;
  3. scheduled original sends for round t are put on the wire; direct sends
     land at their receiver (unless dropped) and queue a broadcast;
  4. every alive receiver acks (cumulative counter + phi-list + implicit
     duplicate-cum complaint) to its rotating target sender; senders fold
     the ack into their knowledge; QUACK / GC state advances.

Failure masks are traced inputs (``FailArrays``), not compile-time
constants, so one compilation serves every failure scenario of a given
shape — and ``run_simulation_batch`` ``jax.vmap``s the same step over a
stack of scenarios for one-compilation sweeps.

The pure-python oracle in ``refsim.py`` mirrors this loop (including the
GC-frontier trajectory) unvectorized; ``tests/test_simulator.py`` and
``tests/test_windowed.py`` cross-check them step by step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import scheduler as sched
from .gc import default_window_slots, gc_frontier
from .quack import claim_bitmask, missing_below_horizon, weighted_quorum_prefix
from .types import (COUNTER_BYTES, MAC_BYTES, SEQNO_BYTES, FailureScenario,
                    NetworkModel, RSMConfig, SimConfig, lcm_scale_factors)

__all__ = ["SimSpec", "SimResult", "FailArrays", "build_spec",
           "run_simulation", "run_simulation_batch"]

NEVER = jnp.int32(-1)
_NEVER_STEP = 2 ** 30     # orig_step pad for window slots beyond the stream
_BIG = jnp.int32(2 ** 30)


@dataclasses.dataclass(frozen=True)
class SimSpec:
    """Fully-resolved, static simulation plan (hashable closure inputs)."""

    n_s: int
    n_r: int
    m: int
    steps: int
    phi: int
    quack_thresh: float      # u_r + 1 (stake units)
    dup_thresh: float        # r_r + 1 (stake units); 1 in CFT mode
    hq_thresh: float         # r_s + 1 (stake units)
    stakes_s: Tuple[float, ...]
    stakes_r: Tuple[float, ...]
    orig_sender: Tuple[int, ...]      # (M,)
    orig_recv: Tuple[int, ...]        # (M,)
    orig_step: Tuple[int, ...]        # (M,) dispatch round of original send
    rs_seq: Tuple[int, ...]           # retransmit sender rotation sequence
    rr_seq: Tuple[int, ...]           # retransmit receiver rotation sequence
    crash_s: Tuple[int, ...]
    crash_r: Tuple[int, ...]
    byz_send_drop: Tuple[bool, ...]
    byz_recv_drop: Tuple[bool, ...]
    byz_ack_advance: Tuple[int, ...]
    byz_ack_low: Tuple[bool, ...]
    byz_bcast_partial: Tuple[bool, ...]
    bcast_limit: int
    window_slots: int = 0             # 0 => dense (full-M) state
    chunk_steps: int = 0              # rounds per compiled chunk (windowed)

    def scan_state_nbytes(self) -> int:
        """Device bytes of the per-round scan state (the P1 footprint)."""
        w = self.window_slots or self.m
        n_s, n_r = self.n_s, self.n_r
        return (3 * n_r * w                # recv_has / bcast_q / bcast_done
                + 3 * n_s * n_r * w        # known / complaint / repeat_c
                + 4 * (n_s * n_r           # last_cum
                       + 2 * n_s * w       # retry / quack_time
                       + w                 # deliver_time
                       + n_r * n_s + n_r   # hq_reports / ack_floor
                       + 2))               # base / retired_delivered


class FailArrays(NamedTuple):
    """Failure masks as traced device arrays (one compile per *shape*)."""

    crash_s: jnp.ndarray           # (n_s,) int32, -1 = never
    crash_r: jnp.ndarray           # (n_r,) int32
    byz_send_drop: jnp.ndarray     # (n_s,) bool
    byz_recv_drop: jnp.ndarray     # (n_r,) bool
    byz_ack_advance: jnp.ndarray   # (n_r,) int32
    byz_ack_low: jnp.ndarray       # (n_r,) bool
    byz_bcast_partial: jnp.ndarray  # (n_r,) bool
    bcast_limit: jnp.ndarray       # () int32


class SimState(NamedTuple):
    recv_has: jnp.ndarray      # (n_r, W) bool — receiver truly holds slot
    bcast_q: jnp.ndarray       # (n_r, W) bool — queued broadcast for t+1
    bcast_done: jnp.ndarray    # (n_r, W) bool
    known: jnp.ndarray         # (n_s, n_r, W) bool — j's claims known to l
    complaint: jnp.ndarray     # (n_s, n_r, W) bool — j's last complaint to l
    repeat_c: jnp.ndarray      # (n_s, n_r, W) bool — complained twice to l
    last_cum: jnp.ndarray      # (n_s, n_r) int32 (absolute counts)
    retry: jnp.ndarray         # (n_s, W) int32
    quack_time: jnp.ndarray    # (n_s, W) int32, -1 = not yet
    deliver_time: jnp.ndarray  # (W,) int32, -1 = not yet
    hq_reports: jnp.ndarray    # (n_r, n_s) int32 (absolute seqnos)
    ack_floor: jnp.ndarray     # (n_r,) int32 (absolute counts)
    base: jnp.ndarray          # () int32 — absolute seqno of window col 0
    retired_delivered: jnp.ndarray  # () int32 — delivered among retired


class StepMetrics(NamedTuple):
    cross_msgs: jnp.ndarray     # direct cross-RSM data copies this round
    intra_msgs: jnp.ndarray     # broadcast copies this round
    resends: jnp.ndarray        # retransmissions this round
    acks: jnp.ndarray           # ack messages this round
    delivered: jnp.ndarray      # cumulative messages delivered
    min_quack_prefix: jnp.ndarray  # min honest-sender quacked prefix


@dataclasses.dataclass
class SimResult:
    spec: SimSpec
    metrics: "np.ndarray-like"            # StepMetrics of (T,) arrays
    quack_time: np.ndarray                # (n_s, M)
    deliver_time: np.ndarray              # (M,)
    retry: np.ndarray                     # (n_s, M)
    recv_has: np.ndarray                  # (n_r, M)
    gc_frontiers: Optional[np.ndarray] = None  # window base per chunk

    # --- derived -------------------------------------------------------
    def completion_step(self) -> int:
        """Round by which every message is QUACKed at every honest sender."""
        honest = _honest_mask(self.spec.crash_s, self.spec.byz_send_drop)
        qt = self.quack_time[honest]
        if qt.size == 0 or (qt < 0).any():
            return -1
        return int(qt.max())

    def delivery_step(self) -> int:
        if (self.deliver_time < 0).any():
            return -1
        return int(self.deliver_time.max())

    def total_cross_msgs(self) -> int:
        return int(np.sum(self.metrics.cross_msgs))

    def total_intra_msgs(self) -> int:
        return int(np.sum(self.metrics.intra_msgs))

    def total_resends(self) -> int:
        return int(np.sum(self.metrics.resends))

    def max_resends_per_msg(self) -> int:
        honest = _honest_mask(self.spec.crash_s, self.spec.byz_send_drop)
        if not honest.any():
            return 0
        return int(self.retry[honest].max())


def _honest_mask(crash, byz_flags) -> np.ndarray:
    crash = np.asarray(crash)
    byz = np.asarray(byz_flags)
    return (crash < 0) & ~byz


def build_spec(sender: RSMConfig, receiver: RSMConfig,
               sim: SimConfig = SimConfig(),
               failures: FailureScenario = FailureScenario.none(),
               use_lcm_scaling: bool = True) -> SimSpec:
    """Resolve schedules + failure masks into a static SimSpec."""
    n_s, n_r, m = sender.n, receiver.n, sim.n_msgs
    st_s = np.asarray(sender.stakes, dtype=np.float64)
    st_r = np.asarray(receiver.stakes, dtype=np.float64)

    orig_sender = sched.sender_assignment(
        sim.scheduler, st_s, m, quantum=sim.quantum, seed=sim.seed)
    orig_recv = sched.receiver_for(
        orig_sender, n_r, recv_stakes=st_r, scheduler=sim.scheduler,
        quantum=sim.quantum, seed=sim.seed + 1)

    # dispatch round of each original send: the i-th message of sender l is
    # sent in round i // window (window sends per sender per round).
    orig_step = np.zeros(m, dtype=np.int64)
    counters = np.zeros(n_s, dtype=np.int64)
    for k in range(m):
        l = orig_sender[k]
        orig_step[k] = counters[l] // max(sim.window, 1)
        counters[l] += 1

    # retransmission rotation sequences (§4.2 unit-stake, §5.3 staked+LCM).
    unit_s = np.allclose(st_s, st_s[0])
    unit_r = np.allclose(st_r, st_r[0])
    if unit_s and unit_r:
        rs_seq = np.arange(n_s, dtype=np.int64)
        rr_seq = np.arange(n_r, dtype=np.int64)
    else:
        psi_s, psi_r = (lcm_scale_factors(st_s.sum(), st_r.sum())
                        if use_lcm_scaling else (1.0, 1.0))
        # quota each replica proportional to (scaled) stake, smoothed.
        q_s = max(n_s, min(4 * n_s, int(np.ceil(st_s.sum() * psi_s
                                                / max(st_s.min() * psi_s, 1)))))
        q_r = max(n_r, min(4 * n_r, int(np.ceil(st_r.sum() * psi_r
                                                / max(st_r.min() * psi_r, 1)))))
        rs_seq = sched.dss_sequence(st_s * psi_s, q_s, q_s)
        rr_seq = sched.dss_sequence(st_r * psi_r, q_r, q_r)

    def tup(x, n, default):
        if x is None:
            return tuple([default] * n)
        return tuple(x)

    ws = sim.window_slots
    if ws is None:
        w_slots = 0
    elif ws == "auto":
        w_slots = default_window_slots(n_s, n_r, sim.window, sim.phi,
                                       sim.chunk_steps)
    else:
        w_slots = int(ws)

    return SimSpec(
        n_s=n_s, n_r=n_r, m=m, steps=sim.steps, phi=sim.phi,
        quack_thresh=receiver.quack_threshold,
        dup_thresh=receiver.dup_threshold,
        hq_thresh=max(sender.r + 1, 1),
        stakes_s=tuple(float(x) for x in st_s),
        stakes_r=tuple(float(x) for x in st_r),
        orig_sender=tuple(int(x) for x in orig_sender),
        orig_recv=tuple(int(x) for x in orig_recv),
        orig_step=tuple(int(x) for x in orig_step),
        rs_seq=tuple(int(x) for x in rs_seq),
        rr_seq=tuple(int(x) for x in rr_seq),
        crash_s=tup(failures.crash_s, n_s, -1),
        crash_r=tup(failures.crash_r, n_r, -1),
        byz_send_drop=tup(failures.byz_send_drop, n_s, False),
        byz_recv_drop=tup(failures.byz_recv_drop, n_r, False),
        byz_ack_advance=tup(failures.byz_ack_advance, n_r, 0),
        byz_ack_low=tup(failures.byz_ack_low, n_r, False),
        byz_bcast_partial=tup(failures.byz_bcast_partial, n_r, False),
        bcast_limit=failures.bcast_limit,
        window_slots=w_slots,
        chunk_steps=sim.chunk_steps if w_slots else 0,
    )


def _fail_arrays(spec: SimSpec) -> FailArrays:
    return FailArrays(
        crash_s=jnp.asarray(spec.crash_s, dtype=jnp.int32),
        crash_r=jnp.asarray(spec.crash_r, dtype=jnp.int32),
        byz_send_drop=jnp.asarray(spec.byz_send_drop, dtype=bool),
        byz_recv_drop=jnp.asarray(spec.byz_recv_drop, dtype=bool),
        byz_ack_advance=jnp.asarray(spec.byz_ack_advance, dtype=jnp.int32),
        byz_ack_low=jnp.asarray(spec.byz_ack_low, dtype=bool),
        byz_bcast_partial=jnp.asarray(spec.byz_bcast_partial, dtype=bool),
        bcast_limit=jnp.int32(max(spec.bcast_limit, 0)),
    )


def _neutral(spec: SimSpec) -> SimSpec:
    """Compile-cache key: failure masks are traced, window handled apart."""
    n_s, n_r = spec.n_s, spec.n_r
    return dataclasses.replace(
        spec,
        crash_s=(-1,) * n_s, crash_r=(-1,) * n_r,
        byz_send_drop=(False,) * n_s, byz_recv_drop=(False,) * n_r,
        byz_ack_advance=(0,) * n_r, byz_ack_low=(False,) * n_r,
        byz_bcast_partial=(False,) * n_r, bcast_limit=0,
        window_slots=0, chunk_steps=0)


def _protocol_step(spec: SimSpec, fail: FailArrays, sched_w, base, w: int):
    """Per-round transition over ``w`` window columns starting at ``base``.

    ``base`` may be a python int (dense: 0) or a traced scalar (windowed);
    all sequence-number arithmetic is absolute so both instantiations run
    the identical protocol.
    """
    n_s, n_r, m = spec.n_s, spec.n_r, spec.m
    phi = spec.phi
    orig_sender, orig_recv, orig_step = sched_w

    stakes_s = jnp.asarray(spec.stakes_s, dtype=jnp.float32)
    stakes_r = jnp.asarray(spec.stakes_r, dtype=jnp.float32)
    rs_seq = jnp.asarray(spec.rs_seq, dtype=jnp.int32)
    rr_seq = jnp.asarray(spec.rr_seq, dtype=jnp.int32)
    ls, lr = len(spec.rs_seq), len(spec.rr_seq)

    abs_idx = (base + jnp.arange(w, dtype=jnp.int32)).astype(jnp.int32)
    idx_r = jnp.arange(n_r, dtype=jnp.int32)
    idx_s = jnp.arange(n_s, dtype=jnp.int32)
    honest_r = (fail.crash_r < 0) & ~(fail.byz_recv_drop | fail.byz_ack_low
                                      | (fail.byz_ack_advance > 0)
                                      | fail.byz_bcast_partial)
    honest_s = (fail.crash_s < 0) & ~fail.byz_send_drop

    # broadcast reach matrix (n_r, n_r): who hears j's intra-RSM broadcast.
    partial_reach = idx_r[None, :] < fail.bcast_limit
    reach = jnp.where(fail.byz_bcast_partial[:, None], partial_reach,
                      jnp.ones((n_r, n_r), dtype=bool))
    reach = reach & (idx_r[None, :] != idx_r[:, None])

    def step(state: SimState, t: jnp.ndarray):
        alive_s = (fail.crash_s < 0) | (t < fail.crash_s)
        alive_r = (fail.crash_r < 0) | (t < fail.crash_r)

        # (1) broadcasts queued last round land now ------------------------
        bcast_sent = state.bcast_q & alive_r[:, None]
        recv_from_bcast = jnp.einsum("jk,ji->ik", bcast_sent, reach) > 0
        recv_has = state.recv_has | (recv_from_bcast & alive_r[:, None])
        bcast_done = state.bcast_done | bcast_sent

        # (2) retransmission declaration + election (knowledge of t-1) -----
        w_complaints = jnp.einsum("ljm,j->lm",
                                  state.repeat_c.astype(jnp.float32),
                                  stakes_r)
        quacked_msg_prev = (jnp.einsum("ljm,j->lm",
                                       state.known.astype(jnp.float32),
                                       stakes_r) >= spec.quack_thresh)
        declared = ((w_complaints >= spec.dup_thresh)
                    & ~quacked_msg_prev
                    & (orig_step[None, :] < t))
        retry_new = state.retry + declared.astype(jnp.int32)
        # Fig. 6: the a-th retransmission of k is sent by the a-th successor
        # of the original sender: sender_new = (orig + #retransmit) mod n_s.
        elected = (rs_seq[(abs_idx[None, :] + retry_new) % ls]
                   == idx_s[:, None])
        resend = (declared & elected & alive_s[:, None]
                  & ~fail.byz_send_drop[:, None])
        # clear complaint trackers where a loss was declared (fresh cycle)
        complaint = jnp.where(declared[:, None, :], False, state.complaint)
        repeat_c = jnp.where(declared[:, None, :], False, state.repeat_c)
        re_target = rr_seq[(orig_recv[None, :] + retry_new) % lr]  # (n_s, W)

        # (3) original sends + landing --------------------------------------
        orig_ok = ((orig_step == t) & alive_s[orig_sender]
                   & ~fail.byz_send_drop[orig_sender])
        s_orig = orig_ok[None, :] & (orig_recv[None, :] == idx_r[:, None])
        s_re = (jnp.einsum("lm,lim->im", resend.astype(jnp.int32),
                           (re_target[:, None, :] == idx_r[None, :, None])
                           .astype(jnp.int32)) > 0)
        wire = s_orig | s_re                                   # (n_r, W)
        land = wire & alive_r[:, None] & ~fail.byz_recv_drop[:, None]
        recv_has = recv_has | land
        bcast_q = land & ~bcast_done
        deliver_now = (recv_has & honest_r[:, None]).any(axis=0)
        deliver_time = jnp.where((state.deliver_time < 0) & deliver_now,
                                 t, state.deliver_time)

        # (3b) highest-quacked metadata rides on every landed data message:
        # a sender's current quacked prefix reaches every receiver it sent
        # anything to this round (constant-size piggyback, §4.3). Window
        # slots below `base` are all-quacked by the retirement rule, so the
        # absolute prefix is base + the in-window prefix.
        qp_prev = base + jnp.sum(
            jnp.cumprod(quacked_msg_prev.astype(jnp.int32), axis=1), axis=1)
        e_lk = ((orig_sender[None, :] == idx_s[:, None])
                & orig_ok[None, :])                            # (n_s, W)
        sent_orig_to = jnp.einsum("lk,ik->li", e_lk.astype(jnp.int32),
                                  s_orig.astype(jnp.int32)) > 0
        sent_re_to = jnp.einsum(
            "lm,lim->li", resend.astype(jnp.int32),
            (re_target[:, None, :] == idx_r[None, :, None]).astype(jnp.int32)
        ) > 0
        heard = (sent_orig_to | sent_re_to).T                  # (n_r, n_s)
        hq_new = jnp.where(heard & alive_r[:, None], qp_prev[None, :], 0)
        hq_reports = jnp.maximum(state.hq_reports, hq_new.astype(jnp.int32))

        # (4) acknowledgements ---------------------------------------------
        ack_floor = weighted_quorum_prefix(hq_reports, stakes_s,
                                           spec.hq_thresh)
        ack_floor = jnp.maximum(state.ack_floor, ack_floor)
        eff = recv_has | (abs_idx[None, :] < ack_floor[:, None])
        cum, claim, _known_mask = claim_bitmask(eff, phi, base, m)
        miss = missing_below_horizon(eff, phi, base)
        # Byzantine lies --------------------------------------------------
        cum = jnp.where(fail.byz_ack_low, 0, cum)
        cum = jnp.where(fail.byz_ack_advance > 0,
                        jnp.minimum(cum + fail.byz_ack_advance, m), cum)
        claim = jnp.where(fail.byz_ack_low[:, None], False, claim)
        claim = jnp.where((fail.byz_ack_advance > 0)[:, None],
                          abs_idx[None, :] < cum[:, None], claim)
        miss = jnp.where(fail.byz_ack_low[:, None],
                         abs_idx[None, :] < phi, miss)
        miss = jnp.where((fail.byz_ack_advance > 0)[:, None], False, miss)
        # implicit duplicate-cum complaint: cum unchanged since last ack to
        # the same sender => complain about index cum (if it exists).
        tgt = (idx_r + t) % n_s                                  # (n_r,)
        upd = (tgt[None, :] == idx_s[:, None]) & alive_r[None, :]  # (n_s,n_r)
        dup_cum = (state.last_cum == cum[None, :])               # (n_s, n_r)
        dup_complaint = (dup_cum[:, :, None]
                         & (abs_idx[None, None, :] == cum[None, :, None])
                         & (cum[None, :, None] < m))
        new_complaint = miss[None, :, :] | dup_complaint         # (n_s,n_r,W)
        known = state.known | (upd[:, :, None] & claim[None, :, :])
        repeat_c = jnp.where(upd[:, :, None],
                             repeat_c | (complaint & new_complaint), repeat_c)
        complaint = jnp.where(upd[:, :, None], new_complaint, complaint)
        last_cum = jnp.where(upd, cum[None, :], state.last_cum)

        # (5) QUACK bookkeeping --------------------------------------------
        quacked_msg = (jnp.einsum("ljm,j->lm", known.astype(jnp.float32),
                                  stakes_r) >= spec.quack_thresh)
        quack_time = jnp.where((state.quack_time < 0) & quacked_msg,
                               t, state.quack_time)

        new_state = SimState(
            recv_has=recv_has, bcast_q=bcast_q, bcast_done=bcast_done,
            known=known, complaint=complaint, repeat_c=repeat_c,
            last_cum=last_cum, retry=retry_new, quack_time=quack_time,
            deliver_time=deliver_time, hq_reports=hq_reports,
            ack_floor=ack_floor, base=state.base,
            retired_delivered=state.retired_delivered)

        qp = base + jnp.sum(jnp.cumprod(quacked_msg.astype(jnp.int32),
                                        axis=1), axis=1)
        min_qp = jnp.min(jnp.where(honest_s, qp, _BIG))
        metrics = StepMetrics(
            cross_msgs=(orig_ok.sum() + resend.sum()).astype(jnp.int32),
            intra_msgs=jnp.einsum("jk,j->", bcast_sent.astype(jnp.int32),
                                  reach.sum(axis=1).astype(jnp.int32)
                                  ).astype(jnp.int32),
            resends=resend.sum().astype(jnp.int32),
            acks=alive_r.sum().astype(jnp.int32),
            delivered=((deliver_time >= 0).sum().astype(jnp.int32)
                       + state.retired_delivered),
            min_quack_prefix=min_qp.astype(jnp.int32),
        )
        return new_state, metrics

    return step


def _init_state(spec: SimSpec, w: int) -> SimState:
    n_s, n_r = spec.n_s, spec.n_r
    f, b = jnp.zeros, jnp.full
    return SimState(
        recv_has=f((n_r, w), dtype=bool),
        bcast_q=f((n_r, w), dtype=bool),
        bcast_done=f((n_r, w), dtype=bool),
        known=f((n_s, n_r, w), dtype=bool),
        complaint=f((n_s, n_r, w), dtype=bool),
        repeat_c=f((n_s, n_r, w), dtype=bool),
        last_cum=b((n_s, n_r), -1, dtype=jnp.int32),
        retry=f((n_s, w), dtype=jnp.int32),
        quack_time=b((n_s, w), -1, dtype=jnp.int32),
        deliver_time=b((w,), -1, dtype=jnp.int32),
        hq_reports=f((n_r, n_s), dtype=jnp.int32),
        ack_floor=f((n_r,), dtype=jnp.int32),
        base=jnp.zeros((), dtype=jnp.int32),
        retired_delivered=jnp.zeros((), dtype=jnp.int32),
    )


def _sched_arrays(spec: SimSpec):
    return (jnp.asarray(spec.orig_sender, dtype=jnp.int32),
            jnp.asarray(spec.orig_recv, dtype=jnp.int32),
            jnp.asarray(spec.orig_step, dtype=jnp.int32))


def _build_run(nspec: SimSpec):
    """Dense full-stream runner: window = [0, M), no rotation."""
    sched_full = _sched_arrays(nspec)

    def run(fail: FailArrays):
        step = _protocol_step(nspec, fail, sched_full, 0, nspec.m)
        state0 = _init_state(nspec, nspec.m)
        ts = jnp.arange(nspec.steps, dtype=jnp.int32)
        return jax.lax.scan(step, state0, ts)

    return run


@functools.lru_cache(maxsize=64)
def _compiled_sim(nspec: SimSpec):
    return jax.jit(_build_run(nspec))


@functools.lru_cache(maxsize=64)
def _compiled_batch(nspec: SimSpec):
    return jax.jit(jax.vmap(_build_run(nspec)))


@functools.lru_cache(maxsize=64)
def _compiled_chunk(nspec: SimSpec, w_slots: int, chunk_len: int):
    """Windowed chunk runner: `chunk_len` rounds at a fixed window base."""
    osend, orecv, ostep = (np.asarray(a) for a in
                           (nspec.orig_sender, nspec.orig_recv,
                            nspec.orig_step))
    pad = lambda a, fill: jnp.asarray(
        np.concatenate([a, np.full(w_slots, fill, dtype=a.dtype)]),
        dtype=jnp.int32)
    osend_p, orecv_p = pad(osend, 0), pad(orecv, 0)
    ostep_p = pad(np.minimum(ostep, _NEVER_STEP), _NEVER_STEP)

    def chunk(fail: FailArrays, state: SimState, t0):
        sl = lambda a: jax.lax.dynamic_slice(a, (state.base,), (w_slots,))
        sched_w = (sl(osend_p), sl(orecv_p), sl(ostep_p))
        step = _protocol_step(nspec, fail, sched_w, state.base, w_slots)
        ts = t0 + jnp.arange(chunk_len, dtype=jnp.int32)
        return jax.lax.scan(step, state, ts)

    return jax.jit(chunk)


def _np_state(state: SimState) -> SimState:
    return jax.tree_util.tree_map(np.asarray, state)


def _rotate(spec: SimSpec, s: SimState, base: int, t_next: int,
            orig_step_pad: np.ndarray, outs) -> Tuple[SimState, int]:
    """Advance the window past the GC frontier (host-side, numpy state)."""
    w = spec.window_slots
    f = gc_frontier(
        base=base, t_next=t_next, m=spec.m,
        known=s.known, bcast_q=s.bcast_q, recv_has=s.recv_has,
        ack_floor=s.ack_floor, stakes_r=np.asarray(spec.stakes_r),
        quack_thresh=spec.quack_thresh,
        orig_step=orig_step_pad[base:base + w],
        crash_r=np.asarray(spec.crash_r),
        byz_ack_low=np.asarray(spec.byz_ack_low))
    if f == 0:
        return s, base
    out_quack, out_deliver, out_retry, out_recv = outs
    out_quack[:, base:base + f] = s.quack_time[:, :f]
    out_deliver[base:base + f] = s.deliver_time[:f]
    out_retry[:, base:base + f] = s.retry[:, :f]
    out_recv[:, base:base + f] = s.recv_has[:, :f]

    def shift(a, fill):
        tail = np.full(a.shape[:-1] + (f,), fill, dtype=a.dtype)
        return np.concatenate([a[..., f:], tail], axis=-1)

    rotated = SimState(
        recv_has=shift(s.recv_has, False), bcast_q=shift(s.bcast_q, False),
        bcast_done=shift(s.bcast_done, False), known=shift(s.known, False),
        complaint=shift(s.complaint, False),
        repeat_c=shift(s.repeat_c, False),
        last_cum=s.last_cum, retry=shift(s.retry, 0),
        quack_time=shift(s.quack_time, -1),
        deliver_time=shift(s.deliver_time, -1),
        hq_reports=s.hq_reports, ack_floor=s.ack_floor,
        base=np.int32(base + f),
        retired_delivered=np.int32(int(s.retired_delivered)
                                   + int((s.deliver_time[:f] >= 0).sum())))
    return rotated, base + f


def _max_msg_by_round(spec: SimSpec) -> np.ndarray:
    """r[t] = highest message index dispatched at or before round t."""
    ostep = np.asarray(spec.orig_step, dtype=np.int64)
    r = np.full(max(spec.steps, 1), -1, dtype=np.int64)
    valid = ostep < spec.steps
    np.maximum.at(r, ostep[valid], np.nonzero(valid)[0])
    return np.maximum.accumulate(r)


def _run_windowed(spec: SimSpec) -> SimResult:
    nspec = _neutral(spec)
    # chunk programs are independent of the horizon: share them across runs
    # that differ only in `steps` (e.g. growing-stream sweeps).
    cspec = dataclasses.replace(nspec, steps=0)
    fail = _fail_arrays(spec)
    w, c_full = spec.window_slots, max(spec.chunk_steps, 1)
    n_s, n_r, m = spec.n_s, spec.n_r, spec.m

    out_quack = np.full((n_s, m), -1, dtype=np.int32)
    out_deliver = np.full((m,), -1, dtype=np.int32)
    out_retry = np.zeros((n_s, m), dtype=np.int32)
    out_recv = np.zeros((n_r, m), dtype=bool)
    outs = (out_quack, out_deliver, out_retry, out_recv)

    orig_step_pad = np.concatenate(
        [np.asarray(spec.orig_step, dtype=np.int64),
         np.full(w, _NEVER_STEP, dtype=np.int64)])
    dispatched_by = _max_msg_by_round(spec)

    state = _init_state(nspec, w)
    base, t = 0, 0
    bases = [0]
    metric_parts = []
    while t < spec.steps:
        c = min(c_full, spec.steps - t)
        need = int(dispatched_by[t + c - 1])
        if need >= base + w:
            raise ValueError(
                f"sliding window overflow: round {t + c - 1} dispatches "
                f"message {need} but the window covers [{base}, {base + w})"
                f" — the GC frontier is {base} after {t} rounds. Increase "
                f"SimConfig.window_slots (or use window_slots='auto'), or "
                f"fall back to the dense path for this scenario.")
        state, ms = _compiled_chunk(cspec, w, c)(fail, state, jnp.int32(t))
        metric_parts.append(jax.tree_util.tree_map(np.asarray, ms))
        t += c
        if t < spec.steps:
            host, new_base = _rotate(spec, _np_state(state), base, t,
                                     orig_step_pad, outs)
            if new_base != base:
                state = jax.tree_util.tree_map(jnp.asarray, host)
                base = new_base
            bases.append(base)

    # flush the live window into the output buffers
    s = _np_state(state)
    live = min(w, m - base)
    if live > 0:
        out_quack[:, base:base + live] = s.quack_time[:, :live]
        out_deliver[base:base + live] = s.deliver_time[:live]
        out_retry[:, base:base + live] = s.retry[:, :live]
        out_recv[:, base:base + live] = s.recv_has[:, :live]

    metrics = StepMetrics(*(
        np.concatenate([getattr(p, name) for p in metric_parts])
        for name in StepMetrics._fields))
    return SimResult(
        spec=spec, metrics=metrics, quack_time=out_quack,
        deliver_time=out_deliver, retry=out_retry, recv_has=out_recv,
        gc_frontiers=np.asarray(bases, dtype=np.int64))


def run_simulation(spec: SimSpec) -> SimResult:
    """Run one spec: windowed when ``spec.window_slots > 0``, else dense."""
    if spec.window_slots:
        return _run_windowed(spec)
    final, ms = _compiled_sim(_neutral(spec))(_fail_arrays(spec))
    final = _np_state(final)
    ms = jax.tree_util.tree_map(np.asarray, ms)
    return SimResult(
        spec=spec,
        metrics=StepMetrics(*ms),
        quack_time=final.quack_time,
        deliver_time=final.deliver_time,
        retry=final.retry,
        recv_has=final.recv_has,
    )


def run_simulation_batch(specs: Sequence[SimSpec]) -> List[SimResult]:
    """Run many failure scenarios of one shape in a single compilation.

    All specs must share every non-failure field (same RSMs, schedules and
    thresholds — e.g. from ``build_spec`` with different ``FailureScenario``
    masks); the failure masks are stacked and the dense runner is
    ``jax.vmap``-ed over them, so a whole sweep costs one compile + one
    device dispatch instead of one ``lru_cache`` entry per scenario.
    Windowed specs are executed with the dense kernel (results identical).
    """
    specs = list(specs)
    if not specs:
        return []
    nspec = _neutral(specs[0])
    for s in specs[1:]:
        if _neutral(s) != nspec:
            raise ValueError("run_simulation_batch: specs differ outside "
                             "their failure masks; batch members must share "
                             "shapes, schedules and thresholds")
    fails = [_fail_arrays(s) for s in specs]
    stacked = FailArrays(*(jnp.stack([getattr(f, name) for f in fails])
                           for name in FailArrays._fields))
    finals, ms = _compiled_batch(nspec)(stacked)
    finals = _np_state(finals)
    ms = jax.tree_util.tree_map(np.asarray, ms)
    out = []
    for b, spec in enumerate(specs):
        out.append(SimResult(
            spec=spec,
            metrics=StepMetrics(*(x[b] for x in ms)),
            quack_time=finals.quack_time[b],
            deliver_time=finals.deliver_time[b],
            retry=finals.retry[b],
            recv_has=finals.recv_has[b],
        ))
    return out
