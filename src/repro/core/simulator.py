"""Vectorized PICSOU simulator — windowed streaming core (``jax.lax.scan``).

The simulator executes the *full* protocol of §4–§5 — round-robin / DSS
send scheduling, receiver rotation, intra-RSM broadcast, cumulative +
phi-list acknowledgements, QUACK formation, duplicate-complaint loss
detection, communication-free retransmitter election, GC with the
highest-quacked metadata defence, stake weighting and LCM-scaled
retransmission rotation — as array state transitions, one scan step per
synchronous round (one cross-RSM RTT).

Per-message state lives in a **sliding window**: each message-indexed array
holds ``W = spec.window_slots`` columns covering absolute sequence numbers
``[base, base + W)``. The run is split into compiled chunks of
``spec.chunk_steps`` rounds; at the end of each chunk the GC frontier
(``gc.gc_frontier_device`` — the prefix both sides may forget, §4.3) is
computed *in-graph* and the ring buffers rotate past it on device
(``lax.dynamic_slice`` shift with ``base`` carried as traced scan state).
The retired columns' quack/deliver/retry/recv outputs leave the device
through a bounded O(W) output queue (``ChunkQueue``) that the host drains
once per chunk — the scan state itself never makes a host round-trip until
the final flush. Failure-free, the frontier tracks the stream, so device
state and compile time are O(W) — *independent of the stream length M* —
which is exactly the paper's P1 constant-metadata invariant applied to the
simulator itself. The dense path (``window_slots == 0``) is the same step
function instantiated at ``base=0, W=M`` with no rotation, and the two are
bit-identical wherever the window is wide enough to hold every in-flight
message (``tests/test_windowed.py``).

Window overflow (a Byzantine stall pinning the frontier while originals
keep dispatching) no longer fails the run: with
``SimConfig.adaptive_window`` (the default) the window grows 2x — the
chunk program is re-instantiated at the wider W and the scan state
migrated on device — and when the required W would reach M the run falls
back to the dense kernel automatically (``gc.grow_window``). Setting
``adaptive_window=False`` restores the strict ``ValueError``.

Because ``base`` is traced state, the windowed chunk also ``jax.vmap``s:
``run_simulation_batch`` executes windowed specs with **per-scenario
window bases**, so whole failure sweeps (fig8/fig9) run windowed *and*
batched in one compilation instead of falling back to the O(M) dense
kernel.

Semantics of a round ``t`` (matching Figure 3/4/5/6 of the paper):
  1. intra-RSM broadcasts queued at t-1 land;
  2. retransmissions are declared/elected from knowledge as of t-1 and the
     corresponding resends are put on the wire;
  3. scheduled original sends for round t are put on the wire; direct sends
     land at their receiver (unless dropped) and queue a broadcast;
  4. every alive receiver acks (cumulative counter + phi-list + implicit
     duplicate-cum complaint) to its rotating target sender; senders fold
     the ack into their knowledge; QUACK / GC state advances.

Failure masks are traced inputs (``FailArrays``), not compile-time
constants, so one compilation serves every failure scenario of a given
shape — and ``run_simulation_batch`` ``jax.vmap``s the same step over a
stack of scenarios for one-compilation sweeps.

The pure-python oracle in ``refsim.py`` mirrors this loop (including the
GC-frontier trajectory) unvectorized; ``tests/test_simulator.py`` and
``tests/test_windowed.py`` cross-check them step by step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.metrics import (MetricsBlock, ObsMetrics, init_metrics_carry,
                           migrate_dense_metrics, obs_from_carry,
                           obs_from_final, pad_metrics,
                           resume_metrics_carry, rotate_metrics,
                           snapshot_metrics, update_metrics)
from ..obs.tracer import obs_begin, obs_end
from . import scheduler as sched
from .gc import gc_frontier_device, grow_window, resolve_window_slots
from .quack import (claim_bitmask, missing_below_horizon,
                    stake_quorum_bitmap, weighted_quorum_prefix)
from .snapshot import (WINDOW_FILLS as _WINDOW_FILLS, device_state,
                       host_state, pad_window, window_shapes
                       as _window_shapes)
from .types import (FailureScenario, RSMConfig, SimConfig,
                    lcm_scale_factors)

__all__ = ["SimSpec", "SimResult", "FailArrays", "build_spec",
           "run_simulation", "run_simulation_batch",
           "require_uniform_batch", "ChunkCheckpoint", "WindowGrowthEvent",
           "spec_failures", "spec_with_failures", "spec_with_quorum",
           "retire_safety_stakes_ok", "chunk_trace_count",
           "chunk_dispatch_count", "host_sync_count"]

# plain Python ints, not jnp scalars: a module-level jnp call would
# initialize the JAX backend at import time (analysis: import-time-jnp);
# weak-typed ints promote to int32 inside the step exactly the same.
_NEVER_STEP = 2 ** 30     # orig_step pad for window slots beyond the stream
_BIG = 2 ** 30


@dataclasses.dataclass(frozen=True)
class SimSpec:
    """Fully-resolved, static simulation plan (hashable closure inputs)."""

    n_s: int
    n_r: int
    m: int
    steps: int
    phi: int
    quack_thresh: float      # u_r + 1 (stake units)
    dup_thresh: float        # r_r + 1 (stake units); 1 in CFT mode
    hq_thresh: float         # r_s + 1 (stake units)
    stakes_s: Tuple[float, ...]
    stakes_r: Tuple[float, ...]
    orig_sender: Tuple[int, ...]      # (M,)
    orig_recv: Tuple[int, ...]        # (M,)
    orig_step: Tuple[int, ...]        # (M,) dispatch round of original send
    rs_seq: Tuple[int, ...]           # retransmit sender rotation sequence
    rr_seq: Tuple[int, ...]           # retransmit receiver rotation sequence
    crash_s: Tuple[int, ...]
    crash_r: Tuple[int, ...]
    byz_send_drop: Tuple[bool, ...]
    byz_recv_drop: Tuple[bool, ...]
    byz_ack_advance: Tuple[int, ...]
    byz_ack_low: Tuple[bool, ...]
    byz_bcast_partial: Tuple[bool, ...]
    bcast_limit: int
    # Byzantine adversary palette (repro.adversary). Optional with None
    # defaults so specs recorded by older traces deserialize unchanged;
    # None is equivalent to the neutral mask everywhere.
    byz_equiv_send: Optional[Tuple[bool, ...]] = None    # (n_s,)
    byz_hq_advance: Optional[Tuple[int, ...]] = None     # (n_s,)
    byz_ack_stale: Optional[Tuple[bool, ...]] = None     # (n_r,)
    drop_pair: Optional[Tuple[Tuple[bool, ...], ...]] = None  # (n_s, n_r)
    window_slots: int = 0             # 0 => dense (full-M) state
    chunk_steps: int = 0              # rounds per compiled chunk (windowed)
    adaptive_window: bool = True      # grow W / dense-fallback on overflow
    superchunk: int = 8               # fused chunks per dispatch (pipeline)
    debug_checks: bool = False        # host-side mirror assertions per drain
    use_pallas_quack: bool = False    # QUACK quorums via the Pallas kernel
    collect_metrics: bool = False     # in-graph obs fabric (repro.obs)

    def scan_state_nbytes(self) -> int:
        """Device bytes of the per-round scan state (the P1 footprint).

        Derived from ``jax.eval_shape`` of the actual carried ``SimState``
        so it cannot drift from the implementation
        (``tests/test_windowed.py`` verifies it against the state a real
        run carries).
        """
        w = self.window_slots or self.m
        state = jax.eval_shape(lambda: _init_state(self, w))
        return sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(state))


class FailArrays(NamedTuple):
    """Per-scenario traced inputs (one compile per *shape*).

    Mostly failure masks; ``commit_floor`` is the commit-gated dispatch
    boundary for chained topologies: message ``k`` may only be originated
    once ``k < commit_floor`` (its entry is committed on the source RSM's
    log). A standalone link is fully committed from the start
    (``commit_floor == m``), which reduces the gate to a no-op; the
    topology engine raises a downstream link's floor between chunks as
    its upstream link retires delivered prefix.
    """

    crash_s: jnp.ndarray           # (n_s,) int32, -1 = never
    crash_r: jnp.ndarray           # (n_r,) int32
    byz_send_drop: jnp.ndarray     # (n_s,) bool
    byz_recv_drop: jnp.ndarray     # (n_r,) bool
    byz_ack_advance: jnp.ndarray   # (n_r,) int32
    byz_ack_low: jnp.ndarray       # (n_r,) bool
    byz_bcast_partial: jnp.ndarray  # (n_r,) bool
    bcast_limit: jnp.ndarray       # () int32
    commit_floor: jnp.ndarray      # () int32 — dispatch gate (abs seqno)
    # adversary palette (repro.adversary)
    byz_equiv_send: jnp.ndarray    # (n_s,) bool — resends equivocate
    byz_hq_advance: jnp.ndarray    # (n_s,) int32 — §4.3 hq-piggyback lie
    byz_ack_stale: jnp.ndarray     # (n_r,) bool — replays previous ack
    drop_pair: jnp.ndarray         # (n_s, n_r) bool — selective drops
    # quorum weights/thresholds are traced too, so a mid-stream stake
    # re-weight / membership change (replay Injection) swaps them with
    # zero recompilation — the compiled programs never close over them
    stakes_s: jnp.ndarray          # (n_s,) float32
    stakes_r: jnp.ndarray          # (n_r,) float32
    quack_thresh: jnp.ndarray      # () float32 — u_r + 1 (stake units)
    dup_thresh: jnp.ndarray        # () float32 — r_r + 1
    hq_thresh: jnp.ndarray         # () float32 — r_s + 1


class SimState(NamedTuple):
    recv_has: jnp.ndarray      # (n_r, W) bool — receiver truly holds slot
    bcast_q: jnp.ndarray       # (n_r, W) bool — queued broadcast for t+1
    bcast_done: jnp.ndarray    # (n_r, W) bool
    orig_sent: jnp.ndarray     # (W,) bool — original dispatch attempted
    known: jnp.ndarray         # (n_s, n_r, W) bool — j's claims known to l
    complaint: jnp.ndarray     # (n_s, n_r, W) bool — j's last complaint to l
    repeat_c: jnp.ndarray      # (n_s, n_r, W) bool — complained twice to l
    last_cum: jnp.ndarray      # (n_s, n_r) int32 (absolute counts)
    retry: jnp.ndarray         # (n_s, W) int32
    quack_time: jnp.ndarray    # (n_s, W) int32, -1 = not yet
    deliver_time: jnp.ndarray  # (W,) int32, -1 = not yet
    hq_reports: jnp.ndarray    # (n_r, n_s) int32 (absolute seqnos)
    ack_floor: jnp.ndarray     # (n_r,) int32 (absolute counts)
    base: jnp.ndarray          # () int32 — absolute seqno of window col 0
    retired_delivered: jnp.ndarray  # () int32 — delivered among retired


class StepMetrics(NamedTuple):
    cross_msgs: jnp.ndarray     # direct cross-RSM data copies this round
    intra_msgs: jnp.ndarray     # broadcast copies this round
    resends: jnp.ndarray        # retransmissions this round
    acks: jnp.ndarray           # ack messages this round
    delivered: jnp.ndarray      # cumulative messages delivered
    min_quack_prefix: jnp.ndarray  # min honest-sender quacked prefix


class ChunkQueue(NamedTuple):
    """Bounded device-side output queue, drained by the host once per chunk.

    Holds the pre-rotation window outputs plus (base, count): columns
    ``[0, count)`` are the slots this chunk's in-graph rotation retired,
    covering absolute sequence numbers ``[base, base + count)``. O(W)
    regardless of stream length — the only per-chunk device->host traffic
    besides the round metrics.
    """

    quack_time: jnp.ndarray    # (n_s, W) pre-rotation
    deliver_time: jnp.ndarray  # (W,)
    retry: jnp.ndarray         # (n_s, W)
    recv_has: jnp.ndarray      # (n_r, W)
    base: jnp.ndarray          # () int32 — window base before rotation
    count: jnp.ndarray         # () int32 — slots retired by this rotation


class ChunkCheckpoint(NamedTuple):
    """Host-side snapshot of a batched windowed run at a chunk boundary.

    Captured by ``_run_windowed_batch`` (when given a ``recorder``) right
    before dispatching the chunk that starts at round ``t``, and accepted
    back as its ``resume`` argument: resuming from a checkpoint replays
    the exact remaining chunk stream — same compiled chunk program (the
    batch shape and window width are unchanged, so nothing recompiles),
    same overflow/growth decisions, same drains — and is bit-identical
    to the original run when the failure schedule is unchanged. All
    leaves are host-side numpy (int32/bool), so a device round-trip is
    exact and the tuple serializes losslessly (``repro.replay``).
    """

    t: int                       # next round to execute
    window_slots: int            # window width in force entering the chunk
    bases: np.ndarray            # (B,) per-lane window base
    state: SimState              # batched scan state, numpy leaves
    fails: FailArrays            # masks in force (numpy leaves, stacked)
    floors: np.ndarray           # (B,) commit floors in force
    out_quack: np.ndarray        # (B, n_s, M) drained retired prefix
    out_deliver: np.ndarray      # (B, M)
    out_retry: np.ndarray        # (B, n_s, M)
    out_recv: np.ndarray         # (B, n_r, M)
    # per-chunk (B, c) metric blocks of the rounds already run; shared by
    # reference with the engine loop (capture is O(1), not O(t)) — use
    # ``metrics()`` for the concatenated (B, t) view.
    metric_parts: Tuple[StepMetrics, ...]
    bases_hist: np.ndarray       # (n_boundaries_so_far, B)
    growth_events: Tuple[WindowGrowthEvent, ...]
    # (B, M) dispatch-round mirror (-1 = not yet dispatched) — feeds
    # ``SimResult.delivery_latency`` and seeds the metrics carry across
    # a resume. Trailing + defaulted so traces recorded before it
    # existed still load (``RunTrace._retuple``); ``None`` falls back
    # to the schedule-derived rounds.
    send_step: Optional[np.ndarray] = None

    def metrics(self) -> StepMetrics:
        """Concatenated (B, t) per-round metrics up to this checkpoint."""
        return _concat_metrics(len(self.bases), list(self.metric_parts))


@dataclasses.dataclass(frozen=True)
class WindowGrowthEvent:
    """One adaptive-window growth decision, attributed to its cause.

    In a batched run the whole batch shares one window width, so a single
    frontier-stalled scenario forces growth for every lane — ``scenario``
    records *which* lane overflowed (batch index) and ``step`` the round
    whose dispatch would have outrun the window, instead of the batch
    silently growing W.  ``new_w == m`` with ``dense_migration`` set means
    the run migrated into the dense layout rather than doubling again.
    """

    step: int                # round whose dispatch overflowed the window
    scenario: int            # batch lane that forced the growth
    need: int                # highest in-flight seqno at that round
    old_w: int
    new_w: int
    dense_migration: bool = False
    # what-if fork batches re-attribute tiled lane indices back to
    # (fork, lane) so consumers never see a mixed index space; None for
    # plain (un-forked) runs and for growths inherited from the shared
    # pre-fork prefix.
    fork: Optional[int] = None


@dataclasses.dataclass
class SimResult:
    spec: SimSpec
    metrics: "np.ndarray-like"            # StepMetrics of (T,) arrays
    quack_time: np.ndarray                # (n_s, M)
    deliver_time: np.ndarray              # (M,)
    retry: np.ndarray                     # (n_s, M)
    recv_has: np.ndarray                  # (n_r, M)
    # window base per chunk boundary; dense runs report the trivial
    # single-entry trajectory [0] so every path populates the field.
    gc_frontiers: Optional[np.ndarray] = None
    # window width the run ended with (== m for dense / dense-fallback
    # runs; > spec.window_slots when adaptive growth kicked in).
    final_window_slots: Optional[int] = None
    # adaptive growth provenance: every growth/dense-migration decision
    # the run (or its whole batch — events are shared batch-wide, the
    # ``scenario`` field says which lane forced each) took. Empty when
    # the window never grew.
    window_growth_events: Tuple[WindowGrowthEvent, ...] = ()
    # (M,) round each message's original dispatch actually happened
    # (commit-floor aware; -1 = never dispatched within the run).
    send_step: Optional[np.ndarray] = None
    # (M,) per-message delivery latency: retire step - send step
    # (-1 = not delivered). Populated by dense, windowed and batched
    # paths alike; the numpy refsim mirrors it bit-exactly.
    delivery_latency: Optional[np.ndarray] = None
    # drained in-graph observability summary (repro.obs), present only
    # when the run's SimConfig.collect_metrics was set.
    obs: Optional[ObsMetrics] = None

    # --- derived -------------------------------------------------------
    def completion_step(self) -> int:
        """Round by which every message is QUACKed at every honest sender."""
        honest = _honest_mask(self.spec.crash_s, self.spec.byz_send_drop)
        qt = self.quack_time[honest]
        if qt.size == 0 or (qt < 0).any():
            return -1
        return int(qt.max())

    def delivery_step(self) -> int:
        if (self.deliver_time < 0).any():
            return -1
        return int(self.deliver_time.max())

    def total_cross_msgs(self) -> int:
        return int(np.sum(self.metrics.cross_msgs))

    def total_intra_msgs(self) -> int:
        return int(np.sum(self.metrics.intra_msgs))

    def total_resends(self) -> int:
        return int(np.sum(self.metrics.resends))

    def max_resends_per_msg(self) -> int:
        honest = _honest_mask(self.spec.crash_s, self.spec.byz_send_drop)
        if not honest.any():
            return 0
        return int(self.retry[honest].max())


def _honest_mask(crash, byz_flags) -> np.ndarray:
    crash = np.asarray(crash)
    byz = np.asarray(byz_flags)
    return (crash < 0) & ~byz


def build_spec(sender: RSMConfig, receiver: RSMConfig,
               sim: SimConfig = SimConfig(),
               failures: FailureScenario = FailureScenario.none(),
               use_lcm_scaling: bool = True) -> SimSpec:
    """Resolve schedules + failure masks into a static SimSpec."""
    n_s, n_r, m = sender.n, receiver.n, sim.n_msgs
    st_s = np.asarray(sender.stakes, dtype=np.float64)
    st_r = np.asarray(receiver.stakes, dtype=np.float64)

    orig_sender = sched.sender_assignment(
        sim.scheduler, st_s, m, quantum=sim.quantum, seed=sim.seed)
    orig_recv = sched.receiver_for(
        orig_sender, n_r, recv_stakes=st_r, scheduler=sim.scheduler,
        quantum=sim.quantum, seed=sim.seed + 1)

    # dispatch round of each original send: the i-th message of sender l is
    # sent in round i // window (window sends per sender per round).
    orig_step = np.zeros(m, dtype=np.int64)
    counters = np.zeros(n_s, dtype=np.int64)
    for k in range(m):
        l = orig_sender[k]
        orig_step[k] = counters[l] // max(sim.window, 1)
        counters[l] += 1

    # retransmission rotation sequences (§4.2 unit-stake, §5.3 staked+LCM).
    unit_s = np.allclose(st_s, st_s[0])
    unit_r = np.allclose(st_r, st_r[0])
    if unit_s and unit_r:
        rs_seq = np.arange(n_s, dtype=np.int64)
        rr_seq = np.arange(n_r, dtype=np.int64)
    else:
        psi_s, psi_r = (lcm_scale_factors(st_s.sum(), st_r.sum())
                        if use_lcm_scaling else (1.0, 1.0))
        # quota each replica proportional to (scaled) stake, smoothed.
        q_s = max(n_s, min(4 * n_s, int(np.ceil(
            st_s.sum() * psi_s / max(st_s.min() * psi_s, 1)))))
        q_r = max(n_r, min(4 * n_r, int(np.ceil(
            st_r.sum() * psi_r / max(st_r.min() * psi_r, 1)))))
        rs_seq = sched.dss_sequence(st_s * psi_s, q_s, q_s)
        rr_seq = sched.dss_sequence(st_r * psi_r, q_r, q_r)

    w_slots = resolve_window_slots(
        sim.window_slots, n_s=n_s, n_r=n_r, send_window=sim.window,
        phi=sim.phi, chunk_steps=sim.chunk_steps, m=m)

    return SimSpec(
        n_s=n_s, n_r=n_r, m=m, steps=sim.steps, phi=sim.phi,
        quack_thresh=receiver.quack_threshold,
        dup_thresh=receiver.dup_threshold,
        hq_thresh=max(sender.r + 1, 1),
        stakes_s=tuple(float(x) for x in st_s),
        stakes_r=tuple(float(x) for x in st_r),
        orig_sender=tuple(int(x) for x in orig_sender),
        orig_recv=tuple(int(x) for x in orig_recv),
        orig_step=tuple(int(x) for x in orig_step),
        rs_seq=tuple(int(x) for x in rs_seq),
        rr_seq=tuple(int(x) for x in rr_seq),
        **_failure_fields(failures, n_s, n_r, sim.steps),
        window_slots=w_slots,
        chunk_steps=sim.chunk_steps if w_slots else 0,
        adaptive_window=sim.adaptive_window,
        superchunk=max(sim.superchunk, 1),
        debug_checks=sim.debug_checks,
        use_pallas_quack=sim.use_pallas_quack,
        collect_metrics=sim.collect_metrics,
    )


def _failure_fields(failures: FailureScenario, n_s: int, n_r: int,
                    steps: Optional[int] = None) -> dict:
    """Resolve a FailureScenario into the SimSpec mask fields.

    Validates shapes and ranges up front (clear ``ValueError`` naming
    the field) instead of letting a wrong-length mask fail deep inside
    tracing or a beyond-horizon crash step silently never fire.
    """

    def tup(x, n, default):
        if x is None:
            return tuple([default] * n)
        return tuple(x)

    if failures is None:
        failures = FailureScenario()
    failures.validate(n_s, n_r, steps)
    if failures.drop_pair is None:
        dp = ((False,) * n_r,) * n_s
    else:
        dp = tuple(tuple(bool(x) for x in row)
                   for row in failures.drop_pair)
    return dict(
        crash_s=tup(failures.crash_s, n_s, -1),
        crash_r=tup(failures.crash_r, n_r, -1),
        byz_send_drop=tup(failures.byz_send_drop, n_s, False),
        byz_recv_drop=tup(failures.byz_recv_drop, n_r, False),
        byz_ack_advance=tup(failures.byz_ack_advance, n_r, 0),
        byz_ack_low=tup(failures.byz_ack_low, n_r, False),
        byz_bcast_partial=tup(failures.byz_bcast_partial, n_r, False),
        bcast_limit=failures.bcast_limit,
        byz_equiv_send=tup(failures.byz_equiv_send, n_s, False),
        byz_hq_advance=tup(failures.byz_hq_advance, n_s, 0),
        byz_ack_stale=tup(failures.byz_ack_stale, n_r, False),
        drop_pair=dp,
    )


def spec_with_failures(spec: SimSpec, failures: FailureScenario) -> SimSpec:
    """Overlay a FailureScenario's masks onto an existing spec.

    Everything structural (schedules, thresholds, window config) is kept,
    so the result batches/replays against the original spec's compiled
    chunk — this is how ``repro.replay`` expresses a mid-run schedule
    edit as a full per-lane spec for the stacked ``FailArrays`` rebuild.
    """
    return dataclasses.replace(
        spec, **_failure_fields(failures, spec.n_s, spec.n_r, spec.steps))


def spec_failures(spec: SimSpec) -> FailureScenario:
    """Extract the failure masks of a spec as a FailureScenario."""
    return FailureScenario(
        crash_s=spec.crash_s, crash_r=spec.crash_r,
        byz_send_drop=spec.byz_send_drop,
        byz_recv_drop=spec.byz_recv_drop,
        byz_ack_advance=spec.byz_ack_advance,
        byz_ack_low=spec.byz_ack_low,
        byz_bcast_partial=spec.byz_bcast_partial,
        bcast_limit=spec.bcast_limit,
        byz_equiv_send=spec.byz_equiv_send,
        byz_hq_advance=spec.byz_hq_advance,
        byz_ack_stale=spec.byz_ack_stale,
        drop_pair=spec.drop_pair)


def spec_with_quorum(spec: SimSpec, stakes_s=None, stakes_r=None,
                     quack_thresh=None, dup_thresh=None,
                     hq_thresh=None) -> SimSpec:
    """Re-weight stakes / quorum thresholds on an existing spec.

    The mid-stream reconfiguration primitive: stakes and thresholds are
    *traced* inputs (they ride ``FailArrays``), so the returned spec
    shares the original's compiled programs — a ``fail_schedule`` /
    replay ``Injection`` swap costs zero recompilation. The retransmit
    rotation schedules (``rs_seq``/``rr_seq``) are committed at spec
    build and intentionally kept — re-deriving them would change the
    compiled constants.
    """
    def pick(new, old, n=None):
        if new is None:
            return old
        new = tuple(float(x) for x in new) if n is not None else float(new)
        if n is not None and len(new) != n:
            raise ValueError(f"stake vector has length {len(new)}, "
                             f"expected {n}")
        return new

    return dataclasses.replace(
        spec,
        stakes_s=pick(stakes_s, spec.stakes_s, spec.n_s),
        stakes_r=pick(stakes_r, spec.stakes_r, spec.n_r),
        quack_thresh=pick(quack_thresh, spec.quack_thresh),
        dup_thresh=pick(dup_thresh, spec.dup_thresh),
        hq_thresh=pick(hq_thresh, spec.hq_thresh))


def _fail_arrays(spec: SimSpec) -> FailArrays:
    n_s, n_r = spec.n_s, spec.n_r

    def tup(x, n, default):
        return [default] * n if x is None else x

    dp = (spec.drop_pair if spec.drop_pair is not None
          else np.zeros((n_s, n_r), dtype=bool))
    return FailArrays(
        crash_s=jnp.asarray(spec.crash_s, dtype=jnp.int32),
        crash_r=jnp.asarray(spec.crash_r, dtype=jnp.int32),
        byz_send_drop=jnp.asarray(spec.byz_send_drop, dtype=bool),
        byz_recv_drop=jnp.asarray(spec.byz_recv_drop, dtype=bool),
        byz_ack_advance=jnp.asarray(spec.byz_ack_advance, dtype=jnp.int32),
        byz_ack_low=jnp.asarray(spec.byz_ack_low, dtype=bool),
        byz_bcast_partial=jnp.asarray(spec.byz_bcast_partial, dtype=bool),
        bcast_limit=jnp.int32(max(spec.bcast_limit, 0)),
        commit_floor=jnp.int32(spec.m),
        byz_equiv_send=jnp.asarray(
            tup(spec.byz_equiv_send, n_s, False), dtype=bool),
        byz_hq_advance=jnp.asarray(
            tup(spec.byz_hq_advance, n_s, 0), dtype=jnp.int32),
        byz_ack_stale=jnp.asarray(
            tup(spec.byz_ack_stale, n_r, False), dtype=bool),
        drop_pair=jnp.asarray(dp, dtype=bool).reshape(n_s, n_r),
        stakes_s=jnp.asarray(spec.stakes_s, dtype=jnp.float32),
        stakes_r=jnp.asarray(spec.stakes_r, dtype=jnp.float32),
        quack_thresh=jnp.float32(spec.quack_thresh),
        dup_thresh=jnp.float32(spec.dup_thresh),
        hq_thresh=jnp.float32(spec.hq_thresh),
    )


def _neutral(spec: SimSpec) -> SimSpec:
    """Compile-cache key: failure masks are traced, window handled apart.

    Host-loop knobs (``superchunk``/``debug_checks``) are normalized away
    — they never change a compiled program. ``use_pallas_quack`` IS part
    of the program (it selects the quorum kernel), so it survives — and
    so does ``collect_metrics`` (it adds the metrics carry to the scan).
    Stakes and quorum thresholds are traced inputs (``FailArrays``), so
    they normalize away too — one compiled program serves every stake
    re-weighting, which is what makes mid-stream reconfiguration free.
    (The stake-derived rotation schedules ``rs_seq``/``rr_seq`` remain
    compiled constants and survive.)
    """
    n_s, n_r = spec.n_s, spec.n_r
    return dataclasses.replace(
        spec,
        crash_s=(-1,) * n_s, crash_r=(-1,) * n_r,
        byz_send_drop=(False,) * n_s, byz_recv_drop=(False,) * n_r,
        byz_ack_advance=(0,) * n_r, byz_ack_low=(False,) * n_r,
        byz_bcast_partial=(False,) * n_r, bcast_limit=0,
        byz_equiv_send=(False,) * n_s, byz_hq_advance=(0,) * n_s,
        byz_ack_stale=(False,) * n_r,
        drop_pair=((False,) * n_r,) * n_s,
        stakes_s=(1.0,) * n_s, stakes_r=(1.0,) * n_r,
        quack_thresh=1.0, dup_thresh=1.0, hq_thresh=1.0,
        window_slots=0, chunk_steps=0, adaptive_window=True,
        superchunk=1, debug_checks=False)


def _protocol_step(spec: SimSpec, fail: FailArrays, sched_w, base, w: int):
    """Per-round transition over ``w`` window columns starting at ``base``.

    ``base`` may be a python int (dense: 0) or a traced scalar (windowed);
    all sequence-number arithmetic is absolute so both instantiations run
    the identical protocol.
    """
    n_s, n_r, m = spec.n_s, spec.n_r, spec.m
    phi = spec.phi
    orig_sender, orig_recv, orig_step = sched_w

    # stakes and quorum thresholds ride the traced FailArrays — the
    # compiled program serves every stake re-weighting / membership swap
    stakes_s = fail.stakes_s
    stakes_r = fail.stakes_r
    rs_seq = jnp.asarray(spec.rs_seq, dtype=jnp.int32)
    rr_seq = jnp.asarray(spec.rr_seq, dtype=jnp.int32)
    ls, lr = len(spec.rs_seq), len(spec.rr_seq)

    abs_idx = (base + jnp.arange(w, dtype=jnp.int32)).astype(jnp.int32)
    idx_r = jnp.arange(n_r, dtype=jnp.int32)
    idx_s = jnp.arange(n_s, dtype=jnp.int32)
    honest_r = (fail.crash_r < 0) & ~(fail.byz_recv_drop | fail.byz_ack_low
                                      | (fail.byz_ack_advance > 0)
                                      | fail.byz_bcast_partial
                                      | fail.byz_ack_stale)
    honest_s = (fail.crash_s < 0) & ~(fail.byz_send_drop
                                      | fail.byz_equiv_send
                                      | (fail.byz_hq_advance > 0))

    # broadcast reach matrix (n_r, n_r): who hears j's intra-RSM broadcast.
    partial_reach = idx_r[None, :] < fail.bcast_limit
    reach = jnp.where(fail.byz_bcast_partial[:, None], partial_reach,
                      jnp.ones((n_r, n_r), dtype=bool))
    reach = reach & (idx_r[None, :] != idx_r[:, None])

    def step(state: SimState, t: jnp.ndarray):
        alive_s = (fail.crash_s < 0) | (t < fail.crash_s)
        alive_r = (fail.crash_r < 0) | (t < fail.crash_r)

        # (1) broadcasts queued last round land now ------------------------
        bcast_sent = state.bcast_q & alive_r[:, None]
        recv_from_bcast = jnp.einsum("jk,ji->ik", bcast_sent, reach) > 0
        recv_has = state.recv_has | (recv_from_bcast & alive_r[:, None])
        bcast_done = state.bcast_done | bcast_sent

        # (2) retransmission declaration + election (knowledge of t-1) -----
        quacked_msg_prev, lost_prev, qprefix_prev = stake_quorum_bitmap(
            state.known, state.repeat_c, stakes_r, fail.quack_thresh,
            fail.dup_thresh, use_pallas=spec.use_pallas_quack)
        # losses can only be declared for messages whose original dispatch
        # already happened; under commit gating the dispatch bit (not the
        # schedule round) is what proves that.
        declared = lost_prev & state.orig_sent[None, :]
        retry_new = state.retry + declared.astype(jnp.int32)
        # Fig. 6: the a-th retransmission of k is sent by the a-th successor
        # of the original sender: sender_new = (orig + #retransmit) mod n_s.
        elected = (rs_seq[(abs_idx[None, :] + retry_new) % ls]
                   == idx_s[:, None])
        resend = (declared & elected & alive_s[:, None]
                  & ~fail.byz_send_drop[:, None])
        # clear complaint trackers where a loss was declared (fresh cycle)
        complaint = jnp.where(declared[:, None, :], False, state.complaint)
        repeat_c = jnp.where(declared[:, None, :], False, state.repeat_c)
        re_target = rr_seq[(orig_recv[None, :] + retry_new) % lr]  # (n_s, W)
        # adversary: an equivocating sender's retransmissions carry a
        # payload conflicting with the original — receivers detect the
        # mismatch and discard them wholesale (no store, no ack claim,
        # no hq metadata heard); the wire copy still happened (metrics
        # count `resend` itself) and the retry counter/rotation advance,
        # so the election keeps rotating toward an honest retransmitter.
        # Selective per-pair drops kill the copy in the network instead:
        # same observable non-delivery, but scoped to (sender, receiver).
        drop_re = jnp.take_along_axis(fail.drop_pair, re_target, axis=1)
        resend_land = resend & ~fail.byz_equiv_send[:, None] & ~drop_re

        # (3) original sends + landing --------------------------------------
        # a message is due once its schedule round has passed AND its
        # entry is committed on the source RSM (commit_floor gate); the
        # dispatch attempt happens exactly once (orig_sent), whether or
        # not the scheduled sender is still alive — matching the ungated
        # semantics where a crashed sender's message is simply never sent.
        due = ((orig_step <= t) & (abs_idx < fail.commit_floor)
               & ~state.orig_sent)
        orig_ok = (due & alive_s[orig_sender]
                   & ~fail.byz_send_drop[orig_sender])
        orig_sent = state.orig_sent | due
        # selective drop of the original copy: the (orig sender, orig
        # receiver) pair is dropped in the network after being sent
        drop_o = fail.drop_pair[orig_sender, orig_recv]          # (W,)
        orig_land = orig_ok & ~drop_o
        s_orig = orig_land[None, :] & (orig_recv[None, :] == idx_r[:, None])
        s_re = (jnp.einsum("lm,lim->im", resend_land.astype(jnp.int32),
                           (re_target[:, None, :] == idx_r[None, :, None])
                           .astype(jnp.int32)) > 0)
        wire = s_orig | s_re                                   # (n_r, W)
        land = wire & alive_r[:, None] & ~fail.byz_recv_drop[:, None]
        recv_has = recv_has | land
        bcast_q = land & ~bcast_done
        deliver_now = (recv_has & honest_r[:, None]).any(axis=0)
        deliver_time = jnp.where((state.deliver_time < 0) & deliver_now,
                                 t, state.deliver_time)

        # (3b) highest-quacked metadata rides on every landed data message:
        # a sender's current quacked prefix reaches every receiver it sent
        # anything to this round (constant-size piggyback, §4.3). Window
        # slots below `base` are all-quacked by the retirement rule, so the
        # absolute prefix is base + the in-window prefix.
        qp_prev = base + qprefix_prev
        e_lk = ((orig_sender[None, :] == idx_s[:, None])
                & orig_land[None, :])                          # (n_s, W)
        sent_orig_to = jnp.einsum("lk,ik->li", e_lk.astype(jnp.int32),
                                  s_orig.astype(jnp.int32)) > 0
        sent_re_to = jnp.einsum(
            "lm,lim->li", resend_land.astype(jnp.int32),
            (re_target[:, None, :] == idx_r[None, :, None]).astype(jnp.int32)
        ) > 0
        heard = (sent_orig_to | sent_re_to).T                  # (n_r, n_s)
        # adversary: an hq-lying sender inflates its piggybacked prefix
        # per receiver — receiver i hears min(true + adv + i, m), so no
        # two receivers can cross-check the same claim (equivocation on
        # the §4.3 metadata). The r_s+1 attestation quorum is the
        # defence: a floor only forms where >= r_s+1 stake agrees, and
        # at most r_s of it can be lying.
        hq_lie = fail.byz_hq_advance                            # (n_s,)
        hq_claim = jnp.where(
            hq_lie[None, :] > 0,
            jnp.minimum(qp_prev[None, :] + hq_lie[None, :]
                        + idx_r[:, None], m),
            qp_prev[None, :])                                   # (n_r, n_s)
        hq_new = jnp.where(heard & alive_r[:, None], hq_claim, 0)
        hq_reports = jnp.maximum(state.hq_reports, hq_new.astype(jnp.int32))

        # (4) acknowledgements ---------------------------------------------
        ack_floor = weighted_quorum_prefix(hq_reports, stakes_s,
                                           fail.hq_thresh)
        ack_floor = jnp.maximum(state.ack_floor, ack_floor)
        eff = recv_has | (abs_idx[None, :] < ack_floor[:, None])
        cum, claim, _known_mask = claim_bitmask(eff, phi, base, m)
        miss = missing_below_horizon(eff, phi, base)
        # Byzantine lies --------------------------------------------------
        cum = jnp.where(fail.byz_ack_low, 0, cum)
        cum = jnp.where(fail.byz_ack_advance > 0,
                        jnp.minimum(cum + fail.byz_ack_advance, m), cum)
        claim = jnp.where(fail.byz_ack_low[:, None], False, claim)
        claim = jnp.where((fail.byz_ack_advance > 0)[:, None],
                          abs_idx[None, :] < cum[:, None], claim)
        miss = jnp.where(fail.byz_ack_low[:, None],
                         abs_idx[None, :] < phi, miss)
        miss = jnp.where((fail.byz_ack_advance > 0)[:, None], False, miss)
        # the ack rotation: receiver j acks sender (j + t) mod n_s, so
        # `upd` marks exactly the (sender, receiver) pairs whose ack
        # state refreshes this round
        tgt = (idx_r + t) % n_s                                  # (n_r,)
        upd = (tgt[None, :] == idx_s[:, None]) & alive_r[None, :]  # (n_s,n_r)
        # adversary: a stale-acking receiver replays its *previous* ack
        # to this round's target verbatim — the cum counter, prefix
        # claim and complaint list it last sent that sender (zero/empty
        # before the first ack). A replayed QUACK is truthful-but-old:
        # monotone claims can never fabricate receipt, but the frozen
        # cum counter trips the duplicate-cum complaint at the sender,
        # manufacturing loss suspicion and resend load (applied LAST so
        # a stale lie freezes whatever lie the other masks produced).
        stale = fail.byz_ack_stale                               # (n_r,)
        prev_cum = jnp.maximum(
            jnp.where(upd, state.last_cum, 0).sum(axis=0), 0)    # (n_r,)
        prev_miss = jnp.where(upd[:, :, None], state.complaint,
                              False).any(axis=0)                 # (n_r, W)
        cum = jnp.where(stale, prev_cum, cum)
        claim = jnp.where(stale[:, None],
                          abs_idx[None, :] < prev_cum[:, None], claim)
        miss = jnp.where(stale[:, None], prev_miss, miss)
        # implicit duplicate-cum complaint: cum unchanged since last ack to
        # the same sender => complain about index cum (if it exists).
        dup_cum = (state.last_cum == cum[None, :])               # (n_s, n_r)
        dup_complaint = (dup_cum[:, :, None]
                         & (abs_idx[None, None, :] == cum[None, :, None])
                         & (cum[None, :, None] < m))
        new_complaint = miss[None, :, :] | dup_complaint         # (n_s,n_r,W)
        known = state.known | (upd[:, :, None] & claim[None, :, :])
        repeat_c = jnp.where(upd[:, :, None],
                             repeat_c | (complaint & new_complaint), repeat_c)
        complaint = jnp.where(upd[:, :, None], new_complaint, complaint)
        last_cum = jnp.where(upd, cum[None, :], state.last_cum)

        # (5) QUACK bookkeeping --------------------------------------------
        # the lost bitmap is unused here (loss declaration works on t-1
        # knowledge, step 2), so the loss quorum is dropped at the call
        quacked_msg, _, qprefix = stake_quorum_bitmap(
            known, repeat_c, stakes_r, fail.quack_thresh,
            fail.dup_thresh, use_pallas=spec.use_pallas_quack,
            need_lost=False)
        quack_time = jnp.where((state.quack_time < 0) & quacked_msg,
                               t, state.quack_time)

        new_state = SimState(
            recv_has=recv_has, bcast_q=bcast_q, bcast_done=bcast_done,
            orig_sent=orig_sent,
            known=known, complaint=complaint, repeat_c=repeat_c,
            last_cum=last_cum, retry=retry_new, quack_time=quack_time,
            deliver_time=deliver_time, hq_reports=hq_reports,
            ack_floor=ack_floor, base=state.base,
            retired_delivered=state.retired_delivered)

        qp = base + qprefix
        min_qp = jnp.min(jnp.where(honest_s, qp, _BIG))
        metrics = StepMetrics(
            cross_msgs=(orig_ok.sum() + resend.sum()).astype(jnp.int32),
            intra_msgs=jnp.einsum("jk,j->", bcast_sent.astype(jnp.int32),
                                  reach.sum(axis=1).astype(jnp.int32)
                                  ).astype(jnp.int32),
            resends=resend.sum().astype(jnp.int32),
            acks=alive_r.sum().astype(jnp.int32),
            delivered=((deliver_time >= 0).sum().astype(jnp.int32)
                       + state.retired_delivered),
            min_quack_prefix=min_qp.astype(jnp.int32),
        )
        return new_state, metrics

    return step


# the window-layout invariants (_WINDOW_FILLS / _window_shapes) and the
# host<->device / width-migration helpers live in core/snapshot.py — one
# shared home for the simulator, the dense-migration path and the
# repro.replay checkpoint machinery.


def _init_state(spec: SimSpec, w: int) -> SimState:
    n_s, n_r = spec.n_s, spec.n_r
    shapes = _window_shapes(n_s, n_r, w)
    window = {
        name: jnp.full(shapes[name], fill,
                       dtype=(bool if isinstance(fill, bool) else jnp.int32))
        for name, fill in _WINDOW_FILLS.items()}
    f = jnp.zeros
    return SimState(
        **window,
        last_cum=jnp.full((n_s, n_r), -1, dtype=jnp.int32),
        hq_reports=f((n_r, n_s), dtype=jnp.int32),
        ack_floor=f((n_r,), dtype=jnp.int32),
        base=jnp.zeros((), dtype=jnp.int32),
        retired_delivered=jnp.zeros((), dtype=jnp.int32),
    )


def _sched_arrays(spec: SimSpec):
    return (jnp.asarray(spec.orig_sender, dtype=jnp.int32),
            jnp.asarray(spec.orig_recv, dtype=jnp.int32),
            jnp.asarray(spec.orig_step, dtype=jnp.int32))


def _build_run(nspec: SimSpec):
    """Dense full-stream runner: window = [0, M), no rotation.

    With ``collect_metrics`` the scan carry becomes ``(state, carry)``
    where ``carry`` is the obs fabric's :class:`MetricsCarry`; metrics
    off, the program is byte-identical to before the fabric existed
    (the wrapper is a static python branch, asserted in
    ``tests/test_obs.py``).
    """
    sched_full = _sched_arrays(nspec)
    collect = nspec.collect_metrics

    def run(fail: FailArrays):
        step = _protocol_step(nspec, fail, sched_full, 0, nspec.m)
        state0 = _init_state(nspec, nspec.m)
        ts = jnp.arange(nspec.steps, dtype=jnp.int32)
        if not collect:
            return jax.lax.scan(step, state0, ts)

        def step_obs(carry, t):
            s, mc = carry
            s2, ms = step(s, t)
            return (s2, update_metrics(mc, s, s2, ms, t)), ms

        return jax.lax.scan(step_obs,
                            (state0, init_metrics_carry(nspec.m)), ts)

    return run


@functools.lru_cache(maxsize=64)
def _compiled_sim(nspec: SimSpec):
    return jax.jit(_build_run(nspec))


@functools.lru_cache(maxsize=64)
def _compiled_batch(nspec: SimSpec):
    return jax.jit(jax.vmap(_build_run(nspec)))


def _rotate_device(s: SimState, f, w: int) -> SimState:
    """Shift the ring buffers left by the (traced) GC frontier ``f``.

    Pure jnp — runs inside the compiled chunk. Each window-indexed array
    is extended by W fresh-fill slots and re-sliced at offset ``f``
    (``lax.dynamic_slice``), which is the in-graph form of the ring
    rotation: columns ``[f, W)`` move to ``[0, W - f)`` and the tail
    refills with fresh slots. ``base`` advances by ``f`` as traced state.
    """
    col = jnp.arange(w, dtype=jnp.int32)

    def shift(a, fill):
        ext = jnp.concatenate(
            [a, jnp.full(a.shape[:-1] + (w,), fill, dtype=a.dtype)],
            axis=-1)
        return jax.lax.dynamic_slice_in_dim(ext, f, w, axis=-1)

    retired_deliv = ((s.deliver_time >= 0) & (col < f)).sum()
    return s._replace(
        **{name: shift(getattr(s, name), fill)
           for name, fill in _WINDOW_FILLS.items()},
        base=(s.base + f).astype(jnp.int32),
        retired_delivered=(s.retired_delivered
                           + retired_deliv).astype(jnp.int32))


# number of times any windowed chunk program has been *traced* (i.e.
# staged for compilation). Warm dispatches do not bump it, so the delta
# across a replay / what-if fork batch is exactly the number of fresh
# compilations it cost — the observable behind the "reusing the already-
# compiled windowed chunk" contract (tests/test_replay.py, bench_replay).
_CHUNK_TRACES = [0]

# pipeline observability: device dispatches issued by the windowed engine
# (one fused superchunk = one dispatch, however many chunks it fuses) and
# host syncs (places the host loop blocked on device results: queue
# drains, checkpoint/migration/final state materializations). The deltas
# across a run are what bench_pipeline and the CI smoke assert on —
# counters, not wall time, so the ~K× dispatch reduction is checked
# deterministically.
_CHUNK_DISPATCHES = [0]
_HOST_SYNCS = [0]


def chunk_trace_count() -> int:
    """How many windowed chunk tracings (compilations) happened so far."""
    return _CHUNK_TRACES[0]


def chunk_dispatch_count() -> int:
    """Device dispatches issued by the windowed engine so far."""
    return _CHUNK_DISPATCHES[0]


def host_sync_count() -> int:
    """Times the windowed engine's host loop blocked on device results."""
    return _HOST_SYNCS[0]


def _donate_state() -> Tuple[int, ...]:
    """Scan-state donation: the chunk callable consumes the carried
    SimState, so its input buffers can be aliased to the outputs (no
    per-chunk O(B·W) copy, halved peak state memory). XLA implements
    input-output aliasing on TPU/GPU; the CPU client ignores donations
    (with a warning), so the hint is only attached where it does
    something. Evaluated lazily (the callers are lru-cached, so once per
    program) — probing the backend at import time would initialize JAX
    as an import side effect and freeze the decision before the user
    could configure the platform."""
    return (1,) if jax.default_backend() != "cpu" else ()


def _build_chunk(nspec: SimSpec, w_slots: int, chunk_len: int, rotate: bool):
    """Windowed chunk: ``chunk_len`` rounds + in-graph GC rotation.

    ``state.base`` is traced, so one compilation serves every window
    position (and, vmapped, every scenario's position). When ``rotate``
    the chunk computes the GC frontier in-graph, emits the pre-rotation
    outputs as a ``ChunkQueue`` and returns the rotated state; the final
    chunk of a run is instantiated with ``rotate=False`` (frontier
    trajectory matches the host-rotation semantics exactly).

    With ``collect_metrics`` the carried state is ``(SimState,
    MetricsCarry)`` and a scalar-only :class:`MetricsBlock` snapshot is
    emitted next to the queue (it rides the same drain — zero extra
    transfers); metrics off, the signature and jaxpr are byte-identical
    to the fabric never existing (static python branches only).
    """
    osend, orecv, ostep = (np.asarray(a) for a in
                           (nspec.orig_sender, nspec.orig_recv,
                            nspec.orig_step))
    pad = lambda a, fill: jnp.asarray(
        np.concatenate([a, np.full(w_slots, fill, dtype=a.dtype)]),
        dtype=jnp.int32)
    osend_p, orecv_p = pad(osend, 0), pad(orecv, 0)
    ostep_p = pad(np.minimum(ostep, _NEVER_STEP), _NEVER_STEP)
    collect = nspec.collect_metrics

    def chunk(fail: FailArrays, carry, t0):
        _CHUNK_TRACES[0] += 1       # body runs only while tracing
        state, mc = carry if collect else (carry, None)
        base0 = state.base
        sl = lambda a: jax.lax.dynamic_slice(a, (base0,), (w_slots,))
        sched_w = (sl(osend_p), sl(orecv_p), sl(ostep_p))
        step = _protocol_step(nspec, fail, sched_w, base0, w_slots)
        ts = t0 + jnp.arange(chunk_len, dtype=jnp.int32)
        if collect:
            def step_obs(c, t):
                s, mcc = c
                s2, ms = step(s, t)
                return (s2, update_metrics(mcc, s, s2, ms, t)), ms

            (state, mc), ms = jax.lax.scan(step_obs, (state, mc), ts)
        else:
            state, ms = jax.lax.scan(step, state, ts)
        if not rotate:
            queue = ChunkQueue(state.quack_time, state.deliver_time,
                               state.retry, state.recv_has, base0,
                               jnp.zeros((), dtype=jnp.int32))
            if collect:
                return (state, mc), ms, queue, snapshot_metrics(mc)
            return state, ms, queue
        f = gc_frontier_device(
            base=base0, t_next=t0 + chunk_len, m=nspec.m,
            known=state.known, bcast_q=state.bcast_q,
            recv_has=state.recv_has, ack_floor=state.ack_floor,
            stakes_r=fail.stakes_r, quack_thresh=fail.quack_thresh,
            orig_sent=state.orig_sent, crash_r=fail.crash_r,
            byz_ack_low=fail.byz_ack_low)
        queue = ChunkQueue(state.quack_time, state.deliver_time,
                           state.retry, state.recv_has, base0, f)
        state = _rotate_device(state, f, w_slots)
        if collect:
            mc = rotate_metrics(mc, f, w_slots)
            return (state, mc), ms, queue, snapshot_metrics(mc)
        return state, ms, queue

    return chunk


@functools.lru_cache(maxsize=64)
def _compiled_batch_chunk(nspec: SimSpec, w_slots: int, chunk_len: int,
                          rotate: bool = True):
    """Per-scenario failure masks AND window bases, one dispatch.

    Single windowed runs go through the same program as a batch of one,
    so there is exactly one chunk kernel to keep correct.
    """
    return jax.jit(jax.vmap(_build_chunk(nspec, w_slots, chunk_len, rotate),
                            in_axes=(0, 0, None)),
                   donate_argnums=_donate_state())


@functools.lru_cache(maxsize=64)
def _compiled_batch_superchunk(nspec: SimSpec, w_slots: int,
                               chunk_len: int, k: int):
    """K fused chunk bodies (rotations included) in ONE compiled dispatch.

    A ``lax.scan`` over chunk boundaries: each inner iteration runs one
    full vmapped chunk — ``chunk_len`` protocol rounds, in-graph GC
    frontier, ring rotation — and emits its pre-rotation
    :class:`ChunkQueue`; the scan stacks the K queues (and the K
    per-chunk metric blocks) into one K-deep device-side buffer the host
    drains after the dispatch returns. The chunk body is traced once
    regardless of K (the trace counter moves by 1), host round-trips
    drop by K×, and because the body is the *same* function the
    synchronous loop dispatches, a fused run is bit-identical to K
    sequential dispatches.

    The host's per-boundary adaptive-window overflow check moves
    in-graph: ``needs`` carries the precomputed dispatch horizon
    ``dispatched_by[t0 + (i+1)*chunk_len - 1]`` per inner chunk (a
    traced input — one compilation serves every span), and before inner
    chunk ``i`` runs, the *exact* device bases are tested against it.
    The moment any lane would overflow, the remaining chunk bodies are
    skipped (a ``lax.cond`` — the untaken branch costs nothing at run
    time) and the per-chunk ``ok`` flags tell the host how many chunks
    actually executed, so it rewinds to that boundary and takes the
    growth decision there with exactly the bases K = 1 would have seen.
    """
    chunk = jax.vmap(_build_chunk(nspec, w_slots, chunk_len, rotate=True),
                     in_axes=(0, 0, None))
    collect = nspec.collect_metrics

    def superchunk(fail: FailArrays, carry0, t0, needs):
        sim0 = carry0[0] if collect else carry0
        n_b = sim0.base.shape[0]
        n_s, n_r = nspec.n_s, nspec.n_r
        zero_q = ChunkQueue(
            quack_time=jnp.zeros((n_b, n_s, w_slots), jnp.int32),
            deliver_time=jnp.zeros((n_b, w_slots), jnp.int32),
            retry=jnp.zeros((n_b, n_s, w_slots), jnp.int32),
            recv_has=jnp.zeros((n_b, n_r, w_slots), bool),
            base=jnp.zeros((n_b,), jnp.int32),
            count=jnp.zeros((n_b,), jnp.int32))
        zero_ms = StepMetrics(*(jnp.zeros((n_b, chunk_len), jnp.int32)
                                for _ in StepMetrics._fields))

        def body(carry, xs):
            st, alive = carry
            i, need_i = xs
            sim = st[0] if collect else st
            # the same per-scenario rule the host loop applies at a
            # boundary: window need capped by the commit floor, measured
            # against each lane's own (exact, in-graph) base
            over = (jnp.minimum(need_i, fail.commit_floor - 1)
                    - sim.base)
            ok = jnp.logical_and(alive, (over < w_slots).all())
            if collect:
                # skipped chunks re-emit the carried accumulator
                # snapshot so the stacked blocks stay structurally
                # K-deep; the host ignores them via ``oks``
                st, ms, queue, blk = jax.lax.cond(
                    ok,
                    lambda s: chunk(fail, s, t0 + i * chunk_len),
                    lambda s: (s, zero_ms,
                               zero_q._replace(base=s[0].base),
                               snapshot_metrics(s[1])),
                    st)
                return (st, ok), (ms, queue, ok, blk)
            st, ms, queue = jax.lax.cond(
                ok,
                lambda s: chunk(fail, s, t0 + i * chunk_len),
                lambda s: (s, zero_ms,
                           zero_q._replace(base=s.base)),
                st)
            return (st, ok), (ms, queue, ok)

        if collect:
            (carry0, _), (ms, queues, oks, blks) = jax.lax.scan(
                body, (carry0, jnp.bool_(True)),
                (jnp.arange(k, dtype=jnp.int32), needs))
            return carry0, ms, queues, oks, blks
        (carry0, _), (ms, queues, oks) = jax.lax.scan(
            body, (carry0, jnp.bool_(True)),
            (jnp.arange(k, dtype=jnp.int32), needs))
        return carry0, ms, queues, oks

    return jax.jit(superchunk, donate_argnums=_donate_state())


# host materialization / width migration are the shared snapshot
# utilities; thin aliases keep the simulator's internal vocabulary.
_np_state = host_state
_grow_state = pad_window


def _widen_on_overflow(spec: SimSpec, w: int, base: int, need: int,
                       t: int) -> Optional[int]:
    """Overflow policy: raise (strict), grow 2x, or None => dense layout.

    ``None`` tells the caller to migrate the windowed scan state into the
    dense layout (base 0, W = M) and continue — no rerun from scratch.
    """
    if not spec.adaptive_window:
        raise ValueError(
            f"sliding window overflow: round {t} dispatches message "
            f"{need} but the window covers [{base}, {base + w}) — the GC "
            f"frontier is {base}. Increase SimConfig.window_slots (or use "
            f"window_slots='auto'), or leave adaptive_window=True for "
            f"automatic growth / dense-layout migration.")
    return grow_window(w, base, need, spec.m)


def _migrate_dense_batch(spec: SimSpec, state: SimState,
                         bases: np.ndarray, out_quack: np.ndarray,
                         out_deliver: np.ndarray, out_retry: np.ndarray,
                         out_recv: np.ndarray) -> SimState:
    """Embed the windowed scan state into the dense layout (base 0, W=M).

    Adaptive-growth endpoint: when the next doubling would reach the full
    stream length, the run keeps its partial progress instead of rerunning
    on the dense kernel from round 0. Live window columns land at their
    absolute positions ``[base_b, base_b + W)``; columns below each
    scenario's base are reconstructed from the already-drained retired
    outputs plus the retirement invariants themselves — a retired slot is
    QUACKed at *every* sender (``known`` may be set all-True without
    changing any threshold decision), effectively received at every
    receiver that still matters (``recv_has`` restored from the drained
    snapshot; the rest is covered by the preserved ack floor), has no
    broadcast pending and its original send dispatched. Per-replica state
    (``last_cum``/``hq_reports``/``ack_floor``) carries over unchanged, so
    the continued run is bit-identical in every observable output to a
    dense run from round 0 (``tests/test_windowed.py``).

    One-off host-side transform (numpy in, device out) — the steady-state
    chunk loop still never round-trips the scan state.
    """
    n_b = len(bases)
    n_s, n_r, m = spec.n_s, spec.n_r, spec.m
    state = _np_state(state)
    w = state.deliver_time.shape[-1]
    shapes = _window_shapes(n_s, n_r, m)
    dense = {
        name: np.full((n_b,) + shapes[name], fill,
                      dtype=(bool if isinstance(fill, bool) else np.int32))
        for name, fill in _WINDOW_FILLS.items()}
    for b in range(n_b):
        lo = int(bases[b])
        live = min(w, m - lo)
        if live > 0:
            for name in _WINDOW_FILLS:
                dense[name][b][..., lo:lo + live] = \
                    getattr(state, name)[b][..., :live]
        if lo > 0:
            dense["recv_has"][b][..., :lo] = out_recv[b][..., :lo]
            dense["retry"][b][..., :lo] = out_retry[b][..., :lo]
            dense["quack_time"][b][..., :lo] = out_quack[b][..., :lo]
            dense["deliver_time"][b][:lo] = out_deliver[b][:lo]
            dense["known"][b][..., :lo] = True
            dense["bcast_done"][b][..., :lo] = True
            dense["orig_sent"][b][:lo] = True
    return SimState(
        **{name: jnp.asarray(a) for name, a in dense.items()},
        last_cum=jnp.asarray(state.last_cum),
        hq_reports=jnp.asarray(state.hq_reports),
        ack_floor=jnp.asarray(state.ack_floor),
        base=jnp.zeros((n_b,), dtype=jnp.int32),
        retired_delivered=jnp.zeros((n_b,), dtype=jnp.int32),
    )


def _max_msg_by_round(spec: SimSpec) -> np.ndarray:
    """r[t] = highest message index dispatched at or before round t."""
    ostep = np.asarray(spec.orig_step, dtype=np.int64)
    r = np.full(max(spec.steps, 1), -1, dtype=np.int64)
    valid = ostep < spec.steps
    np.maximum.at(r, ostep[valid], np.nonzero(valid)[0])
    return np.maximum.accumulate(r)


def _run_windowed(spec: SimSpec) -> SimResult:
    """Single windowed run == a batch of one (same kernel, same drains)."""
    return _run_windowed_batch([spec])[0]


def _dense_send_step(spec: SimSpec) -> np.ndarray:
    """Dispatch rounds of the dense (ungated) path: the schedule round,
    -1 for messages whose round never arrives within ``steps``."""
    ostep = np.asarray(spec.orig_step, dtype=np.int64)
    return np.where(ostep < spec.steps, ostep, -1).astype(np.int32)


def _latency_from(send_step: np.ndarray,
                  deliver_time: np.ndarray) -> np.ndarray:
    """Per-message retire-step - send-step; -1 = not delivered."""
    return np.where(deliver_time >= 0, deliver_time - send_step,
                    -1).astype(np.int32)


def run_simulation(spec: SimSpec) -> SimResult:
    """Run one spec: windowed when ``spec.window_slots > 0``, else dense."""
    if spec.window_slots:
        return _run_windowed(spec)
    carry, ms = _compiled_sim(_neutral(spec))(_fail_arrays(spec))
    # one explicit batched fetch — per-leaf np.asarray here is an
    # implicit d2h transfer the analysis sanitizer rejects
    carry, ms = jax.device_get((carry, ms))
    final, mc = carry if spec.collect_metrics else (carry, None)
    ss = _dense_send_step(spec)
    return SimResult(
        spec=spec,
        metrics=StepMetrics(*ms),
        quack_time=final.quack_time,
        deliver_time=final.deliver_time,
        retry=final.retry,
        recv_has=final.recv_has,
        gc_frontiers=np.zeros(1, dtype=np.int64),
        final_window_slots=spec.m,
        send_step=ss,
        delivery_latency=_latency_from(ss, final.deliver_time),
        obs=obs_from_carry(mc) if mc is not None else None,
    )


def retire_safety_stakes_ok(spec: SimSpec) -> bool:
    """Whether the GC retire-implies-delivered invariant is provable.

    A retired slot is QUACKed at every sender, and a QUACK quorum
    (``quack_thresh`` = u_r+1 stake) intersects at least one *honest*
    receiver's truthful claim — unless receivers that can fabricate
    claims (``byz_ack_advance``) control a whole quorum by themselves,
    or senders lying in the §4.3 hq piggyback (``byz_hq_advance``)
    control a whole attestation quorum (``hq_thresh`` = r_s+1) and can
    raise ack floors past undelivered messages. Within those stake
    budgets the invariant is exact (the engine's debug retire check and
    ``repro.adversary.safety`` assert it); beyond them the protocol's
    own assumptions are violated and retirement may outrun delivery.
    Every other adversary kind (drops, equivocation, stale replays,
    low acks, partial broadcasts) only ever *suppresses* claims, so it
    can never make the invariant unsound.
    """
    st_r = np.asarray(spec.stakes_r, dtype=np.float64)
    adv = np.asarray(spec.byz_ack_advance, dtype=np.int64)
    fabricating = float(st_r[adv > 0].sum())
    if fabricating >= float(spec.quack_thresh):
        return False
    if spec.byz_hq_advance is not None:
        st_s = np.asarray(spec.stakes_s, dtype=np.float64)
        hq = np.asarray(spec.byz_hq_advance, dtype=np.int64)
        if float(st_s[hq > 0].sum()) >= float(spec.hq_thresh):
            return False
    return True


def _stacked_fails(specs: Sequence[SimSpec]) -> FailArrays:
    fails = [_fail_arrays(s) for s in specs]
    return FailArrays(*(jnp.stack([getattr(f, name) for f in fails])
                        for name in FailArrays._fields))


def _run_dense_batch(specs: List[SimSpec]) -> List[SimResult]:
    nspec = _neutral(specs[0])
    carry, ms = _compiled_batch(nspec)(_stacked_fails(specs))
    carry, ms = jax.device_get((carry, ms))
    collect = specs[0].collect_metrics
    finals, mc = carry if collect else (carry, None)
    out = []
    for b, spec in enumerate(specs):
        ss = _dense_send_step(spec)
        out.append(SimResult(
            spec=spec,
            metrics=StepMetrics(*(x[b] for x in ms)),
            quack_time=finals.quack_time[b],
            deliver_time=finals.deliver_time[b],
            retry=finals.retry[b],
            recv_has=finals.recv_has[b],
            gc_frontiers=np.zeros(1, dtype=np.int64),
            final_window_slots=spec.m,
            send_step=ss,
            delivery_latency=_latency_from(ss, finals.deliver_time[b]),
            obs=obs_from_final(mc, [], b) if collect else None,
        ))
    return out


def _scatter_retired(bases: np.ndarray, counts: np.ndarray, srcs,
                     outs) -> np.ndarray:
    """Fold one drained queue block into the (B, ..., M) output mirrors.

    Writes each lane's leading ``counts[b]`` window columns to absolute
    slots ``[bases[b], bases[b] + counts[b])`` — one vectorized
    advanced-indexing write per output array instead of a per-lane
    Python copy loop. ``srcs``/``outs`` are the (quack_time,
    deliver_time, retry, recv_has) quadruples. Returns the advanced
    per-lane bases (the inputs are never mutated).
    """
    qq, qd, qr, qh = srcs
    out_quack, out_deliver, out_retry, out_recv = outs
    counts = np.asarray(counts, dtype=np.int64)
    if counts.any():
        w = qd.shape[-1]
        mask = np.arange(w, dtype=np.int64)[None, :] < counts[:, None]
        rows, cols = np.nonzero(mask)
        abs_cols = bases[rows] + cols
        out_quack[rows, :, abs_cols] = qq[rows, :, cols]
        out_deliver[rows, abs_cols] = qd[rows, cols]
        out_retry[rows, :, abs_cols] = qr[rows, :, cols]
        out_recv[rows, :, abs_cols] = qh[rows, :, cols]
    return bases + counts


def _concat_metrics(n_b: int, metric_parts) -> StepMetrics:
    """Concatenate per-chunk (B, c) metric parts into (B, t) arrays."""
    if not metric_parts:
        return StepMetrics(*(np.zeros((n_b, 0), dtype=np.int32)
                             for _ in StepMetrics._fields))
    return StepMetrics(*(
        np.concatenate([np.asarray(getattr(p, name)) for p in metric_parts],
                       axis=-1)
        for name in StepMetrics._fields))


def _run_windowed_batch(specs: List[SimSpec], commit_floors=None, *,
                        fail_schedule=None, recorder=None,
                        resume: Optional[ChunkCheckpoint] = None,
                        drain_sink=None,
                        ) -> List[SimResult]:
    """Windowed batch entry point; see ``_run_windowed_batch_impl``.

    When ``SimConfig.debug_checks`` is set the whole run executes under
    the analysis sanitizer's :func:`repro.analysis.engine_guard`: any
    implicit device->host materialization in the drain / checkpoint /
    final-flush path (a ``np.asarray`` on a ``jax.Array`` outside
    ``jax.device_get``) raises ``SanitizerError`` instead of silently
    serializing the pipeline.
    """
    _tr = obs_begin()
    try:
        if specs and specs[0].debug_checks:
            from ..analysis.sanitizer import engine_guard
            with engine_guard():
                return _run_windowed_batch_impl(
                    specs, commit_floors, fail_schedule=fail_schedule,
                    recorder=recorder, resume=resume,
                    drain_sink=drain_sink)
        return _run_windowed_batch_impl(
            specs, commit_floors, fail_schedule=fail_schedule,
            recorder=recorder, resume=resume, drain_sink=drain_sink)
    finally:
        obs_end(_tr, "run", cat="engine", lanes=len(specs),
                steps=specs[0].steps if specs else 0)


def _run_windowed_batch_impl(specs: List[SimSpec], commit_floors=None, *,
                             fail_schedule=None, recorder=None,
                             resume: Optional[ChunkCheckpoint] = None,
                             drain_sink=None,
                             ) -> List[SimResult]:
    """Batched windowed sweep: per-scenario failure masks AND window bases.

    The vmapped chunk rotates each scenario's ring buffers at its own GC
    frontier in-graph, so the whole sweep is one compilation with
    O(B * W) state — windowed and batched at once. Window overflow
    (checked per scenario against its own base and commit floor) grows W
    for the whole batch; when the required width would reach M the scan
    state migrates into the dense layout (``_migrate_dense_batch``) and
    the same chunk loop continues — partial progress is kept, never
    rerun. Every growth decision is recorded
    (``SimResult.window_growth_events``) with the lane that forced it
    and the overflow round, instead of the batch silently growing W.

    Execution is **pipelined** (``SimSpec.superchunk`` = K): up to K
    full rotating chunk bodies fuse into one compiled dispatch
    (``_compiled_batch_superchunk`` — a ``lax.scan`` over chunk
    boundaries with a K-deep output queue), and the host drains a
    dispatch's queue *while the next dispatch computes* (JAX async
    dispatch; at most one dispatch is ever in flight undrained). Fusion
    and the drain overlap both break automatically at every boundary
    where host interaction is mandatory — recorder checkpoints,
    ``fail_schedule`` swaps, ``commit_floors`` updates, window
    growth/dense fallback, and the final unrotated chunk — and the
    launch-ahead path is only taken when the conservative overflow bound
    (host-side ``dispatched_by``/``floors`` mirrors against the
    pre-drain bases) proves no growth decision could trigger, so every K
    is bit-identical to the K = 1 synchronous loop in outputs, metrics,
    frontier trajectories, growth events and recorded traces.
    ``chunk_dispatch_count`` / ``host_sync_count`` expose the ~K×
    dispatch and sync reduction deterministically (``bench_pipeline``).

    ``commit_floors``, when given, is called as ``commit_floors(t, bases)``
    before the chunk starting at round ``t`` (``bases`` = each scenario's
    current retired prefix) and must return the per-scenario commit
    floors for that chunk. The topology engine uses it to route one
    link's retired/delivered prefix into the commit stream of chained
    downstream links — the floors are traced inputs, so updating them
    between chunks costs no recompilation.

    ``fail_schedule``, when given, is called as ``fail_schedule(t)`` at
    the top of each chunk; returning a list of specs (same structure as
    ``specs``, differing only in failure masks) swaps the stacked
    ``FailArrays`` in force from round ``t`` onward — a mid-stream
    crash/heal/drop-schedule edit. The masks are traced inputs, so a
    swap costs no recompilation; returning ``None`` keeps the masks.

    ``recorder`` (an object with ``wants(t) -> bool`` and
    ``capture(ChunkCheckpoint)``) captures chunk-boundary checkpoints;
    ``resume`` restarts the loop from a previously captured checkpoint —
    the replay subsystem's entry points (``repro.replay``).

    ``drain_sink`` switches the loop into **horizon mode** (the
    ``repro.stream`` session driver): M is treated as a message horizon
    rather than an allocation. No (B, ..., M) output mirrors are built —
    every drained chunk is retired *online* into the sink
    (``sink.on_chunk(t_end, metrics, queue, block, bases)`` per inner
    chunk, ``sink.on_final(state, metrics_carry, bases, w, events, t)``
    after the terminal flush) and the call returns ``[]`` instead of
    per-lane ``SimResult``\\ s. Host memory per dispatch is O(B * W);
    the dispatch/fusion structure is byte-identical to batch mode (the
    sink rides the drains that already happen), so the zero-extra-
    dispatch contract is held by construction. Requires
    ``collect_metrics`` (the blocks *are* the live feed) and is mutually
    exclusive with ``recorder``/``resume`` (checkpoints capture O(M)
    mirrors that horizon mode never materializes); window growth stays
    available but the dense-layout fallback (O(M) state) raises instead
    of silently allocating the horizon.
    """
    spec0 = specs[0]
    n_b = len(specs)
    nspec = _neutral(spec0)
    cspec = dataclasses.replace(nspec, steps=0)
    n_s, n_r, m = spec0.n_s, spec0.n_r, spec0.m
    c_full = max(spec0.chunk_steps, 1)

    if drain_sink is not None:
        if recorder is not None or resume is not None:
            raise ValueError("drain_sink (horizon mode) is incompatible "
                             "with recorder/resume: checkpoints capture "
                             "the O(M) output mirrors horizon mode "
                             "exists to avoid")
        if not spec0.collect_metrics:
            raise ValueError("drain_sink requires collect_metrics=True: "
                             "the MetricsBlock snapshots riding the "
                             "drain are the live telemetry feed")

    # Per-run program lookup: the lru_cached constructors hash the whole
    # frozen spec — including O(M) schedule tuples — on every call,
    # which horizon-scale runs (M ~ 1e6, thousands of dispatches) cannot
    # afford. Key by the only fields that vary inside one run.
    progs: dict = {}

    def chunk_prog(w_slots: int, c_len: int, rotate: bool):
        key = (w_slots, c_len, rotate, 1)
        fn = progs.get(key)
        if fn is None:
            fn = progs[key] = _compiled_batch_chunk(cspec, w_slots,
                                                    c_len, rotate)
        return fn

    def super_prog(w_slots: int, c_len: int, k: int):
        key = (w_slots, c_len, True, k)
        fn = progs.get(key)
        if fn is None:
            fn = progs[key] = _compiled_batch_superchunk(cspec, w_slots,
                                                         c_len, k)
        return fn

    dispatched_by = _max_msg_by_round(spec0)
    collect = spec0.collect_metrics
    ostep = np.asarray(spec0.orig_step, dtype=np.int64)

    # carry = SimState when metrics are off, (SimState, MetricsCarry)
    # when on — the two accessors keep the loop body branch-free
    _sim = (lambda cy: cy[0]) if collect else (lambda cy: cy)

    retain = drain_sink is None       # batch mode: O(M) host mirrors
    if resume is None:
        w = spec0.window_slots
        fails = _stacked_fails(specs)
        if retain:
            out_quack = np.full((n_b, n_s, m), -1, dtype=np.int32)
            out_deliver = np.full((n_b, m), -1, dtype=np.int32)
            out_retry = np.zeros((n_b, n_s, m), dtype=np.int32)
            out_recv = np.zeros((n_b, n_r, m), dtype=bool)
        else:
            out_quack = out_deliver = out_retry = out_recv = None
        carry = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n_b,) + x.shape),
            _init_state(nspec, w))
        if collect:
            carry = (carry, jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (n_b,) + x.shape),
                init_metrics_carry(w)))
        bases = np.zeros(n_b, dtype=np.int64)
        bases_hist = [bases.copy()]
        floors = np.full(n_b, m, dtype=np.int64)
        t = 0
        metric_parts = []
        growth_events: List[WindowGrowthEvent] = []
        # per-message dispatch-round mirror (commit-floor aware): filled
        # as floors open, feeds SimResult.delivery_latency + checkpoints
        # (horizon mode drops it — another O(M) buffer)
        send_step = (np.full((n_b, m), -1, dtype=np.int64)
                     if retain else None)
        open_floor = np.zeros(n_b, dtype=np.int64)
    else:
        if len(resume.bases) != n_b:
            raise ValueError(
                f"resume checkpoint has {len(resume.bases)} lanes, specs "
                f"describe {n_b}")
        w = int(resume.window_slots)
        fails = FailArrays(*(jnp.asarray(x) for x in resume.fails))
        out_quack = np.array(resume.out_quack, dtype=np.int32)
        out_deliver = np.array(resume.out_deliver, dtype=np.int32)
        out_retry = np.array(resume.out_retry, dtype=np.int32)
        out_recv = np.array(resume.out_recv, dtype=bool)
        carry = device_state(resume.state)
        bases = np.array(resume.bases, dtype=np.int64)
        bases_hist = [np.array(r, dtype=np.int64)
                      for r in resume.bases_hist]
        floors = np.array(resume.floors, dtype=np.int64)
        t = int(resume.t)
        metric_parts = [p for p in resume.metric_parts
                        if np.asarray(p.acks).shape[-1]]
        growth_events = list(resume.growth_events)
        if resume.send_step is not None:
            send_step = np.array(resume.send_step, dtype=np.int64)
        else:
            # pre-send_step trace: every message below the checkpoint's
            # floor dispatched at its schedule round (exact for
            # standalone links, where the floor opened at t=0)
            send_step = np.where(
                np.arange(m, dtype=np.int64)[None, :] < floors[:, None],
                ostep[None, :], -1)
        open_floor = floors.copy()
        if collect:
            carry = (carry,
                     resume_metrics_carry(w, bases, send_step, m))

    K = max(spec0.superchunk, 1)
    debug = spec0.debug_checks
    # lanes whose adversary stakes stay inside the quorum budgets have a
    # provable retire-implies-delivered invariant; the debug drain check
    # asserts it per retired slot (repro.adversary safety contract)
    retire_check = np.array([retire_safety_stakes_ok(s) for s in specs])

    pending: List[dict] = []   # dispatched, not yet drained (≤ 1 entry)
    obs_parts: List = []       # drained per-chunk MetricsBlock snapshots

    def drain_one(ent: dict) -> None:
        """Materialize one dispatch's K-deep queue + metric blocks and
        fold them into the host mirrors, inner chunk by inner chunk —
        bit-identical to K separate synchronous drains. A fused span the
        in-graph overflow guard cut short rewinds ``t`` to the boundary
        of the first unexecuted chunk; the loop re-enters there and
        takes the growth decision exactly where K = 1 would have."""
        nonlocal bases, t
        _tw = obs_begin()
        # one batched fetch per dispatch — the metrics blocks (when
        # collecting) ride the same device_get, zero extra transfers
        ms, queue, oks, blk = jax.device_get(
            (ent["ms"], ent["queue"], ent["oks"], ent["blk"]))
        # a successor dispatch still in flight means this wait ran
        # concurrently with device compute (PR 5 double buffering)
        obs_end(_tw, "drain_wait", cat="drain", k=ent["k"],
                overlapped=bool(pending))
        _HOST_SYNCS[0] += 1
        k = ent["k"]
        executed = k if oks is None else int(np.asarray(oks).sum())
        if executed < k:
            t = ent["t0"] + executed * ent["c"]
        for i in range(executed):
            if k == 1:
                msp, qp, bp = ms, queue, blk
            else:
                msp = StepMetrics(*(getattr(ms, name)[i]
                                    for name in StepMetrics._fields))
                qp = ChunkQueue(*(getattr(queue, name)[i]
                                  for name in ChunkQueue._fields))
                bp = None if blk is None else MetricsBlock(
                    *(getattr(blk, name)[i]
                      for name in MetricsBlock._fields))
            msp = StepMetrics(*(np.asarray(x) for x in msp))
            if retain:
                metric_parts.append(msp)
                if bp is not None:
                    obs_parts.append(bp)
            if not ent["rotate"]:
                if not retain:
                    drain_sink.on_chunk(ent["t0"] + (i + 1) * ent["c"],
                                        msp, qp, bp, bases.copy())
                continue               # final chunk: nothing retired
            # the host's base mirror must track the in-graph rotation
            # exactly; the comparison is debug-gated so steady-state
            # drains never block on a consistency assertion
            if debug and not (np.asarray(qp.base) == bases).all():
                raise RuntimeError(
                    "window base mirror diverged from device rotation")
            # GC safety under adversaries: a retired slot must be
            # physically held by >= 1 replica of the receiver RSM —
            # recv_has is ground-truth receipt, so only a quorum of
            # *fabricated* claims can quack an unreceived message, and
            # that is provably impossible while fabricating stake stays
            # inside the quorum budgets (retire_safety_stakes_ok).
            # Debug-gated like the base check; repro.adversary's
            # property tests run with it.
            if debug and retire_check.any():
                cnt = np.asarray(qp.count, dtype=np.int64)
                held = np.asarray(qp.recv_has).any(axis=1)   # (B, W)
                ret = (np.arange(held.shape[-1])[None, :] < cnt[:, None])
                bad = ret & ~held & retire_check[:, None]
                if bad.any():
                    b, kk = np.argwhere(bad)[0]
                    raise RuntimeError(
                        f"GC safety violation: lane {b} retired window "
                        f"slot {kk} (abs seqno {int(bases[b]) + int(kk)}) "
                        f"that no replica has received — the frontier "
                        f"outran an undelivered message under an "
                        f"adversary whose stake budget should make that "
                        f"impossible")
            if retain:
                bases = _scatter_retired(
                    bases, qp.count,
                    (np.asarray(qp.quack_time),
                     np.asarray(qp.deliver_time),
                     np.asarray(qp.retry), np.asarray(qp.recv_has)),
                    (out_quack, out_deliver, out_retry, out_recv))
                bases_hist.append(bases.copy())
            else:
                # horizon mode: the chunk's outputs retire into the
                # sink instead of (B, ..., M) mirrors — O(B * W) per
                # drain, independent of how far the stream has run
                bases = bases + np.asarray(qp.count, dtype=np.int64)
                drain_sink.on_chunk(ent["t0"] + (i + 1) * ent["c"],
                                    msp, qp, bp, bases.copy())

    def drain_all() -> None:
        while pending:
            drain_one(pending.pop(0))

    while t < spec0.steps:
        c = min(c_full, spec0.steps - t)
        # (a) failure-schedule swap: host-only work — the masks are
        # traced inputs, so a swap needs no device sync
        new_specs = None if fail_schedule is None else fail_schedule(t)
        if new_specs is not None:
            new_specs = list(new_specs)
            if (len(new_specs) != n_b
                    or any(_neutral(s) != nspec for s in new_specs)):
                raise ValueError(
                    "fail_schedule must return one spec per lane, "
                    "differing from the originals only in failure "
                    "masks, stakes or quorum thresholds (all traced "
                    "inputs — anything else would force a recompile)")
            fails = _stacked_fails(new_specs)._replace(
                commit_floor=jnp.asarray(floors, dtype=jnp.int32))
            retire_check = np.array([retire_safety_stakes_ok(s)
                                     for s in new_specs])
        # (b) recorder checkpoint: mandatory host interaction — flush
        # the pipeline so the captured state is exactly the boundary
        # state and the recorded trace stays bit-exact
        if recorder is not None and recorder.wants(t):
            drain_all()
            _HOST_SYNCS[0] += 1
            _tc = obs_begin()
            recorder.capture(ChunkCheckpoint(
                t=t, window_slots=w, bases=bases.copy(),
                state=_np_state(_sim(carry)), fails=_np_state(fails),
                floors=floors.copy(),
                out_quack=out_quack.copy(), out_deliver=out_deliver.copy(),
                out_retry=out_retry.copy(), out_recv=out_recv.copy(),
                metric_parts=tuple(metric_parts),
                bases_hist=np.stack(bases_hist),
                growth_events=tuple(growth_events),
                send_step=send_step.copy()))
            obs_end(_tc, "checkpoint", cat="snapshot", t=t)
        # (c) commit floors are a function of this boundary's actual
        # retired prefixes, so the pipeline drains before asking
        if commit_floors is not None:
            drain_all()
            _tp = obs_begin()
            new_floors = np.asarray(commit_floors(t, bases.copy()),
                                    dtype=np.int64)
            obs_end(_tp, "plan_floors", cat="plan", t=t)
            if not np.array_equal(new_floors, floors):
                floors = new_floors
                fails = fails._replace(
                    commit_floor=jnp.asarray(floors, dtype=jnp.int32))
        # (c2) dispatch-round mirror: floors that opened since the last
        # boundary dispatch their newly-committed messages at
        # max(schedule round, now) — standalone links (floor = M at
        # t = 0) reduce to the schedule rounds exactly
        if send_step is not None and (floors > open_floor).any():
            for b in np.nonzero(floors > open_floor)[0]:
                ks = np.arange(open_floor[b], floors[b])
                send_step[b, ks] = np.maximum(ostep[ks], t)
                open_floor[b] = floors[b]
        # (d) per-scenario overflow check: a scenario dispatches nothing
        # past its commit floor, so its window need is capped by
        # floor - 1 and measured against its OWN base (a chained link's
        # lagging base must not force growth for messages it cannot send
        # yet). The check is evaluated against the host-side
        # dispatched_by/floors mirrors first; only a *potential*
        # overflow blocks on the in-flight dispatch for the exact bases.
        need_b = np.minimum(int(dispatched_by[t + c - 1]), floors - 1)
        if pending and (need_b - bases >= w).any():
            drain_all()
        over = need_b - bases
        b_worst = int(over.argmax())
        if over[b_worst] >= w:
            drain_all()
            new_w = _widen_on_overflow(spec0, w, int(bases[b_worst]),
                                       int(need_b[b_worst]), t + c - 1)
            growth_events.append(WindowGrowthEvent(
                step=t + c - 1, scenario=b_worst,
                need=int(need_b[b_worst]), old_w=w,
                new_w=m if new_w is None else new_w,
                dense_migration=new_w is None))
            if new_w is None:
                if not retain:
                    # the width that would have held this overflow:
                    # enough slots above the stalled lane's frontier to
                    # cover its dispatch head, rounded to the 64-slot
                    # granularity stream_window_slots uses
                    span = int(need_b[b_worst]) + 1 - int(bases[b_worst])
                    suggest = int(-(-span // 64) * 64)
                    raise RuntimeError(
                        "stream session window overflow: the dense "
                        "fallback would allocate the full horizon "
                        f"(W={w} -> M={m}). Lane {b_worst}'s dispatch "
                        f"head is {int(need_b[b_worst])} with GC "
                        f"frontier {int(bases[b_worst])}, so "
                        f"stream_window_slots >= {suggest} would have "
                        "sufficed — pass SimConfig(window_slots="
                        f"{suggest}) (or raise the slack in repro."
                        "stream.workload.stream_window_slots), or "
                        "lower the arrival rate")
                _tg = obs_begin()
                sim_state = _migrate_dense_batch(
                    spec0, _sim(carry), bases, out_quack,
                    out_deliver, out_retry, out_recv)
                if collect:
                    carry = (sim_state, migrate_dense_metrics(
                        carry[1], bases, send_step, m))
                else:
                    carry = sim_state
                _HOST_SYNCS[0] += 1
                bases[:] = 0
                w = m
                obs_end(_tg, "dense_migration", cat="window", t=t,
                        new_w=m)
            else:
                _tg = obs_begin()
                if collect:
                    carry = (_grow_state(carry[0], new_w),
                             pad_metrics(carry[1], new_w))
                else:
                    carry = _grow_state(carry, new_w)
                w = new_w
                obs_end(_tg, "window_growth", cat="window", t=t,
                        new_w=new_w)
        # (e) fusion span: up to K full rotating chunks per dispatch,
        # broken at every boundary where host interaction is mandatory —
        # a recorder checkpoint, a failure-schedule swap, a commit-floor
        # update, or the final (unrotated) chunk. Window overflow inside
        # the span is guarded *in-graph* (the superchunk stops at the
        # first boundary any lane would overflow and reports how far it
        # got), so the fusion length never depends on device results.
        # the replay subsystem stays on K = 1 chunk programs end to end:
        # recorded (parent) runs execute chunk-at-a-time so they compile
        # exactly the programs every later resume / schedule-edited
        # replay reuses — fusing either side would mint per-span-length
        # programs and break the replay/fork zero-recompilation
        # contract for some checkpoint spacings (tests/test_replay.py);
        # async drains still apply.
        fusible = (resume is None and fail_schedule is None
                   and recorder is None)
        last = t + c >= spec0.steps
        k = 1
        if not last and c == c_full and commit_floors is None and fusible:
            k = min(K, (spec0.steps - t - 1) // c_full)
        # launch-ahead is safe only when the conservative bound — zero
        # frontier advance over the whole span, measured from the
        # (possibly pre-drain) host bases — proves the in-graph overflow
        # guard cannot fire, so this span is final and the next
        # boundary's planning needs nothing from this dispatch's results
        span_need = np.minimum(int(dispatched_by[t + k * c - 1]),
                               floors - 1)
        async_ok = K > 1 and bool((span_need - bases < w).all())
        # (f) dispatch, then drain the *previous* dispatch's queue while
        # this one computes (async double buffering; JAX dispatch is
        # asynchronous, so the call returns before the device finishes)
        _td = obs_begin()
        traces_before = _CHUNK_TRACES[0]
        blk = None
        if k == 1:
            res = chunk_prog(w, c, not last)(fails, carry, jnp.int32(t))
            if collect:
                carry, ms, queue, blk = res
            else:
                carry, ms, queue = res
            oks = None
        else:
            needs = np.asarray(dispatched_by[t + c - 1:t + k * c:c],
                               dtype=np.int32)
            res = super_prog(w, c, k)(fails, carry, jnp.int32(t),
                                      jnp.asarray(needs))
            if collect:
                carry, ms, queue, oks, blk = res
            else:
                carry, ms, queue, oks = res
        _CHUNK_DISPATCHES[0] += 1
        obs_end(_td,
                "compile" if _CHUNK_TRACES[0] > traces_before
                else "dispatch",
                cat="dispatch", t=t, k=k)
        pending.append(dict(t0=t, k=k, c=c, rotate=not last, ms=ms,
                            queue=queue, oks=oks, blk=blk))
        t += k * c
        while len(pending) > 1:
            drain_one(pending.pop(0))
        if not async_ok:
            drain_all()   # sync regime (and the superchunk=1 legacy loop)

    drain_all()
    _tf = obs_begin()
    got = jax.device_get(carry)        # one batched fetch, carry incl.
    final = _sim(got)                  # the metrics carry when enabled
    final_mc = got[1] if collect else None
    _HOST_SYNCS[0] += 1
    if retain:
        _scatter_retired(
            bases, np.minimum(w, m - bases).clip(min=0),
            (final.quack_time, final.deliver_time, final.retry,
             final.recv_has),
            (out_quack, out_deliver, out_retry, out_recv))
    obs_end(_tf, "final_flush", cat="drain")

    if not retain:
        drain_sink.on_final(final, final_mc, bases.copy(), w,
                            tuple(growth_events), t)
        return []

    # sanitize the dispatch mirror: a round beyond the run never fired
    ss_all = np.where((send_step >= 0) & (send_step < spec0.steps),
                      send_step, -1).astype(np.int32)

    traj = np.stack(bases_hist)                     # (n_boundaries, n_b)
    all_metrics = _concat_metrics(n_b, metric_parts)
    events = tuple(growth_events)
    out = []
    for b, spec in enumerate(specs):
        metrics = StepMetrics(*(getattr(all_metrics, name)[b]
                                for name in StepMetrics._fields))
        out.append(SimResult(
            spec=spec, metrics=metrics,
            quack_time=out_quack[b], deliver_time=out_deliver[b],
            retry=out_retry[b], recv_has=out_recv[b],
            gc_frontiers=traj[:, b].astype(np.int64),
            final_window_slots=w,
            window_growth_events=events,
            send_step=ss_all[b],
            delivery_latency=_latency_from(ss_all[b], out_deliver[b]),
            obs=(obs_from_final(final_mc, obs_parts, b)
                 if collect else None),
        ))
    return out


def run_simulation_batch(specs: Sequence[SimSpec]) -> List[SimResult]:
    """Run many failure scenarios of one shape in a single compilation.

    All specs must share every non-failure field (same RSMs, schedules,
    thresholds and window config — e.g. from ``build_spec`` with different
    ``FailureScenario`` masks); the failure masks are stacked and the
    runner ``jax.vmap``-ed over them, so a whole sweep costs one compile +
    one device dispatch (per chunk, when windowed) instead of one
    ``lru_cache`` entry per scenario. Windowed specs run on the windowed
    kernel with per-scenario window bases (``_run_windowed_batch``) —
    O(B * W) device state instead of O(B * M) — and are bit-identical to
    per-scenario runs.
    """
    specs = list(specs)
    if not specs:
        return []
    require_uniform_batch(specs)
    if specs[0].window_slots:
        return _run_windowed_batch(specs)
    return _run_dense_batch(specs)


def require_uniform_batch(specs: Sequence[SimSpec]) -> None:
    """Raise unless the specs differ only in their failure masks.

    The shared precondition of every vmapped dispatch: one compilation
    serves the whole batch only when shapes, schedules, thresholds and
    window config agree. Used by ``run_simulation_batch`` and the
    topology engine (where each batch member is one link of the graph).
    """
    nspec = _neutral(specs[0])
    win_key = (specs[0].window_slots, specs[0].chunk_steps,
               specs[0].adaptive_window, specs[0].superchunk,
               specs[0].debug_checks)
    for s in specs[1:]:
        if (_neutral(s) != nspec
                or (s.window_slots, s.chunk_steps, s.adaptive_window,
                    s.superchunk, s.debug_checks)
                != win_key):
            raise ValueError("run_simulation_batch: specs differ outside "
                             "their failure masks; batch members must share "
                             "shapes, schedules, thresholds and window "
                             "config (window_slots / chunk_steps / "
                             "adaptive_window)")
