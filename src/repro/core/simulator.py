"""Vectorized PICSOU simulator (synchronous rounds, ``jax.lax.scan``).

The simulator executes the *full* protocol of §4–§5 — round-robin / DSS
send scheduling, receiver rotation, intra-RSM broadcast, cumulative +
phi-list acknowledgements, QUACK formation, duplicate-complaint loss
detection, communication-free retransmitter election, GC with the
highest-quacked metadata defence, stake weighting and LCM-scaled
retransmission rotation — as dense array state transitions, one scan step
per synchronous round (one cross-RSM RTT).

Semantics of a round ``t`` (matching Figure 3/4/5/6 of the paper):
  1. intra-RSM broadcasts queued at t-1 land;
  2. retransmissions are declared/elected from knowledge as of t-1 and the
     corresponding resends are put on the wire;
  3. scheduled original sends for round t are put on the wire; direct sends
     land at their receiver (unless dropped) and queue a broadcast;
  4. every alive receiver acks (cumulative counter + phi-list + implicit
     duplicate-cum complaint) to its rotating target sender; senders fold
     the ack into their knowledge; QUACK / GC state advances.

The pure-python oracle in ``refsim.py`` mirrors this loop unvectorized;
``tests/test_simulator.py`` cross-checks them step by step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import scheduler as sched
from .quack import claim_bitmask, missing_below_horizon, weighted_quorum_prefix
from .types import (COUNTER_BYTES, MAC_BYTES, SEQNO_BYTES, FailureScenario,
                    NetworkModel, RSMConfig, SimConfig, lcm_scale_factors)

__all__ = ["SimSpec", "SimResult", "build_spec", "run_simulation"]

NEVER = jnp.int32(-1)


@dataclasses.dataclass(frozen=True)
class SimSpec:
    """Fully-resolved, static simulation plan (hashable closure inputs)."""

    n_s: int
    n_r: int
    m: int
    steps: int
    phi: int
    quack_thresh: float      # u_r + 1 (stake units)
    dup_thresh: float        # r_r + 1 (stake units); 1 in CFT mode
    hq_thresh: float         # r_s + 1 (stake units)
    stakes_s: Tuple[float, ...]
    stakes_r: Tuple[float, ...]
    orig_sender: Tuple[int, ...]      # (M,)
    orig_recv: Tuple[int, ...]        # (M,)
    orig_step: Tuple[int, ...]        # (M,) dispatch round of original send
    rs_seq: Tuple[int, ...]           # retransmit sender rotation sequence
    rr_seq: Tuple[int, ...]           # retransmit receiver rotation sequence
    crash_s: Tuple[int, ...]
    crash_r: Tuple[int, ...]
    byz_send_drop: Tuple[bool, ...]
    byz_recv_drop: Tuple[bool, ...]
    byz_ack_advance: Tuple[int, ...]
    byz_ack_low: Tuple[bool, ...]
    byz_bcast_partial: Tuple[bool, ...]
    bcast_limit: int


class SimState(NamedTuple):
    recv_has: jnp.ndarray      # (n_r, M) bool — receiver truly holds k
    bcast_q: jnp.ndarray       # (n_r, M) bool — queued broadcast for t+1
    bcast_done: jnp.ndarray    # (n_r, M) bool
    known: jnp.ndarray         # (n_s, n_r, M) bool — j's claims known to l
    complaint: jnp.ndarray     # (n_s, n_r, M) bool — j's last complaint to l
    repeat_c: jnp.ndarray      # (n_s, n_r, M) bool — complained twice to l
    last_cum: jnp.ndarray      # (n_s, n_r) int32
    retry: jnp.ndarray         # (n_s, M) int32
    quack_time: jnp.ndarray    # (n_s, M) int32, -1 = not yet
    deliver_time: jnp.ndarray  # (M,) int32, -1 = not yet
    hq_reports: jnp.ndarray    # (n_r, n_s) int32
    ack_floor: jnp.ndarray     # (n_r,) int32


class StepMetrics(NamedTuple):
    cross_msgs: jnp.ndarray     # direct cross-RSM data copies this round
    intra_msgs: jnp.ndarray     # broadcast copies this round
    resends: jnp.ndarray        # retransmissions this round
    acks: jnp.ndarray           # ack messages this round
    delivered: jnp.ndarray      # cumulative messages delivered
    min_quack_prefix: jnp.ndarray  # min honest-sender quacked prefix


@dataclasses.dataclass
class SimResult:
    spec: SimSpec
    metrics: "np.ndarray-like"            # StepMetrics of (T,) arrays
    quack_time: np.ndarray                # (n_s, M)
    deliver_time: np.ndarray              # (M,)
    retry: np.ndarray                     # (n_s, M)
    recv_has: np.ndarray                  # (n_r, M)

    # --- derived -------------------------------------------------------
    def completion_step(self) -> int:
        """Round by which every message is QUACKed at every honest sender."""
        honest = _honest_mask(self.spec.crash_s, self.spec.byz_send_drop)
        qt = self.quack_time[honest]
        if qt.size == 0 or (qt < 0).any():
            return -1
        return int(qt.max())

    def delivery_step(self) -> int:
        if (self.deliver_time < 0).any():
            return -1
        return int(self.deliver_time.max())

    def total_cross_msgs(self) -> int:
        return int(np.sum(self.metrics.cross_msgs))

    def total_intra_msgs(self) -> int:
        return int(np.sum(self.metrics.intra_msgs))

    def total_resends(self) -> int:
        return int(np.sum(self.metrics.resends))

    def max_resends_per_msg(self) -> int:
        honest = _honest_mask(self.spec.crash_s, self.spec.byz_send_drop)
        if not honest.any():
            return 0
        return int(self.retry[honest].max())


def _honest_mask(crash, byz_flags) -> np.ndarray:
    crash = np.asarray(crash)
    byz = np.asarray(byz_flags)
    return (crash < 0) & ~byz


def build_spec(sender: RSMConfig, receiver: RSMConfig,
               sim: SimConfig = SimConfig(),
               failures: FailureScenario = FailureScenario.none(),
               use_lcm_scaling: bool = True) -> SimSpec:
    """Resolve schedules + failure masks into a static SimSpec."""
    n_s, n_r, m = sender.n, receiver.n, sim.n_msgs
    st_s = np.asarray(sender.stakes, dtype=np.float64)
    st_r = np.asarray(receiver.stakes, dtype=np.float64)

    orig_sender = sched.sender_assignment(
        sim.scheduler, st_s, m, quantum=sim.quantum, seed=sim.seed)
    orig_recv = sched.receiver_for(
        orig_sender, n_r, recv_stakes=st_r, scheduler=sim.scheduler,
        quantum=sim.quantum, seed=sim.seed + 1)

    # dispatch round of each original send: the i-th message of sender l is
    # sent in round i // window (window sends per sender per round).
    orig_step = np.zeros(m, dtype=np.int64)
    counters = np.zeros(n_s, dtype=np.int64)
    for k in range(m):
        l = orig_sender[k]
        orig_step[k] = counters[l] // max(sim.window, 1)
        counters[l] += 1

    # retransmission rotation sequences (§4.2 unit-stake, §5.3 staked+LCM).
    unit_s = np.allclose(st_s, st_s[0])
    unit_r = np.allclose(st_r, st_r[0])
    if unit_s and unit_r:
        rs_seq = np.arange(n_s, dtype=np.int64)
        rr_seq = np.arange(n_r, dtype=np.int64)
    else:
        psi_s, psi_r = (lcm_scale_factors(st_s.sum(), st_r.sum())
                        if use_lcm_scaling else (1.0, 1.0))
        # quota each replica proportional to (scaled) stake, smoothed.
        q_s = max(n_s, min(4 * n_s, int(np.ceil(st_s.sum() * psi_s
                                                / max(st_s.min() * psi_s, 1)))))
        q_r = max(n_r, min(4 * n_r, int(np.ceil(st_r.sum() * psi_r
                                                / max(st_r.min() * psi_r, 1)))))
        rs_seq = sched.dss_sequence(st_s * psi_s, q_s, q_s)
        rr_seq = sched.dss_sequence(st_r * psi_r, q_r, q_r)

    def tup(x, n, default):
        if x is None:
            return tuple([default] * n)
        return tuple(x)

    return SimSpec(
        n_s=n_s, n_r=n_r, m=m, steps=sim.steps, phi=sim.phi,
        quack_thresh=receiver.quack_threshold,
        dup_thresh=receiver.dup_threshold,
        hq_thresh=max(sender.r + 1, 1),
        stakes_s=tuple(float(x) for x in st_s),
        stakes_r=tuple(float(x) for x in st_r),
        orig_sender=tuple(int(x) for x in orig_sender),
        orig_recv=tuple(int(x) for x in orig_recv),
        orig_step=tuple(int(x) for x in orig_step),
        rs_seq=tuple(int(x) for x in rs_seq),
        rr_seq=tuple(int(x) for x in rr_seq),
        crash_s=tup(failures.crash_s, n_s, -1),
        crash_r=tup(failures.crash_r, n_r, -1),
        byz_send_drop=tup(failures.byz_send_drop, n_s, False),
        byz_recv_drop=tup(failures.byz_recv_drop, n_r, False),
        byz_ack_advance=tup(failures.byz_ack_advance, n_r, 0),
        byz_ack_low=tup(failures.byz_ack_low, n_r, False),
        byz_bcast_partial=tup(failures.byz_bcast_partial, n_r, False),
        bcast_limit=failures.bcast_limit,
    )


@functools.lru_cache(maxsize=64)
def _compiled_sim(spec: SimSpec):
    """Build + jit the scan for a spec (cached: specs are hashable)."""
    n_s, n_r, m = spec.n_s, spec.n_r, spec.m
    phi = spec.phi

    stakes_s = jnp.asarray(spec.stakes_s, dtype=jnp.float32)
    stakes_r = jnp.asarray(spec.stakes_r, dtype=jnp.float32)
    orig_sender = jnp.asarray(spec.orig_sender, dtype=jnp.int32)
    orig_recv = jnp.asarray(spec.orig_recv, dtype=jnp.int32)
    orig_step = jnp.asarray(spec.orig_step, dtype=jnp.int32)
    rs_seq = jnp.asarray(spec.rs_seq, dtype=jnp.int32)
    rr_seq = jnp.asarray(spec.rr_seq, dtype=jnp.int32)
    crash_s = jnp.asarray(spec.crash_s, dtype=jnp.int32)
    crash_r = jnp.asarray(spec.crash_r, dtype=jnp.int32)
    byz_send_drop = jnp.asarray(spec.byz_send_drop, dtype=bool)
    byz_recv_drop = jnp.asarray(spec.byz_recv_drop, dtype=bool)
    byz_ack_advance = jnp.asarray(spec.byz_ack_advance, dtype=jnp.int32)
    byz_ack_low = jnp.asarray(spec.byz_ack_low, dtype=bool)
    byz_bcast_partial = jnp.asarray(spec.byz_bcast_partial, dtype=bool)

    idx_m = jnp.arange(m, dtype=jnp.int32)
    idx_r = jnp.arange(n_r, dtype=jnp.int32)
    idx_s = jnp.arange(n_s, dtype=jnp.int32)
    honest_r = (crash_r < 0) & ~(byz_recv_drop | byz_ack_low
                                 | (byz_ack_advance > 0) | byz_bcast_partial)
    honest_s = (crash_s < 0) & ~byz_send_drop
    ls, lr = len(spec.rs_seq), len(spec.rr_seq)

    # broadcast reach matrix (n_r, n_r): who hears j's intra-RSM broadcast.
    reach = np.ones((n_r, n_r), dtype=bool)
    for j in range(n_r):
        if spec.byz_bcast_partial[j]:
            reach[j, :] = False
            reach[j, :max(spec.bcast_limit, 0)] = True
        reach[j, j] = False
    reach = jnp.asarray(reach)

    def step(state: SimState, t: jnp.ndarray):
        alive_s = (crash_s < 0) | (t < crash_s)
        alive_r = (crash_r < 0) | (t < crash_r)

        # (1) broadcasts queued last round land now ------------------------
        bcast_sent = state.bcast_q & alive_r[:, None]
        recv_from_bcast = jnp.einsum("jk,ji->ik", bcast_sent, reach) > 0
        recv_has = state.recv_has | (recv_from_bcast & alive_r[:, None])
        bcast_done = state.bcast_done | bcast_sent

        # (2) retransmission declaration + election (knowledge of t-1) -----
        w_complaints = jnp.einsum("ljm,j->lm",
                                  state.repeat_c.astype(jnp.float32), stakes_r)
        quacked_msg_prev = (jnp.einsum("ljm,j->lm",
                                       state.known.astype(jnp.float32),
                                       stakes_r) >= spec.quack_thresh)
        declared = ((w_complaints >= spec.dup_thresh)
                    & ~quacked_msg_prev
                    & (orig_step[None, :] < t))
        retry_new = state.retry + declared.astype(jnp.int32)
        # Fig. 6: the a-th retransmission of k is sent by the a-th successor
        # of the original sender: sender_new = (orig + #retransmit) mod n_s.
        elected = rs_seq[(idx_m[None, :] + retry_new) % ls] == idx_s[:, None]
        resend = declared & elected & alive_s[:, None] & ~byz_send_drop[:, None]
        # clear complaint trackers where a loss was declared (fresh cycle)
        complaint = jnp.where(declared[:, None, :], False, state.complaint)
        repeat_c = jnp.where(declared[:, None, :], False, state.repeat_c)
        re_target = rr_seq[(orig_recv[None, :] + retry_new) % lr]  # (n_s, M)

        # (3) original sends + landing --------------------------------------
        orig_ok = ((orig_step == t) & alive_s[orig_sender]
                   & ~byz_send_drop[orig_sender])
        s_orig = orig_ok[None, :] & (orig_recv[None, :] == idx_r[:, None])
        s_re = (jnp.einsum("lm,lim->im", resend.astype(jnp.int32),
                           (re_target[:, None, :] == idx_r[None, :, None])
                           .astype(jnp.int32)) > 0)
        wire = s_orig | s_re                                   # (n_r, M)
        land = wire & alive_r[:, None] & ~byz_recv_drop[:, None]
        recv_has = recv_has | land
        bcast_q = land & ~bcast_done
        deliver_now = (recv_has & honest_r[:, None]).any(axis=0)
        deliver_time = jnp.where((state.deliver_time < 0) & deliver_now,
                                 t, state.deliver_time)

        # (3b) highest-quacked metadata rides on every landed data message:
        # a sender's current quacked prefix reaches every receiver it sent
        # anything to this round (constant-size piggyback, §4.3).
        qp_prev = jnp.sum(jnp.cumprod(quacked_msg_prev.astype(jnp.int32),
                                      axis=1), axis=1)        # (n_s,)
        e_lk = ((orig_sender[None, :] == idx_s[:, None])
                & orig_ok[None, :])                            # (n_s, M)
        sent_orig_to = jnp.einsum("lk,ik->li", e_lk.astype(jnp.int32),
                                  s_orig.astype(jnp.int32)) > 0
        sent_re_to = jnp.einsum(
            "lm,lim->li", resend.astype(jnp.int32),
            (re_target[:, None, :] == idx_r[None, :, None]).astype(jnp.int32)
        ) > 0
        heard = (sent_orig_to | sent_re_to).T                  # (n_r, n_s)
        hq_new = jnp.where(heard & alive_r[:, None], qp_prev[None, :], 0)
        hq_reports = jnp.maximum(state.hq_reports, hq_new)

        # (4) acknowledgements ---------------------------------------------
        ack_floor = weighted_quorum_prefix(hq_reports, stakes_s,
                                           spec.hq_thresh)
        ack_floor = jnp.maximum(state.ack_floor, ack_floor)
        eff = recv_has | (idx_m[None, :] < ack_floor[:, None])
        cum, claim, _known_mask = claim_bitmask(eff, phi)
        miss = missing_below_horizon(eff, phi)
        # Byzantine lies --------------------------------------------------
        cum = jnp.where(byz_ack_low, 0, cum)
        cum = jnp.where(byz_ack_advance > 0,
                        jnp.minimum(cum + byz_ack_advance, m), cum)
        claim = jnp.where(byz_ack_low[:, None], False, claim)
        claim = jnp.where((byz_ack_advance > 0)[:, None],
                          idx_m[None, :] < cum[:, None], claim)
        miss = jnp.where(byz_ack_low[:, None], idx_m[None, :] < phi, miss)
        miss = jnp.where((byz_ack_advance > 0)[:, None], False, miss)
        # implicit duplicate-cum complaint: cum unchanged since last ack to
        # the same sender => complain about index cum (if it exists).
        tgt = (idx_r + t) % n_s                                  # (n_r,)
        upd = (tgt[None, :] == idx_s[:, None]) & alive_r[None, :]  # (n_s,n_r)
        dup_cum = (state.last_cum == cum[None, :])               # (n_s, n_r)
        dup_complaint = (dup_cum[:, :, None]
                         & (idx_m[None, None, :] == cum[None, :, None])
                         & (cum[None, :, None] < m))
        new_complaint = miss[None, :, :] | dup_complaint         # (n_s,n_r,M)
        known = state.known | (upd[:, :, None] & claim[None, :, :])
        repeat_c = jnp.where(upd[:, :, None],
                             repeat_c | (complaint & new_complaint), repeat_c)
        complaint = jnp.where(upd[:, :, None], new_complaint, complaint)
        last_cum = jnp.where(upd, cum[None, :], state.last_cum)

        # (5) QUACK bookkeeping --------------------------------------------
        quacked_msg = (jnp.einsum("ljm,j->lm", known.astype(jnp.float32),
                                  stakes_r) >= spec.quack_thresh)
        quack_time = jnp.where((state.quack_time < 0) & quacked_msg,
                               t, state.quack_time)

        new_state = SimState(
            recv_has=recv_has, bcast_q=bcast_q, bcast_done=bcast_done,
            known=known, complaint=complaint, repeat_c=repeat_c,
            last_cum=last_cum, retry=retry_new, quack_time=quack_time,
            deliver_time=deliver_time, hq_reports=hq_reports,
            ack_floor=ack_floor)

        qp = jnp.sum(jnp.cumprod(quacked_msg.astype(jnp.int32), axis=1),
                     axis=1)
        min_qp = jnp.min(jnp.where(honest_s, qp, jnp.int32(2 ** 30)))
        metrics = StepMetrics(
            cross_msgs=(orig_ok.sum() + resend.sum()).astype(jnp.int32),
            intra_msgs=jnp.einsum("jk,j->", bcast_sent.astype(jnp.int32),
                                  reach.sum(axis=1).astype(jnp.int32)
                                  ).astype(jnp.int32),
            resends=resend.sum().astype(jnp.int32),
            acks=alive_r.sum().astype(jnp.int32),
            delivered=(deliver_time >= 0).sum().astype(jnp.int32),
            min_quack_prefix=min_qp.astype(jnp.int32),
        )
        return new_state, metrics

    def init_state() -> SimState:
        f, b = jnp.zeros, jnp.full
        return SimState(
            recv_has=f((n_r, m), dtype=bool),
            bcast_q=f((n_r, m), dtype=bool),
            bcast_done=f((n_r, m), dtype=bool),
            known=f((n_s, n_r, m), dtype=bool),
            complaint=f((n_s, n_r, m), dtype=bool),
            repeat_c=f((n_s, n_r, m), dtype=bool),
            last_cum=b((n_s, n_r), -1, dtype=jnp.int32),
            retry=f((n_s, m), dtype=jnp.int32),
            quack_time=b((n_s, m), -1, dtype=jnp.int32),
            deliver_time=b((m,), -1, dtype=jnp.int32),
            hq_reports=f((n_r, n_s), dtype=jnp.int32),
            ack_floor=f((n_r,), dtype=jnp.int32),
        )

    @jax.jit
    def run():
        state0 = init_state()
        ts = jnp.arange(spec.steps, dtype=jnp.int32)
        final, ms = jax.lax.scan(step, state0, ts)
        return final, ms

    return run


def run_simulation(spec: SimSpec) -> SimResult:
    final, ms = _compiled_sim(spec)()
    final = jax.tree_util.tree_map(np.asarray, final)
    ms = jax.tree_util.tree_map(np.asarray, ms)
    return SimResult(
        spec=spec,
        metrics=StepMetrics(*ms),
        quack_time=final.quack_time,
        deliver_time=final.deliver_time,
        retry=final.retry,
        recv_has=final.recv_has,
    )
