"""The three C3B protocols of §6: PICSOU, ATA, OST.

Each protocol exposes
  * ``loads(...)``  — the per-message resource profile for the analytic
    capacity model (``network.py``), and
  * ``simulate(...)`` — the step simulator run (PICSOU only; ATA and OST
    have closed-form message counts and no ack machinery).

Copies of a message m sent across RSMs (Figure 2):
  ATA    : n_s * n_r   (every replica to every replica; no acks; robust)
  OST    : 1           (single pair; NOT a C3B — delivery not guaranteed)
  PICSOU : 1 + resends (QUACK-driven; the theoretical minimum, robust)
plus intra-RSM: PICSOU broadcasts each message once inside the receiver
RSM (n_r - 1 copies); ATA needs no intra-RSM broadcast.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from .network import NodeLoad, Resources, throughput_from_loads
from .simulator import (SimResult, SimSpec, build_spec, run_simulation,
                        run_simulation_batch)
from .types import (COUNTER_BYTES, MAC_BYTES, SEQNO_BYTES, FailureScenario,
                    NetworkModel, RSMConfig, SimConfig)

__all__ = ["picsou_loads", "ata_loads", "ost_loads", "analytic_throughput",
           "C3BRun", "run_picsou", "run_picsou_batch"]


def _ack_bytes(cfg: RSMConfig, backlog: int = 0) -> float:
    """Cumulative counter + quack counter + phi entries (+ MAC if BFT)."""
    b = 2 * COUNTER_BYTES + SEQNO_BYTES * backlog
    if cfg.r > 0:
        b += MAC_BYTES
    return float(b)


def picsou_loads(ns: int, nr: int, net: NetworkModel,
                 sender_cfg: RSMConfig, recv_cfg: RSMConfig,
                 resend_factor: float = 0.0,
                 window: int = 8) -> Resources:
    """PICSOU per-delivered-message loads (§4.1 failure-free + resends).

    resend_factor: expected extra cross copies per message (0 when
    failure-free; ~failure fraction otherwise — each resend re-crosses and
    re-broadcasts).
    """
    s = net.msg_bytes
    a = _ack_bytes(recv_cfg)
    rf = 1.0 + resend_factor
    sender = NodeLoad(
        egress_bytes=rf * s / ns,          # originates 1/ns of the stream
        ingress_bytes=a,                   # one ack per round, piggybacked
        msg_ops=rf * 1.0 / ns + 1.0 / ns,  # send + ack processing share
        cross_egress_bytes=rf * s / ns,
    )
    receiver = NodeLoad(
        # direct share + intra-broadcast ingress of everyone else's shares
        ingress_bytes=rf * s / nr + s * (nr - 1) / nr,
        # re-broadcast of its direct share to nr-1 peers + ack egress
        egress_bytes=rf * s * (nr - 1) / nr + a,
        msg_ops=rf * 1.0 / nr + 1.0 + 1.0 / nr,  # recv + bcast handling
        cross_egress_bytes=a,
    )
    return Resources(
        loads={"sender": sender, "receiver": receiver},
        cross_pair_bytes=rf * s / (ns * nr),   # rotation spreads over pairs
        pairs_used=nr,
        inflight_sources=ns,
        window=window,
    )


def ata_loads(ns: int, nr: int, net: NetworkModel,
              sender_cfg: RSMConfig, recv_cfg: RSMConfig,
              window: int = 8) -> Resources:
    """All-to-all: every replica sends every message to every peer."""
    s = net.msg_bytes
    sender = NodeLoad(
        egress_bytes=s * nr,               # each sender sends nr copies
        msg_ops=float(nr),
        cross_egress_bytes=s * nr,
    )
    receiver = NodeLoad(
        ingress_bytes=s * ns,              # each receiver ingests ns copies
        msg_ops=float(ns),
    )
    return Resources(
        loads={"sender": sender, "receiver": receiver},
        cross_pair_bytes=s,                # every pair carries every message
        pairs_used=nr,
        inflight_sources=ns,
        window=window,
    )


def ost_loads(ns: int, nr: int, net: NetworkModel,
              sender_cfg: RSMConfig, recv_cfg: RSMConfig,
              window: int = 8) -> Resources:
    """One-shot upper bound: single sender-receiver pair per message."""
    s = net.msg_bytes
    sender = NodeLoad(egress_bytes=s / ns, msg_ops=1.0 / ns,
                      cross_egress_bytes=s / ns)
    receiver = NodeLoad(ingress_bytes=s / nr, msg_ops=1.0 / nr)
    return Resources(
        loads={"sender": sender, "receiver": receiver},
        cross_pair_bytes=s / (ns * nr),
        pairs_used=1,                      # unique pairs, no fan-out
        inflight_sources=ns,
        window=window,
    )


_LOADS = {"picsou": picsou_loads, "ata": ata_loads, "ost": ost_loads}


def analytic_throughput(protocol: str, sender_cfg: RSMConfig,
                        recv_cfg: RSMConfig, net: NetworkModel,
                        resend_factor: float = 0.0,
                        window: int = 8) -> Dict[str, float]:
    kw = dict(window=window)
    if protocol == "picsou":
        kw["resend_factor"] = resend_factor
    res = _LOADS[protocol](sender_cfg.n, recv_cfg.n, net,
                           sender_cfg, recv_cfg, **kw)
    return throughput_from_loads(res, net)


def staked_picsou_throughput(stakes, nic_Bps,
                             net: NetworkModel) -> Dict[str, float]:
    """Stake-aware PICSOU capacity (§6.3 scenarios).

    DSS apportions send/receive work proportional to stake, so replica i
    carries share_i = stake_i / total of the per-message load on both the
    send and the receive/broadcast side; the system rate is bound by the
    most-loaded replica relative to its own NIC:

      sender bound_i   = NIC_i / (share_i * s * n)        (its sends)
      receiver bound_i = NIC_i / (share_i * s * (n - 1))  (its broadcasts)
    """
    import numpy as _np
    stakes = _np.asarray(stakes, dtype=_np.float64)
    nic = _np.broadcast_to(_np.asarray(nic_Bps, dtype=_np.float64),
                           stakes.shape)
    share = stakes / stakes.sum()
    n = len(stakes)
    s = net.msg_bytes
    send_bound = nic / _np.maximum(share * s * n, 1e-12)
    recv_bound = nic / _np.maximum(share * s * max(n - 1, 1), 1e-12)
    tput = float(min(send_bound.min(), recv_bound.min()))
    # also bounded by the balanced-case receiver ingress NIC/s
    tput = min(tput, float(nic.min()) / s * n / max(n - 1, 1))
    return {"throughput_msgs_per_s": tput,
            "binding_replica": int(_np.argmin(_np.minimum(send_bound,
                                                          recv_bound)))}


@dataclasses.dataclass
class C3BRun:
    """A PICSOU simulator run + derived protocol-level statistics."""

    result: SimResult
    spec: SimSpec

    @property
    def cross_copies_per_msg(self) -> float:
        return self.result.total_cross_msgs() / self.spec.m

    @property
    def intra_copies_per_msg(self) -> float:
        return self.result.total_intra_msgs() / self.spec.m

    @property
    def resends_per_msg(self) -> float:
        return self.result.total_resends() / self.spec.m

    @property
    def all_quacked(self) -> bool:
        return self.result.completion_step() >= 0

    @property
    def all_delivered(self) -> bool:
        return self.result.delivery_step() >= 0

    def quack_throughput_per_step(self) -> float:
        """Unique QUACKs per round at a correct replica (§6 definition)."""
        done = self.result.completion_step()
        if done < 0:
            return 0.0
        return self.spec.m / max(done, 1)


def run_picsou(sender_cfg: RSMConfig, recv_cfg: RSMConfig,
               sim: SimConfig = SimConfig(),
               failures: FailureScenario = FailureScenario.none()) -> C3BRun:
    spec = build_spec(sender_cfg, recv_cfg, sim, failures)
    return C3BRun(result=run_simulation(spec), spec=spec)


def run_picsou_batch(sender_cfg: RSMConfig, recv_cfg: RSMConfig,
                     sim: SimConfig,
                     scenarios: Sequence[FailureScenario]) -> List[C3BRun]:
    """Run a whole failure-scenario sweep in one compilation (jax.vmap).

    All scenarios share the schedules/thresholds of (sender_cfg, recv_cfg,
    sim); their failure masks are stacked and dispatched as a single
    batched simulation (``run_simulation_batch``), so a sweep costs one
    compile + one device call instead of one cached program per scenario.
    """
    specs = [build_spec(sender_cfg, recv_cfg, sim, f) for f in scenarios]
    return [C3BRun(result=r, spec=s)
            for s, r in zip(specs, run_simulation_batch(specs))]
