"""Readable pure-numpy reference simulator (the protocol oracle).

This mirrors ``simulator.py`` step-for-step but in explicit loops, so the
protocol logic can be read top-to-bottom against §4–§5 of the paper and the
vectorized implementation can be cross-checked exactly
(``tests/test_simulator.py::test_jax_matches_reference``).

For a windowed spec (``spec.window_slots > 0``) the oracle also mirrors
the sliding-window machinery: it keeps full dense state (it is the
*oracle*, it never forgets) but advances the same GC frontier with the
same shared ``gc.gc_frontier`` rule at the same chunk boundaries as the
jax windowed path — including the adaptive overflow policy
(``gc.grow_window``: widen the mirrored window 2x when a stalled frontier
would overflow it, or mark the run as fallen back to dense, in which case
``gc_frontiers`` collapses to the trivial ``[0]`` trajectory exactly like
``SimResult``) — snapshots every retired slot's outputs at retirement
time, and asserts at the end of the run that none of them ever changed
afterwards. That is the ground truth for the windowed core: if the
retirement rule ever forgot a slot whose state could still move, the
snapshot check fails here first. The frontier trajectory is returned in
``RefResult.gc_frontiers`` so tests can compare it bit-for-bit against
``SimResult.gc_frontiers``, and ``RefResult.retired_quack_margin`` records
the smallest stake-weighted QUACK margin over all retired slots (a retired
slot must be QUACKed at *every* sender — §4.3's "both sides may forget the
quacked prefix").
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .gc import gc_frontier
from .simulator import (SimSpec, _NEVER_STEP, _max_msg_by_round,
                        _widen_on_overflow)

__all__ = ["run_reference"]


@dataclasses.dataclass
class RefResult:
    quack_time: np.ndarray    # (n_s, M)
    deliver_time: np.ndarray  # (M,)
    retry: np.ndarray         # (n_s, M)
    recv_has: np.ndarray      # (n_r, M)
    cross_msgs: np.ndarray    # (T,)
    intra_msgs: np.ndarray    # (T,)
    resends: np.ndarray       # (T,)
    gc_frontiers: Optional[np.ndarray] = None   # (n_chunks,) window base
    retired_quack_margin: Optional[float] = None


def _cum(received_row: np.ndarray) -> int:
    p = 0
    for v in received_row:
        if not v:
            break
        p += 1
    return p


def _claim_and_missing(received_row: np.ndarray, phi: int):
    """Honest ack payload: (cum, claim bitmask, missing list<=phi)."""
    m = received_row.shape[0]
    cum = _cum(received_row)
    top = 0
    for k in range(m - 1, -1, -1):
        if received_row[k]:
            top = k + 1
            break
    missing = [k for k in range(top) if not received_row[k]][:phi]
    # horizon: strictly below the (phi+1)-th missing index
    gaps = [k for k in range(m) if not received_row[k]]
    horizon = gaps[phi] if len(gaps) > phi else m
    claim = np.zeros(m, dtype=bool)
    for k in range(m):
        if k < cum or (k < horizon and received_row[k]):
            claim[k] = True
    return cum, claim, missing


def _quorum_prefix(vals: np.ndarray, stakes: np.ndarray, thr: float) -> int:
    order = np.argsort(-vals, kind="stable")
    w = 0.0
    for i in order:
        w += stakes[i]
        if w >= thr:
            return int(vals[i])
    return 0


def run_reference(spec: SimSpec) -> RefResult:
    n_s, n_r, m, phi = spec.n_s, spec.n_r, spec.m, spec.phi
    st_s = np.asarray(spec.stakes_s)
    st_r = np.asarray(spec.stakes_r)
    orig_sender = np.asarray(spec.orig_sender)
    orig_recv = np.asarray(spec.orig_recv)
    orig_step = np.asarray(spec.orig_step)
    rs_seq = np.asarray(spec.rs_seq)
    rr_seq = np.asarray(spec.rr_seq)
    ls, lr = len(rs_seq), len(rr_seq)
    crash_s = np.asarray(spec.crash_s)
    crash_r = np.asarray(spec.crash_r)
    byz_send_drop = np.asarray(spec.byz_send_drop)
    byz_recv_drop = np.asarray(spec.byz_recv_drop)
    byz_ack_advance = np.asarray(spec.byz_ack_advance)
    byz_ack_low = np.asarray(spec.byz_ack_low)
    byz_bcast_partial = np.asarray(spec.byz_bcast_partial)
    honest_r = ((crash_r < 0) & ~(byz_recv_drop | byz_ack_low
                                  | (byz_ack_advance > 0)
                                  | byz_bcast_partial))

    recv_has = np.zeros((n_r, m), dtype=bool)
    bcast_q = np.zeros((n_r, m), dtype=bool)
    bcast_done = np.zeros((n_r, m), dtype=bool)
    known = np.zeros((n_s, n_r, m), dtype=bool)
    complaint = np.zeros((n_s, n_r, m), dtype=bool)
    repeat_c = np.zeros((n_s, n_r, m), dtype=bool)
    last_cum = np.full((n_s, n_r), -1, dtype=np.int64)
    retry = np.zeros((n_s, m), dtype=np.int64)
    quack_time = np.full((n_s, m), -1, dtype=np.int64)
    deliver_time = np.full(m, -1, dtype=np.int64)
    hq_reports = np.zeros((n_r, n_s), dtype=np.int64)
    ack_floor = np.zeros(n_r, dtype=np.int64)

    cross_hist: List[int] = []
    intra_hist: List[int] = []
    resend_hist: List[int] = []

    # --- sliding-window mirror (windowed specs only) ----------------------
    win = spec.window_slots
    chunk = max(spec.chunk_steps, 1)
    base = 0
    bases = [0] if win else None
    dense_fallback = False
    retired_snaps = []        # (k, quack_time col, deliver, retry col, recv col)
    retired_margin = np.inf
    # pad enough for the widest window adaptive growth can reach (< m)
    orig_step_pad = np.concatenate(
        [orig_step, np.full(max(win, 1) + m, _NEVER_STEP,
                            dtype=orig_step.dtype)])
    dispatched_by = _max_msg_by_round(spec) if win else None

    def quacked_at(l: int) -> np.ndarray:
        w = (known[l].astype(np.float64) * st_r[:, None]).sum(axis=0)
        return w >= spec.quack_thresh

    for t in range(spec.steps):
        # (0) window mirror: adaptive overflow policy at chunk starts,
        # exactly where the jax windowed path checks before a chunk.
        if win and not dense_fallback and t % chunk == 0:
            chunk_end = min(t + chunk, spec.steps) - 1
            need = int(dispatched_by[chunk_end])
            if need >= base + win:
                new_w = _widen_on_overflow(spec, win, base, need, chunk_end)
                if new_w is None:
                    dense_fallback = True
                else:
                    win = new_w

        alive_s = (crash_s < 0) | (t < crash_s)
        alive_r = (crash_r < 0) | (t < crash_r)

        # (1) broadcasts land
        intra = 0
        new_recv = np.zeros((n_r, m), dtype=bool)
        for j in range(n_r):
            if not alive_r[j]:
                continue
            for k in range(m):
                if bcast_q[j, k]:
                    targets = (range(min(spec.bcast_limit, n_r))
                               if byz_bcast_partial[j] else range(n_r))
                    for i in targets:
                        if i == j:
                            continue
                        intra += 1
                        if alive_r[i]:
                            new_recv[i, k] = True
                    bcast_done[j, k] = True
        bcast_q[:] = False
        recv_has |= new_recv

        # (2) retransmissions (from knowledge as of t-1)
        resends = []  # (sender, msg, target)
        for l in range(n_s):
            qk = quacked_at(l)
            for k in range(m):
                w = float((repeat_c[l, :, k] * st_r).sum())
                if w >= spec.dup_thresh and not qk[k] and orig_step[k] < t:
                    retry[l, k] += 1
                    complaint[l, :, k] = False
                    repeat_c[l, :, k] = False
                    if rs_seq[(k + retry[l, k]) % ls] == l:
                        if alive_s[l] and not byz_send_drop[l]:
                            tgt = rr_seq[(orig_recv[k] + retry[l, k]) % lr]
                            resends.append((l, k, int(tgt)))

        # (3) original sends + landing
        wire = []  # (sender, msg, target)
        for k in range(m):
            if orig_step[k] == t:
                l = orig_sender[k]
                if alive_s[l] and not byz_send_drop[l]:
                    wire.append((int(l), k, int(orig_recv[k])))
        wire.extend(resends)
        qp_prev = np.array([int(np.cumprod(quacked_at(l)).sum())
                            for l in range(n_s)])
        for (l, k, i) in wire:
            if alive_r[i]:
                hq_reports[i, l] = max(hq_reports[i, l], qp_prev[l])
                if not byz_recv_drop[i]:
                    if not recv_has[i, k]:
                        recv_has[i, k] = True
                        if not bcast_done[i, k]:
                            bcast_q[i, k] = True
        for k in range(m):
            if deliver_time[k] < 0 and (recv_has[:, k] & honest_r).any():
                deliver_time[k] = t

        # (4) acks
        for j in range(n_r):
            if not alive_r[j]:
                continue
            ack_floor[j] = max(ack_floor[j],
                               _quorum_prefix(hq_reports[j], st_s,
                                              spec.hq_thresh))
            eff = recv_has[j].copy()
            eff[:ack_floor[j]] = True
            cum, claim, missing = _claim_and_missing(eff, phi)
            if byz_ack_low[j]:
                cum, claim, missing = 0, np.zeros(m, bool), list(range(phi))
            elif byz_ack_advance[j] > 0:
                cum = min(cum + int(byz_ack_advance[j]), m)
                claim = np.arange(m) < cum
                missing = []
            l = (j + t) % n_s
            known[l, j] |= claim
            newc = np.zeros(m, dtype=bool)
            for k in missing:
                if k < m:
                    newc[k] = True
            if last_cum[l, j] == cum and cum < m:
                newc[cum] = True
            repeat_c[l, j] |= complaint[l, j] & newc
            complaint[l, j] = newc
            last_cum[l, j] = cum

        # (5) QUACK bookkeeping
        for l in range(n_s):
            qk = quacked_at(l)
            newly = qk & (quack_time[l] < 0)
            quack_time[l, newly] = t

        cross_hist.append(len(wire))
        intra_hist.append(intra)
        resend_hist.append(len(resends))

        # (6) window mirror: advance the GC frontier at chunk boundaries,
        # exactly where the jax windowed path rotates its ring buffers
        # in-graph.
        t_next = t + 1
        if (win and not dense_fallback and t_next % chunk == 0
                and t_next < spec.steps):
            lo, hi = base, base + win
            f = gc_frontier(
                base=base, t_next=t_next, m=m,
                known=known[:, :, lo:hi], bcast_q=bcast_q[:, lo:hi],
                recv_has=recv_has[:, lo:hi], ack_floor=ack_floor,
                stakes_r=st_r, quack_thresh=spec.quack_thresh,
                orig_step=orig_step_pad[lo:hi], crash_r=crash_r,
                byz_ack_low=byz_ack_low)
            for k in range(base, base + f):
                # float32 like the device QUACK einsum (see gc_frontier)
                w_k = (known[:, :, k].astype(np.float32)
                       * st_r[None, :].astype(np.float32)).sum(axis=1)
                retired_margin = min(retired_margin, float(w_k.min()))
                retired_snaps.append((k, quack_time[:, k].copy(),
                                      deliver_time[k], retry[:, k].copy(),
                                      recv_has[:, k].copy()))
            base += f
            bases.append(base)

    # retirement safety: a retired slot's outputs must never change again.
    for (k, qt, dt, rt, rh) in retired_snaps:
        assert np.array_equal(qt, quack_time[:, k]), (
            f"retired slot {k}: quack_time changed after retirement")
        assert dt == deliver_time[k], (
            f"retired slot {k}: deliver_time changed after retirement")
        assert np.array_equal(rt, retry[:, k]), (
            f"retired slot {k}: retry changed after retirement")
        assert np.array_equal(rh, recv_has[:, k]), (
            f"retired slot {k}: recv_has changed after retirement")

    if win and dense_fallback:
        frontiers = np.zeros(1, dtype=np.int64)   # mirrors SimResult
    elif win:
        frontiers = np.asarray(bases, dtype=np.int64)
    else:
        frontiers = None
    return RefResult(
        quack_time=quack_time, deliver_time=deliver_time, retry=retry,
        recv_has=recv_has, cross_msgs=np.array(cross_hist),
        intra_msgs=np.array(intra_hist), resends=np.array(resend_hist),
        gc_frontiers=frontiers,
        retired_quack_margin=(retired_margin if win else None))
