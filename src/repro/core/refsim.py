"""Readable pure-numpy reference simulator (the protocol oracle).

This mirrors ``simulator.py`` step-for-step but in explicit loops, so the
protocol logic can be read top-to-bottom against §4–§5 of the paper and the
vectorized implementation can be cross-checked exactly
(``tests/test_simulator.py::test_jax_matches_reference``).

The per-round transition lives in :class:`_RefMachine` so it can be driven
two ways: ``run_reference`` replays one link exactly like ``run_simulation``
(including the sliding-window mirror below), and the multi-link topology
oracle (``repro.topology.refmirror``) drives one machine per link with the
same chunk boundaries and commit-floor plumbing as the vmapped topology
engine. Original dispatch is commit-gated exactly like the device kernel:
message ``k`` is attempted at the first round ``t >= orig_step[k]`` with
``k < commit_floor`` (a standalone link has ``commit_floor == m``, which
reduces the gate to the ungated schedule).

For a windowed spec (``spec.window_slots > 0``) the oracle also mirrors
the sliding-window machinery: it keeps full dense state (it is the
*oracle*, it never forgets) but advances the same GC frontier with the
same shared ``gc.gc_frontier`` rule at the same chunk boundaries as the
jax windowed path — including the adaptive overflow policy
(``gc.grow_window``: widen the mirrored window 2x when a stalled frontier
would overflow it; when the doubling would reach M the jax path migrates
its scan state into the dense layout and keeps rotating, which the oracle
mirrors by widening its window to M and carrying the frontier trajectory
on) — snapshots every retired slot's outputs at retirement time, and
asserts at the end of the run that none of them ever changed afterwards.
That is the ground truth for the windowed core: if the retirement rule
ever forgot a slot whose state could still move, the snapshot check fails
here first. The frontier trajectory is returned in
``RefResult.gc_frontiers`` so tests can compare it bit-for-bit against
``SimResult.gc_frontiers``, and ``RefResult.retired_quack_margin`` records
the smallest stake-weighted QUACK margin over all retired slots (a retired
slot must be QUACKed at *every* sender — §4.3's "both sides may forget the
quacked prefix").
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .gc import gc_frontier
from .simulator import (SimSpec, _max_msg_by_round, _widen_on_overflow,
                        spec_failures)

__all__ = ["run_reference"]


@dataclasses.dataclass
class RefResult:
    quack_time: np.ndarray    # (n_s, M)
    deliver_time: np.ndarray  # (M,)
    retry: np.ndarray         # (n_s, M)
    recv_has: np.ndarray      # (n_r, M)
    cross_msgs: np.ndarray    # (T,)
    intra_msgs: np.ndarray    # (T,)
    resends: np.ndarray      # (T,)
    gc_frontiers: Optional[np.ndarray] = None   # (n_chunks,) window base
    retired_quack_margin: Optional[float] = None
    # number of window slots the GC frontier retired while undelivered —
    # 0 whenever the adversary stake budget is within the §4.3 bound
    # (``simulator.retire_safety_stakes_ok``); the oracle counts it so
    # the safety property can be asserted independently of the engine
    retired_undelivered: Optional[int] = None
    # dispatch round of each original send (-1 = never dispatched) and
    # per-message retire-step - send-step (-1 = not delivered) — the
    # oracle for ``SimResult.send_step`` / ``SimResult.delivery_latency``
    send_step: Optional[np.ndarray] = None      # (M,)
    delivery_latency: Optional[np.ndarray] = None  # (M,)


def _cum(received_row: np.ndarray) -> int:
    p = 0
    for v in received_row:
        if not v:
            break
        p += 1
    return p


def _claim_and_missing(received_row: np.ndarray, phi: int):
    """Honest ack payload: (cum, claim bitmask, missing list<=phi)."""
    m = received_row.shape[0]
    cum = _cum(received_row)
    top = 0
    for k in range(m - 1, -1, -1):
        if received_row[k]:
            top = k + 1
            break
    missing = [k for k in range(top) if not received_row[k]][:phi]
    # horizon: strictly below the (phi+1)-th missing index
    gaps = [k for k in range(m) if not received_row[k]]
    horizon = gaps[phi] if len(gaps) > phi else m
    claim = np.zeros(m, dtype=bool)
    for k in range(m):
        if k < cum or (k < horizon and received_row[k]):
            claim[k] = True
    return cum, claim, missing


def _quorum_prefix(vals: np.ndarray, stakes: np.ndarray, thr: float) -> int:
    order = np.argsort(-vals, kind="stable")
    w = 0.0
    for i in order:
        w += stakes[i]
        if w >= thr:
            return int(vals[i])
    return 0


class _RefMachine:
    """One link's full protocol state + per-round transition (explicit
    loops). ``step(t, commit_floor)`` advances one synchronous round;
    ``frontier``/``retire`` mirror the device chunk-boundary rotation."""

    def __init__(self, spec: SimSpec):
        self.spec = spec
        self.n_s, self.n_r, self.m = spec.n_s, spec.n_r, spec.m
        self.phi = spec.phi
        self.set_quorum(spec)
        self.orig_sender = np.asarray(spec.orig_sender)
        self.orig_recv = np.asarray(spec.orig_recv)
        self.orig_step = np.asarray(spec.orig_step)
        self.rs_seq = np.asarray(spec.rs_seq)
        self.rr_seq = np.asarray(spec.rr_seq)
        self.set_failures(spec_failures(spec))

        n_s, n_r, m = self.n_s, self.n_r, self.m
        self.recv_has = np.zeros((n_r, m), dtype=bool)
        self.bcast_q = np.zeros((n_r, m), dtype=bool)
        self.bcast_done = np.zeros((n_r, m), dtype=bool)
        self.orig_sent = np.zeros(m, dtype=bool)
        self.known = np.zeros((n_s, n_r, m), dtype=bool)
        self.complaint = np.zeros((n_s, n_r, m), dtype=bool)
        self.repeat_c = np.zeros((n_s, n_r, m), dtype=bool)
        self.last_cum = np.full((n_s, n_r), -1, dtype=np.int64)
        self.retry = np.zeros((n_s, m), dtype=np.int64)
        self.quack_time = np.full((n_s, m), -1, dtype=np.int64)
        self.deliver_time = np.full(m, -1, dtype=np.int64)
        self.send_time = np.full(m, -1, dtype=np.int64)
        self.hq_reports = np.zeros((n_r, n_s), dtype=np.int64)
        self.ack_floor = np.zeros(n_r, dtype=np.int64)

        self.cross_hist: List[int] = []
        self.intra_hist: List[int] = []
        self.resend_hist: List[int] = []
        # (k, quack col, deliver, retry col, recv col) at retirement time
        self.retired_snaps: list = []
        self.retired_margin = np.inf
        self.retired_undelivered = 0

    def set_quorum(self, spec: SimSpec) -> None:
        """Swap stakes / quorum thresholds in force from the next step on.

        The oracle twin of the engine's stake re-weighting: stakes and
        thresholds ride the traced ``FailArrays``
        (``simulator.spec_with_quorum``), so a mid-stream swap at a chunk
        boundary costs the engine zero recompiles — and costs the oracle
        one attribute update. The retransmit rotations (``rs_seq`` /
        ``rr_seq``) are committed at build and intentionally not swapped,
        matching the engine.
        """
        self.st_s = np.asarray(spec.stakes_s, dtype=np.float64)
        self.st_r = np.asarray(spec.stakes_r, dtype=np.float64)
        self.quack_thresh = float(spec.quack_thresh)
        self.dup_thresh = float(spec.dup_thresh)
        self.hq_thresh = float(spec.hq_thresh)

    def set_failures(self, failures) -> None:
        """Swap the failure masks in force from the next ``step`` on.

        The oracle twin of the engine's mid-stream ``FailArrays`` swap at
        a chunk boundary (``repro.replay`` schedule injection): crash or
        recover replicas, open or heal a partition, change drop/lie
        schedules. Protocol state (received sets, complaints, QUACK
        bookkeeping) is untouched — only the masks change.
        """
        n_s, n_r = self.n_s, self.n_r

        def tup(x, n, default):
            return np.asarray([default] * n if x is None else list(x))

        self.crash_s = tup(failures.crash_s, n_s, -1)
        self.crash_r = tup(failures.crash_r, n_r, -1)
        self.byz_send_drop = tup(failures.byz_send_drop, n_s, False)
        self.byz_recv_drop = tup(failures.byz_recv_drop, n_r, False)
        self.byz_ack_advance = tup(failures.byz_ack_advance, n_r, 0)
        self.byz_ack_low = tup(failures.byz_ack_low, n_r, False)
        self.byz_bcast_partial = tup(failures.byz_bcast_partial, n_r, False)
        self.bcast_limit = int(failures.bcast_limit)
        self.byz_equiv_send = tup(failures.byz_equiv_send, n_s, False)
        self.byz_hq_advance = tup(failures.byz_hq_advance, n_s, 0)
        self.byz_ack_stale = tup(failures.byz_ack_stale, n_r, False)
        dp = failures.drop_pair
        self.drop_pair = (np.zeros((n_s, n_r), dtype=bool) if dp is None
                          else np.asarray([list(r) for r in dp], dtype=bool))
        self.honest_r = ((self.crash_r < 0)
                         & ~(self.byz_recv_drop | self.byz_ack_low
                             | (self.byz_ack_advance > 0)
                             | self.byz_bcast_partial
                             | self.byz_ack_stale))

    def quacked_at(self, l: int) -> np.ndarray:
        w = (self.known[l].astype(np.float64)
             * self.st_r[:, None]).sum(axis=0)
        return w >= self.quack_thresh

    def delivered_prefix(self) -> int:
        return _cum(self.deliver_time >= 0)

    def step(self, t: int, commit_floor: Optional[int] = None) -> None:
        n_s, n_r, m, phi = self.n_s, self.n_r, self.m, self.phi
        floor = m if commit_floor is None else int(commit_floor)
        alive_s = (self.crash_s < 0) | (t < self.crash_s)
        alive_r = (self.crash_r < 0) | (t < self.crash_r)
        # stale-ack replay reads the complaint list as it stood at the
        # start of the round — before step (2) clears declared cycles —
        # exactly like the vectorized step reads ``state.complaint``
        stale_any = bool(self.byz_ack_stale.any())
        complaint_prev = self.complaint.copy() if stale_any else None

        # (1) broadcasts land
        intra = 0
        new_recv = np.zeros((n_r, m), dtype=bool)
        for j in range(n_r):
            if not alive_r[j]:
                continue
            for k in range(m):
                if self.bcast_q[j, k]:
                    targets = (range(min(self.bcast_limit, n_r))
                               if self.byz_bcast_partial[j] else range(n_r))
                    for i in targets:
                        if i == j:
                            continue
                        intra += 1
                        if alive_r[i]:
                            new_recv[i, k] = True
                    self.bcast_done[j, k] = True
        self.bcast_q[:] = False
        self.recv_has |= new_recv

        # (2) retransmissions (from knowledge as of t-1; only messages
        # whose original dispatch already happened — the sent bit, not the
        # schedule round, under commit-gated dispatch). Each wire entry
        # carries a ``lands`` flag: an equivocating sender's resend is
        # detected and discarded wholesale by the receiver, and a
        # drop_pair edge kills the copy in the network — either way the
        # wire copy happened (it counts in the metrics, the retry counter
        # and the election rotation advance) but nothing is stored, acked
        # or heard as §4.3 metadata.
        resends = []  # (sender, msg, target, lands)
        for l in range(n_s):
            qk = self.quacked_at(l)
            for k in range(m):
                w = float((self.repeat_c[l, :, k] * self.st_r).sum())
                if (w >= self.dup_thresh and not qk[k]
                        and self.orig_sent[k]):
                    self.retry[l, k] += 1
                    self.complaint[l, :, k] = False
                    self.repeat_c[l, :, k] = False
                    if self.rs_seq[(k + self.retry[l, k])
                                   % len(self.rs_seq)] == l:
                        if alive_s[l] and not self.byz_send_drop[l]:
                            tgt = int(self.rr_seq[(self.orig_recv[k]
                                                   + self.retry[l, k])
                                                  % len(self.rr_seq)])
                            lands = (not self.byz_equiv_send[l]
                                     and not self.drop_pair[l, tgt])
                            resends.append((l, k, tgt, lands))

        # (3) original sends + landing: a message is due once its schedule
        # round has passed AND its entry is committed on the source RSM;
        # the dispatch attempt happens exactly once, alive or not.
        wire = []  # (sender, msg, target, lands)
        for k in range(m):
            if (self.orig_sent[k] or self.orig_step[k] > t or k >= floor):
                continue
            self.orig_sent[k] = True
            self.send_time[k] = t
            l = self.orig_sender[k]
            if alive_s[l] and not self.byz_send_drop[l]:
                i = int(self.orig_recv[k])
                wire.append((int(l), k, i, not self.drop_pair[l, i]))
        wire.extend(resends)
        qp_prev = np.array([int(np.cumprod(self.quacked_at(l)).sum())
                            for l in range(n_s)])
        for (l, k, i, lands) in wire:
            if alive_r[i] and lands:
                # §4.3 metadata piggyback; an hq-lying sender inflates
                # its claimed prefix per receiver (min(true+adv+i, m)) so
                # no two receivers can cross-check the same number
                adv = int(self.byz_hq_advance[l])
                hq = (int(qp_prev[l]) if adv == 0
                      else min(int(qp_prev[l]) + adv + i, m))
                self.hq_reports[i, l] = max(self.hq_reports[i, l], hq)
                if not self.byz_recv_drop[i]:
                    if not self.recv_has[i, k]:
                        self.recv_has[i, k] = True
                        if not self.bcast_done[i, k]:
                            self.bcast_q[i, k] = True
        for k in range(m):
            if (self.deliver_time[k] < 0
                    and (self.recv_has[:, k] & self.honest_r).any()):
                self.deliver_time[k] = t

        # (4) acks
        for j in range(n_r):
            if not alive_r[j]:
                continue
            self.ack_floor[j] = max(
                self.ack_floor[j],
                _quorum_prefix(self.hq_reports[j], self.st_s,
                               self.hq_thresh))
            eff = self.recv_has[j].copy()
            eff[:self.ack_floor[j]] = True
            cum, claim, missing = _claim_and_missing(eff, phi)
            if self.byz_ack_low[j]:
                cum, claim, missing = 0, np.zeros(m, bool), list(range(phi))
            elif self.byz_ack_advance[j] > 0:
                cum = min(cum + int(self.byz_ack_advance[j]), m)
                claim = np.arange(m) < cum
                missing = []
            l = (j + t) % n_s
            # stale replay (applied LAST, freezing whatever the other
            # lie masks produced): resend the previous ack to this
            # round's target verbatim — its last cum counter, the prefix
            # claim below it, and its previous complaint list. Truthful
            # but old: monotone claims cannot fabricate receipt, but the
            # frozen cum trips the duplicate-cum complaint below.
            stale = bool(self.byz_ack_stale[j])
            if stale:
                cum = max(int(self.last_cum[l, j]), 0)
                claim = np.arange(m) < cum
            self.known[l, j] |= claim
            newc = np.zeros(m, dtype=bool)
            if stale:
                newc[:] = complaint_prev[l, j]
            else:
                for k in missing:
                    if k < m:
                        newc[k] = True
            if self.last_cum[l, j] == cum and cum < m:
                newc[cum] = True
            self.repeat_c[l, j] |= self.complaint[l, j] & newc
            self.complaint[l, j] = newc
            self.last_cum[l, j] = cum

        # (5) QUACK bookkeeping
        for l in range(n_s):
            qk = self.quacked_at(l)
            newly = qk & (self.quack_time[l] < 0)
            self.quack_time[l, newly] = t

        self.cross_hist.append(len(wire))
        self.intra_hist.append(intra)
        self.resend_hist.append(len(resends))

    def frontier(self, base: int, win: int, t_next: int) -> int:
        """Shared §4.3 retirement rule over window ``[base, base+win)``."""
        lo, hi = base, base + win
        return gc_frontier(
            base=base, t_next=t_next, m=self.m,
            known=self.known[:, :, lo:hi], bcast_q=self.bcast_q[:, lo:hi],
            recv_has=self.recv_has[:, lo:hi], ack_floor=self.ack_floor,
            stakes_r=self.st_r, quack_thresh=self.quack_thresh,
            orig_sent=self.orig_sent[lo:hi], crash_r=self.crash_r,
            byz_ack_low=self.byz_ack_low)

    def retire(self, base: int, f: int) -> None:
        """Snapshot slots ``[base, base+f)`` at retirement time."""
        for k in range(base, base + f):
            # §4.3 safety: a retired slot must be physically held by at
            # least one replica of the receiver RSM — recv_has is ground
            # truth receipt, so a quorum of fabricated claims (the only
            # way to quack an unreceived message) is caught here even
            # when every truthful holder sits outside honest_r
            # (bcast-partial or later-crashing replicas).
            if not self.recv_has[:, k].any():
                self.retired_undelivered += 1
            # float32 like the device QUACK einsum (see gc_frontier)
            w_k = (self.known[:, :, k].astype(np.float32)
                   * self.st_r[None, :].astype(np.float32)).sum(axis=1)
            self.retired_margin = min(self.retired_margin,
                                      float(w_k.min()))
            self.retired_snaps.append((k, self.quack_time[:, k].copy(),
                                       self.deliver_time[k],
                                       self.retry[:, k].copy(),
                                       self.recv_has[:, k].copy()))

    def assert_retirement_safe(self) -> None:
        """A retired slot's outputs must never change again."""
        for (k, qt, dt, rt, rh) in self.retired_snaps:
            assert np.array_equal(qt, self.quack_time[:, k]), (
                f"retired slot {k}: quack_time changed after retirement")
            assert dt == self.deliver_time[k], (
                f"retired slot {k}: deliver_time changed after retirement")
            assert np.array_equal(rt, self.retry[:, k]), (
                f"retired slot {k}: retry changed after retirement")
            assert np.array_equal(rh, self.recv_has[:, k]), (
                f"retired slot {k}: recv_has changed after retirement")

    def result(self, frontiers: Optional[np.ndarray],
               windowed: bool) -> RefResult:
        return RefResult(
            quack_time=self.quack_time, deliver_time=self.deliver_time,
            retry=self.retry, recv_has=self.recv_has,
            cross_msgs=np.array(self.cross_hist),
            intra_msgs=np.array(self.intra_hist),
            resends=np.array(self.resend_hist),
            gc_frontiers=frontiers,
            retired_quack_margin=(self.retired_margin if windowed
                                  else None),
            retired_undelivered=(self.retired_undelivered if windowed
                                 else None),
            send_step=self.send_time.copy(),
            delivery_latency=np.where(
                self.deliver_time >= 0,
                self.deliver_time - self.send_time, -1))


def run_reference(spec: SimSpec, fail_schedule=None) -> RefResult:
    """Oracle run; ``fail_schedule(t)`` is consulted at chunk starts and
    swaps the failure state in force from round ``t`` on — the numpy twin
    of the engine's mid-stream ``FailArrays`` swap, so replayed-with-
    injection runs can be checked against a from-scratch oracle executing
    the merged schedule. Each entry may be a ``FailureScenario`` (mask
    swap only) or a full ``SimSpec`` (mask swap *plus* stake/threshold
    re-weighting — the reconfiguration primitive, mirroring the engine's
    ``fail_schedule`` returning ``spec_with_quorum`` specs)."""
    mac = _RefMachine(spec)

    # --- sliding-window mirror (windowed specs only) ----------------------
    win = spec.window_slots
    chunk = max(spec.chunk_steps, 1)
    base = 0
    bases = [0] if win else None
    dispatched_by = _max_msg_by_round(spec) if win else None

    for t in range(spec.steps):
        # (0) failure-schedule swap at chunk starts, exactly where the
        # engine rebuilds its stacked FailArrays.
        if fail_schedule is not None and t % chunk == 0:
            new_fails = fail_schedule(t)
            if new_fails is not None:
                if isinstance(new_fails, SimSpec):
                    mac.set_quorum(new_fails)
                    mac.set_failures(spec_failures(new_fails))
                else:
                    mac.set_failures(new_fails)
        # window mirror: adaptive overflow policy at chunk starts,
        # exactly where the jax windowed path checks before a chunk.
        if win and t % chunk == 0:
            chunk_end = min(t + chunk, spec.steps) - 1
            need = int(dispatched_by[chunk_end])
            if need >= base + win:
                new_w = _widen_on_overflow(spec, win, base, need, chunk_end)
                # None => the jax path migrates its scan state into the
                # dense layout (W = M) and keeps rotating; mirror by
                # widening the window to M and carrying the trajectory on.
                win = spec.m if new_w is None else new_w

        mac.step(t)

        # (6) window mirror: advance the GC frontier at chunk boundaries,
        # exactly where the jax windowed path rotates its ring buffers
        # in-graph.
        t_next = t + 1
        if win and t_next % chunk == 0 and t_next < spec.steps:
            f = mac.frontier(base, win, t_next)
            mac.retire(base, f)
            base += f
            bases.append(base)

    mac.assert_retirement_safe()
    frontiers = np.asarray(bases, dtype=np.int64) if win else None
    return mac.result(frontiers, bool(win))
