"""QUACK (cumulative quorum acknowledgement) primitives (§4.1, §5.1).

All functions are pure jnp array ops so they can run inside ``lax.scan``
(simulator) or be jit-compiled standalone. Sequence numbers are 0-based and
acks are *counts*: ``ack == p`` means "I hold the contiguous prefix of p
messages m_0 .. m_{p-1}". A QUACK for prefix p forms at a sender once
replicas totalling ``u_r + 1`` stake have acked >= p — at least one of those
is honest, and an honest receiver broadcasts intra-RSM, so delivery of
m_0..m_{p-1} is guaranteed (§4.1 "Detecting successful sends").

Sliding-window (offset-aware) form: every function takes an optional
``base`` — the absolute sequence number of column 0 of the ``received``
array. The window invariant maintained by the simulator's GC rotation
(§4.3) is that everything below ``base`` is already held (or floor-acked)
by every replica whose acks still matter, so the absolute cumulative ack
is ``base +`` the in-window prefix and gap ranks start at zero at the
window base. ``base == 0`` with a full-width array recovers the dense
semantics exactly.

``base`` may be a python int, a traced scalar (device-side window
rotation carries it as scan state), or a per-scenario batch of scalars
under ``jax.vmap`` (batched windowed sweeps) — all offset arithmetic is
normalized to int32 so the three instantiations produce bit-identical
programs.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "cumulative_ack",
    "claim_bitmask",
    "weighted_quorum_prefix",
    "selective_quack",
    "missing_below_horizon",
    "stake_quorum_bitmap",
]


def stake_quorum_bitmap(claims: jnp.ndarray, complaints: jnp.ndarray,
                        stakes: jnp.ndarray, quack_thresh: float,
                        dup_thresh: float, *, use_pallas: bool = False,
                        need_lost: bool = True):
    """Stake-weighted QUACK / loss quorum decisions over a window (§4.1/§4.2).

    claims / complaints: (n_s, n_r, W) bool — receiver claim and
    repeat-complaint bitmaps as known to each sender. Returns
    ``(quacked (n_s, W) bool, lost (n_s, W) bool, prefix (n_s,) int32)``
    where ``quacked`` is the u_r+1 stake quorum, ``lost`` the r_r+1
    duplicate-complaint quorum on not-yet-quacked messages, and
    ``prefix`` the contiguous quacked prefix length (window-relative; the
    caller adds its window ``base``).

    ``use_pallas`` routes the reduction through the Pallas TPU kernel
    (``kernels.quack_scan`` — MXU stake matmul + cross-block prefix
    carry; interpret mode off-TPU via ``kernels.ops.default_interpret``).
    Stakes are small integers in every configuration the protocol uses,
    so the float32 quorum sums are exact and the two paths agree
    bit-for-bit (``tests/test_pipeline.py``).

    ``need_lost=False`` declares the loss quorum unused (``lost`` comes
    back ``None``): the jnp path's complaints einsum would be DCE'd by
    XLA anyway, but a Pallas kernel is opaque to DCE, so the kernel path
    must drop the complaints stream at the call boundary.
    """
    stakes = stakes.astype(jnp.float32)
    if use_pallas:
        from ..kernels.ops import default_interpret, quack_scan
        from ..kernels.quack_scan import BLOCK_W
        # the kernel streams W in blocks of min(BLOCK_W, W) and needs
        # the width to be a block multiple; window widths are arbitrary
        # (auto sizing rounds to 64, growth doubles, dense fallback uses
        # M), so pad with never-claimed columns — they sit beyond every
        # real column, leaving the quorum bitmaps and the contiguous
        # quacked prefix untouched — and slice back.
        w = claims.shape[-1]
        pad = (-w) % min(BLOCK_W, w)
        if pad:
            ext = jnp.zeros(claims.shape[:-1] + (pad,), dtype=bool)
            claims = jnp.concatenate([claims, ext], axis=-1)
            complaints = jnp.concatenate([complaints, ext], axis=-1)
        # thresholds stay jnp values (possibly traced — stake re-weight
        # swaps feed them through FailArrays): the kernel takes them as
        # (1, 1) scalar blocks, so a traced threshold costs no recompile
        quacked, lost, prefix = quack_scan(
            claims, complaints, stakes,
            jnp.asarray(quack_thresh, dtype=jnp.float32),
            jnp.asarray(dup_thresh, dtype=jnp.float32), block_w=BLOCK_W,
            interpret=default_interpret(), compute_lost=need_lost)
        return (quacked[..., :w],
                None if lost is None else lost[..., :w],
                prefix.astype(jnp.int32))
    w_claim = jnp.einsum("ljm,j->lm", claims.astype(jnp.float32), stakes)
    quacked = w_claim >= quack_thresh
    lost = None
    if need_lost:
        w_comp = jnp.einsum("ljm,j->lm", complaints.astype(jnp.float32),
                            stakes)
        lost = (w_comp >= dup_thresh) & ~quacked
    prefix = jnp.sum(jnp.cumprod(quacked.astype(jnp.int32), axis=-1),
                     axis=-1)
    return quacked, lost, prefix.astype(jnp.int32)


def cumulative_ack(received: jnp.ndarray, base=0) -> jnp.ndarray:
    """Highest contiguous prefix count per receiver.

    received: (n_r, W) bool -> (n_r,) int32 *absolute* counts. ``base`` is
    the absolute index of column 0 (window invariant: everything below it
    counts as received).
    """
    base = jnp.asarray(base, dtype=jnp.int32)
    prefix = jnp.cumprod(received.astype(jnp.int32), axis=-1)
    return (base + prefix.sum(axis=-1)).astype(jnp.int32)


def missing_below_horizon(received: jnp.ndarray, phi: int,
                          base=0) -> jnp.ndarray:
    """Which messages a receiver reports missing, bounded by the phi-list.

    A receiver only reports gaps below its highest received index (anything
    above could simply not have been sent yet), and at most ``phi`` of them
    (§4.2 Parallel Cumulative Acknowledgments). Returns (n_r, W) bool for
    the window columns; gaps can only exist at or above ``base``.
    """
    w = received.shape[-1]
    base = jnp.asarray(base, dtype=jnp.int32)
    idx = base + jnp.arange(w, dtype=jnp.int32)
    # top[j] = 1 + highest received index (base if nothing in-window)
    any_recv = received.any(axis=-1)
    top = jnp.where(any_recv,
                    base + w - jnp.argmax(received[..., ::-1], axis=-1),
                    base).astype(jnp.int32)
    missing = (~received) & (idx[None, :] < top[:, None])
    # keep only the first `phi` missing entries per row
    rank = jnp.cumsum(missing.astype(jnp.int32), axis=-1)
    return missing & (rank <= phi)


def claim_bitmask(received: jnp.ndarray, phi: int, base=0, total=None):
    """Receiver's honest ack payload: (cum_ack, claim, claim_known).

    claim_known[j, k] — the ack message from j describes the status of k
    (true for all k below the horizon where <= phi gaps exist);
    claim[j, k]      — j claims to have received k (only meaningful where
    claim_known).  This is exactly "cumulative counter + phi-list" in array
    form: below the horizon, claim == received; missing list = the gaps.

    ``base``/``total`` select the sliding-window form: columns cover
    absolute indices [base, base + W) of a stream of ``total`` messages
    (``total`` must be given explicitly when ``base`` is traced).
    """
    w = received.shape[-1]
    base = jnp.asarray(base, dtype=jnp.int32)
    if total is None:
        total = base + w
    total = jnp.asarray(total, dtype=jnp.int32)
    idx = base + jnp.arange(w, dtype=jnp.int32)
    cum = cumulative_ack(received, base)
    # horizon: everything strictly below the (phi+1)-th missing index is
    # described. rank counts missing entries; positions with rank <= phi and
    # (missing => in the reported list) are known.
    missing_all = (~received)
    rank_all = jnp.cumsum(missing_all.astype(jnp.int32), axis=-1)
    # (phi+1)-th missing position per row (or `total` if <= phi gaps)
    over = rank_all > phi
    horizon = jnp.where(over.any(axis=-1),
                        base + jnp.argmax(over, axis=-1), total)
    # also bounded by top (we cannot claim receipt of unseen suffix): known
    # region = [0, max(horizon, cum)) union received-with-rank<=phi.
    known = idx[None, :] < horizon[:, None]
    claim = received & known
    # everything below cum is received by definition of cum:
    claim = claim | (idx[None, :] < cum[:, None])
    known = known | (idx[None, :] < cum[:, None])
    return cum, claim, known


def weighted_quorum_prefix(ack_vals: jnp.ndarray, stakes: jnp.ndarray,
                           threshold: float) -> jnp.ndarray:
    """Largest prefix p such that stake >= threshold has acked >= p (§5.1).

    ack_vals: (..., n_r) int; stakes: (n_r,); returns (...,) int32.
    Sort acks descending, accumulate stake, and take the largest ack value
    at which the running stake first reaches the threshold.
    """
    order = jnp.argsort(-ack_vals, axis=-1)
    sorted_acks = jnp.take_along_axis(ack_vals, order, axis=-1)
    sorted_stakes = jnp.take_along_axis(
        jnp.broadcast_to(stakes, ack_vals.shape), order, axis=-1)
    cw = jnp.cumsum(sorted_stakes, axis=-1)
    ok = cw >= threshold
    idx = jnp.argmax(ok, axis=-1)  # first position where quorum reached
    val = jnp.take_along_axis(sorted_acks, idx[..., None], axis=-1)[..., 0]
    return jnp.where(ok.any(axis=-1), val, 0).astype(jnp.int32)


def selective_quack(known_has: jnp.ndarray, stakes: jnp.ndarray,
                    threshold: float) -> jnp.ndarray:
    """Per-message QUACK with phi-list info (§4.2 parallel recovery).

    known_has: (..., n_r, M) bool — sender's knowledge that receiver j claims
    to hold message k. Returns (..., M) bool: stake-weighted count >= u_r+1.
    """
    w = jnp.einsum("...jm,j->...m", known_has.astype(stakes.dtype), stakes)
    return w >= threshold
