"""QUACK (cumulative quorum acknowledgement) primitives (§4.1, §5.1).

All functions are pure jnp array ops so they can run inside ``lax.scan``
(simulator) or be jit-compiled standalone. Sequence numbers are 0-based and
acks are *counts*: ``ack == p`` means "I hold the contiguous prefix of p
messages m_0 .. m_{p-1}". A QUACK for prefix p forms at a sender once
replicas totalling ``u_r + 1`` stake have acked >= p — at least one of those
is honest, and an honest receiver broadcasts intra-RSM, so delivery of
m_0..m_{p-1} is guaranteed (§4.1 "Detecting successful sends").

Sliding-window (offset-aware) form: every function takes an optional
``base`` — the absolute sequence number of column 0 of the ``received``
array. The window invariant maintained by the simulator's GC rotation
(§4.3) is that everything below ``base`` is already held (or floor-acked)
by every replica whose acks still matter, so the absolute cumulative ack
is ``base +`` the in-window prefix and gap ranks start at zero at the
window base. ``base == 0`` with a full-width array recovers the dense
semantics exactly.

``base`` may be a python int, a traced scalar (device-side window
rotation carries it as scan state), or a per-scenario batch of scalars
under ``jax.vmap`` (batched windowed sweeps) — all offset arithmetic is
normalized to int32 so the three instantiations produce bit-identical
programs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "cumulative_ack",
    "claim_bitmask",
    "weighted_quorum_prefix",
    "selective_quack",
    "missing_below_horizon",
]


def cumulative_ack(received: jnp.ndarray, base=0) -> jnp.ndarray:
    """Highest contiguous prefix count per receiver.

    received: (n_r, W) bool -> (n_r,) int32 *absolute* counts. ``base`` is
    the absolute index of column 0 (window invariant: everything below it
    counts as received).
    """
    base = jnp.asarray(base, dtype=jnp.int32)
    prefix = jnp.cumprod(received.astype(jnp.int32), axis=-1)
    return (base + prefix.sum(axis=-1)).astype(jnp.int32)


def missing_below_horizon(received: jnp.ndarray, phi: int,
                          base=0) -> jnp.ndarray:
    """Which messages a receiver reports missing, bounded by the phi-list.

    A receiver only reports gaps below its highest received index (anything
    above could simply not have been sent yet), and at most ``phi`` of them
    (§4.2 Parallel Cumulative Acknowledgments). Returns (n_r, W) bool for
    the window columns; gaps can only exist at or above ``base``.
    """
    w = received.shape[-1]
    base = jnp.asarray(base, dtype=jnp.int32)
    idx = base + jnp.arange(w, dtype=jnp.int32)
    # top[j] = 1 + highest received index (base if nothing in-window)
    any_recv = received.any(axis=-1)
    top = jnp.where(any_recv,
                    base + w - jnp.argmax(received[..., ::-1], axis=-1),
                    base).astype(jnp.int32)
    missing = (~received) & (idx[None, :] < top[:, None])
    # keep only the first `phi` missing entries per row
    rank = jnp.cumsum(missing.astype(jnp.int32), axis=-1)
    return missing & (rank <= phi)


def claim_bitmask(received: jnp.ndarray, phi: int, base=0, total=None):
    """Receiver's honest ack payload: (cum_ack, claim, claim_known).

    claim_known[j, k] — the ack message from j describes the status of k
    (true for all k below the horizon where <= phi gaps exist);
    claim[j, k]      — j claims to have received k (only meaningful where
    claim_known).  This is exactly "cumulative counter + phi-list" in array
    form: below the horizon, claim == received; missing list = the gaps.

    ``base``/``total`` select the sliding-window form: columns cover
    absolute indices [base, base + W) of a stream of ``total`` messages
    (``total`` must be given explicitly when ``base`` is traced).
    """
    w = received.shape[-1]
    base = jnp.asarray(base, dtype=jnp.int32)
    if total is None:
        total = base + w
    total = jnp.asarray(total, dtype=jnp.int32)
    idx = base + jnp.arange(w, dtype=jnp.int32)
    cum = cumulative_ack(received, base)
    # horizon: everything strictly below the (phi+1)-th missing index is
    # described. rank counts missing entries; positions with rank <= phi and
    # (missing => in the reported list) are known.
    missing_all = (~received)
    rank_all = jnp.cumsum(missing_all.astype(jnp.int32), axis=-1)
    # (phi+1)-th missing position per row (or `total` if <= phi gaps)
    over = rank_all > phi
    horizon = jnp.where(over.any(axis=-1),
                        base + jnp.argmax(over, axis=-1), total)
    # also bounded by top (we cannot claim receipt of unseen suffix): known
    # region = [0, max(horizon, cum)) union received-with-rank<=phi.
    known = idx[None, :] < horizon[:, None]
    claim = received & known
    # everything below cum is received by definition of cum:
    claim = claim | (idx[None, :] < cum[:, None])
    known = known | (idx[None, :] < cum[:, None])
    return cum, claim, known


def weighted_quorum_prefix(ack_vals: jnp.ndarray, stakes: jnp.ndarray,
                           threshold: float) -> jnp.ndarray:
    """Largest prefix p such that stake >= threshold has acked >= p (§5.1).

    ack_vals: (..., n_r) int; stakes: (n_r,); returns (...,) int32.
    Sort acks descending, accumulate stake, and take the largest ack value
    at which the running stake first reaches the threshold.
    """
    order = jnp.argsort(-ack_vals, axis=-1)
    sorted_acks = jnp.take_along_axis(ack_vals, order, axis=-1)
    sorted_stakes = jnp.take_along_axis(
        jnp.broadcast_to(stakes, ack_vals.shape), order, axis=-1)
    cw = jnp.cumsum(sorted_stakes, axis=-1)
    ok = cw >= threshold
    idx = jnp.argmax(ok, axis=-1)  # first position where quorum reached
    val = jnp.take_along_axis(sorted_acks, idx[..., None], axis=-1)[..., 0]
    return jnp.where(ok.any(axis=-1), val, 0).astype(jnp.int32)


def selective_quack(known_has: jnp.ndarray, stakes: jnp.ndarray,
                    threshold: float) -> jnp.ndarray:
    """Per-message QUACK with phi-list info (§4.2 parallel recovery).

    known_has: (..., n_r, M) bool — sender's knowledge that receiver j claims
    to hold message k. Returns (..., M) bool: stake-weighted count >= u_r+1.
    """
    w = jnp.einsum("...jm,j->...m", known_has.astype(stakes.dtype), stakes)
    return w >= threshold
