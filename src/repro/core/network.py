"""Analytic network capacity model for C3B protocol throughput (§6 setup).

The paper measures C3B throughput (completed C3B invocations/sec) on GCP
c2-standard-8 VMs; we model each node as a full-duplex NIC plus a
per-message CPU budget, and cross-RSM pairs as independently capped links
(the geo experiments cap each pairwise connection at 135 Mbit/s).

Throughput of a protocol = min over binding resources of
``capacity / per-message-load``:

  * per-node NIC egress / ingress bytes per delivered message,
  * per-node message-operation count (serialization/syscall CPU),
  * per-pair cross-RSM link bytes,
  * in-flight window / RTT (geo),

Each protocol contributes its own per-message load profile
(see ``protocols.py``). The model is calibrated once (R_MSG_OPS, window)
and validated against the paper's reported ratios in
``benchmarks/fig8_scalability.py`` — agreement is within ~2x everywhere
and the scaling *trends* (ratio grows with n; geo >> LAN; large messages >
small) match exactly; deviations are tabulated in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

from .types import NetworkModel

__all__ = ["NodeLoad", "Resources", "throughput_from_loads", "R_MSG_OPS"]

# Per-node message-operation rate (ops/sec): calibrated so that the LAN
# small-message ratios land in the paper's observed range (§6.1).
R_MSG_OPS = 20_000.0


@dataclasses.dataclass(frozen=True)
class NodeLoad:
    """Per-delivered-message load of one node class."""

    egress_bytes: float = 0.0      # bytes sent per delivered message
    ingress_bytes: float = 0.0     # bytes received per delivered message
    msg_ops: float = 0.0           # message operations per delivered message
    cross_egress_bytes: float = 0.0  # subset of egress crossing RSM boundary


@dataclasses.dataclass(frozen=True)
class Resources:
    """System-level constraints for one protocol run."""

    loads: Dict[str, NodeLoad]      # node-class -> per-message load
    cross_pair_bytes: float = 0.0   # bytes per message on the busiest pair
    pairs_used: int = 1
    inflight_sources: int = 1       # nodes that can have a window in flight
    window: int = 8                 # outstanding messages per source


def throughput_from_loads(res: Resources, net: NetworkModel,
                          msg_ops_rate: float = R_MSG_OPS) -> Dict[str, float]:
    """Messages/sec = min over binding constraints; returns all terms."""
    terms: Dict[str, float] = {}
    for name, load in res.loads.items():
        if load.egress_bytes > 0:
            terms[f"{name}.egress"] = net.nic_Bps / load.egress_bytes
        if load.ingress_bytes > 0:
            terms[f"{name}.ingress"] = net.nic_Bps / load.ingress_bytes
        if load.msg_ops > 0:
            terms[f"{name}.cpu"] = msg_ops_rate / load.msg_ops
        if load.cross_egress_bytes > 0:
            # a node's cross-RSM egress cannot exceed the sum of its pair caps
            per_node_cross = min(net.nic_Bps, res.pairs_used * net.cross_Bps)
            terms[f"{name}.cross"] = per_node_cross / load.cross_egress_bytes
    if res.cross_pair_bytes > 0:
        terms["pair"] = net.cross_Bps / res.cross_pair_bytes
    if net.rtt_s > 0:
        terms["window"] = res.inflight_sources * res.window / net.rtt_s
    tput = min(terms.values()) if terms else math.inf
    out = dict(terms)
    out["throughput_msgs_per_s"] = tput
    out["throughput_MBps"] = tput * net.msg_bytes / 1e6
    out["bottleneck"] = min(terms, key=terms.get)  # type: ignore[assignment]
    return out
