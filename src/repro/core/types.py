"""Core configuration types for the PICSOU / C3B protocol implementation.

The paper's system model (§2.1) is the UpRight failure model: each RSM has
``n`` replicas, is *live* despite up to ``u`` failures of any kind and *safe*
despite up to ``r`` commission (Byzantine) failures, with ``n = 2u + r + 1``.
``u = r = f`` gives the classic 3f+1 BFT setting; ``r = 0`` gives 2f+1 CFT.

Stake-based RSMs (§5) generalize this: each replica ``j`` holds stake
``delta_j``; thresholds ``u`` / ``r`` are stake amounts instead of counts.
Traditional RSMs set every stake to 1.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "RSMConfig",
    "NetworkModel",
    "FailureScenario",
    "SimConfig",
    "COUNTER_BYTES",
    "SEQNO_BYTES",
    "MAC_BYTES",
]

# Wire-format constants (metadata accounting, §3 P1: constant-size metadata).
COUNTER_BYTES = 8   # one cumulative-ack counter
SEQNO_BYTES = 8     # one sequence number (phi-list entry / piggybacked hq)
MAC_BYTES = 32      # per-message MAC when r > 0 (BFT configurations)


@dataclasses.dataclass(frozen=True)
class RSMConfig:
    """One replicated state machine, in the UpRight model.

    n:      replica count.
    u:      liveness threshold (stake units; replica count when unit stakes).
    r:      safety/commission threshold (stake units). r == 0 => CFT.
    stakes: per-replica stake (defaults to all-ones). Total stake is the
            paper's ``n_i`` in the weighted setting (§5).
    """

    n: int
    u: int
    r: int
    stakes: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.stakes is None:
            object.__setattr__(self, "stakes", tuple([1.0] * self.n))
        if len(self.stakes) != self.n:
            raise ValueError(f"stakes len {len(self.stakes)} != n {self.n}")
        if self.n <= 0:
            raise ValueError("n must be positive")
        if self.u < 0 or self.r < 0:
            raise ValueError("u, r must be non-negative")

    @classmethod
    def bft(cls, f: int,
            stakes: Optional[Sequence[float]] = None) -> "RSMConfig":
        """3f+1 BFT RSM (u = r = f)."""
        return cls(n=3 * f + 1, u=f, r=f,
                   stakes=tuple(stakes) if stakes is not None else None)

    @classmethod
    def cft(cls, f: int,
            stakes: Optional[Sequence[float]] = None) -> "RSMConfig":
        """2f+1 CFT RSM (u = f, r = 0)."""
        return cls(n=2 * f + 1, u=f, r=0,
                   stakes=tuple(stakes) if stakes is not None else None)

    @property
    def total_stake(self) -> float:
        return float(sum(self.stakes))

    @property
    def quack_threshold(self) -> float:
        """Stake that must acknowledge before a QUACK forms: u + 1 (§4.1)."""
        return self.u + 1

    @property
    def dup_threshold(self) -> float:
        """Duplicate-QUACK size proving loss: r + 1, or 1 for CFT (§4.2)."""
        return max(self.r + 1, 1)

    def stake_array(self) -> np.ndarray:
        return np.asarray(self.stakes, dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Analytic link model used by the simulator and the capacity analysis.

    The paper's testbed (§6): c2-standard-8 VMs; geo experiments cap each
    *pairwise cross-RSM connection* at 135 Mbit/s with 163 ms ping. We model:

    msg_bytes:       application message size (paper sweeps 0.1 kB .. 1 MB).
    nic_gbps:        per-node NIC bandwidth (full duplex), Gbit/s.
    intra_gbps:      per-pair intra-RSM bandwidth, Gbit/s.
    cross_gbps:      per-pair cross-RSM bandwidth, Gbit/s (135 Mb/s geo).
    rtt_s:           cross-RSM round-trip, seconds (one simulator step).
    phi:             phi-list bound (§4.2 parallel cumulative acks).
    """

    msg_bytes: float = 1e6
    nic_gbps: float = 10.0
    intra_gbps: float = 10.0
    cross_gbps: float = 10.0
    rtt_s: float = 0.001
    phi: int = 1000

    @property
    def nic_Bps(self) -> float:
        return self.nic_gbps * 1e9 / 8.0

    @property
    def intra_Bps(self) -> float:
        return self.intra_gbps * 1e9 / 8.0

    @property
    def cross_Bps(self) -> float:
        return self.cross_gbps * 1e9 / 8.0

    def ack_meta_bytes(self, n_missing: int = 0, bft: bool = True) -> float:
        """Ack = 1 cumulative counter + phi-list entries (+ MAC when BFT)."""
        b = COUNTER_BYTES + SEQNO_BYTES * min(n_missing, self.phi)
        return b + (MAC_BYTES if bft else 0)

    @classmethod
    def geo(cls, msg_bytes: float = 1e6) -> "NetworkModel":
        """Paper's Iowa <-> Hong Kong setup (§6.1 geo-replication)."""
        return cls(msg_bytes=msg_bytes, nic_gbps=10.0, intra_gbps=10.0,
                   cross_gbps=0.135, rtt_s=0.163)

    @classmethod
    def lan(cls, msg_bytes: float = 1e6) -> "NetworkModel":
        return cls(msg_bytes=msg_bytes)


@dataclasses.dataclass(frozen=True)
class FailureScenario:
    """Which replicas misbehave and how.

    crash_s / crash_r:        step at which each sender/receiver replica
                              crashes (never sends/acks/broadcasts after);
                              -1 => never. Shape (n_s,) / (n_r,).
    byz_send_drop:            sender silently never originates its messages
                              (commission failure; still acks on the mirror
                              direction).  Shape (n_s,) bool.
    byz_recv_drop:            receiver drops direct cross-RSM messages (does
                              not store/bcast/ack them). Shape (n_r,) bool.
    byz_ack_advance:          receiver lies: acks +adv beyond truth.
                              Shape (n_r,) int.
    byz_ack_low:              receiver lies: always acks 0. (n_r,) bool.
    byz_bcast_partial:        receiver broadcasts only to the first
                              ``bcast_limit`` replicas (the §4.3 GC-stall
                              attack). (n_r,) bool.
    bcast_limit:              number of replicas a partial broadcaster reaches.
    byz_equiv_send:           equivocating sender: its *retransmissions*
                              carry payloads conflicting with the original,
                              so receivers detect the mismatch and discard
                              them (the message neither lands nor counts as
                              heard). Originals are honest. (n_s,) bool.
    byz_hq_advance:           sender lies in its §4.3 highest-quacked
                              piggyback: receiver ``i`` hears
                              ``min(true_prefix + adv + i, M)`` — a
                              *per-receiver-conflicting* inflated claim
                              (the equivocation form of the GC-stall
                              attack, defended by the r_s+1 attestation
                              quorum). 0 => honest. (n_s,) int.
    byz_ack_stale:            receiver replays its previous QUACK ack to
                              each sender verbatim (stale cum counter,
                              stale claims, stale complaint list) instead
                              of reporting fresh state. (n_r,) bool.
    drop_pair:                selective network fault: messages (originals
                              and retransmissions alike) from sender ``l``
                              to receiver ``j`` are silently dropped when
                              ``drop_pair[l][j]``; acks still flow.
                              Shape (n_s, n_r) bool (tuple of tuples).
    """

    crash_s: Optional[Tuple[int, ...]] = None
    crash_r: Optional[Tuple[int, ...]] = None
    byz_send_drop: Optional[Tuple[bool, ...]] = None
    byz_recv_drop: Optional[Tuple[bool, ...]] = None
    byz_ack_advance: Optional[Tuple[int, ...]] = None
    byz_ack_low: Optional[Tuple[bool, ...]] = None
    byz_bcast_partial: Optional[Tuple[bool, ...]] = None
    bcast_limit: int = 0
    byz_equiv_send: Optional[Tuple[bool, ...]] = None
    byz_hq_advance: Optional[Tuple[int, ...]] = None
    byz_ack_stale: Optional[Tuple[bool, ...]] = None
    drop_pair: Optional[Tuple[Tuple[bool, ...], ...]] = None

    @classmethod
    def none(cls) -> "FailureScenario":
        return cls()

    def validate(self, n_s: int, n_r: int,
                 steps: Optional[int] = None) -> "FailureScenario":
        """Shape/range-check the masks against an RSM pair (and horizon).

        Raises ``ValueError`` naming the offending field instead of
        letting a wrong-length mask fail deep inside tracing (or a
        beyond-horizon crash step silently no-op). Returns ``self`` so
        call sites can validate inline.
        """
        def _len(name, val, n):
            if val is not None and len(val) != n:
                raise ValueError(
                    f"FailureScenario.{name} has {len(val)} entries, "
                    f"RSM has {n} replicas (one entry per replica)")

        for name, n in (("crash_s", n_s), ("byz_send_drop", n_s),
                        ("byz_equiv_send", n_s), ("byz_hq_advance", n_s)):
            _len(name, getattr(self, name), n)
        for name in ("crash_r", "byz_recv_drop", "byz_ack_advance",
                     "byz_ack_low", "byz_bcast_partial", "byz_ack_stale"):
            _len(name, getattr(self, name), n_r)
        if self.drop_pair is not None:
            if len(self.drop_pair) != n_s or any(
                    len(row) != n_r for row in self.drop_pair):
                raise ValueError(
                    f"FailureScenario.drop_pair must be (n_s={n_s}, "
                    f"n_r={n_r}); got "
                    f"{(len(self.drop_pair),) + tuple(set(len(r) for r in self.drop_pair))}")
        for name in ("crash_s", "crash_r"):
            val = getattr(self, name)
            if val is None:
                continue
            for j, step in enumerate(val):
                if step < -1:
                    raise ValueError(
                        f"FailureScenario.{name}[{j}] = {step}: crash "
                        f"steps must be >= 0 (-1 = never crashes)")
                if steps is not None and step >= steps:
                    raise ValueError(
                        f"FailureScenario.{name}[{j}] = {step} is beyond "
                        f"the run horizon (steps = {steps}); the crash "
                        f"would silently never happen — use -1 for "
                        f"'never' or lower the crash step")
        if self.byz_hq_advance is not None and any(
                a < 0 for a in self.byz_hq_advance):
            raise ValueError("FailureScenario.byz_hq_advance entries must "
                             "be >= 0 (0 = honest)")
        if self.byz_ack_advance is not None and any(
                a < 0 for a in self.byz_ack_advance):
            raise ValueError("FailureScenario.byz_ack_advance entries "
                             "must be >= 0 (0 = honest)")
        if self.bcast_limit < 0:
            raise ValueError("FailureScenario.bcast_limit must be >= 0")
        return self

    @classmethod
    def crash_fraction(cls, n_s: int, n_r: int, frac: float,
                       seed: int = 0, at_step: int = 0) -> "FailureScenario":
        """Paper §6.2: randomly fail ``frac`` of replicas (send nothing)."""
        if not 0.0 <= frac <= 1.0:
            raise ValueError(f"crash_fraction frac must be in [0, 1], "
                             f"got {frac}")
        if at_step < 0:
            raise ValueError(f"crash_fraction at_step must be >= 0, "
                             f"got {at_step}")
        if n_s <= 0 or n_r <= 0:
            raise ValueError(f"crash_fraction needs positive replica "
                             f"counts, got n_s={n_s}, n_r={n_r}")
        rng = np.random.RandomState(seed)
        ks = max(0, min(int(round(frac * n_s)), n_s - 1))
        kr = max(0, min(int(round(frac * n_r)), n_r - 1))
        cs = np.full(n_s, -1, dtype=np.int64)
        cr = np.full(n_r, -1, dtype=np.int64)
        cs[rng.choice(n_s, size=ks, replace=False)] = at_step
        cr[rng.choice(n_r, size=kr, replace=False)] = at_step
        return cls(crash_s=tuple(int(x) for x in cs),
                   crash_r=tuple(int(x) for x in cr))


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static shape / schedule parameters for one simulation run.

    n_msgs:          number of messages M transmitted by the sender RSM.
    steps:           number of synchronous rounds T to simulate.
    window:          max new originations per sender per step (TCP window).
    scheduler:       'round_robin' | 'dss' | 'skewed_rr' | 'lottery' (§5.2).
    quantum:         DSS message quantum q (messages per scheduling quantum).
    phi:             phi-list bound (selective-repeat width, §4.2).
    seed:            PRNG seed (lottery scheduler only).
    window_slots:    sliding-window width W for the GC-driven windowed
                     simulator core: scan state covers only the W in-flight
                     sequence numbers above the GC frontier (§4.3) instead
                     of all M. None => dense (full-M) state; "auto" =>
                     sized from n, window, phi and chunk_steps
                     (``gc.default_window_slots``), falling back to the
                     dense path when the computed W would not be smaller
                     than M (windowing would buy nothing); an int fixes W.
                     Rotation past the GC frontier happens *on device*
                     (in-graph ``lax.dynamic_slice`` ring shift at each
                     chunk boundary) — the host only drains a bounded
                     O(W) output queue per chunk, never the scan state.
    chunk_steps:     rounds per compiled scan chunk in windowed mode; the
                     window rotates (GC frontier advances in-graph) at
                     chunk boundaries.
    adaptive_window: overflow semantics when a stalled GC frontier pins
                     the window while originals keep dispatching. True
                     (default): grow W adaptively (2x, migrating the scan
                     state on device); when W would reach M, migrate the
                     scan state into the dense layout (base 0, W = M) and
                     continue the same chunked run — partial progress is
                     kept, never rerun. False: raise ``ValueError`` (the
                     strict pre-growth behaviour, useful for sizing
                     tests).
    superchunk:      fusion depth K of the pipelined windowed engine: up
                     to K chunk bodies (rotations included) execute
                     inside ONE compiled dispatch (``lax.scan`` over
                     chunk boundaries, K-deep output queue), and the host
                     drains a dispatch's queue while the *next* dispatch
                     computes (async double buffering). Fusion breaks
                     automatically at every boundary where host
                     interaction is mandatory — recorder checkpoints,
                     ``fail_schedule``/``commit_floors`` updates,
                     adaptive window growth and dense fallback — so any
                     K is bit-identical to K = 1. ``superchunk=1``
                     restores the fully synchronous per-chunk loop
                     (dispatch, block, drain).
    debug_checks:    enable per-drain host-side invariant checks (the
                     window-base mirror vs the in-graph rotation) AND
                     run the whole windowed batch under the analysis
                     sanitizer's ``engine_guard`` (``repro.analysis``),
                     which raises on any implicit device->host transfer
                     in the drain path. Off by default so steady-state
                     drains never block on a consistency assertion;
                     turned on in tests.
    use_pallas_quack: route the stake-weighted QUACK/loss quorum bitmaps
                     (the protocol's compute hot loop) through the
                     Pallas TPU kernel ``kernels.quack_scan`` instead of
                     the jnp einsum path. Interpret mode on CPU (bit-
                     faithful, slow); default off.
    collect_metrics: carry the in-graph observability fabric
                     (``repro.obs.metrics.MetricsCarry``) through the
                     chunk/superchunk scan bodies: per-lane delivery-
                     latency histograms (power-of-two buckets), window-
                     occupancy and GC-frontier-lag high-water marks,
                     QUACK/loss-quorum trigger counts and resend totals,
                     drained with the existing per-dispatch queue (zero
                     extra dispatches or transfers). Off by default —
                     disabled runs stage byte-identical jaxprs
                     (``tests/test_obs.py``).
    """

    n_msgs: int = 256
    steps: int = 200
    window: int = 4
    scheduler: str = "round_robin"
    quantum: int = 64
    phi: int = 32
    seed: int = 0
    window_slots: Optional[object] = None     # None | "auto" | int
    chunk_steps: int = 32
    adaptive_window: bool = True
    superchunk: int = 8
    debug_checks: bool = False
    use_pallas_quack: bool = False
    collect_metrics: bool = False

    def __post_init__(self):
        ws = self.window_slots
        if ws is not None and ws != "auto" and (not isinstance(ws, int)
                                                or ws <= 0):
            raise ValueError(f"window_slots must be None, 'auto' or a "
                             f"positive int, got {ws!r}")
        if self.chunk_steps <= 0:
            raise ValueError("chunk_steps must be positive")
        if self.superchunk <= 0:
            raise ValueError("superchunk must be positive")


def lcm_scale_factors(total_s: float, total_r: float) -> Tuple[float, float]:
    """§5.3 LCM stake rescaling: psi_s = LCM/delta_s, psi_r = LCM/delta_r.

    Stakes may be non-integer; we rescale via the LCM of the integerized
    totals (the paper assumes integral stake).
    """
    ts, tr = int(round(total_s)), int(round(total_r))
    if ts <= 0 or tr <= 0:
        raise ValueError("total stakes must be positive")
    l = math.lcm(ts, tr)
    return l / ts, l / tr
