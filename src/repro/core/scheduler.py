"""Sender/receiver scheduling (§4.1 round-robin, §5.2 stake-aware DSS).

Four schedulers, matching the paper:

* ``round_robin``  — unit-stake partitioning: message k is originated by
  sender ``k mod n_s``; each sender rotates its receiver every send (§4.1).
* ``skewed_rr``    — strawman V1: sender l takes delta_l consecutive turns.
* ``lottery``      — strawman V2: ticket lottery proportional to stake.
* ``dss``          — Dynamic Sharewise Scheduler: Hamilton apportionment of
  a message quantum q across stakes, interleaved smoothly (WFQ-style) so
  fairness holds *within* the quantum, not just across quanta (§5.2).

All return an assignment ``sender_of(k)`` for message indices and a receiver
rotation; they are numpy-side (schedule construction is control-plane work —
the hot data-plane state transitions stay in JAX).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = [
    "hamilton_apportion",
    "dss_sequence",
    "skewed_rr_sequence",
    "lottery_sequence",
    "round_robin_sequence",
    "sender_assignment",
    "receiver_for",
]


def hamilton_apportion(stakes: np.ndarray, q: int) -> np.ndarray:
    """Hamilton's method of apportionment (§5.2 DSS, Figure 7).

    stakes: (n,) positive weights; q: total seats (messages per quantum).
    Returns integer counts c with sum(c) == q, matching the paper's worked
    example: standard divisor SD = total/q, standard quota SQ_l = delta_l/SD,
    lower quota LQ_l = floor(SQ_l), leftover seats go to the largest
    penalty ratios PR_l = SQ_l - LQ_l (ties broken by replica index for
    determinism).
    """
    stakes = np.asarray(stakes, dtype=np.float64)
    if q < 0:
        raise ValueError("q must be >= 0")
    total = stakes.sum()
    if total <= 0:
        raise ValueError("total stake must be positive")
    sd = total / max(q, 1)
    sq = stakes / sd if q > 0 else np.zeros_like(stakes)
    lq = np.floor(sq).astype(np.int64)
    pr = sq - lq
    left = q - int(lq.sum())
    # largest penalty ratio first; ties by lower index (stable determinism)
    order = np.lexsort((np.arange(len(stakes)), -pr))
    c = lq.copy()
    if left > 0:
        c[order[:left]] += 1
    return c


def _smooth_interleave(counts: np.ndarray) -> np.ndarray:
    """WFQ-style smooth sequencing of per-node counts within a quantum.

    Deterministic earliest-virtual-finish-time ordering: node l's i-th slot
    has virtual time (i + 1) / counts[l]; emit in ascending virtual time.
    Guarantees each node's sends are spread evenly through the quantum (the
    DSS 'fairness over short periods' requirement that lottery scheduling
    fails, §5.2).
    """
    counts = np.asarray(counts, dtype=np.int64)
    q = int(counts.sum())
    nodes = []
    vtimes = []
    for l, c in enumerate(counts):
        if c <= 0:
            continue
        i = np.arange(1, c + 1, dtype=np.float64)
        nodes.append(np.full(c, l, dtype=np.int64))
        vtimes.append(i / c)
    if not nodes:
        return np.zeros(0, dtype=np.int64)
    nodes = np.concatenate(nodes)
    vtimes = np.concatenate(vtimes)
    order = np.lexsort((nodes, vtimes))
    seq = nodes[order]
    assert seq.shape[0] == q
    return seq


def dss_sequence(stakes: np.ndarray, q: int, n_msgs: int) -> np.ndarray:
    """DSS sender sequence for ``n_msgs`` messages with quantum ``q``."""
    counts = hamilton_apportion(stakes, q)
    quantum_seq = _smooth_interleave(counts)
    if quantum_seq.shape[0] == 0:
        raise ValueError("empty quantum")
    reps = -(-n_msgs // quantum_seq.shape[0])
    return np.tile(quantum_seq, reps)[:n_msgs]


def skewed_rr_sequence(stakes: np.ndarray, n_msgs: int) -> np.ndarray:
    """Strawman V1 (§5.2): node l takes floor(delta_l) consecutive turns.

    Fair in the long run but serializes: a single high-stake faulty node can
    own a long contiguous block of the stream.
    """
    stakes = np.asarray(stakes)
    blocks = [np.full(max(int(round(s)), 1), l, dtype=np.int64)
              for l, s in enumerate(stakes)]
    cycle = np.concatenate(blocks)
    reps = -(-n_msgs // cycle.shape[0])
    return np.tile(cycle, reps)[:n_msgs]


def lottery_sequence(stakes: np.ndarray, n_msgs: int,
                     seed: int = 0) -> np.ndarray:
    """Strawman V2 (§5.2): ticket lottery. Fair only in expectation."""
    stakes = np.asarray(stakes, dtype=np.float64)
    p = stakes / stakes.sum()
    rng = np.random.RandomState(seed)
    return rng.choice(len(stakes), size=n_msgs, p=p).astype(np.int64)


def round_robin_sequence(n_nodes: int, n_msgs: int) -> np.ndarray:
    """§4.1: message k is sent by replica k mod n_s."""
    return (np.arange(n_msgs, dtype=np.int64) % n_nodes)


def sender_assignment(scheduler: str, stakes: np.ndarray, n_msgs: int,
                      quantum: int = 64, seed: int = 0) -> np.ndarray:
    """Original sender of each message index under the chosen scheduler."""
    n = len(stakes)
    if scheduler == "round_robin":
        return round_robin_sequence(n, n_msgs)
    if scheduler == "dss":
        return dss_sequence(np.asarray(stakes), quantum, n_msgs)
    if scheduler == "skewed_rr":
        return skewed_rr_sequence(np.asarray(stakes), n_msgs)
    if scheduler == "lottery":
        return lottery_sequence(np.asarray(stakes), n_msgs, seed)
    raise ValueError(f"unknown scheduler {scheduler!r}")


def receiver_for(sender_seq: np.ndarray, n_r: int,
                 recv_stakes: Optional[np.ndarray] = None,
                 scheduler: str = "round_robin",
                 quantum: int = 64, seed: int = 1) -> np.ndarray:
    """Receiver of each message's original send.

    §4.1: the l-th sender rotates receivers every send: its i-th message
    goes to ``(prev + 1) mod n_r``. For stake-aware scheduling the receiver
    side is apportioned with the same DSS machinery (the paper notes DSS
    identifies senders and receivers identically, §5.2).
    """
    n_msgs = sender_seq.shape[0]
    if (scheduler in ("dss", "skewed_rr", "lottery")
            and recv_stakes is not None):
        base = sender_assignment(scheduler, recv_stakes, n_msgs,
                                 quantum=quantum, seed=seed)
        return base
    # per-sender rotation: i-th send of sender l -> (l + i) mod n_r
    recv = np.zeros(n_msgs, dtype=np.int64)
    counters = np.zeros(int(sender_seq.max()) + 1 if n_msgs else 1,
                        dtype=np.int64)
    for k in range(n_msgs):
        l = sender_seq[k]
        recv[k] = (l + counters[l]) % n_r
        counters[l] += 1
    return recv
