"""Garbage collection (§4.3), including the Byzantine GC-stall defence.

Naive rule: a QUACKed message has provably reached an honest receiver, so
the sender may drop it. The paper's counterexample: a Byzantine receiver
broadcasts m_k to exactly u_r+1 replicas of which u_r are faulty; a QUACK
forms, m_k is GC'd, the faulty replicas go silent — now no QUACK can ever
form past k and honest receivers keep duplicate-acking a message the sender
no longer holds.

Fix: when a sender sees a duplicate QUACK for k' below its GC frontier, it
piggybacks its *highest quacked sequence number* k on outgoing traffic.
After ``r_s + 1`` distinct senders (stake-weighted) report >= k, receivers
know >= 1 honest sender attests that every message <= k reached *some*
honest receiver, and may advance their cumulative ack floor to k (§4.3
strategy (1); strategy (2) — fetching m from peers — is modelled by the
intra-RSM broadcast already).
"""

from __future__ import annotations

import jax.numpy as jnp

from .quack import weighted_quorum_prefix

__all__ = ["collectable", "ack_floor_from_reports"]


def collectable(quacked_prefix: jnp.ndarray, m: int) -> jnp.ndarray:
    """(n_s,) quacked prefix -> (n_s, M) bool of GC-able messages."""
    idx = jnp.arange(m, dtype=jnp.int32)
    return idx[None, :] < quacked_prefix[:, None]


def ack_floor_from_reports(hq_reports: jnp.ndarray,
                           sender_stakes: jnp.ndarray,
                           r_s_threshold: float) -> jnp.ndarray:
    """Receivers' provable ack floor from highest-quacked metadata.

    hq_reports: (n_r, n_s) int — highest-quacked seqno claimed by each
    sender, as heard by each receiver (0 if never heard). The floor is the
    largest k such that senders totalling >= r_s + 1 stake claim >= k —
    the same order-statistic as a QUACK, on the sender side.
    Returns (n_r,) int32.
    """
    return weighted_quorum_prefix(hq_reports, sender_stakes, r_s_threshold)
