"""Garbage collection (§4.3), including the Byzantine GC-stall defence.

Naive rule: a QUACKed message has provably reached an honest receiver, so
the sender may drop it. The paper's counterexample: a Byzantine receiver
broadcasts m_k to exactly u_r+1 replicas of which u_r are faulty; a QUACK
forms, m_k is GC'd, the faulty replicas go silent — now no QUACK can ever
form past k and honest receivers keep duplicate-acking a message the sender
no longer holds.

Fix: when a sender sees a duplicate QUACK for k' below its GC frontier, it
piggybacks its *highest quacked sequence number* k on outgoing traffic.
After ``r_s + 1`` distinct senders (stake-weighted) report >= k, receivers
know >= 1 honest sender attests that every message <= k reached *some*
honest receiver, and may advance their cumulative ack floor to k (§4.3
strategy (1); strategy (2) — fetching m from peers — is modelled by the
intra-RSM broadcast already).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from .quack import weighted_quorum_prefix

__all__ = ["collectable", "ack_floor_from_reports", "gc_frontier",
           "gc_frontier_device", "grow_window", "default_window_slots",
           "resolve_window_slots", "chunk_boundaries", "snap_to_boundary"]


def chunk_boundaries(steps: int, chunk_steps: int) -> np.ndarray:
    """Rounds at which a chunked windowed run starts a compiled chunk.

    These are the only rounds where the scan state is observable from the
    host — where the GC frontier advances, commit floors move, failure
    schedules may be swapped in, and ``repro.replay`` checkpoints can be
    captured or resumed from.
    """
    if steps <= 0:
        return np.zeros(0, dtype=np.int64)
    return np.arange(0, steps, max(int(chunk_steps), 1), dtype=np.int64)


def snap_to_boundary(t: int, chunk_steps: int) -> int:
    """Largest chunk-boundary round <= ``t`` (where a mid-run event —
    an injected crash, a replay fork — can actually take effect)."""
    c = max(int(chunk_steps), 1)
    return (max(int(t), 0) // c) * c


def collectable(quacked_prefix: jnp.ndarray, m: int) -> jnp.ndarray:
    """(n_s,) quacked prefix -> (n_s, M) bool of GC-able messages."""
    idx = jnp.arange(m, dtype=jnp.int32)
    return idx[None, :] < quacked_prefix[:, None]


def ack_floor_from_reports(hq_reports: jnp.ndarray,
                           sender_stakes: jnp.ndarray,
                           r_s_threshold: float) -> jnp.ndarray:
    """Receivers' provable ack floor from highest-quacked metadata.

    hq_reports: (n_r, n_s) int — highest-quacked seqno claimed by each
    sender, as heard by each receiver (0 if never heard). The floor is the
    largest k such that senders totalling >= r_s + 1 stake claim >= k —
    the same order-statistic as a QUACK, on the sender side.
    Returns (n_r,) int32.
    """
    return weighted_quorum_prefix(hq_reports, sender_stakes, r_s_threshold)


def gc_frontier(*, base: int, t_next: int, m: int,
                known: np.ndarray, bcast_q: np.ndarray,
                recv_has: np.ndarray, ack_floor: np.ndarray,
                stakes_r: np.ndarray, quack_thresh: float,
                orig_sent: np.ndarray, crash_r: np.ndarray,
                byz_ack_low: np.ndarray) -> int:
    """How many window slots may be retired without changing the run.

    Host-side (numpy) companion of the sliding-window simulator: given the
    window state after round ``t_next - 1`` (window columns = absolute
    indices ``base .. base + W``), return the number of leading slots whose
    per-message state can never change again, so the window base may
    advance past them. A slot ``k`` is retirable iff

      * its original send has actually been dispatched (``orig_sent[k]``;
        under commit-gated dispatch — chained topologies — the schedule
        round alone is only a lower bound, so the dispatch *bit* is what
        proves the slot can no longer originate),
      * it is QUACKed at *every* sender — so no sender can ever declare a
        loss / resend / re-quack it (§4.3: the quacked prefix is what both
        sides are allowed to forget),
      * no intra-RSM broadcast of it is still queued, and
      * every receiver that will still emit acks (not crashed by
        ``t_next``, not a low-acking liar whose payload ignores its state)
        effectively holds it (``recv_has`` or below its §4.3 ack floor) —
        otherwise the slot would keep occupying one of the receiver's phi
        gap slots and perturb future ack payloads.

    The retired prefix is exactly the metadata both RSMs "forget" in the
    paper's GC; the conjunction above is what makes forgetting *exact* in
    the simulator (bit-identical to the dense run).
    """
    w = known.shape[-1]
    abs_idx = base + np.arange(w, dtype=np.int64)
    # float32 to match the device step's stake einsum exactly — retirement
    # must agree bit-for-bit with the compiled QUACK decision.
    w_known = np.einsum("ljm,j->lm", known.astype(np.float32),
                        np.asarray(stakes_r, dtype=np.float32))
    quacked_everywhere = (w_known >= np.float32(quack_thresh)).all(axis=0)
    dispatched = np.asarray(orig_sent)[:w]
    no_pending_bcast = ~bcast_q.any(axis=0)
    relevant = ((np.asarray(crash_r) < 0) | (np.asarray(crash_r) > t_next))
    relevant = relevant & ~np.asarray(byz_ack_low)
    eff = recv_has | (abs_idx[None, :] < np.asarray(ack_floor)[:, None])
    eff_full = (eff | ~relevant[:, None]).all(axis=0)
    ok = (quacked_everywhere & dispatched & no_pending_bcast & eff_full
          & (abs_idx < m))
    return int(np.cumprod(ok.astype(np.int64)).sum())


def gc_frontier_device(*, base, t_next, m: int,
                       known, bcast_q, recv_has, ack_floor,
                       stakes_r, quack_thresh: float,
                       orig_sent, crash_r, byz_ack_low):
    """Traced (jnp) port of :func:`gc_frontier` — runs inside the chunk.

    Same retirement rule, evaluated on device so the sliding-window
    simulator can rotate its ring buffers in-graph instead of pulling the
    state to the host every chunk. ``base``/``t_next`` may be traced
    scalars and every array a traced value (including under ``jax.vmap``
    with per-scenario window bases). The stake einsum is float32, exactly
    like the compiled QUACK decision and the numpy oracle above, so all
    three agree bit-for-bit.

    ``orig_sent`` is the (W,) window slice of the carried dispatch bits
    (``SimState.orig_sent``); ``crash_r``/``byz_ack_low`` come from the
    traced ``FailArrays``. Returns a () int32 — the number of leading
    window slots that may be retired.
    """
    w = known.shape[-1]
    abs_idx = (base + jnp.arange(w, dtype=jnp.int32)).astype(jnp.int32)
    w_known = jnp.einsum("ljm,j->lm", known.astype(jnp.float32),
                         stakes_r.astype(jnp.float32))
    # asarray, not jnp.float32(): the threshold may be a traced scalar
    # (stake re-weighting rides the FailArrays), and np.float32(tracer)
    # would force concretization
    thr = jnp.asarray(quack_thresh, dtype=jnp.float32)
    quacked_everywhere = (w_known >= thr).all(axis=0)
    dispatched = orig_sent
    no_pending_bcast = ~bcast_q.any(axis=0)
    relevant = ((crash_r < 0) | (crash_r > t_next)) & ~byz_ack_low
    eff = recv_has | (abs_idx[None, :] < ack_floor[:, None])
    eff_full = (eff | ~relevant[:, None]).all(axis=0)
    ok = (quacked_everywhere & dispatched & no_pending_bcast & eff_full
          & (abs_idx < m))
    return jnp.cumprod(ok.astype(jnp.int32)).sum().astype(jnp.int32)


def grow_window(w: int, base: int, need: int, m: int) -> Optional[int]:
    """Adaptive window sizing on overflow (§4.3 under a stalled frontier).

    A Byzantine stall can pin the GC frontier while originals keep
    dispatching, so the highest in-flight sequence number ``need`` outruns
    the window ``[base, base + w)``. Double ``w`` until the window covers
    ``need`` again; if the required width would reach the full stream
    length ``m``, windowing buys nothing over the dense state — return
    ``None`` to signal the caller to migrate the scan state into the
    dense layout (base 0, W = M) and continue from there.
    """
    new_w = max(int(w), 1)
    while need >= base + new_w:
        new_w *= 2
    if new_w >= m:
        return None
    return new_w


def default_window_slots(n_s: int, n_r: int, send_window: int, phi: int,
                         chunk_steps: int, slack_rounds: int = 8) -> int:
    """Window width W for the sliding-window simulator (§4.3 sizing).

    The frontier only advances at chunk boundaries, so the window must hold
    one chunk's worth of fresh originations (``n_s * send_window`` per
    round) plus the un-retired backlog: a message QUACKs at every sender
    only after the ack rotation has visited all of them (~``n_s`` rounds)
    and the intra-RSM broadcast landed (+receiver rotation slack, ~``n_r``),
    and the phi-list bounds how far ahead complaints reach. Failure-free
    this is a constant independent of stream length — the paper's P1.
    """
    lag = chunk_steps + n_s + n_r + slack_rounds
    w = n_s * max(send_window, 1) * lag + phi
    return int(-(-w // 64) * 64)


def resolve_window_slots(window_slots, *, n_s: int, n_r: int,
                         send_window: int, phi: int, chunk_steps: int,
                         m: int) -> int:
    """Resolve ``SimConfig.window_slots`` (None | "auto" | int) to a width.

    Returns the concrete window width W, with 0 meaning the dense
    (full-M) kernel. ``"auto"`` sizes W via :func:`default_window_slots`
    and clamps to dense when the computed W would not be smaller than M —
    windowing would buy nothing there. This is the single home of the
    auto→dense clamp rule, shared by ``build_spec`` and the bench/figure
    wiring (``bench_windowed``, ``bench_topology``, fig8/fig9), so the
    kernel-selection story cannot drift between them.
    """
    if window_slots is None:
        return 0
    if window_slots == "auto":
        w = default_window_slots(n_s, n_r, send_window, phi, chunk_steps)
        return 0 if w >= m else w
    return int(window_slots)
