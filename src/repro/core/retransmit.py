"""Retransmission logic (§4.2) — detection, election, and bounds.

Key properties implemented and tested:
* loss is declared only after ``r + 1`` distinct replicas (stake-weighted)
  repeat a complaint — no single Byzantine replica can trigger a spurious
  resend (1 complaint suffices in CFT mode, r == 0);
* the retransmitter is elected with *zero* extra communication:
  ``sender_new = (sender_orig + #retransmit) mod n_s``;
* at most ``u_s + u_r + 1`` retransmissions are needed under synchrony
  (Lemma 1), and with random pairings 72 resends reach 1e-9 failure
  probability regardless of RSM size (Theorem 1).
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

__all__ = [
    "elect_retransmitter",
    "declared_lost",
    "max_retransmissions",
    "theorem1_resends",
    "faulty_pair_bound",
]


def elect_retransmitter(orig_sender: jnp.ndarray, retry_count: jnp.ndarray,
                        n_s: int) -> jnp.ndarray:
    """§4.2: sender_new = (sender_original + #retransmit) mod n_s.

    orig_sender, retry_count: (M,) int arrays; elementwise election. Every
    honest replica evaluates this identically — a single retransmitter per
    round with no coordination messages.
    """
    return ((orig_sender + retry_count) % n_s).astype(jnp.int32)


def declared_lost(repeat_complaints: jnp.ndarray, stakes: jnp.ndarray,
                  dup_threshold: float) -> jnp.ndarray:
    """Stake-weighted repeated-complaint quorum (§4.2 duplicate QUACKs).

    repeat_complaints: (n_r, M) bool — receiver j has complained about
    message k in two successive acks to the same sender (the duplicate-ack
    condition generalized to phi-lists). A message is *definitely* lost
    when complainers total >= dup_threshold stake (r+1; at least one honest).
    Returns (M,) bool.
    """
    w = jnp.einsum("jm,j->m", repeat_complaints.astype(stakes.dtype), stakes)
    return w >= dup_threshold


def max_retransmissions(u_s: int, u_r: int) -> int:
    """Lemma 1: at most u_s + u_r + 1 attempts reach a correct pair."""
    return u_s + u_r + 1


def faulty_pair_bound(n_s: int, u_s: int, n_r: int, u_r: int) -> float:
    """Theorem 1, Eq. (1)/(5): fraction of sender-receiver pairs with a fault.

    Faulty = u_s*n_r + u_r*n_s - u_s*u_r; the bound Faulty/(n_s*n_r) <= 3/4
    holds whenever both replication factors a = (n-1)/u are >= 2.
    """
    faulty = u_s * n_r + u_r * n_s - u_s * u_r
    return faulty / float(n_s * n_r)


def theorem1_resends(p_fail: float = 1e-9, p_pair: float = 0.75) -> int:
    """Theorem 1: q = ceil(log_{p_pair} p_fail); 72 for 1e-9 at 3/4."""
    return int(math.ceil(math.log(p_fail) / math.log(p_pair)))


def empirical_delivery_probability(n_s: int, u_s: int, n_r: int, u_r: int,
                                   retries: int, trials: int = 20000,
                                   seed: int = 0) -> float:
    """Monte-Carlo check of the §4.2 claim: with a fixed ratio of faulty
    nodes and random ids, ~8 retries already give 99.9% delivery."""
    rng = np.random.RandomState(seed)
    faulty_s = np.zeros(n_s, bool)
    faulty_s[:u_s] = True
    faulty_r = np.zeros(n_r, bool)
    faulty_r[:u_r] = True
    ok = 0
    for _ in range(trials):
        s = rng.permutation(n_s)[:retries % n_s or n_s]
        r = rng.permutation(n_r)[:retries % n_r or n_r]
        # a rotation visits distinct pairs; success iff some pair is clean
        m = min(retries, len(s), len(r))
        if np.any(~faulty_s[s[:m]] & ~faulty_r[r[:m]]):
            ok += 1
    return ok / trials
