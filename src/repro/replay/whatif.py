"""Forked what-if driver: N divergent futures from one shared prefix.

``fork_whatif`` takes a checkpoint and N schedule variants
(:class:`ForkSpec`), tiles the checkpointed scan state across N fork
blocks, and executes *all* forks as one batch on the existing vmapped
windowed chunk kernel — one dispatch per chunk for the entire fork set,
per-fork (indeed per-lane) window bases, O(N·B·W) device state. The
chunk program compiles per (window width, batch shape): a cold fork
batch pays that once for its N·B shape — independent of chunk count,
fork count and edit content, because the schedule edits are traced-input
swaps — and re-forking at the same shape compiles *nothing*, however
different the edits. The compile delta is measured
(``WhatIfReport.chunk_traces``) rather than assumed.

Chained topologies fork too: the lane->upstream commit-floor plan is
replicated per fork block, so each future routes its own retired
prefixes downstream independently.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from ..core.simulator import (ChunkCheckpoint, SimResult,
                              _run_windowed_batch, chunk_trace_count)
from ..topology.engine import plan_floors
from .replay import (InjectionSet, _normalize_injections,
                     _validate_injection, build_fail_schedule)
from .trace import Injection, RunTrace

__all__ = ["ForkSpec", "ForkOutcome", "WhatIfReport", "fork_whatif"]


@dataclasses.dataclass(frozen=True)
class ForkSpec:
    """One what-if future: a name and its schedule edits (an empty edit
    list is the baseline fork — the original schedule continued)."""

    name: str
    injections: InjectionSet = ()


def _lane_stats(r: SimResult) -> Dict[str, int]:
    mask = np.asarray(r.deliver_time) >= 0
    prefix = int(np.argmin(mask)) if not mask.all() else int(len(mask))
    return dict(
        delivered=int(mask.sum()),
        delivered_prefix=prefix,
        retired_prefix=int(r.gc_frontiers[-1]),
        resends=int(np.sum(r.metrics.resends)),
        delivery_step=int(r.deliver_time.max()) if mask.all() else -1,
    )


@dataclasses.dataclass
class ForkOutcome:
    """One future's results plus per-lane divergence metrics."""

    name: str
    results: List[SimResult]            # one per lane
    stats: Dict[str, Dict[str, int]]    # lane name -> metrics
    divergence: Dict[str, Dict[str, int]]  # lane -> metric -> delta vs base

    def __getitem__(self, lane: str) -> SimResult:
        return self.results[list(self.stats).index(lane)]


@dataclasses.dataclass
class WhatIfReport:
    """All futures forked from one checkpoint, executed as one batch."""

    from_step: int
    lane_names: List[str]
    forks: List[ForkOutcome]
    baseline: Dict[str, Dict[str, int]]   # the original schedule's stats
    chunk_traces: int    # fresh chunk compilations the fork batch cost

    def __getitem__(self, name: str) -> ForkOutcome:
        for f in self.forks:
            if f.name == name:
                return f
        raise KeyError(name)

    def rows(self) -> List[dict]:
        """Flat per-fork-per-lane rows (bench / JSON friendly)."""
        out = []
        for f in self.forks:
            for lane in self.lane_names:
                out.append(dict(fork=f.name, lane=lane, **f.stats[lane],
                                **{f"d_{k}": v
                                   for k, v in f.divergence[lane].items()}))
        return out


def _tile_checkpoint(ckpt: ChunkCheckpoint, n: int) -> ChunkCheckpoint:
    """Replicate a B-lane checkpoint into N fork blocks (N*B lanes)."""

    def rep(a, axis=0):
        return np.concatenate([np.asarray(a)] * n, axis=axis)

    return ChunkCheckpoint(
        t=ckpt.t, window_slots=ckpt.window_slots,
        bases=rep(ckpt.bases),
        state=type(ckpt.state)(*(rep(x) for x in ckpt.state)),
        fails=type(ckpt.fails)(*(rep(x) for x in ckpt.fails)),
        floors=rep(ckpt.floors),
        out_quack=rep(ckpt.out_quack), out_deliver=rep(ckpt.out_deliver),
        out_retry=rep(ckpt.out_retry), out_recv=rep(ckpt.out_recv),
        metric_parts=tuple(type(part)(*(rep(x) for x in part))
                           for part in ckpt.metric_parts),
        bases_hist=rep(ckpt.bases_hist, axis=1),
        growth_events=ckpt.growth_events,
        send_step=(None if ckpt.send_step is None
                   else rep(ckpt.send_step)),
    )


def _reattribute_events(events, n_b: int, from_step: int):
    """Map tiled-lane growth indices back to (fork, lane).

    Events inherited from the shared pre-fork prefix (``step <
    from_step``) already carry original lane indices; events the fork
    batch itself recorded use the tiled N*B layout and are split back
    into a fork id + original lane, so consumers never see a mixed
    index space.
    """
    return tuple(
        e if e.step < from_step else dataclasses.replace(
            e, fork=e.scenario // n_b, scenario=e.scenario % n_b)
        for e in events)


def fork_whatif(trace: RunTrace, from_step: int,
                forks: Sequence[ForkSpec]) -> WhatIfReport:
    """Execute N schedule variants from one checkpoint as one batch.

    Each fork's injections use the same format as :func:`replay` /
    :func:`replay_topology` (lane-keyed mapping, or a bare sequence for
    lane 0). Divergence metrics are reported per fork and lane, deltas
    taken against the original run's outputs when the trace carries
    them.
    """
    if not forks:
        raise ValueError("fork_whatif needs at least one ForkSpec")
    names = [f.name for f in forks]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate fork names: {names}")
    n_forks, n_b = len(forks), trace.n_lanes
    ckpt = trace.checkpoint_at(int(from_step))

    # per-fork edits re-keyed onto the tiled (fork-major) lane layout
    tiled_specs = [s for _ in range(n_forks) for s in trace.specs]
    by_tiled_lane: Dict[int, List[Injection]] = {}
    for f_idx, fork in enumerate(forks):
        by_lane = _normalize_injections(trace, fork.injections)
        for lane, edits in by_lane.items():
            for e in edits:
                _validate_injection(trace, e, int(from_step))
            by_tiled_lane[f_idx * n_b + lane] = edits
    schedule, _ = build_fail_schedule(trace, by_tiled_lane,
                                      specs=tiled_specs)

    commit_floors = None
    if trace.floor_plan:
        m = trace.specs[0].m
        plan = {f * n_b + i: f * n_b + j
                for f in range(n_forks)
                for i, j in trace.floor_plan.items()}

        def commit_floors(t, bases):        # noqa: F811
            return plan_floors(plan, n_forks * n_b, m, bases)

    traces_before = chunk_trace_count()
    results = _run_windowed_batch(
        tiled_specs, commit_floors=commit_floors,
        resume=_tile_checkpoint(ckpt, n_forks),
        fail_schedule=schedule if by_tiled_lane else None)
    traces_after = chunk_trace_count()

    # divergence baseline: the original run's outputs when the trace
    # still carries them; for traces loaded from disk, an unchanged
    # replay of the same checkpoint (bit-identical to the original, so
    # the deltas are the same).
    base_results = trace.results
    if base_results is None:
        cf = None
        if trace.floor_plan:
            m = trace.specs[0].m

            def cf(t, bases):                   # noqa: F811
                return plan_floors(trace.floor_plan, n_b, m, bases)

        base_results = _run_windowed_batch(list(trace.specs),
                                           commit_floors=cf, resume=ckpt)
    baseline = {lane: _lane_stats(r)
                for lane, r in zip(trace.lane_names, base_results)}

    outcomes = []
    for f_idx, fork in enumerate(forks):
        block = results[f_idx * n_b:(f_idx + 1) * n_b]
        for r in block:
            r.window_growth_events = _reattribute_events(
                r.window_growth_events, n_b, int(from_step))
        stats = {lane: _lane_stats(r)
                 for lane, r in zip(trace.lane_names, block)}
        divergence = {
            lane: {k: stats[lane][k] - baseline[lane][k]
                   for k in stats[lane]}
            for lane in trace.lane_names}
        outcomes.append(ForkOutcome(name=fork.name, results=block,
                                    stats=stats, divergence=divergence))
    return WhatIfReport(from_step=int(from_step),
                        lane_names=list(trace.lane_names),
                        forks=outcomes, baseline=baseline,
                        chunk_traces=traces_after - traces_before)
