"""repro.replay — checkpointing, deterministic replay, what-if forking.

The simulator's chunked windowed runs expose their scan state at chunk
boundaries; this package turns that into an experimentation engine:

* **Checkpointing** — ``record_simulation`` / ``record_batch`` /
  ``record_topology`` run the existing engines while capturing
  chunk-boundary snapshots (``RunTrace``: ring-buffer scan state, window
  bases, GC-frontier trajectory, drained output prefix, commit floors
  and the ``FailArrays`` in force), serializable via ``save``/``load``
  (npz).
* **Deterministic replay with injection** — ``replay`` /
  ``replay_topology`` resume any checkpoint, optionally with
  ``Injection`` schedule edits (crash/recover a replica, open/heal a
  partition, change drop schedules from a chunk boundary on), reusing
  the already-compiled windowed chunk. Replay with an unchanged
  schedule is bit-identical to the original run; replay with edits is
  bit-identical to a from-scratch run executing the merged schedule
  (engine and numpy oracle both — ``repro.replay.oracle``).
* **Forked what-if driver** — ``fork_whatif`` executes N schedule
  variants from one checkpoint as a single vmapped batch (one dispatch
  per chunk, per-fork window bases) and reports per-fork divergence.

    res, trace = record_simulation(spec)
    futures = fork_whatif(trace, from_step=32, forks=[
        ForkSpec("crash-early", [Injection(32, crash_scenario)]),
        ForkSpec("baseline", []),
    ])
"""

from .oracle import replay_oracle, replay_topology_oracle
from .replay import (record_batch, record_simulation, record_topology,
                     replay, replay_topology)
from .trace import Injection, RunTrace, TraceRecorder
from .whatif import ForkOutcome, ForkSpec, WhatIfReport, fork_whatif

__all__ = [
    "Injection", "RunTrace", "TraceRecorder",
    "record_simulation", "record_batch", "record_topology",
    "replay", "replay_topology",
    "replay_oracle", "replay_topology_oracle",
    "ForkSpec", "ForkOutcome", "WhatIfReport", "fork_whatif",
]
