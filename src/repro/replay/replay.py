"""Record runs with chunk-boundary checkpoints; replay them with edits.

Recording drives the *existing* engines (``_run_windowed_batch`` /
``run_topology``) with a :class:`~repro.replay.trace.TraceRecorder`
attached — same compiled chunk programs, same results, plus a
:class:`~repro.replay.trace.RunTrace` of resumable checkpoints.

Replaying resumes a checkpoint with an optional list of
:class:`~repro.replay.trace.Injection` schedule edits. The edits become
the engine's ``fail_schedule`` callback: at each edited chunk boundary
the stacked ``FailArrays`` are rebuilt from the trace's structural specs
with the edited masks overlaid (``spec_with_failures``) — a traced-input
swap, so nothing recompiles and the replay reuses the parent run's
compiled chunk. With no edits, replay is bit-identical to the original
run; with edits, it is bit-identical to a from-scratch run executing the
merged schedule (``tests/test_replay.py`` checks both, against the
numpy oracles in ``repro.replay.oracle``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from ..core.simulator import (SimResult, SimSpec, _run_windowed_batch,
                              spec_with_failures, spec_with_quorum)
from ..core.types import FailureScenario
from ..obs.tracer import obs_span
from ..topology.engine import (TopologyResult, _floor_plan, link_specs,
                               run_topology)
from ..topology.graph import Topology
from .trace import Injection, RunTrace, TraceRecorder

__all__ = ["record_simulation", "record_batch", "record_topology",
           "replay", "replay_topology", "build_fail_schedule",
           "scenario_swaps", "spec_swaps", "apply_injection"]

# per-lane edit sets: a bare sequence applies to lane 0 (the common
# single-link case); a mapping keys lanes by index or lane name.
InjectionSet = Union[Sequence[Injection],
                     Mapping[Union[int, str], Sequence[Injection]]]


def _force_windowed(spec: SimSpec, chunk_steps: int) -> SimSpec:
    """Checkpoint/replay needs chunk boundaries: dense specs run the
    windowed kernel at full width instead (bit-identical results —
    exactly the ``link_specs`` rule for topologies)."""
    if spec.window_slots:
        return spec
    return dataclasses.replace(spec, window_slots=spec.m,
                               chunk_steps=max(chunk_steps, 1))


def record_simulation(spec: SimSpec, every: int = 1,
                      chunk_steps: int = 32,
                      ) -> Tuple[SimResult, RunTrace]:
    """Run one spec on the windowed kernel, capturing checkpoints.

    Dense specs (``window_slots == 0``) are promoted to the windowed
    kernel at full width so chunk boundaries exist; ``chunk_steps`` sets
    the boundary spacing in that case. ``every`` thins the recorded
    boundaries (a checkpoint at round 0 is always captured).
    """
    results, trace = record_batch([_force_windowed(spec, chunk_steps)],
                                  every=every)
    return results[0], trace


def record_batch(specs: Sequence[SimSpec], every: int = 1,
                 ) -> Tuple[List[SimResult], RunTrace]:
    """Run a scenario batch on the vmapped windowed kernel, capturing
    chunk-boundary checkpoints for the whole batch (one snapshot covers
    every lane — forks and replays stay one-dispatch-per-chunk)."""
    specs = list(specs)
    if not specs or not specs[0].window_slots:
        raise ValueError("record_batch needs windowed specs "
                         "(window_slots > 0); use record_simulation for "
                         "automatic dense promotion")
    rec = TraceRecorder(specs[0].chunk_steps, every=every)
    results = _run_windowed_batch(specs, recorder=rec)
    trace = RunTrace(kind="link", specs=specs,
                     lane_names=[f"lane{i}" for i in range(len(specs))],
                     floor_plan={}, checkpoints=rec.checkpoints,
                     results=results)
    return results, trace


def record_topology(topo: Topology, every: int = 1,
                    ) -> Tuple[TopologyResult, RunTrace]:
    """Run a topology, capturing checkpoints across all links at once."""
    specs = link_specs(topo)
    rec = TraceRecorder(specs[0].chunk_steps, every=every)
    result = run_topology(topo, recorder=rec)
    trace = RunTrace(kind="topology", specs=specs,
                     lane_names=[l.name for l in topo.links],
                     floor_plan=_floor_plan(topo),
                     checkpoints=rec.checkpoints,
                     results=[result.links[l.name].result
                              for l in topo.links],
                     topology=topo)
    return result, trace


# --- failure timelines ---------------------------------------------------

def _lane_index(trace: RunTrace, key: Union[int, str]) -> int:
    if isinstance(key, str):
        try:
            return trace.lane_names.index(key)
        except ValueError:
            raise KeyError(f"unknown lane {key!r}; lanes: "
                           f"{trace.lane_names}") from None
    if not 0 <= int(key) < trace.n_lanes:
        raise KeyError(f"lane index {key} out of range "
                       f"[0, {trace.n_lanes})")
    return int(key)


def _normalize_injections(trace: RunTrace,
                          injections: Optional[InjectionSet],
                          ) -> Dict[int, List[Injection]]:
    if injections is None:
        return {}
    if isinstance(injections, Mapping):
        by_lane = {_lane_index(trace, k): list(v)
                   for k, v in injections.items()}
    else:
        by_lane = {0: list(injections)} if injections else {}
    for lane, edits in by_lane.items():
        by_lane[lane] = sorted(edits, key=lambda e: e.at_step)
    return by_lane


def _validate_injection(trace: RunTrace, inj: Injection,
                        from_step: int) -> None:
    spec = trace.specs[0]
    if inj.at_step % trace.chunk_steps != 0:
        raise ValueError(
            f"injection at round {inj.at_step} is not a chunk boundary "
            f"(chunk_steps={trace.chunk_steps}); mid-run edits can only "
            f"take effect where the scan state is host-observable")
    if not from_step <= inj.at_step < trace.steps:
        raise ValueError(
            f"injection at round {inj.at_step} outside the replayed "
            f"range [{from_step}, {trace.steps})")
    if inj.failures is None and not inj.reconfigures:
        raise ValueError(
            f"injection at round {inj.at_step} edits nothing: give "
            f"failure masks, a stake re-weight, or both")
    if inj.failures is not None:
        # full palette validation (shapes, crash horizons, lie ranges)
        inj.failures.validate(spec.n_s, spec.n_r, trace.steps)
    for name, n in (("stakes_s", spec.n_s), ("stakes_r", spec.n_r)):
        v = getattr(inj, name)
        if v is not None and len(v) != n:
            raise ValueError(f"injection {name} has {len(v)} entries, "
                             f"RSM has {n} replicas")


def scenario_swaps(base_scenarios: Sequence[FailureScenario],
                   by_lane: Dict[int, List[Injection]]):
    """Merge per-lane *mask* edits into cumulative swap points.

    Returns ``(swaps, final)`` where ``swaps`` maps each edited
    chunk-boundary round to the full per-lane scenario list in force
    from that round on — unedited lanes keep their current masks through
    every swap — and ``final`` is each lane's scenario at the end.
    Reconfiguration (stake/threshold) edits are invisible here; the
    full merge rule including them is :func:`spec_swaps`.
    """
    current = list(base_scenarios)
    swaps: Dict[int, List[FailureScenario]] = {}
    for t in sorted({e.at_step for edits in by_lane.values()
                     for e in edits}):
        for lane, edits in by_lane.items():
            for e in edits:
                if e.at_step == t and e.failures is not None:
                    current[lane] = e.failures
        swaps[t] = list(current)
    return swaps, current


def apply_injection(spec: SimSpec, inj: Injection) -> SimSpec:
    """Overlay one edit onto a lane's current spec (masks, then quorum).

    Both halves are traced-input rewrites (``spec_with_failures`` /
    ``spec_with_quorum``), so the result shares the input spec's
    compiled chunk programs.
    """
    s = spec
    if inj.failures is not None:
        s = spec_with_failures(s, inj.failures)
    if inj.reconfigures:
        s = spec_with_quorum(s, stakes_s=inj.stakes_s,
                             stakes_r=inj.stakes_r,
                             quack_thresh=inj.quack_thresh,
                             dup_thresh=inj.dup_thresh,
                             hq_thresh=inj.hq_thresh)
    return s


def spec_swaps(base_specs: Sequence[SimSpec],
               by_lane: Dict[int, List[Injection]]):
    """Merge per-lane edits into cumulative spec-level swap points.

    The single home of the timeline-merge rule (engine schedules and the
    numpy oracles both layer on it, so they cannot drift): returns
    ``(swaps, final)`` where ``swaps`` maps each edited chunk-boundary
    round to the full per-lane *spec* list in force from that round on —
    masks AND stakes/thresholds, cumulatively overlaid in ``at_step``
    order — and ``final`` is each lane's spec at the end of the run.
    """
    current = list(base_specs)
    swaps: Dict[int, List[SimSpec]] = {}
    for t in sorted({e.at_step for edits in by_lane.values()
                     for e in edits}):
        for lane, edits in by_lane.items():
            for e in edits:
                if e.at_step == t:
                    current[lane] = apply_injection(current[lane], e)
        swaps[t] = list(current)
    return swaps, current


def build_fail_schedule(trace: RunTrace,
                        by_lane: Dict[int, List[Injection]],
                        specs: Optional[List[SimSpec]] = None):
    """Compile per-lane edits into the engine's ``fail_schedule`` fn.

    Returns ``(schedule, final_specs)``: ``schedule(t)`` yields the
    full per-lane spec list whenever any lane's masks, stakes or
    thresholds change at ``t`` (``None`` otherwise), per the
    :func:`spec_swaps` merge rule.
    """
    specs = list(trace.specs) if specs is None else list(specs)
    swaps, current = spec_swaps(specs, by_lane)

    def schedule(t: int):
        return swaps.get(int(t))

    return schedule, list(current)


def _prepare(trace: RunTrace, from_step: int,
             injections: Optional[InjectionSet]):
    ckpt = trace.checkpoint_at(int(from_step))
    by_lane = _normalize_injections(trace, injections)
    for edits in by_lane.values():
        for e in edits:
            _validate_injection(trace, e, int(from_step))
    schedule, _ = build_fail_schedule(trace, by_lane)
    return ckpt, (schedule if by_lane else None)


def replay(trace: RunTrace, from_step: int,
           injections: Optional[InjectionSet] = None) -> List[SimResult]:
    """Resume a link trace from the checkpoint at ``from_step``.

    With no ``injections`` the replayed tail is bit-identical to the
    original run (same frontiers, delivered masks, metrics). Each
    injection swaps a lane's failure masks at a chunk boundary
    ``>= from_step``; the result equals a from-scratch run executing the
    merged schedule. ``SimResult.spec`` keeps the structural (original)
    masks — the edits live in the injection list.
    """
    if trace.kind != "link":
        raise ValueError(f"replay() takes a link trace, got "
                         f"{trace.kind!r}; use replay_topology()")
    ckpt, schedule = _prepare(trace, from_step, injections)
    with obs_span("replay_resume", cat="engine", from_step=int(ckpt.t)):
        return _run_windowed_batch(trace.specs, resume=ckpt,
                                   fail_schedule=schedule)


def replay_topology(trace: RunTrace, from_step: int,
                    injections: Optional[InjectionSet] = None,
                    ) -> TopologyResult:
    """Resume a topology trace from ``from_step`` (per-link injections
    keyed by link name). Commit-floor plumbing picks up exactly where
    the checkpoint left it: the floor history of the skipped chunks is
    reconstructed from the checkpoint's base trajectory."""
    if trace.kind != "topology" or trace.topology is None:
        raise ValueError(f"replay_topology() takes a topology trace, "
                         f"got {trace.kind!r}")
    ckpt, schedule = _prepare(trace, from_step, injections)
    with obs_span("replay_resume", cat="engine", from_step=int(ckpt.t)):
        return run_topology(trace.topology, resume=ckpt,
                            fail_schedule=schedule)
