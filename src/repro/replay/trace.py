"""RunTrace — chunk-boundary checkpoints with a stable npz serialization.

A :class:`RunTrace` is the replayable record of one engine run: the
per-lane structural specs, the topology (for multi-link runs), the
lane->upstream commit-floor plan, and a list of
:class:`~repro.core.simulator.ChunkCheckpoint` snapshots captured at
chunk boundaries. Every checkpoint leaf is host-side numpy (int32/bool),
so ``save``/``load`` round-trips bit-exactly: a trace loaded from disk
resumes into the very same chunk stream as one captured in memory.

:class:`Injection` is one schedule edit — a full
:class:`~repro.core.FailureScenario` replacement for a lane taking
effect at a chunk-boundary round. Edits compose into a failure
*timeline*; ``repro.replay.replay`` turns a timeline into the engine's
``fail_schedule`` callback (and the oracle's numpy twin).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

import numpy as np

from ..core.simulator import (ChunkCheckpoint, FailArrays, SimResult,
                              SimSpec, SimState, StepMetrics,
                              WindowGrowthEvent)
from ..core.snapshot import state_from_arrays, state_to_arrays
from ..core.types import FailureScenario, RSMConfig, SimConfig
from ..topology.graph import LinkSpec, Topology

__all__ = ["Injection", "TraceRecorder", "RunTrace"]

_FORMAT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Injection:
    """One schedule edit taking effect at chunk boundary ``at_step``.

    ``failures`` (when given) replaces the lane's failure masks wholesale
    from round ``at_step`` on — crash or recover a replica, open or heal
    a partition, change drop/lie schedules. The quorum fields (when
    given) re-weight the lane's stakes / thresholds from the same round —
    the mid-stream *reconfiguration* primitive: a membership change is a
    crash-mask flip (remove = crash at ``at_step``; add = flip a replica
    that was "crashed since round 0" back to ``-1``) plus a stake
    re-weight moving the new member's stake and the u/r quorum thresholds
    (``simulator.spec_with_quorum``). Both ride the traced ``FailArrays``,
    so applying an edit never recompiles anything; edits compose
    cumulatively (a later injection overlays the lane state the earlier
    ones produced). ``at_step`` must be a multiple of the run's
    ``chunk_steps``."""

    at_step: int
    failures: Optional[FailureScenario] = None
    stakes_s: Optional[tuple] = None
    stakes_r: Optional[tuple] = None
    quack_thresh: Optional[float] = None
    dup_thresh: Optional[float] = None
    hq_thresh: Optional[float] = None

    @property
    def reconfigures(self) -> bool:
        """True when this edit changes stakes or quorum thresholds."""
        return any(v is not None for v in (
            self.stakes_s, self.stakes_r, self.quack_thresh,
            self.dup_thresh, self.hq_thresh))


class TraceRecorder:
    """Checkpoint sink handed to the engine (``wants``/``capture``).

    Captures every ``every``-th chunk boundary (the boundary at round 0
    always qualifies, so a trace can replay from the very start). The
    capture cost — one O(B·W) device->host state materialization — is
    only paid at boundaries ``wants`` accepts.
    """

    def __init__(self, chunk_steps: int, every: int = 1):
        if every < 1:
            raise ValueError("every must be >= 1")
        self.chunk = max(int(chunk_steps), 1)
        self.every = int(every)
        self.checkpoints: List[ChunkCheckpoint] = []

    def wants(self, t: int) -> bool:
        return (t // self.chunk) % self.every == 0

    def capture(self, ckpt: ChunkCheckpoint) -> None:
        self.checkpoints.append(ckpt)


@dataclasses.dataclass
class RunTrace:
    """Replayable record of one chunked windowed run.

    kind:        "link" (single spec or scenario batch) | "topology".
    specs:       per-lane structural specs, masks = the original run's
                 static failure scenario (the base every timeline edit
                 overlays onto).
    lane_names:  one name per batch lane (link names for topologies).
    floor_plan:  lane -> upstream lane (chained commit gating); empty
                 for standalone links and fanouts.
    checkpoints: chunk-boundary snapshots, ascending ``t``.
    results:     the original run's per-lane outputs (in-memory traces
                 only — not serialized; baselines are re-derivable by an
                 unchanged replay).
    topology:    the graph (topology traces), serialized with the trace.
    """

    kind: str
    specs: List[SimSpec]
    lane_names: List[str]
    floor_plan: Dict[int, int]
    checkpoints: List[ChunkCheckpoint]
    results: Optional[List[SimResult]] = None
    topology: Optional[Topology] = None

    # --- addressing ------------------------------------------------------
    @property
    def n_lanes(self) -> int:
        return len(self.specs)

    @property
    def chunk_steps(self) -> int:
        return max(self.specs[0].chunk_steps, 1)

    @property
    def steps(self) -> int:
        return self.specs[0].steps

    def boundaries(self) -> np.ndarray:
        """Rounds at which this trace holds a checkpoint."""
        return np.asarray([c.t for c in self.checkpoints], dtype=np.int64)

    def checkpoint_at(self, t: int) -> ChunkCheckpoint:
        for c in self.checkpoints:
            if c.t == t:
                return c
        raise KeyError(
            f"no checkpoint at round {t}; recorded boundaries: "
            f"{self.boundaries().tolist()}")

    def last_checkpoint_before(self, t: int) -> ChunkCheckpoint:
        """Latest checkpoint with ``ckpt.t <= t`` (e.g. the pre-crash
        snapshot for an event scheduled at round ``t``)."""
        best = None
        for c in self.checkpoints:
            if c.t <= t and (best is None or c.t > best.t):
                best = c
        if best is None:
            raise KeyError(f"no checkpoint at or before round {t}")
        return best

    # --- serialization ---------------------------------------------------
    def save(self, path: str) -> None:
        """Serialize to one compressed npz (stable, numpy-only form)."""
        meta = {
            "version": _FORMAT_VERSION,
            "kind": self.kind,
            "lane_names": list(self.lane_names),
            "floor_plan": {str(k): int(v)
                           for k, v in self.floor_plan.items()},
            "specs": [dataclasses.asdict(s) for s in self.specs],
            "topology": (_topology_to_json(self.topology)
                         if self.topology is not None else None),
            "checkpoints": [
                {"t": int(c.t), "window_slots": int(c.window_slots),
                 "growth_events": [dataclasses.asdict(e)
                                   for e in c.growth_events]}
                for c in self.checkpoints],
        }
        arrays: Dict[str, np.ndarray] = {}
        for i, c in enumerate(self.checkpoints):
            p = f"c{i}."
            arrays[p + "bases"] = np.asarray(c.bases)
            arrays[p + "floors"] = np.asarray(c.floors)
            arrays[p + "bases_hist"] = np.asarray(c.bases_hist)
            arrays[p + "out_quack"] = np.asarray(c.out_quack)
            arrays[p + "out_deliver"] = np.asarray(c.out_deliver)
            arrays[p + "out_retry"] = np.asarray(c.out_retry)
            arrays[p + "out_recv"] = np.asarray(c.out_recv)
            if c.send_step is not None:
                arrays[p + "send_step"] = np.asarray(c.send_step)
            arrays.update(state_to_arrays(c.state, p + "state."))
            arrays.update(state_to_arrays(c.fails, p + "fails."))
            # per-chunk metric blocks flatten to the (B, t) view on disk
            arrays.update(state_to_arrays(c.metrics(), p + "metrics."))
        np.savez_compressed(path, meta=np.asarray(json.dumps(meta)),
                            **arrays)

    @classmethod
    def load(cls, path: str) -> "RunTrace":
        with np.load(path, allow_pickle=False) as d:
            meta = json.loads(str(d["meta"]))
            if meta["version"] != _FORMAT_VERSION:
                raise ValueError(
                    f"trace format v{meta['version']} != "
                    f"v{_FORMAT_VERSION}")
            specs = [_spec_from_json(s) for s in meta["specs"]]
            fail_defaults = _fail_array_defaults(specs)
            checkpoints = []
            for i, cm in enumerate(meta["checkpoints"]):
                p = f"c{i}."
                checkpoints.append(ChunkCheckpoint(
                    t=int(cm["t"]),
                    window_slots=int(cm["window_slots"]),
                    bases=d[p + "bases"],
                    state=state_from_arrays(SimState, d, p + "state."),
                    fails=state_from_arrays(FailArrays, d, p + "fails.",
                                            defaults=fail_defaults),
                    floors=d[p + "floors"],
                    out_quack=d[p + "out_quack"],
                    out_deliver=d[p + "out_deliver"],
                    out_retry=d[p + "out_retry"],
                    out_recv=d[p + "out_recv"],
                    metric_parts=(state_from_arrays(StepMetrics, d,
                                                    p + "metrics."),),
                    bases_hist=d[p + "bases_hist"],
                    growth_events=tuple(
                        WindowGrowthEvent(**e)
                        for e in cm["growth_events"]),
                    # absent in pre-PR-8 traces: ChunkCheckpoint defaults
                    # it to None and the engine falls back to the
                    # schedule-derived dispatch rounds
                    send_step=(d[p + "send_step"]
                               if p + "send_step" in d else None),
                ))
        topo = (_topology_from_json(meta["topology"])
                if meta["topology"] is not None else None)
        return cls(
            kind=meta["kind"],
            specs=specs,
            lane_names=list(meta["lane_names"]),
            floor_plan={int(k): int(v)
                        for k, v in meta["floor_plan"].items()},
            checkpoints=checkpoints,
            results=None,
            topology=topo,
        )


def _fail_array_defaults(specs: List[SimSpec]) -> dict:
    """Stacked-``FailArrays`` fields absent from pre-palette traces.

    Adversary masks default to all-honest (the fields did not exist, so
    nothing could have injected them), and the traced stakes/thresholds
    default to each lane's *spec* values — NOT neutral ones: a resumed
    old trace must run the same quorum rules it was recorded under.
    """
    b, n_s, n_r = len(specs), specs[0].n_s, specs[0].n_r
    return dict(
        byz_equiv_send=np.zeros((b, n_s), dtype=bool),
        byz_hq_advance=np.zeros((b, n_s), dtype=np.int32),
        byz_ack_stale=np.zeros((b, n_r), dtype=bool),
        drop_pair=np.zeros((b, n_s, n_r), dtype=bool),
        stakes_s=np.asarray([s.stakes_s for s in specs], dtype=np.float32),
        stakes_r=np.asarray([s.stakes_r for s in specs], dtype=np.float32),
        quack_thresh=np.asarray([s.quack_thresh for s in specs],
                                dtype=np.float32),
        dup_thresh=np.asarray([s.dup_thresh for s in specs],
                              dtype=np.float32),
        hq_thresh=np.asarray([s.hq_thresh for s in specs],
                             dtype=np.float32),
    )


# --- dataclass <-> json (tuples come back from JSON as lists) -------------

def _deep_tuple(v):
    return (tuple(_deep_tuple(x) for x in v) if isinstance(v, list)
            else v)


def _retuple(cls, d: dict):
    fields = {}
    for f in dataclasses.fields(cls):
        if f.name not in d:
            # field added after the trace was written: keep its default
            # (new fields must always be default-compatible additions)
            continue
        # deep: nested masks like ``drop_pair`` must come back as tuples
        # of tuples, or spec equality (the replay zero-recompile check
        # compares ``_neutral`` specs) would break on list != tuple
        fields[f.name] = _deep_tuple(d[f.name])
    return cls(**fields)


def _spec_from_json(d: dict) -> SimSpec:
    return _retuple(SimSpec, d)


def _failures_from_json(d: dict) -> FailureScenario:
    return _retuple(FailureScenario, d)


def _topology_to_json(topo: Topology) -> dict:
    return {
        "clusters": {n: dataclasses.asdict(c)
                     for n, c in topo.clusters.items()},
        "links": [dataclasses.asdict(l) for l in topo.links],
        "sim": dataclasses.asdict(topo.sim),
    }


def _topology_from_json(d: dict) -> Topology:
    links = []
    for ld in d["links"]:
        ld = dict(ld)
        ld["failures"] = _failures_from_json(ld["failures"])
        links.append(LinkSpec(**ld))
    return Topology(
        clusters={n: _retuple(RSMConfig, c)
                  for n, c in d["clusters"].items()},
        links=tuple(links),
        sim=SimConfig(**d["sim"]),
    )
