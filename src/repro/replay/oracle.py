"""Numpy oracles for replay: from-scratch runs of the merged schedule.

The replay contract is checked against ground truth the same way the
windowed core is: the pure-python reference machines execute the *merged*
schedule — the original failure masks until each injection's
``at_step``, the edited masks after — from round 0, with the same chunk
boundaries, the same window-growth mirror and the same commit-floor
plumbing as the engine. An engine replay from any checkpoint must match
this from-scratch oracle bit-for-bit (and, with no edits, the original
run itself).

``replay_oracle`` covers single-lane link traces (per-message outputs
AND the GC-frontier trajectory are comparable); for multi-lane link
batches compare per-message outputs only — the engine grows the window
batch-wide, so a lone lane's frontier trajectory can legitimately
differ while every output stays bit-identical.
"""

from __future__ import annotations

from typing import Optional

from ..core.refsim import RefResult, run_reference
from ..topology.refmirror import (RefTopologyResult,
                                  run_topology_reference)
from .replay import (InjectionSet, _normalize_injections,
                     _validate_injection, spec_swaps)
from .trace import RunTrace

__all__ = ["replay_oracle", "replay_topology_oracle"]


def _trace_swaps(trace: RunTrace, by_lane):
    """Swap points for a trace's lanes (shared merge rule — the oracle
    applies the exact spec lists the engine schedule was built from,
    masks and stake/threshold reconfigurations alike)."""
    swaps, _ = spec_swaps(trace.specs, by_lane)
    return swaps


def replay_oracle(trace: RunTrace,
                  injections: Optional[InjectionSet] = None,
                  lane: int = 0) -> RefResult:
    """From-scratch oracle run of lane ``lane`` under the merged
    schedule (original masks, then each injection at its boundary)."""
    by_lane = _normalize_injections(trace, injections)
    for edits in by_lane.values():
        for e in edits:
            _validate_injection(trace, e, 0)
    swaps = _trace_swaps(trace, by_lane)
    spec = trace.specs[lane]

    def schedule(t):
        s = swaps.get(int(t))
        return None if s is None else s[lane]

    return run_reference(spec, fail_schedule=schedule)


def replay_topology_oracle(trace: RunTrace,
                           injections: Optional[InjectionSet] = None,
                           ) -> RefTopologyResult:
    """From-scratch topology oracle under the merged schedule — one
    reference machine per link, same chunk structure, same batch-wide
    window growth and commit-floor plumbing as the engine."""
    if trace.kind != "topology" or trace.topology is None:
        raise ValueError("replay_topology_oracle needs a topology trace")
    by_lane = _normalize_injections(trace, injections)
    for edits in by_lane.values():
        for e in edits:
            _validate_injection(trace, e, 0)
    swaps = _trace_swaps(trace, by_lane)

    def schedule(t):
        return swaps.get(int(t))

    return run_topology_reference(trace.topology, fail_schedule=schedule)
