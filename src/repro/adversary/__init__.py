"""Byzantine adversary palette + mid-stream reconfiguration toolkit.

The engine's fault model (:class:`~repro.core.FailureScenario`) carries
every adversary as *traced* inputs riding the stacked ``FailArrays``, so
an attack can be switched on, escalated, or healed at any chunk boundary
— by a ``fail_schedule`` callback, a replay
:class:`~repro.replay.Injection`, or a streaming
:class:`~repro.stream.StreamSession` attack schedule — without a single
recompile. This package is the scenario-construction layer on top:

* :mod:`~repro.adversary.palette` — named constructors for each
  adversary kind (equivocating senders, stale/replayed QUACK acks,
  §4.3 highest-quacked liars, selective per-pair drops, greedy
  stake-weighted quorum attacks) and for the reconfiguration
  injections (remove/join a replica, re-weight stakes) expressed as
  crash-mask flips plus ``spec_with_quorum`` swaps.
* :mod:`~repro.adversary.safety` — the §4.3 retirement-safety budget:
  which adversary stake totals keep "no undelivered message is ever
  retired" *provable*, and assertion helpers that check engine and
  oracle runs against it.

Every palette scenario is mirrored bit-exactly by the numpy oracle
(``core/refsim.py``) — ``tests/test_adversary.py`` sweeps the palette
across dense, windowed, superchunk and Pallas engine paths.
"""

from .palette import (ADVERSARY_KINDS, adversary_scenario, equivocators,
                      hq_liars, join_receiver, remove_receiver,
                      selective_drops, stake_attack, stale_ackers,
                      streaming_attack)
from .safety import (QuorumBudget, assert_safe_retirement, quorum_budget)

__all__ = [
    "ADVERSARY_KINDS", "adversary_scenario", "equivocators", "hq_liars",
    "selective_drops", "stake_attack", "stale_ackers", "streaming_attack",
    "remove_receiver", "join_receiver",
    "QuorumBudget", "quorum_budget", "assert_safe_retirement",
]
