"""Named constructors for Byzantine scenarios and reconfigurations.

Each constructor returns a plain :class:`~repro.core.FailureScenario`
(or a replay :class:`~repro.replay.Injection` for the reconfiguration
half), so palette output composes with everything the fault pipeline
already does: static specs (``build_spec(failures=...)``), mid-stream
swaps (``fail_schedule``), replay edits, and streaming attack
schedules. ``adversary_scenario`` is the uniform sweep entry point the
property tests and ``bench_adversary`` iterate over.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from ..core.types import FailureScenario
from ..replay.trace import Injection

__all__ = ["ADVERSARY_KINDS", "adversary_scenario", "equivocators",
           "stale_ackers", "hq_liars", "selective_drops", "stake_attack",
           "streaming_attack", "remove_receiver", "join_receiver"]


def _mask(n: int, idxs: Sequence[int], name: str) -> Tuple[bool, ...]:
    idxs = tuple(int(i) for i in idxs)
    for i in idxs:
        if not 0 <= i < n:
            raise ValueError(f"{name} index {i} out of range [0, {n})")
    return tuple(i in idxs for i in range(n))


def equivocators(n_s: int, senders: Sequence[int] = (0,),
                 base: FailureScenario = FailureScenario(),
                 ) -> FailureScenario:
    """Senders whose retransmissions equivocate (conflicting payloads).

    Receivers detect the mismatch against the original's digest and
    discard the copy — the resend burns wire budget and a rotation slot
    but never lands, so recovery waits for the election to rotate past
    the equivocator (§4.2's coordination-free election is what bounds
    the damage).
    """
    return dataclasses.replace(
        base, byz_equiv_send=_mask(n_s, senders, "equivocators"))


def stale_ackers(n_r: int, receivers: Sequence[int] = (0,),
                 base: FailureScenario = FailureScenario(),
                 ) -> FailureScenario:
    """Receivers that replay their previous QUACK ack verbatim.

    Truthful-but-old: a replayed claim can never fabricate receipt (so
    retirement stays safe with *any* stake behind it), but the frozen
    cumulative counter trips duplicate-cum complaints at every sender —
    manufactured loss suspicion, resend load, and quorum drag.
    """
    return dataclasses.replace(
        base, byz_ack_stale=_mask(n_r, receivers, "stale_ackers"))


def hq_liars(n_s: int, senders: Sequence[int] = (0,), advance: int = 4,
             base: FailureScenario = FailureScenario(),
             ) -> FailureScenario:
    """Senders inflating their §4.3 highest-quacked piggyback.

    Receiver ``i`` hears ``min(true + advance + i, M)`` — per-receiver
    conflicting, so the lie cannot be cross-checked. The r_s+1
    attestation quorum is the defence: an ack floor only advances where
    senders totalling >= r_s+1 stake agree, and at most r_s stake of
    that can be lying.
    """
    if advance <= 0:
        raise ValueError("advance must be > 0 (0 = honest)")
    adv = _mask(n_s, senders, "hq_liars")
    return dataclasses.replace(
        base, byz_hq_advance=tuple(advance if x else 0 for x in adv))


def selective_drops(n_s: int, n_r: int,
                    pairs: Sequence[Tuple[int, int]],
                    base: FailureScenario = FailureScenario(),
                    ) -> FailureScenario:
    """Network faults scoped to (sender, receiver) edges.

    Originals and retransmissions on a dropped edge vanish silently
    (acks still flow) — the adversarial network of §4.2, where recovery
    must route around the dead edges through the retransmitter rotation
    and the intra-RSM broadcast.
    """
    dp = np.zeros((n_s, n_r), dtype=bool)
    for (l, j) in pairs:
        if not (0 <= int(l) < n_s and 0 <= int(j) < n_r):
            raise ValueError(f"selective_drops pair ({l}, {j}) out of "
                             f"range ({n_s}, {n_r})")
        dp[int(l), int(j)] = True
    return dataclasses.replace(
        base, drop_pair=tuple(tuple(bool(x) for x in row) for row in dp))


def stake_attack(stakes: Sequence[float], thresh: float,
                 side: str = "receiver", advance: int = 4,
                 base: FailureScenario = FailureScenario(),
                 ) -> FailureScenario:
    """Greedy maximal-stake quorum attack within the corruption budget.

    Corrupts replicas in descending stake order while the corrupted
    total stays strictly below ``thresh`` — the strongest coalition the
    UpRight model admits (one more and the adversary *owns* the quorum,
    which no protocol survives). ``side="receiver"`` makes the coalition
    fabricate ack claims (``byz_ack_advance``) against the QUACK
    threshold u_r+1; ``side="sender"`` makes it inflate §4.3
    highest-quacked attestations (``byz_hq_advance``) against the
    attestation threshold r_s+1. Both stay inside the provable
    retirement-safety budget (``adversary.safety.quorum_budget``).
    """
    st = np.asarray(list(stakes), dtype=np.float64)
    order = np.argsort(-st, kind="stable")
    chosen, total = [], 0.0
    for i in order:
        if total + st[i] >= thresh:
            continue
        chosen.append(int(i))
        total += st[i]
    if side == "receiver":
        adv = tuple(advance if i in chosen else 0
                    for i in range(len(st)))
        return dataclasses.replace(base, byz_ack_advance=adv)
    if side == "sender":
        adv = tuple(advance if i in chosen else 0
                    for i in range(len(st)))
        return dataclasses.replace(base, byz_hq_advance=adv)
    raise ValueError(f"side must be 'receiver' or 'sender', got {side!r}")


# --- sweep entry point ----------------------------------------------------

ADVERSARY_KINDS = ("equivocate", "stale_ack", "hq_lie", "selective_drop",
                   "stake_attack")


def adversary_scenario(kind: str, n_s: int, n_r: int, seed: int = 0,
                       stakes_r: Optional[Sequence[float]] = None,
                       quack_thresh: Optional[float] = None,
                       ) -> FailureScenario:
    """One seeded scenario of the given kind (tests / bench sweeps).

    Picks the attacked replicas pseudo-randomly but keeps the corrupted
    coalition within the u/r budget of a BFT-1 configuration (at most
    one replica per side for the lie kinds), so every generated schedule
    is one the protocol must *survive*, not merely detect.
    """
    rng = np.random.default_rng(seed)
    if kind == "equivocate":
        return equivocators(n_s, (int(rng.integers(n_s)),))
    if kind == "stale_ack":
        return stale_ackers(n_r, (int(rng.integers(n_r)),))
    if kind == "hq_lie":
        return hq_liars(n_s, (int(rng.integers(n_s)),),
                        advance=int(rng.integers(1, 6)))
    if kind == "selective_drop":
        n_edges = int(rng.integers(1, max(n_s * n_r // 4, 2)))
        pairs = {(int(rng.integers(n_s)), int(rng.integers(n_r)))
                 for _ in range(n_edges)}
        return selective_drops(n_s, n_r, sorted(pairs))
    if kind == "stake_attack":
        st = (tuple(stakes_r) if stakes_r is not None
              else (1.0,) * n_r)
        thr = (float(quack_thresh) if quack_thresh is not None
               else 2.0)
        return stake_attack(st, thr, side="receiver",
                            advance=int(rng.integers(1, 6)))
    raise ValueError(f"unknown adversary kind {kind!r}; "
                     f"palette: {ADVERSARY_KINDS}")


def streaming_attack(kind: str, n_s: int, n_r: int) -> FailureScenario:
    """A palette attack dressed for the streaming SLO demo.

    A *single* liar in a BFT-1 configuration is fully masked — the
    honest quorums outvote it and the watchdogs see nothing, which is
    the defence working, not the demo failing. To make each adversary's
    marginal cost observable (resend-rate / latency breach while the
    attack is on, recovery after it is healed), the lie kinds are paired
    with the network pressure that exposes them: an edge partition
    forces retransmissions, which equivocators void, hq liars poison
    with false floors, and stale/advancing ackers drag through the
    complaint machinery. Every returned scenario keeps the fabricating
    stake inside the provable §4.3 budget — the stream degrades but
    never retires an undelivered message.
    """
    drop_to_0 = selective_drops(n_s, n_r, [(l, 0) for l in range(n_s)])
    if kind == "equivocate":
        # all-but-one sender equivocates: every resend voids until the
        # election rotates to the lone honest retransmitter
        return equivocators(n_s, tuple(range(max(n_s - 1, 1))),
                            base=drop_to_0)
    if kind == "stale_ack":
        # a stale coalition plus one crashed honest receiver makes the
        # stalers' stake pivotal to the QUACK quorum: their frozen
        # claims stall the quacked prefix and the GC frontier until the
        # heal (crash round 0 = dead for this scenario's whole reign)
        crash = [-1] * n_r
        crash[n_r - 1] = 0
        return stale_ackers(n_r, tuple(range(min(2, n_r))),
                            base=FailureScenario(crash_r=tuple(crash)))
    if kind == "hq_lie":
        return hq_liars(n_s, (0,), advance=8, base=drop_to_0)
    if kind == "selective_drop":
        return drop_to_0
    if kind == "stake_attack":
        # receiver 0's inbound edges are dead while receiver 1 fabricates
        # claims — the quorum must still find an honest voter
        return stake_attack((1.0,) * n_r, 2.0, side="receiver",
                            advance=6, base=drop_to_0)
    raise ValueError(f"unknown adversary kind {kind!r}; "
                     f"palette: {ADVERSARY_KINDS}")


# --- reconfiguration ------------------------------------------------------

def remove_receiver(n_r: int, j: int, at_step: int,
                    stakes_r: Sequence[float],
                    quack_thresh: float, dup_thresh: float,
                    base: FailureScenario = FailureScenario(),
                    ) -> Injection:
    """Membership change: receiver ``j`` leaves the RSM at ``at_step``.

    Expressed entirely through traced inputs: a crash mask stops the
    replica (it never acks again) and a stake re-weight removes its
    vote, with the quorum thresholds handed in already adjusted for the
    smaller membership (the config-service commit the paper delegates
    membership to — here the caller). Zero recompiles.
    """
    if not 0 <= j < n_r:
        raise ValueError(f"receiver index {j} out of range [0, {n_r})")
    crash = list(base.crash_r or (-1,) * n_r)
    crash[j] = int(at_step)
    st = [float(x) for x in stakes_r]
    st[j] = 0.0
    return Injection(
        at_step=int(at_step),
        failures=dataclasses.replace(base, crash_r=tuple(crash)),
        stakes_r=tuple(st), quack_thresh=float(quack_thresh),
        dup_thresh=float(dup_thresh))


def join_receiver(n_r: int, j: int, at_step: int,
                  stakes_r: Sequence[float],
                  quack_thresh: float, dup_thresh: float,
                  base: FailureScenario = FailureScenario(),
                  ) -> Injection:
    """Membership change: receiver ``j`` joins the RSM at ``at_step``.

    The join twin of :func:`remove_receiver`: the base run models the
    future member as crashed-from-round-0 (``crash_r[j] == 0``); the
    injection flips its crash entry to ``-1`` (alive from the swap
    boundary on — the traced alive mask re-evaluates every round) and
    weights its stake in.
    """
    if not 0 <= j < n_r:
        raise ValueError(f"receiver index {j} out of range [0, {n_r})")
    crash = list(base.crash_r or (-1,) * n_r)
    crash[j] = -1
    return Injection(
        at_step=int(at_step),
        failures=dataclasses.replace(base, crash_r=tuple(crash)),
        stakes_r=tuple(float(x) for x in stakes_r),
        quack_thresh=float(quack_thresh), dup_thresh=float(dup_thresh))
