"""§4.3 retirement-safety budgets and assertion helpers.

The GC frontier retires a window slot only when it is QUACKed at every
sender — and a QUACK is only as trustworthy as the stake behind it. Two
palette adversaries can *fabricate* effective claims (everything else
merely suppresses): an ack-advancing receiver coalition fabricates
receipt claims against the QUACK threshold u_r+1, and an hq-lying
sender coalition fabricates §4.3 attestations against the attestation
threshold r_s+1 (whose false ack floor turns into receiver claims). As
long as each coalition's stake stays strictly below its threshold,
every quorum that forms contains at least one honest voter and "no
undelivered message is ever retired" is provable — the engine asserts
it at drain time under ``debug_checks``, the numpy oracle counts
violations in ``RefResult.retired_undelivered``, and this module makes
the budget arithmetic and the assertions reusable.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.simulator import SimSpec, retire_safety_stakes_ok

__all__ = ["QuorumBudget", "quorum_budget", "assert_safe_retirement"]


@dataclasses.dataclass(frozen=True)
class QuorumBudget:
    """How much fabricating stake a spec's adversaries wield.

    ``provable`` == both margins positive == the §4.3 argument applies:
    every QUACK and every attestation floor contains an honest voter, so
    no undelivered message can ever be retired. A non-provable spec is
    still *runnable* (the engine happily simulates an owned quorum —
    that is how the defence's necessity is demonstrated), but the safety
    assertions below must not be applied to it.
    """

    fabricating_receiver_stake: float   # byz_ack_advance coalition
    quack_thresh: float
    fabricating_sender_stake: float     # byz_hq_advance coalition
    hq_thresh: float
    provable: bool

    @property
    def receiver_margin(self) -> float:
        return self.quack_thresh - self.fabricating_receiver_stake

    @property
    def sender_margin(self) -> float:
        return self.hq_thresh - self.fabricating_sender_stake


def quorum_budget(spec: SimSpec) -> QuorumBudget:
    """The fabricating-stake arithmetic behind
    :func:`~repro.core.simulator.retire_safety_stakes_ok`, itemized."""
    st_r = np.asarray(spec.stakes_r, dtype=np.float64)
    st_s = np.asarray(spec.stakes_s, dtype=np.float64)
    adv_r = np.asarray(spec.byz_ack_advance or (0,) * spec.n_r) > 0
    adv_s = np.asarray(spec.byz_hq_advance or (0,) * spec.n_s) > 0
    return QuorumBudget(
        fabricating_receiver_stake=float(st_r[adv_r].sum()),
        quack_thresh=float(spec.quack_thresh),
        fabricating_sender_stake=float(st_s[adv_s].sum()),
        hq_thresh=float(spec.hq_thresh),
        provable=retire_safety_stakes_ok(spec))


def assert_safe_retirement(spec: SimSpec, result) -> None:
    """Assert a finished run never retired an undelivered message.

    "Delivered" here is ground-truth receipt: every sequence number
    below the final GC frontier must be physically held by >= 1 replica
    of the receiver RSM (``recv_has``; fabricated claims never set it —
    a bcast-partial or later-crashing holder still counts). Applies to
    both engine results (``SimResult``) and oracle results
    (``RefResult`` — the retirement-time counter must be zero). Only
    meaningful when the spec's budget is provable; raises ``ValueError``
    on a non-provable spec instead of asserting a property the
    adversary is entitled to break.
    """
    budget = quorum_budget(spec)
    if not budget.provable:
        raise ValueError(
            "retirement safety is not provable for this spec: "
            f"fabricating receiver stake {budget.fabricating_receiver_stake}"
            f" vs quack_thresh {budget.quack_thresh}, fabricating sender "
            f"stake {budget.fabricating_sender_stake} vs hq_thresh "
            f"{budget.hq_thresh} — an owned quorum may retire anything")
    ru = getattr(result, "retired_undelivered", None)
    if ru is not None:
        assert ru == 0, (f"oracle retired {ru} undelivered slot(s) "
                         f"despite a provable stake budget")
        return
    frontiers = getattr(result, "gc_frontiers", None)
    if frontiers is None:
        return                       # dense run: nothing was retired
    final = int(np.asarray(frontiers)[-1])
    held = np.asarray(result.recv_has).any(axis=0)[:final]
    bad = np.flatnonzero(~held)
    assert bad.size == 0, (
        f"engine retired seqnos {bad.tolist()} (frontier {final}) that "
        f"no replica has received, despite a provable stake budget")
