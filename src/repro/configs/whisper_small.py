"""whisper-small [audio]: 12L d_model=768 12H d_ff=3072 vocab=51865 —
enc-dec; conv frontend is a STUB (input_specs() provides precomputed
1500-frame embeddings) [arXiv:2212.04356; unverified]."""
from .base import ModelConfig, register


@register("whisper-small")
def whisper_small() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab=51865, head_dim=64,
        encoder_layers=12, encoder_seq=1500,
        source="[arXiv:2212.04356; unverified]",
    )
