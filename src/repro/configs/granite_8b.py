"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152 — llama-arch, code [arXiv:2405.04324; hf]."""
from .base import ModelConfig, register


@register("granite-8b")
def granite_8b() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="dense",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=49152, head_dim=128,
        source="[arXiv:2405.04324; hf]",
    )
