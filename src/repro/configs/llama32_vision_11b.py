"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — gated cross-attn image layers every 5 layers; vision tower
is a STUB (input_specs() provides precomputed patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from .base import ModelConfig, register


@register("llama-3.2-vision-11b")
def llama32_vision_11b() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=128256, head_dim=128,
        cross_attn_period=5, vision_seq=1601,
        rope_theta=5e5,
        source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
    )
