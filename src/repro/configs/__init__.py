"""Architecture registry: one module per assigned architecture.

Importing this package registers all configs; use
``repro.configs.get_config("mixtral-8x22b")`` (or ``"<name>-smoke"``).
"""

from .base import (SHAPES, ModelConfig, ShapeSpec, get_config, list_configs,
                   register, shape_applicable)

# Import for registration side effects (one module per assigned arch);
# kept as one visually grouped block rather than isort-merged.
# isort: off
from . import granite_34b        # noqa: F401
from . import qwen2_72b          # noqa: F401
from . import granite_8b         # noqa: F401
from . import starcoder2_3b      # noqa: F401
from . import hymba_1_5b        # noqa: F401
from . import deepseek_moe_16b   # noqa: F401
from . import mixtral_8x22b      # noqa: F401
from . import rwkv6_7b           # noqa: F401
from . import whisper_small      # noqa: F401
from . import llama32_vision_11b  # noqa: F401
# isort: on

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "get_config",
           "list_configs", "register", "shape_applicable"]
