"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16, MHA) d_ff=1408
vocab=102400, MoE 64 routed experts top-6 + 2 shared, fine-grained;
layer 0 keeps a dense FFN [arXiv:2401.06066; hf]."""
from .base import ModelConfig, register


@register("deepseek-moe-16b")
def deepseek_moe_16b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944,  # dense-FFN width for the first dense layer
        vocab=102400, head_dim=128,
        n_experts=64, n_shared_experts=2, top_k=6, expert_d_ff=1408,
        first_dense_layers=1,
        source="[arXiv:2401.06066; hf]",
    )
