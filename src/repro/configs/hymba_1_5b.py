"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads
[arXiv:2411.13676; hf]. Most layers use sliding-window attention; every
8th layer is global (the hymba paper keeps 3 global layers). The mamba
heads run in parallel with the attention heads inside every block.
"""
from .base import ModelConfig, register


@register("hymba-1.5b")
def hymba_1_5b() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab=32001, head_dim=64,
        ssm_state=16, ssm_heads=25,
        sliding_window=1024, global_layer_period=11,
        source="[arXiv:2411.13676; hf]",
    )
