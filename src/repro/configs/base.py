"""Model configuration schema + registry for the assigned architectures.

Every architecture in the assignment pool is expressed as a ``ModelConfig``;
``smoke()`` derives a reduced same-family variant for CPU tests. The FULL
configs are exercised only through the dry-run (ShapeDtypeStruct, no
allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

__all__ = ["ModelConfig", "ShapeSpec", "register", "get_config",
           "list_configs", "SHAPES", "shape_applicable"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    first_dense_layers: int = 0   # deepseek-moe: layer 0 keeps a dense FFN
    capacity_factor: float = 1.25
    # --- attention variants -------------------------------------------
    sliding_window: int = 0       # 0 = full attention
    global_layer_period: int = 0  # hybrid: every k-th layer uses full attn
    # --- SSM / linear-attention ----------------------------------------
    ssm_state: int = 0            # per-head recurrent state width
    ssm_heads: int = 0            # hybrid: parallel SSM heads per layer
    # --- encoder-decoder ------------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0          # stub frontend sequence (whisper frames)
    # --- VLM -------------------------------------------------------------
    cross_attn_period: int = 0    # insert a cross-attn layer every k layers
    vision_seq: int = 0           # stub patch-embedding sequence
    # --- numerics --------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # --- runtime ---------------------------------------------------------
    attn_block_q: int = 512       # chunked-attention block sizes (XLA path)
    attn_block_kv: int = 1024
    rwkv_chunk: int = 128
    use_pallas: bool = False      # TPU path; CPU dry-run uses the jnp path
    remat: bool = True
    # --- perf levers (EXPERIMENTS.md §Perf; defaults = baseline) ----------
    moe_dispatch_2d: bool = False  # shard the MoE capacity dim over 'data'
    moe_impl: str = "scatter"      # scatter | dense (few-expert MoEs)
    remat_policy: str = "none"     # none | dots (save dot outputs in bwd)
    rwkv_scan_block: int = 1       # timesteps per scan iteration (state
    #                                HBM round-trips / block)
    source: str = ""              # provenance note [arXiv; tier]

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid / SWA archs)."""
        return (self.family in ("ssm", "hybrid")
                or (self.sliding_window > 0 and self.global_layer_period == 0))

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (enc-dec incl.)

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        attn = d * q + 2 * d * kv + q * d
        mlp = 3 * d * dff  # SwiGLU
        per_layer = attn + mlp + 2 * d
        if self.family == "moe":
            e_mlp = 3 * self.d_model * self.expert_d_ff
            routed = self.n_experts * e_mlp
            shared = self.n_shared_experts * e_mlp
            router = d * self.n_experts
            per_layer = attn + routed + shared + router + 2 * d
        if self.family == "ssm":
            # rwkv6: time-mix (r,k,v,w,g) + channel-mix
            per_layer = 5 * d * d + 3 * d * dff + 2 * d
        if self.family == "hybrid":
            per_layer = attn + mlp + 2 * d + 3 * d * d  # + ssm head params
        total = self.n_layers * per_layer + 2 * v * d
        if self.encoder_layers:
            total += self.encoder_layers * (d * q * 2 + 2 * d * kv
                                            + 3 * d * dff + 2 * d)
        if self.cross_attn_period:
            n_cross = self.n_layers // self.cross_attn_period
            total += n_cross * (attn + mlp)
        return int(total)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k + shared experts)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        hd = self.resolved_head_dim
        attn = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d)
        e_mlp = 3 * d * self.expert_d_ff
        active = attn + (self.top_k + self.n_shared_experts) * e_mlp + 2 * d
        return int(self.n_layers * active + 2 * self.vocab * d)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            expert_d_ff=64 if self.expert_d_ff else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            sliding_window=min(self.sliding_window, 16) or 0,
            global_layer_period=self.global_layer_period and 2,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_heads=min(self.ssm_heads, 2) if self.ssm_heads else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
            cross_attn_period=self.cross_attn_period and 2,
            vision_seq=min(self.vision_seq, 16) if self.vision_seq else 0,
            attn_block_q=8, attn_block_kv=16, rwkv_chunk=8,
            dtype="float32", param_dtype="float32", remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (skip noted in DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full-attention arch: 500k decode is quadratic-cost"
    return True, ""


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # allow '<name>-smoke'
        if name.endswith("-smoke") and name[:-6] in _REGISTRY:
            return _REGISTRY[name[:-6]]().smoke()
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs():
    return sorted(_REGISTRY)
