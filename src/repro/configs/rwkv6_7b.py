"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536 — Finch, data-dependent decay [arXiv:2404.05892; hf]."""
from .base import ModelConfig, register


@register("rwkv6-7b")
def rwkv6_7b() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
        d_ff=14336, vocab=65536, head_dim=64,
        ssm_state=64,
        source="[arXiv:2404.05892; hf]",
    )
