"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf]."""
from .base import ModelConfig, register


@register("mixtral-8x22b")
def mixtral_8x22b() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab=32768, head_dim=128,
        n_experts=8, n_shared_experts=0, top_k=2, expert_d_ff=16384,
        sliding_window=4096,
        source="[arXiv:2401.04088; hf]",
    )
