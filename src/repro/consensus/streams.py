"""Consensus commit-stream models.

Baselines measured in the paper (§6.4, n = 4 replicas each):
  * ResilientDB (PBFT)  : 39,000 tx/s
  * Raft (etcd v3.0)    : 39,000 tx/s
  * Algorand (PoS)      :    130 tx/s
  * File                : infinite (in-memory proposal generator, §6.1)

A ``ConsensusModel`` produces a committed-request rate and the
quorum-certificate size attached to each transmitted message
(⟨m, k⟩_{Q_s} in §3); the C3B layer's throughput couples with it by
min(): the RSM cannot respond to clients faster than QUACKs arrive
(the implementation waits for the QUACK before replying, §6).
"""

from __future__ import annotations

import dataclasses

from ..core.types import MAC_BYTES, RSMConfig

__all__ = ["ConsensusModel", "FileModel", "PBFTModel", "RaftModel",
           "AlgorandModel", "coupled_throughput"]


@dataclasses.dataclass(frozen=True)
class ConsensusModel:
    name: str
    commit_rate: float               # committed requests / sec (n=4 baseline)
    quorum_sig_count: int            # signatures in the commit certificate
    intra_msgs_per_commit: float     # intra-RSM message complexity
    cft: bool = False

    def cert_bytes(self, cfg: RSMConfig) -> float:
        """Quorum-certificate bytes on each cross-RSM message."""
        if self.cft:
            return MAC_BYTES  # leader MAC is enough in crash-only settings
        return float(self.quorum_sig_count * MAC_BYTES)

    def rate_at(self, n: int) -> float:
        """Crude scaling of commit rate with replica count (quadratic
        intra-RSM traffic for BFT, linear for CFT)."""
        base_n = 4
        if self.commit_rate == float("inf"):
            return self.commit_rate
        if self.cft:
            return self.commit_rate * base_n / max(n, 1)
        return self.commit_rate * (base_n / max(n, 1)) ** 2


def FileModel() -> ConsensusModel:
    return ConsensusModel("file", float("inf"), 0, 0.0, cft=True)


def PBFTModel() -> ConsensusModel:
    # ResilientDB: PBFT, 2f+1 commit certificate, O(n^2) messages
    return ConsensusModel("pbft", 39_000.0, 3, 2.0 * 4)


def RaftModel() -> ConsensusModel:
    return ConsensusModel("raft", 39_000.0, 1, 2.0, cft=True)


def AlgorandModel() -> ConsensusModel:
    return ConsensusModel("algorand", 130.0, 3, 3.0 * 4)


def coupled_throughput(consensus_rate: float, c3b_rate: float,
                       overhead_ops: float = 0.02) -> float:
    """RSM throughput once PICSOU is attached (§6.4).

    The RSM replies to a client only after the QUACK for the request's
    batch arrives, so sustained rate = min(consensus, C3B) less a small
    CPU share for the two forwarding threads (measured <15% worst case in
    the paper; overhead_ops models that fraction).
    """
    return min(consensus_rate, c3b_rate) * (1.0 - overhead_ops)
