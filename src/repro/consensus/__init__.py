"""Commit-stream models of the RSM-internal consensus protocols (§6.4).

PICSOU sits *behind* consensus: each replica forwards committed requests to
the co-located PICSOU library (Figure 1). For the heterogeneous-RSM case
study the relevant properties of the consensus protocol are its commit
throughput, quorum-certificate size and intra-RSM message complexity — we
model those (per the paper's own measured baselines) rather than
re-implementing PBFT/Raft/Algorand bit-for-bit.
"""

from .streams import (AlgorandModel, ConsensusModel, FileModel, PBFTModel,
                      RaftModel, coupled_throughput)

__all__ = ["ConsensusModel", "FileModel", "PBFTModel", "RaftModel",
           "AlgorandModel", "coupled_throughput"]
