"""Roofline analysis: 3-term model from the compiled dry-run artifact."""

from .hlo import collective_bytes_from_hlo, parse_collectives
from .model import (HW, RooflineReport, analyze_compiled, model_flops,
                    roofline_terms)

__all__ = ["HW", "RooflineReport", "analyze_compiled", "roofline_terms",
           "model_flops", "collective_bytes_from_hlo", "parse_collectives"]
