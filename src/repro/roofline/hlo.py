"""Parse collective ops + wire bytes out of post-SPMD optimized HLO text.

``compiled.as_text()`` (after GSPMD partitioning) contains per-device
shapes; we extract every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, its shard shape and its replica-group
size, and convert to *wire bytes per chip* with ring-algorithm costs:

  all-reduce      : 2 * N * (g-1)/g      (reduce-scatter + all-gather)
  all-gather      : O * (g-1)            (operand forwarded g-1 times)
  reduce-scatter  : N * (g-1)/g
  all-to-all      : N * (g-1)/g
  collective-permute : N                 (one hop)

where N is the per-device tensor bytes appearing in the op and g the
replica-group size.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

__all__ = ["parse_collectives", "collective_bytes_from_hlo", "CollectiveOp"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_OP_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    shard_bytes: int          # per-device tensor bytes in the op
    group_size: int
    wire_bytes_per_chip: float
    line: str = ""


def _shape_bytes(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str.strip())
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    if dt == "tuple":
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _result_bytes(line: str) -> int:
    """Bytes of the op's result (sum over tuple elements)."""
    m = re.search(r"=\s+(\([^)]*\)|\S+\[[\d,]*\](?:\{[^}]*\})?)\s", line)
    if not m:
        return 0
    t = m.group(1)
    if t.startswith("("):
        return sum(_shape_bytes(x) for x in re.findall(r"\w+\[[\d,]*\]", t))
    return _shape_bytes(t)


def _group_size(line: str, kind: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        g0, g1, total = int(m.group(1)), int(m.group(2)), int(m.group(3))
        # iota groups [a,b]<=[n]: groups of size b (the minor dimension)
        return max(g1, 1)
    m = _LIST_GROUPS_RE.search(line)
    if m:
        body = m.group(1).strip()
        if not body:
            return 1
        return body.count(",") + 1
    if kind == "collective-permute":
        return 2
    return 1


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls or ls.startswith("//"):
            continue
        matched = None
        for kind in _OP_KINDS:
            # op name appears as " kind(" in HLO (e.g. "all-reduce(")
            if f" {kind}(" in ls or f"{kind}-start(" in ls:
                matched = kind
                break
        if not matched:
            continue
        if f"{matched}-done" in ls:
            continue  # avoid double counting async pairs
        n = _result_bytes(ls)
        g = _group_size(ls, matched)
        if matched == "all-reduce":
            wire = 2.0 * n * (g - 1) / max(g, 1)
        elif matched == "all-gather":
            # result is the gathered tensor; each chip forwards its shard
            # (result/g) g-1 times
            wire = (n / max(g, 1)) * (g - 1)
        elif matched == "reduce-scatter":
            # operand = result * g; each chip sends
            # operand*(g-1)/g = result*(g-1)
            wire = float(n) * (g - 1)
        elif matched == "all-to-all":
            wire = float(n) * (g - 1) / max(g, 1)
        else:  # collective-permute
            wire = float(n)
        ops.append(CollectiveOp(kind=matched, shard_bytes=n, group_size=g,
                                wire_bytes_per_chip=wire, line=ls[:160]))
    return ops


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Aggregate wire bytes per chip, by op kind + total."""
    out: Dict[str, float] = {k: 0.0 for k in _OP_KINDS}
    count: Dict[str, int] = {k: 0 for k in _OP_KINDS}
    for op in parse_collectives(hlo_text):
        out[op.kind] += op.wire_bytes_per_chip
        count[op.kind] += 1
    total = sum(out.values())
    res = {f"bytes.{k}": v for k, v in out.items()}
    res.update({f"count.{k}": float(v) for k, v in count.items()})
    res["bytes.total"] = total
    return res
