import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Per-op collective inspector — the §Perf diagnostic tool.

Compiles one (arch x shape x mesh) cell and prints the top-N collectives
by execution-count-weighted wire bytes, so a hillclimb iteration can see
exactly WHICH tensor crosses the wire and from which computation (e.g.
the MoE dispatch-buffer gradient all-reduces of EXPERIMENTS.md [M2/M3]).

  PYTHONPATH=src python -m repro.roofline.inspect \
      --arch mixtral-8x22b --shape train_4k --opt moe2d --top 12
"""

import argparse
from collections import defaultdict


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--impl", default=None)
    ap.add_argument("--opt", default="")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    from ..configs import SHAPES, get_config
    from ..launch import steps as S
    from ..launch.dryrun import apply_opts
    from ..launch.mesh import make_production_mesh
    from . import hlo_cost as m

    cfg = apply_opts(get_config(args.arch), args.opt)
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    with mesh:
        bundle = S.build_step(cfg, mesh, SHAPES[args.shape], impl=args.impl)
        text = bundle.lower().compile().as_text()

    comps, entry = m._parse_computations(text)
    mult = defaultdict(float)
    fusion_internal = defaultdict(bool)
    mult[entry] = 1.0
    order, seen, i = [entry], {entry}, 0
    while i < len(order):
        cname = order[i]
        i += 1
        cmult = mult[cname]
        for op in comps.get(cname, []):
            rest = op.rest
            if op.opcode == "while" or " while(" in rest:
                trip = 1.0
                tm = m._TRIP_RE.search(rest)
                if tm:
                    trip = float(tm.group(1))
                for rx, extra in ((m._BODY_RE, trip), (m._COND_RE, trip + 1)):
                    mm = rx.search(rest)
                    if mm and mm.group(1) in comps:
                        mult[mm.group(1)] += cmult * extra
                        if mm.group(1) not in seen:
                            seen.add(mm.group(1))
                            order.append(mm.group(1))
                continue
            mm = m._CALLS_RE.search(rest)
            if mm and mm.group(1) in comps:
                c2 = mm.group(1)
                mult[c2] += cmult
                fusion_internal[c2] = True
                if c2 not in seen:
                    seen.add(c2)
                    order.append(c2)

    rows = []
    wire_fns = {
        "all-reduce": lambda n, g: 2.0 * n * (g - 1) / max(g, 1),
        "all-gather": lambda n, g: (n / max(g, 1)) * (g - 1),
        "reduce-scatter": lambda n, g: float(n) * (g - 1),
        "all-to-all": lambda n, g: float(n) * (g - 1) / max(g, 1),
        "collective-permute": lambda n, g: float(n),
    }
    for cname, ops in comps.items():
        cm = mult.get(cname, 0.0)
        if cm <= 0 or fusion_internal.get(cname):
            continue
        for op in ops:
            for kind, fn in wire_fns.items():
                if op.opcode in (kind, f"{kind}-start"):
                    n = m._shape_bytes_from_type(op.type_str)
                    g = m._group_size(op.rest)
                    rows.append((fn(n, g) * cm, cm, kind, g,
                                 op.type_str[:64], cname[:44]))
                    break
    rows.sort(key=lambda x: -x[0])
    print(f"# top collectives: {args.arch} x {args.shape} x {args.mesh} "
          f"impl={args.impl or 'scan'} opt={args.opt or '-'}")
    print("wire_total,exec_count,kind,group,shard_type,computation")
    for w, cm, kind, g, t, cn in rows[:args.top]:
        print(f"{w / 1e9:10.2f}GB x{cm:6.0f} {kind:18s} g={g:4d} {t:64s} "
              f"{cn}")


if __name__ == "__main__":
    main()
