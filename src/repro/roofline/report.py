"""Render the dry-run results JSONL into the roofline tables.

  PYTHONPATH=src python -m repro.roofline.report dryrun_results.jsonl
"""

from __future__ import annotations

import json
import sys
from collections import OrderedDict

ARCH_ORDER = ["granite-34b", "qwen2-72b", "granite-8b", "starcoder2-3b",
              "hymba-1.5b", "deepseek-moe-16b", "mixtral-8x22b", "rwkv6-7b",
              "whisper-small", "llama-3.2-vision-11b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(path: str, mesh: str = "single", tag: str = ""):
    best = OrderedDict()
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("mesh") != mesh or r.get("tag", "") != tag:
                continue
            best[(r["arch"], r["shape"], r.get("impl", "scan"))] = r
    return best


def fmt_row(r):
    if r["status"] == "SKIP":
        return (f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | — | — | "
                f"{r['reason']} |")
    if r["status"] != "OK":
        return (f"| {r['arch']} | {r['shape']} | FAIL | — | — | — | — | — | "
                f"{r.get('error', '')[:60]} |")
    dom = r["bottleneck"]
    total = max(r["compute_s"], r["memory_s"], r["collective_s"])
    frac = r["compute_s"] / total if total > 0 else 0.0
    return (f"| {r['arch']} | {r['shape']} | {r['status']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{dom}** "
            f"| {r['useful_ratio']:.3f} | roofline-frac={frac:.2f} |")


def table(path: str, mesh: str, impl: str = "scan", tag: str = ""):
    rows = load(path, mesh, tag)
    out = ["| arch | shape | status | compute_s | memory_s | collective_s "
           "| bottleneck | useful | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = rows.get((arch, shape, impl))
            if r is None:
                continue
            out.append(fmt_row(r))
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.jsonl"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "single"
    impl = sys.argv[3] if len(sys.argv) > 3 else "scan"
    tag = sys.argv[4] if len(sys.argv) > 4 else ""
    print(table(path, mesh, impl, tag))


if __name__ == "__main__":
    main()
