"""Execution-count-aware HLO cost model.

``compiled.cost_analysis()`` counts every computation ONCE — a while loop
(lax.scan over 88 layers, or an RWKV time scan) contributes a single body
execution, so FLOPs / bytes / collective counts are understated by the
trip count. The optimized HLO text, however, carries
``backend_config={"known_trip_count":{"n":...}}`` on each while op.

This module parses the post-SPMD optimized HLO into computations, builds
the call graph (while bodies/conditions, fusions, to_apply), propagates
execution-count multipliers from ENTRY, and accumulates:

  * dot FLOPs        (2 * prod(result dims) * prod(contracting dims)),
    attributed through fusions,
  * HBM bytes        (operands + results of non-fusion-internal ops —
    fusion internals never round-trip HBM),
  * collective wire bytes (ring-cost formulas, see hlo.py),

all scaled by the computation's execution count. Shapes in post-SPMD HLO
are per-device, so every figure is per-chip.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional

__all__ = ["analyze_hlo_text", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_COMP_HEADER = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_TYPE_RE = re.compile(
    r"^(\([^)]*\)|[\w\[\],\s]+?\[[\d,]*\](?:\{[^}]*\})?)\s+(\S+?)\(")
_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(
    r"(?:true_computation|false_computation"
    r"|branch_computations=\{[^}]*)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes_from_type(t: str) -> int:
    total = 0
    for m in _SHAPE_TOKEN.finditer(t):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(t: str) -> Optional[List[int]]:
    m = _SHAPE_TOKEN.search(t)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    type_str: str
    rest: str


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    wire_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_count: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))


def _parse_computations(text: str):
    comps: Dict[str, List[_Op]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        tm = _TYPE_RE.match(rhs)
        if not tm:
            # tuple-typed or oddly formatted; try a looser parse
            sp = rhs.split(" ", 1)
            comps[cur].append(_Op(name, "unknown", sp[0],
                                  sp[1] if len(sp) > 1 else ""))
            continue
        comps[cur].append(_Op(name, tm.group(2), tm.group(1), rhs))
    return comps, entry


def _group_size(rest: str) -> int:
    m = _IOTA_GROUPS_RE.search(rest)
    if m:
        return max(int(m.group(2)), 1)
    m = _LIST_GROUPS_RE.search(rest)
    if m:
        body = m.group(1).strip()
        return body.count(",") + 1 if body else 1
    return 2


def analyze_hlo_text(text: str) -> HloCost:
    comps, entry = _parse_computations(text)
    if entry is None:
        entry = next(iter(comps), None)
        if entry is None:
            return HloCost()

    # ---- call graph with execution-count multipliers ----------------------
    mult: Dict[str, float] = defaultdict(float)
    fusion_internal: Dict[str, bool] = defaultdict(bool)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        cmult = mult[cname]
        for op in comps.get(cname, []):
            rest = op.rest
            if op.opcode == "while" or " while(" in rest:
                trip = 1.0
                tm = _TRIP_RE.search(rest)
                if tm:
                    trip = float(tm.group(1))
                for rx, extra in ((_BODY_RE, trip), (_COND_RE, trip + 1)):
                    m = rx.search(rest)
                    if m and m.group(1) in comps:
                        mult[m.group(1)] += cmult * extra
                        if m.group(1) not in seen:
                            seen.add(m.group(1))
                            order.append(m.group(1))
                continue
            m = _CALLS_RE.search(rest)
            if m and m.group(1) in comps:
                callee = m.group(1)
                mult[callee] += cmult
                fusion_internal[callee] = True  # fusion: no HBM round-trip
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
            m = _APPLY_RE.search(rest)
            if m and m.group(1) in comps:
                callee = m.group(1)
                mult[callee] += 0.0   # reduction lambdas: negligible
                fusion_internal[callee] = True
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    # ---- accumulate costs --------------------------------------------------
    cost = HloCost()
    for cname, ops in comps.items():
        cmult = mult.get(cname, 0.0)
        if cmult <= 0.0:
            continue
        table = {op.name: op.type_str for op in ops}
        for op in ops:
            rest = op.rest
            # FLOPs: dots anywhere (incl. fusion internals)
            if op.opcode in ("dot", "dot-general") or rest.startswith("dot("):
                res_dims = _shape_dims(op.type_str) or []
                flops = 2.0
                for d in res_dims:
                    flops *= d
                mc = _LHS_CONTRACT.search(rest)
                lhs_ref = _OPERAND_RE.search(rest[rest.find("("):])
                if mc and lhs_ref and lhs_ref.group(1) in table:
                    lhs_dims = _shape_dims(table[lhs_ref.group(1)]) or []
                    for idx in (mc.group(1).split(",") if mc.group(1)
                                else []):
                        ii = int(idx)
                        if ii < len(lhs_dims):
                            flops *= lhs_dims[ii]
                cost.flops += flops * cmult
            if fusion_internal.get(cname):
                continue
            # HBM bytes: result + operands for top-level ops
            if op.opcode in ("parameter", "constant", "get-tuple-element",
                             "tuple", "bitcast"):
                continue
            nbytes = _shape_bytes_from_type(op.type_str)
            args_part = rest[rest.find("("):rest.find(")") + 1]
            for ref in _OPERAND_RE.finditer(args_part):
                t = table.get(ref.group(1))
                if t:
                    nbytes += _shape_bytes_from_type(t)
            cost.hbm_bytes += nbytes * cmult
            # collectives
            for kind in _COLL_KINDS:
                if op.opcode in (kind, f"{kind}-start"):
                    n = _shape_bytes_from_type(op.type_str)
                    g = _group_size(rest)
                    if kind == "all-reduce":
                        wire = 2.0 * n * (g - 1) / max(g, 1)
                    elif kind == "all-gather":
                        wire = (n / max(g, 1)) * (g - 1)
                    elif kind == "reduce-scatter":
                        wire = float(n) * (g - 1)
                    elif kind == "all-to-all":
                        wire = float(n) * (g - 1) / max(g, 1)
                    else:
                        wire = float(n)
                    cost.wire_bytes += wire * cmult
                    cost.wire_by_kind[kind] += wire * cmult
                    cost.coll_count[kind] += cmult
                    break
    return cost
