"""Three-term roofline from the compiled artifact (TPU v5e targets).

  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = wire_bytes_per_chip / link_bw

cost_analysis() on the post-SPMD module reports per-device FLOPs/bytes;
collective wire bytes come from the HLO parser (hlo.py). MODEL_FLOPS is
the analytic 6*N*D (dense) / 6*N_active*D (MoE) + attention term — the
MODEL/HLO ratio surfaces remat recompute and masked-block waste.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from ..configs.base import ModelConfig, ShapeSpec
from .hlo_cost import analyze_hlo_text

__all__ = ["HW", "RooflineReport", "analyze_compiled", "roofline_terms",
           "model_flops"]


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e per-chip constants (assignment-specified)."""

    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # B/s
    link_bw: float = 50e9             # B/s per ICI link
    hbm_bytes: float = 16e9


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    wire_bytes_per_chip: float
    model_flops_total: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_ratio: float              # MODEL_FLOPS / (HLO_FLOPs * chips)
    collective_breakdown: Dict[str, float]
    memory_analysis: str = ""

    def as_row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.n_chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "hlo_gflops_per_chip": self.hlo_flops_per_chip / 1e9,
            "hbm_GB_per_chip": self.hlo_bytes_per_chip / 1e9,
            "wire_MB_per_chip": self.wire_bytes_per_chip / 1e6,
        }


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Analytic useful FLOPs for one step of this cell.

    Train: 6*N*D (fwd+bwd) + attention 12*L*S^2*d_attn*B (causal halved).
    Prefill: 2*N*D + attention. Decode: 2*N_active*B + cache reads ~0 FLOPs
    (memory-bound; FLOPs = 2*N_active per token + attention S*d per layer).
    """
    n_active = cfg.n_active_params()
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    d_attn = cfg.n_heads * hd
    if shape.kind == "train":
        tokens = b * s
        core = 6.0 * n_active * tokens
        attn = 0.0
        if cfg.family != "ssm":
            w = cfg.sliding_window or s
            ctx = min(w, s)
            attn = 12.0 * cfg.n_layers * b * s * ctx * d_attn * 0.5
        return core + attn
    if shape.kind == "prefill":
        tokens = b * s
        core = 2.0 * n_active * tokens
        attn = 0.0
        if cfg.family != "ssm":
            w = cfg.sliding_window or s
            ctx = min(w, s)
            attn = 4.0 * cfg.n_layers * b * s * ctx * d_attn * 0.5
        return core + attn
    # decode: one token per sequence
    core = 2.0 * n_active * b
    attn = 0.0
    if cfg.family != "ssm":
        w = cfg.sliding_window or s
        ctx = min(w, s)
        attn = 4.0 * cfg.n_layers * b * ctx * d_attn
    return core + attn


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   wire_per_chip: float, hw: HW = HW()) -> Dict[str, float]:
    return {
        "compute_s": flops_per_chip / hw.peak_flops,
        "memory_s": bytes_per_chip / hw.hbm_bw,
        "collective_s": wire_per_chip / hw.link_bw,
    }


def analyze_compiled(compiled, cfg: ModelConfig, shape: ShapeSpec,
                     mesh_name: str, n_chips: int,
                     hw: HW = HW(),
                     hlo_text: Optional[str] = None) -> RooflineReport:
    """Primary terms come from the execution-count-aware HLO cost model
    (hlo_cost.py) — ``compiled.cost_analysis()`` counts while-loop bodies
    (scan over layers / time) only once, so it understates FLOPs/bytes by
    the trip count. The raw cost_analysis is kept in the breakdown for
    reference."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # some backends return [dict]
        cost = cost[0] if cost else {}
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = analyze_hlo_text(text)
    flops = hc.flops
    byts = hc.hbm_bytes
    wire = hc.wire_bytes
    coll = {f"bytes.{k}": v for k, v in hc.wire_by_kind.items()}
    coll.update({f"count.{k}": v for k, v in hc.coll_count.items()})
    coll["bytes.total"] = wire
    coll["raw.cost_analysis.flops"] = float(cost.get("flops", 0.0))
    coll["raw.cost_analysis.bytes"] = float(cost.get("bytes accessed", 0.0))
    terms = roofline_terms(flops, byts, wire, hw)
    bottleneck = max(terms, key=terms.get).replace("_s", "")
    mf = model_flops(cfg, shape)
    useful = mf / max(flops * n_chips, 1.0)
    try:
        mem = str(compiled.memory_analysis())
    except Exception as e:  # noqa: BLE001
        mem = f"<memory_analysis unavailable: {e}>"
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, n_chips=n_chips,
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=byts,
        wire_bytes_per_chip=wire, model_flops_total=mf,
        compute_s=terms["compute_s"], memory_s=terms["memory_s"],
        collective_s=terms["collective_s"], bottleneck=bottleneck,
        useful_ratio=useful, collective_breakdown=coll,
        memory_analysis=mem)
