"""Replay & what-if walkthrough: fork futures from a shared prefix.

  PYTHONPATH=src python examples/replay_whatif.py

Part 1 — disaster recovery as an *injected event*: the primary streams
its log to two backups while ``repro.replay`` records chunk-boundary
checkpoints; the crash is swapped into the already-compiled run at the
last boundary before it hits (identical report to the static-schedule
run), and the pre-crash trace comes back with the report.

Part 2 — what-if study on that trace: from the pre-crash checkpoint,
fork four futures (no crash, the recorded crash, a later crash, and a
crash with a partitioned backup) and execute them as ONE vmapped batch —
one device dispatch per chunk for all four futures — then compare how
much log each backup would have salvaged in each world.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import FailureScenario, RSMConfig, SimConfig
from repro.apps import run_disaster_recovery
from repro.replay import ForkSpec, Injection, fork_whatif


def main():
    cfg = RSMConfig.bft(1)                     # n=4, u=r=1 per cluster
    sim = SimConfig(n_msgs=192, steps=100, window=1, phi=8,
                    window_slots=48, chunk_steps=8)
    crash_at = 20

    print("== disaster recovery, crash injected via replay ==")
    rep = run_disaster_recovery(cfg, cfg, sim, crash_at=crash_at,
                                inject_via_replay=True)
    print(f"  crash scheduled at round {crash_at}, injected at chunk "
          f"boundary {rep.injected_at}")
    print(f"  phase-1 prefixes: {rep.phase1_prefixes}")
    print(f"  elected {rep.elected!r}; converged={rep.converged} at "
          f"{rep.recovered_entries}/{sim.n_msgs} entries")

    trace = rep.phase1_trace
    n = cfg.n
    crash_now = FailureScenario(crash_s=(crash_at,) * n)
    t0 = rep.injected_at
    later = t0 + 4 * sim.chunk_steps
    crash_later = FailureScenario(crash_s=(later,) * n)
    partition = FailureScenario(byz_recv_drop=(True,) + (False,) * (n - 1))

    def everywhere(scenario, at):
        return {lane: [Injection(at, scenario)]
                for lane in trace.lane_names}

    futures = [
        ForkSpec("no-crash"),
        ForkSpec("crash-now", everywhere(crash_now, t0)),
        ForkSpec(f"crash@{later}", everywhere(crash_later, later)),
        ForkSpec("crash+partition", {
            trace.lane_names[0]: [Injection(t0, crash_now)],
            trace.lane_names[1]: [Injection(t0, FailureScenario(
                crash_s=(crash_at,) * n,
                byz_recv_drop=partition.byz_recv_drop))],
        }),
    ]

    print(f"\n== what-if: {len(futures)} futures forked from the "
          f"pre-crash checkpoint (round {t0}) ==")
    report = fork_whatif(trace, t0, futures)
    print(f"  one vmapped batch, {report.chunk_traces} fresh chunk "
          f"compilations")
    print(f"  {'future':<18}" + "".join(f"{l:>16}"
                                        for l in trace.lane_names))
    for fork in report.forks:
        row = "".join(f"{fork.stats[l]['delivered_prefix']:>16}"
                      for l in trace.lane_names)
        print(f"  {fork.name:<18}{row}  (delivered prefix)")
    worst = min(report.forks,
                key=lambda f: min(s["delivered_prefix"]
                                  for s in f.stats.values()))
    print(f"  most lossy future: {worst.name!r}")


if __name__ == "__main__":
    main()
