"""C3B protocol walkthrough: PICSOU vs ATA, failure-free and under attack.

  PYTHONPATH=src python examples/c3b_simulation.py

Runs the full vectorized protocol simulator in the paper's configurations
and prints the headline efficiency/robustness numbers next to the paper's
claims, then a two-link disaster-recovery demo on the multi-link
topology layer (primary fanning out to two backups, failover to the
most-caught-up one). ``window_slots="auto"`` everywhere: the shared
clamp rule (``gc.resolve_window_slots``) picks the windowed kernel when
it pays off and the dense kernel at these small paper shapes —
bit-identical either way.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (FailureScenario, NetworkModel, RSMConfig, SimConfig,
                        analytic_throughput, run_picsou)
from repro.apps import run_disaster_recovery


def main():
    bft = RSMConfig.bft(2)               # n=7, u=r=2
    cft = RSMConfig.cft(2)               # n=5, u=2, r=0

    print("== failure-free BFT<->BFT (n=7) ==")
    run = run_picsou(bft, bft, SimConfig(n_msgs=128, steps=80, window=4,
                                         phi=16, window_slots="auto"))
    print(f"  delivered: {run.all_delivered}; quacked: {run.all_quacked}")
    print(f"  cross copies/msg: {run.cross_copies_per_msg:.2f} "
          f"(theoretical minimum 1.0)")
    print(f"  intra copies/msg: {run.intra_copies_per_msg:.2f} (= n-1)")

    print("== generality: CFT sender -> BFT receiver ==")
    run = run_picsou(cft, bft, SimConfig(n_msgs=64, steps=80, window=2,
                                         phi=16, window_slots="auto"))
    print(f"  delivered: {run.all_delivered}")

    print("== robustness: byzantine receiver drops everything ==")
    fails = FailureScenario(byz_recv_drop=(True,) + (False,) * 6)
    run = run_picsou(bft, bft, SimConfig(n_msgs=64, steps=400, window=1,
                                         phi=16, window_slots="auto"),
                     fails)
    print(f"  delivered: {run.all_delivered}; "
          f"resends/msg: {run.resends_per_msg:.3f}; "
          f"max retries: {run.result.max_resends_per_msg()} "
          f"(Lemma-1 bound {bft.u * 2 + 1})")

    print("== disaster recovery: primary -> 2 backups, crash + failover ==")
    bft1 = RSMConfig.bft(1)              # n=4
    rep = run_disaster_recovery(
        bft1, bft1,
        SimConfig(n_msgs=64, steps=120, window=1, phi=16,
                  window_slots="auto"),
        backups=("backup-0", "backup-1"), crash_at=8,
        backup_failures={"backup-1": FailureScenario(
            crash_r=(2, 2, -1, -1))})
    print(f"  primary crashed at round 8; prefixes: "
          f"{rep.phase1_prefixes}")
    print(f"  elected {rep.elected} "
          f"({rep.recovered_entries}/{64} log entries survive); "
          f"converged after catch-up: {rep.converged}")

    print("== throughput model: PICSOU vs ATA (1MB, geo) ==")
    for n in (4, 19):
        f = max((n - 1) // 3, 1)
        cfg = RSMConfig(n=n, u=f, r=f)
        net = NetworkModel.geo(1e6)
        p = analytic_throughput("picsou", cfg, cfg, net)
        a = analytic_throughput("ata", cfg, cfg, net)
        ratio = p['throughput_msgs_per_s'] / a['throughput_msgs_per_s']
        print(f"  n={n:2d}: picsou {p['throughput_msgs_per_s']:8.1f}/s vs "
              f"ata {a['throughput_msgs_per_s']:6.1f}/s -> {ratio:5.1f}x")


if __name__ == "__main__":
    main()
