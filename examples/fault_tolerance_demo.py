"""Fault-tolerance walkthrough: pod failure mid-training + QUACK-durable
checkpoint restart + straggler re-apportionment.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/fault_tolerance_demo.py
"""

import argparse
import os
import shutil
import sys

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.crosspod import ReplicationLedger  # noqa: E402
from repro.launch.elastic import replan_membership, replan_quotas  # noqa: E402
from repro.launch.train import run  # noqa: E402

CKPT = "/tmp/repro_ft_demo_ckpt"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    kw = dict(arch="starcoder2-3b-smoke", seq=64, batch=8, mode="ddp",
              sync="picsou", compress=False, ckpt_every=5, seed=0, lr=3e-3)

    print("== phase 1: 2-pod training, checkpoint every 5 steps ==")
    run(argparse.Namespace(steps=10, mesh="2x2x2", ckpt_dir=CKPT,
                           restore=False, **kw))

    print("== pod 0 fails! replanning membership ==")
    plan = replan_membership(alive_pods=[1], hosts_per_pod=4,
                             data_parallel=2, model_parallel=2,
                             last_committed_step=9)
    print(f"  new mesh: {plan.mesh_shape} axes {plan.mesh_axes}; "
          f"restore from step {plan.restore_step}")

    print("== phase 2: resume on the surviving pod from the QUACK-durable "
          "checkpoint ==")
    run(argparse.Namespace(steps=5, mesh="2x2", ckpt_dir=CKPT,
                           restore=True, **kw))

    print("== straggler mitigation: host 2 slows to 25% -> DSS re-quota ==")
    before = replan_quotas(np.array([1.0, 1.0, 1.0, 1.0]), quantum=16)
    after = replan_quotas(np.array([1.0, 1.0, 0.25, 1.0]), quantum=16)
    print(f"  quotas before: {before}")
    print(f"  quotas after : {after}")

    print("== replication ledger: lost shard -> deterministic re-election ==")
    led = ReplicationLedger(n_hosts=4, u=1, r=0)
    led.plan_sends(list(range(4)))
    led.record_ack(0, 1)
    led.record_ack(0, 1)            # duplicate: shard 2 missing (CFT: 1 dup)
    lost = led.lost_shards()
    print(f"  lost shards: {lost}; retransmitter: "
          f"{led.elect_retransmitter(lost[0])} (origin+1 mod n)")
    print("demo complete")


if __name__ == "__main__":
    main()
