"""Quickstart: train a reduced granite-8b for 100 steps on CPU.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/quickstart.py

Uses the production step builder (FSDP x TP pjit path) on a 2x2 mesh,
the deterministic synthetic data pipeline, cosine LR, and async
QUACK-replicated checkpoints.
"""

import argparse
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import run  # noqa: E402


def main():
    args = argparse.Namespace(
        arch="granite-8b-smoke", steps=100, seq=64, batch=8, mesh="2x2",
        mode="pjit", sync="picsou", compress=False,
        ckpt_dir="/tmp/repro_quickstart_ckpt", ckpt_every=25,
        restore=False, seed=0, lr=1e-2)
    losses = run(args)
    assert losses[-1] < losses[0], "training should make progress"
    print(f"quickstart done: ce {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
