"""Batched serving: prefill + ring-buffer KV decode on a small mesh.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x22b-smoke
"""

import argparse
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import run  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=12)
    a = ap.parse_args()
    args = argparse.Namespace(arch=a.arch, batch=a.batch, prompt_len=32,
                              gen=a.gen, mesh="2x2", seed=0)
    run(args)


if __name__ == "__main__":
    main()
