"""Theorem 1 / §4.2 — retransmission bounds and delivery probability."""

from __future__ import annotations

import numpy as np

from repro.core import (FailureScenario, RSMConfig, SimConfig,
                        faulty_pair_bound, run_picsou, theorem1_resends)


def delivery_probability_curve(max_retries=12, trials=4000, n=12, f=3,
                               seed=0):
    """Monte-Carlo: random rotation of sender/receiver pairs with a fixed
    byzantine ratio; paper claim: ~8 retries -> 99.9% delivery."""
    rng = np.random.RandomState(seed)
    out = []
    for q in range(1, max_retries + 1):
        fails = 0
        for _ in range(trials):
            s0 = rng.randint(n)
            r0 = rng.randint(n)
            ok = False
            for a in range(q):
                s = (s0 + a) % n
                r = (r0 + a) % n
                if s >= f and r >= f:     # first f ids are faulty
                    ok = True
                    break
            fails += not ok
        out.append({"retries": q, "p_delivery": 1.0 - fails / trials})
    return out


def worst_case_resends():
    """Adversarial placement: lemma-1 bound in the simulator."""
    rows = []
    for f in (1, 2):
        cfg = RSMConfig.bft(f)
        n = cfg.n
        fails = FailureScenario(
            crash_s=tuple([2] * f + [-1] * (n - f)),
            byz_recv_drop=tuple([True] * f + [False] * (n - f)))
        run = run_picsou(cfg, cfg,
                         SimConfig(n_msgs=max(2 * n, 16), steps=900,
                                   window=1, phi=16), fails)
        rows.append({
            "f": f, "n": n,
            "delivered": run.all_delivered,
            "max_retries": run.result.max_resends_per_msg(),
            "lemma1_bound": 2 * f + 1,
        })
    return rows


def main():
    print("# Theorem 1 — pair-fault bound and resend count")
    print(f"bound_q_1e-9,{theorem1_resends(1e-9):d}")
    for fs in (1, 2, 4):
        ns = 3 * fs + 1
        frac = faulty_pair_bound(ns, fs, ns, fs)
        print(f"faulty_pair_frac_f{fs},{frac:.3f}")
    print("# delivery probability vs retries (n=12, f=3, rotation)")
    print("retries,p_delivery")
    for r in delivery_probability_curve():
        print(f"{r['retries']},{r['p_delivery']:.4f}")
    print("# adversarial resend counts (simulator)")
    print("f,n,delivered,max_retries,lemma1_bound")
    for r in worst_case_resends():
        print(f"{r['f']},{r['n']},{r['delivered']},{r['max_retries']},"
              f"{r['lemma1_bound']}")


if __name__ == "__main__":
    main()
