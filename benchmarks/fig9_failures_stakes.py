"""Figure 9 — failures (33% crashed replicas) and stake scenarios.

(i) stake scenarios: Graded / Unfair / Fair / Large (§6.3);
(ii) 33% random crash failures: simulator measures actual resend overhead,
the capacity model converts it to throughput vs failure-free ATA.
"""

from __future__ import annotations

import numpy as np

from repro.core import (FailureScenario, NetworkModel, RSMConfig, SimConfig,
                        analytic_throughput, run_picsou_batch)
from repro.core.protocols import staked_picsou_throughput


def stake_scenarios(n=19, msg=1e6):
    net = NetworkModel.lan(msg)
    nic = net.nic_Bps
    base = staked_picsou_throughput(np.ones(n), nic, net)
    rows = []

    def add(name, stakes, nics):
        r = staked_picsou_throughput(stakes, nics, net)
        rows.append({
            "scenario": name,
            "msgs_per_s": r["throughput_msgs_per_s"],
            "vs_equal": r["throughput_msgs_per_s"]
            / base["throughput_msgs_per_s"],
        })

    add("equal", np.ones(n), nic)
    add("graded", np.arange(1, n + 1, dtype=float), nic)   # stake = id
    unfair = np.ones(n) * (0.5 / (n - 1))
    unfair[0] = 0.5
    add("unfair", unfair, nic)
    fair_nics = np.ones(n) * nic
    fair_nics[0] = 10 * nic                                 # 10x bandwidth
    add("fair", unfair, fair_nics)
    add("large", np.ones(n) * 1000.0, nic)                  # LCM/apportion
    return rows


def failure_runs(n_seeds: int = 4):
    """33% crash failures, ``n_seeds`` random placements per size.

    All placements of one size share shapes/schedules, so the whole seed
    sweep runs as ONE vmap-batched simulation (one compile + one dispatch
    per n) instead of one cached program per scenario.
    ``window_slots="auto"`` picks the kernel via the one shared clamp
    rule (``gc.resolve_window_slots``): dense here (M=128 is below the
    auto window width — and heavy-crash sweeps pin the GC frontier,
    which the adaptive overflow policy would migrate to the dense layout
    anyway); windowed+batched engages automatically on larger,
    lighter-failure sweeps (see ``bench_windowed --batch``).
    """
    rows = []
    for n in (4, 10, 19):
        f = max((n - 1) // 3, 1)
        cfg = RSMConfig(n=n, u=f, r=f)
        scenarios = [FailureScenario.crash_fraction(n, n, 0.33, seed=s)
                     for s in range(1, n_seeds + 1)]
        runs = run_picsou_batch(
            cfg, cfg, SimConfig(n_msgs=128, steps=600, window=2, phi=32,
                                window_slots="auto"),
            scenarios)
        resend_factor = float(np.mean([r.resends_per_msg for r in runs]))
        net = NetworkModel.lan(1e6)
        p = analytic_throughput("picsou", cfg, cfg, net,
                                resend_factor=resend_factor)
        a = analytic_throughput("ata", cfg, cfg, net)
        rows.append({
            "n": n,
            "delivered": all(r.all_delivered for r in runs),
            "resends_per_msg": resend_factor,
            "picsou_msgs_s": p["throughput_msgs_per_s"],
            "ata_msgs_s": a["throughput_msgs_per_s"],
            "ratio": p["throughput_msgs_per_s"]
            / max(a["throughput_msgs_per_s"], 1e-9),
        })
    return rows


def main():
    print("# Figure 9(i) — stake scenarios (n=19, 1MB)")
    print("scenario,msgs_per_s,vs_equal")
    for r in stake_scenarios():
        print(f"{r['scenario']},{r['msgs_per_s']:.1f},{r['vs_equal']:.3f}")
    print("# Figure 9(ii) — 33% crash failures (1MB)")
    print("n,delivered,resends_per_msg,picsou_msgs_s,ata_msgs_s,ratio")
    for r in failure_runs():
        print(f"{r['n']},{r['delivered']},{r['resends_per_msg']:.3f},"
              f"{r['picsou_msgs_s']:.1f},{r['ata_msgs_s']:.1f},"
              f"{r['ratio']:.2f}")


if __name__ == "__main__":
    main()
