"""repro.replay benchmarks: checkpoint overhead + what-if fork sweeps.

Two sections:

* ``record`` — the cost of running with chunk-boundary checkpoint
  capture vs the plain windowed run, plus the serialized trace size
  (the checkpointing tax of turning a run into an experiment).
* ``forks`` — fork-count x stream-length sweep: from one mid-stream
  checkpoint, fork N crash-time variants (fork 0 = baseline, fork i
  crashes a sender i chunk boundaries later) and execute them as ONE
  vmapped batch — one dispatch per chunk for the whole fork set. Cold
  vs warm wall time and the measured chunk-compile counts
  (``chunk_traces``; warm re-forks must be 0 — the "no recompilation"
  contract) are reported per point, with per-fork amortized cost and
  the divergence spread across futures.

  PYTHONPATH=src python -m benchmarks.bench_replay
      [--sizes 4096,16384] [--forks 2,4,8] [--every 2]
      [--json BENCH_replay.json]

The CI fast tier runs the acceptance smoke — checkpoint -> inject ->
4-fork batch at small shapes — via ``--sizes 1024 --forks 4``.
"""

from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

from benchmarks.run import _dump_json
from repro.core import FailureScenario, RSMConfig, SimConfig
from repro.core.gc import snap_to_boundary
from repro.core.simulator import build_spec, run_simulation
from repro.replay import ForkSpec, Injection, fork_whatif, record_simulation

SIZES = (4096, 16384)
FORKS = (2, 4, 8)
CFG = RSMConfig.bft(1)
SEND_WINDOW = 4


def _spec(m: int):
    steps = m // (CFG.n * SEND_WINDOW) + 60
    sim = SimConfig(n_msgs=m, steps=steps, window=SEND_WINDOW, phi=32,
                    window_slots="auto", chunk_steps=32)
    return build_spec(CFG, CFG, sim)


def _fork_point(trace) -> int:
    """A boundary with traffic still in flight: ~mid-dispatch."""
    spec = trace.specs[0]
    dispatch_rounds = spec.m // (spec.n_s * SEND_WINDOW)
    bounds = trace.boundaries()
    return int(bounds[np.searchsorted(bounds, dispatch_rounds // 2,
                                      side="right") - 1])


def _variants(trace, n_forks: int, fork_t: int):
    """Fork 0 = baseline; fork i crashes sender 0 i-1 boundaries later
    (the 'when does the crash hurt least' what-if sweep)."""
    spec = trace.specs[0]
    chunk = trace.chunk_steps
    out = [ForkSpec("baseline")]
    for i in range(1, n_forks):
        t = snap_to_boundary(min(fork_t + (i - 1) * chunk,
                                 spec.steps - 1), chunk)
        crash = FailureScenario(
            crash_s=(t,) + (-1,) * (spec.n_s - 1))
        out.append(ForkSpec(f"crash{i}@{t}", [Injection(t, crash)]))
    return out


def record_rows(sizes, every: int):
    rows = []
    for m in sizes:
        spec = _spec(m)
        run_simulation(spec)                       # compile
        t0 = time.time()
        run_simulation(spec)
        plain = time.time() - t0
        t0 = time.time()
        res, trace = record_simulation(spec, every=every)
        rec = time.time() - t0
        with tempfile.NamedTemporaryFile(suffix=".npz",
                                         delete=False) as f:
            path = f.name
        try:
            trace.save(path)
            trace_bytes = os.path.getsize(path)
        finally:
            os.unlink(path)
        rows.append({
            "section": "record",
            "n_msgs": m,
            "window_slots": spec.window_slots,
            "chunk_steps": spec.chunk_steps,
            "every": every,
            "n_checkpoints": len(trace.checkpoints),
            "plain_warm_s": plain,
            "record_warm_s": rec,
            "record_overhead": rec / max(plain, 1e-9) - 1.0,
            "trace_bytes": trace_bytes,
            "complete": bool((np.asarray(res.deliver_time) >= 0).all()),
        })
    return rows


def fork_rows(sizes, forks, every: int):
    rows = []
    for m in sizes:
        spec = _spec(m)
        _, trace = record_simulation(spec, every=every)
        fork_t = _fork_point(trace)
        for n in forks:
            variants = _variants(trace, n, fork_t)
            t0 = time.time()
            cold_rep = fork_whatif(trace, fork_t, variants)
            cold = time.time() - t0
            t0 = time.time()
            rep = fork_whatif(trace, fork_t, variants)
            warm = time.time() - t0
            stats = [f.stats["lane0"] for f in rep.forks]
            resends = [s["resends"] for s in stats]
            dsteps = [s["delivery_step"] for s in stats]
            rows.append({
                "section": "forks",
                "n_msgs": m,
                "forks": n,
                "fork_step": fork_t,
                "window_slots": spec.window_slots,
                "cold_s": cold,
                "warm_s": warm,
                "warm_s_per_fork": warm / n,
                "chunk_traces_cold": cold_rep.chunk_traces,
                "chunk_traces_warm": rep.chunk_traces,
                "resends_min": min(resends),
                "resends_max": max(resends),
                "delivery_step_min": min(dsteps),
                "delivery_step_max": max(dsteps),
            })
    return rows


def main(sizes=SIZES, forks=FORKS, every=2, json_path=None):
    rs = record_rows(sizes, every)
    print("# checkpoint recording overhead (windowed run + O(W) "
          "snapshots)")
    print("n_msgs,window_slots,n_ckpts,plain_warm_s,record_warm_s,"
          "overhead,trace_bytes,complete")
    for r in rs:
        print(f"{r['n_msgs']},{r['window_slots']},{r['n_checkpoints']},"
              f"{r['plain_warm_s']:.2f},{r['record_warm_s']:.2f},"
              f"{r['record_overhead']:.1%},{r['trace_bytes']},"
              f"{r['complete']}")
    fr = fork_rows(sizes, forks, every)
    print("# what-if fork sweep (N futures, one vmapped dispatch/chunk)")
    print("n_msgs,forks,fork_step,cold_s,warm_s,warm_s_per_fork,"
          "traces_cold,traces_warm,resends_spread,delivery_spread")
    for r in fr:
        print(f"{r['n_msgs']},{r['forks']},{r['fork_step']},"
              f"{r['cold_s']:.2f},{r['warm_s']:.2f},"
              f"{r['warm_s_per_fork']:.3f},{r['chunk_traces_cold']},"
              f"{r['chunk_traces_warm']},"
              f"{r['resends_max'] - r['resends_min']},"
              f"{r['delivery_step_max'] - r['delivery_step_min']}")
    rs.extend(fr)
    if json_path:
        _dump_json(json_path, rs)
    return rs


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated n_msgs sweep "
                         f"(default {','.join(map(str, SIZES))})")
    ap.add_argument("--forks", type=str, default=None,
                    help="comma-separated fork counts "
                         f"(default {','.join(map(str, FORKS))})")
    ap.add_argument("--every", type=int, default=2,
                    help="checkpoint every N chunk boundaries")
    ap.add_argument("--json", type=str, default=None,
                    help="dump machine-readable rows to this path")
    args = ap.parse_args()
    sizes = (tuple(int(x) for x in args.sizes.split(","))
             if args.sizes else SIZES)
    forks = (tuple(int(x) for x in args.forks.split(","))
             if args.forks else FORKS)
    main(sizes, forks, args.every, json_path=args.json)
