"""Cross-pod sync schedules: measured HLO wire bytes, picsou vs ATA.

Lowers both schedules on a (2,4,4)-host mesh, parses the partitioned HLO
and reports collective wire bytes + the analytic DCN split for the
production (2,16,16) mesh. This is the paper's Figure-2 message-count
argument executed on real collectives.
"""

from __future__ import annotations



def main():
    # needs its own device count: run under dryrun-style env if top-level
    import os
    if "xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_"
                                     "count=32")
    import jax
    import jax.numpy as jnp

    from repro.crosspod import (ata_cross_pod_sync, dcn_bytes_analytic,
                                picsou_cross_pod_sync)
    from repro.launch.mesh import make_mesh
    from repro.roofline.hlo_cost import analyze_hlo_text

    mesh = make_mesh((2, 4, 4), ("pod", "data", "model"))
    g = {"w": jax.ShapeDtypeStruct((1024, 1024), jnp.float32)}
    n_bytes = 1024 * 1024 * 4

    rows = []
    for name, fn in (("picsou", picsou_cross_pod_sync),
                     ("ata", ata_cross_pod_sync)):
        lowered = jax.jit(lambda x, fn=fn: fn(x, mesh)).lower(g)
        hc = analyze_hlo_text(lowered.compile().as_text())
        rows.append((name, hc.wire_bytes, dict(hc.wire_by_kind)))

    print("# measured wire bytes per chip (1 sync of 4MB, mesh 2x4x4)")
    print("schedule,wire_bytes_per_chip,breakdown")
    for name, wire, kinds in rows:
        print(f"{name},{wire:.0f},"
              + ";".join(f"{k}={v:.0f}" for k, v in kinds.items()))

    print("# analytic DCN split on the production mesh (2,16,16)")
    print("schedule,dcn_bytes_per_chip,ici_bytes_per_chip,dcn_reduction")
    shape = {"pod": 2, "data": 16, "model": 16}
    for name in ("ata", "picsou"):
        d = dcn_bytes_analytic(n_bytes, shape, name)
        print(f"{name},{d['dcn_per_chip']:.0f},{d['ici_per_chip']:.0f},"
              f"{d.get('dcn_reduction', 1.0):.1f}")


if __name__ == "__main__":
    main()
