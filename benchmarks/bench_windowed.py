"""Dense vs sliding-window simulator core: wall-clock + state footprint.

The dense path carries (n_s, n_r, M) per-message state through the whole
``lax.scan`` — memory and compile time grow with stream length M. The
windowed path (GC-driven ring buffers, §4.3) keeps O(W) state regardless
of M. This bench sweeps M in {256, 4096, 65536} and reports, per path,
the first-call wall time (includes compile), steady-state wall time, and
the scan-state footprint in bytes.

  PYTHONPATH=src python -m benchmarks.bench_windowed [--dense-max N]
"""

from __future__ import annotations

import argparse
import time

from repro.core import RSMConfig, SimConfig
from repro.core.simulator import build_spec, run_simulation

SIZES = (256, 4096, 65536)
SENDER = RSMConfig.bft(1)
RECEIVER = RSMConfig.bft(1)
SEND_WINDOW = 4


def _sim(m: int, windowed: bool) -> SimConfig:
    steps = m // (SENDER.n * SEND_WINDOW) + 60
    return SimConfig(n_msgs=m, steps=steps, window=SEND_WINDOW, phi=32,
                     window_slots=("auto" if windowed else None),
                     chunk_steps=32)


def _run(m: int, windowed: bool):
    spec = build_spec(SENDER, RECEIVER, _sim(m, windowed))
    t0 = time.time()
    res = run_simulation(spec)
    cold = time.time() - t0
    t0 = time.time()
    res = run_simulation(spec)
    warm = time.time() - t0
    ok = bool((res.deliver_time >= 0).all() and (res.quack_time >= 0).all())
    return {
        "path": "windowed" if windowed else "dense",
        "n_msgs": m,
        "window_slots": spec.window_slots or spec.m,
        "state_bytes": spec.scan_state_nbytes(),
        "cold_s": cold,
        "warm_s": warm,
        "complete": ok,
    }


def rows(dense_max: int = 4096):
    out = []
    for m in SIZES:
        out.append(_run(m, windowed=True))
        if m <= dense_max:
            out.append(_run(m, windowed=False))
        else:
            spec = build_spec(SENDER, RECEIVER, _sim(m, False))
            out.append({"path": "dense", "n_msgs": m,
                        "window_slots": m,
                        "state_bytes": spec.scan_state_nbytes(),
                        "cold_s": float("nan"), "warm_s": float("nan"),
                        "complete": "skipped(dense-max)"})
    return out


def main(dense_max: int = 4096):
    rs = rows(dense_max)
    print("# windowed vs dense simulator core (BFT1<->BFT1, window=4)")
    print("path,n_msgs,window_slots,state_bytes,cold_s,warm_s,complete")
    for r in rs:
        print(f"{r['path']},{r['n_msgs']},{r['window_slots']},"
              f"{r['state_bytes']},{r['cold_s']:.2f},{r['warm_s']:.2f},"
              f"{r['complete']}")
    return rs


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dense-max", type=int, default=4096,
                    help="largest n_msgs to run on the dense path "
                         "(beyond this only the windowed path runs)")
    args = ap.parse_args()
    main(args.dense_max)
