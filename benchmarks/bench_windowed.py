"""Dense vs sliding-window simulator core: wall-clock + state footprint.

The dense path carries (n_s, n_r, M) per-message state through the whole
``lax.scan`` — memory and compile time grow with stream length M. The
windowed path (GC-driven ring buffers, §4.3) keeps O(W) state regardless
of M, with the GC frontier and ring rotation computed *in-graph*: the
host drains a bounded O(W) output queue per chunk and never round-trips
the scan state. This bench sweeps M and reports, per path, the
first-call wall time (includes compile), steady-state wall time, and the
scan-state footprint in bytes.

A second section times batched windowed failure sweeps: B scenarios as
one ``jax.vmap``-ed chunk stream with per-scenario window bases
(``run_simulation_batch``) against B sequential windowed runs.

  PYTHONPATH=src python -m benchmarks.bench_windowed [--dense-max N]
      [--sizes 256,4096,65536,102400] [--batch B]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import FailureScenario, RSMConfig, SimConfig
from repro.core.simulator import build_spec, run_simulation, \
    run_simulation_batch

SIZES = (256, 4096, 65536, 102400)
SENDER = RSMConfig.bft(1)
RECEIVER = RSMConfig.bft(1)
SEND_WINDOW = 4


def _sim(m: int, windowed: bool) -> SimConfig:
    steps = m // (SENDER.n * SEND_WINDOW) + 60
    return SimConfig(n_msgs=m, steps=steps, window=SEND_WINDOW, phi=32,
                     window_slots=("auto" if windowed else None),
                     chunk_steps=32)


def _run(m: int, windowed: bool):
    spec = build_spec(SENDER, RECEIVER, _sim(m, windowed))
    t0 = time.time()
    res = run_simulation(spec)
    cold = time.time() - t0
    t0 = time.time()
    res = run_simulation(spec)
    warm = time.time() - t0
    ok = bool((res.deliver_time >= 0).all() and (res.quack_time >= 0).all())
    # 'auto' clamps to the dense kernel when W >= M — label the row by the
    # kernel that actually ran so small sizes don't fake a comparison
    kernel = ("windowed" if spec.window_slots else "dense(auto)") \
        if windowed else "dense"
    return {
        "path": kernel,
        "n_msgs": m,
        "window_slots": spec.window_slots or spec.m,
        "state_bytes": spec.scan_state_nbytes(),
        "cold_s": cold,
        "warm_s": warm,
        "complete": ok,
    }


def rows(dense_max: int = 4096, sizes=SIZES):
    out = []
    for m in sizes:
        out.append(_run(m, windowed=True))
        if m <= dense_max:
            out.append(_run(m, windowed=False))
        else:
            spec = build_spec(SENDER, RECEIVER, _sim(m, False))
            out.append({"path": "dense", "n_msgs": m,
                        "window_slots": m,
                        "state_bytes": spec.scan_state_nbytes(),
                        "cold_s": float("nan"), "warm_s": float("nan"),
                        "complete": "skipped(dense-max)"})
    return out


def batch_rows(m: int = 8192, n_scenarios: int = 4):
    """Batched windowed sweep vs the same scenarios run sequentially."""
    sim = _sim(m, windowed=True)
    n = SENDER.n
    # crashes fire mid-run (different placement per seed), so the
    # per-scenario GC frontiers genuinely diverge inside the one dispatch.
    scenarios = [FailureScenario.none()]
    scenarios += [FailureScenario.crash_fraction(n, n, 0.25, seed=s,
                                                 at_step=8)
                  for s in range(1, n_scenarios)]
    specs = [build_spec(SENDER, RECEIVER, sim, f) for f in scenarios]
    t0 = time.time()
    runs = run_simulation_batch(specs)
    cold = time.time() - t0
    t0 = time.time()
    runs = run_simulation_batch(specs)
    warm = time.time() - t0
    seq = [run_simulation(s) for s in specs]   # warm the batch-of-1 programs
    t0 = time.time()
    seq = [run_simulation(s) for s in specs]
    seq_warm = time.time() - t0
    # crashed senders legitimately leave their messages undelivered, so
    # completeness is judged on the failure-free scenario only; the crash
    # scenarios must still match their sequential runs bit-for-bit.
    ok = bool((runs[0].deliver_time >= 0).all()) and all(
        np.array_equal(np.asarray(getattr(b, out)),
                       np.asarray(getattr(s, out)))
        for b, s in zip(runs, seq)
        for out in ("quack_time", "deliver_time", "retry", "recv_has"))
    # report the kernel/width the run *ended* with: 'auto' clamps to dense
    # when W >= M, and adaptive growth / dense fallback can change the
    # width mid-run (final_window_slots == M signals dense).
    final_w = runs[0].final_window_slots
    return {
        "n_msgs": m,
        "scenarios": len(specs),
        "kernel": ("windowed" if specs[0].window_slots and final_w < specs[0].m
                   else "dense"),
        "window_slots": final_w,
        "batched_cold_s": cold,
        "batched_warm_s": warm,
        "sequential_warm_s": seq_warm,
        "complete": bool(ok),
    }


def main(dense_max: int = 4096, sizes=SIZES, batch: int = 4):
    rs = rows(dense_max, sizes)
    print("# windowed vs dense simulator core (BFT1<->BFT1, window=4)")
    print("path,n_msgs,window_slots,state_bytes,cold_s,warm_s,complete")
    for r in rs:
        print(f"{r['path']},{r['n_msgs']},{r['window_slots']},"
              f"{r['state_bytes']},{r['cold_s']:.2f},{r['warm_s']:.2f},"
              f"{r['complete']}")
    if batch > 0:
        b = batch_rows(m=min(max(sizes), 8192), n_scenarios=batch)
        print("# batched failure sweep (windowed kernel => per-scenario "
              "window bases)")
        print("n_msgs,scenarios,kernel,window_slots,batched_cold_s,"
              "batched_warm_s,sequential_warm_s,complete")
        print(f"{b['n_msgs']},{b['scenarios']},{b['kernel']},"
              f"{b['window_slots']},"
              f"{b['batched_cold_s']:.2f},{b['batched_warm_s']:.2f},"
              f"{b['sequential_warm_s']:.2f},{b['complete']}")
        rs.append(b)
    return rs


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dense-max", type=int, default=4096,
                    help="largest n_msgs to run on the dense path "
                         "(beyond this only the windowed path runs)")
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated n_msgs sweep (default "
                         "256,4096,65536,102400); tiny values make a CI "
                         "smoke run")
    ap.add_argument("--batch", type=int, default=4,
                    help="scenarios in the batched windowed sweep "
                         "(0 disables the section)")
    args = ap.parse_args()
    sizes = (tuple(int(s) for s in args.sizes.split(","))
             if args.sizes else SIZES)
    main(args.dense_max, sizes, args.batch)
