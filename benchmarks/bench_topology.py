"""Multi-link C3B topology sweeps on the batched windowed kernel.

Sweeps link count x stream length x failure scenario over fanout
topologies (primary -> N backups, the disaster-recovery shape): every
link is one lane of a single vmapped windowed chunk stream, so the
device state is O(L * W) and a whole graph costs one compilation and one
dispatch per chunk. A second section times a chained relay pipeline
(commit-floor plumbing between chunks) and reports the end-to-end
delivery lag the chaining introduces.

  PYTHONPATH=src python -m benchmarks.bench_topology
      [--links 2,4,8] [--sizes 2048,8192] [--scenarios none,crash25,byz]
      [--json BENCH_topology.json]

The CI fast tier runs the acceptance smoke — a 4-link x 8192-message
sweep — via ``--links 4 --sizes 8192``.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.run import _dump_json
from repro.core import FailureScenario, RSMConfig, SimConfig
from repro.topology import Topology, link_specs, run_topology

LINKS = (2, 4, 8)
SIZES = (2048, 8192)
SCENARIOS = ("none", "crash25", "byz")
CFG = RSMConfig.bft(1)
SEND_WINDOW = 4


def _sim(m: int) -> SimConfig:
    steps = m // (CFG.n * SEND_WINDOW) + 60
    return SimConfig(n_msgs=m, steps=steps, window=SEND_WINDOW, phi=32,
                     window_slots="auto", chunk_steps=32)


def _scenario_failures(scenario: str, n_links: int, n: int) -> dict:
    """Per-backup link failures for one sweep point."""
    if scenario == "none":
        return {}
    if scenario == "crash25":
        # staggered receiver crashes: every other backup loses 25% of its
        # replicas mid-run, so the per-link GC frontiers genuinely diverge
        # inside the one dispatch.
        return {f"b{i}": FailureScenario.crash_fraction(
                    n, n, 0.25, seed=i, at_step=8)
                for i in range(0, n_links, 2)}
    if scenario == "byz":
        byz = (True,) + (False,) * (n - 1)
        return {f"b{i}": FailureScenario(byz_recv_drop=byz)
                for i in range(0, n_links, 2)}
    raise ValueError(f"unknown scenario {scenario!r}")


def _fanout(n_links: int, m: int, scenario: str) -> Topology:
    return Topology.fanout(
        "p", [f"b{i}" for i in range(n_links)], CFG, _sim(m),
        failures=_scenario_failures(scenario, n_links, CFG.n))


def rows(links=LINKS, sizes=SIZES, scenarios=SCENARIOS):
    out = []
    for m in sizes:
        for n_links in links:
            for scenario in scenarios:
                topo = _fanout(n_links, m, scenario)
                spec = link_specs(topo)[0]
                t0 = time.time()
                res = run_topology(topo)
                cold = time.time() - t0
                t0 = time.time()
                res = run_topology(topo)
                warm = time.time() - t0
                # crashed/byzantine receivers can legitimately strand
                # messages; completeness is judged on the clean links.
                clean = [l.name for l in topo.links
                         if l.name.split("->")[1] not in
                         _scenario_failures(scenario, n_links, CFG.n)]
                ok = all(res[n].delivered_prefix() == m for n in clean)
                out.append({
                    "section": "fanout",
                    "links": n_links,
                    "n_msgs": m,
                    "scenario": scenario,
                    "window_slots": res[topo.link_names[0]]
                    .result.final_window_slots,
                    "state_bytes_per_link": spec.scan_state_nbytes(),
                    "cold_s": cold,
                    "warm_s": warm,
                    "complete": bool(ok),
                })
    return out


def chain_rows(depth: int = 3, m: int = 2048):
    """Chained relay pipeline: delivery lag of commit-floor plumbing."""
    topo = Topology.chain([f"c{i}" for i in range(depth)], CFG, _sim(m))
    t0 = time.time()
    res = run_topology(topo)
    cold = time.time() - t0
    t0 = time.time()
    res = run_topology(topo)
    warm = time.time() - t0
    first, last = topo.link_names[0], topo.link_names[-1]
    d_first = int(np.asarray(res[first].result.deliver_time).max())
    d_last = int(np.asarray(res[last].result.deliver_time).max())
    return {
        "section": "chain",
        "links": depth - 1,
        "n_msgs": m,
        "scenario": "chained",
        "complete": bool(res[last].delivered_prefix() == m),
        "cold_s": cold,
        "warm_s": warm,
        "first_hop_done_round": d_first,
        "last_hop_done_round": d_last,
        "pipeline_lag_rounds": d_last - d_first,
    }


def main(links=LINKS, sizes=SIZES, scenarios=SCENARIOS, chain_depth=3,
         json_path=None):
    rs = rows(links, sizes, scenarios)
    print("# multi-link fanout sweeps (BFT1, one vmapped dispatch/chunk)")
    print("links,n_msgs,scenario,window_slots,state_bytes_per_link,"
          "cold_s,warm_s,complete")
    for r in rs:
        print(f"{r['links']},{r['n_msgs']},{r['scenario']},"
              f"{r['window_slots']},{r['state_bytes_per_link']},"
              f"{r['cold_s']:.2f},{r['warm_s']:.2f},{r['complete']}")
    if chain_depth >= 2:
        c = chain_rows(chain_depth, min(sizes))
        print("# chained relay pipeline (commit-floor plumbing)")
        print("links,n_msgs,complete,cold_s,warm_s,first_done,last_done,"
              "lag_rounds")
        print(f"{c['links']},{c['n_msgs']},{c['complete']},"
              f"{c['cold_s']:.2f},{c['warm_s']:.2f},"
              f"{c['first_hop_done_round']},{c['last_hop_done_round']},"
              f"{c['pipeline_lag_rounds']}")
        rs.append(c)
    if json_path:
        _dump_json(json_path, rs)
    return rs


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--links", type=str, default=None,
                    help="comma-separated link counts (default 2,4,8)")
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated n_msgs sweep (default 2048,8192)")
    ap.add_argument("--scenarios", type=str, default=None,
                    help="comma-separated subset of none,crash25,byz")
    ap.add_argument("--chain-depth", type=int, default=3,
                    help="clusters in the chained-pipeline section "
                         "(<2 disables it)")
    ap.add_argument("--json", type=str, default=None,
                    help="also write the rows as machine-readable JSON")
    args = ap.parse_args()
    main(tuple(int(s) for s in args.links.split(","))
         if args.links else LINKS,
         tuple(int(s) for s in args.sizes.split(","))
         if args.sizes else SIZES,
         tuple(args.scenarios.split(",")) if args.scenarios else SCENARIOS,
         args.chain_depth, args.json)
