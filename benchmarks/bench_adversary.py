"""Byzantine adversary palette benchmarks: attack tax + reconfig cost.

Two sections:

* ``palette`` — per-adversary-kind sweep against the honest baseline at
  each size: warm wall time, wire amplification (resends / extra cross
  messages the attack manufactures) and the measured chunk-compile
  delta. Every adversary mask rides the traced ``FailArrays``, so the
  honest program must serve the *entire* palette — the headline
  ``extra_traces`` column is expected to be 0 for every kind.
* ``reconfig`` — mid-stream membership/quorum edits replayed from a
  checkpoint: remove-replica, join-replica and stake re-weight
  injections, warm wall time per replay and the chunk-compile delta
  after one warm-up (the zero-recompilation contract for
  reconfiguration, same counter the replay bench gates on).

  PYTHONPATH=src python -m benchmarks.bench_adversary
      [--sizes 2048,8192] [--json BENCH_adversary.json]

The CI fast tier runs ``--sizes 256`` as an acceptance smoke
(``tests/test_adversary.py``).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.run import _dump_json
from repro.adversary import ADVERSARY_KINDS, adversary_scenario
from repro.core import FailureScenario, RSMConfig, SimConfig
from repro.adversary import join_receiver, remove_receiver
from repro.core.simulator import (build_spec, chunk_trace_count,
                                  run_simulation, spec_with_quorum)
from repro.replay import Injection, record_simulation, replay

SIZES = (2048, 8192)
CFG = RSMConfig.bft(1)
SEND_WINDOW = 4


def _sim(m: int) -> SimConfig:
    steps = m // (CFG.n * SEND_WINDOW) + 60
    return SimConfig(n_msgs=m, steps=steps, window=SEND_WINDOW, phi=32,
                     window_slots="auto", chunk_steps=32)


def _run(spec):
    t0 = time.time()
    res = run_simulation(spec)
    np.asarray(res.deliver_time)
    return res, time.time() - t0


def palette_rows(sizes):
    rows = []
    for m in sizes:
        honest = build_spec(CFG, CFG, _sim(m))
        _run(honest)                               # cold compile
        base_traces = chunk_trace_count()
        hres, hwarm = _run(honest)
        hcross = int(np.asarray(hres.metrics.cross_msgs).sum())
        rows.append(dict(section="palette", kind="honest", n_msgs=m,
                         warm_s=hwarm, resends=0, extra_cross=0,
                         delivered=m, extra_traces=0))
        for kind in ADVERSARY_KINDS:
            sc = adversary_scenario(kind, CFG.n, CFG.n, seed=0)
            spec = build_spec(CFG, CFG, _sim(m), failures=sc)
            res, warm = _run(spec)
            rows.append(dict(
                section="palette", kind=kind, n_msgs=m, warm_s=warm,
                resends=int(np.asarray(res.metrics.resends).sum()),
                extra_cross=int(np.asarray(res.metrics.cross_msgs).sum())
                            - hcross,
                delivered=int((np.asarray(res.deliver_time) >= 0).sum()),
                extra_traces=chunk_trace_count() - base_traces))
            print(f"palette,{kind},{m},{warm:.3f}s,"
                  f"resends={rows[-1]['resends']},"
                  f"extra_traces={rows[-1]['extra_traces']}")
    return rows


def reconfig_rows(sizes):
    rows = []
    n = CFG.n
    for m in sizes:
        spec = build_spec(CFG, CFG, _sim(m))
        _, trace = record_simulation(spec)
        chunk = trace.chunk_steps
        t_edit = (spec.steps // (2 * chunk)) * chunk
        variants = {
            "remove_replica": [remove_receiver(
                n, n - 1, t_edit, stakes_r=(1.0,) * n,
                quack_thresh=2.0, dup_thresh=2.0)],
            "stake_reweight": [Injection(
                t_edit, stakes_r=(2.0,) + (1.0,) * (n - 1),
                quack_thresh=3.0)],
            "adversary_on_off": [
                Injection(t_edit,
                          failures=adversary_scenario("selective_drop",
                                                      n, n, seed=0)),
                Injection(min(t_edit * 2, spec.steps - chunk)
                          // chunk * chunk,
                          failures=FailureScenario())],
        }
        # join twin: the base run models the future member as
        # crashed-from-round-0 with zero stake; the injection flips it
        # alive and weights it in (same compiled program — crash masks,
        # stakes and thresholds are all traced)
        spec_j = build_spec(CFG, CFG, _sim(m), failures=FailureScenario(
            crash_r=(-1,) * (n - 1) + (0,)))
        spec_j = spec_with_quorum(spec_j,
                                  stakes_r=(1.0,) * (n - 1) + (0.0,))
        _, trace_j = record_simulation(spec_j)
        replay(trace, t_edit, variants["remove_replica"])  # warm-up
        base_traces = chunk_trace_count()
        jobs = [(name, trace, inj) for name, inj in variants.items()]
        jobs.append(("join_replica", trace_j, [join_receiver(
            n, n - 1, t_edit, stakes_r=(1.0,) * n,
            quack_thresh=2.0, dup_thresh=2.0)]))
        for name, tr, inj in jobs:
            t0 = time.time()
            ri = replay(tr, t_edit, inj)[0]
            np.asarray(ri.deliver_time)
            rows.append(dict(
                section="reconfig", kind=name, n_msgs=m,
                warm_s=time.time() - t0,
                delivered=int((np.asarray(ri.deliver_time) >= 0).sum()),
                extra_traces=chunk_trace_count() - base_traces))
            print(f"reconfig,{name},{m},{rows[-1]['warm_s']:.3f}s,"
                  f"extra_traces={rows[-1]['extra_traces']}")
    return rows


def main(sizes=None, json_path=None):
    sizes = tuple(sizes) if sizes else SIZES
    rows = palette_rows(sizes) + reconfig_rows(sizes)
    if json_path:
        _dump_json(json_path, rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default=None,
                    help="comma-separated n_msgs sizes")
    ap.add_argument("--json", default="BENCH_adversary.json")
    a = ap.parse_args()
    sizes = (tuple(int(s) for s in a.sizes.split(","))
             if a.sizes else None)
    main(sizes=sizes, json_path=a.json)
