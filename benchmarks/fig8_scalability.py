"""Figure 8 — C3B throughput vs network size / message size / geo.

Reproduces the paper's scalability study with the analytic capacity model
(validated trends) plus step-simulator quack-throughput measurements for
the protocol dynamics. Paper reference points are printed next to each
model ratio.
"""

from __future__ import annotations

import time

from repro.core import (FailureScenario, NetworkModel, RSMConfig, SimConfig,
                        analytic_throughput, run_picsou, run_picsou_batch)

# paper-reported PICSOU/ATA ratios [§6.1]
PAPER = {
    (4, 1e2, "lan"): 1.84, (19, 1e2, "lan"): 8.4,
    (4, 1e6, "lan"): 3.7, (19, 1e6, "lan"): 13.4,
    (4, 1e6, "geo"): 9.7, (19, 1e6, "geo"): 24.0,
}


def rows():
    out = []
    for n in (4, 7, 10, 13, 16, 19):
        f = max((n - 1) // 3, 1)
        cfg = RSMConfig(n=n, u=f, r=f)
        for msg, netname in ((1e2, "lan"), (1e6, "lan"), (1e6, "geo")):
            net = (NetworkModel.geo(msg) if netname == "geo"
                   else NetworkModel.lan(msg))
            p = analytic_throughput("picsou", cfg, cfg, net)
            a = analytic_throughput("ata", cfg, cfg, net)
            o = analytic_throughput("ost", cfg, cfg, net)
            ratio = (p["throughput_msgs_per_s"]
                     / max(a["throughput_msgs_per_s"], 1e-9))
            paper = PAPER.get((n, msg, netname), float("nan"))
            out.append({
                "n": n, "msg_bytes": msg, "net": netname,
                "picsou": p["throughput_msgs_per_s"],
                "ata": a["throughput_msgs_per_s"],
                "ost": o["throughput_msgs_per_s"],
                "ratio": ratio, "paper_ratio": paper,
                "picsou_bottleneck": p["bottleneck"],
                "ata_bottleneck": a["bottleneck"],
            })
    return out


def simulator_points():
    """Quack throughput (msgs/round) from the full protocol simulator."""
    out = []
    for n in (4, 10, 19):
        f = max((n - 1) // 3, 1)
        cfg = RSMConfig(n=n, u=f, r=f)
        t0 = time.time()
        run = run_picsou(cfg, cfg, SimConfig(n_msgs=256, steps=120,
                                             window=4, phi=64))
        dt = time.time() - t0
        out.append({
            "n": n,
            "quacks_per_round": run.quack_throughput_per_step(),
            "cross_copies_per_msg": run.cross_copies_per_msg,
            "intra_copies_per_msg": run.intra_copies_per_msg,
            "sim_wall_s": round(dt, 2),
        })
    return out


def scenario_sweep(n: int = 10):
    """Protocol dynamics across failure scenarios, one compilation.

    All scenarios share the (n, schedule) shape, so the sweep is a single
    vmap-batched dispatch (``run_picsou_batch``). ``window_slots="auto"``
    picks the right kernel via the one shared clamp rule
    (``gc.resolve_window_slots``): at this figure's paper shape
    (M=128 < auto W) it clamps to the dense batch kernel, and at larger
    streams the same call runs windowed+batched with per-scenario window
    bases (see ``bench_windowed --batch`` for that regime); results are
    bit-identical either way."""
    f = max((n - 1) // 3, 1)
    cfg = RSMConfig(n=n, u=f, r=f)
    sim = SimConfig(n_msgs=128, steps=600, window=2, phi=32,
                    window_slots="auto")
    named = [("none", FailureScenario.none())]
    named += [(f"crash{int(frac * 100)}",
               FailureScenario.crash_fraction(n, n, frac, seed=2))
              for frac in (0.1, 0.2, 0.33)]
    byz = [False] * n
    byz[0] = True
    named.append(("byz_drop", FailureScenario(byz_recv_drop=tuple(byz))))
    runs = run_picsou_batch(cfg, cfg, sim, [s for _, s in named])
    out = []
    for (name, _), run in zip(named, runs):
        out.append({
            "scenario": name,
            "delivered": run.all_delivered,
            "resends_per_msg": run.resends_per_msg,
            "cross_copies_per_msg": run.cross_copies_per_msg,
            "quacks_per_round": run.quack_throughput_per_step(),
        })
    return out


def main():
    print("# Figure 8 — scalability (analytic capacity model)")
    print("n,msg_bytes,net,picsou_msgs_s,ata_msgs_s,ost_msgs_s,"
          "ratio,paper_ratio,picsou_bneck,ata_bneck")
    for r in rows():
        print(f"{r['n']},{r['msg_bytes']:.0f},{r['net']},"
              f"{r['picsou']:.1f},{r['ata']:.1f},{r['ost']:.1f},"
              f"{r['ratio']:.2f},{r['paper_ratio']:.2f},"
              f"{r['picsou_bottleneck']},{r['ata_bottleneck']}")
    print("# Figure 8 — simulator quack throughput")
    print("n,quacks_per_round,cross_per_msg,intra_per_msg,sim_wall_s")
    for r in simulator_points():
        print(f"{r['n']},{r['quacks_per_round']:.2f},"
              f"{r['cross_copies_per_msg']:.3f},"
              f"{r['intra_copies_per_msg']:.2f},{r['sim_wall_s']}")
    print("# Figure 8 — batched failure-scenario sweep (n=10, one compile)")
    print("scenario,delivered,resends_per_msg,cross_per_msg,quacks_per_round")
    for r in scenario_sweep():
        print(f"{r['scenario']},{r['delivered']},"
              f"{r['resends_per_msg']:.3f},{r['cross_copies_per_msg']:.3f},"
              f"{r['quacks_per_round']:.2f}")


if __name__ == "__main__":
    main()
