"""Figure 10 — heterogeneous RSM pairs (Algorand / ResilientDB / Raft).

Each RSM runs its consensus at the measured base rate (§6.4); PICSOU links
them with per-message certificate overhead; the pair sustains
min(commit rates, C3B rate) less the forwarding-thread overhead — the
paper's claim is < 15% worst-case throughput loss and that slow Algorand
can feed fast Raft.
"""

from __future__ import annotations

from repro.consensus import (AlgorandModel, PBFTModel, RaftModel,
                             coupled_throughput)
from repro.core import NetworkModel, RSMConfig, analytic_throughput

MODELS = {"algorand": AlgorandModel(), "resilientdb": PBFTModel(),
          "raft": RaftModel()}


def rows(n=4, tx_bytes=512.0, batch=64):
    """Each C3B message carries a batch of committed transactions (the
    paper's implementation forwards consensus batches; ResilientDB commits
    batches of 100+), so the C3B message rate needed is commit_rate/batch.
    """
    cfg = RSMConfig.bft(1)
    rows = []
    for a_name, a in MODELS.items():
        for b_name, b in MODELS.items():
            msg = tx_bytes * batch + a.cert_bytes(cfg)
            net = NetworkModel.lan(msg)
            c3b = analytic_throughput("picsou", cfg, cfg, net)
            c3b_tx_rate = c3b["throughput_msgs_per_s"] * batch
            rate_a = a.rate_at(n)
            rate_b = b.rate_at(n)
            pair = coupled_throughput(min(rate_a, rate_b), c3b_tx_rate)
            overhead = 1.0 - pair / min(rate_a, rate_b)
            rows.append({
                "sender": a_name, "receiver": b_name,
                "sender_rate": rate_a, "receiver_rate": rate_b,
                "c3b_rate": c3b_tx_rate,
                "coupled": pair, "overhead_frac": overhead,
            })
    return rows


def main():
    print("# Figure 10 — heterogeneous RSMs (n=4, 512B tx, batch=64)")
    print("sender,receiver,sender_tx_s,receiver_tx_s,c3b_msgs_s,"
          "coupled_tx_s,overhead")
    worst = 0.0
    for r in rows():
        worst = max(worst, r["overhead_frac"])
        print(f"{r['sender']},{r['receiver']},{r['sender_rate']:.0f},"
              f"{r['receiver_rate']:.0f},{r['c3b_rate']:.0f},"
              f"{r['coupled']:.0f},{r['overhead_frac']:.3f}")
    print(f"# worst-case overhead: {worst:.1%} (paper: <15%)")


if __name__ == "__main__":
    main()
