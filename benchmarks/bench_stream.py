"""Streaming session driver: sustained throughput vs analytic capacity.

Sweeps arrival process x horizon (plus a utilization ladder on the
constant process) through ``repro.stream``'s resident-engine session
and reports, per point, the sustained delivery rate as a **fraction of
the analytic PICSOU capacity** (``core/network.py`` pricing at the
session's fleet size), the live-path latency percentiles, and the
deterministic dispatch counters — the headline is "X% of analytic
capacity sustained at fleet size N", not a wall-clock number.

Every warm run re-executes the identical session after a cold
compile pass, so ``warm_s`` prices the resident steady state (drain +
telemetry fold only), and ``problems`` carries the live-vs-device
invariant: the merge-folded sketch must equal the device's final
cumulative histogram bit-exactly on every row.

  PYTHONPATH=src python -m benchmarks.bench_stream
      [--horizons 8192,65536] [--kinds constant,diurnal,bursty,heavytail]
      [--utils 0.25,0.5,0.9] [--json BENCH_stream.json]
"""

from __future__ import annotations

import argparse
import time

from repro.core import RSMConfig, SimConfig
from repro.stream import ArrivalProcess, StreamConfig, StreamSession

HORIZONS = (8192, 65536)
KINDS = ("constant", "diurnal", "bursty", "heavytail")
UTILS = (0.25, 0.5, 0.9)
SENDER = RSMConfig.bft(1)
RECEIVER = RSMConfig.bft(1)


def _sim() -> SimConfig:
    return SimConfig(window=4, phi=6, window_slots="auto",
                     chunk_steps=16, superchunk=8, debug_checks=False)


def _session(kind: str, horizon: int, rate: float,
             utilization=None) -> StreamSession:
    process = ArrivalProcess(kind=kind, rate=rate, seed=0)
    cfg = StreamConfig(horizon=horizon, process=process,
                       utilization=utilization, report_every=8)
    return StreamSession(SENDER, RECEIVER, _sim(), cfg)


def _measure(kind: str, horizon: int, rate: float, utilization=None):
    session = _session(kind, horizon, rate, utilization)
    t0 = time.time()
    session.run()
    cold = time.time() - t0
    t0 = time.time()
    res = session.run()
    warm = time.time() - t0
    cap = res.capacity
    p = res.percentiles()
    return {
        "kind": kind,
        "horizon": horizon,
        "utilization": utilization,
        "rate_msgs_per_round": cap["offered_msgs_per_round"],
        "offered_frac": cap["offered_frac"],
        "sustained_msgs_per_s": cap["sustained_msgs_per_s"],
        "sustained_frac": cap["sustained_frac"],
        "fleet": cap["fleet"],
        "bottleneck": cap["bottleneck"],
        "p50": p["p50"], "p99": p["p99"],
        "window_slots": res.final_window_slots,
        "dispatches": res.counters["dispatches"],
        "chunks_drained": res.counters["chunks_drained"],
        "live_rows": res.counters["live_rows"],
        "slo_events": len(res.slo_events),
        "cold_s": cold,
        "warm_s": warm,
        "delivered": res.delivered,
        "complete": res.delivered == horizon,
        "problems": list(res.problems),
    }


def rows(horizons=HORIZONS, kinds=KINDS, utils=UTILS):
    out = []
    for h in horizons:
        for kind in kinds:
            out.append(_measure(kind, h, rate=6.0))
    for u in utils:
        out.append(_measure("constant", min(horizons), rate=1.0,
                            utilization=u))
    return out


def main(horizons=HORIZONS, kinds=KINDS, utils=UTILS, json_path=None):
    rs = rows(horizons, kinds, utils)
    print("# streaming session driver (BFT1<->BFT1, window=4, K=8; "
          "sustained rate priced vs analytic capacity)")
    print("kind,horizon,util,offered_frac,sustained_frac,"
          "sustained_msgs_per_s,p99,window_slots,dispatches,warm_s,"
          "complete")
    for r in rs:
        util = f"{r['utilization']:.2f}" if r["utilization"] else "-"
        print(f"{r['kind']},{r['horizon']},{util},"
              f"{r['offered_frac']:.3f},{r['sustained_frac']:.3f},"
              f"{r['sustained_msgs_per_s']:.0f},{r['p99']},"
              f"{r['window_slots']},{r['dispatches']},"
              f"{r['warm_s']:.2f},{r['complete']}")
        for p in r["problems"]:
            print(f"#   PROBLEM: {p}")
    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(rs, f, indent=1, default=float)
        print(f"# wrote {json_path}")
    return rs


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--horizons", type=str, default=None,
                    help="comma-separated horizons (default 8192,65536); "
                         "tiny values make a CI smoke")
    ap.add_argument("--kinds", type=str, default=None,
                    help="comma-separated arrival kinds (default all 4)")
    ap.add_argument("--utils", type=str, default=None,
                    help="comma-separated utilization ladder for the "
                         "capacity-calibrated section (default "
                         "0.25,0.5,0.9; empty string disables)")
    ap.add_argument("--json", type=str, default=None)
    args = ap.parse_args()
    horizons = (tuple(int(s) for s in args.horizons.split(","))
                if args.horizons else HORIZONS)
    kinds = (tuple(args.kinds.split(",")) if args.kinds else KINDS)
    utils = (tuple(float(s) for s in args.utils.split(",") if s)
             if args.utils is not None else UTILS)
    main(horizons, kinds, utils, args.json)
