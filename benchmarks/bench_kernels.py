"""Kernel micro-benchmarks (interpret mode on CPU => correctness-scale
timings; the real perf story is the roofline VMEM analysis in
EXPERIMENTS.md). Reports us/call for kernel vs pure-jnp oracle."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention, quack_scan, rwkv6_chunked
from repro.kernels.ref import (mha_reference, quack_reference,
                               rwkv6_reference)


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def main():
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 5)

    q = jax.random.normal(ks[0], (1, 4, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    t_kern = _time(lambda *a: flash_attention(*a, causal=True, block_q=128,
                                              block_kv=128), q, k, v)
    t_ref = _time(lambda *a: mha_reference(*a, causal=True), q, k, v)
    print(f"flash_attention_interp,{t_kern:.0f},ref_us={t_ref:.0f}")

    r = jax.random.normal(ks[0], (1, 2, 256, 32)) * 0.5
    kk = jax.random.normal(ks[1], (1, 2, 256, 32)) * 0.5
    vv = jax.random.normal(ks[2], (1, 2, 256, 32)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (1, 2, 256, 32))) * .5 + .45
    u = jax.random.normal(ks[4], (2, 32)) * 0.5
    t_kern = _time(lambda *a: rwkv6_chunked(*a, chunk=128), r, kk, vv, w, u)
    t_ref = _time(lambda *a: rwkv6_reference(*a)[0], r, kk, vv, w, u)
    print(f"rwkv6_chunked_interp,{t_kern:.0f},ref_us={t_ref:.0f}")

    claims = jax.random.bernoulli(ks[0], 0.6, (4, 16, 1024))
    comps = jax.random.bernoulli(ks[1], 0.2, (4, 16, 1024))
    stakes = jnp.ones(16)
    t_kern = _time(lambda *a: quack_scan(*a, 5.0, 2.0, block_w=512),
                   claims, comps, stakes)
    t_ref = _time(lambda *a: quack_reference(*a, 5.0, 2.0),
                  claims, comps, stakes)
    print(f"quack_scan_interp,{t_kern:.0f},ref_us={t_ref:.0f}")


if __name__ == "__main__":
    main()
