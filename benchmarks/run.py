"""Benchmark harness — one function per paper table/figure.

Prints each figure's detailed CSV block, then a summary line per table in
``name,us_per_call,derived`` form (us_per_call = wall time of the harness
function; derived = the table's headline number).

  PYTHONPATH=src python -m benchmarks.run [--obs] [--only a,b] \
      [--summary-json BENCH_summary.json]

Sections are failure-isolated: an exception in one sweep is recorded as
that section's status and the run continues, so the machine-readable
artifacts are never empty. ``BENCH_summary.json`` (rewritten after
*every* section, so even a hard crash leaves the completed prefix)
carries per-section ``status``/``derived``/``error``/``seconds``; any
section that should have produced a ``BENCH_*.json`` but died before
its sweep finished gets a stub file with the failure recorded.

``--obs`` additionally runs an instrumented observability pass
(``repro.obs`` — in-graph metrics fabric + span tracer) and attaches
its output as a ``metrics`` section to every ``BENCH_*.json`` written
by the run: delivery-latency histogram + bucketed p50/p95/p99, HWMs,
event counters, and the host-span rollup with the drain-overlap ratio.
List-shaped BENCH files are wrapped to ``{"rows": [...], "metrics":
{...}}`` in that mode; without ``--obs`` their schema is unchanged.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def _timed(name, fn):
    t0 = time.time()
    derived = fn()
    us = (time.time() - t0) * 1e6
    print(f"{name},{us:.0f},{derived}")
    return derived


def _dump_json(path, rows):
    """Machine-readable perf trajectory (BENCH_*.json next to the run)."""
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    print(f"# wrote {path}")


def fig8():
    from benchmarks import fig8_scalability as m
    rs = m.rows()
    m.main()
    geo19 = [r for r in rs if r["n"] == 19 and r["net"] == "geo"][0]
    return f"picsou_vs_ata_geo_n19={geo19['ratio']:.1f}x(paper 24x)"


def fig9():
    from benchmarks import fig9_failures_stakes as m
    m.main()
    rows = m.stake_scenarios()
    unfair = [r for r in rows if r["scenario"] == "unfair"][0]
    return f"unfair_drop={1 - unfair['vs_equal']:.0%}(paper 87%)"


def fig10():
    from benchmarks import fig10_heterogeneous as m
    rs = m.rows()
    m.main()
    worst = max(r["overhead_frac"] for r in rs)
    return f"worst_overhead={worst:.1%}(paper <15%)"


def thm1():
    from benchmarks import bench_retransmit as m
    m.main()
    curve = m.delivery_probability_curve(max_retries=8)
    return f"p_delivery_8_retries={curve[-1]['p_delivery']:.4f}(paper 99.9%)"


def kernels():
    from benchmarks import bench_kernels as m
    m.main()
    return "interpret-mode (see EXPERIMENTS.md roofline for TPU story)"


def windowed():
    from benchmarks import bench_windowed as m
    rs = m.main()
    _dump_json("BENCH_windowed.json", rs)
    big = [r for r in rs if r.get("path") == "windowed"][-1]
    dense_big = [r for r in rs if r.get("path") == "dense"
                 and r["n_msgs"] == big["n_msgs"]][0]
    ratio = dense_big["state_bytes"] / max(big["state_bytes"], 1)
    return (f"state@{big['n_msgs']}={big['state_bytes']}B"
            f"(const,W={big['window_slots']}),dense/windowed_state="
            f"{ratio:.1f}x")


def pipeline():
    from benchmarks import bench_pipeline as m
    rs = m.main(json_path="BENCH_pipeline.json")
    singles = [r for r in rs if r["batch"] == 1]
    big_m = max(r["n_msgs"] for r in singles)
    best = max((r for r in singles if r["n_msgs"] == big_m),
               key=lambda r: r["k"])
    sync = [r for r in singles
            if r["n_msgs"] == big_m and r["k"] == 1][0]
    return (f"K={best['k']}@{big_m}="
            f"{best.get('speedup_vs_sync', 1.0):.2f}x_warm,"
            f"dispatches{sync['dispatches']}->{best['dispatches']},"
            f"syncs{sync['host_syncs']}->{best['host_syncs']}")


def topology():
    from benchmarks import bench_topology as m
    rs = m.main(json_path="BENCH_topology.json")
    fan = [r for r in rs if r["section"] == "fanout"
           and r["scenario"] == "none"]
    big = max(fan, key=lambda r: (r["links"], r["n_msgs"]))
    chain = [r for r in rs if r["section"] == "chain"]
    lag = chain[-1]["pipeline_lag_rounds"] if chain else "n/a"
    return (f"{big['links']}links@{big['n_msgs']}msgs_warm="
            f"{big['warm_s']:.2f}s,chain_lag={lag}rounds")


def stream():
    from benchmarks import bench_stream as m
    rs = m.main(json_path="BENCH_stream.json")
    cal = [r for r in rs if r.get("utilization")]
    if cal:
        best = max(cal, key=lambda r: r["utilization"])
        return (f"sustained={best['sustained_frac']:.0%}_of_capacity"
                f"@u={best['utilization']:.2f},fleet={best['fleet']},"
                f"p99={best['p99']}")
    big = max(rs, key=lambda r: r["horizon"])
    return f"sustained={big['sustained_frac']:.0%}_of_capacity"


def replay():
    from benchmarks import bench_replay as m
    rs = m.main(json_path="BENCH_replay.json")
    fk = [r for r in rs if r["section"] == "forks"]
    big = max(fk, key=lambda r: (r["forks"], r["n_msgs"]))
    rec = [r for r in rs if r["section"] == "record"][-1]
    return (f"{big['forks']}forks@{big['n_msgs']}msgs_warm="
            f"{big['warm_s']:.2f}s({big['warm_s_per_fork']:.3f}s/fork,"
            f"{big['chunk_traces_warm']}recompiles),record_overhead="
            f"{rec['record_overhead']:.0%}")


def adversary():
    from benchmarks import bench_adversary as m
    rs = m.main(json_path="BENCH_adversary.json")
    pal = [r for r in rs if r["section"] == "palette"
           and r["kind"] != "honest"]
    big_m = max(r["n_msgs"] for r in pal)
    extra = sum(r["extra_traces"] for r in rs)
    worst = max((r for r in pal if r["n_msgs"] == big_m),
                key=lambda r: r["resends"])
    rec = [r for r in rs if r["section"] == "reconfig"][-1]
    return (f"palette{len({r['kind'] for r in pal})}@{big_m},"
            f"worst={worst['kind']}({worst['resends']}resends),"
            f"reconfig_warm={rec['warm_s']:.2f}s,extra_traces={extra}")


def crosspod():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
    env.setdefault("PYTHONPATH", "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_crosspod"],
        env=env, capture_output=True, text=True, timeout=900)
    print(out.stdout, end="")
    if out.returncode != 0:
        print(out.stderr[-1000:])
        return "FAILED"
    lines = [l for l in out.stdout.splitlines() if l.startswith("picsou,")]
    return f"dcn_reduction={lines[-1].split(',')[-1]}x" if lines else "n/a"


# section name -> (harness fn, BENCH json the sweep is expected to emit)
TABLES = (("fig8_scalability", fig8, None),
          ("fig9_failures_stakes", fig9, None),
          ("fig10_heterogeneous", fig10, None),
          ("thm1_retransmit", thm1, None),
          ("windowed_sim", windowed, "BENCH_windowed.json"),
          ("pipeline", pipeline, "BENCH_pipeline.json"),
          ("topology_apps", topology, "BENCH_topology.json"),
          ("replay_whatif", replay, "BENCH_replay.json"),
          ("stream", stream, "BENCH_stream.json"),
          ("adversary", adversary, "BENCH_adversary.json"),
          ("kernels", kernels, None),
          ("crosspod_collectives", crosspod, None))

# regression gate knobs for --compare: a section regresses when its wall
# time grows by more than REGRESSION_FRAC over the prior summary AND the
# absolute growth clears REGRESSION_FLOOR_S (sub-second jitter on tiny
# sections is not a regression)
REGRESSION_FRAC = 0.15
REGRESSION_FLOOR_S = 1.0


def compare_summaries(prev: dict, cur: dict,
                      frac: float = REGRESSION_FRAC,
                      floor_s: float = REGRESSION_FLOOR_S):
    """Diff two BENCH_summary.json documents section-by-section.

    Returns ``(lines, regressions)`` — a printable report over every
    section present in both summaries (wall-time delta + derived-metric
    change), and the subset of lines that constitute wall-time
    regressions (> ``frac`` slower AND > ``floor_s`` absolute growth,
    ok-status sections only). New/removed sections are reported but are
    never regressions.
    """
    pv = {s["name"]: s for s in prev.get("sections", ())}
    cv = {s["name"]: s for s in cur.get("sections", ())}
    lines, regressions = [], []
    for name, c in cv.items():
        p = pv.get(name)
        if p is None:
            lines.append(f"  {name}: new section "
                         f"({c.get('seconds', 0):.2f}s)")
            continue
        ps, cs = float(p.get("seconds", 0)), float(c.get("seconds", 0))
        delta = cs - ps
        ratio = (cs / ps - 1.0) if ps > 0 else 0.0
        line = f"  {name}: {ps:.2f}s -> {cs:.2f}s ({ratio:+.0%})"
        if p.get("derived") != c.get("derived"):
            line += f"; derived {p.get('derived')} -> {c.get('derived')}"
        if (p.get("status"), c.get("status")) != ("ok", "ok"):
            line += (f"; status {p.get('status')} -> {c.get('status')}")
        elif ratio > frac and delta > floor_s:
            line += "  ** REGRESSION"
            regressions.append(line)
        lines.append(line)
    for name in pv.keys() - cv.keys():
        lines.append(f"  {name}: section missing from current run")
    return lines, regressions


def obs_metrics_section(n_msgs: int = 4096, k: int = 8) -> dict:
    """One instrumented observability run (``repro.obs``) as a JSON
    section: device latency histogram + percentiles and the host span
    rollup, so every BENCH artifact carries measured distributions next
    to its headline ratios."""
    from repro.core.simulator import build_spec
    from repro.core.types import RSMConfig, SimConfig
    from repro.obs.report import run_reported
    sim = SimConfig(n_msgs=n_msgs, steps=n_msgs // 4 + 96, window=4,
                    phi=6, window_slots="auto", chunk_steps=32,
                    superchunk=k, collect_metrics=True)
    spec = build_spec(RSMConfig.bft(1), RSMConfig.bft(1), sim)
    _, report = run_reported(spec)
    problems = report.validate()
    span = report.spans
    return {
        "shape": {"n_msgs": n_msgs, "superchunk": k,
                  "window_slots": report.meta["window_slots"]},
        "obs": report.obs["link"].to_dict(),
        "drain_overlap_ratio": span["drain_overlap_ratio"],
        "no_drains": span.get("no_drains", False),
        "span_totals_ms": _span_totals_ms(span),
        "dispatches": report.meta["chunk_dispatches"],
        "validated": not problems,
        "problems": problems,
    }


def _span_totals_ms(span_dict: dict) -> dict:
    totals: dict = {}
    for s in span_dict.get("spans", ()):
        totals[s["name"]] = totals.get(s["name"], 0.0) + s["dur_ns"] / 1e6
    return {k: round(v, 3) for k, v in sorted(totals.items())}


def _attach_metrics(path: str, metrics: dict) -> None:
    """Add a ``metrics`` section to one BENCH json (wrapping row lists)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        doc = []
    if isinstance(doc, list):
        doc = {"rows": doc}
    doc["metrics"] = metrics
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    print(f"# attached metrics section to {path}")


def _write_stub(path: str, section: str, error: str) -> None:
    """Failed sweeps still leave a (status-carrying) BENCH artifact."""
    _dump_json(path, {"rows": [], "section": section,
                      "status": "failed", "error": error})


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.run")
    ap.add_argument("--obs", action="store_true",
                    help="run an instrumented repro.obs pass and attach "
                         "a metrics section to every BENCH_*.json")
    ap.add_argument("--only", default=None,
                    help="comma-separated section names to run")
    ap.add_argument("--summary-json", default="BENCH_summary.json")
    ap.add_argument("--compare", default=None, metavar="PREV_summary.json",
                    help="after the run, diff the fresh summary against "
                         "this prior BENCH_summary.json and exit nonzero "
                         "on a >15%% warm wall-time regression in any "
                         "section (small absolute deltas are ignored)")
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    tables = [t for t in TABLES if only is None or t[0] in only]
    if only:
        unknown = only - {t[0] for t in TABLES}
        if unknown:
            ap.error(f"unknown sections: {sorted(unknown)}; "
                     f"have {[t[0] for t in TABLES]}")

    print("== PICSOU / C3B benchmark suite ==")
    summary = []

    def flush_summary():
        _dump_json(args.summary_json,
                   {"status": ("ok" if all(s["status"] == "ok"
                                           for s in summary) else "partial"),
                    "sections": summary})

    for name, fn, bench_json in tables:
        print(f"\n### {name}")
        t0 = time.time()
        entry = {"name": name, "status": "ok", "error": None}
        try:
            entry["derived"] = fn()
        except Exception as e:  # noqa: BLE001
            entry.update(status="failed", derived=f"FAILED:{type(e).__name__}",
                         error=f"{type(e).__name__}: {e}")
            if bench_json and not os.path.exists(bench_json):
                _write_stub(bench_json, name, entry["error"])
        entry["seconds"] = round(time.time() - t0, 3)
        summary.append(entry)
        flush_summary()   # crash-safe: completed prefix always on disk

    if args.obs:
        print("\n### obs (instrumented metrics pass)")
        t0 = time.time()
        try:
            metrics = obs_metrics_section()
        except Exception as e:  # noqa: BLE001
            metrics = {"validated": False,
                       "problems": [f"{type(e).__name__}: {e}"]}
        for _, _, bench_json in tables:
            if bench_json and os.path.exists(bench_json):
                _attach_metrics(bench_json, metrics)
        summary.append({"name": "obs", "error": None,
                        "seconds": round(time.time() - t0, 3),
                        "status": "ok" if metrics.get("validated")
                        else "failed",
                        "derived": f"drain_overlap="
                        f"{metrics.get('drain_overlap_ratio', 0):.3f}"})
        flush_summary()

    print("\n== summary (name,us_per_call,derived) ==")
    for s in summary:
        print(f"{s['name']},{s['seconds'] * 1e6:.0f},{s['derived']}")

    rc = 0 if all(s["status"] == "ok" for s in summary) else 1
    if args.compare:
        print(f"\n== compare vs {args.compare} ==")
        try:
            with open(args.compare) as f:
                prev = json.load(f)
        except (OSError, ValueError) as e:
            print(f"  (no usable baseline: {e})")
            return rc
        lines, regressions = compare_summaries(
            prev, {"sections": summary})
        for line in lines:
            print(line)
        if regressions:
            print(f"\n{len(regressions)} wall-time regression(s) "
                  f"(>{REGRESSION_FRAC:.0%} and "
                  f">{REGRESSION_FLOOR_S:.0f}s slower)")
            rc = rc or 2
        else:
            print("no wall-time regressions")
    return rc


if __name__ == "__main__":
    sys.exit(main())
