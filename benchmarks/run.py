"""Benchmark harness — one function per paper table/figure.

Prints each figure's detailed CSV block, then a summary line per table in
``name,us_per_call,derived`` form (us_per_call = wall time of the harness
function; derived = the table's headline number).

  PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time


def _timed(name, fn):
    t0 = time.time()
    derived = fn()
    us = (time.time() - t0) * 1e6
    print(f"{name},{us:.0f},{derived}")
    return derived


def _dump_json(path, rows):
    """Machine-readable perf trajectory (BENCH_*.json next to the run)."""
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    print(f"# wrote {path}")


def fig8():
    from benchmarks import fig8_scalability as m
    rs = m.rows()
    m.main()
    geo19 = [r for r in rs if r["n"] == 19 and r["net"] == "geo"][0]
    return f"picsou_vs_ata_geo_n19={geo19['ratio']:.1f}x(paper 24x)"


def fig9():
    from benchmarks import fig9_failures_stakes as m
    m.main()
    rows = m.stake_scenarios()
    unfair = [r for r in rows if r["scenario"] == "unfair"][0]
    return f"unfair_drop={1 - unfair['vs_equal']:.0%}(paper 87%)"


def fig10():
    from benchmarks import fig10_heterogeneous as m
    rs = m.rows()
    m.main()
    worst = max(r["overhead_frac"] for r in rs)
    return f"worst_overhead={worst:.1%}(paper <15%)"


def thm1():
    from benchmarks import bench_retransmit as m
    m.main()
    curve = m.delivery_probability_curve(max_retries=8)
    return f"p_delivery_8_retries={curve[-1]['p_delivery']:.4f}(paper 99.9%)"


def kernels():
    from benchmarks import bench_kernels as m
    m.main()
    return "interpret-mode (see EXPERIMENTS.md roofline for TPU story)"


def windowed():
    from benchmarks import bench_windowed as m
    rs = m.main()
    _dump_json("BENCH_windowed.json", rs)
    big = [r for r in rs if r.get("path") == "windowed"][-1]
    dense_big = [r for r in rs if r.get("path") == "dense"
                 and r["n_msgs"] == big["n_msgs"]][0]
    ratio = dense_big["state_bytes"] / max(big["state_bytes"], 1)
    return (f"state@{big['n_msgs']}={big['state_bytes']}B"
            f"(const,W={big['window_slots']}),dense/windowed_state="
            f"{ratio:.1f}x")


def pipeline():
    from benchmarks import bench_pipeline as m
    rs = m.main(json_path="BENCH_pipeline.json")
    singles = [r for r in rs if r["batch"] == 1]
    big_m = max(r["n_msgs"] for r in singles)
    best = max((r for r in singles if r["n_msgs"] == big_m),
               key=lambda r: r["k"])
    sync = [r for r in singles
            if r["n_msgs"] == big_m and r["k"] == 1][0]
    return (f"K={best['k']}@{big_m}="
            f"{best.get('speedup_vs_sync', 1.0):.2f}x_warm,"
            f"dispatches{sync['dispatches']}->{best['dispatches']},"
            f"syncs{sync['host_syncs']}->{best['host_syncs']}")


def topology():
    from benchmarks import bench_topology as m
    rs = m.main(json_path="BENCH_topology.json")
    fan = [r for r in rs if r["section"] == "fanout"
           and r["scenario"] == "none"]
    big = max(fan, key=lambda r: (r["links"], r["n_msgs"]))
    chain = [r for r in rs if r["section"] == "chain"]
    lag = chain[-1]["pipeline_lag_rounds"] if chain else "n/a"
    return (f"{big['links']}links@{big['n_msgs']}msgs_warm="
            f"{big['warm_s']:.2f}s,chain_lag={lag}rounds")


def replay():
    from benchmarks import bench_replay as m
    rs = m.main(json_path="BENCH_replay.json")
    fk = [r for r in rs if r["section"] == "forks"]
    big = max(fk, key=lambda r: (r["forks"], r["n_msgs"]))
    rec = [r for r in rs if r["section"] == "record"][-1]
    return (f"{big['forks']}forks@{big['n_msgs']}msgs_warm="
            f"{big['warm_s']:.2f}s({big['warm_s_per_fork']:.3f}s/fork,"
            f"{big['chunk_traces_warm']}recompiles),record_overhead="
            f"{rec['record_overhead']:.0%}")


def crosspod():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
    env.setdefault("PYTHONPATH", "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_crosspod"],
        env=env, capture_output=True, text=True, timeout=900)
    print(out.stdout, end="")
    if out.returncode != 0:
        print(out.stderr[-1000:])
        return "FAILED"
    lines = [l for l in out.stdout.splitlines() if l.startswith("picsou,")]
    return f"dcn_reduction={lines[-1].split(',')[-1]}x" if lines else "n/a"


def main() -> None:
    tables = (("fig8_scalability", fig8),
              ("fig9_failures_stakes", fig9),
              ("fig10_heterogeneous", fig10),
              ("thm1_retransmit", thm1),
              ("windowed_sim", windowed),
              ("pipeline", pipeline),
              ("topology_apps", topology),
              ("replay_whatif", replay),
              ("kernels", kernels),
              ("crosspod_collectives", crosspod))
    print("== PICSOU / C3B benchmark suite ==")
    summary = []
    for name, fn in tables:
        print(f"\n### {name}")
        t0 = time.time()
        try:
            derived = fn()
        except Exception as e:  # noqa: BLE001
            derived = f"FAILED:{type(e).__name__}"
        summary.append((name, (time.time() - t0) * 1e6, derived))
    print("\n== summary (name,us_per_call,derived) ==")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
