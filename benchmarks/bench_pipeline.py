"""Pipelined superchunk engine: dispatch/sync counts + wall time.

Sweeps fusion depth K x stream size x batch width on the windowed
simulator core. Every point's warm run executes under the analysis
sanitizer (``repro.analysis.sanitized``), which reports the
**deterministic pipeline counters** — device dispatches, host syncs,
fresh chunk tracings and implicit device->host transfers — so the ~K×
dispatch and sync reduction is asserted on counts, not timings
(``--check`` evaluates a ``DispatchContract`` per row; used by the
fast-tier CI smoke). K = 1 is the synchronous legacy loop (dispatch,
block, drain per chunk) and is the speedup baseline.

  PYTHONPATH=src python -m benchmarks.bench_pipeline
      [--sizes 16384,102400] [--ks 1,2,4,8] [--batch 4]
      [--json BENCH_pipeline.json] [--check]
"""

from __future__ import annotations

import argparse
import sys
import time

from repro.analysis import DispatchContract, SanitizerReport, sanitized
from repro.core import FailureScenario, RSMConfig, SimConfig
from repro.core.simulator import (build_spec, run_simulation,
                                  run_simulation_batch)

SIZES = (16384, 102400)
KS = (1, 2, 4, 8)
SENDER = RSMConfig.bft(1)
RECEIVER = RSMConfig.bft(1)
SEND_WINDOW = 4


def _sim(m: int, k: int) -> SimConfig:
    steps = m // (SENDER.n * SEND_WINDOW) + 60
    return SimConfig(n_msgs=m, steps=steps, window=SEND_WINDOW, phi=32,
                     window_slots="auto", chunk_steps=32, superchunk=k,
                     debug_checks=False)


def _measure(m: int, k: int, batch: int):
    sim = _sim(m, k)
    if batch <= 1:
        specs = [build_spec(SENDER, RECEIVER, sim)]
    else:
        n = SENDER.n
        fails = [FailureScenario.none()]
        fails += [FailureScenario.crash_fraction(n, n, 0.25, seed=s,
                                                 at_step=8)
                  for s in range(1, batch)]
        specs = [build_spec(SENDER, RECEIVER, sim, f) for f in fails]
    run = (lambda: run_simulation(specs[0])) if batch <= 1 else \
        (lambda: run_simulation_batch(specs))

    t0 = time.time()
    res = run()
    cold = time.time() - t0
    t0 = time.time()
    # counters + implicit-transfer interposition; the contract itself
    # is evaluated later in check(), per row against its K=1 baseline
    with sanitized(check=False) as rep:
        res = run()
    warm = time.time() - t0
    res0 = res if batch <= 1 else res[0]
    ok = bool((res0.deliver_time >= 0).all()
              and (res0.quack_time >= 0).all())
    return {
        "n_msgs": m,
        "k": k,
        "batch": batch,
        "window_slots": specs[0].window_slots or specs[0].m,
        "chunk_steps": specs[0].chunk_steps,
        "cold_s": cold,
        "warm_s": warm,
        "dispatches": rep.dispatches,
        "host_syncs": rep.host_syncs,
        "warm_traces": rep.recompiles,
        "implicit_transfers": list(rep.transfers),
        "complete": ok,
    }


def rows(sizes=SIZES, ks=KS, batch: int = 4):
    out = []
    for m in sizes:
        for k in ks:
            out.append(_measure(m, k, 1))
    if batch > 1:
        mb = min(max(sizes), 16384)
        for k in ks:
            out.append(_measure(mb, k, batch))
    # speedup + shrink ratios vs the K=1 row of the same (size, batch)
    base = {(r["n_msgs"], r["batch"]): r for r in out if r["k"] == 1}
    for r in out:
        b = base.get((r["n_msgs"], r["batch"]))
        if b is not None and b["warm_s"] > 0:
            r["speedup_vs_sync"] = b["warm_s"] / max(r["warm_s"], 1e-9)
            r["dispatch_shrink"] = (b["dispatches"]
                                    / max(r["dispatches"], 1))
    return out


def check(rs) -> bool:
    """The CI contract, via the analysis sanitizer's declarative form:
    each row is replayed into a :class:`SanitizerReport` and judged
    against a :class:`DispatchContract` derived from its own K = 1
    baseline — at most ceil(sync/K) + 3 dispatches (one slack above the
    engine contract, for adaptive-growth rewinds inside fused spans),
    syncs <= dispatches + 2, zero warm retraces, zero implicit
    device->host transfers."""
    ok = True
    base = {(r["n_msgs"], r["batch"]): r for r in rs if r["k"] == 1}
    for r in rs:
        b = base[(r["n_msgs"], r["batch"])]
        contract = DispatchContract(
            max_dispatches=-(-b["dispatches"] // r["k"]) + 3,
            max_recompiles=0, max_transfers=0, sync_slack=2,
            label=f"K={r['k']} @ {r['n_msgs']} (batch {r['batch']})")
        rep = SanitizerReport(
            contract=contract, dispatches=r["dispatches"],
            host_syncs=r["host_syncs"], recompiles=r["warm_traces"],
            transfers=tuple(r.get("implicit_transfers", ())),
            closed=True)
        for v in rep.violations():
            print(f"CHECK FAILED: {contract.label}: {v}")
            ok = False
        if not r["complete"]:
            print(f"CHECK FAILED: {contract.label}: incomplete")
            ok = False
    return ok


def main(sizes=SIZES, ks=KS, batch: int = 4, json_path=None,
         run_check: bool = False):
    rs = rows(sizes, ks, batch)
    print("# pipelined superchunk engine (BFT1<->BFT1, window=4, "
          "chunk=32; K=1 == synchronous loop)")
    print("n_msgs,batch,k,window_slots,dispatches,host_syncs,"
          "warm_traces,cold_s,warm_s,speedup_vs_sync,complete")
    for r in rs:
        print(f"{r['n_msgs']},{r['batch']},{r['k']},{r['window_slots']},"
              f"{r['dispatches']},{r['host_syncs']},{r['warm_traces']},"
              f"{r['cold_s']:.2f},{r['warm_s']:.2f},"
              f"{r.get('speedup_vs_sync', 1.0):.2f},{r['complete']}")
    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(rs, f, indent=1, default=float)
        print(f"# wrote {json_path}")
    if run_check and not check(rs):
        sys.exit(1)
    return rs


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated n_msgs sweep (default "
                         "16384,102400); tiny values make a CI smoke")
    ap.add_argument("--ks", type=str, default=None,
                    help="comma-separated superchunk depths (default "
                         "1,2,4,8; 1 = synchronous baseline)")
    ap.add_argument("--batch", type=int, default=4,
                    help="scenarios in the batched section (<=1 "
                         "disables it)")
    ap.add_argument("--json", type=str, default=None,
                    help="write machine-readable rows to this path")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless the dispatch/sync counters "
                         "shrink ~K x (the CI contract; no wall-time "
                         "assertions)")
    args = ap.parse_args()
    sizes = (tuple(int(s) for s in args.sizes.split(","))
             if args.sizes else SIZES)
    ks = tuple(int(s) for s in args.ks.split(",")) if args.ks else KS
    if 1 not in ks:
        ks = (1,) + ks
    main(sizes, ks, args.batch, args.json, args.check)
