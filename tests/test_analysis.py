"""repro.analysis: AST linter rules, jaxpr auditor, runtime sanitizer.

Each AST rule gets a minimal fixture snippet that triggers *exactly one*
finding (and a twin suppressed with ``# analysis: ignore[rule]``); the
jaxpr auditor is run over a tiny windowed config and must certify the
engine's superchunk program free of host callbacks (with a seeded
``debug_callback`` as the positive control); the sanitizer enforces the
dispatch contract ``<= ceil(C/K) + 2`` with zero implicit transfers at
K = 8 and zero recompilations on a warm replay resume.
"""

import dataclasses
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (DispatchContract, SanitizerError, dispatch_bound,
                            dispatch_contract, estimate_dispatches,
                            lint_source, sanitized)
from repro.analysis.astlint import load_baseline, partition
from repro.analysis.jaxprlint import audit_callable, audit_engine
from repro.core import RSMConfig, SimConfig
from repro.core.simulator import build_spec, run_simulation

BFT1 = RSMConfig.bft(1)


def _one(src: str, rule: str):
    """Lint a fixture and assert exactly one finding of ``rule``."""
    findings = lint_source(textwrap.dedent(src), path="fixture.py")
    assert [f.rule for f in findings] == [rule], findings
    return findings[0]


def _none(src: str):
    findings = lint_source(textwrap.dedent(src), path="fixture.py")
    assert findings == [], findings


# --- astlint: one fixture per rule, positive + suppressed ----------------

SEEDED_ITEM_IN_SCAN = """
    import jax

    def _build(spec):
        def step(carry, x):
            v = carry + x
            bad = v.item(){SUPPRESS}
            return carry, bad

        def run(xs):
            return jax.lax.scan(step, 0, xs)

        return run
"""


def test_rule_host_sync_item_in_scan_body():
    """The acceptance seed: a ``.item()`` inside a scan body is found,
    named, and carries the fix-it hint."""
    f = _one(SEEDED_ITEM_IN_SCAN.format(SUPPRESS=""), "host-sync")
    assert f.symbol == "_build.step"
    assert ".item()" in f.message
    assert "drain" in f.hint
    assert f.fingerprint() == "host-sync::fixture.py::_build.step"


def test_rule_host_sync_suppressed_inline():
    _none(SEEDED_ITEM_IN_SCAN.format(
        SUPPRESS="  # analysis: ignore[host-sync]"))


def test_rule_host_sync_np_asarray_and_device_get():
    f = _one("""
        import jax, numpy as np

        @jax.jit
        def f(x):
            return np.asarray(x) + 1
    """, "host-sync")
    assert "np.asarray" in f.message
    f = _one("""
        import jax

        @jax.jit
        def f(x):
            return jax.device_get(x)
    """, "host-sync")
    assert "device_get" in f.message


def test_rule_tracer_branch():
    src = """
        import jax

        @jax.jit
        def f(x):
            y = x + 1
            {LINE}
                y = y * 2
            return y
    """
    f = _one(src.format(LINE="if y > 0:"), "tracer-branch")
    assert "lax.cond" in f.message
    _none(src.format(LINE="if y > 0:  # analysis: ignore[tracer-branch]"))
    # static config dispatch (string compare) is not flagged
    _none("""
        import jax

        @jax.jit
        def f(x, kind):
            if kind == "rwkv":
                return x * 2
            return x
    """)
    # jit static_argnames are static at trace time
    _none("""
        import jax

        @jax.jit(static_argnames=("n",))
        def f(x, n):
            if n > 4:
                return x * 2
            return x
    """)


def test_rule_import_time_jnp():
    f = _one("""
        import jax.numpy as jnp

        BIG = jnp.int32(2 ** 30)
    """, "import-time-jnp")
    assert "import time" in f.message
    _none("""
        import jax.numpy as jnp

        BIG = 2 ** 30

        def f():
            return jnp.int32(BIG)
    """)


def test_rule_missing_donate():
    src = """
        import jax

        def _build(spec):
            def step(carry, x):
                return carry + x, x

            def run(state, xs):
                return jax.lax.scan(step, state, xs)

            return run

        def compiled(spec):
            return jax.jit(_build(spec){DONATE})
    """
    f = _one(src.format(DONATE=""), "missing-donate")
    assert "donate_argnums" in f.message
    assert f.symbol.startswith("compiled->")
    _none(src.format(DONATE=", donate_argnums=(0,)"))


def test_rule_pytree_fields():
    f = _one("""
        import dataclasses
        import jax.numpy as jnp

        @dataclasses.dataclass(frozen=True)
        class Spec:
            steps: int
            masks: jnp.ndarray
    """, "pytree-fields")
    assert "Spec.masks" in f.symbol
    _none("""
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class Spec:
            steps: int
            masks: tuple
    """)


def test_repo_tree_is_clean_modulo_baseline():
    """The gate invariant CI enforces: zero unbaselined findings on the
    tree, and no stale baseline entries."""
    from repro.analysis.astlint import lint_tree
    root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    baseline_path = os.path.join(os.path.dirname(__file__), "..",
                                 "ANALYSIS_BASELINE.txt")
    findings = lint_tree(os.path.relpath(root))
    baseline = load_baseline(os.path.relpath(baseline_path))
    new, old = partition(findings, baseline)
    assert new == [], [f.render() for f in new]
    live = {f.fingerprint() for f in findings}
    assert baseline <= live, f"stale baseline entries: {baseline - live}"


# --- jaxprlint -----------------------------------------------------------

def test_audit_engine_superchunk_free_of_host_callbacks():
    """The engine's actual compiled programs — dense, chunk, final
    chunk, K=8 superchunk — contain zero host callbacks, zero dtype
    widenings, at the jaxpr AND lowered-module level."""
    report = audit_engine(m=48, window_slots=16, chunk_steps=4,
                          superchunk=4)
    assert report["ok"], report["violations"]
    names = {p["name"] for p in report["programs"]}
    assert {"dense", "chunk", "chunk_final", "superchunk"} <= names
    sc = next(p for p in report["programs"] if p["name"] == "superchunk")
    assert sc["host_callbacks"] == [] or sc["host_callbacks"] == ()
    assert sc["lowered_callback_calls"] == 0
    assert "scan" in sc["primitives"]


def test_audit_callable_detects_seeded_callback():
    """Positive control: a debug_callback smuggled into a scan body is
    reported (so the zero-callback certification is falsifiable)."""
    def leaky(xs):
        def step(c, x):
            jax.debug.callback(lambda v: None, x)
            return c + x, x
        return jax.lax.scan(step, jnp.int32(0), xs)

    audit = audit_callable(leaky, (jnp.arange(4, dtype=jnp.int32),),
                           "leaky")
    assert not audit.ok
    assert "debug_callback" in audit.host_callbacks
    assert any("debug_callback" in v for v in audit.violations())


def test_audit_callable_detects_widening():
    def widens(x):
        return x.astype(jnp.float64) if jax.config.jax_enable_x64 \
            else x.astype(jnp.int32) + jnp.int32(1)

    # x64 disabled (repo default): int32 math stays clean
    clean = audit_callable(widens, (jnp.arange(3, dtype=jnp.int32),),
                           "clean")
    assert clean.ok


def test_estimate_matches_engine_span_arithmetic():
    # 42 full chunks at K=8: 5 spans of 8 + tail — measured 7 on the
    # real engine (test below keeps them honest against each other)
    assert estimate_dispatches(168, 4, 8) == 7
    assert estimate_dispatches(168, 4, 1) == 42
    assert estimate_dispatches(40, 4, 8) == 3
    assert estimate_dispatches(124, 32, 8) == 2
    for steps, c, k in [(168, 4, 8), (40, 4, 2), (200, 8, 4)]:
        n_chunks = -(-steps // c)
        assert estimate_dispatches(steps, c, k) <= dispatch_bound(
            steps, c, k), (steps, c, k)
        assert estimate_dispatches(steps, c, 1) == n_chunks


# --- sanitizer -----------------------------------------------------------

def _spec(k: int, **over):
    kw = dict(n_msgs=128, steps=128 // 4 + 40, window=1, phi=6,
              window_slots=64, chunk_steps=4, superchunk=k,
              debug_checks=True)
    kw.update(over)
    return build_spec(BFT1, BFT1, SimConfig(**kw))


def test_sanitizer_dispatch_contract_k8():
    """The acceptance contract: a K = 8 run fits ceil(C/K) + 2
    dispatches with zero implicit device->host transfers, measured
    under SimConfig.debug_checks (engine guard nested inside)."""
    spec = _spec(8)
    run_simulation(spec)                        # warm
    with sanitized(dispatch_contract(spec, warm=True)) as rep:
        run_simulation(spec)
    n_chunks = -(-spec.steps // spec.chunk_steps)
    assert rep.dispatches <= -(-n_chunks // 8) + 2
    assert rep.transfers == ()
    assert rep.recompiles == 0
    assert rep.host_syncs <= rep.dispatches + 2


def test_sanitizer_warm_replay_resume_zero_recompiles():
    """Replay resume under the sanitizer: zero fresh tracings, zero
    implicit transfers — the recorded parent compiled every program the
    resumed tail reuses."""
    from repro.replay import record_simulation, replay

    spec = _spec(8, n_msgs=96, steps=120, window_slots=24, chunk_steps=8)
    r0, trace = record_simulation(spec, every=2)
    mid = trace.boundaries()[len(trace.boundaries()) // 2]
    contract = DispatchContract(max_recompiles=0, max_transfers=0,
                                sync_slack=2, label="replay resume")
    with sanitized(contract) as rep:
        replayed = replay(trace, int(mid))[0]
    assert rep.recompiles == 0
    assert rep.transfers == ()
    assert np.array_equal(replayed.deliver_time, r0.deliver_time)


def test_sanitizer_flags_implicit_transfer():
    x = jnp.arange(8)
    with pytest.raises(SanitizerError, match="implicit device->host"):
        with sanitized(DispatchContract(max_transfers=0)):
            np.asarray(x)
    # the sanctioned route stays silent
    with sanitized(DispatchContract(max_transfers=0)) as rep:
        jax.device_get(x)
    assert rep.transfers == ()
    # host->host numpy conversions are not transfers
    with sanitized(DispatchContract(max_transfers=0)) as rep:
        np.asarray([1, 2, 3])
    assert rep.transfers == ()


def test_sanitizer_contract_violation_message_names_ceiling():
    spec = _spec(1, n_msgs=32, steps=24, window_slots=32)
    run_simulation(spec)
    tight = DispatchContract(max_dispatches=1, label="tight")
    with pytest.raises(SanitizerError, match="dispatches > contract 1"):
        with sanitized(tight):
            run_simulation(spec)


def test_engine_guard_behind_debug_checks():
    """debug_checks wires the engine guard: results identical, and the
    guard composes with an outer sanitized() (both see the counters)."""
    spec = _spec(4)
    off = dataclasses.replace(spec, debug_checks=False)
    a, b = run_simulation(spec), run_simulation(off)
    assert np.array_equal(a.deliver_time, b.deliver_time)
    with sanitized(dispatch_contract(spec, warm=True)) as rep:
        run_simulation(spec)
    assert rep.dispatches > 0 and rep.transfers == ()


def test_engine_guard_catches_seeded_transfer():
    from repro.analysis.sanitizer import engine_guard
    x = jnp.arange(4)
    with pytest.raises(SanitizerError, match="implicit device->host"):
        with engine_guard():
            np.asarray(x)


def test_dispatch_bound_shapes():
    assert dispatch_bound(168, 4, 8) == -(-42 // 8) + 2
    assert dispatch_bound(168, 4, 1) == 44
    assert dispatch_bound(40, 0, 8) == 3        # dense: one dispatch
    assert dispatch_bound(1, 4, 8) == 3


# --- CLI gate ------------------------------------------------------------

def test_cli_check_passes_on_tree():
    """`python -m repro.analysis --check --skip-engine` exits 0 on the
    repo (the engine passes run in their own tests above)."""
    from repro.analysis.__main__ import main
    root = os.path.relpath(
        os.path.join(os.path.dirname(__file__), "..", "src", "repro"))
    base = os.path.relpath(
        os.path.join(os.path.dirname(__file__), "..",
                     "ANALYSIS_BASELINE.txt"))
    assert main(["--check", "--skip-engine", "--root", root,
                 "--baseline", base]) == 0


def test_cli_check_fails_on_seeded_violation(tmp_path, capsys):
    """The documented gate failure: an unbaselined `.item()`-in-scan
    violation seeded into a tree makes `--check` exit 1 and print the
    finding with its hint."""
    bad = tmp_path / "seeded.py"
    bad.write_text(textwrap.dedent(SEEDED_ITEM_IN_SCAN.format(SUPPRESS="")))
    from repro.analysis.__main__ import main
    rc = main(["--check", "--skip-engine", "--root", str(tmp_path),
               "--baseline", str(tmp_path / "NO_BASELINE.txt")])
    assert rc == 1
    out = capsys.readouterr().out
    assert "[host-sync]" in out and "hint:" in out
    # baselining the fingerprint turns the same tree green
    fp = f"host-sync::{os.path.relpath(bad)}::_build.step"
    (tmp_path / "BASE.txt").write_text(fp + "\n")
    rc = main(["--check", "--skip-engine", "--root", str(tmp_path),
               "--baseline", str(tmp_path / "BASE.txt")])
    assert rc == 0
