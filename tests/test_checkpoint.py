"""Checkpoint substrate tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step, restore_tree,
                              save_tree)


def _tree(seed=0):
    rng = jax.random.PRNGKey(seed)
    return {"layer": {"w": jax.random.normal(rng, (8, 16)),
                      "b": jnp.zeros((16,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_tree(t, str(tmp_path), step=3, n_shards=3)
    out, step = restore_tree(t, str(tmp_path))
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checksum_verification(tmp_path):
    t = _tree()
    save_tree(t, str(tmp_path), step=1, n_shards=2)
    victim = os.path.join(str(tmp_path), "step_00000001",
                          "shard_0000.npz")
    with open(victim, "r+b") as f:
        f.seek(30)
        f.write(b"\x00\x01\x02")
    with pytest.raises(IOError):
        restore_tree(t, str(tmp_path))


def test_latest_step_and_gc(tmp_path):
    t = _tree()
    mgr = CheckpointManager(str(tmp_path), n_shards=2, keep=2)
    for s in (1, 5, 9):
        mgr.save_async(s, t)
    mgr.wait()
    mgr.close()
    assert latest_step(str(tmp_path)) == 9
    kept = sorted(os.listdir(str(tmp_path)))
    assert len([k for k in kept if k.startswith("step_")]) == 2


def test_async_replication_summary(tmp_path):
    mgr = CheckpointManager(str(tmp_path), n_shards=4, peer_hosts=4, u=1)
    mgr.save_async(2, _tree())
    mgr.wait()
    res = mgr.result(2)
    mgr.close()
    assert res is not None
    assert res["replication"]["durable_frac"] == 1.0
