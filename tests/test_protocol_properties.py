"""Property-based tests (hypothesis) for the C3B invariants (§2.2).

Random RSM sizes, stake vectors, failure placements (within the UpRight
model: <= u failures of any kind, <= r of them byzantine) must preserve:

* Eventual delivery — every transmitted message reaches >= 1 correct
  replica of the receiver RSM;
* Integrity-adjacent invariant — a QUACK forms only when replicas holding
  >= u_r+1 stake have claimed the prefix (so >= 1 honest holder exists);
* Lemma 1 — no message needs more than u_s + u_r + 1 retransmissions;
* GC safety — the quacked prefix at any honest sender only grows.

The strategy includes GC-stalling adversaries (the §4.3 partial-broadcast
attack), so the windowed ≡ dense property below covers frontier-pinning
scenarios. This module needs hypothesis (CI installs it and asserts it is
importable); a hypothesis-free seeded twin of the windowed ≡ dense
property lives in ``tests/test_windowed.py`` so the invariant executes
even where hypothesis is unavailable.
"""

import dataclasses

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import FailureScenario, RSMConfig, SimConfig  # noqa: E402
from repro.core.refsim import run_reference  # noqa: E402
from repro.core.simulator import build_spec, run_simulation  # noqa: E402


@st.composite
def rsm_pair_with_failures(draw):
    f_s = draw(st.integers(0, 1))
    f_r = draw(st.integers(0, 1))
    sender = RSMConfig.bft(max(f_s, 1))
    receiver = RSMConfig.bft(max(f_r, 1))
    # place at most u failures per side, at most r byzantine; GC-stalling
    # kinds (the §4.3 partial-broadcast attack) included so the windowed
    # properties below cover frontier-pinning adversaries.
    crash_s = [-1] * sender.n
    byz_recv = [False] * receiver.n
    byz_partial = [False] * receiver.n
    crash_r = [-1] * receiver.n
    n_fail_s = draw(st.integers(0, sender.u))
    n_fail_r = draw(st.integers(0, receiver.u))
    for i in draw(st.permutations(range(sender.n)))[:n_fail_s]:
        crash_s[i] = draw(st.integers(0, 8))
    kinds = draw(st.lists(
        st.sampled_from(["crash", "byz_drop", "bcast_partial"]),
        min_size=n_fail_r, max_size=n_fail_r))
    targets = draw(st.permutations(range(receiver.n)))[:n_fail_r]
    for i, kind in zip(targets, kinds):
        if kind == "crash":
            crash_r[i] = draw(st.integers(0, 8))
        elif kind == "bcast_partial":
            byz_partial[i] = True
        else:
            byz_recv[i] = True
    fails = FailureScenario(crash_s=tuple(crash_s), crash_r=tuple(crash_r),
                            byz_recv_drop=tuple(byz_recv),
                            byz_bcast_partial=tuple(byz_partial),
                            bcast_limit=draw(st.integers(1, 2)))
    return sender, receiver, fails


@settings(max_examples=15, deadline=None)
@given(rsm_pair_with_failures(), st.integers(0, 3))
def test_eventual_delivery_and_lemma1(pair, seed):
    sender, receiver, fails = pair
    sim = SimConfig(n_msgs=12, steps=260, window=1, phi=6, seed=seed)
    spec = build_spec(sender, receiver, sim, fails)
    res = run_simulation(spec)
    # Eventual delivery: every message reaches a correct receiver replica
    assert (res.deliver_time >= 0).all(), res.deliver_time
    # Lemma 1: retransmissions bounded by u_s + u_r + 1
    honest_s = (np.asarray(spec.crash_s) < 0)
    bound = sender.u + receiver.u + 1
    assert res.retry[honest_s].max() <= bound
    # GC safety: quacked prefix is monotone over rounds
    mq = np.asarray(res.metrics.min_quack_prefix)
    assert (np.diff(mq) >= 0).all()


@settings(max_examples=8, deadline=None)
@given(rsm_pair_with_failures(), st.integers(0, 3))
def test_gc_frontier_never_retires_unquacked(pair, seed):
    """Sliding-window GC safety (§4.3): the frontier only ever retires a
    slot that is QUACKed at *every* sender (so stake >= u_r + 1 claimed
    it), and retiring it is invisible — the windowed run reproduces the
    dense run bit-for-bit and the oracle's retirement snapshots never
    change after the fact (asserted inside ``run_reference``)."""
    sender, receiver, fails = pair
    sim = SimConfig(n_msgs=12, steps=140, window=1, phi=6, seed=seed,
                    window_slots=12, chunk_steps=8)
    spec = build_spec(sender, receiver, sim, fails)
    res_w = run_simulation(spec)
    res_d = run_simulation(dataclasses.replace(spec, window_slots=0,
                                               chunk_steps=0))
    for name in ("quack_time", "deliver_time", "retry", "recv_has"):
        assert np.array_equal(getattr(res_w, name), getattr(res_d, name))
    ref = run_reference(spec)        # snapshot-asserts retirement safety
    assert np.array_equal(ref.gc_frontiers, res_w.gc_frontiers)
    assert (np.diff(res_w.gc_frontiers) >= 0).all()
    if ref.gc_frontiers[-1] > 0:
        assert ref.retired_quack_margin >= spec.quack_thresh


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 8), st.integers(1, 50), st.integers(0, 3))
def test_quack_quorum_has_honest_holder(n, stake_scale, seed):
    """Whenever a QUACK forms, replicas totalling >= u+1 stake claimed the
    prefix — with <= u faulty stake, at least one claimant is honest."""
    rng = np.random.RandomState(seed)
    stakes = rng.randint(1, stake_scale + 1, size=n).astype(float)
    total = stakes.sum()
    u = (total - 1) // 3
    import jax.numpy as jnp
    from repro.core.quack import weighted_quorum_prefix
    acks = jnp.asarray(rng.randint(0, 10, size=n))
    prefix = int(weighted_quorum_prefix(acks, jnp.asarray(stakes), u + 1))
    claimed = stakes[(np.asarray(acks) >= prefix)].sum() if prefix else 0
    if prefix > 0:
        assert claimed >= u + 1


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(0.5, 1e6), min_size=2, max_size=10),
       st.integers(1, 300))
def test_apportionment_quota_rule(stakes, q):
    from repro.core.scheduler import hamilton_apportion
    c = hamilton_apportion(np.asarray(stakes), q)
    sq = np.asarray(stakes) / np.sum(stakes) * q
    assert c.sum() == q
    assert np.all(c >= np.floor(sq) - 1e-9)
    assert np.all(c <= np.ceil(sq) + 1e-9)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4))
def test_lcm_scaling_makes_totals_equal(ts, tr):
    from repro.core.types import lcm_scale_factors
    psi_s, psi_r = lcm_scale_factors(ts * 7, tr * 11)
    assert abs(ts * 7 * psi_s - tr * 11 * psi_r) < 1e-9


@st.composite
def injection_timeline(draw):
    """A fault injected at a random chunk boundary, healed at a later
    one: (chunk_steps, fault scenario, t_fault, t_heal)."""
    chunk = draw(st.sampled_from([4, 8]))
    k1 = draw(st.integers(1, 8))
    k2 = draw(st.integers(k1 + 1, k1 + 8))
    j = draw(st.integers(0, 3))
    kind = draw(st.sampled_from(["crash_recv", "partition", "bcast"]))
    t_fault, t_heal = k1 * chunk, k2 * chunk
    crash_r = [-1] * 4
    byz_recv = [False] * 4
    byz_partial = [False] * 4
    if kind == "crash_recv":
        crash_r[j] = t_fault
    elif kind == "partition":
        byz_recv[j] = True
    else:
        byz_partial[j] = True
    fault = FailureScenario(
        crash_r=tuple(crash_r), byz_recv_drop=tuple(byz_recv),
        byz_bcast_partial=tuple(byz_partial), bcast_limit=2)
    return chunk, fault, t_fault, t_heal


@settings(max_examples=10, deadline=None)
@given(injection_timeline(), st.integers(0, 2))
def test_replay_with_injection_equals_merged_schedule(plan, seed):
    """Replay property (repro.replay): resuming a checkpoint with a
    fault injected at a random chunk boundary (healed at a later one)
    is bit-identical to a from-scratch run executing the merged
    schedule — engine (resume-from-checkpoint vs resume-from-round-0)
    and numpy oracle both."""
    from repro.core.simulator import build_spec
    from repro.replay import (Injection, record_simulation, replay,
                              replay_oracle)

    chunk, fault, t_fault, t_heal = plan
    sender = receiver = RSMConfig.bft(1)
    sim = SimConfig(n_msgs=24, steps=160, window=1, phi=6, seed=seed,
                    window_slots=12, chunk_steps=chunk)
    spec = build_spec(sender, receiver, sim, FailureScenario.none())
    res, trace = record_simulation(spec)
    edits = [Injection(t_fault, fault),
             Injection(t_heal, FailureScenario.none())]
    ri = replay(trace, t_fault, edits)[0]
    scratch = replay(trace, 0, edits)[0]
    ref = replay_oracle(trace, edits)
    for name in ("quack_time", "deliver_time", "retry", "recv_has"):
        assert np.array_equal(getattr(ri, name), getattr(scratch, name))
        assert np.array_equal(getattr(ri, name), getattr(ref, name))
    assert np.array_equal(ri.gc_frontiers, scratch.gc_frontiers)
    assert np.array_equal(ri.gc_frontiers, ref.gc_frontiers)
    assert np.array_equal(np.asarray(ri.metrics.resends), ref.resends)
    # the unchanged-schedule twin: replay of the recorded run itself
    ru = replay(trace, t_fault)[0]
    for name in ("quack_time", "deliver_time", "retry", "recv_has"):
        assert np.array_equal(getattr(ru, name), getattr(res, name))
