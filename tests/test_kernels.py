"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, quack_scan, rwkv6_chunked
from repro.kernels.ref import (mha_reference, quack_reference,
                               rwkv6_reference)

RNG = jax.random.PRNGKey(7)


@pytest.mark.parametrize("b,h,kv,sq,skv,d", [
    (2, 4, 2, 128, 128, 64),
    (1, 4, 4, 256, 256, 32),
    (2, 4, 1, 128, 256, 64),     # MQA + longer kv (prefill w/ cache)
    (1, 8, 2, 64, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, kv, sq, skv, d, dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kv, skv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kv, skv, d), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    ref = mha_reference(q, k, v, causal=True)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("window", [32, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_window_and_noncausal(window, causal):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 128, 64))
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    w = window if causal else 0
    out = flash_attention(q, k, v, causal=causal, window=w,
                          block_q=64, block_kv=64)
    ref = mha_reference(q, k, v, causal=causal, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6,
                               rtol=2e-6)


@pytest.mark.parametrize("b,h,t,d,chunk", [
    (2, 2, 64, 32, 16),
    (1, 4, 128, 64, 64),
    (2, 1, 256, 16, 128),
    (1, 2, 64, 64, 64),          # single chunk
])
def test_rwkv6_chunked_sweep(b, h, t, d, chunk):
    ks = jax.random.split(RNG, 5)
    r = jax.random.normal(ks[0], (b, h, t, d)) * 0.5
    k = jax.random.normal(ks[1], (b, h, t, d)) * 0.5
    v = jax.random.normal(ks[2], (b, h, t, d)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, t, d))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (h, d)) * 0.5
    y = rwkv6_chunked(r, k, v, w, u, chunk=chunk)
    yref, _ = rwkv6_reference(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=1e-4,
                               rtol=1e-4)


def test_rwkv6_bf16_inputs():
    ks = jax.random.split(RNG, 5)
    shp = (1, 2, 64, 32)
    r, k, v = (jax.random.normal(ks[i], shp).astype(jnp.bfloat16)
               for i in range(3))
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], shp)) * 0.5
         + 0.45).astype(jnp.bfloat16)
    u = jax.random.normal(ks[4], (2, 32)).astype(jnp.bfloat16)
    y = rwkv6_chunked(r, k, v, w, u, chunk=32)
    yref, _ = rwkv6_reference(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yref, np.float32), atol=0.15,
                               rtol=0.15)


@pytest.mark.parametrize("s,r,w,bw", [
    (3, 7, 64, 32),
    (2, 16, 512, 512),
    (4, 5, 128, 64),
    (1, 33, 256, 128),
])
def test_quack_scan_sweep(s, r, w, bw):
    ks = jax.random.split(jax.random.PRNGKey(s), 3)
    claims = jax.random.bernoulli(ks[0], 0.6, (s, r, w))
    comps = jax.random.bernoulli(ks[1], 0.2, (s, r, w))
    stakes = jnp.abs(jax.random.normal(ks[2], (r,))) + 0.5
    qk, lk, pk = quack_scan(claims, comps, stakes, 3.0, 1.5, block_w=bw)
    qr, lr, pr = quack_reference(claims, comps, stakes, 3.0, 1.5)
    assert bool((qk == qr).all())
    assert bool((lk == lr).all())
    assert bool((pk == pr).all())


def test_quack_scan_matches_protocol_semantics():
    """Kernel quorum decisions == the simulator's quack primitive."""
    from repro.core.quack import selective_quack
    ks = jax.random.split(RNG, 2)
    claims = jax.random.bernoulli(ks[0], 0.5, (2, 4, 64))
    stakes = jnp.ones(4)
    q, _, _ = quack_scan(claims, jnp.zeros_like(claims), stakes, 2.0, 2.0)
    q2 = selective_quack(claims, stakes, 2.0)
    assert bool((q == q2).all())
