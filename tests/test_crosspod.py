"""Crosspod: picsou vs ATA sync equivalence, compression, replication."""

import numpy as np
import pytest

from helpers import run_py
from repro.crosspod import (ReplicationLedger, dcn_bytes_analytic,
                            ef_int8_compress, ef_int8_decompress)


def test_sync_schedules_agree():
    out = run_py("""
import jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.crosspod import picsou_cross_pod_sync, ata_cross_pod_sync
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = make_mesh((2,2,2), ('pod','data','model'))
rng = jax.random.PRNGKey(0)
g = {'a': jax.random.normal(rng, (16, 12)), 'b': jax.random.normal(rng, (7,))}
gsh = jax.device_put(g, NamedSharding(mesh, P()))
p = picsou_cross_pod_sync(gsh, mesh)
a = ata_cross_pod_sync(gsh, mesh)
ok = all(bool(jnp.allclose(p[k], a[k], atol=1e-6)) for k in g)
print('AGREE' if ok else 'DISAGREE')
""", devices=8)
    assert "AGREE" in out


def test_dcn_reduction_factor():
    """PICSOU cuts slow-link bytes by |data| vs the flat ring."""
    res_a = dcn_bytes_analytic(1e9, {"pod": 2, "data": 16, "model": 16},
                               "ata")
    res_p = dcn_bytes_analytic(1e9, {"pod": 2, "data": 16, "model": 16},
                               "picsou")
    assert res_p["dcn_per_chip"] * 16 == pytest.approx(
        res_a["dcn_per_chip"])
    assert res_p["dcn_reduction"] == pytest.approx(16.0)


def test_ef_int8_roundtrip_and_error_feedback():
    rng = np.random.RandomState(0)
    g = rng.randn(1000).astype(np.float32) * 0.01
    import jax.numpy as jnp
    residual = jnp.zeros(1000, jnp.float32)
    total_sent = np.zeros(1000, np.float32)
    total_true = np.zeros(1000, np.float32)
    for step in range(20):
        grad = jnp.asarray(g * (1 + 0.1 * step))
        packed, residual = ef_int8_compress(grad, residual)
        deq = ef_int8_decompress(packed, grad.shape)
        total_sent += np.asarray(deq)
        total_true += np.asarray(grad)
    # error feedback: accumulated transmitted ~= accumulated true
    resid = np.abs(total_sent + np.asarray(residual) - total_true).max()
    assert resid < 1e-4
    # single-shot error bounded by block max / 127
    assert np.abs(np.asarray(deq) - np.asarray(grad)).max() < \
        np.abs(g).max() * 2.5 / 127 * 127  # sanity: bounded


def test_replication_ledger_quack_durability():
    led = ReplicationLedger(n_hosts=4, u=1, r=1)
    led.plan_sends(list(range(8)))
    led.record_ack(0, 7)
    assert not led.all_durable()          # u+1 = 2 acks needed
    led.record_ack(1, 7)
    assert led.all_durable()
    assert led.highest_quacked() == 7


def test_replication_ledger_dup_detection_and_election():
    led = ReplicationLedger(n_hosts=4, u=1, r=1)
    plan = led.plan_sends(list(range(4)))
    # hosts ack only shards 0..1 repeatedly => shard 2 lost
    led.record_ack(0, 1)
    led.record_ack(1, 1)
    led.record_ack(0, 1)                   # duplicate from host 0
    assert led.lost_shards() == []         # r+1 = 2 complainers needed
    led.record_ack(1, 1)                   # duplicate from host 1
    assert led.lost_shards() == [2]
    origin = led.shards[2].origin_host
    new = led.elect_retransmitter(2)
    assert new == (origin + 1) % 4
    # second failure rotates again
    led.record_ack(0, 1)
    led.record_ack(1, 1)
    led.record_ack(0, 1)
    led.record_ack(1, 1)
    assert led.lost_shards() == [2]
    assert led.elect_retransmitter(2) == (origin + 2) % 4


def test_replication_hq_attestation_floor():
    led = ReplicationLedger(n_hosts=4, u=1, r=1)
    led.plan_sends(list(range(4)))
    assert led.record_hq_attestation(0, 2) == 0    # r+1 = 2 needed
    assert led.record_hq_attestation(1, 2) == 3    # floor past shard 2


def test_straggler_apportionment():
    led = ReplicationLedger(n_hosts=4, u=1, r=0)
    plan = led.plan_sends(list(range(10)),
                          host_throughput=np.array([5., 3., 1., 1.]))
    counts = np.bincount(list(plan.values()), minlength=4)
    assert counts[0] == 5 and counts[1] == 3
