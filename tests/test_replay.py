"""repro.replay: checkpointing, deterministic replay, what-if forking.

The replay contract under test:

* replay from any chunk-boundary checkpoint with an *unchanged* schedule
  is bit-identical to the original run — frontiers, delivered masks,
  per-round metrics — for single-link and topology runs, engine and
  numpy oracle both;
* replay with injected schedule edits equals a from-scratch run
  executing the merged schedule (engine and oracle);
* a forked what-if batch executes N schedule variants as one vmapped
  chunk stream, reusing the compiled chunk (trace-count deltas are
  measured, not assumed);
* traces survive an npz save/load round-trip bit-exactly;
* replay stays exact across the adaptive-growth and dense-fallback
  boundaries (checkpoint while windowed, overflow after resume).
"""

import dataclasses

import numpy as np
import pytest

from repro.core import FailureScenario, RSMConfig, SimConfig
from repro.core.simulator import (build_spec, chunk_trace_count,
                                  run_simulation)
from repro.replay import (ForkSpec, Injection, RunTrace, fork_whatif,
                          record_batch, record_simulation, record_topology,
                          replay, replay_oracle, replay_topology,
                          replay_topology_oracle)
from repro.topology import Topology

BFT1 = RSMConfig.bft(1)
OUTPUTS = ("quack_time", "deliver_time", "retry", "recv_has")
METRICS = ("cross_msgs", "intra_msgs", "resends", "acks", "delivered",
           "min_quack_prefix")

SIM = SimConfig(n_msgs=96, steps=120, window=1, phi=6, window_slots=24,
                chunk_steps=8)
# one sender crashes mid-stream: its scheduled originals after the crash
# are never dispatched, so schedule edits around the crash genuinely
# change delivery.
CRASH_S0 = FailureScenario(crash_s=(16, -1, -1, -1))
DROP_R0 = FailureScenario(byz_recv_drop=(True, False, False, False))


def _assert_results_equal(a, b, frontiers=True, metrics=True):
    for out in OUTPUTS:
        assert np.array_equal(getattr(a, out), getattr(b, out)), out
    if frontiers:
        assert np.array_equal(a.gc_frontiers, b.gc_frontiers)
    if metrics:
        for name in METRICS:
            assert np.array_equal(np.asarray(getattr(a.metrics, name)),
                                  np.asarray(getattr(b.metrics, name))), name


def _assert_matches_oracle(res, ref, frontiers=True):
    for out in OUTPUTS:
        assert np.array_equal(getattr(res, out), getattr(ref, out)), out
    if frontiers:
        assert np.array_equal(res.gc_frontiers, ref.gc_frontiers)
    assert np.array_equal(np.asarray(res.metrics.resends), ref.resends)
    assert np.array_equal(np.asarray(res.metrics.cross_msgs),
                          ref.cross_msgs)


# --- checkpointing + unchanged replay ------------------------------------

def test_unchanged_replay_bit_identical_from_every_checkpoint():
    spec = build_spec(BFT1, BFT1, SIM, CRASH_S0)
    res, trace = record_simulation(spec)
    # recording itself does not perturb the run
    _assert_results_equal(res, run_simulation(spec))
    assert len(trace.checkpoints) == (SIM.steps - 1) // SIM.chunk_steps + 1
    for t in trace.boundaries().tolist():
        rr = replay(trace, t)[0]
        _assert_results_equal(rr, res)
        assert rr.final_window_slots == res.final_window_slots
    # the replay oracle reproduces the original run too
    _assert_matches_oracle(res, replay_oracle(trace))


def test_thinned_recording_and_missing_checkpoint():
    spec = build_spec(BFT1, BFT1, SIM)
    res, trace = record_simulation(spec, every=2)
    bounds = trace.boundaries()
    assert np.array_equal(bounds % (2 * SIM.chunk_steps),
                          np.zeros_like(bounds))
    _assert_results_equal(replay(trace, int(bounds[-1]))[0], res)
    with pytest.raises(KeyError, match="no checkpoint at round 8"):
        trace.checkpoint_at(8)
    assert trace.last_checkpoint_before(23).t == 16


def test_trace_save_load_roundtrip(tmp_path):
    spec = build_spec(BFT1, BFT1, SIM, DROP_R0)
    res, trace = record_simulation(spec)
    path = str(tmp_path / "trace.npz")
    trace.save(path)
    loaded = RunTrace.load(path)
    assert [s for s in loaded.specs] == [s for s in trace.specs]
    assert loaded.lane_names == trace.lane_names
    assert np.array_equal(loaded.boundaries(), trace.boundaries())
    for c0, c1 in zip(trace.checkpoints, loaded.checkpoints):
        for name in ("bases", "floors", "bases_hist", "out_deliver"):
            assert np.array_equal(np.asarray(getattr(c0, name)),
                                  np.asarray(getattr(c1, name))), name
        for f in c0.state._fields:
            assert np.array_equal(np.asarray(getattr(c0.state, f)),
                                  np.asarray(getattr(c1.state, f))), f
    _assert_results_equal(replay(loaded, 16)[0], res)


# --- injection ------------------------------------------------------------

@pytest.mark.parametrize("at_step,edit", [
    (16, CRASH_S0),                                      # crash mid-run
    (16, FailureScenario(crash_r=(16, -1, -1, -1))),     # receiver crash
    (16, FailureScenario(byz_recv_drop=(True, False, False, False))),
], ids=["crash_sender", "crash_receiver", "open_partition"])
def test_injected_replay_equals_merged_schedule(at_step, edit):
    spec = build_spec(BFT1, BFT1, SIM)
    res, trace = record_simulation(spec)
    inj = [Injection(at_step, edit)]
    ri = replay(trace, at_step, inj)[0]
    # equals the from-scratch engine run of the merged schedule...
    scratch = replay(trace, 0, inj)[0]
    _assert_results_equal(ri, scratch)
    # ...and the from-scratch numpy oracle of the merged schedule
    _assert_matches_oracle(ri, replay_oracle(trace, inj))
    # the injected future genuinely diverges from the recorded one
    assert any(not np.array_equal(getattr(ri, out), getattr(res, out))
               for out in OUTPUTS)


def test_heal_injection():
    """Open a partition from round 0 (static), heal it mid-run: the
    replayed future delivers directly what the unhealed run only gets
    through loss detection + retransmission."""
    sim = dataclasses.replace(SIM, steps=200)
    spec = build_spec(BFT1, BFT1, sim, DROP_R0)
    res, trace = record_simulation(spec)
    heal = [Injection(16, FailureScenario.none())]
    ri = replay(trace, 16, heal)[0]
    _assert_matches_oracle(ri, replay_oracle(trace, heal))
    assert not np.array_equal(ri.deliver_time, res.deliver_time)
    assert (np.sum(ri.metrics.resends) < np.sum(res.metrics.resends))


def test_injection_validation():
    spec = build_spec(BFT1, BFT1, SIM)
    _, trace = record_simulation(spec)
    with pytest.raises(ValueError, match="not a chunk boundary"):
        replay(trace, 16, [Injection(19, CRASH_S0)])
    with pytest.raises(ValueError, match="outside the replayed range"):
        replay(trace, 16, [Injection(8, CRASH_S0)])
    with pytest.raises(ValueError, match="replicas"):
        replay(trace, 16, [Injection(
            16, FailureScenario(crash_s=(1, -1)))])
    with pytest.raises(KeyError, match="unknown lane"):
        replay(trace, 16, {"nope": [Injection(16, CRASH_S0)]})
    with pytest.raises(KeyError, match="no checkpoint"):
        replay(trace, 13)


def test_scenario_batch_replay():
    """Batched (multi-lane) traces replay too: per-lane checkpoint bases
    resume and per-lane injections apply to their own lane only."""
    specs = [build_spec(BFT1, BFT1, SIM, f)
             for f in (FailureScenario.none(), DROP_R0)]
    results, trace = record_batch(specs)
    for t in (0, 16, 48):
        rr = replay(trace, t)
        for r0, r1 in zip(results, rr):
            _assert_results_equal(r0, r1)
    ri = replay(trace, 16, {1: [Injection(16, FailureScenario.none())]})
    _assert_results_equal(ri[0], results[0])          # lane 0 untouched
    assert not np.array_equal(ri[1].deliver_time, results[1].deliver_time)


# --- adaptive growth / dense fallback across the replay boundary ----------

GC_STALL = FailureScenario(byz_bcast_partial=(True, False, False, False),
                           bcast_limit=2, crash_r=(-1, 8, -1, -1))


def test_replay_across_dense_fallback_boundary():
    """Checkpoint while windowed; after resume the stalled frontier
    forces growth and then the dense-layout migration
    (``_migrate_dense_batch``) — the replayed run takes the identical
    trajectory and stays bit-identical to the original, the dense run
    and the oracle."""
    sim = SimConfig(n_msgs=64, steps=200, window=1, phi=6,
                    window_slots=16, chunk_steps=8)
    spec = build_spec(BFT1, BFT1, sim, GC_STALL)
    res, trace = record_simulation(spec)
    assert res.final_window_slots == spec.m       # original fell back
    migration = [e for e in res.window_growth_events if e.dense_migration]
    assert migration, "fixture must cross the dense-fallback boundary"
    mig_chunk = (migration[0].step // sim.chunk_steps) * sim.chunk_steps
    windowed_bounds = [int(c.t) for c in trace.checkpoints
                       if c.window_slots < spec.m]
    assert windowed_bounds and windowed_bounds[-1] <= mig_chunk
    for t in windowed_bounds:                     # resume pre-migration
        rr = replay(trace, t)[0]
        _assert_results_equal(rr, res)
        assert rr.final_window_slots == spec.m
        assert [e for e in rr.window_growth_events if e.dense_migration]
    # post-migration checkpoints resume in the dense layout
    dense_bounds = [int(c.t) for c in trace.checkpoints
                    if c.window_slots == spec.m]
    assert dense_bounds
    _assert_results_equal(replay(trace, dense_bounds[0])[0], res)
    _assert_matches_oracle(res, replay_oracle(trace))


def test_replay_across_adaptive_growth_boundary():
    """Same, for plain 2x growth (no dense migration): checkpoints taken
    at the initial width resume and re-take the identical growth."""
    sim = SimConfig(n_msgs=128, steps=128 // 4 + 80, window=1, phi=6,
                    window_slots=16, chunk_steps=8)
    stall = FailureScenario(byz_bcast_partial=(True, False, False, False),
                            bcast_limit=2)
    spec = build_spec(BFT1, BFT1, sim, stall)
    res, trace = record_simulation(spec)
    assert spec.window_slots < res.final_window_slots < spec.m
    assert res.window_growth_events
    assert all(not e.dense_migration for e in res.window_growth_events)
    first_grow = res.window_growth_events[0]
    assert first_grow.scenario == 0 and first_grow.old_w == 16
    narrow = [int(c.t) for c in trace.checkpoints
              if c.window_slots == spec.window_slots]
    for t in (narrow[0], narrow[-1]):
        rr = replay(trace, t)[0]
        _assert_results_equal(rr, res)
        assert rr.final_window_slots == res.final_window_slots
        assert rr.window_growth_events == res.window_growth_events


# --- topology replay ------------------------------------------------------

TOPO_SIM = SimConfig(n_msgs=96, steps=160, window=1, phi=6,
                     window_slots=24, chunk_steps=8)


def _chain_topo():
    return Topology.chain(["a", "b", "c"], BFT1, TOPO_SIM)


def test_topology_unchanged_replay_bit_identical():
    topo = _chain_topo()
    r0, trace = record_topology(topo)
    assert trace.floor_plan == {1: 0}
    for t in (0, 24, 64):
        rr = replay_topology(trace, t)
        for name in trace.lane_names:
            _assert_results_equal(rr[name].result, r0[name].result)
            assert np.array_equal(rr[name].commit_floors,
                                  r0[name].commit_floors)
    ref = replay_topology_oracle(trace)
    for name in trace.lane_names:
        _assert_matches_oracle(r0[name].result, ref[name].result)
        assert np.array_equal(r0[name].commit_floors,
                              ref[name].commit_floors)


def test_topology_injected_replay_matches_oracle():
    """Crash the upstream link's senders mid-stream: the downstream
    link's commit floor freezes with it, and engine == oracle on every
    output and every floor trajectory."""
    topo = _chain_topo()
    r0, trace = record_topology(topo)
    inj = {"a->b": [Injection(16, FailureScenario(crash_s=(16,) * 4))]}
    ri = replay_topology(trace, 16, inj)
    ref = replay_topology_oracle(trace, inj)
    for name in trace.lane_names:
        _assert_matches_oracle(ri[name].result, ref[name].result)
        assert np.array_equal(ri[name].commit_floors,
                              ref[name].commit_floors)
    # the crash genuinely cut the chain short
    assert ri["b->c"].delivered_prefix() < r0["b->c"].delivered_prefix()


def test_topology_trace_save_load(tmp_path):
    topo = _chain_topo()
    r0, trace = record_topology(topo)
    path = str(tmp_path / "topo.npz")
    trace.save(path)
    loaded = RunTrace.load(path)
    assert loaded.kind == "topology"
    assert loaded.topology == topo
    rr = replay_topology(loaded, 24)
    for name in trace.lane_names:
        _assert_results_equal(rr[name].result, r0[name].result)


# --- forked what-if -------------------------------------------------------

def test_fork_whatif_matches_individual_replays():
    spec = build_spec(BFT1, BFT1, SIM)
    res, trace = record_simulation(spec)
    variants = [
        ForkSpec("baseline"),
        ForkSpec("crash-16", [Injection(16, CRASH_S0)]),
        ForkSpec("crash-32", [Injection(
            32, FailureScenario(crash_s=(32, -1, -1, -1)))]),
        ForkSpec("partition", [Injection(16, DROP_R0)]),
    ]
    report = fork_whatif(trace, 16, variants)
    assert report.lane_names == ["lane0"]
    # every fork's per-message outputs and per-round metric streams are
    # bit-identical to its one-at-a-time replay. (Frontier trajectories
    # are excluded: the fork batch shares one window width, so a stalled
    # fork widens everyone's window and retirement can batch up — the
    # outputs are invariant to that, the rotation schedule is not.)
    for fs in variants:
        solo = replay(trace, 16, fs.injections)[0]
        _assert_results_equal(report[fs.name].results[0], solo,
                              frontiers=False)
    # the baseline fork reproduces the parent run exactly
    _assert_results_equal(report["baseline"].results[0], res,
                          frontiers=False)
    assert report["baseline"].divergence["lane0"]["delivered"] == 0
    # the futures genuinely diverge: a crashed sender's tail messages
    # only arrive through loss detection + rotated retransmission
    # (eventual delivery holds — the cost shows up in resends and time)
    base_stats = report["baseline"].stats["lane0"]
    crash = report["crash-16"].stats["lane0"]
    assert crash["resends"] > base_stats["resends"]
    assert crash["delivery_step"] > base_stats["delivery_step"]
    assert report["crash-16"].divergence["lane0"]["resends"] > 0
    assert (report["partition"].stats["lane0"]["resends"]
            > base_stats["resends"])
    rows = report.rows()
    assert len(rows) == 4 and {r["fork"] for r in rows} == {
        "baseline", "crash-16", "crash-32", "partition"}


def test_fork_whatif_reuses_compiled_chunk():
    """The fork batch costs at most the one batch-width tracing of the
    chunk program — and zero once a batch of that width is warm:
    re-forking (different edits, same shapes) never recompiles."""
    spec = build_spec(BFT1, BFT1, SIM)
    _, trace = record_simulation(spec)
    variants = [ForkSpec("a"), ForkSpec("b", [Injection(16, CRASH_S0)]),
                ForkSpec("c", [Injection(24, DROP_R0)])]
    first = fork_whatif(trace, 16, variants)
    assert first.chunk_traces <= 2      # rotate + final no-rotate chunk
    again = fork_whatif(trace, 24, [
        ForkSpec("x", [Injection(24, CRASH_S0)]), ForkSpec("y"),
        ForkSpec("z", [Injection(32, DROP_R0)])])
    assert again.chunk_traces == 0      # same shapes: fully warm
    before = chunk_trace_count()
    replay(trace, 16, [Injection(16, CRASH_S0)])
    assert chunk_trace_count() == before    # replay reuses parent width


def test_fork_whatif_topology():
    topo = _chain_topo()
    r0, trace = record_topology(topo)
    inj = {"a->b": [Injection(16, FailureScenario(crash_s=(16,) * 4))]}
    report = fork_whatif(trace, 16, [ForkSpec("baseline"),
                                     ForkSpec("upstream-crash", inj)])
    for name in trace.lane_names:
        _assert_results_equal(report["baseline"][name],
                              r0[name].result, frontiers=False)
    solo = replay_topology(trace, 16, inj)
    for name in trace.lane_names:
        _assert_results_equal(report["upstream-crash"][name],
                              solo[name].result, frontiers=False)
    assert (report["upstream-crash"].divergence["b->c"]["delivered"] < 0)


def test_fork_whatif_on_loaded_trace_has_baseline(tmp_path):
    """A trace loaded from disk carries no original results; the what-if
    baseline is derived from an unchanged replay instead (bit-identical
    to the original), so divergence never silently degrades to {}."""
    spec = build_spec(BFT1, BFT1, SIM)
    res, trace = record_simulation(spec)
    path = str(tmp_path / "t.npz")
    trace.save(path)
    loaded = RunTrace.load(path)
    assert loaded.results is None
    report = fork_whatif(loaded, 16, [
        ForkSpec("baseline"), ForkSpec("crash", [Injection(16, CRASH_S0)])])
    assert report.baseline == {"lane0": {
        k: v for k, v in report["baseline"].stats["lane0"].items()}}
    assert report["crash"].divergence["lane0"]["resends"] > 0
    in_memory = fork_whatif(trace, 16, [
        ForkSpec("baseline"), ForkSpec("crash", [Injection(16, CRASH_S0)])])
    assert report.baseline == in_memory.baseline
    assert (report["crash"].divergence["lane0"]
            == in_memory["crash"].divergence["lane0"])


def test_fork_growth_event_reattribution():
    """Fork batches re-attribute tiled lane indices back to (fork,
    lane): pre-fork (shared prefix) events keep their original lane
    index, post-fork events are split into fork id + lane."""
    from repro.core.simulator import WindowGrowthEvent
    from repro.replay.whatif import _reattribute_events
    pre = WindowGrowthEvent(step=7, scenario=1, need=31, old_w=16,
                            new_w=32)
    post = WindowGrowthEvent(step=40, scenario=5, need=90, old_w=32,
                             new_w=64)
    out = _reattribute_events((pre, post), n_b=2, from_step=16)
    assert out[0] == pre and out[0].fork is None
    assert out[1].fork == 2 and out[1].scenario == 1
    assert (out[1].step, out[1].old_w, out[1].new_w) == (40, 32, 64)


def test_fork_rejects_duplicates_and_empty():
    spec = build_spec(BFT1, BFT1, SIM)
    _, trace = record_simulation(spec)
    with pytest.raises(ValueError, match="at least one"):
        fork_whatif(trace, 16, [])
    with pytest.raises(ValueError, match="duplicate fork names"):
        fork_whatif(trace, 16, [ForkSpec("a"), ForkSpec("a")])


# --- disaster recovery as an injected event -------------------------------

def test_disaster_recovery_injected_equals_static():
    from repro.apps import run_disaster_recovery
    sim = SimConfig(n_msgs=96, steps=60, window=1, phi=6,
                    window_slots=24, chunk_steps=8)
    kw = dict(crash_at=12, backup_failures={
        "backup-1": FailureScenario(byz_recv_drop=(True, True, False,
                                                   False))})
    static = run_disaster_recovery(BFT1, BFT1, sim, **kw)
    injected = run_disaster_recovery(BFT1, BFT1, sim, **kw,
                                     inject_via_replay=True)
    oracle = run_disaster_recovery(BFT1, BFT1, sim, **kw,
                                   inject_via_replay=True,
                                   use_reference=True)
    for r in (injected, oracle):
        assert r.elected == static.elected
        assert r.phase1_prefixes == static.phase1_prefixes
        assert r.final_prefixes == static.final_prefixes
        assert r.converged == static.converged
        assert np.array_equal(r.recovered_log, static.recovered_log)
    assert injected.injected_at == 8          # last boundary before 12
    assert injected.phase1_trace is not None
    # the crash genuinely truncated the stream (what-if has room to fork)
    assert static.phase1_prefixes[static.elected] < sim.n_msgs


def test_growth_event_observability_in_batch():
    """Satellite: a batched sweep records WHICH scenario forced adaptive
    growth (and the overflow round) instead of silently growing W."""
    sim = SimConfig(n_msgs=128, steps=128 // 4 + 80, window=1, phi=6,
                    window_slots=16, chunk_steps=8)
    stall = FailureScenario(byz_bcast_partial=(True, False, False, False),
                            bcast_limit=2)
    specs = [build_spec(BFT1, BFT1, sim, f)
             for f in (FailureScenario.none(), stall)]
    from repro.core.simulator import run_simulation_batch
    batched = run_simulation_batch(specs)
    events = batched[0].window_growth_events
    assert events and events == batched[1].window_growth_events
    # the first overflow is the shared dispatch ramp (both lanes at base
    # 0 — attribution tie-breaks to lane 0); every later growth is the
    # GC-stalled lane pinning its base while originals keep dispatching.
    assert len(events) >= 2
    assert all(e.scenario == 1 for e in events[1:])   # the stalled lane
    assert all(e.new_w == 2 * e.old_w for e in events)
    assert [e.old_w for e in events] == [16 * 2 ** i
                                         for i in range(len(events))]
    assert all(0 <= e.step < sim.steps for e in events)
    assert all(not e.dense_migration for e in events)
    # a windowed run whose window holds the dispatch ramp records none
    roomy = dataclasses.replace(sim, n_msgs=256, steps=256 // 4 + 80,
                                window_slots=160)
    clean = run_simulation(build_spec(BFT1, BFT1, roomy))
    assert clean.window_growth_events == ()
    assert clean.gc_frontiers[-1] == 256
