"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finite values; prefill/decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_configs, shape_applicable
from repro.models import (decode_step, forward, init_model, loss_fn,
                          prefill)
from repro.models.model import encode
from repro.optim import AdamWConfig, adamw_init, adamw_update

ARCHS = list_configs()
RNG = jax.random.PRNGKey(0)

pytestmark = pytest.mark.slow     # per-arch sweeps; full CI tier only


def _batch(cfg, b, s):
    batch = {"tokens": jax.random.randint(RNG, (b, s), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            RNG, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["memory"] = jax.random.normal(
            RNG, (b, cfg.vision_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).smoke()
    params = init_model(cfg, RNG)
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    memory = batch.get("memory")
    if cfg.family == "encdec":
        memory = encode(params, cfg, batch["frames"])
    logits, aux = forward(params, cfg, batch["tokens"], memory=memory)
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).smoke()
    params = init_model(cfg, RNG)
    opt = adamw_init(params)
    batch = _batch(cfg, 2, 32)

    @jax.jit
    def step(p, o, b):
        (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(p, cfg, b)
        p, o = adamw_update(AdamWConfig(), g, p, o)
        return p, o, loss

    params2, opt2, loss = step(params, opt, batch)
    assert bool(jnp.isfinite(loss))
    # parameters actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch).smoke()
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_model(cfg, RNG)
    b, s = 2, 16
    batch = _batch(cfg, b, s + 2)
    tokens = batch["tokens"]
    memory = batch.get("memory")
    mem_fwd = (encode(params, cfg, batch["frames"])
               if cfg.family == "encdec" else memory)
    full, _ = forward(params, cfg, tokens, memory=mem_fwd)
    last, caches = prefill(params, cfg, tokens[:, :s],
                           memory=(batch.get("frames")
                                   if cfg.family == "encdec" else memory),
                           cache_len=s + 2)
    np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                               np.asarray(full[:, s - 1], np.float32),
                               atol=2e-2, rtol=2e-2)
    d1, caches = decode_step(params, cfg, caches, tokens[:, s:s + 1],
                             jnp.int32(s))
    d2, _ = decode_step(params, cfg, caches, tokens[:, s + 1:s + 2],
                        jnp.int32(s + 1))
    np.testing.assert_allclose(np.asarray(d1[:, 0], np.float32),
                               np.asarray(full[:, s], np.float32),
                               atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(d2[:, 0], np.float32),
                               np.asarray(full[:, s + 1], np.float32),
                               atol=2e-2, rtol=2e-2)


def test_shape_applicability_matrix():
    """long_500k only for sub-quadratic archs (DESIGN.md table)."""
    expect_runs = {"hymba-1.5b", "mixtral-8x22b", "rwkv6-7b"}
    runs = {a for a in ARCHS
            if shape_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert runs == expect_runs


def test_param_counts_in_range():
    """Full configs land near their nameplate sizes.

    granite/starcoder run ~30-40% above nameplate because the framework
    uses SwiGLU MLPs uniformly where those originals use 2-matrix MLPs
    (DESIGN.md hardware-adaptation notes); bounds are sanity checks
    against order-of-magnitude config errors, not bit-exact replication.
    """
    expected = {
        "granite-34b": (30e9, 50e9),
        "qwen2-72b": (65e9, 80e9),
        "granite-8b": (7e9, 10e9),
        "starcoder2-3b": (2.5e9, 4.8e9),
        "hymba-1.5b": (1.0e9, 2.2e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "mixtral-8x22b": (120e9, 150e9),
        "rwkv6-7b": (6e9, 9.5e9),
        "whisper-small": (0.15e9, 0.4e9),
        "llama-3.2-vision-11b": (8e9, 13e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B not in [{lo / 1e9}" \
                              f", {hi / 1e9}]B"


def test_moe_dense_matches_scatter():
    """§Perf lever: dense dispatch must be numerically identical to the
    scatter path (at high capacity factor)."""
    cfg_s = dataclasses.replace(get_config("mixtral-8x22b").smoke(),
                                capacity_factor=8.0)
    cfg_d = dataclasses.replace(cfg_s, moe_impl="dense")
    params = init_model(cfg_s, RNG)
    tokens = jax.random.randint(RNG, (2, 32), 0, cfg_s.vocab)
    a, _ = forward(params, cfg_s, tokens)
    b, _ = forward(params, cfg_d, tokens)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-4)


def test_rwkv_blocked_scan_matches_baseline():
    """§Perf lever: blocked recurrence == per-step recurrence."""
    cfg1 = get_config("rwkv6-7b").smoke()
    cfg2 = dataclasses.replace(cfg1, rwkv_scan_block=8)
    params = init_model(cfg1, RNG)
    tokens = jax.random.randint(RNG, (2, 32), 0, cfg1.vocab)
    a, _ = forward(params, cfg1, tokens)
    b, _ = forward(params, cfg2, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_hybrid_blocked_scan_matches_baseline():
    cfg1 = get_config("hymba-1.5b").smoke()
    cfg2 = dataclasses.replace(cfg1, rwkv_scan_block=8)
    params = init_model(cfg1, RNG)
    tokens = jax.random.randint(RNG, (2, 32), 0, cfg1.vocab)
    a, _ = forward(params, cfg1, tokens)
    b, _ = forward(params, cfg2, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_triangular_matches_scan_attention():
    """§Perf lever: triangular causal attention == masked-scan attention."""
    cfg = get_config("granite-8b").smoke()
    params = init_model(cfg, RNG)
    tokens = jax.random.randint(RNG, (2, 32), 0, cfg.vocab)
    a, _ = forward(params, cfg, tokens, impl="scan")
    b, _ = forward(params, cfg, tokens, impl="triangular")
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-4)
