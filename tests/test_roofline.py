"""Roofline accounting tests (controlled HLO examples)."""

import pytest

from helpers import run_py
from repro.configs import SHAPES, get_config
from repro.roofline.hlo import parse_collectives
from repro.roofline.model import HW, model_flops, roofline_terms


def test_matmul_flop_convention():
    out = run_py("""
import jax, jax.numpy as jnp
from repro.roofline.hlo_cost import analyze_hlo_text
f = jax.jit(lambda a, b: a @ b)
c = f.lower(jax.ShapeDtypeStruct((512,512), jnp.float32),
            jax.ShapeDtypeStruct((512,512), jnp.float32)).compile()
hc = analyze_hlo_text(c.as_text())
assert hc.flops == 2*512**3, hc.flops
assert abs(hc.hbm_bytes - 3*512*512*4) < 1e5, hc.hbm_bytes
print('FLOPS-OK')
""", devices=1)
    assert "FLOPS-OK" in out


def test_scan_trip_count_accounting():
    out = run_py("""
import jax, jax.numpy as jnp
from repro.roofline.hlo_cost import analyze_hlo_text
def body(c, _):
    return (c @ c).astype(c.dtype), None
g = jax.jit(lambda x: jax.lax.scan(body, x, None, length=7)[0])
c = g.lower(jax.ShapeDtypeStruct((128,128), jnp.float32)).compile()
hc = analyze_hlo_text(c.as_text())
assert hc.flops == 7*2*128**3, hc.flops
print('SCAN-OK')
""", devices=1)
    assert "SCAN-OK" in out


def test_collectives_counted_with_trip_multiplier():
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.roofline.hlo_cost import analyze_hlo_text
mesh = Mesh(np.array(jax.devices()[:8]).reshape(2,4), ('data','model'))
def body(c, _):
    y = jax.lax.with_sharding_constraint(
        c @ c, NamedSharding(mesh, P('data', None)))
    return y.astype(c.dtype), None
h = jax.jit(lambda x: jax.lax.scan(body, x, None, length=5)[0],
            in_shardings=NamedSharding(mesh, P('data','model')))
c = h.lower(jax.ShapeDtypeStruct((128,128), jnp.float32)).compile()
hc = analyze_hlo_text(c.as_text())
total = sum(hc.coll_count.values())
assert total % 5 == 0 and total > 0, hc.coll_count
print('COLL-OK')
""", devices=8)
    assert "COLL-OK" in out


def test_roofline_terms_formula():
    t = roofline_terms(197e12, 819e9, 50e9, HW())
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)


def test_model_flops_dense_vs_moe():
    dense = get_config("granite-8b")
    moe = get_config("deepseek-moe-16b")
    sh = SHAPES["train_4k"]
    # MoE uses active params (top-k + shared), far below total
    assert moe.n_active_params() < 0.35 * moe.n_params()
    f_dense = model_flops(dense, sh)
    tokens = sh.global_batch * sh.seq_len
    assert f_dense > 6.0 * dense.n_params() * tokens  # attention adds more


def test_ring_cost_formulas():
    hlo = """
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %ar = f32[16,16] all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
  %ag = f32[16,16] all-gather(%p), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""
    ops = parse_collectives(hlo)
    assert len(ops) == 2
    ar, ag = ops
    n = 16 * 16 * 4
    assert ar.wire_bytes_per_chip == pytest.approx(2 * n * 3 / 4)
    assert ag.group_size == 4
