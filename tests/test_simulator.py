"""Simulator behaviour tests + JAX-vs-reference cross-checks."""

import numpy as np

from repro.core import (FailureScenario, RSMConfig, SimConfig, run_picsou)
from repro.core.refsim import run_reference
from repro.core.simulator import build_spec, run_simulation

BFT1 = RSMConfig.bft(1)          # n=4, u=r=1
CFT1 = RSMConfig.cft(1)          # n=3, u=1, r=0


def _match(spec):
    jr = run_simulation(spec)
    rr = run_reference(spec)
    for name in ("quack_time", "deliver_time", "retry", "recv_has"):
        a, b = getattr(jr, name), getattr(rr, name)
        assert np.array_equal(np.asarray(a), np.asarray(b)), name
    return jr


def test_failure_free_efficiency():
    """P1: exactly one cross-RSM copy and n_r-1 intra copies per message."""
    run = run_picsou(BFT1, BFT1, SimConfig(n_msgs=32, steps=40, window=2,
                                           phi=8))
    assert run.all_delivered and run.all_quacked
    assert run.cross_copies_per_msg == 1.0
    assert run.intra_copies_per_msg == BFT1.n - 1
    assert run.resends_per_msg == 0.0


def test_jax_matches_reference_failure_free():
    _match(build_spec(BFT1, BFT1, SimConfig(n_msgs=24, steps=30, window=2,
                                            phi=6)))


def test_jax_matches_reference_crash():
    spec = build_spec(BFT1, BFT1,
                      SimConfig(n_msgs=24, steps=150, window=1, phi=6),
                      FailureScenario(crash_s=(1, -1, -1, -1)))
    jr = _match(spec)
    assert (jr.deliver_time >= 0).all()


def test_jax_matches_reference_byzantine():
    spec = build_spec(BFT1, BFT1,
                      SimConfig(n_msgs=24, steps=200, window=1, phi=6),
                      FailureScenario(byz_recv_drop=(True, False, False,
                                                     False),
                                      byz_ack_low=(False, True, False,
                                                   False)))
    jr = _match(spec)
    assert (jr.deliver_time >= 0).all()


def test_crashed_sender_recovers_with_bounded_retries():
    spec = build_spec(BFT1, BFT1,
                      SimConfig(n_msgs=24, steps=240, window=1, phi=6),
                      FailureScenario(crash_s=(2, -1, -1, -1),
                                      byz_recv_drop=(True, False, False,
                                                     False)))
    jr = run_simulation(spec)
    assert (jr.deliver_time >= 0).all()
    honest = np.array([False, True, True, True])
    assert jr.retry[honest].max() <= 3       # Lemma 1: u_s + u_r + 1


def test_byzantine_liar_causes_no_spurious_resends():
    """Robustness (P3): a single low-acking liar (r=1) cannot trigger
    resends — duplicate QUACKs need r+1 distinct complainers."""
    spec = build_spec(BFT1, BFT1,
                      SimConfig(n_msgs=24, steps=150, window=1, phi=6),
                      FailureScenario(byz_ack_low=(True, False, False,
                                                   False)))
    jr = run_simulation(spec)
    assert int(jr.metrics.resends.sum()) == 0
    assert (jr.deliver_time >= 0).all()


def test_cft_single_dup_triggers_resend():
    """In CFT mode (r=0) a single duplicate complaint suffices (§4.2)."""
    spec = build_spec(CFT1, CFT1,
                      SimConfig(n_msgs=12, steps=120, window=1, phi=6),
                      FailureScenario(crash_s=(1, -1, -1)))
    jr = run_simulation(spec)
    assert (jr.deliver_time >= 0).all()
    assert int(jr.metrics.resends.sum()) > 0


def test_gc_stall_defence_progresses():
    """§4.3: byzantine partial broadcast + colluding crash stalls the naive
    protocol; highest-quacked metadata lets the stream progress."""
    fail = FailureScenario(byz_bcast_partial=(True, False, False, False),
                           bcast_limit=2, crash_r=(-1, 8, -1, -1))
    spec = build_spec(BFT1, BFT1,
                      SimConfig(n_msgs=24, steps=300, window=1, phi=6),
                      fail)
    jr = run_simulation(spec)
    # failures exceed u_r here (model violated) so delivery of poisoned
    # messages is excused — but the quack stream must NOT stall:
    assert int(jr.metrics.min_quack_prefix[-1]) > 8


def test_staked_dss_run():
    ss = RSMConfig(n=4, u=333, r=333, stakes=(333., 223., 222., 222.))
    rs = RSMConfig(n=4, u=333, r=333, stakes=(250., 250., 250., 250.))
    spec = build_spec(ss, rs, SimConfig(n_msgs=24, steps=80, window=2,
                                        phi=6, scheduler="dss", quantum=12))
    jr = _match(spec)
    assert (jr.deliver_time >= 0).all()


def test_mixed_cft_bft():
    """Generality (P2): a CFT RSM can talk to a BFT RSM."""
    spec = build_spec(CFT1, BFT1, SimConfig(n_msgs=24, steps=60, window=2,
                                            phi=6))
    jr = run_simulation(spec)
    assert (jr.deliver_time >= 0).all()
    spec = build_spec(BFT1, CFT1, SimConfig(n_msgs=24, steps=60, window=2,
                                            phi=6))
    jr = run_simulation(spec)
    assert (jr.deliver_time >= 0).all()
