"""Paper §6 applications end-to-end: disaster recovery + reconciliation.

Every fixture runs twice — on the vmapped multi-link engine and on the
pure-numpy multi-link oracle (``use_reference=True``) — and the two
reports must be identical: same election, same per-backup prefixes, same
merged stores, same round counts. The app-level claims (failover picks
the most-caught-up backup, convergence to the elected log, stores merge
to equality) are then asserted on top.
"""

import dataclasses

import numpy as np
import pytest

from repro.apps import (lww_merge, run_disaster_recovery,
                        run_reconciliation)
from repro.core import FailureScenario, RSMConfig, SimConfig

BFT1 = RSMConfig.bft(1)
CFT1 = RSMConfig.cft(1)

SIM = SimConfig(n_msgs=32, steps=80, window=1, phi=6, window_slots=24,
                chunk_steps=4)

LAGGY = FailureScenario(crash_r=(2, 2, -1, -1))
BYZ = FailureScenario(byz_recv_drop=(True, False, False, False))

# (name, crash_at, backup_failures) — >=3 clusters in every fixture.
DR_FIXTURES = [
    ("clean_no_crash", None, {}),
    ("crash_late", 10, {"backup-1": LAGGY}),
    ("crash_early_truncates", 3, {"backup-1": LAGGY}),
    ("three_backups", 6, {"backup-1": LAGGY, "backup-2": BYZ}),
]


def _dr(name, crash_at, fails, use_reference):
    backups = sorted({"backup-0", "backup-1"} | set(fails))
    return run_disaster_recovery(
        BFT1, BFT1, SIM, backups=backups, crash_at=crash_at,
        backup_failures=fails, use_reference=use_reference)


@pytest.mark.parametrize("name,crash_at,fails", DR_FIXTURES,
                         ids=[f[0] for f in DR_FIXTURES])
def test_disaster_recovery_matches_oracle(name, crash_at, fails):
    rep = _dr(name, crash_at, fails, use_reference=False)
    ref = _dr(name, crash_at, fails, use_reference=True)
    assert rep.elected == ref.elected
    assert rep.phase1_prefixes == ref.phase1_prefixes
    assert rep.final_prefixes == ref.final_prefixes
    assert rep.converged == ref.converged
    assert np.array_equal(rep.recovered_log, ref.recovered_log)
    # the underlying per-link outputs are bit-identical too
    for lname, lr in rep.phase1.links.items():
        rr = ref.phase1[lname]
        for out in ("quack_time", "deliver_time", "retry", "recv_has"):
            assert np.array_equal(np.asarray(getattr(lr.result, out)),
                                  np.asarray(getattr(rr.result, out))), \
                (lname, out)


@pytest.mark.parametrize("name,crash_at,fails", DR_FIXTURES,
                         ids=[f[0] for f in DR_FIXTURES])
def test_disaster_recovery_semantics(name, crash_at, fails):
    rep = _dr(name, crash_at, fails, use_reference=False)
    # the election picked a most-caught-up backup
    assert rep.phase1_prefixes[rep.elected] == max(
        rep.phase1_prefixes.values())
    # everyone converged to the elected backup's log
    assert rep.converged
    for b, p in rep.final_prefixes.items():
        assert p == rep.recovered_entries, b
    assert np.array_equal(rep.recovered_log,
                          np.arange(rep.recovered_entries))


def test_disaster_recovery_crash_truncates_log():
    """An early primary crash really loses tail entries: the recovered
    log is a strict prefix, and the catch-up stream only carries it."""
    rep = _dr("trunc", 3, {"backup-1": LAGGY}, use_reference=False)
    assert 0 < rep.recovered_entries < SIM.n_msgs
    assert rep.phase2 is not None
    assert rep.converged


def test_disaster_recovery_laggy_backup_not_elected():
    rep = _dr("lag", 10, {"backup-1": LAGGY}, use_reference=False)
    assert rep.elected == "backup-0"
    assert rep.phase1_prefixes["backup-1"] < rep.phase1_prefixes["backup-0"]


# --- reconciliation ---------------------------------------------------------

def _stores_2way():
    return {
        "a": {k: (k * 10, 1) for k in range(12)} | {50: (7, 5)},
        "b": {k: (k * 10, 1) for k in range(6)} | {50: (1, 1),
                                                   60: (9, 2)},
    }


def _stores_3way():
    return {
        "a": {k: (k, 2) for k in range(8)},
        "b": {k: (k + 1, 1) for k in range(8)} | {20: (4, 4)},
        "c": {30: (5, 1)},
    }


RECON_SIM = SimConfig(n_msgs=16, steps=60, window=1, phi=6,
                      window_slots=16, chunk_steps=4)

RECON_FIXTURES = [
    ("two_way", _stores_2way, RECON_SIM, {}),
    ("three_way", _stores_3way, RECON_SIM, {}),
    ("two_way_byz_link", _stores_2way, RECON_SIM,
     {"a->b": FailureScenario(byz_recv_drop=(True, False, False, False))}),
    ("three_way_small_stream", _stores_3way,
     dataclasses.replace(RECON_SIM, n_msgs=4, steps=40, window_slots=4),
     {}),
]


@pytest.mark.parametrize("name,mk,sim,fails", RECON_FIXTURES,
                         ids=[f[0] for f in RECON_FIXTURES])
def test_reconciliation_matches_oracle(name, mk, sim, fails):
    r = run_reconciliation(BFT1, mk(), sim, failures=fails)
    ref = run_reconciliation(BFT1, mk(), sim, failures=fails,
                             use_reference=True)
    assert r.rounds == ref.rounds
    assert r.exchanged == ref.exchanged
    assert r.stores == ref.stores
    assert r.converged == ref.converged


@pytest.mark.parametrize("name,mk,sim,fails", RECON_FIXTURES,
                         ids=[f[0] for f in RECON_FIXTURES])
def test_reconciliation_converges_to_lww_union(name, mk, sim, fails):
    stores = mk()
    expect: dict = {}
    for s in stores.values():
        lww_merge(expect, [(k, v, ver) for k, (v, ver) in s.items()])
    r = run_reconciliation(BFT1, stores, sim, failures=fails)
    assert r.converged, r.rounds
    for n, s in r.stores.items():
        assert s == expect, n


def test_reconciliation_small_stream_needs_multiple_rounds():
    """A stream shorter than the delta forces chunking across rounds."""
    stores = _stores_3way()
    sim = dataclasses.replace(RECON_SIM, n_msgs=4, steps=40,
                              window_slots=4)
    r = run_reconciliation(BFT1, stores, sim)
    assert r.rounds > 1 and r.converged


def test_reconciliation_already_converged_is_a_noop():
    stores = {"a": {1: (2, 3)}, "b": {1: (2, 3)}}
    r = run_reconciliation(BFT1, stores, RECON_SIM)
    assert r.rounds == 0 and r.converged and r.exchanged == 0


def test_lww_merge_commutative_idempotent():
    entries = [(1, 5, 2), (1, 9, 1), (2, 3, 3), (1, 5, 2)]
    a: dict = {}
    lww_merge(a, entries)
    b: dict = {}
    for e in reversed(entries):
        lww_merge(b, [e])
    assert a == b == {1: (5, 2), 2: (3, 3)}
