"""Consensus stream models (§6.4 heterogeneous RSM case study)."""

import pytest

from repro.consensus import (AlgorandModel, FileModel, PBFTModel, RaftModel,
                             coupled_throughput)
from repro.core.types import RSMConfig


def test_baseline_rates_match_paper():
    assert PBFTModel().commit_rate == 39_000
    assert RaftModel().commit_rate == 39_000
    assert AlgorandModel().commit_rate == 130
    assert FileModel().commit_rate == float("inf")


def test_coupling_overhead_below_15_percent():
    """Paper: < 15% RSM throughput decrease in the worst case when PICSOU
    is attached and C3B keeps pace."""
    for model in (PBFTModel(), RaftModel(), AlgorandModel()):
        base = model.commit_rate
        with_c3b = coupled_throughput(base, c3b_rate=base * 10)
        assert with_c3b >= 0.85 * base


def test_slow_fast_coupling():
    """Algorand (130/s) must be able to feed Raft (39k/s): the pair runs at
    the slower RSM's rate, not at zero."""
    out = coupled_throughput(AlgorandModel().commit_rate,
                             c3b_rate=RaftModel().commit_rate)
    assert out == pytest.approx(130 * 0.98)


def test_cert_bytes():
    cfg = RSMConfig.bft(1)
    assert PBFTModel().cert_bytes(cfg) > RaftModel().cert_bytes(cfg)
