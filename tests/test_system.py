"""End-to-end system tests: training convergence, restart determinism,
ddp-vs-pjit equivalence, serving."""

import pytest

from helpers import run_py

pytestmark = pytest.mark.slow     # end-to-end runs; full CI tier only


def test_training_loss_decreases():
    out = run_py("""
import argparse
from repro.launch.train import run
args = argparse.Namespace(arch='starcoder2-3b-smoke', steps=40, seq=64,
                          batch=8, mesh='2x2', mode='pjit', sync='picsou',
                          compress=False, ckpt_dir='', ckpt_every=10,
                          restore=False, seed=0, lr=1e-2)
losses = run(args)
first = sum(losses[:5]) / 5
last = sum(losses[-5:]) / 5
assert last < first - 0.05, (first, last)
print('CONVERGE-OK', first, '->', last)
""", devices=8, timeout=600)
    assert "CONVERGE-OK" in out


def test_ddp_picsou_matches_pjit_losses():
    """Same init + same data: the explicit picsou-sync DDP path and the
    GSPMD pjit path must produce the same loss trajectory."""
    out = run_py("""
import argparse
from repro.launch.train import run
kw = dict(arch='granite-8b-smoke', steps=4, seq=32, batch=8,
          compress=False, ckpt_dir='', ckpt_every=10, restore=False,
          seed=0, lr=3e-4)
l_pjit = run(argparse.Namespace(mesh='2x2', mode='pjit', sync='picsou',
                                **kw))
l_ddp = run(argparse.Namespace(mesh='2x2x2', mode='ddp', sync='picsou',
                               **kw))
l_ata = run(argparse.Namespace(mesh='2x2x2', mode='ddp', sync='ata', **kw))
for a, b in zip(l_pjit, l_ddp):
    assert abs(a - b) < 5e-2, (l_pjit, l_ddp)
for a, b in zip(l_ddp, l_ata):
    assert abs(a - b) < 1e-4, (l_ddp, l_ata)
print('EQUIV-OK')
""", devices=8, timeout=600)
    assert "EQUIV-OK" in out


def test_checkpoint_restart_continues_exactly(tmp_path):
    out = run_py(f"""
import argparse
from repro.launch.train import run
kw = dict(arch='starcoder2-3b-smoke', seq=32, batch=8, mesh='2x2',
          mode='pjit', sync='picsou', compress=False, ckpt_every=4,
          seed=0, lr=3e-4)
a = run(argparse.Namespace(steps=8, ckpt_dir='{tmp_path}', restore=False,
                           **kw))
b = run(argparse.Namespace(steps=12, ckpt_dir='', restore=False, **kw))
# restart from the step-7 checkpoint: steps 8..11 must match reference b
c = run(argparse.Namespace(steps=4, ckpt_dir='{tmp_path}', restore=True,
                           **kw))
print('RESUMED', c)
for x, y in zip(b[8:12], c):
    assert abs(x - y) < 2e-3, (b[8:12], c)
print('RESTART-OK')
""", devices=8, timeout=600)
    assert "RESTART-OK" in out


def test_serving_generates():
    out = run_py("""
import argparse
from repro.launch.serve import run
args = argparse.Namespace(arch='granite-8b-smoke', batch=2, prompt_len=16,
                          gen=4, mesh='2x2', seed=0)
gen = run(args)
assert gen.shape == (2, 5)
print('SERVE-OK')
""", devices=8, timeout=600)
    assert "SERVE-OK" in out


def test_compressed_sync_trains():
    out = run_py("""
import argparse
from repro.launch.train import run
args = argparse.Namespace(arch='granite-8b-smoke', steps=6, seq=32,
                          batch=8, mesh='2x2x2', mode='ddp', sync='picsou',
                          compress=True, ckpt_dir='', ckpt_every=10,
                          restore=False, seed=0, lr=3e-4)
losses = run(args)
assert all(l == l for l in losses)  # finite
print('COMPRESS-OK')
""", devices=8, timeout=600)
    assert "COMPRESS-OK" in out
