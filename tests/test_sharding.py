"""Sharding planner + small-mesh dry-run integration tests."""

import pytest

from helpers import run_py


def test_spec_for_divisibility():
    out = run_py("""
from repro.launch.mesh import make_mesh
from repro.models.sharding import spec_for, DEFAULT_RULES
from jax.sharding import PartitionSpec as P
mesh = make_mesh((2, 4), ('data', 'model'))
# heads=8 divisible by model=4 -> sharded
s = spec_for(mesh, ('batch','seq','heads','head_dim'), (8, 16, 8, 64))
assert s == P('data', None, 'model', None), s
# kv_heads=2 NOT divisible by 4 -> dropped
s = spec_for(mesh, ('batch','seq','kv_heads','head_dim'), (8, 16, 2, 64))
assert s == P('data', None, None, None), s
# cache_seq fallback rule grabs model instead
rules = dict(DEFAULT_RULES, cache_seq='model')
s = spec_for(mesh, ('batch','cache_seq','kv_heads','head_dim'),
             (8, 64, 2, 64), rules)
assert s == P('data', 'model', None, None), s
# axis used at most once: batch over (pod,data) on a 3D mesh
mesh3 = make_mesh((2, 2, 2), ('pod','data','model'))
s = spec_for(mesh3, ('batch','seq','embed'), (8, 16, 32))
assert s == P(('pod','data'), None, None), s
print('SHARDING-OK')
""", devices=8)
    assert "SHARDING-OK" in out


def test_rules_for_kv_fallback():
    out = run_py("""
from repro.launch.mesh import make_mesh
from repro.launch.steps import rules_for
from repro.configs import get_config
mesh = make_mesh((2, 4), ('data', 'model'))
r1 = rules_for(get_config('qwen2-72b'), mesh)      # kv=8 div by 4 -> no fb
assert r1['cache_seq'] is None, r1['cache_seq']
r2 = rules_for(get_config('hymba-1.5b'), mesh)     # kv=5 not div by 4
assert r2['cache_seq'] == 'model'
print('RULES-OK')
""", devices=8)
    assert "RULES-OK" in out


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("granite-8b", "train_4k"),
    ("deepseek-moe-16b", "decode_32k"),
    ("rwkv6-7b", "train_4k"),
])
def test_small_mesh_lower_compile(arch, shape):
    """Reduced-config lower+compile on a (2,2,2) mesh + roofline parse."""
    out = run_py(f"""
import dataclasses
from repro.configs import SHAPES, get_config
from repro.launch import steps as S
from repro.launch.mesh import make_mesh
from repro.roofline import analyze_compiled

cfg = dataclasses.replace(get_config('{arch}').smoke(), remat=True,
                          dtype='bfloat16')
sh = dataclasses.replace(SHAPES['{shape}'], seq_len=64, global_batch=4)
mesh = make_mesh((2,2,2), ('pod','data','model'))
with mesh:
    b = S.build_step(cfg, mesh, sh)
    compiled = b.lower().compile()
    rep = analyze_compiled(compiled, cfg, sh, 'test', 8)
assert rep.hlo_flops_per_chip > 0
assert rep.hlo_bytes_per_chip > 0
print('CELL-OK', rep.bottleneck)
""", devices=8, timeout=420)
    assert "CELL-OK" in out
