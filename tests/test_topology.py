"""Multi-link topology invariants: engine vs oracle, vmap isolation,
chained-delivery prefix consistency.

Every fixture runs the vmapped multi-link engine (one windowed dispatch
per chunk across all links) and the pure-numpy multi-link oracle, and
all per-message outputs, GC-frontier trajectories and commit-floor
trajectories must agree bit-for-bit. Unchained links must additionally
be bit-identical to their standalone single-link runs (no cross-link
state bleed under ``jax.vmap``), and chained links must never deliver —
or commit — anything their upstream link has not delivered.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import FailureScenario, RSMConfig, SimConfig
from repro.core.simulator import run_simulation
from repro.topology import (LinkSpec, Topology, link_specs, run_topology,
                            run_topology_reference)

BFT1 = RSMConfig.bft(1)
CFT1 = RSMConfig.cft(1)

OUTPUTS = ("quack_time", "deliver_time", "retry", "recv_has")

SIM = SimConfig(n_msgs=24, steps=80, window=1, phi=6, window_slots=16,
                chunk_steps=4)

RECV_BYZ = FailureScenario(byz_recv_drop=(True, False, False, False))
RECV_CRASH = FailureScenario(crash_r=(2, 2, -1, -1))
GC_STALL = FailureScenario(byz_bcast_partial=(True, False, False, False),
                           bcast_limit=2)

# (name, topology) — >=3-cluster shapes included for every constructor.
FIXTURES = [
    ("pair_clean", Topology.pair("a", "b", BFT1, SIM)),
    ("pair_one_byz", Topology.pair("a", "b", BFT1, SIM,
                                   failures_ab=RECV_BYZ)),
    ("fanout_3c", Topology.fanout("p", ["b0", "b1"], BFT1, SIM,
                                  failures={"b1": RECV_CRASH})),
    ("fanout_4c", Topology.fanout("p", ["b0", "b1", "b2"], BFT1, SIM,
                                  failures={"b0": RECV_BYZ,
                                            "b2": RECV_CRASH})),
    ("chain_3c", Topology.chain(["a", "b", "c"], BFT1, SIM)),
    ("chain_4c_fault", Topology.chain(
        ["a", "b", "c", "d"], BFT1, SIM,
        failures={"b->c": RECV_CRASH})),
    ("chain_cft", Topology.chain(["a", "b", "c"], CFT1, dataclasses.replace(
        SIM, n_msgs=12, steps=60, window_slots=12))),
    ("chain_gc_stall", Topology.chain(
        ["a", "b", "c"], BFT1,
        dataclasses.replace(SIM, steps=140, chunk_steps=8),
        failures={"a->b": GC_STALL})),
]
IDS = [f[0] for f in FIXTURES]


@pytest.mark.parametrize("name,topo", FIXTURES, ids=IDS)
def test_engine_matches_oracle(name, topo):
    """The vmapped engine and the numpy multi-link mirror agree
    bit-for-bit on every output, frontier and commit-floor trajectory."""
    er = run_topology(topo)
    rr = run_topology_reference(topo)
    for lname in topo.link_names:
        a, b = er[lname], rr[lname]
        for out in OUTPUTS:
            assert np.array_equal(np.asarray(getattr(a.result, out)),
                                  np.asarray(getattr(b.result, out))), \
                (lname, out)
        assert np.array_equal(a.result.gc_frontiers,
                              b.result.gc_frontiers), lname
        assert np.array_equal(a.commit_floors, b.commit_floors), lname


@pytest.mark.parametrize("name,topo", FIXTURES, ids=IDS)
def test_per_link_frontier_monotone(name, topo):
    """Each link's GC frontier only ever advances, never past its stream,
    and its commit floors are monotone too (retired prefixes are)."""
    er = run_topology(topo)
    for lname in topo.link_names:
        lr = er[lname]
        assert (np.diff(lr.result.gc_frontiers) >= 0).all(), lname
        assert lr.result.gc_frontiers[-1] <= topo.sim.n_msgs, lname
        assert (np.diff(lr.commit_floors) >= 0).all(), lname


@pytest.mark.parametrize("name,topo", [f for f in FIXTURES
                                       if "chain" not in f[0]],
                         ids=[i for i in IDS if "chain" not in i])
def test_no_cross_link_state_bleed(name, topo):
    """Unchained links are bit-identical to their standalone runs: one
    lane of the vmapped batch cannot observe another lane's state."""
    er = run_topology(topo)
    for spec, l in zip(link_specs(topo), topo.links):
        solo = run_simulation(spec)
        lr = er[l.name]
        for out in OUTPUTS:
            assert np.array_equal(np.asarray(getattr(lr.result, out)),
                                  np.asarray(getattr(solo, out))), \
                (l.name, out)
        assert np.array_equal(lr.result.gc_frontiers, solo.gc_frontiers)


@pytest.mark.parametrize("name,topo", [f for f in FIXTURES
                                       if "chain" in f[0]],
                         ids=[i for i in IDS if "chain" in i])
def test_chained_delivery_prefix_consistency(name, topo):
    """Downstream commits ⊆ upstream delivered: a chained link never
    originates (commit floor), delivers or quacks anything its upstream
    link has not durably delivered — mirrored in the oracle."""
    for res in (run_topology(topo), run_topology_reference(topo)):
        for l in topo.links:
            if l.upstream is None:
                continue
            dn, up = res[l.name], res[l.upstream]
            up_mask = up.delivered_mask()
            # the commit floor the link ran under never exceeds the
            # upstream delivered prefix (it is the retired prefix, which
            # is quacked => delivered)
            assert dn.commit_floors.max() <= up.delivered_prefix(), l.name
            dn_mask = dn.delivered_mask()
            assert not (dn_mask & ~up_mask).any(), l.name
            # and the downstream *delivered prefix* is contained in the
            # upstream one (prefix consistency, not just set inclusion)
            assert dn.delivered_prefix() <= up.delivered_prefix(), l.name


def test_chain_end_to_end_delivery():
    """With enough rounds the whole chain drains: the last hop delivers
    the full stream even though every hop is commit-gated."""
    topo = Topology.chain(["a", "b", "c"], BFT1, SIM)
    er = run_topology(topo)
    assert er["b->c"].delivered_prefix() == SIM.n_msgs
    # gating is real: the downstream floor actually started below m and rose
    floors = er["b->c"].commit_floors
    assert floors[0] == 0 and floors[-1] == SIM.n_msgs


def test_single_vmapped_dispatch_per_chunk(monkeypatch):
    """No per-link Python loop over compiled calls: each chunk costs
    exactly ONE vmapped dispatch covering every link of the graph."""
    from repro.core import simulator as sim_mod

    calls = []
    real = sim_mod._compiled_batch_chunk

    def counting(*args, **kwargs):
        fn = real(*args, **kwargs)

        def wrapped(fails, state, t0):
            calls.append(int(np.asarray(fails.crash_s).shape[0]))
            return fn(fails, state, t0)
        return wrapped

    monkeypatch.setattr(sim_mod, "_compiled_batch_chunk", counting)
    topo = Topology.fanout("p", ["b0", "b1", "b2"], BFT1, SIM)
    run_topology(topo)
    n_chunks = -(-SIM.steps // SIM.chunk_steps)
    assert len(calls) == n_chunks            # one dispatch per chunk...
    assert set(calls) == {len(topo.links)}   # ...covering all links at once


def test_topology_validation():
    with pytest.raises(ValueError, match="unknown cluster"):
        Topology(clusters={"a": BFT1},
                 links=(LinkSpec("x", "a", "b"),), sim=SIM)
    with pytest.raises(ValueError, match="self-loop"):
        Topology(clusters={"a": BFT1},
                 links=(LinkSpec("x", "a", "a"),), sim=SIM)
    with pytest.raises(ValueError, match="cycle"):
        Topology(clusters={"a": BFT1, "b": BFT1},
                 links=(LinkSpec("x", "a", "b", upstream="y"),
                        LinkSpec("y", "b", "a", upstream="x")), sim=SIM)
    with pytest.raises(ValueError, match="share"):
        Topology(clusters={"a": BFT1, "b": BFT1, "c": CFT1},
                 links=(LinkSpec("x", "a", "b"),
                        LinkSpec("y", "a", "c")), sim=SIM)


def test_auto_window_forces_chunked_execution():
    """window_slots='auto' on a small stream clamps to dense for a
    standalone run, but topology execution keeps chunk boundaries (full
    width) so the commit plumbing can run — results unchanged."""
    sim = dataclasses.replace(SIM, window_slots="auto")
    topo = Topology.chain(["a", "b", "c"], BFT1, sim)
    specs = link_specs(topo)
    assert all(s.window_slots > 0 for s in specs)
    er = run_topology(topo)
    assert er["b->c"].delivered_prefix() == sim.n_msgs
