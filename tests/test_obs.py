"""Observability stack: in-graph metrics fabric, span tracer, reports.

The layer's contract has three legs, and each gets direct coverage:

* **Exactness** — the device-accumulated delivery-latency histogram
  equals the numpy histogram of the per-message ``delivery_latency``
  array for every path (dense, windowed, superchunk-fused, batched
  sweeps, chained topologies, replay resume), and metrics collection
  never perturbs the simulation itself (bit-identical outputs on vs
  off).
* **Zero overhead on the dispatch path** — ``collect_metrics=True``
  adds 0 device dispatches and 0 implicit transfers (the block rides
  the existing drain), at most one extra compile, and with metrics off
  the staged jaxprs are byte-identical to a never-instrumented build.
* **Reporting** — spans carry the canonical engine names, the Chrome
  trace validates against the Perfetto-loadable schema, RunReports
  round-trip through npz+json, and the CLI selftest gate passes.
"""

import dataclasses
import json
import os
import sys

import numpy as np
import pytest

from helpers import REPO
from repro.core import FailureScenario, RSMConfig, SimConfig
from repro.core.refsim import run_reference
from repro.core.simulator import (build_spec, chunk_dispatch_count,
                                  chunk_trace_count, run_simulation,
                                  run_simulation_batch)
from repro.obs.metrics import (NUM_LATENCY_BUCKETS, bucket_label,
                               latency_histogram_np, percentile_from_hist)
from repro.obs.report import (RunReport, run_reported,
                              run_reported_topology, validate_chrome_trace)
from repro.obs.tracer import SpanTracer, obs_span, tracing

BFT1 = RSMConfig.bft(1)
OUTPUTS = ("quack_time", "deliver_time", "retry", "recv_has")

GC_STALL = FailureScenario(byz_bcast_partial=(True, False, False, False),
                           bcast_limit=2)
STALL_PLUS_CRASH = FailureScenario(
    byz_bcast_partial=(True, False, False, False), bcast_limit=2,
    crash_r=(-1, 8, -1, -1))

# same fusion-break classes as tests/test_pipeline.py: rotation-only,
# adaptive growth, dense-layout fallback, crashed sender
FIXTURES = [
    ("rotating", dict(n_msgs=128, steps=128 // 4 + 40, window=1, phi=6,
                      window_slots=32, chunk_steps=4),
     FailureScenario.none()),
    ("adaptive_growth", dict(n_msgs=128, steps=128 // 4 + 80, window=1,
                             phi=6, window_slots=16, chunk_steps=8),
     GC_STALL),
    ("dense_fallback", dict(n_msgs=64, steps=200, window=1, phi=6,
                            window_slots=16, chunk_steps=8),
     STALL_PLUS_CRASH),
    ("crash_sender", dict(n_msgs=24, steps=150, window=1, phi=6,
                          window_slots=24, chunk_steps=8),
     FailureScenario(crash_s=(1, -1, -1, -1))),
]
IDS = [f[0] for f in FIXTURES]


def _spec(simkw, fails, k, collect=False):
    sim = SimConfig(debug_checks=True, superchunk=k,
                    collect_metrics=collect, **simkw)
    return build_spec(BFT1, BFT1, sim, fails)


def _assert_same_run(a, b):
    for out in OUTPUTS:
        assert np.array_equal(getattr(a, out), getattr(b, out)), out
    assert np.array_equal(a.gc_frontiers, b.gc_frontiers)
    assert np.array_equal(a.send_step, b.send_step)
    assert np.array_equal(a.delivery_latency, b.delivery_latency)
    assert a.window_growth_events == b.window_growth_events


# --- exactness: device metrics vs numpy oracles --------------------------

@pytest.mark.parametrize("k", [1, 8])
@pytest.mark.parametrize("name,simkw,fails", FIXTURES, ids=IDS)
def test_metrics_exact_and_nonperturbing(name, simkw, fails, k):
    """Metrics-on ≡ metrics-off bit-for-bit, and the device histogram
    equals the numpy histogram of the per-message latency array, across
    every fusion-break class at K ∈ {1, 8}."""
    off = run_simulation(_spec(simkw, fails, k))
    on = run_simulation(_spec(simkw, fails, k, collect=True))
    _assert_same_run(off, on)
    assert off.obs is None and on.obs is not None
    oracle = latency_histogram_np(on.delivery_latency)
    assert np.array_equal(np.asarray(on.obs.latency_hist), oracle)
    delivered = int((np.asarray(on.deliver_time) >= 0).sum())
    assert on.obs.total_counted() + on.obs.uncounted == delivered
    assert on.obs.uncounted == 0
    assert on.obs.resend_total == int(np.sum(on.metrics.resends))


def test_dense_path_metrics_exact():
    """The dense (window_slots=None) kernel populates send_step /
    delivery_latency / obs from the same oracle-checked rule."""
    simkw = dict(n_msgs=64, steps=120, window=1, phi=6)
    fails = FailureScenario(crash_s=(5, -1, -1, -1))
    sim = SimConfig(collect_metrics=True, **simkw)
    r = run_simulation(build_spec(BFT1, BFT1, sim, fails))
    assert r.obs is not None
    oracle = latency_histogram_np(r.delivery_latency)
    assert np.array_equal(np.asarray(r.obs.latency_hist), oracle)
    # windowed at full width must agree with dense exactly
    rw = run_simulation(_spec(dict(window_slots=64, chunk_steps=8,
                                   **simkw), fails, 8, collect=True))
    assert np.array_equal(r.delivery_latency, rw.delivery_latency)
    assert np.array_equal(np.asarray(r.obs.latency_hist),
                          np.asarray(rw.obs.latency_hist))


def test_batched_sweep_metrics_exact():
    """Vmapped scenario sweeps: each lane's histogram matches its own
    latency array (per-lane carries through the K=8 fused kernel)."""
    simkw = dict(n_msgs=128, steps=128 // 4 + 60, window=1, phi=6,
                 window_slots=32, chunk_steps=8)
    scenarios = [FailureScenario.none(), GC_STALL,
                 FailureScenario(crash_s=(1, -1, -1, -1)),
                 FailureScenario.crash_fraction(4, 4, 0.33, seed=1)]
    rs = run_simulation_batch(
        [_spec(simkw, f, 8, collect=True) for f in scenarios])
    for r in rs:
        assert np.array_equal(np.asarray(r.obs.latency_hist),
                              latency_histogram_np(r.delivery_latency))
        assert r.obs.uncounted == 0


@pytest.mark.parametrize("name,simkw,fails", FIXTURES[:3], ids=IDS[:3])
def test_delivery_latency_matches_refsim(name, simkw, fails):
    """``SimResult.send_step`` / ``delivery_latency`` are bit-identical
    to the numpy reference machine's mirrors."""
    r = run_simulation(_spec(simkw, fails, 8))
    ref = run_reference(_spec(simkw, fails, 1))
    assert np.array_equal(r.send_step, ref.send_step)
    assert np.array_equal(r.delivery_latency, ref.delivery_latency)


def test_topology_chain_metrics_exact():
    """Chained topology: per-link histograms match per-link latency
    arrays, metrics collection leaves chained results untouched, and
    the refsim topology mirror agrees on the latency arrays."""
    from repro.topology.engine import run_topology
    from repro.topology.graph import Topology
    from repro.topology.refmirror import run_topology_reference

    SIM = SimConfig(n_msgs=96, steps=96 // 4 + 60, window=1, phi=6,
                    window_slots=24, chunk_steps=8)
    SIM_ON = dataclasses.replace(SIM, collect_metrics=True)
    r_off = run_topology(Topology.chain(["a", "b", "c"], BFT1, SIM))
    r_on = run_topology(Topology.chain(["a", "b", "c"], BFT1, SIM_ON))
    ref = run_topology_reference(Topology.chain(["a", "b", "c"], BFT1,
                                                SIM))
    for name in ("a->b", "b->c"):
        a, b = r_on[name].result, r_off[name].result
        _assert_same_run(a, b)
        assert np.array_equal(np.asarray(a.obs.latency_hist),
                              latency_histogram_np(a.delivery_latency))
        rr = ref[name].result
        assert np.array_equal(a.send_step, rr.send_step)
        assert np.array_equal(a.delivery_latency, rr.delivery_latency)


def test_replay_resume_metrics_exact(tmp_path):
    """A resumed replay reproduces send_step/delivery_latency exactly
    (in-flight send times cross the checkpoint via the serialized
    mirror), and its segment-scoped histogram matches the numpy oracle
    restricted to post-checkpoint deliveries."""
    from repro.replay import record_simulation, replay
    from repro.replay.trace import RunTrace

    simkw = dict(n_msgs=96, steps=120, window=1, phi=6,
                 window_slots=24, chunk_steps=8)
    spec = _spec(simkw, FailureScenario(crash_s=(16, -1, -1, -1)), 8,
                 collect=True)
    r0, trace = record_simulation(spec)
    path = os.path.join(str(tmp_path), "trace.npz")
    trace.save(path)
    loaded = RunTrace.load(path)
    for c0, c1 in zip(trace.checkpoints, loaded.checkpoints):
        assert (c0.send_step is None) == (c1.send_step is None)
        if c0.send_step is not None:
            assert np.array_equal(c0.send_step, c1.send_step)
    mid = int(trace.boundaries()[len(trace.boundaries()) // 2])
    rr = replay(loaded, mid)[0]
    _assert_same_run(r0, rr)
    seg_lat = np.where(np.asarray(r0.deliver_time) >= mid,
                       np.asarray(r0.delivery_latency), -1)
    assert np.array_equal(np.asarray(rr.obs.latency_hist),
                          latency_histogram_np(seg_lat))
    assert rr.obs.uncounted == 0


# --- overhead: the zero-new-transfers contract ---------------------------

@pytest.mark.parametrize("k", [1, 8])
def test_metrics_overhead_contract(k):
    """collect_metrics=True adds 0 dispatches, 0 implicit transfers and
    0 warm recompiles (≤1 extra compile cold) vs metrics-off — asserted
    via the analysis sanitizer's dispatch contract."""
    from repro.analysis import dispatch_contract, sanitized

    # shape unique to this test so the cold-compile deltas are real
    simkw = dict(n_msgs=136, steps=136 // 4 + 40, window=1, phi=6,
                 window_slots=34, chunk_steps=4)
    off = _spec(simkw, FailureScenario.none(), k)
    on = _spec(simkw, FailureScenario.none(), k, collect=True)

    t0 = chunk_trace_count()
    run_simulation(off)
    cold_off = chunk_trace_count() - t0
    t0 = chunk_trace_count()
    run_simulation(on)
    cold_on = chunk_trace_count() - t0
    assert cold_on <= cold_off + 1, (cold_on, cold_off)

    with sanitized(dispatch_contract(off, warm=True)) as rep_off:
        r_off = run_simulation(off)
    with sanitized(dispatch_contract(on, warm=True)) as rep_on:
        r_on = run_simulation(on)
    _assert_same_run(r_off, r_on)
    assert rep_on.dispatches == rep_off.dispatches
    assert rep_on.transfers == () and rep_off.transfers == ()


def test_metrics_off_jaxprs_byte_identical():
    """Turning collect_metrics on and back off rebuilds byte-identical
    programs: the flag is a static Python branch, so disabled metrics
    cannot perturb staging (same cache key, same jaxpr text)."""
    import jax
    import jax.numpy as jnp

    from repro.core.simulator import (_build_chunk, _build_run,
                                      _fail_arrays, _init_state, _neutral)

    sim = SimConfig(n_msgs=48, steps=60, window=1, phi=6,
                    window_slots=12, chunk_steps=4)
    spec_off = build_spec(BFT1, BFT1, sim)
    spec_on = dataclasses.replace(spec_off, collect_metrics=True)
    spec_off2 = dataclasses.replace(spec_on, collect_metrics=False)
    assert spec_off2 == spec_off        # compile-cache key equality

    nspec = _neutral(spec_off)
    nspec2 = _neutral(spec_off2)
    assert nspec2 == nspec
    fails, state = _fail_arrays(spec_off), _init_state(nspec, 12)
    cspec = dataclasses.replace(nspec, steps=0)
    cspec2 = dataclasses.replace(nspec2, steps=0)
    t0 = jnp.int32(0)
    jp = str(jax.make_jaxpr(_build_chunk(cspec, 12, 4, True))(
        fails, state, t0))
    jp2 = str(jax.make_jaxpr(_build_chunk(cspec2, 12, 4, True))(
        fails, state, t0))
    assert jp == jp2
    assert str(jax.make_jaxpr(_build_run(nspec))(fails)) == \
        str(jax.make_jaxpr(_build_run(nspec2))(fails))
    # and the metrics-on program is genuinely different (the fabric
    # exists when asked for)
    mspec = dataclasses.replace(cspec, collect_metrics=True)
    from repro.obs.metrics import init_metrics_carry
    jp_on = str(jax.make_jaxpr(_build_chunk(mspec, 12, 4, True))(
        fails, (state, init_metrics_carry(12)), t0))
    assert jp_on != jp


# --- unit: buckets and percentiles ---------------------------------------

def test_bucket_edges_and_percentiles():
    lat = np.array([0, 0, 1, 2, 3, 4, 65535, 65536, 70000, -1])
    hist = latency_histogram_np(lat)
    assert int(hist.sum()) == 9                       # -1 excluded
    assert hist[0] == 2                               # lat 0
    assert hist[1] == 1                               # lat 1
    assert hist[2] == 2                               # lat 2,3 -> [2,4)
    assert hist[3] == 1                               # lat 4 -> [4,8)
    assert hist[NUM_LATENCY_BUCKETS - 2] == 1         # 65535 < 2^16
    assert hist[NUM_LATENCY_BUCKETS - 1] == 2         # >= 2^16 sink
    assert bucket_label(0) == "0"
    assert bucket_label(1) == "1"
    assert bucket_label(2) == "2-3"
    assert bucket_label(3) == "4-7"
    assert bucket_label(NUM_LATENCY_BUCKETS - 1) == ">=65536"
    assert percentile_from_hist(np.zeros(NUM_LATENCY_BUCKETS), 50) == -1
    one = np.zeros(NUM_LATENCY_BUCKETS, dtype=int)
    one[0] = 100
    assert percentile_from_hist(one, 99) == 0
    one[3] = 1    # 1 of 101 deliveries in [4,8): p50 still bucket 0
    assert percentile_from_hist(one, 50) == 0
    assert percentile_from_hist(one, 100) == 8        # upper edge of [4,8)
    sink = np.zeros(NUM_LATENCY_BUCKETS, dtype=int)
    sink[-1] = 5
    assert percentile_from_hist(sink, 50) == 65536    # sink lower bound


def test_latency_bucket_device_matches_np():
    import jax.numpy as jnp

    from repro.obs.metrics import latency_bucket, latency_bucket_np

    lat = np.array([0, 1, 2, 3, 7, 8, 1023, 1024, 65535, 65536, 10 ** 6])
    assert np.array_equal(np.asarray(latency_bucket(jnp.asarray(lat))),
                          latency_bucket_np(lat))


# --- tracer + report -----------------------------------------------------

def test_tracer_spans_and_chrome_schema():
    tr = SpanTracer()
    with tracing(tr):
        with obs_span("outer", cat="test", k=1):
            with obs_span("inner", cat="test"):
                pass
    assert tr.count("outer") == tr.count("inner") == 1
    assert tr.total_ns("outer") >= tr.total_ns("inner")
    doc = tr.to_chrome_trace()
    assert validate_chrome_trace(doc) == []
    assert {e["name"] for e in doc["traceEvents"]} == {"outer", "inner"}
    assert "outer" in tr.summary()
    # disabled tracing records nothing and takes no clock samples
    from repro.obs.tracer import obs_begin
    assert obs_begin() is None


def test_validate_chrome_trace_rejects_malformed():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({}) != []
    bad = {"traceEvents": [{"name": "x", "cat": "c", "ph": "B",
                            "ts": 0, "dur": -1, "pid": 0, "tid": 0,
                            "args": {}}]}
    problems = validate_chrome_trace(bad)
    assert any("ph" in p for p in problems)
    assert any("negative dur" in p for p in problems)


def test_engine_emits_canonical_spans():
    """A windowed run records run/compile-or-dispatch/drain_wait/
    final_flush; a chained topology adds run_topology + plan_floors."""
    tr = SpanTracer()
    simkw = dict(n_msgs=96, steps=96 // 4 + 40, window=1, phi=6,
                 window_slots=24, chunk_steps=8)
    with tracing(tr):
        run_simulation(_spec(simkw, FailureScenario.none(), 8))
    names = set(tr.names())
    assert {"run", "drain_wait", "final_flush"} <= names
    assert names & {"compile", "dispatch"}
    assert 0.0 <= tr.drain_overlap_ratio() <= 1.0
    for s in tr.spans:
        if s.name == "drain_wait":
            assert "overlapped" in s.args

    from repro.topology.graph import Topology
    _, report = run_reported_topology(Topology.chain(
        ["a", "b", "c"], BFT1,
        SimConfig(n_msgs=64, steps=120, window=1, phi=6,
                  window_slots=16, chunk_steps=8)))
    tnames = {e["name"] for e in report.chrome_trace["traceEvents"]}
    assert {"run_topology", "plan_floors", "run"} <= tnames


def test_run_report_roundtrip(tmp_path):
    simkw = dict(n_msgs=96, steps=96 // 4 + 40, window=1, phi=6,
                 window_slots=24, chunk_steps=8)
    _, report = run_reported(_spec(simkw, GC_STALL, 8))
    assert report.validate() == []
    assert "link" in report.percentile_table()
    prefix = os.path.join(str(tmp_path), "report")
    paths = report.save(prefix)
    assert os.path.exists(paths["json"]) and os.path.exists(paths["npz"])
    back = RunReport.load(prefix)
    assert back.validate() == []
    assert np.array_equal(back.obs["link"].latency_hist,
                          report.obs["link"].latency_hist)
    assert np.array_equal(back.latency["link"], report.latency["link"])
    assert back.spans["drain_overlap_ratio"] == \
        report.spans["drain_overlap_ratio"]
    # json side is self-contained (no numpy types leak through)
    json.dumps(back.to_json_dict())


def test_report_requires_metrics():
    simkw = dict(n_msgs=48, steps=60, window=1, phi=6,
                 window_slots=12, chunk_steps=4)
    r = run_simulation(_spec(simkw, FailureScenario.none(), 1))
    from repro.obs.report import report_from_results
    with pytest.raises(ValueError, match="collect_metrics"):
        report_from_results([r], SpanTracer())


def test_obs_selftest_cli(tmp_path):
    """The CI gate: ``python -m repro.obs --selftest`` exits 0 and
    leaves the RunReport + Perfetto trace artifacts."""
    from repro.obs.__main__ import main

    out = os.path.join(str(tmp_path), "obs_out")
    assert main(["--selftest", "--out", out]) == 0
    assert os.path.exists(os.path.join(out, "report.json"))
    assert os.path.exists(os.path.join(out, "report.npz"))
    with open(os.path.join(out, "trace.json")) as f:
        assert validate_chrome_trace(json.load(f)) == []


# --- benchmarks/run.py resilience ---------------------------------------

def _bench_run_module():
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    import benchmarks.run as br
    return br


def test_bench_run_partial_failure_writes_artifacts(tmp_path,
                                                    monkeypatch, capsys):
    """A section that dies mid-sweep is recorded as failed, its BENCH
    json gets a status stub, the summary json lands anyway, and the
    exit code reflects the partial failure."""
    br = _bench_run_module()
    monkeypatch.chdir(tmp_path)

    def ok_section():
        return "fine"

    def boom():
        raise RuntimeError("sweep died mid-flight")

    monkeypatch.setattr(br, "TABLES", (
        ("good", ok_section, None),
        ("bad", boom, "BENCH_bad.json"),
    ))
    rc = br.main([])
    capsys.readouterr()
    assert rc == 1
    with open("BENCH_summary.json") as f:
        summary = json.load(f)
    assert summary["status"] == "partial"
    by_name = {s["name"]: s for s in summary["sections"]}
    assert by_name["good"]["status"] == "ok"
    assert by_name["bad"]["status"] == "failed"
    assert "sweep died" in by_name["bad"]["error"]
    with open("BENCH_bad.json") as f:
        stub = json.load(f)
    assert stub["status"] == "failed" and stub["rows"] == []


def test_bench_run_obs_attaches_metrics(tmp_path, monkeypatch, capsys):
    """--obs attaches a validated metrics section (histogram +
    percentiles + drain-overlap ratio) to every BENCH json."""
    br = _bench_run_module()
    monkeypatch.chdir(tmp_path)

    def writes_json():
        br._dump_json("BENCH_mini.json", [{"n": 1}])
        return "ok"

    monkeypatch.setattr(br, "TABLES", (
        ("mini", writes_json, "BENCH_mini.json"),))
    orig_section = br.obs_metrics_section
    monkeypatch.setattr(br, "obs_metrics_section",
                        lambda *a, **kw: orig_section(n_msgs=512, k=8))
    rc = br.main(["--obs"])
    capsys.readouterr()
    assert rc == 0
    with open("BENCH_mini.json") as f:
        doc = json.load(f)
    assert doc["rows"] == [{"n": 1}]
    m = doc["metrics"]
    assert m["validated"], m["problems"]
    assert len(m["obs"]["latency_hist"]) == NUM_LATENCY_BUCKETS
    assert m["obs"]["total_counted"] == 512
    assert 0.0 <= m["drain_overlap_ratio"] <= 1.0
    assert "p95" in m["obs"]


# --- acceptance (slow tier) ----------------------------------------------

@pytest.mark.slow
def test_acceptance_100k_superchunk_report():
    """ISSUE 8 acceptance: a 100k-message K=8 run with metrics on
    yields a RunReport whose histogram matches the numpy oracle's
    latency array exactly, with the dispatch count unchanged vs
    metrics-off (≤ ceil(C/K)+2) and a loadable Perfetto trace with
    compile/dispatch/drain spans."""
    sim = SimConfig(n_msgs=100_000, steps=100_000 // 8 + 96, window=8,
                    phi=6, window_slots="auto", chunk_steps=32,
                    superchunk=8, collect_metrics=True)
    spec = build_spec(BFT1, BFT1, sim)
    result, report = run_reported(spec)
    assert report.validate() == []
    o = report.obs["link"]
    assert o.total_counted() == 100_000
    assert np.array_equal(np.asarray(o.latency_hist),
                          latency_histogram_np(result.delivery_latency))
    for q in ("p50", "p95", "p99"):
        assert o.percentiles()[q] >= 0

    n_chunks = -(-spec.steps // spec.chunk_steps)
    bound = -(-n_chunks // 8) + 2
    assert report.meta["chunk_dispatches"] <= bound

    d0 = chunk_dispatch_count()
    off = run_simulation(dataclasses.replace(spec, collect_metrics=False))
    assert report.meta["chunk_dispatches"] == chunk_dispatch_count() - d0
    assert np.array_equal(result.deliver_time, off.deliver_time)

    doc = report.chrome_trace
    assert validate_chrome_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"drain_wait", "final_flush", "run"} <= names
    assert names & {"compile", "dispatch"}
    assert 0.0 <= report.spans["drain_overlap_ratio"] <= 1.0
