"""Elastic scaling plans."""

import numpy as np

from repro.launch.elastic import replan_membership, replan_quotas


def test_pod_loss_replans_mesh():
    plan = replan_membership([0, 1], hosts_per_pod=4, data_parallel=16,
                             model_parallel=16, last_committed_step=100)
    assert plan.mesh_shape == (2, 16, 16)
    plan = replan_membership([1], hosts_per_pod=4, data_parallel=16,
                             model_parallel=16, last_committed_step=100)
    assert plan.mesh_shape == (16, 16)
    assert plan.restore_step == 100


def test_quota_replanning_tracks_throughput():
    q = replan_quotas(np.array([4.0, 2.0, 1.0, 1.0]), quantum=16)
    assert q[0] == 8 and q[1] == 4 and q[2] == 2 and q[3] == 2


def test_quota_lcm_rescaling():
    # incommensurate pod totals: quotas still integral and proportional
    q = replan_quotas(np.array([3.0, 1.0]), quantum=8, peer_total_stake=12)
    assert sum(q.values()) == 8
    assert q[0] == 6
