"""Sliding-window simulator core: dense-vs-windowed equivalence.

Every fixture from the dense test suite runs three ways — dense jax,
windowed jax (ring buffers + chunked scans + GC-frontier rotation), and
the numpy oracle mirroring the window — and all per-message outputs,
per-round metric streams, and the GC-frontier trajectory itself must
agree bit-for-bit.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import FailureScenario, RSMConfig, SimConfig
from repro.core.quack import claim_bitmask, missing_below_horizon
from repro.core.refsim import run_reference
from repro.core.simulator import (build_spec, run_simulation,
                                  run_simulation_batch)

BFT1 = RSMConfig.bft(1)          # n=4, u=r=1
CFT1 = RSMConfig.cft(1)          # n=3, u=1, r=0

OUTPUTS = ("quack_time", "deliver_time", "retry", "recv_has")
METRICS = ("cross_msgs", "intra_msgs", "resends", "acks", "delivered",
           "min_quack_prefix")

# (name, sender, receiver, SimConfig kwargs, failures)
# window_slots < n_msgs wherever the GC frontier can advance early enough
# to rotate (exercising ring-buffer shifts); adversarial stalls keep W=M.
FIXTURES = [
    ("failure_free", BFT1, BFT1,
     dict(n_msgs=24, steps=30, window=1, phi=6, window_slots=16,
          chunk_steps=4),
     FailureScenario.none()),
    ("failure_free_w2", BFT1, BFT1,
     dict(n_msgs=24, steps=30, window=2, phi=6, window_slots=24,
          chunk_steps=2),
     FailureScenario.none()),
    ("crash_sender", BFT1, BFT1,
     dict(n_msgs=24, steps=150, window=1, phi=6, window_slots=24,
          chunk_steps=8),
     FailureScenario(crash_s=(1, -1, -1, -1))),
    ("byzantine_recv", BFT1, BFT1,
     dict(n_msgs=24, steps=200, window=1, phi=6, window_slots=24,
          chunk_steps=16),
     FailureScenario(byz_recv_drop=(True, False, False, False),
                     byz_ack_low=(False, True, False, False))),
    ("crash_plus_byz", BFT1, BFT1,
     dict(n_msgs=24, steps=240, window=1, phi=6, window_slots=24,
          chunk_steps=32),
     FailureScenario(crash_s=(2, -1, -1, -1),
                     byz_recv_drop=(True, False, False, False))),
    ("liar_low", BFT1, BFT1,
     dict(n_msgs=24, steps=150, window=1, phi=6, window_slots=24,
          chunk_steps=8),
     FailureScenario(byz_ack_low=(True, False, False, False))),
    ("cft_dup_resend", CFT1, CFT1,
     dict(n_msgs=12, steps=120, window=1, phi=6, window_slots=12,
          chunk_steps=8),
     FailureScenario(crash_s=(1, -1, -1))),
    ("gc_stall_defence", BFT1, BFT1,
     dict(n_msgs=24, steps=300, window=1, phi=6, window_slots=24,
          chunk_steps=16),
     FailureScenario(byz_bcast_partial=(True, False, False, False),
                     bcast_limit=2, crash_r=(-1, 8, -1, -1))),
    ("staked_dss", RSMConfig(n=4, u=333, r=333,
                             stakes=(333., 223., 222., 222.)),
     RSMConfig(n=4, u=333, r=333, stakes=(250., 250., 250., 250.)),
     dict(n_msgs=24, steps=80, window=2, phi=6, scheduler="dss",
          quantum=12, window_slots=24, chunk_steps=8),
     FailureScenario.none()),
    ("mixed_cft_to_bft", CFT1, BFT1,
     dict(n_msgs=24, steps=60, window=2, phi=6, window_slots=24,
          chunk_steps=4),
     FailureScenario.none()),
    ("mixed_bft_to_cft", BFT1, CFT1,
     dict(n_msgs=24, steps=60, window=2, phi=6, window_slots=24,
          chunk_steps=4),
     FailureScenario.none()),
    ("ack_advance_liar", BFT1, BFT1,
     dict(n_msgs=24, steps=120, window=1, phi=6, window_slots=24,
          chunk_steps=8),
     FailureScenario(byz_ack_advance=(3, 0, 0, 0))),
]

IDS = [f[0] for f in FIXTURES]


def _dense(spec):
    return dataclasses.replace(spec, window_slots=0, chunk_steps=0)


@pytest.mark.parametrize("name,snd,rcv,simkw,fails", FIXTURES, ids=IDS)
def test_windowed_matches_dense(name, snd, rcv, simkw, fails):
    spec_w = build_spec(snd, rcv, SimConfig(**simkw), fails)
    assert spec_w.window_slots > 0
    jw = run_simulation(spec_w)
    jd = run_simulation(_dense(spec_w))
    for out in OUTPUTS:
        assert np.array_equal(getattr(jw, out), getattr(jd, out)), out
    for mname in METRICS:
        assert np.array_equal(getattr(jw.metrics, mname),
                              getattr(jd.metrics, mname)), mname
    # the frontier only moves forward and never overtakes the quack stream
    assert (np.diff(jw.gc_frontiers) >= 0).all()
    assert jw.gc_frontiers[-1] <= spec_w.m


@pytest.mark.parametrize("name,snd,rcv,simkw,fails", FIXTURES[:6], ids=IDS[:6])
def test_refsim_mirrors_window_rotation(name, snd, rcv, simkw, fails):
    """The numpy oracle replays the same frontier trajectory and proves
    each retirement safe (snapshot assertions inside run_reference)."""
    spec_w = build_spec(snd, rcv, SimConfig(**simkw), fails)
    jw = run_simulation(spec_w)
    rw = run_reference(spec_w)          # asserts retirement safety itself
    for jout, rout in zip(OUTPUTS, ("quack_time", "deliver_time", "retry",
                                    "recv_has")):
        assert np.array_equal(getattr(jw, jout), getattr(rw, rout)), jout
    assert np.array_equal(jw.gc_frontiers, rw.gc_frontiers)
    if rw.gc_frontiers[-1] > 0:
        # §4.3: a retired slot is QUACKed at every sender — its stake-
        # weighted claim mass reached u_r + 1 before it was forgotten.
        assert rw.retired_quack_margin >= spec_w.quack_thresh


def test_rotation_actually_happens():
    spec = build_spec(BFT1, BFT1,
                      SimConfig(n_msgs=24, steps=30, window=1, phi=6,
                                window_slots=16, chunk_steps=4))
    jw = run_simulation(spec)
    assert jw.gc_frontiers.max() > 0          # window really slid
    assert (jw.deliver_time >= 0).all()


def test_window_overflow_raises():
    """A window too small for the in-flight set fails loudly, not wrongly."""
    spec = build_spec(BFT1, BFT1,
                      SimConfig(n_msgs=64, steps=40, window=4, phi=6,
                                window_slots=8, chunk_steps=4))
    with pytest.raises(ValueError, match="window overflow"):
        run_simulation(spec)


def test_long_stream_constant_state():
    """Long-horizon run: scan state is O(W), not O(M), and the stream
    completes — the paper's P1 constant-metadata invariant applied to the
    simulator itself."""
    m = 20_000
    sim = SimConfig(n_msgs=m, steps=m // 16 + 60, window=4, phi=32,
                    window_slots="auto", chunk_steps=32)
    spec = build_spec(BFT1, BFT1, sim)
    assert spec.window_slots < m // 4          # genuinely windowed
    small = build_spec(BFT1, BFT1, dataclasses.replace(
        sim, n_msgs=m // 10, steps=m // 160 + 60))
    assert spec.scan_state_nbytes() == small.scan_state_nbytes()
    r = run_simulation(spec)
    assert (r.deliver_time >= 0).all()
    assert (r.quack_time >= 0).all()
    assert r.total_cross_msgs() == m           # P1: one cross copy per msg
    assert r.gc_frontiers[-1] == m


def test_batch_matches_sequential():
    sim = SimConfig(n_msgs=24, steps=120, window=1, phi=6)
    scenarios = [
        FailureScenario.none(),
        FailureScenario(crash_s=(1, -1, -1, -1)),
        FailureScenario(byz_recv_drop=(True, False, False, False),
                        byz_ack_low=(False, True, False, False)),
        FailureScenario(byz_bcast_partial=(True, False, False, False),
                        bcast_limit=2, crash_r=(-1, 8, -1, -1)),
        FailureScenario.crash_fraction(4, 4, 0.33, seed=1),
    ]
    specs = [build_spec(BFT1, BFT1, sim, f) for f in scenarios]
    batched = run_simulation_batch(specs)
    for spec, br in zip(specs, batched):
        sr = run_simulation(spec)
        for out in OUTPUTS:
            assert np.array_equal(getattr(br, out), getattr(sr, out)), out
        for mname in METRICS:
            assert np.array_equal(getattr(br.metrics, mname),
                                  getattr(sr.metrics, mname)), mname


def test_batch_rejects_mismatched_shapes():
    a = build_spec(BFT1, BFT1, SimConfig(n_msgs=24, steps=40, window=1,
                                         phi=6))
    b = build_spec(BFT1, BFT1, SimConfig(n_msgs=32, steps=40, window=1,
                                         phi=6))
    with pytest.raises(ValueError, match="failure masks"):
        run_simulation_batch([a, b])


def test_offset_quack_ops_match_dense_slice():
    """Windowed claim/missing ops == dense ops restricted to the window,
    whenever everything below the base is received (the GC invariant)."""
    rng = np.random.RandomState(0)
    m, base, w, phi = 40, 12, 20, 3
    eff = rng.rand(5, m) < 0.6
    eff[:, :base] = True                       # window invariant
    cum_d, claim_d, known_d = claim_bitmask(eff, phi)
    miss_d = missing_below_horizon(eff, phi)
    win = eff[:, base:base + w]
    cum_w, claim_w, known_w = claim_bitmask(win, phi, base, m)
    miss_w = missing_below_horizon(win, phi, base)
    assert np.array_equal(np.asarray(cum_w), np.asarray(cum_d))
    assert np.array_equal(np.asarray(claim_w),
                          np.asarray(claim_d)[:, base:base + w])
    assert np.array_equal(np.asarray(known_w),
                          np.asarray(known_d)[:, base:base + w])
    assert np.array_equal(np.asarray(miss_w),
                          np.asarray(miss_d)[:, base:base + w])
