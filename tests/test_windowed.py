"""Sliding-window simulator core: dense-vs-windowed equivalence.

Every fixture from the dense test suite runs three ways — dense jax,
windowed jax (device-resident ring buffers: in-graph GC frontier +
``lax.dynamic_slice`` rotation, bounded per-chunk output queue), and the
numpy oracle mirroring the window — and all per-message outputs,
per-round metric streams, and the GC-frontier trajectory itself must
agree bit-for-bit. Batched windowed sweeps (per-scenario traced window
bases under ``jax.vmap``), adaptive window growth under GC-stalling
adversaries, and the automatic dense fallback are covered the same way.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import FailureScenario, RSMConfig, SimConfig
from repro.core.quack import claim_bitmask, missing_below_horizon
from repro.core.refsim import run_reference
from repro.core.simulator import (_compiled_batch_chunk, _compiled_sim,
                                  _fail_arrays, _init_state, _neutral,
                                  _stacked_fails, build_spec,
                                  run_simulation, run_simulation_batch)

BFT1 = RSMConfig.bft(1)          # n=4, u=r=1
CFT1 = RSMConfig.cft(1)          # n=3, u=1, r=0

OUTPUTS = ("quack_time", "deliver_time", "retry", "recv_has")
METRICS = ("cross_msgs", "intra_msgs", "resends", "acks", "delivered",
           "min_quack_prefix")

# (name, sender, receiver, SimConfig kwargs, failures)
# window_slots < n_msgs wherever the GC frontier can advance early enough
# to rotate (exercising ring-buffer shifts); adversarial stalls keep W=M.
FIXTURES = [
    ("failure_free", BFT1, BFT1,
     dict(n_msgs=24, steps=30, window=1, phi=6, window_slots=16,
          chunk_steps=4),
     FailureScenario.none()),
    ("failure_free_w2", BFT1, BFT1,
     dict(n_msgs=24, steps=30, window=2, phi=6, window_slots=24,
          chunk_steps=2),
     FailureScenario.none()),
    ("crash_sender", BFT1, BFT1,
     dict(n_msgs=24, steps=150, window=1, phi=6, window_slots=24,
          chunk_steps=8),
     FailureScenario(crash_s=(1, -1, -1, -1))),
    ("byzantine_recv", BFT1, BFT1,
     dict(n_msgs=24, steps=200, window=1, phi=6, window_slots=24,
          chunk_steps=16),
     FailureScenario(byz_recv_drop=(True, False, False, False),
                     byz_ack_low=(False, True, False, False))),
    ("crash_plus_byz", BFT1, BFT1,
     dict(n_msgs=24, steps=240, window=1, phi=6, window_slots=24,
          chunk_steps=32),
     FailureScenario(crash_s=(2, -1, -1, -1),
                     byz_recv_drop=(True, False, False, False))),
    ("liar_low", BFT1, BFT1,
     dict(n_msgs=24, steps=150, window=1, phi=6, window_slots=24,
          chunk_steps=8),
     FailureScenario(byz_ack_low=(True, False, False, False))),
    ("cft_dup_resend", CFT1, CFT1,
     dict(n_msgs=12, steps=120, window=1, phi=6, window_slots=12,
          chunk_steps=8),
     FailureScenario(crash_s=(1, -1, -1))),
    ("gc_stall_defence", BFT1, BFT1,
     dict(n_msgs=24, steps=300, window=1, phi=6, window_slots=24,
          chunk_steps=16),
     FailureScenario(byz_bcast_partial=(True, False, False, False),
                     bcast_limit=2, crash_r=(-1, 8, -1, -1))),
    ("staked_dss", RSMConfig(n=4, u=333, r=333,
                             stakes=(333., 223., 222., 222.)),
     RSMConfig(n=4, u=333, r=333, stakes=(250., 250., 250., 250.)),
     dict(n_msgs=24, steps=80, window=2, phi=6, scheduler="dss",
          quantum=12, window_slots=24, chunk_steps=8),
     FailureScenario.none()),
    ("mixed_cft_to_bft", CFT1, BFT1,
     dict(n_msgs=24, steps=60, window=2, phi=6, window_slots=24,
          chunk_steps=4),
     FailureScenario.none()),
    ("mixed_bft_to_cft", BFT1, CFT1,
     dict(n_msgs=24, steps=60, window=2, phi=6, window_slots=24,
          chunk_steps=4),
     FailureScenario.none()),
    ("ack_advance_liar", BFT1, BFT1,
     dict(n_msgs=24, steps=120, window=1, phi=6, window_slots=24,
          chunk_steps=8),
     FailureScenario(byz_ack_advance=(3, 0, 0, 0))),
]

IDS = [f[0] for f in FIXTURES]


def _dense(spec):
    return dataclasses.replace(spec, window_slots=0, chunk_steps=0)


@pytest.mark.parametrize("name,snd,rcv,simkw,fails", FIXTURES, ids=IDS)
def test_windowed_matches_dense(name, snd, rcv, simkw, fails):
    spec_w = build_spec(snd, rcv, SimConfig(**simkw), fails)
    assert spec_w.window_slots > 0
    jw = run_simulation(spec_w)
    jd = run_simulation(_dense(spec_w))
    for out in OUTPUTS:
        assert np.array_equal(getattr(jw, out), getattr(jd, out)), out
    for mname in METRICS:
        assert np.array_equal(getattr(jw.metrics, mname),
                              getattr(jd.metrics, mname)), mname
    # the frontier only moves forward and never overtakes the quack stream
    assert (np.diff(jw.gc_frontiers) >= 0).all()
    assert jw.gc_frontiers[-1] <= spec_w.m


@pytest.mark.parametrize("name,snd,rcv,simkw,fails", FIXTURES[:6], ids=IDS[:6])
def test_refsim_mirrors_window_rotation(name, snd, rcv, simkw, fails):
    """The numpy oracle replays the same frontier trajectory and proves
    each retirement safe (snapshot assertions inside run_reference)."""
    spec_w = build_spec(snd, rcv, SimConfig(**simkw), fails)
    jw = run_simulation(spec_w)
    rw = run_reference(spec_w)          # asserts retirement safety itself
    for jout, rout in zip(OUTPUTS, ("quack_time", "deliver_time", "retry",
                                    "recv_has")):
        assert np.array_equal(getattr(jw, jout), getattr(rw, rout)), jout
    assert np.array_equal(jw.gc_frontiers, rw.gc_frontiers)
    if rw.gc_frontiers[-1] > 0:
        # §4.3: a retired slot is QUACKed at every sender — its stake-
        # weighted claim mass reached u_r + 1 before it was forgotten.
        assert rw.retired_quack_margin >= spec_w.quack_thresh


def test_rotation_actually_happens():
    spec = build_spec(BFT1, BFT1,
                      SimConfig(n_msgs=24, steps=30, window=1, phi=6,
                                window_slots=16, chunk_steps=4))
    jw = run_simulation(spec)
    assert jw.gc_frontiers.max() > 0          # window really slid
    assert (jw.deliver_time >= 0).all()


def test_window_overflow_raises_in_strict_mode():
    """With adaptive growth disabled, a window too small for the in-flight
    set fails loudly, not wrongly."""
    spec = build_spec(BFT1, BFT1,
                      SimConfig(n_msgs=64, steps=40, window=4, phi=6,
                                window_slots=8, chunk_steps=4,
                                adaptive_window=False))
    with pytest.raises(ValueError, match="window overflow"):
        run_simulation(spec)


# the §4.3 GC-stall attack: a partial broadcaster pins the frontier while
# originals keep dispatching, so an undersized window must grow.
GC_STALL = FailureScenario(byz_bcast_partial=(True, False, False, False),
                           bcast_limit=2)


@pytest.mark.parametrize("name,simkw,fails", [
    ("failure_free_lag",
     dict(n_msgs=128, steps=128 // 4 + 80, window=1, phi=6,
          window_slots=16, chunk_steps=8),
     FailureScenario.none()),
    ("gc_stall_adversary",
     dict(n_msgs=128, steps=128 // 4 + 80, window=1, phi=6,
          window_slots=16, chunk_steps=8),
     GC_STALL),
], ids=["failure_free_lag", "gc_stall_adversary"])
def test_adaptive_window_growth(name, simkw, fails):
    """Overflow grows the window (2x, state migrated on device) instead of
    raising; the grown run stays windowed and bit-identical to dense."""
    spec = build_spec(BFT1, BFT1, SimConfig(**simkw), fails)
    rw = run_simulation(spec)
    rd = run_simulation(_dense(spec))
    for out in OUTPUTS:
        assert np.array_equal(getattr(rw, out), getattr(rd, out)), out
    assert rw.final_window_slots > spec.window_slots      # actually grew
    assert rw.final_window_slots < spec.m                 # still windowed
    assert rw.gc_frontiers.max() > 0                      # and rotated
    assert (rw.deliver_time >= 0).all()
    # the numpy oracle mirrors the same growth decisions, so the frontier
    # trajectories still agree bit-for-bit.
    rr = run_reference(spec)
    assert np.array_equal(rw.gc_frontiers, rr.gc_frontiers)


def test_adaptive_window_dense_fallback_migrates_state():
    """When a stalled frontier would force W to reach M, the scan state
    migrates into the dense layout (base 0, W = M) and the same chunked
    run continues — partial progress is kept (the frontier trajectory
    carries on past the migration point), never rerun, and every output
    is still bit-identical to a dense run from round 0."""
    fails = FailureScenario(byz_bcast_partial=(True, False, False, False),
                            bcast_limit=2, crash_r=(-1, 8, -1, -1))
    spec = build_spec(BFT1, BFT1,
                      SimConfig(n_msgs=64, steps=200, window=1, phi=6,
                                window_slots=16, chunk_steps=8), fails)
    rw = run_simulation(spec)
    rd = run_simulation(_dense(spec))
    for out in OUTPUTS:
        assert np.array_equal(getattr(rw, out), getattr(rd, out)), out
    for mname in METRICS:
        assert np.array_equal(getattr(rw.metrics, mname),
                              getattr(rd.metrics, mname)), mname
    assert rw.final_window_slots == spec.m         # ended in dense layout
    # the run kept its pre-migration progress and kept rotating after the
    # migration: a real, monotone frontier trajectory, not the trivial [0]
    assert (np.diff(rw.gc_frontiers) >= 0).all()
    assert rw.gc_frontiers.max() > 0
    assert rw.spec is spec                         # result keeps the spec
    rr = run_reference(spec)                       # oracle mirrors migration
    assert np.array_equal(rw.gc_frontiers, rr.gc_frontiers)


def test_long_stream_constant_state():
    """Long-horizon run: scan state is O(W), not O(M), and the stream
    completes — the paper's P1 constant-metadata invariant applied to the
    simulator itself."""
    m = 20_000
    sim = SimConfig(n_msgs=m, steps=m // 16 + 60, window=4, phi=32,
                    window_slots="auto", chunk_steps=32)
    spec = build_spec(BFT1, BFT1, sim)
    assert spec.window_slots < m // 4          # genuinely windowed
    small = build_spec(BFT1, BFT1, dataclasses.replace(
        sim, n_msgs=m // 10, steps=m // 160 + 60))
    assert spec.scan_state_nbytes() == small.scan_state_nbytes()
    r = run_simulation(spec)
    assert (r.deliver_time >= 0).all()
    assert (r.quack_time >= 0).all()
    assert r.total_cross_msgs() == m           # P1: one cross copy per msg
    assert r.gc_frontiers[-1] == m


def test_batch_matches_sequential():
    sim = SimConfig(n_msgs=24, steps=120, window=1, phi=6)
    scenarios = [
        FailureScenario.none(),
        FailureScenario(crash_s=(1, -1, -1, -1)),
        FailureScenario(byz_recv_drop=(True, False, False, False),
                        byz_ack_low=(False, True, False, False)),
        FailureScenario(byz_bcast_partial=(True, False, False, False),
                        bcast_limit=2, crash_r=(-1, 8, -1, -1)),
        FailureScenario.crash_fraction(4, 4, 0.33, seed=1),
    ]
    specs = [build_spec(BFT1, BFT1, sim, f) for f in scenarios]
    batched = run_simulation_batch(specs)
    for spec, br in zip(specs, batched):
        sr = run_simulation(spec)
        for out in OUTPUTS:
            assert np.array_equal(getattr(br, out), getattr(sr, out)), out
        for mname in METRICS:
            assert np.array_equal(getattr(br.metrics, mname),
                                  getattr(sr.metrics, mname)), mname


BATCH_SCENARIOS = [
    FailureScenario.none(),
    FailureScenario(crash_s=(1, -1, -1, -1)),
    FailureScenario(byz_recv_drop=(True, False, False, False),
                    byz_ack_low=(False, True, False, False)),
    FailureScenario(byz_bcast_partial=(True, False, False, False),
                    bcast_limit=2, crash_r=(-1, 8, -1, -1)),
    FailureScenario.crash_fraction(4, 4, 0.33, seed=1),
]


def test_batch_windowed_matches_sequential_and_dense():
    """Windowed specs batch with per-scenario window bases: one vmapped
    chunk stream, bit-identical to per-scenario windowed AND dense runs
    (outputs, metric streams, and each scenario's frontier trajectory)."""
    sim = SimConfig(n_msgs=24, steps=150, window=1, phi=6,
                    window_slots=24, chunk_steps=8)
    specs = [build_spec(BFT1, BFT1, sim, f) for f in BATCH_SCENARIOS]
    assert all(s.window_slots > 0 for s in specs)
    batched = run_simulation_batch(specs)
    rotated = 0
    for spec, br in zip(specs, batched):
        sw = run_simulation(spec)
        sd = run_simulation(_dense(spec))
        for out in OUTPUTS:
            assert np.array_equal(getattr(br, out), getattr(sw, out)), out
            assert np.array_equal(getattr(br, out), getattr(sd, out)), out
        for mname in METRICS:
            assert np.array_equal(getattr(br.metrics, mname),
                                  getattr(sw.metrics, mname)), mname
        assert np.array_equal(br.gc_frontiers, sw.gc_frontiers)
        assert br.final_window_slots == sw.final_window_slots
        rotated += int(br.gc_frontiers.max() > 0)
    # the batch genuinely ran windowed: most scenarios rotated, and the
    # per-scenario trajectories diverge (bases are truly per-scenario).
    assert rotated >= 3
    trajs = {tuple(br.gc_frontiers) for br in batched}
    assert len(trajs) > 1


def test_batch_windowed_rotation_smaller_window():
    """A genuinely sliding batch (W < M) with staggered crash scenarios."""
    sim = SimConfig(n_msgs=24, steps=60, window=1, phi=6,
                    window_slots=16, chunk_steps=4)
    scenarios = [FailureScenario.none(),
                 FailureScenario(crash_r=(-1, -1, -1, 40)),
                 FailureScenario(crash_s=(-1, -1, 45, -1))]
    specs = [build_spec(BFT1, BFT1, sim, f) for f in scenarios]
    batched = run_simulation_batch(specs)
    for spec, br in zip(specs, batched):
        sw = run_simulation(spec)
        for out in OUTPUTS:
            assert np.array_equal(getattr(br, out), getattr(sw, out)), out
        assert np.array_equal(br.gc_frontiers, sw.gc_frontiers)
        assert br.gc_frontiers.max() > 0


def test_batch_windowed_adaptive_growth():
    """Batched adaptive growth: a stalling scenario overflows the shared
    window, the whole batched state migrates to 2x W on device, and every
    scenario still matches its own dense run bit-for-bit."""
    sim = SimConfig(n_msgs=128, steps=128 // 4 + 80, window=1, phi=6,
                    window_slots=16, chunk_steps=8)
    scenarios = [FailureScenario.none(), GC_STALL]
    specs = [build_spec(BFT1, BFT1, sim, f) for f in scenarios]
    batched = run_simulation_batch(specs)
    for spec, br in zip(specs, batched):
        sd = run_simulation(_dense(spec))
        for out in OUTPUTS:
            assert np.array_equal(getattr(br, out), getattr(sd, out)), out
        assert br.final_window_slots > spec.window_slots   # grew
        assert br.final_window_slots < spec.m              # still windowed
        assert br.gc_frontiers.max() > 0                   # and rotated
    # the stalled scenario's frontier genuinely lags the clean one
    assert not np.array_equal(batched[0].gc_frontiers,
                              batched[1].gc_frontiers)


def test_result_field_parity_across_paths():
    """Dense, windowed and batched results populate the same SimResult
    fields: gc_frontiers is never None (dense = trivial [0] trajectory)
    and final_window_slots reports the width the run ended with."""
    sim_w = SimConfig(n_msgs=24, steps=30, window=1, phi=6,
                      window_slots=16, chunk_steps=4)
    sim_d = SimConfig(n_msgs=24, steps=30, window=1, phi=6)
    spec_w = build_spec(BFT1, BFT1, sim_w)
    spec_d = build_spec(BFT1, BFT1, sim_d)
    rw = run_simulation(spec_w)
    rd = run_simulation(spec_d)
    batch = run_simulation_batch([spec_d, spec_d])
    batch_w = run_simulation_batch([spec_w, spec_w])
    for r in [rw, rd, *batch, *batch_w]:
        assert r.gc_frontiers is not None
        assert r.gc_frontiers.dtype == np.int64
        assert r.final_window_slots is not None
        assert (np.diff(r.gc_frontiers) >= 0).all()
    assert np.array_equal(rd.gc_frontiers, np.zeros(1, dtype=np.int64))
    assert rd.final_window_slots == spec_d.m
    assert rw.final_window_slots == spec_w.window_slots
    for r in batch:
        assert np.array_equal(r.gc_frontiers, np.zeros(1, dtype=np.int64))
    for r in batch_w:
        assert r.gc_frontiers.max() > 0


def test_scan_state_nbytes_matches_carried_state():
    """``SimSpec.scan_state_nbytes`` equals the bytes of the state the
    compiled runners actually carry (derived via ``jax.eval_shape``, so
    it cannot drift from the implementation)."""
    import jax
    import jax.numpy as jnp

    def nbytes(tree):
        return sum(np.asarray(leaf).nbytes
                   for leaf in jax.tree_util.tree_leaves(tree))

    spec_w = build_spec(BFT1, BFT1,
                        SimConfig(n_msgs=24, steps=30, window=1, phi=6,
                                  window_slots=16, chunk_steps=4))
    nspec = _neutral(spec_w)
    cspec = dataclasses.replace(nspec, steps=0)
    state1 = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (1,) + x.shape),
        _init_state(nspec, spec_w.window_slots))
    state, _, _ = _compiled_batch_chunk(cspec, spec_w.window_slots, 4, True)(
        _stacked_fails([spec_w]), state1, np.int32(0))
    assert nbytes(state) == spec_w.scan_state_nbytes()   # batch of 1

    spec_d = build_spec(BFT1, BFT1, SimConfig(n_msgs=24, steps=30,
                                              window=1, phi=6))
    final, _ = _compiled_sim(_neutral(spec_d))(_fail_arrays(spec_d))
    assert nbytes(final) == spec_d.scan_state_nbytes()


def _random_scenario(rng, n_s, n_r):
    """Random UpRight-model failure placement, GC-stalling kinds included."""
    crash_s = [-1] * n_s
    crash_r = [-1] * n_r
    byz_recv = [False] * n_r
    byz_low = [False] * n_r
    byz_partial = [False] * n_r
    if rng.rand() < 0.7:
        crash_s[rng.randint(n_s)] = int(rng.randint(0, 10))
    kind = rng.choice(["none", "crash", "byz_drop", "ack_low",
                       "bcast_partial"])
    j = rng.randint(n_r)
    if kind == "crash":
        crash_r[j] = int(rng.randint(0, 10))
    elif kind == "byz_drop":
        byz_recv[j] = True
    elif kind == "ack_low":
        byz_low[j] = True
    elif kind == "bcast_partial":
        byz_partial[j] = True
    return FailureScenario(
        crash_s=tuple(crash_s), crash_r=tuple(crash_r),
        byz_recv_drop=tuple(byz_recv), byz_ack_low=tuple(byz_low),
        byz_bcast_partial=tuple(byz_partial),
        bcast_limit=int(rng.randint(1, 3)))


@pytest.mark.parametrize("seed", range(8))
def test_property_windowed_equals_dense(seed):
    """Property: windowed ≡ dense (bit-identical quack/deliver/retry) over
    randomly generated failure scenarios including GC-stalling ones.

    Deliberately hypothesis-free so it always executes (CI and local)
    instead of ``importorskip``-skipping; ``test_protocol_properties``
    layers the hypothesis-driven version on top where available.
    """
    rng = np.random.RandomState(seed)
    fails = _random_scenario(rng, 4, 4)
    sim = SimConfig(n_msgs=12, steps=160, window=1, phi=6,
                    window_slots=12, chunk_steps=int(rng.choice([4, 8, 16])))
    spec = build_spec(BFT1, BFT1, sim, fails)
    rw = run_simulation(spec)
    rd = run_simulation(_dense(spec))
    for out in ("quack_time", "deliver_time", "retry"):
        assert np.array_equal(getattr(rw, out), getattr(rd, out)), (out,
                                                                    fails)
    assert (np.diff(rw.gc_frontiers) >= 0).all()


def test_batch_rejects_mismatched_shapes():
    a = build_spec(BFT1, BFT1, SimConfig(n_msgs=24, steps=40, window=1,
                                         phi=6))
    b = build_spec(BFT1, BFT1, SimConfig(n_msgs=32, steps=40, window=1,
                                         phi=6))
    with pytest.raises(ValueError, match="failure masks"):
        run_simulation_batch([a, b])


def test_offset_quack_ops_match_dense_slice():
    """Windowed claim/missing ops == dense ops restricted to the window,
    whenever everything below the base is received (the GC invariant)."""
    rng = np.random.RandomState(0)
    m, base, w, phi = 40, 12, 20, 3
    eff = rng.rand(5, m) < 0.6
    eff[:, :base] = True                       # window invariant
    cum_d, claim_d, known_d = claim_bitmask(eff, phi)
    miss_d = missing_below_horizon(eff, phi)
    win = eff[:, base:base + w]
    cum_w, claim_w, known_w = claim_bitmask(win, phi, base, m)
    miss_w = missing_below_horizon(win, phi, base)
    assert np.array_equal(np.asarray(cum_w), np.asarray(cum_d))
    assert np.array_equal(np.asarray(claim_w),
                          np.asarray(claim_d)[:, base:base + w])
    assert np.array_equal(np.asarray(known_w),
                          np.asarray(known_d)[:, base:base + w])
    assert np.array_equal(np.asarray(miss_w),
                          np.asarray(miss_d)[:, base:base + w])
