"""Retransmission logic + the paper's bounds (Lemma 1, Theorem 1)."""

import jax.numpy as jnp

from repro.core.retransmit import (declared_lost, elect_retransmitter,
                                   faulty_pair_bound, max_retransmissions,
                                   theorem1_resends)


def test_election_formula():
    orig = jnp.array([0, 1, 2, 3])
    retry = jnp.array([1, 1, 2, 0])
    out = elect_retransmitter(orig, retry, 4)
    assert out.tolist() == [1, 2, 0, 3]


def test_declared_lost_needs_quorum():
    """No single Byzantine complainer can trigger a resend when r=1."""
    comp = jnp.zeros((4, 8), bool).at[0, 3].set(True)
    stakes = jnp.ones(4)
    assert not bool(declared_lost(comp, stakes, dup_threshold=2.0)[3])
    comp = comp.at[1, 3].set(True)
    assert bool(declared_lost(comp, stakes, dup_threshold=2.0)[3])


def test_lemma1_bound():
    assert max_retransmissions(1, 1) == 3
    assert max_retransmissions(2, 3) == 6


def test_theorem1_72_resends():
    # ceil(log_{3/4} 1e-9) = ceil(72.03) = 73; the paper states 72 (rounds
    # the 72.03 down). We keep the strict ceiling and accept both readings.
    assert theorem1_resends(1e-9, 0.75) in (72, 73)
    assert theorem1_resends(1e-6, 0.75) == 49


def test_theorem1_pair_bound():
    # Faulty/(ns*nr) <= 3/4 whenever both replication factors >= 2
    for f_s in range(1, 6):
        for f_r in range(1, 6):
            ns, nr = 3 * f_s + 1, 3 * f_r + 1
            assert faulty_pair_bound(ns, f_s, nr, f_r) <= 0.75 + 1e-9


def test_eight_retries_delivery_probability():
    """§4.2: with a fixed byzantine ratio (f = n/3, independent pairs),
    8 retries already push delivery probability past 99%."""
    p_pair_faulty = 1.0 - (2.0 / 3.0) ** 2     # sender or receiver faulty
    p_fail = p_pair_faulty ** 8
    assert p_fail < 0.01
