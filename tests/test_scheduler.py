"""Scheduler tests — includes the paper's Figure 7 worked examples."""

import numpy as np

from repro.core.scheduler import (dss_sequence, hamilton_apportion,
                                  lottery_sequence, round_robin_sequence,
                                  sender_assignment, skewed_rr_sequence)


def test_figure7_d1():
    c = hamilton_apportion(np.array([25, 25, 25, 25.0]), 100)
    assert c.tolist() == [25, 25, 25, 25]


def test_figure7_d2():
    c = hamilton_apportion(np.array([250, 250, 250, 250.0]), 100)
    assert c.tolist() == [25, 25, 25, 25]


def test_figure7_d3():
    # stakes (214, 262, 262, 262), q=100 -> (22, 26, 26, 26) per the paper
    c = hamilton_apportion(np.array([214, 262, 262, 262.0]), 100)
    assert c.tolist() == [22, 26, 26, 26]
    assert c.sum() == 100


def test_figure7_d4():
    c = hamilton_apportion(np.array([97, 1, 1, 1.0]), 10)
    assert c.tolist() == [10, 0, 0, 0]


def test_hamilton_quota_property():
    """Hamilton satisfies the quota rule: floor(SQ) <= c <= ceil(SQ)."""
    rng = np.random.RandomState(0)
    for _ in range(50):
        n = rng.randint(2, 12)
        stakes = rng.uniform(0.1, 100, size=n)
        q = rng.randint(1, 200)
        c = hamilton_apportion(stakes, q)
        sq = stakes / stakes.sum() * q
        assert c.sum() == q
        assert np.all(c >= np.floor(sq) - 1e-9)
        assert np.all(c <= np.ceil(sq) + 1e-9)


def test_dss_short_term_fairness():
    """DSS (smooth interleave) spreads each node through the quantum —
    the property lottery scheduling lacks (§5.2)."""
    stakes = np.array([4.0, 4.0])
    seq = dss_sequence(stakes, 8, 8)
    # perfectly alternating halves: no node takes >2 consecutive slots
    runs = []
    run = 1
    for a, b in zip(seq, seq[1:]):
        run = run + 1 if a == b else 1
        runs.append(run)
    assert max(runs, default=1) <= 2


def test_skewed_rr_serializes():
    stakes = np.array([6.0, 1.0, 1.0])
    seq = skewed_rr_sequence(stakes, 8)
    # strawman V1: node 0 owns a contiguous block
    assert seq[:6].tolist() == [0] * 6


def test_lottery_long_run_fair():
    stakes = np.array([3.0, 1.0])
    seq = lottery_sequence(stakes, 20000, seed=1)
    frac = (seq == 0).mean()
    assert abs(frac - 0.75) < 0.02


def test_round_robin():
    assert round_robin_sequence(4, 8).tolist() == [0, 1, 2, 3, 0, 1, 2, 3]


def test_sender_assignment_dispatch():
    for sched in ("round_robin", "dss", "skewed_rr", "lottery"):
        seq = sender_assignment(sched, np.ones(4), 16, quantum=8)
        assert seq.shape == (16,)
        assert seq.min() >= 0 and seq.max() < 4
