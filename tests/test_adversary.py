"""Byzantine adversary palette: oracle equivalence, retirement safety,
mid-stream reconfiguration, and streaming SLO degradation.

Every palette adversary (equivocating senders, stale/replayed QUACK
acks, §4.3 highest-quacked liars, selective per-pair drops, greedy
stake-weighted quorum attacks) must be mirrored bit-exactly by the numpy
oracle across the dense, windowed, superchunk-fused and Pallas-kernel
engine paths, and across chained multi-link topologies. The §4.3
retirement-safety invariant — no undelivered message is ever retired by
the GC frontier — must hold for every scenario whose fabricating stake
stays inside the provable budget (``repro.adversary.safety``). Mid-stream
reconfigurations (remove/join a replica, re-weight stakes) replay
bit-exactly against both a from-scratch run and the oracle with zero
warm recompiles, and a streaming session under each attack degrades
visibly (SLO watchdog breach) and recovers after the heal.

The oracle-equivalence and safety sweeps are seeded and always run; a
hypothesis twin widens the same properties to random adversary
placements when hypothesis is installed.
"""

import os
import sys

import numpy as np
import pytest

from repro.adversary import (ADVERSARY_KINDS, adversary_scenario,
                             assert_safe_retirement, equivocators, hq_liars,
                             join_receiver, quorum_budget, remove_receiver,
                             selective_drops, stake_attack, stale_ackers,
                             streaming_attack)
from repro.core import FailureScenario, RSMConfig, SimConfig
from repro.core.refsim import run_reference
from repro.core.simulator import (build_spec, chunk_trace_count,
                                  run_simulation, spec_with_quorum)
from repro.obs.live import SLOConfig
from repro.replay import (Injection, RunTrace, record_simulation, replay,
                          replay_oracle)
from repro.stream.session import StreamConfig, StreamSession
from repro.topology import Topology, run_topology, run_topology_reference

BFT1 = RSMConfig(n=4, u=1, r=1)
OUTPUTS = ("quack_time", "deliver_time", "retry", "recv_has")
METRICS = ("cross_msgs", "intra_msgs", "resends")


def _sim(windowed: bool, superchunk: int = 1, **kw) -> SimConfig:
    base = dict(n_msgs=48, steps=64, window=2, phi=3, seed=7)
    if windowed:
        base.update(window_slots=64, chunk_steps=8, superchunk=superchunk)
    base.update(kw)
    return SimConfig(**base)


def _assert_engine_matches_oracle(spec, ctx: str):
    res = run_simulation(spec)
    ref = run_reference(spec)
    for f in OUTPUTS:
        assert np.array_equal(np.asarray(getattr(res, f)),
                              getattr(ref, f)), (ctx, f)
    for f in METRICS:
        assert np.array_equal(np.asarray(getattr(res.metrics, f)),
                              getattr(ref, f)), (ctx, f)
    if res.gc_frontiers is not None and ref.gc_frontiers is not None:
        assert np.array_equal(np.asarray(res.gc_frontiers),
                              ref.gc_frontiers), ctx
    return res, ref


# --------------------------------------------------------------- palette

def test_palette_mask_validation():
    with pytest.raises(ValueError, match="out of range"):
        equivocators(4, (4,))
    with pytest.raises(ValueError, match="out of range"):
        stale_ackers(4, (-1,))
    with pytest.raises(ValueError, match="advance"):
        hq_liars(4, (0,), advance=0)
    with pytest.raises(ValueError, match="out of range"):
        selective_drops(4, 4, [(0, 5)])
    with pytest.raises(ValueError, match="side"):
        stake_attack((1.0,) * 4, 2.0, side="auditor")
    with pytest.raises(ValueError, match="unknown adversary kind"):
        adversary_scenario("bribery", 4, 4)
    with pytest.raises(ValueError, match="unknown adversary kind"):
        streaming_attack("bribery", 4, 4)


def test_palette_scenarios_validate():
    """Every generated scenario passes FailureScenario.validate for the
    RSM pair it was built for (shape contract of build_spec)."""
    for kind in ADVERSARY_KINDS:
        for seed in range(3):
            sc = adversary_scenario(kind, 4, 4, seed=seed)
            sc.validate(4, 4, 64)
        streaming_attack(kind, 4, 4).validate(4, 4, 64)


def test_stake_attack_respects_budget():
    """The greedy coalition is maximal but stays strictly below the
    threshold — the strongest adversary the safety argument admits."""
    sc = stake_attack((3.0, 2.0, 1.0, 1.0), 4.0, side="receiver")
    adv = np.asarray(sc.byz_ack_advance) > 0
    st = np.asarray((3.0, 2.0, 1.0, 1.0))
    assert 0 < st[adv].sum() < 4.0
    # greedy: the stake-3 replica must be in (3 < 4), stake-2 not (5 >= 4)
    assert adv[0] and not adv[1]
    spec = build_spec(BFT1, BFT1, _sim(True), failures=sc)
    spec = spec_with_quorum(spec, stakes_r=(3.0, 2.0, 1.0, 1.0),
                            quack_thresh=4.0)
    budget = quorum_budget(spec)
    assert budget.provable and budget.receiver_margin > 0


def test_quorum_budget_detects_owned_quorum():
    """A coalition at or above the threshold is not provable, and the
    safety assertion refuses to bless it."""
    sc = FailureScenario(byz_ack_advance=(4, 4, 0, 0))
    spec = build_spec(BFT1, BFT1, _sim(True), failures=sc)
    assert not quorum_budget(spec).provable
    with pytest.raises(ValueError, match="not provable"):
        assert_safe_retirement(spec, run_reference(spec))


# ----------------------------------------------- oracle equivalence sweep

ENGINE_PATHS = [("dense", False, 1), ("windowed", True, 1),
                ("superchunk", True, 8)]


@pytest.mark.parametrize("kind", ADVERSARY_KINDS)
@pytest.mark.parametrize("path,windowed,k", ENGINE_PATHS,
                         ids=[p[0] for p in ENGINE_PATHS])
def test_adversary_matches_oracle(kind, path, windowed, k):
    """Seeded sweep: every adversary kind is bit-identical between the
    engine (dense / windowed / superchunk-fused) and the numpy oracle,
    including per-step wire metrics, and never retires an undelivered
    message."""
    for seed in (0, 1):
        sc = adversary_scenario(kind, 4, 4, seed=seed)
        spec = build_spec(BFT1, BFT1, _sim(windowed, k), failures=sc)
        res, ref = _assert_engine_matches_oracle(
            spec, f"{kind}/{path}/seed{seed}")
        if windowed:
            assert ref.retired_undelivered == 0, (kind, seed)
            if quorum_budget(spec).provable:
                assert_safe_retirement(spec, ref)
                assert_safe_retirement(spec, res)


@pytest.mark.parametrize("kind", ADVERSARY_KINDS)
def test_adversary_pallas_quack_matches(kind):
    """The Pallas quorum kernel agrees with the oracle under every
    adversary kind (interpret mode off-TPU)."""
    sc = adversary_scenario(kind, 4, 4, seed=0)
    spec = build_spec(BFT1, BFT1, _sim(True, use_pallas_quack=True),
                      failures=sc)
    _assert_engine_matches_oracle(spec, f"{kind}/pallas")


def test_adversary_combo_with_quorum_reweight():
    """Composed masks (equivocation + hq lie + stale ack + drops + a
    crash) under a non-uniform stake vector still mirror the oracle."""
    dp = tuple(tuple(i == 0 and j in (0, 2) for j in range(4))
               for i in range(4))
    sc = FailureScenario(byz_equiv_send=(True, False, False, False),
                         byz_hq_advance=(0, 2, 0, 0),
                         byz_ack_stale=(False, True, False, False),
                         drop_pair=dp, crash_r=(-1, -1, -1, 30))
    for windowed in (False, True):
        spec = build_spec(BFT1, BFT1, _sim(windowed), failures=sc)
        spec = spec_with_quorum(spec, stakes_r=(2.0, 1.0, 1.0, 1.0),
                                quack_thresh=3.0)
        _assert_engine_matches_oracle(spec, f"combo/windowed={windowed}")


def test_adversary_chain_matches_oracle():
    """Chained topology with a different adversary on each hop: the
    vmapped engine and the multi-link numpy mirror agree bit-for-bit."""
    sim = SimConfig(n_msgs=24, steps=80, window=1, phi=6, window_slots=16,
                    chunk_steps=4)
    topo = Topology.chain(
        ["a", "b", "c"], BFT1, sim,
        failures={"a->b": adversary_scenario("stale_ack", 4, 4, seed=1),
                  "b->c": selective_drops(4, 4, [(0, 0), (1, 2)])})
    er = run_topology(topo)
    rr = run_topology_reference(topo)
    for lname in topo.link_names:
        for out in OUTPUTS:
            assert np.array_equal(
                np.asarray(getattr(er[lname].result, out)),
                np.asarray(getattr(rr[lname].result, out))), (lname, out)
        assert np.array_equal(er[lname].result.gc_frontiers,
                              rr[lname].result.gc_frontiers), lname


# ------------------------------------------------- hypothesis widening

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def adversary_specs(draw):
        """Random palette scenario + engine path + optional stake
        re-weight, with the fabricating stake kept inside the provable
        §4.3 budget."""
        kind = draw(st.sampled_from(ADVERSARY_KINDS))
        seed = draw(st.integers(0, 63))
        sc = adversary_scenario(kind, 4, 4, seed=seed)
        windowed = draw(st.booleans())
        k = draw(st.sampled_from([1, 8])) if windowed else 1
        spec = build_spec(BFT1, BFT1,
                          _sim(windowed, k, seed=draw(st.integers(0, 7))),
                          failures=sc)
        if draw(st.booleans()):
            # re-weight one honest replica's stake upward and raise the
            # QUACK threshold with it (membership-weight churn)
            boosted = draw(st.integers(2, 3))
            stakes = tuple(2.0 if i == boosted else 1.0 for i in range(4))
            spec = spec_with_quorum(spec, stakes_r=stakes,
                                    quack_thresh=3.0, dup_thresh=2.0)
        return spec, f"{kind}/seed{seed}/windowed={windowed}/K={k}"

    @settings(max_examples=20, deadline=None)
    @given(adversary_specs())
    def test_property_adversary_oracle_and_gc_safety(drawn):
        """Random adversary placements: engine ≡ oracle bit-for-bit, and
        provable stake budgets never retire an undelivered message."""
        spec, ctx = drawn
        res, ref = _assert_engine_matches_oracle(spec, ctx)
        if ref.retired_undelivered is not None:
            assert ref.retired_undelivered == 0, ctx
            if quorum_budget(spec).provable:
                assert_safe_retirement(spec, ref)
                assert_safe_retirement(spec, res)


# --------------------------------------------- mid-stream reconfiguration

REPLAY_SIM = SimConfig(n_msgs=64, steps=64, window=2, phi=3, seed=3,
                       window_slots=64, chunk_steps=16)


def _assert_replay_consistent(trace, inj, resume_t):
    """Replay-from-checkpoint ≡ from-scratch engine ≡ numpy oracle."""
    ri = replay(trace, resume_t, inj)[0]
    scratch = replay(trace, 0, inj)[0]
    ref = replay_oracle(trace, inj)
    for f in OUTPUTS:
        a = np.asarray(getattr(ri, f))
        assert np.array_equal(a, np.asarray(getattr(scratch, f))), f
        assert np.array_equal(a, getattr(ref, f)), f
    return ri


def test_remove_receiver_reconfig_replays_bitexact():
    spec = build_spec(BFT1, BFT1, REPLAY_SIM)
    _, trace = record_simulation(spec)
    inj = [remove_receiver(4, 3, 16, stakes_r=(1.0, 1.0, 1.0, 1.0),
                           quack_thresh=2.0, dup_thresh=2.0)]
    assert inj[0].reconfigures and inj[0].failures.crash_r[3] == 16
    ri = _assert_replay_consistent(trace, inj, 16)
    # the shrunk membership still delivers the whole stream
    assert (np.asarray(ri.deliver_time) >= 0).all()


def test_join_receiver_reconfig_replays_bitexact():
    """The base run models the future member as crashed-from-0 with zero
    stake; the injection weights it in at a chunk boundary."""
    spec = build_spec(BFT1, BFT1, REPLAY_SIM,
                      failures=FailureScenario(crash_r=(-1, -1, -1, 0)))
    spec = spec_with_quorum(spec, stakes_r=(1.0, 1.0, 1.0, 0.0))
    _, trace = record_simulation(spec)
    inj = [join_receiver(4, 3, 32, stakes_r=(1.0, 1.0, 1.0, 1.0),
                         quack_thresh=2.0, dup_thresh=2.0)]
    ri = _assert_replay_consistent(trace, inj, 32)
    assert (np.asarray(ri.deliver_time) >= 0).all()


def test_adversary_injection_replays_bitexact():
    spec = build_spec(BFT1, BFT1, REPLAY_SIM)
    _, trace = record_simulation(spec)
    dp = tuple(tuple(i == 1 and j == 2 for j in range(4)) for i in range(4))
    inj = [Injection(32, failures=FailureScenario(
        byz_ack_stale=(False, True, False, False), drop_pair=dp))]
    _assert_replay_consistent(trace, inj, 32)


def test_stake_reweight_injection_replays_bitexact():
    """A pure quorum-rule edit (no mask change) is a valid injection."""
    spec = build_spec(BFT1, BFT1, REPLAY_SIM)
    _, trace = record_simulation(spec)
    inj = [Injection(16, stakes_r=(2.0, 1.0, 1.0, 1.0), quack_thresh=3.0)]
    _assert_replay_consistent(trace, inj, 16)


def test_empty_injection_rejected():
    spec = build_spec(BFT1, BFT1, REPLAY_SIM)
    _, trace = record_simulation(spec)
    with pytest.raises(ValueError, match="edits nothing"):
        replay(trace, 16, [Injection(16)])


def test_reconfig_zero_warm_recompiles():
    """Swapping membership, stakes and adversary masks mid-replay rides
    entirely on traced inputs: after one warm-up replay, arbitrarily
    different reconfigurations trace zero new chunk programs."""
    spec = build_spec(BFT1, BFT1, REPLAY_SIM)
    _, trace = record_simulation(spec)
    warmup = [remove_receiver(4, 3, 16, stakes_r=(1.0,) * 4,
                              quack_thresh=2.0, dup_thresh=2.0)]
    replay(trace, 16, warmup)
    before = chunk_trace_count()
    variants = [
        [remove_receiver(4, 2, 32, stakes_r=(1.0,) * 4,
                         quack_thresh=2.0, dup_thresh=2.0)],
        [Injection(16, stakes_r=(2.0, 1.0, 1.0, 1.0), quack_thresh=3.0)],
        [Injection(32, failures=streaming_attack("selective_drop", 4, 4))],
        [Injection(16, failures=adversary_scenario("equivocate", 4, 4)),
         Injection(48, stakes_r=(1.0, 2.0, 1.0, 1.0), quack_thresh=3.0)],
    ]
    for inj in variants:
        replay(trace, 16, inj)
    assert chunk_trace_count() == before, \
        "reconfiguration forced a chunk retrace"


def test_trace_roundtrip_preserves_adversary_state(tmp_path):
    """Traces recorded under adversary masks + re-weighted quorums
    survive an npz save/load and replay identically."""
    sc = adversary_scenario("selective_drop", 4, 4, seed=2)
    spec = build_spec(BFT1, BFT1, REPLAY_SIM, failures=sc)
    spec = spec_with_quorum(spec, stakes_r=(2.0, 1.0, 1.0, 1.0),
                            quack_thresh=3.0)
    _, trace = record_simulation(spec)
    inj = [Injection(32, failures=stale_ackers(4, (1,), base=sc))]
    ri = replay(trace, 32, inj)[0]
    path = os.path.join(str(tmp_path), "trace.npz")
    trace.save(path)
    t2 = RunTrace.load(path)
    r2 = replay(t2, 32, inj)[0]
    for f in OUTPUTS:
        assert np.array_equal(np.asarray(getattr(ri, f)),
                              np.asarray(getattr(r2, f))), f


# ----------------------------------------------- streaming SLO degradation

@pytest.mark.parametrize("kind", ADVERSARY_KINDS)
def test_streaming_attack_breaches_and_recovers(kind):
    """Graceful degradation, not just survival: each palette attack
    switched on mid-stream trips an SLO watchdog breach, and healing it
    produces the matching recovery event — while the stream still
    delivers its whole horizon."""
    sim = SimConfig(window=2, phi=3, chunk_steps=16, window_slots="auto")
    cfg = StreamConfig(horizon=1024, utilization=0.5,
                       slo=SLOConfig(p99_latency_rounds=24,
                                     resend_rate=0.25,
                                     frontier_stall_chunks=2),
                       report_every=2)
    sess = StreamSession(BFT1, BFT1, sim, cfg)
    chunk = max(sess.spec.chunk_steps, 1)
    res = sess.run(fail_schedule={4 * chunk: streaming_attack(kind, 4, 4),
                                  16 * chunk: FailureScenario.none()})
    assert not res.problems, (kind, res.problems)
    breach = [e for e in res.slo_events if not e.recovered]
    recov = [e for e in res.slo_events if e.recovered]
    assert breach, f"{kind}: attack caused no SLO breach"
    assert recov, f"{kind}: no SLO recovery after the heal"
    assert all(min(e.t for e in breach) >= 4 * chunk for e in breach), kind


# ------------------------------------------------------- bench smoke

def test_bench_adversary_smoke(tmp_path, monkeypatch):
    """Acceptance smoke for ``benchmarks.bench_adversary``: the palette
    + reconfig sweeps run at a tiny size, write the BENCH json, and the
    whole palette rides the honest compiled program (extra_traces 0)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if repo not in sys.path:
        sys.path.insert(0, repo)
    from benchmarks import bench_adversary as m
    out = os.path.join(str(tmp_path), "BENCH_adversary.json")
    rows = m.main(sizes=(256,), json_path=out)
    assert os.path.exists(out)
    pal = [r for r in rows if r["section"] == "palette"
           and r["kind"] != "honest"]
    assert {r["kind"] for r in pal} == set(ADVERSARY_KINDS)
    assert all(r["delivered"] == 256 for r in pal), pal
    assert all(r["extra_traces"] == 0 for r in rows), \
        [r for r in rows if r["extra_traces"]]
    assert {r["kind"] for r in rows if r["section"] == "reconfig"} == \
        {"remove_replica", "join_replica", "stake_reweight",
         "adversary_on_off"}
