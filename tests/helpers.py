"""Test helpers. Multi-device tests run in a subprocess so that
XLA_FLAGS=--xla_force_host_platform_device_count is never set globally
(plain tests must see 1 device)."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run a python snippet in a fresh process with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:{out.stdout}\nSTDERR:{out.stderr}")
    return out.stdout
