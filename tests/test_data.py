"""Data pipeline determinism + distribution sanity."""

import numpy as np

from repro.data import SyntheticTokens


def test_determinism_across_shardings():
    spec = SyntheticTokens(vocab=1000, seq_len=32, global_batch=8, seed=5)
    full = spec.batch_at(11)["tokens"]
    halves = [spec.batch_at(11, shard=i, n_shards=2)["tokens"]
              for i in range(2)]
    np.testing.assert_array_equal(full, np.concatenate(halves, axis=0))
    quarters = [spec.batch_at(11, shard=i, n_shards=4)["tokens"]
                for i in range(4)]
    np.testing.assert_array_equal(full, np.concatenate(quarters, axis=0))


def test_step_variation_and_repeatability():
    spec = SyntheticTokens(vocab=1000, seq_len=32, global_batch=4, seed=5)
    a = spec.batch_at(1)["tokens"]
    b = spec.batch_at(2)["tokens"]
    assert not np.array_equal(a, b)
    np.testing.assert_array_equal(a, spec.batch_at(1)["tokens"])


def test_token_range_and_skew():
    spec = SyntheticTokens(vocab=500, seq_len=256, global_batch=16, seed=0)
    t = spec.batch_at(0)["tokens"]
    assert t.min() >= 0 and t.max() < 500
    # zipf-ish: low ids more likely
    low = (t < 100).mean()
    assert low > 0.25
