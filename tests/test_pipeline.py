"""Pipelined superchunk engine: fusion, async drains, counters, Pallas.

The windowed engine fuses up to K = ``SimConfig.superchunk`` chunk
bodies into one compiled dispatch and drains a dispatch's K-deep output
queue while the next dispatch computes. The contract under test: **any K
is bit-identical to the synchronous K = 1 loop** — outputs, per-round
metric streams, GC-frontier trajectories, adaptive-growth events,
recorded traces — across every fusion-break boundary (adaptive growth,
dense fallback, recorder checkpoints, commit-floor callbacks,
failure-schedule swaps), while the dispatch and host-sync counters
(`chunk_dispatch_count` / `host_sync_count`) shrink ~K×. The counter
assertions are what the CI smoke relies on — deterministic counts, not
wall time.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import FailureScenario, RSMConfig, SimConfig
from repro.core.quack import stake_quorum_bitmap
from repro.core.simulator import (build_spec, run_simulation,
                                  run_simulation_batch)

BFT1 = RSMConfig.bft(1)

OUTPUTS = ("quack_time", "deliver_time", "retry", "recv_has")
METRICS = ("cross_msgs", "intra_msgs", "resends", "acks", "delivered",
           "min_quack_prefix")

GC_STALL = FailureScenario(byz_bcast_partial=(True, False, False, False),
                           bcast_limit=2)
STALL_PLUS_CRASH = FailureScenario(
    byz_bcast_partial=(True, False, False, False), bcast_limit=2,
    crash_r=(-1, 8, -1, -1))

# every fusion-break class is represented: plain rotation, adaptive
# window growth (frontier stalled mid-run), dense-layout fallback, and
# a crashed sender (retransmission election stays busy all run).
FIXTURES = [
    ("rotating", dict(n_msgs=128, steps=128 // 4 + 40, window=1, phi=6,
                      window_slots=32, chunk_steps=4),
     FailureScenario.none()),
    ("adaptive_growth", dict(n_msgs=128, steps=128 // 4 + 80, window=1,
                             phi=6, window_slots=16, chunk_steps=8),
     GC_STALL),
    ("dense_fallback", dict(n_msgs=64, steps=200, window=1, phi=6,
                            window_slots=16, chunk_steps=8),
     STALL_PLUS_CRASH),
    ("crash_sender", dict(n_msgs=24, steps=150, window=1, phi=6,
                          window_slots=24, chunk_steps=8),
     FailureScenario(crash_s=(1, -1, -1, -1))),
]
IDS = [f[0] for f in FIXTURES]


def _spec(simkw, fails, k):
    sim = SimConfig(debug_checks=True, superchunk=k, **simkw)
    return build_spec(BFT1, BFT1, sim, fails)


def _assert_same(a, b):
    for out in OUTPUTS:
        assert np.array_equal(getattr(a, out), getattr(b, out)), out
    for name in METRICS:
        assert np.array_equal(getattr(a.metrics, name),
                              getattr(b.metrics, name)), name
    assert np.array_equal(a.gc_frontiers, b.gc_frontiers)
    assert a.final_window_slots == b.final_window_slots
    assert a.window_growth_events == b.window_growth_events


@pytest.mark.parametrize("k", [2, 8])
@pytest.mark.parametrize("name,simkw,fails", FIXTURES, ids=IDS)
def test_superchunk_bit_identical_to_sync(name, simkw, fails, k):
    """K ∈ {2, 8} ≡ K = 1 across every fusion-break class — outputs,
    metric streams, frontier trajectories, growth events."""
    sync = run_simulation(_spec(simkw, fails, 1))
    fused = run_simulation(_spec(simkw, fails, k))
    _assert_same(sync, fused)


def test_superchunk_batch_bit_identical():
    """Fused batched sweeps (per-scenario window bases) ≡ sync sweeps."""
    simkw = dict(n_msgs=128, steps=128 // 4 + 60, window=1, phi=6,
                 window_slots=32, chunk_steps=8)
    scenarios = [FailureScenario.none(), GC_STALL,
                 FailureScenario(crash_s=(1, -1, -1, -1)),
                 FailureScenario.crash_fraction(4, 4, 0.33, seed=1)]
    b1 = run_simulation_batch([_spec(simkw, f, 1) for f in scenarios])
    b8 = run_simulation_batch([_spec(simkw, f, 8) for f in scenarios])
    for sync, fused in zip(b1, b8):
        _assert_same(sync, fused)


def test_dispatch_and_sync_counts_shrink():
    """The CI acceptance observable: at K = 8 the engine issues ~K×
    fewer device dispatches and host syncs than the synchronous loop —
    asserted via the analysis sanitizer's declarative contract
    (``<= ceil(C/K) + 2`` dispatches, 0 implicit transfers, 0 warm
    recompiles), on deterministic counters, not wall time."""
    from repro.analysis import dispatch_contract, sanitized

    simkw = dict(n_msgs=512, steps=512 // 4 + 40, window=1, phi=6,
                 window_slots=256, chunk_steps=4)
    s1 = _spec(simkw, FailureScenario.none(), 1)
    s8 = _spec(simkw, FailureScenario.none(), 8)
    run_simulation(s1), run_simulation(s8)      # warm both programs

    # warm=True adds the zero-recompilation clause; sanitized() raises
    # on any violated ceiling, transfers and syncs included
    with sanitized(dispatch_contract(s1, warm=True)) as rep1:
        r1 = run_simulation(s1)
    with sanitized(dispatch_contract(s8, warm=True)) as rep8:
        r8 = run_simulation(s8)

    _assert_same(r1, r8)
    n_chunks = -(-s1.steps // s1.chunk_steps)
    assert rep1.dispatches == n_chunks          # sync loop: 1 per chunk
    # fused: ~steps/(K*chunk) (+1 for the final unrotated chunk and a
    # partial tail span) — the same ceiling the contract enforces
    assert rep8.dispatches <= -(-n_chunks // 8) + 2, rep8.to_dict()
    assert rep8.host_syncs <= rep8.dispatches + 2   # one drain/dispatch
    assert rep1.host_syncs >= n_chunks              # sync: one per chunk
    assert rep1.transfers == () and rep8.transfers == ()


def test_async_drain_overlap_engages():
    """With a window wide enough for the conservative bound, the engine
    launches dispatch k+1 before draining k (observable: results are
    still exact — this fixture's whole point is that the overlap path
    is the one executing; counters confirm the fused cadence)."""
    simkw = dict(n_msgs=256, steps=256 // 4 + 40, window=1, phi=6,
                 window_slots=128, chunk_steps=4)
    s1 = _spec(simkw, FailureScenario.none(), 1)
    s4 = _spec(simkw, FailureScenario.none(), 4)
    _assert_same(run_simulation(s1), run_simulation(s4))


def test_debug_checks_off_still_exact():
    """debug_checks only gates the host mirror assertion — results are
    identical with it off (the benchmark configuration)."""
    simkw = dict(n_msgs=128, steps=128 // 4 + 40, window=1, phi=6,
                 window_slots=32, chunk_steps=4)
    spec_dbg = _spec(simkw, GC_STALL, 8)
    spec_off = dataclasses.replace(spec_dbg, debug_checks=False)
    assert spec_dbg.debug_checks and not spec_off.debug_checks
    _assert_same(run_simulation(spec_dbg), run_simulation(spec_off))


def test_recorder_boundaries_flush_pipeline():
    """Recorded runs are a mandatory host-interaction path (chunk-at-a-
    time, checkpoints flush the pipeline): a trace recorded under K = 8
    with sparse checkpoints is bit-exact with the K = 1 trace, its
    replay reproduces the run, and — because the parent compiled every
    program the tail reuses — the replay retraces nothing."""
    from repro.replay import record_simulation, replay

    simkw = dict(n_msgs=96, steps=120, window=1, phi=6,
                 window_slots=24, chunk_steps=8)
    r1, tr1 = record_simulation(_spec(simkw, FailureScenario.none(), 1),
                                every=2)
    r8, tr8 = record_simulation(_spec(simkw, FailureScenario.none(), 8),
                                every=2)
    _assert_same(r1, r8)
    assert [c.t for c in tr1.checkpoints] == [c.t for c in tr8.checkpoints]
    for c1, c8 in zip(tr1.checkpoints, tr8.checkpoints):
        assert np.array_equal(c1.bases, c8.bases)
        assert np.array_equal(c1.bases_hist, c8.bases_hist)
        assert np.array_equal(c1.floors, c8.floors)
        for name in type(c1.state)._fields:
            assert np.array_equal(getattr(c1.state, name),
                                  getattr(c8.state, name)), name
        m1, m8 = c1.metrics(), c8.metrics()
        for name in METRICS:
            assert np.array_equal(getattr(m1, name),
                                  getattr(m8, name)), name
    mid = tr8.boundaries()[len(tr8.boundaries()) // 2]
    from repro.core.simulator import chunk_trace_count
    before = chunk_trace_count()
    replayed = replay(tr8, int(mid))[0]
    assert chunk_trace_count() == before    # zero-recompilation contract
    for out in OUTPUTS:
        assert np.array_equal(getattr(replayed, out), getattr(r8, out)), out


def test_commit_floor_boundaries_stay_synchronous():
    """Chained topologies (commit-floor callbacks every chunk) are a
    mandatory host-interaction boundary: K = 8 ≡ K = 1 including the
    per-chunk floor history."""
    from repro.topology import Topology, LinkSpec, run_topology

    def chain(k):
        return Topology(
            clusters={"a": BFT1, "b": BFT1, "c": BFT1},
            links=(LinkSpec("a->b", "a", "b"),
                   LinkSpec("b->c", "b", "c", upstream="a->b")),
            sim=SimConfig(n_msgs=96, steps=160, window=1, phi=6,
                          window_slots=24, chunk_steps=8, superchunk=k,
                          debug_checks=True))

    r1, r8 = run_topology(chain(1)), run_topology(chain(8))
    for name in ("a->b", "b->c"):
        for out in OUTPUTS:
            assert np.array_equal(getattr(r1[name].result, out),
                                  getattr(r8[name].result, out)), out
        assert np.array_equal(r1[name].commit_floors,
                              r8[name].commit_floors)
        assert np.array_equal(r1[name].result.gc_frontiers,
                              r8[name].result.gc_frontiers)


def test_fail_schedule_swap_breaks_fusion_exactly():
    """A mid-stream schedule edit (replay injection) lands on a fused
    run exactly as on the synchronous loop: replayed-with-injection ≡
    from-scratch merged schedule, for a superchunk=8 trace."""
    from repro.core.simulator import spec_with_failures
    from repro.replay import Injection, record_simulation, replay

    crash = FailureScenario(crash_s=(16, -1, -1, -1))
    simkw = dict(n_msgs=96, steps=120, window=1, phi=6,
                 window_slots=24, chunk_steps=8)
    spec = _spec(simkw, FailureScenario.none(), 8)
    _, trace = record_simulation(spec)
    edited = replay(trace, 16, [Injection(at_step=16, failures=crash)])[0]
    # from-scratch: crash in force from round 16 == crash masks with the
    # pre-16 prefix unaffected (crash_s=16 fires at round 16 exactly)
    scratch = run_simulation(spec_with_failures(spec, crash))
    for out in OUTPUTS:
        assert np.array_equal(getattr(edited, out),
                              getattr(scratch, out)), out


def test_superchunk_respects_strict_overflow():
    """Strict (adaptive_window=False) overflow still raises at the same
    boundary under fusion — the in-graph guard stops the span and the
    host re-checks exactly where K = 1 would have raised."""
    for k in (1, 8):
        sim = SimConfig(n_msgs=64, steps=40, window=4, phi=6,
                        window_slots=8, chunk_steps=4,
                        adaptive_window=False, superchunk=k)
        with pytest.raises(ValueError, match="window overflow"):
            run_simulation(build_spec(BFT1, BFT1, sim))


# --- Pallas QUACK kernel wiring -----------------------------------------

def test_stake_quorum_bitmap_pallas_matches_jnp():
    """Unit equivalence: the Pallas quorum kernel (interpret mode off-
    TPU) and the jnp einsum path agree exactly — quacked/lost bitmaps
    and contiguous quacked prefix."""
    import jax.numpy as jnp

    rng = np.random.RandomState(0)
    # 600: wider than one 512 block but not a multiple of it — the
    # padded-kernel path every auto/grown/dense window width exercises
    for s, r, w in [(4, 4, 24), (3, 5, 16), (2, 3, 130), (2, 3, 600)]:
        claims = jnp.asarray(rng.rand(s, r, w) < 0.5)
        comp = jnp.asarray(rng.rand(s, r, w) < 0.3)
        stakes = jnp.asarray(rng.randint(1, 5, size=r).astype(np.float32))
        jn = stake_quorum_bitmap(claims, comp, stakes, 3.0, 2.0,
                                 use_pallas=False)
        pl = stake_quorum_bitmap(claims, comp, stakes, 3.0, 2.0,
                                 use_pallas=True)
        for a, b in zip(jn, pl):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        # the lost-free variant (the hot loop's step-5 shape: the
        # complaints stream is cut at the kernel boundary)
        for up in (False, True):
            q, lost, p = stake_quorum_bitmap(claims, comp, stakes, 3.0,
                                             2.0, use_pallas=up,
                                             need_lost=False)
            assert lost is None
            assert np.array_equal(np.asarray(q), np.asarray(jn[0]))
            assert np.array_equal(np.asarray(p), np.asarray(jn[2]))


def test_pallas_quack_run_equivalence():
    """A windowed AND a dense run with use_pallas_quack=True are bit-
    identical to the jnp-path runs (the kernel sits inside the scan)."""
    simkw = dict(n_msgs=16, steps=40, window=1, phi=6, window_slots=16,
                 chunk_steps=4)
    spec = _spec(simkw, FailureScenario(crash_s=(1, -1, -1, -1)), 2)
    spec_p = dataclasses.replace(spec, use_pallas_quack=True)
    _assert_same(run_simulation(spec), run_simulation(spec_p))
    dense = dataclasses.replace(spec, window_slots=0, chunk_steps=0)
    dense_p = dataclasses.replace(dense, use_pallas_quack=True)
    rd, rdp = run_simulation(dense), run_simulation(dense_p)
    for out in OUTPUTS:
        assert np.array_equal(getattr(rd, out), getattr(rdp, out)), out


# --- randomized equivalence ---------------------------------------------

def _random_scenario(rng, n_s, n_r):
    crash_s = [-1] * n_s
    crash_r = [-1] * n_r
    byz_recv = [False] * n_r
    byz_low = [False] * n_r
    byz_partial = [False] * n_r
    if rng.rand() < 0.7:
        crash_s[rng.randint(n_s)] = int(rng.randint(0, 10))
    kind = rng.choice(["none", "crash", "byz_drop", "ack_low",
                       "bcast_partial"])
    j = rng.randint(n_r)
    if kind == "crash":
        crash_r[j] = int(rng.randint(0, 10))
    elif kind == "byz_drop":
        byz_recv[j] = True
    elif kind == "ack_low":
        byz_low[j] = True
    elif kind == "bcast_partial":
        byz_partial[j] = True
    return FailureScenario(
        crash_s=tuple(crash_s), crash_r=tuple(crash_r),
        byz_recv_drop=tuple(byz_recv), byz_ack_low=tuple(byz_low),
        byz_bcast_partial=tuple(byz_partial),
        bcast_limit=int(rng.randint(1, 3)))


@pytest.mark.parametrize("seed", range(6))
def test_property_superchunk_equals_sync_seeded(seed):
    """Hypothesis-free seeded twin of the property below, so the fused ≡
    sync invariant executes even where hypothesis is unavailable."""
    rng = np.random.RandomState(seed)
    fails = _random_scenario(rng, 4, 4)
    k = int(rng.choice([2, 3, 8]))
    simkw = dict(n_msgs=48, steps=160, window=1, phi=6,
                 window_slots=int(rng.choice([12, 16, 24])),
                 chunk_steps=int(rng.choice([4, 8])))
    _assert_same(run_simulation(_spec(simkw, fails, 1)),
                 run_simulation(_spec(simkw, fails, k)))


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), k=st.integers(2, 8),
           chunk=st.sampled_from([4, 8, 16]),
           w=st.sampled_from([12, 16, 24]))
    def test_property_superchunk_equals_sync(seed, k, chunk, w):
        """Property: for random fusion depth K, chunk length, window
        width and failure schedule, the fused engine ≡ the synchronous
        loop bit-for-bit (growth/dense-fallback boundaries included)."""
        rng = np.random.RandomState(seed)
        fails = _random_scenario(rng, 4, 4)
        simkw = dict(n_msgs=48, steps=160, window=1, phi=6,
                     window_slots=w, chunk_steps=chunk)
        _assert_same(run_simulation(_spec(simkw, fails, 1)),
                     run_simulation(_spec(simkw, fails, k)))
except ImportError:                                   # pragma: no cover
    pass
