"""Optimizer substrate tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, global_norm)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(cfg, g, params, opt)
    assert float(loss(params)) < 1e-3


def test_grad_clipping():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    g = {"w": jnp.full((4,), 100.0)}
    p2, opt = adamw_update(cfg, g, params, opt)
    # clipped update magnitude bounded by lr (adam normalizes to ~lr)
    assert float(jnp.abs(p2["w"]).max()) < 1.1


def test_global_norm():
    t = {"a": jnp.ones(4), "b": jnp.ones(9) * 2.0}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(4 + 36))


def test_cosine_schedule_shape():
    s = jnp.asarray([0, 50, 100, 5000, 10000])
    vals = cosine_schedule(s, warmup=100, total=10000)
    v = np.asarray(vals)
    assert v[0] == 0.0
    assert abs(v[2] - 1.0) < 1e-6
    assert v[3] < 1.0
    assert abs(v[4] - 0.1) < 1e-2
